"""End-to-end DGNN serving driver (the paper's deployment scenario).

Runs both base models (EvolveGCN -> V1/V3, GCRN-M2 -> V2/V3) over both
datasets (BC-Alpha, UCI), with the paper's ablation levels, and prints the
Table IV / Fig. 6 style comparison measured on this host. V3 is the
time-fused stream engine: the server batches snapshots into chunks and the
recurrent state — the node store for GCRN, the evolving weight matrices
for EvolveGCN — stays in VMEM across each chunk. Batched multi-stream
serving is included (--streams N).

    PYTHONPATH=src python examples/serve_stream.py [--snapshots 32] [--streams 4]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.dgnn import BC_ALPHA, UCI, DGNN_CONFIGS
from repro.core import (build_model, init_states_batched, run_batched,
                        run_stream, stack_time)
from repro.graph import (
    generate_temporal_graph,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)
from repro.serve import SnapshotServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshots", type=int, default=24)
    ap.add_argument("--streams", type=int, default=4)
    args = ap.parse_args()

    pairs = [("evolvegcn", ("v1", "v3")), ("gcrn-m2", ("v2", "v3"))]
    for ds in (BC_ALPHA, UCI):
        tg, ft = generate_temporal_graph(ds)
        snaps = slice_snapshots(tg, 1.0)[: args.snapshots]
        for name, modes in pairs:
            for m in ("baseline",) + modes:
                srv = SnapshotServer(DGNN_CONFIGS[name], ft,
                                     n_global=tg.n_global_nodes, mode=m)
                params, state = srv.init(jax.random.PRNGKey(0))
                _, outs, stats = srv.run(params, state, snaps)
                print(f"{ds.name:9s} {name:10s} {m:8s} "
                      f"{stats.mean_latency_ms:8.3f} ms/snapshot "
                      f"(host prep {np.mean(stats.preprocess_ms):.3f} ms, overlapped)")

    # batched multi-stream serving: the production throughput axis.
    # mode="v3" runs ALL B streams through ONE batched stream-kernel
    # launch (batch axis = leading grid dimension, one VMEM-resident
    # state store per stream).
    ds = BC_ALPHA
    tg, ft = generate_temporal_graph(ds)
    snaps = slice_snapshots(tg, 1.0)[: args.snapshots]
    pads = [pad_snapshot(renumber_and_normalize(s), ft, 640, 4096, 64)
            for s in snaps]
    sT = stack_time(pads)
    B = args.streams
    sTB = jax.tree.map(lambda a: np.stack([a] * B, axis=1), sT)
    cfg = DGNN_CONFIGS["gcrn-m2"]
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(0))
    for m in ("v2", "v3"):
        states = init_states_batched(model, params, B, mode=m)
        run = jax.jit(lambda p, s, x, m=m: run_batched(model, p, s, x,
                                                       mode=m)[1])
        out = run(params, states, sTB)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = run(params, states, sTB)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        total = B * args.snapshots
        launches = "1 batched stream launch" if m == "v3" else "vmapped scan"
        print(f"\nbatched streams [{m}]: {B} x {args.snapshots} snapshots in "
              f"{dt*1e3:.1f} ms -> {total/dt:.0f} snapshots/s ({launches})")

    # multi-tenant server: independent clients, same-bucket chunks from
    # different clients grouped into one batched V3 launch
    n_per = max(args.snapshots // 2, 2)
    streams = {f"client{i}": slice_snapshots(tg, 1.0)[i: i + n_per]
               for i in range(args.streams)}
    srv = SnapshotServer(cfg, ft, n_global=tg.n_global_nodes, mode="v3",
                         stream_chunk=4)
    params, _ = srv.init(jax.random.PRNGKey(0))
    states = {sid: srv.model.init_state(params, mode="v3")
              for sid in streams}
    t0 = time.perf_counter()
    _, outs, stats = srv.run_multi(params, states, streams)
    dt = time.perf_counter() - t0
    served = sum(len(v) for v in outs.values())
    print(f"multi-tenant v3: {len(streams)} clients, {served} snapshots in "
          f"{dt*1e3:.1f} ms ({stats.mean_latency_ms:.3f} ms/snapshot, "
          f"host prep overlapped across {len(streams)} producer threads)")


if __name__ == "__main__":
    main()
