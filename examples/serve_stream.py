"""End-to-end DGNN serving driver (the paper's deployment scenario).

Runs both base models (EvolveGCN -> V1/V3, GCRN-M2 -> V2/V3) over both
datasets (BC-Alpha, UCI), with the paper's ablation levels, and prints the
Table IV / Fig. 6 style comparison measured on this host. Everything goes
through the typed plan/execute API: a validated ``StreamPlan`` per
configuration, a ``BoosterSession`` owning params/state, and the serving
engine as a consumer of the session. V3 is the time-fused stream engine:
the server batches snapshots into chunks and the recurrent state — the
node store for GCRN, the evolving weight matrices for EvolveGCN — stays
in VMEM across each chunk. Batched multi-stream serving is included
(--streams N), plus a RAGGED batch: unequal-length streams in ONE launch
via the plan's ``lengths`` capability.

    PYTHONPATH=src python examples/serve_stream.py [--snapshots 32] [--streams 4]
"""
import argparse
import time

import jax
import numpy as np

from repro.api import BoosterSession, plan
from repro.configs.dgnn import BC_ALPHA, UCI, DGNN_CONFIGS
from repro.core import init_states_batched, run_plan_batched, stack_time
from repro.graph import (
    generate_temporal_graph,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshots", type=int, default=24)
    ap.add_argument("--streams", type=int, default=4)
    args = ap.parse_args()

    pairs = [("evolvegcn", ("v1", "v3")), ("gcrn-m2", ("v2", "v3"))]
    for ds in (BC_ALPHA, UCI):
        tg, ft = generate_temporal_graph(ds)
        snaps = slice_snapshots(tg, 1.0)[: args.snapshots]
        for name, levels in pairs:
            for lv in ("baseline",) + levels:
                session = BoosterSession(
                    DGNN_CONFIGS[name], plan(DGNN_CONFIGS[name], level=lv),
                    n_global=tg.n_global_nodes, feat_table=ft,
                    rng=jax.random.PRNGKey(0))
                _, stats = session.serve(snaps)
                print(f"{ds.name:9s} {name:10s} {lv:8s} "
                      f"{stats.mean_latency_ms:8.3f} ms/snapshot "
                      f"(host prep {np.mean(stats.preprocess_ms):.3f} ms, overlapped)")

    # batched multi-stream serving: the production throughput axis.
    # level="v3" runs ALL B streams through ONE batched stream-kernel
    # launch (batch axis = leading grid dimension, one VMEM-resident
    # state store per stream).
    ds = BC_ALPHA
    tg, ft = generate_temporal_graph(ds)
    snaps = slice_snapshots(tg, 1.0)[: args.snapshots]
    pads = [pad_snapshot(renumber_and_normalize(s), ft, 640, 4096, 64)
            for s in snaps]
    sT = stack_time(pads)
    B = args.streams
    sBT = jax.tree.map(lambda a: np.stack([a] * B, axis=0), sT)
    cfg = DGNN_CONFIGS["gcrn-m2"]
    for lv in ("v2", "v3"):
        p = plan(cfg, level=lv, batch=B)
        session = BoosterSession(cfg, p, n_global=tg.n_global_nodes,
                                 feat_table=ft, rng=jax.random.PRNGKey(0))
        states = init_states_batched(session.model, session.params, B,
                                     mode=lv)
        run = jax.jit(lambda pr, s, x, p=p: run_plan_batched(
            session.model, pr, s, x, p)[1])
        out = run(session.params, states, sBT)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = run(session.params, states, sBT)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        total = B * args.snapshots
        launches = "1 batched stream launch" if lv == "v3" else "vmapped scan"
        print(f"\nbatched streams [{lv}]: {B} x {args.snapshots} snapshots in "
              f"{dt*1e3:.1f} ms -> {total/dt:.0f} snapshots/s ({launches})")

    # RAGGED batch: unequal-length streams in ONE launch — the plan's
    # ``lengths`` capability masks each stream's dead tail in-launch, and
    # the session slices outputs back to true lengths.
    session = BoosterSession(cfg, plan(cfg, level="v3"),
                             n_global=tg.n_global_nodes, feat_table=ft,
                             rng=jax.random.PRNGKey(0))
    lens = [max(args.snapshots // (i + 1), 2) for i in range(B)]
    ragged = [stack_time(pads[:t]) for t in lens]
    _, outs = session.run_batched(ragged)
    print(f"ragged batch [v3]: lengths {lens} in one launch -> "
          f"per-stream outputs {[o.shape[0] for o in outs]}")

    # multi-tenant server: independent clients, same-bucket chunks from
    # different clients grouped into one batched V3 launch
    n_per = max(args.snapshots // 2, 2)
    streams = {f"client{i}": slice_snapshots(tg, 1.0)[i: i + n_per]
               for i in range(args.streams)}
    session = BoosterSession(cfg, plan(cfg, level="v3", stream_chunk=4),
                             n_global=tg.n_global_nodes, feat_table=ft,
                             rng=jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    _, outs, stats = session.serve_multi(streams)
    dt = time.perf_counter() - t0
    served = sum(len(v) for v in outs.values())
    print(f"multi-tenant v3: {len(streams)} clients, {served} snapshots in "
          f"{dt*1e3:.1f} ms ({stats.mean_latency_ms:.3f} ms/snapshot, "
          f"host prep overlapped across {len(streams)} producer threads)")


if __name__ == "__main__":
    main()
