"""Quickstart: serve a dynamic-graph stream through DGNN-Booster V2.

Generates a UCI-like temporal graph, slices snapshots on the host, and
streams them through the GCRN-M2 model with the V2 fused dataflow —
the paper's end-to-end inference pipeline in ~30 lines of user code.

The surface is the typed plan/execute API: build ONE validated
``StreamPlan`` (dataflow level, tiling, serve policy — anything invalid
raises right here, not at launch), bind it to a ``BoosterSession`` that
owns the params and recurrent state, and serve.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.api import BoosterSession, plan
from repro.configs.dgnn import GCRN_M2, STATIC_GCN, TGN, UCI
from repro.graph import generate_temporal_graph, pad_event_block, slice_snapshots

def main():
    # 1. data: time-stamped COO edges (here: synthetic UCI-like stream)
    tg, feat_table = generate_temporal_graph(UCI)
    snapshots = slice_snapshots(tg, time_splitter=1.0)[:24]

    # 2. plan + session: GCRN-M2 with the V2 (intra-step GNN/RNN fusion)
    #    dataflow, validated at construction time
    session = BoosterSession(GCRN_M2, plan(GCRN_M2, level="v2"),
                             n_global=tg.n_global_nodes,
                             feat_table=feat_table,
                             rng=jax.random.PRNGKey(0))

    # 3. serve: host thread preprocesses (CPU tasks), device consumes
    outputs, stats = session.serve(snapshots)

    print(f"served {len(outputs)} snapshots")
    print(f"mean device latency  : {stats.mean_latency_ms:8.3f} ms/snapshot")
    print(f"mean host preprocess : {np.mean(stats.preprocess_ms):8.3f} ms/snapshot (overlapped)")
    print(f"end-to-end           : {stats.total_ms:8.1f} ms total")
    print(f"embedding of node 0 @ last snapshot: {outputs[-1][0, :4]}")

    # 4. the other two temporal contracts through the SAME engine
    #    (docs/stream_engine.md): a static GCN — no recurrence, snapshots
    #    fold onto the batch axis — and an event-driven TGN whose global
    #    node memory stays on-chip across ragged event batches.
    static = BoosterSession(STATIC_GCN, plan(STATIC_GCN),
                            n_global=tg.n_global_nodes,
                            feat_table=feat_table,
                            rng=jax.random.PRNGKey(1))
    s_outs, s_stats = static.serve(snapshots[:8])
    print(f"static_gcn (temporal={static.plan.temporal!r}): "
          f"served {len(s_outs)} independent snapshots, "
          f"{s_stats.mean_latency_ms:.3f} ms/snapshot")

    rng = np.random.default_rng(7)
    G = tg.n_global_nodes
    blocks = []
    for _ in range(4):  # 4 batches of 12 timestamped interactions
        src = rng.integers(0, G, 12)
        dst = (src + rng.integers(1, G, 12)) % G
        ts = rng.uniform(0.0, 10.0, 12).astype(np.float32)
        blocks.append(pad_event_block(src, dst, ts, feat_table,
                                      n_pad=32, k_max=8))
    tgn = BoosterSession(TGN, plan(TGN, level="v3"), n_global=G,
                         feat_table=feat_table,
                         rng=jax.random.PRNGKey(2))
    t_outs = tgn.run(jax.tree.map(lambda *xs: np.stack(xs), *blocks))
    print(f"tgn (temporal={tgn.plan.temporal!r}): "
          f"{len(blocks)} event batches -> outputs {np.asarray(t_outs).shape}, "
          f"memory store ({G}, {TGN.hidden}) resident across batches")


if __name__ == "__main__":
    main()
