"""Quickstart: serve a dynamic-graph stream through DGNN-Booster V2.

Generates a UCI-like temporal graph, slices snapshots on the host, and
streams them through the GCRN-M2 model with the V2 fused dataflow —
the paper's end-to-end inference pipeline in ~30 lines of user code.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.dgnn import GCRN_M2, UCI
from repro.graph import generate_temporal_graph, slice_snapshots
from repro.serve import SnapshotServer

def main():
    # 1. data: time-stamped COO edges (here: synthetic UCI-like stream)
    tg, feat_table = generate_temporal_graph(UCI)
    snapshots = slice_snapshots(tg, time_splitter=1.0)[:24]

    # 2. engine: GCRN-M2 with the V2 (intra-step GNN/RNN fusion) dataflow
    server = SnapshotServer(GCRN_M2, feat_table, n_global=tg.n_global_nodes,
                            mode="v2")
    params, state = server.init(jax.random.PRNGKey(0))

    # 3. serve: host thread preprocesses (CPU tasks), device consumes
    state, outputs, stats = server.run(params, state, snapshots)

    print(f"served {len(outputs)} snapshots")
    print(f"mean device latency  : {stats.mean_latency_ms:8.3f} ms/snapshot")
    print(f"mean host preprocess : {np.mean(stats.preprocess_ms):8.3f} ms/snapshot (overlapped)")
    print(f"end-to-end           : {stats.total_ms:8.1f} ms total")
    print(f"embedding of node 0 @ last snapshot: {outputs[-1][0, :4]}")


if __name__ == "__main__":
    main()
