"""Train EvolveGCN on dynamic link prediction (BC-Alpha-like stream).

Demonstrates the full training substrate on the paper's own model: the
fault-tolerant loop (resume + async checkpoints), AdamW, and optionally the
int8 error-feedback gradient compression path. Loss: BCE on dot-product
scores of positive edges at t+1 vs sampled negatives, predicted from the
V1-engine embeddings at t.

    PYTHONPATH=src python examples/train_evolvegcn.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import plan
from repro.configs.dgnn import BC_ALPHA, EVOLVEGCN
from repro.core import build_model, run_plan, stack_time
from repro.graph import (
    generate_temporal_graph,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)
from repro.optim import AdamWConfig
from repro.train import TrainLoopConfig, train

WINDOW = 6


def build_batches(tg, ft, snaps, steps, seed=0):
    """Sliding windows of padded snapshots + link-prediction targets."""
    rng = np.random.default_rng(seed)
    pads = [pad_snapshot(renumber_and_normalize(s), ft, 640, 4096, 64)
            for s in snaps]
    for i in range(steps):
        t0 = rng.integers(0, len(pads) - WINDOW - 1)
        window = stack_time(pads[t0 : t0 + WINDOW])
        nxt = pads[t0 + WINDOW]
        # positives: edges of snapshot t0+WINDOW in LOCAL ids of ITS padding;
        # we score in global id space via the renumber tables
        e = int(nxt.n_edges)
        pos = np.stack([np.asarray(nxt.renumber)[np.asarray(nxt.src)[:e]],
                        np.asarray(nxt.renumber)[np.asarray(nxt.dst)[:e]]], 1)
        neg = rng.integers(0, tg.n_global_nodes, pos.shape)
        npairs = 256
        sel = rng.integers(0, pos.shape[0], npairs)
        yield {
            "window": window,
            "pos": jnp.asarray(pos[sel], jnp.int32),
            "neg": jnp.asarray(neg[sel], jnp.int32),
            "last_renumber": window.renumber[-1],
            "last_mask": window.node_mask[-1],
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/evolvegcn_ckpt")
    args = ap.parse_args()

    tg, ft = generate_temporal_graph(BC_ALPHA)
    snaps = slice_snapshots(tg, 1.0)
    model = build_model(EVOLVEGCN, n_global=tg.n_global_nodes)
    params0 = model.init(jax.random.PRNGKey(0))
    v1 = plan(EVOLVEGCN, level="v1")

    def loss_fn(params, batch):
        state = model.init_state(params, mode=v1.level)
        _, outs = run_plan(model, params, state, batch["window"], v1)
        emb_local = outs[-1]                       # (n_pad, out_dim)
        # scatter window-final embeddings into a global table for scoring
        ren = batch["last_renumber"]
        idx = jnp.where(ren >= 0, ren, tg.n_global_nodes)
        glob = jnp.zeros((tg.n_global_nodes + 1, emb_local.shape[1]))
        glob = glob.at[idx].set(emb_local * batch["last_mask"][:, None],
                                mode="drop")
        def score(pairs):
            return (glob[pairs[:, 0]] * glob[pairs[:, 1]]).sum(-1)
        pos, neg = score(batch["pos"]), score(batch["neg"])
        return (jnp.mean(jax.nn.softplus(-pos)) +
                jnp.mean(jax.nn.softplus(neg)))

    opt = AdamWConfig(lr=3e-3, weight_decay=0.01, warmup_steps=20,
                      total_steps=args.steps)
    loop = TrainLoopConfig(total_steps=args.steps, checkpoint_every=50,
                           checkpoint_dir=args.ckpt)
    params, res = train(loss_fn, params0,
                        build_batches(tg, ft, snaps, args.steps), opt, loop)
    k = max(1, len(res.losses) // 10)
    print(f"resumed_from={res.resumed_from} steps={res.final_step}")
    print(f"loss: first10={np.mean(res.losses[:k]):.4f} "
          f"last10={np.mean(res.losses[-k:]):.4f}")
    print(f"mean step time: {np.mean(res.step_times[1:])*1e3:.1f} ms; "
          f"stragglers: {res.straggler_steps}")


if __name__ == "__main__":
    main()
