"""Generate tokens from any assigned architecture (reduced config) with the
batched greedy decode path — exercises KV caches / SSM states end to end.

    PYTHONPATH=src python examples/lm_generate.py --arch jamba-v0.1-52b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, list_archs, reduce_for_smoke
from repro.models import RuntimeConfig, init_params
from repro.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-v0.1-52b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = reduce_for_smoke(ARCHS[args.arch])
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    rt = RuntimeConfig(tp=1, moe_impl="dense", attn_chunk=128)
    params, _ = init_params(cfg, rt, jax.random.PRNGKey(0))
    prompt = jnp.asarray([[1, 2, 3, 4]] * args.batch, jnp.int32)
    toks = generate(params, cfg, rt, prompt, steps=args.steps, skv=128)
    print(f"{args.arch} (reduced {cfg.param_count()/1e6:.1f}M params)")
    for b in range(args.batch):
        print(f"  lane {b}: {list(map(int, toks[b]))}")


if __name__ == "__main__":
    main()
