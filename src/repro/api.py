"""Typed plan/execute front-end for the DGNN-Booster stack.

The paper's claim is a *generic* accelerator framework; this module is the
generic *surface*: instead of picking dataflows by bare mode strings
(``run_stream(..., mode="v3")``), tile knobs by scattered kwargs and serve
policy by ``SnapshotServer.__init__`` arguments, callers build ONE typed,
frozen :class:`StreamPlan` — validated against the stream-engine registry
and the hardware tiling limits at construction time — and hand it to an
executor:

  * :func:`plan` — the validating builder (from a ``DGNNConfig`` or a raw
    family name). Every invalid combination (unknown family, a dataflow
    level the family does not support, misaligned ``tn``/``td`` tiles,
    ragged ``lengths`` that do not match the batch, a ``DeviceSpec`` the
    host cannot satisfy) raises HERE, not at launch time.
  * :class:`BoosterSession` — owns a model + params + recurrent state and
    exposes ``run`` (one stream), ``run_batched`` (B independent streams,
    ragged T welcome) and ``serve`` / ``serve_multi`` (the snapshot
    serving engine as a consumer of the session).
  * ``core/dataflow.run_plan[_batched]`` — the engine executors a plan
    compiles down to. The historical ``run_stream(mode=...)`` /
    ``run_batched(mode=...)`` entry points survive as deprecated shims
    that build the equivalent plan.
  * :func:`run_arrays` — the kernel-level executor for pre-padded ELL
    stream arrays (benchmarks); same plan, no snapshot pytrees.

Two engine capabilities exist ONLY through the plan:

  * ``lengths`` — per-stream ragged T inside one batched launch: stream
    b's steps past ``lengths[b]`` execute as in-launch no-ops, so a batch
    of unequal-length streams needs no host-manufactured empty snapshots.
  * ``device`` — a :class:`DeviceSpec` sharding the leading B grid axis
    over a ``launch/mesh.py`` data-axis mesh via shard_map; streams are
    independent, so the sharded launch is bit-identical to the unsharded
    one.

See docs/api.md for the plan-field -> engine-behavior table and migration
notes from the mode-string surface.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dgnn import DGNNConfig
from repro.kernels import ops as _ops
from repro.kernels import stream_fused as _stream
from repro.launch.mesh import DeviceSpec

# dataflow levels each registered family supports (the paper's ablation
# ladder; v1 is the module-overlap schedule — undefined for the integrated
# family, whose Pipeline-O2 is v2 — and v2 the intra-step fusion, which
# the weights-evolved family has no kernel for).
FAMILY_LEVELS = {
    "gcrn": ("baseline", "o1", "v2", "v3"),
    "stacked": ("baseline", "o1", "v1", "v2", "v3"),
    "evolve": ("baseline", "o1", "v1", "v3"),
    # temporal-contract families (PR 8): the event-stream and static
    # specs have no historical module-overlap/fusion ladders — baseline
    # (per-step XLA) or the stream engine.
    "tgn": ("baseline", "v3"),
    "static_gcn": ("baseline", "v3"),
}

_FAMILY_OF_TYPE = {
    "integrated": "gcrn",
    "stacked": "stacked",
    "weights_evolved": "evolve",
    "event_memory": "tgn",
    "static": "static_gcn",
}

# TPU tiling alignment for the node/state tile knobs (sublane granularity;
# the engine's BlockSpecs assume it).
_TILE_ALIGN = 8

_UNSET = object()


def family_for(cfg: DGNNConfig) -> str:
    """Stream-engine family (registry key) of a DGNN model config."""
    try:
        return _FAMILY_OF_TYPE[cfg.dgnn_type]
    except KeyError:
        raise ValueError(f"unknown dgnn_type {cfg.dgnn_type!r}") from None


@dataclass(frozen=True)
class StreamPlan:
    """A validated, immutable execution plan for the DGNN-Booster stack.

    Construct through :func:`plan`; every field is checked in
    ``__post_init__`` so an invalid plan cannot exist. See docs/api.md for
    the field -> engine behavior table.
    """

    family: str                       # stream-engine registry key
    level: str = "v3"                 # dataflow level (ablation ladder)
    # time semantics, DERIVED from the family's cell spec (None = fill in
    # at construction): "dense" snapshot stream | "event" ragged event
    # stream | "static" T=1 no-recurrence. Passing a value that
    # contradicts the registry raises — the plan cannot lie about time.
    temporal: Optional[str] = None
    tn: int = 128                     # node-tile rows (grid J axis)
    td: Optional[int] = None          # state-feature block (grid D axis)
    # state residency: "vmem" keeps the recurrent store in VMEM scratch
    # across the stream; "hbm_paged" leaves it in HBM and DMA-stages the
    # (n_global, td) windows through a buffer_depth-deep ring (bit-identical
    # outputs; lifts the n_global x hidden VMEM cap). v3 only; needs td.
    state_residency: str = "vmem"
    buffer_depth: Optional[int] = None  # DMA ring depth (1 | 2 | 4)
    batch: int = 1                    # B independent streams per launch
    lengths: Optional[tuple] = None   # per-stream ragged T (len == batch)
    device: DeviceSpec = field(default_factory=DeviceSpec)
    # serve policy (SnapshotServer consumes these)
    n_pad: int = 640
    e_pad: int = 4096
    k_max: int = 64
    buckets: Optional[tuple] = None   # ((n, e, k), ...) smallest-first
    stream_chunk: int = 8             # snapshots per v3 chunk launch
    queue_depth: int = 2              # host->device queue (ping-pong = 2)
    promote_buckets: Optional[float] = None  # max promotion overhead ratio
    promotion_guard: str = "static"   # "static" proxy | "measured" times
    # multi-tenant scheduling (docs/serve_scheduler.md)
    scheduler: str = "rounds"         # "rounds" barrier | "continuous" ticks
    state_pool_pages: Optional[int] = None  # paged tenant-state pool size
    prefill_chunk: Optional[int] = None     # backlog chunk quota per tick
    # fault isolation / recovery (docs/serve_robustness.md)
    supervision: str = "strict"       # "strict" raise | "isolate" per tenant
    max_retries: int = 0              # chunk-launch retries (rolled-back)
    retry_backoff_ms: float = 10.0    # exponential backoff base
    launch_timeout_ms: Optional[float] = None  # per-launch deadline
    degrade: bool = False             # solo/oracle degradation ladder
    fault_plan: Optional[object] = None  # serve.faults.FaultPlan (chaos)

    def __post_init__(self):
        _validate(self)

    # ------------------------------------------------------- helpers ----

    def lengths_array(self):
        """(B,) int32 lengths, or None when the plan is not ragged."""
        if self.lengths is None:
            return None
        return jnp.asarray(self.lengths, jnp.int32)

    def as_dict(self) -> dict:
        """JSON-ready plan record (benchmarks embed it in BENCH_streams)."""
        return dataclasses.asdict(self)


def _validate(p: StreamPlan) -> None:
    fams = _ops.stream_families()
    if p.family not in fams:
        raise ValueError(f"unknown stream-engine family {p.family!r}; "
                         f"registered: {fams}")
    if p.level not in FAMILY_LEVELS[p.family]:
        raise ValueError(
            f"dataflow level {p.level!r} is not defined for family "
            f"{p.family!r}; supported: {FAMILY_LEVELS[p.family]}")
    temporal = _ops.family_temporal(p.family)
    if p.temporal is None:
        object.__setattr__(p, "temporal", temporal)  # frozen: fill-in
    elif p.temporal != temporal:
        raise ValueError(
            f"temporal={p.temporal!r} contradicts family {p.family!r}, "
            f"whose cell spec declares {temporal!r} time semantics")
    if p.temporal == "static" and p.state_pool_pages is not None:
        raise ValueError(
            "state_pool_pages pages RECURRENT tenant state; family "
            f"{p.family!r} is static (stateless) — nothing to page")
    if not (isinstance(p.tn, int) and p.tn > 0 and p.tn % _TILE_ALIGN == 0):
        raise ValueError(f"tn={p.tn!r}: node tile must be a positive "
                         f"multiple of {_TILE_ALIGN}")
    if p.td is not None and not (isinstance(p.td, int) and p.td > 0
                                 and p.td % _TILE_ALIGN == 0):
        raise ValueError(f"td={p.td!r}: state-feature block must be None "
                         f"(fully resident) or a positive multiple of "
                         f"{_TILE_ALIGN}")
    if p.state_residency not in _stream.RESIDENCY_MODES:
        raise ValueError(
            f"state_residency={p.state_residency!r}: expected one of "
            f"{_stream.RESIDENCY_MODES}")
    if p.state_residency == "hbm_paged":
        if p.temporal == "static":
            raise ValueError(
                "state_residency='hbm_paged' is undefined for static "
                f"family {p.family!r}: zero StateDefs — there is no "
                "recurrent store to page")
        if p.level != "v3":
            raise ValueError(
                "state_residency='hbm_paged' is a stream-engine (v3) "
                f"capability; level={p.level!r} has no resident store")
        if p.td is None:
            raise ValueError(
                "state_residency='hbm_paged' requires td blocking: td is "
                "the (n_global, td) paging window the DMA ring stages "
                "(td=None keeps the store fully VMEM-resident)")
    if p.buffer_depth is not None:
        if p.state_residency != "hbm_paged":
            raise ValueError(
                f"buffer_depth={p.buffer_depth!r} requires "
                "state_residency='hbm_paged': the DMA staging ring only "
                "exists for an HBM-paged store")
        if p.buffer_depth not in _stream.BUFFER_DEPTHS:
            raise ValueError(
                f"buffer_depth must be one of {_stream.BUFFER_DEPTHS}, "
                f"got {p.buffer_depth!r}")
    if not (isinstance(p.batch, int) and p.batch >= 1):
        raise ValueError(f"batch={p.batch!r}: need an int >= 1")
    if p.lengths is not None:
        if p.level != "v3":
            raise ValueError("ragged lengths are a stream-engine (v3) "
                             f"capability; level={p.level!r}")
        if len(p.lengths) != p.batch:
            raise ValueError(f"lengths has {len(p.lengths)} entries for "
                             f"batch={p.batch}")
        if not all(isinstance(t, (int, np.integer)) and t >= 0
                   for t in p.lengths):
            raise ValueError(f"lengths={p.lengths!r}: need ints >= 0")
        if max(p.lengths) == 0:
            raise ValueError("lengths are all zero: nothing to run")
    if not isinstance(p.device, DeviceSpec) or p.device.n_devices < 1:
        raise ValueError(f"device={p.device!r}: need a DeviceSpec with "
                         "n_devices >= 1")
    if p.device.n_devices > 1:
        if p.level != "v3":
            raise ValueError("DeviceSpec sharding shards the stream-engine "
                             f"batch grid axis; level={p.level!r} has none")
        if p.batch % p.device.n_devices:
            raise ValueError(f"batch={p.batch} is not divisible by "
                             f"n_devices={p.device.n_devices}")
        if p.device.n_devices > jax.device_count():
            raise ValueError(
                f"DeviceSpec wants {p.device.n_devices} devices; this host "
                f"has {jax.device_count()} (use XLA_FLAGS="
                "--xla_force_host_platform_device_count=N on CPU)")
    for name in ("n_pad", "e_pad", "k_max", "stream_chunk", "queue_depth"):
        v = getattr(p, name)
        if not (isinstance(v, int) and v >= 1):
            raise ValueError(f"{name}={v!r}: need an int >= 1")
    if p.buckets is not None:
        bs = tuple(tuple(b) for b in p.buckets)
        if not bs or any(len(b) != 3 or any(int(x) < 1 for x in b)
                         for b in bs):
            raise ValueError(f"buckets={p.buckets!r}: need non-empty "
                             "(n_pad, e_pad, k_max) triples")
        for a, b in zip(bs, bs[1:]):
            if any(x > y for x, y in zip(a, b)):
                raise ValueError(f"buckets must be a smallest-first chain; "
                                 f"{a} !<= {b}")
    if p.promote_buckets is not None:
        if p.buckets is None:
            raise ValueError("promote_buckets needs bucketed padding "
                             "(buckets=None)")
        if not p.promote_buckets > 0:
            raise ValueError(f"promote_buckets={p.promote_buckets!r}: need "
                             "a ratio > 0")
    if p.promotion_guard not in ("static", "measured"):
        raise ValueError(f"promotion_guard={p.promotion_guard!r}: "
                         "'static' or 'measured'")
    if p.promotion_guard == "measured" and p.promote_buckets is None:
        raise ValueError("promotion_guard='measured' without "
                         "promote_buckets: nothing to guard")
    if p.scheduler not in ("rounds", "continuous"):
        raise ValueError(f"scheduler={p.scheduler!r}: 'rounds' or "
                         "'continuous'")
    if p.scheduler == "continuous" and p.level != "v3":
        raise ValueError("the continuous-batching scheduler composes "
                         "ragged stream-engine launches; "
                         f"level={p.level!r} has no stream kernel")
    if p.state_pool_pages is not None:
        if p.scheduler != "continuous":
            raise ValueError("state_pool_pages is a continuous-scheduler "
                             "capability (scheduler='continuous')")
        if not (isinstance(p.state_pool_pages, int)
                and p.state_pool_pages >= 1):
            raise ValueError(f"state_pool_pages={p.state_pool_pages!r}: "
                             "need an int >= 1 (None = unbounded)")
    if p.prefill_chunk is not None:
        if p.scheduler != "continuous":
            raise ValueError("prefill_chunk is a continuous-scheduler "
                             "capability (scheduler='continuous')")
        if not (isinstance(p.prefill_chunk, int)
                and 1 <= p.prefill_chunk <= p.stream_chunk):
            raise ValueError(
                f"prefill_chunk={p.prefill_chunk!r}: need an int in "
                f"[1, stream_chunk={p.stream_chunk}] (a prefill chunk "
                "larger than the launch chunk cap cannot be composed)")
    if p.supervision not in ("strict", "isolate"):
        raise ValueError(f"supervision={p.supervision!r}: 'strict' or "
                         "'isolate'")
    if not (isinstance(p.max_retries, int) and p.max_retries >= 0):
        raise ValueError(f"max_retries={p.max_retries!r}: need an int >= 0")
    if not (isinstance(p.retry_backoff_ms, (int, float))
            and p.retry_backoff_ms >= 0):
        raise ValueError(f"retry_backoff_ms={p.retry_backoff_ms!r}: "
                         "need >= 0")
    if p.launch_timeout_ms is not None and not p.launch_timeout_ms > 0:
        raise ValueError(f"launch_timeout_ms={p.launch_timeout_ms!r}: "
                         "need > 0 (None = no deadline)")
    if not isinstance(p.degrade, bool):
        raise ValueError(f"degrade={p.degrade!r}: need a bool")
    if p.fault_plan is not None:
        from repro.serve.faults import FaultPlan

        if not isinstance(p.fault_plan, FaultPlan):
            raise ValueError(f"fault_plan={p.fault_plan!r}: need a "
                             "serve.faults.FaultPlan")


def plan(cfg: Optional[DGNNConfig] = None, *, family: Optional[str] = None,
         temporal: Optional[str] = None,
         level: Optional[str] = None, tn: int = 128, td=_UNSET,
         state_residency: str = "vmem", buffer_depth=None,
         batch: int = 1, lengths=None, device: Optional[DeviceSpec] = None,
         n_pad: int = 640, e_pad: int = 4096, k_max: int = 64,
         buckets=None, stream_chunk: int = 8, queue_depth: int = 2,
         promote_buckets=None, promotion_guard: str = "static",
         scheduler: str = "rounds", state_pool_pages=None,
         prefill_chunk=None,
         supervision: str = "strict", max_retries: int = 0,
         retry_backoff_ms: float = 10.0, launch_timeout_ms=None,
         degrade: bool = False, fault_plan=None) -> StreamPlan:
    """Build a validated :class:`StreamPlan`.

    From a ``DGNNConfig``, the family, preferred dataflow level and the
    D-axis block size default from the config (``dgnn_type``,
    ``cfg.dataflow``, ``cfg.stream_td``); from a bare ``family`` the level
    defaults to "v3". Everything is checked at construction time — a plan
    that would fail at launch does not exist.
    """
    if cfg is not None:
        fam = family_for(cfg)
        if family is not None and family != fam:
            raise ValueError(f"family={family!r} contradicts cfg "
                             f"{cfg.name!r} (family {fam!r})")
        family = fam
        level = level if level is not None else cfg.dataflow
        td = cfg.stream_td if td is _UNSET else td
    if family is None:
        raise ValueError("plan() needs a DGNNConfig or a family name")
    return StreamPlan(
        family=family, temporal=temporal,
        level=level if level is not None else "v3", tn=tn,
        td=None if td is _UNSET else td,
        state_residency=state_residency, buffer_depth=buffer_depth,
        batch=batch,
        lengths=None if lengths is None else tuple(int(t) for t in lengths),
        device=device if device is not None else DeviceSpec(),
        n_pad=n_pad, e_pad=e_pad, k_max=k_max,
        buckets=None if buckets is None else tuple(tuple(b) for b in buckets),
        stream_chunk=stream_chunk, queue_depth=queue_depth,
        promote_buckets=promote_buckets, promotion_guard=promotion_guard,
        scheduler=scheduler, state_pool_pages=state_pool_pages,
        prefill_chunk=prefill_chunk,
        supervision=supervision, max_retries=max_retries,
        retry_backoff_ms=retry_backoff_ms,
        launch_timeout_ms=launch_timeout_ms, degrade=degrade,
        fault_plan=fault_plan)


def run_arrays(p: StreamPlan, *args, force_ref: bool = False):
    """Kernel-level plan executor: pre-padded ELL stream arrays straight
    through the stream engine (the family argument lists of
    ``kernels/ops.stream_steps``). A plan with ``batch > 1`` OR ragged
    ``lengths`` takes the batched entry — its args carry a leading
    (B, ...) axis (B == plan.batch, possibly 1) — with the plan's lengths
    and device sharding; benchmarks use this instead of naming the ops
    entry points."""
    if p.batch > 1 or p.lengths is not None:
        return _ops.stream_steps_batched(
            p.family, *args, tn=p.tn, td=p.td, lengths=p.lengths_array(),
            device=p.device, state_residency=p.state_residency,
            buffer_depth=p.buffer_depth, force_ref=force_ref)
    return _ops.stream_steps(p.family, *args, tn=p.tn, td=p.td,
                             state_residency=p.state_residency,
                             buffer_depth=p.buffer_depth,
                             force_ref=force_ref)


class BoosterSession:
    """A model + params + recurrent state bound to one :class:`StreamPlan`.

    The front-end of the stack: build once, then ``run`` padded snapshot
    streams through the plan's dataflow, ``run_batched`` a ragged batch of
    independent streams in one launch, or ``serve`` raw COO snapshot
    iterators through the serving engine (which consumes this session).

    ``run`` advances the session's own recurrent state (streaming
    semantics); ``run_batched`` is stateless-by-default — pass ``states``
    to continue previous chunks, or take the returned states forward.
    """

    def __init__(self, cfg: DGNNConfig, plan: Optional[StreamPlan] = None,
                 *, n_global: int = 4096, feat_table=None, params=None,
                 rng=None):
        from repro.core.dataflow import build_model

        self.cfg = cfg
        self.plan = plan if plan is not None else _plan_builder(cfg)
        fam = family_for(cfg)
        if self.plan.family != fam:
            raise ValueError(f"plan family {self.plan.family!r} does not "
                             f"serve cfg {cfg.name!r} (family {fam!r})")
        self.model = build_model(cfg, n_global=n_global)
        self.n_global = n_global
        self.feat_table = feat_table
        self.params = params
        self.state = None
        if params is None and rng is not None:
            self.init(rng)
        elif params is not None:
            self.reset_state()

    # -------------------------------------------------------- state ----

    def init(self, rng):
        """(Re)initialize params and a fresh recurrent state; returns
        ``(params, state)`` (the historical SnapshotServer.init pair)."""
        self.params = self.model.init(rng)
        self.reset_state()
        return self.params, self.state

    def reset_state(self):
        self.state = self.model.init_state(self.params, mode=self.plan.level)
        return self.state

    def _need_params(self):
        if self.params is None:
            raise RuntimeError("session has no params: pass params= or "
                               "rng=, or call session.init(rng)")

    # ---------------------------------------------------- execution ----

    def run(self, snaps_T):
        """One padded (T, ...) snapshot stream through the plan's engine,
        advancing the session state. Returns the (T, n_pad, out) outputs."""
        from repro.core.dataflow import run_plan

        self._need_params()
        self.state, outs = run_plan(self.model, self.params, self.state,
                                    snaps_T, self.plan)
        return outs

    def run_batched(self, streams: list, states=None):
        """B independent padded streams — RAGGED T welcome — in ONE
        batched launch.

        ``streams`` is a list of per-stream (T_b, ...) snapshot pytrees.
        Unequal lengths are stacked to the longest (tail slots repeat the
        stream's last snapshot; their content is ignored — the launch
        masks them out via the plan's ragged-lengths capability) and each
        stream's outputs are sliced back to its true length. Returns
        ``(final_states, [outs_b (T_b, n, out)])``; row b of the states
        equals running stream b alone.
        """
        from repro.core.dataflow import init_states_batched, run_plan_batched

        self._need_params()
        B = len(streams)
        lens = [int(jax.tree.leaves(s)[0].shape[0]) for s in streams]
        if self.plan.lengths is not None:
            if list(self.plan.lengths) != lens:
                raise ValueError(f"plan.lengths={self.plan.lengths} does "
                                 f"not match stream lengths {lens}")
        p = self.plan
        if p.batch != B:
            p = dataclasses.replace(p, batch=B, lengths=None)
        if len(set(lens)) > 1 and p.lengths is None:  # genuinely ragged
            p = dataclasses.replace(p, lengths=tuple(lens))
        t_max = max(lens)
        padded = [jax.tree.map(
            lambda a, t=t: np.concatenate(
                [a, np.repeat(np.asarray(a)[-1:], t_max - t, axis=0)], axis=0)
            if t < t_max else a, s) for s, t in zip(streams, lens)]
        snaps_BT = jax.tree.map(lambda *xs: np.stack(xs, axis=0), *padded)
        if states is None:
            states = init_states_batched(self.model, self.params, B,
                                         mode=p.level)
        states, outs_BT = run_plan_batched(self.model, self.params, states,
                                           snaps_BT, p)
        outs_BT = np.asarray(outs_BT)
        return states, [outs_BT[b, :lens[b]] for b in range(B)]

    # ------------------------------------------------------ serving ----

    def _server(self):
        from repro.serve.engine import SnapshotServer

        if self.feat_table is None:
            raise RuntimeError("serving needs the global feat_table: pass "
                               "feat_table= to BoosterSession")
        return SnapshotServer(session=self)

    def serve(self, snaps: Iterable):
        """Serve a raw COO snapshot iterator through the engine (host
        preprocessing overlapped with device launches), advancing the
        session state. Returns ``(outputs, ServeStats)``."""
        self._need_params()
        if self.state is None:
            self.reset_state()
        self.state, outs, stats = self._server().run(self.params, self.state,
                                                     snaps)
        return outs, stats

    def serve_multi(self, streams: dict, states: Optional[dict] = None):
        """Serve many independent client streams concurrently (one
        recurrent state per tenant; same-bucket chunks co-batched into one
        launch). Returns ``(states, {sid: [outputs]}, ServeStats)``."""
        self._need_params()
        if states is None:
            states = {sid: self.model.init_state(self.params,
                                                 mode=self.plan.level)
                      for sid in streams}
        return self._server().run_multi(self.params, states, streams)


_plan_builder = plan
