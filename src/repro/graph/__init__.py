from repro.graph.coo import COOSnapshot, TemporalGraph, slice_snapshots, snapshot_stats
from repro.graph.csr import LocalSnapshot, max_in_degree, renumber_and_normalize, to_ell
from repro.graph.events import PaddedEventBlock, pad_event_block, unpad_event_block
from repro.graph.padding import (
    DEFAULT_BUCKETS,
    PaddedSnapshot,
    bucket_cost,
    choose_bucket,
    choose_bucket_batch,
    empty_like_padded,
    empty_padded,
    pad_snapshot,
    pow2_target,
    promote_bucket_groups,
    round_up,
    stack_streams,
    unpad_snapshot,
)
from repro.graph.synthetic import generate_temporal_graph

__all__ = [
    "COOSnapshot", "TemporalGraph", "slice_snapshots", "snapshot_stats",
    "LocalSnapshot", "renumber_and_normalize", "to_ell", "max_in_degree",
    "PaddedEventBlock", "pad_event_block", "unpad_event_block",
    "PaddedSnapshot", "pad_snapshot", "stack_streams", "choose_bucket",
    "choose_bucket_batch", "unpad_snapshot", "empty_like_padded",
    "empty_padded", "bucket_cost", "promote_bucket_groups",
    "pow2_target", "round_up",
    "DEFAULT_BUCKETS", "generate_temporal_graph",
]
