"""Graph renumbering + COO->CSR/ELL conversion (host side).

Renumbering (paper §IV-B): active global node ids are compacted to a dense
local index space [0, n_nodes) so device buffers are contiguous and gathers
are regular. The renumber table (local -> global) drives scatter-back into
the global node-state store, mirroring the paper's BRAM-address table.

Format conversion (paper §IV-B): COO is producer-friendly but irregular;
we build (a) a local-id COO with precomputed GCN normalization per edge for
the segment-sum reference path, and (b) an ELL (padded neighbor-list) layout
for the Pallas SpMM kernel — the TPU-friendly stand-in for the paper's
on-FPGA CSR, chosen because fixed-width rows map directly onto VMEM tiles.
Self-loops are added here so device code never branches.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.coo import COOSnapshot


@dataclass
class LocalSnapshot:
    """Renumbered snapshot with GCN normalization, still host-side numpy."""

    src: np.ndarray        # (e',) int32 local ids (self-loops included)
    dst: np.ndarray        # (e',) int32
    coef: np.ndarray       # (e',) float32  1/sqrt(d_src * d_dst)
    edge_feat: np.ndarray  # (e', De) float32 (zeros for self-loops)
    renumber: np.ndarray   # (n,) int64 local -> global
    n_nodes: int
    t_index: int


def renumber_and_normalize(snap: COOSnapshot, symmetric: bool = True) -> LocalSnapshot:
    active = snap.active_nodes()                    # sorted unique global ids
    n = active.size
    # global -> local via searchsorted on the sorted active list
    src_l = np.searchsorted(active, snap.src).astype(np.int32)
    dst_l = np.searchsorted(active, snap.dst).astype(np.int32)
    de = snap.edge_feat.shape[1]
    if symmetric:
        # undirected message passing: add reverse edges (paper's GCN use)
        src2 = np.concatenate([src_l, dst_l])
        dst2 = np.concatenate([dst_l, src_l])
        ef2 = np.concatenate([snap.edge_feat, snap.edge_feat], axis=0)
    else:
        src2, dst2, ef2 = src_l, dst_l, snap.edge_feat
    # self loops (A + I)
    loops = np.arange(n, dtype=np.int32)
    src3 = np.concatenate([src2, loops])
    dst3 = np.concatenate([dst2, loops])
    ef3 = np.concatenate([ef2, np.zeros((n, de), np.float32)], axis=0)
    # symmetric normalization D^-1/2 (A+I) D^-1/2 over in-degree
    deg = np.bincount(dst3, minlength=n).astype(np.float64)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    coef = (dinv[src3] * dinv[dst3]).astype(np.float32)
    return LocalSnapshot(
        src=src3.astype(np.int32),
        dst=dst3.astype(np.int32),
        coef=coef,
        edge_feat=ef3.astype(np.float32),
        renumber=active.astype(np.int64),
        n_nodes=int(n),
        t_index=snap.t_index,
    )


def to_ell(ls: LocalSnapshot, n_pad: int, k_max: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Padded neighbor lists: for each dst node, up to k_max (src, coef).

    Returns (neigh_idx (n_pad, k_max) int32, neigh_coef (n_pad, k_max) f32,
    neigh_eidx (n_pad, k_max) int32 — index into the edge array, for edge
    features). Overflow beyond k_max raises: the bucket chooser must pick a
    k_max >= max in-degree (the "snapshot fits on-chip" contract).

    Fully vectorized (stable argsort by dst + per-dst rank via the
    run-start offset): this runs once per snapshot inside the serve
    producer thread, so a per-edge Python loop here throttles the §IV-D
    host/device overlap the engine is built around. Slot order per dst is
    original edge order (stable sort), identical to the sequential fill.
    """
    idx = np.zeros((n_pad, k_max), np.int32)
    coe = np.zeros((n_pad, k_max), np.float32)
    eid = np.zeros((n_pad, k_max), np.int32)
    e = ls.src.shape[0]
    if e == 0:
        return idx, coe, eid
    order = np.argsort(ls.dst, kind="stable")
    dst_s = ls.dst[order]
    # rank within each dst run = position - first index of that dst value
    rank = np.arange(e) - np.searchsorted(dst_s, dst_s, side="left")
    over = rank >= k_max
    if over.any():
        # report the same node the sequential fill would have raised on:
        # the first edge (in original edge order) past its node's k_max
        bad = int(ls.dst[order[over].min()])
        raise ValueError(f"in-degree overflow at node {bad}: k_max={k_max}")
    idx[dst_s, rank] = ls.src[order]
    coe[dst_s, rank] = ls.coef[order]
    eid[dst_s, rank] = order
    return idx, coe, eid


def max_in_degree(ls: LocalSnapshot) -> int:
    return int(np.bincount(ls.dst, minlength=ls.n_nodes).max()) if ls.dst.size else 0
