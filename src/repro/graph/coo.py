"""Temporal COO edge store + time-splitter snapshot slicing.

This is host-side work ("CPU tasks" in the paper's §IV-D task-scheduling
scheme): the raw dynamic graph arrives as a time-stamped COO edge list, the
host slices it into discrete snapshots G^1..G^T by a time splitter and
computes per-snapshot node/edge counts — exactly the preprocessing the
paper assigns to the host CPU.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class TemporalGraph:
    """Raw dynamic graph: time-ordered COO edges over a global node space."""

    src: np.ndarray          # (E,) int64 global node ids
    dst: np.ndarray          # (E,) int64
    time: np.ndarray         # (E,) float64, nondecreasing not required
    edge_feat: np.ndarray    # (E, De) float32 (De may be 0)
    n_global_nodes: int

    def __post_init__(self) -> None:
        assert self.src.shape == self.dst.shape == self.time.shape
        assert self.edge_feat.shape[0] == self.src.shape[0]

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])


@dataclass
class COOSnapshot:
    """One discrete-time snapshot, still in global node ids (pre-renumber)."""

    src: np.ndarray          # (e,) int64
    dst: np.ndarray          # (e,) int64
    edge_feat: np.ndarray    # (e, De)
    t_index: int

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def active_nodes(self) -> np.ndarray:
        return np.unique(np.concatenate([self.src, self.dst]))


def slice_snapshots(tg: TemporalGraph, time_splitter: float) -> list[COOSnapshot]:
    """Slice by fixed time window (the paper's "time splitter").

    Snapshots are contiguous windows of width ``time_splitter`` from
    min(time); empty windows are dropped (matching how dataset snapshot
    counts are reported in Table III).
    """
    order = np.argsort(tg.time, kind="stable")
    src, dst, t = tg.src[order], tg.dst[order], tg.time[order]
    ef = tg.edge_feat[order]
    t0 = float(t[0]) if t.size else 0.0
    bins = np.floor((t - t0) / time_splitter).astype(np.int64)
    out: list[COOSnapshot] = []
    for i, b in enumerate(np.unique(bins)):
        m = bins == b
        out.append(COOSnapshot(src=src[m], dst=dst[m], edge_feat=ef[m], t_index=i))
    return out


def snapshot_stats(snaps: list[COOSnapshot]) -> dict:
    """avg/max node & edge counts, as reported in the paper's Table III."""
    nodes = np.array([s.active_nodes().size for s in snaps])
    edges = np.array([s.n_edges for s in snaps])
    return {
        "avg_nodes": float(nodes.mean()),
        "avg_edges": float(edges.mean()),
        "max_nodes": int(nodes.max()),
        "max_edges": int(edges.max()),
        "snapshots": len(snaps),
    }
