"""Static-shape padding of snapshots for the device.

TPU programs have static shapes; the FPGA analogue in the paper is the fixed
BRAM allocation sized for the largest snapshot. We pad every snapshot into a
(n_pad, e_pad, k_max) bucket and carry masks. Padded edges point at a
dedicated sink row with coef 0, so no device-side branching is needed.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import numpy as np

from repro.graph.csr import LocalSnapshot, to_ell


def round_up(n: int, m: int) -> int:
    """Round ``n`` up to the next multiple of ``m`` (tile-alignment rule
    shared by the kernel row padding and the bucket machinery — the single
    copy; kernels/stream_fused.py and kernels/ops.py import it)."""
    return ((n + m - 1) // m) * m


def pow2_target(real: int, cap: int | None = None) -> int:
    """Next power of two >= ``real`` (>= 1), optionally capped.

    The padded sizes a jit cache is allowed to hold — log2 many per bucket.
    Shared by the serve chunk/batch padding and the plan front-end (one
    copy; serve/engine.py previously reimplemented it).

    Contract (changed after the silent-undersize bug): the result is
    ALWAYS >= ``real``. A ``cap`` smaller than ``real`` cannot be
    satisfied — a padding target below the true length would truncate
    live data — so it raises ``ValueError`` instead of silently returning
    ``cap``; a satisfiable cap clamps the power of two down to ``cap``
    (still >= ``real``, just no longer a power of two)."""
    if cap is not None and cap < real:
        raise ValueError(
            f"pow2_target: cap={cap} < real={real} — a padding target "
            "smaller than the real length would truncate live data")
    target = 1
    while target < real:
        target *= 2
    return max(min(target, cap), 1) if cap is not None else target


@jax.tree_util.register_dataclass
@dataclass
class PaddedSnapshot:
    """Device-ready snapshot. All arrays static-shape; a pytree."""

    # COO path (segment-sum reference)
    src: jax.Array        # (e_pad,) int32
    dst: jax.Array        # (e_pad,) int32
    coef: jax.Array       # (e_pad,) f32; 0 on padding
    edge_feat: jax.Array  # (e_pad, De) f32
    # ELL path (Pallas kernel)
    neigh_idx: jax.Array   # (n_pad, k_max) int32
    neigh_coef: jax.Array  # (n_pad, k_max) f32; 0 on padding
    neigh_eidx: jax.Array  # (n_pad, k_max) int32 into edge_feat
    # node data
    node_feat: jax.Array  # (n_pad, Din) f32
    node_mask: jax.Array  # (n_pad,) f32; 1 for real nodes
    renumber: jax.Array   # (n_pad,) int32 local->global (-1 on padding)
    n_nodes: jax.Array    # () int32
    n_edges: jax.Array    # () int32

    @property
    def n_pad(self) -> int:
        return self.node_feat.shape[0]

    @property
    def e_pad(self) -> int:
        return self.src.shape[0]

    @property
    def k_max(self) -> int:
        return self.neigh_idx.shape[1]


def pad_snapshot(
    ls: LocalSnapshot,
    feat_table: np.ndarray,
    n_pad: int,
    e_pad: int,
    k_max: int,
) -> PaddedSnapshot:
    """Pad a renumbered snapshot into the (n_pad, e_pad, k_max) bucket.

    ``feat_table`` is the global node-feature store (G, Din); the renumber
    table selects the active rows — the paper's DRAM->BRAM load, guided by
    the renumber table.
    """
    n, e = ls.n_nodes, ls.src.shape[0]
    if n > n_pad or e > e_pad:
        raise ValueError(f"snapshot ({n},{e}) exceeds bucket ({n_pad},{e_pad})")
    de = ls.edge_feat.shape[1]
    src = np.full(e_pad, n_pad - 1, np.int32)
    dst = np.full(e_pad, n_pad - 1, np.int32)
    coef = np.zeros(e_pad, np.float32)
    ef = np.zeros((e_pad, de), np.float32)
    src[:e], dst[:e], coef[:e], ef[:e] = ls.src, ls.dst, ls.coef, ls.edge_feat
    nidx, ncoe, neid = to_ell(ls, n_pad, k_max)
    nf = np.zeros((n_pad, feat_table.shape[1]), np.float32)
    nf[:n] = feat_table[ls.renumber]
    mask = np.zeros(n_pad, np.float32)
    mask[:n] = 1.0
    ren = np.full(n_pad, -1, np.int32)
    ren[:n] = ls.renumber
    return PaddedSnapshot(
        src=src, dst=dst, coef=coef, edge_feat=ef,
        neigh_idx=nidx, neigh_coef=ncoe, neigh_eidx=neid,
        node_feat=nf, node_mask=mask, renumber=ren,
        n_nodes=np.int32(n), n_edges=np.int32(e),
    )


def empty_padded(n_pad: int, e_pad: int, k_max: int, din: int,
                 de: int) -> PaddedSnapshot:
    """An all-padding snapshot of the given bucket and feature dims.

    Running it through any dataflow engine is a no-op on the recurrent
    state (masks 0, renumber -1 so every scatter drops) and produces
    all-zero outputs — used to pad the tail of a stream chunk so the
    time-fused V3 kernel always sees a static T, and by the serve
    engine's bucket-calibration warmup.
    """
    return PaddedSnapshot(
        src=np.full(e_pad, n_pad - 1, np.int32),
        dst=np.full(e_pad, n_pad - 1, np.int32),
        coef=np.zeros(e_pad, np.float32),
        edge_feat=np.zeros((e_pad, de), np.float32),
        neigh_idx=np.zeros((n_pad, k_max), np.int32),
        neigh_coef=np.zeros((n_pad, k_max), np.float32),
        neigh_eidx=np.zeros((n_pad, k_max), np.int32),
        node_feat=np.zeros((n_pad, din), np.float32),
        node_mask=np.zeros(n_pad, np.float32),
        renumber=np.full(n_pad, -1, np.int32),
        n_nodes=np.int32(0),
        n_edges=np.int32(0),
    )


def empty_like_padded(ps: PaddedSnapshot) -> PaddedSnapshot:
    """An all-padding snapshot in the same bucket as ``ps``."""
    return empty_padded(ps.n_pad, ps.e_pad, ps.k_max, ps.node_feat.shape[1],
                        ps.edge_feat.shape[1])


def stack_streams(snaps: list[PaddedSnapshot]) -> PaddedSnapshot:
    """Stack independent streams along a leading batch axis (B, ...)."""
    return jax.tree.map(lambda *xs: np.stack(xs, axis=0), *snaps)


def unpad_snapshot(ps: PaddedSnapshot) -> dict:
    """Strip the padding from a PaddedSnapshot back to ragged host arrays.

    Inverse of ``pad_snapshot`` up to the ELL conversion: returns the live
    COO slice and per-node arrays (the round-trip contract the property
    tests assert). Keys: src, dst, coef, edge_feat, node_feat, renumber.
    """
    n = int(ps.n_nodes)
    e = int(ps.n_edges)
    return {
        "src": np.asarray(ps.src)[:e],
        "dst": np.asarray(ps.dst)[:e],
        "coef": np.asarray(ps.coef)[:e],
        "edge_feat": np.asarray(ps.edge_feat)[:e],
        "node_feat": np.asarray(ps.node_feat)[:n],
        "renumber": np.asarray(ps.renumber)[:n],
    }


def choose_bucket(n: int, e: int, k: int,
                  buckets: tuple[tuple[int, int, int], ...]) -> tuple[int, int, int]:
    """Pick the smallest bucket that fits (host-side; see serve/engine)."""
    for b in buckets:
        if n <= b[0] and e <= b[1] and k <= b[2]:
            return b
    raise ValueError(f"no bucket fits snapshot ({n},{e},k={k})")


def choose_bucket_batch(dims: "list[tuple[int, int, int]]",
                        buckets: tuple[tuple[int, int, int], ...]
                        ) -> tuple[int, int, int]:
    """Smallest bucket covering EVERY (n, e, k) in ``dims``.

    Used to co-bucket the snapshots of one multi-tenant stream chunk (and,
    transitively, the streams batched into one V3 launch): batching needs
    identical static shapes, so the chunk pays the max of its members —
    the multi-tenant padding tradeoff. Equal to the elementwise-max query
    against ``choose_bucket``, hence >= every member's individual bucket
    (the monotonicity property tests assert).
    """
    if not dims:
        raise ValueError("empty chunk: no dims to bucket")
    n = max(d[0] for d in dims)
    e = max(d[1] for d in dims)
    k = max(d[2] for d in dims)
    return choose_bucket(n, e, k, buckets)


def bucket_cost(bucket: tuple[int, int, int]) -> int:
    """Padded per-snapshot compute proxy for a bucket: ELL aggregation
    lanes (n_pad * k_max) plus the per-node transform rows (n_pad) — the
    work a snapshot pays when padded into the bucket, whatever its true
    size. Used by the promotion guard below."""
    n_pad, _, k_max = bucket
    return n_pad * (k_max + 1)


def promote_bucket_groups(groups: dict, buckets: tuple,
                          max_overhead: float, cost=bucket_cost) -> dict:
    """Cross-bucket batching via bucket promotion (multi-tenant grouper).

    ``groups`` maps bucket -> list of same-bucket stream chunks queued for
    one batched V3 launch each. A smaller-bucket group may be PROMOTED
    into the next-larger occupied bucket — its chunks re-pad to the bigger
    shape and join that launch — which trades padding overhead for one
    fewer device dispatch (the win batching exists for: small per-tenant
    chunks underutilize the device anyway). The guard: promotion happens
    only when bucket_cost(target) <= max_overhead * bucket_cost(own), so a
    tiny chunk is never inflated into a huge bucket just to save a launch.

    Returns a new groups dict; members keep their (sid, chunk, bucket)
    layout with the bucket re-tagged to the promotion target. Promotion is
    transitive up the chain (a promoted group can merge again) as long as
    every hop honours the guard against the member's ORIGINAL bucket.

    ``cost`` maps a bucket to its per-snapshot cost: the static padded-
    compute proxy ``bucket_cost`` by default, or measured per-bucket step
    times from the serve engine's warmup calibration (the adaptive guard).
    """
    order = {b: i for i, b in enumerate(buckets)}
    merged: dict = {b: list(members) for b, members in groups.items()}
    # ascending visit order: merges only move members into LATER buckets,
    # so every visited key is still present
    for b in sorted(merged, key=order.get):
        bigger = [b2 for b2 in merged if b2 != b and order[b2] > order[b]]
        if not bigger:
            continue
        target = min(bigger, key=order.get)
        # guard against each member's own bucket (promotion may chain)
        if any(cost(target) > max_overhead * cost(own)
               for _, _, own in merged[b]):
            continue
        merged[target] = merged[target] + merged[b]
        del merged[b]
    return {b: [(sid, chunk, b) for sid, chunk, _ in members]
            for b, members in merged.items()}


DEFAULT_BUCKETS = ((128, 512, 32), (320, 1024, 48), (640, 4096, 96))
