"""Static-shape padding of timestamped EVENT BATCHES (the "event"
temporal contract).

An event-driven temporal GNN (TGN/TGAT lineage) consumes a stream of
interaction events ``(u, v, t)`` instead of graph snapshots. For the
stream engine, consecutive events are grouped into BATCHES; each batch
pads into the same ELL row layout the dense families use — one row per
TOUCHED node, lanes carrying that node's events in the batch — so a
ragged event stream rides the engine's existing (T, n, k) grid with
``lengths`` generalizing from ragged-T snapshots to ragged per-event
batches.

The symmetric-lane convention: event ``(u, v, t)`` writes lane ``(v, t)``
on row ``u`` AND lane ``(u, t)`` on row ``v`` (interaction memory is
undirected — both endpoints observe the event), which also guarantees
every coef-nonzero lane references a mask-1 row: both endpoints of every
event are touched rows of the same batch. Dead lanes carry coef 0, so
their timestamps (zero-filled) contribute exactly zero to the time
encoding — the Hypothesis property tests pin this.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np


@jax.tree_util.register_dataclass
@dataclass
class PaddedEventBlock:
    """Device-ready batch of timestamped events. A pytree, laid out like
    the ELL half of PaddedSnapshot so the stream engine's node tiling
    applies unchanged; ``neigh_ts`` rides the slot dense families use for
    edge indices."""

    neigh_idx: jax.Array   # (n_pad, k_max) int32 local partner per event
    neigh_coef: jax.Array  # (n_pad, k_max) f32 1/deg; 0 on padding
    neigh_ts: jax.Array    # (n_pad, k_max) f32 event timestamps; 0 on padding
    node_feat: jax.Array   # (n_pad, Din) f32 touched-node features
    node_mask: jax.Array   # (n_pad,) f32; 1 for touched nodes
    renumber: jax.Array    # (n_pad,) int32 local->global (-1 on padding)
    n_nodes: jax.Array     # () int32 touched nodes
    n_events: jax.Array    # () int32 real events

    @property
    def n_pad(self) -> int:
        return self.node_feat.shape[0]

    @property
    def k_max(self) -> int:
        return self.neigh_idx.shape[1]


def pad_event_block(src, dst, ts, feat_table, n_pad: int,
                    k_max: int) -> PaddedEventBlock:
    """Pad one batch of events ``(src[i], dst[i], ts[i])`` into the
    (n_pad, k_max) ELL layout over the batch's TOUCHED nodes.

    ``feat_table`` is the global node-feature store (G, Din); touched
    nodes (the union of both endpoints) renumber into rows 0..n-1 in
    sorted-global-id order. Per-row lanes are coef-weighted 1/deg (mean
    aggregation over the node's events in the batch). Raises when the
    batch overflows the bucket — more touched nodes than ``n_pad``, a
    node with more events than ``k_max``, or a self-loop event (an
    interaction needs two distinct endpoints).
    """
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    ts = np.asarray(ts, np.float32)
    if not (src.shape == dst.shape == ts.shape and src.ndim == 1):
        raise ValueError(f"event batch arrays must be 1-D and congruent: "
                         f"src {src.shape}, dst {dst.shape}, ts {ts.shape}")
    if np.any(src == dst):
        raise ValueError("self-loop events (src == dst) are not "
                         "interactions; drop them before padding")
    touched = np.unique(np.concatenate([src, dst]))
    n = int(touched.shape[0])
    if n > n_pad:
        raise ValueError(f"event batch touches {n} nodes; bucket n_pad="
                         f"{n_pad}")
    local = {int(g): i for i, g in enumerate(touched)}

    idx = np.zeros((n_pad, k_max), np.int32)
    coef = np.zeros((n_pad, k_max), np.float32)
    tsl = np.zeros((n_pad, k_max), np.float32)
    deg = np.zeros(n_pad, np.int32)
    for u, v, t in zip(src, dst, ts):  # symmetric: both endpoints observe
        for a, b in ((int(u), int(v)), (int(v), int(u))):
            i = local[a]
            if deg[i] >= k_max:
                raise ValueError(f"node {a} has more than k_max={k_max} "
                                 "events in this batch")
            idx[i, deg[i]] = local[b]
            tsl[i, deg[i]] = t
            deg[i] += 1
    rows = deg > 0
    coef[rows] = (np.arange(k_max)[None, :]
                  < deg[rows, None]) / deg[rows, None]

    nf = np.zeros((n_pad, feat_table.shape[1]), np.float32)
    nf[:n] = np.asarray(feat_table)[touched]
    mask = np.zeros(n_pad, np.float32)
    mask[:n] = 1.0
    ren = np.full(n_pad, -1, np.int32)
    ren[:n] = touched
    return PaddedEventBlock(
        neigh_idx=idx, neigh_coef=coef, neigh_ts=tsl,
        node_feat=nf, node_mask=mask, renumber=ren,
        n_nodes=np.int32(n), n_events=np.int32(src.shape[0]))


def unpad_event_block(blk: PaddedEventBlock):
    """Recover the event multiset from a padded block as sorted
    ``(src, dst, ts)`` arrays with ``src < dst`` (the undirected
    canonical form — padding adds symmetric lanes, so each event is
    emitted once, from its smaller-global-id endpoint)."""
    idx = np.asarray(blk.neigh_idx)
    coef = np.asarray(blk.neigh_coef)
    tsl = np.asarray(blk.neigh_ts)
    ren = np.asarray(blk.renumber)
    events = []
    for i in range(blk.n_pad):
        if ren[i] < 0:
            continue
        for l in range(blk.k_max):
            if coef[i, l] == 0.0:
                continue
            g_other = int(ren[idx[i, l]])
            if int(ren[i]) < g_other:
                events.append((int(ren[i]), g_other, float(tsl[i, l])))
    events.sort()
    if not events:
        return (np.zeros(0, np.int32), np.zeros(0, np.int32),
                np.zeros(0, np.float32))
    s, d, t = zip(*events)
    return (np.asarray(s, np.int32), np.asarray(d, np.int32),
            np.asarray(t, np.float32))
