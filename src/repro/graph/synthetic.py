"""Synthetic temporal-graph generators matching the paper's dataset stats.

BC-Alpha and UCI (Table III) are small temporal interaction networks. The
container has no network access, so we generate statistically matched
synthetic stand-ins: preferential-attachment node reuse (heavy-tailed
degree, like trust/message networks), per-snapshot node/edge counts drawn
to match the reported avg/max.
"""
from __future__ import annotations

import numpy as np

from repro.configs.dgnn import DatasetConfig
from repro.graph.coo import TemporalGraph


def generate_temporal_graph(ds: DatasetConfig, feat_dim: int = 64) -> tuple[TemporalGraph, np.ndarray]:
    """Returns (temporal graph with time splitter == 1.0, node feature table)."""
    rng = np.random.default_rng(ds.seed)
    # global node pool sized so per-snapshot active counts match avg_nodes
    n_global = ds.max_nodes * 6
    src_all, dst_all, t_all = [], [], []
    # preferential attachment weights, updated as edges arrive
    pop = np.ones(n_global, np.float64)
    for t in range(ds.snapshots):
        # heavy-tailed edge count per snapshot, clipped to max
        e = int(np.clip(rng.lognormal(np.log(ds.avg_edges), 0.45), 8, ds.max_edges))
        # a working set of candidate nodes for this snapshot
        ws = int(np.clip(rng.lognormal(np.log(ds.avg_nodes), 0.35), 8, ds.max_nodes))
        p = pop / pop.sum()
        cand = rng.choice(n_global, size=ws, replace=False, p=p)
        s = rng.choice(cand, size=e)
        d = rng.choice(cand, size=e)
        keep = s != d
        s, d = s[keep], d[keep]
        src_all.append(s)
        dst_all.append(d)
        t_all.append(np.full(s.size, t + 0.5))
        np.add.at(pop, s, 1.0)
        np.add.at(pop, d, 1.0)
    src = np.concatenate(src_all)
    dst = np.concatenate(dst_all)
    time = np.concatenate(t_all)
    # edge features: interaction weight + recency channels (like trust scores)
    de = 8
    ef = rng.normal(0, 1, (src.size, de)).astype(np.float32)
    feat_table = rng.normal(0, 1, (n_global, feat_dim)).astype(np.float32)
    return TemporalGraph(src=src, dst=dst, time=time, edge_feat=ef,
                         n_global_nodes=n_global), feat_table
