"""Trip-count corrections for cost_analysis under lax.map/scan.

The dry-run lowers models with LAYERS UNROLLED, so per-layer FLOPs,
bytes and collectives are counted exactly. Three inner loops remain
rolled for compile-time/memory sanity, and XLA's HloCostAnalysis counts
a while-loop body ONCE regardless of trip count:

  1. q-chunked attention   (lax.map over S/attn_chunk query chunks),
  2. chunked SSD           (lax.map over S/ssm_chunk chunks),
  3. chunked cross-entropy (lax.map over S/loss_chunk chunks).

This module adds the missing (trips-1) * per-iteration terms from closed
forms that mirror the implementations exactly (same einsums, same padded
dims). Train steps multiply by 3 (fwd + ~2x bwd, the same convention XLA's
own counting gives the unrolled parts via autodiff). Every correction is
itemized in the dry-run JSON so the accounting is auditable.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class Correction:
    flops: float = 0.0
    bytes_hbm: float = 0.0
    detail: dict = None


def _train_mult(shape: ShapeConfig) -> float:
    return 3.0 if shape.kind == "train" else 1.0


def flash_correction(cfg: ModelConfig, shape: ShapeConfig, tp: int,
                     bq: int = 512, bk: int = 512) -> Correction:
    """attn_impl="flash": the kernel is a custom call (0 cost to XLA);
    add its exact closed-form work/traffic (kernels/flash_attention.py)."""
    from repro.kernels.flash_attention import flops_bytes

    s = shape.seq_len
    b = shape.global_batch
    hq, hkv = cfg.padded_heads(tp), cfg.padded_kv_heads(tp)
    hd = cfg.resolved_head_dim
    n_attn = sum(1 for mx, _ in cfg.layer_kinds() if mx == "attn")
    fb = flops_bytes(b, hq, hkv, s, hd, causal=cfg.causal, bq=bq, bk=bk)
    # train: fwd (2 matmuls/pair) + dkv pass (3) + dq pass (2, w/ recompute
    # shared) => flops x3.5; K/V re-streamed by both bwd passes => bytes x3
    mf = 3.5 if shape.kind == "train" else 1.0
    mb = 3.0 if shape.kind == "train" else 1.0
    return Correction(fb["flops"] * n_attn * mf, fb["bytes"] * n_attn * mb,
                      {"site": "flash_attention", "layers": n_attn,
                       "tile_pairs": fb["tile_pairs"], "bq": bq, "bk": bk})


def attention_correction(cfg: ModelConfig, shape: ShapeConfig, tp: int,
                         attn_chunk: int) -> Correction:
    """Missing q-chunk trips of chunked_attention (full KV per chunk)."""
    s = 1 if shape.is_decode else shape.seq_len
    if s <= attn_chunk:
        return Correction(0.0, 0.0, {})
    b = shape.global_batch
    hq = cfg.padded_heads(tp)
    hkv = cfg.padded_kv_heads(tp)
    hd = cfg.resolved_head_dim
    n_attn = sum(1 for mx, _ in cfg.layer_kinds() if mx == "attn")
    nc = s // attn_chunk
    # per-iteration: scores (2*B*Hq*c*S*hd) + out (same)
    per_iter_flops = 4.0 * b * hq * attn_chunk * s * hd
    # per-iteration bytes: stream K and V (bf16) + q/out chunk
    per_iter_bytes = (2 * b * s * hkv * hd * 2.0) + (2 * b * attn_chunk * hq * hd * 2.0)
    m = _train_mult(shape)
    f = (nc - 1) * per_iter_flops * n_attn * m
    by = (nc - 1) * per_iter_bytes * n_attn * m
    return Correction(f, by, {"site": "attention", "nc": nc, "layers": n_attn})


def ssd_correction(cfg: ModelConfig, shape: ShapeConfig, tp: int) -> Correction:
    """Missing chunk trips of ssd_chunked's per-chunk lax.map."""
    if cfg.ssm_state == 0 or shape.is_decode:
        return Correction(0.0, 0.0, {})
    s = shape.seq_len
    q = cfg.ssm_chunk
    if s <= q:
        return Correction(0.0, 0.0, {})
    b = shape.global_batch
    h, p, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    n_ssm = sum(1 for mx, _ in cfg.layer_kinds() if mx == "ssm")
    nc = s // q
    # per-iteration einsums: scores 2*b*q^2*h*n; y_intra 2*b*q^2*h*p;
    # states 2*b*q*h*n*p; (y_inter is outside the map)
    per_iter_flops = 2.0 * b * q * q * h * (n + p) + 2.0 * b * q * h * n * p
    per_iter_bytes = b * q * h * (p + 2 * n / max(h // cfg.ssm_nheads, 1)) * 4.0 + b * q * h * 4.0
    m = _train_mult(shape)
    f = (nc - 1) * per_iter_flops * n_ssm * m
    by = (nc - 1) * per_iter_bytes * n_ssm * m
    return Correction(f, by, {"site": "ssd", "nc": nc, "layers": n_ssm})


def loss_correction(cfg: ModelConfig, shape: ShapeConfig, tp: int,
                    loss_chunk: int) -> Correction:
    """Missing seq-chunk trips of chunked_ce_loss (train only)."""
    if shape.kind != "train":
        return Correction(0.0, 0.0, {})
    s, b = shape.seq_len, shape.global_batch
    c = min(loss_chunk, s)
    nc = s // c
    if nc <= 1:
        return Correction(0.0, 0.0, {})
    vp = cfg.padded_vocab()
    per_iter_flops = 2.0 * b * c * cfg.d_model * vp
    per_iter_bytes = b * c * vp * 4.0 + b * c * cfg.d_model * 2.0
    m = _train_mult(shape)
    return Correction((nc - 1) * per_iter_flops * m,
                      (nc - 1) * per_iter_bytes * m,
                      {"site": "loss", "nc": nc})


def total_corrections(cfg: ModelConfig, shape: ShapeConfig, tp: int,
                      attn_chunk: int, loss_chunk: int,
                      attn_impl: str = "xla", flash_bq: int = 512,
                      flash_bk: int = 512) -> dict:
    if attn_impl == "flash" and not shape.is_decode:
        attn = flash_correction(cfg, shape, tp, flash_bq, flash_bk)
    else:
        attn = attention_correction(cfg, shape, tp, attn_chunk)
    cs = [
        attn,
        ssd_correction(cfg, shape, tp),
        loss_correction(cfg, shape, tp, loss_chunk),
    ]
    return {
        "flops": sum(c.flops for c in cs),
        "bytes_hbm": sum(c.bytes_hbm for c in cs),
        "items": [c.detail for c in cs if c.detail],
    }
