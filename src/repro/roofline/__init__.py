from repro.roofline.analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    CollectiveStats,
    Roofline,
    collective_bytes,
    model_flops,
)

__all__ = [
    "HBM_BW", "ICI_BW", "PEAK_FLOPS", "CollectiveStats", "Roofline",
    "collective_bytes", "model_flops",
]
