from repro.roofline.analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    CollectiveStats,
    Roofline,
    collective_bytes,
    cost_analysis_dict,
    model_flops,
)

__all__ = [
    "HBM_BW", "ICI_BW", "PEAK_FLOPS", "CollectiveStats", "Roofline",
    "collective_bytes", "cost_analysis_dict", "model_flops",
]
