"""Roofline terms from compiled dry-run artifacts (no hardware needed).

compute term    = HLO_FLOPs   / (chips * peak_FLOP/s)
memory term     = HLO_bytes   / (chips * HBM_bw)
collective term = coll_bytes  / (chips * link_bw)

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` (models are lowered
UNROLLED for the dry-run precisely because HloCostAnalysis does not
multiply while-loop bodies by trip count). Collective bytes are parsed from
the compiled HLO text: we sum the OPERAND sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, resolving
operand result types from their defining instructions.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI
per link.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jaxlibs return a one-element list of per-program dicts; newer
    ones return the dict directly. Indexing the list with a string key is
    the seed's ``TypeError: list indices must be integers`` dry-run
    failure (tests/test_multidevice.py::test_mini_dryrun_8dev_mesh).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of collective ops in (compiled) HLO text."""
    # pass 1: result types of every named instruction
    result_bytes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if m:
            result_bytes[m.group(1)] = _type_bytes(m.group(2))
    stats = CollectiveStats()
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        op = m.group(3)
        base = None
        for c in COLLECTIVE_OPS:
            if op == c or op.startswith(c + "-start") or op.startswith(c + "."):
                base = c
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting async pairs
        # operand bytes: resolve %refs on the RHS after the opcode
        rhs = ln.split(op, 1)[1]
        rhs = rhs.split("channel_id")[0]
        obytes = 0
        for om in _OPERAND_RE.finditer(rhs):
            obytes += result_bytes.get(om.group(1), 0)
        if obytes == 0:
            # fall back to the result size (equal for all-reduce/permute)
            obytes = result_bytes.get(m.group(1), 0)
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + obytes
        stats.count_by_op[base] = stats.count_by_op.get(base, 0) + 1
    return stats


@dataclass
class Roofline:
    """All byte/flop inputs are PER-DEVICE (the compiled module is the SPMD
    per-device program — verified against a hand-checked sharded matmul);
    ``model_flops`` is the GLOBAL analytic useful work."""

    flops: float          # per-device HLO flops (+ per-device corrections)
    bytes_hbm: float      # per-device HLO bytes accessed
    bytes_coll: float     # per-device collective operand bytes
    chips: int
    model_flops: float = 0.0  # global

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.bytes_coll / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/padding/dispatch waste detector."""
        if not self.flops:
            return 0.0
        return (self.model_flops / self.chips) / self.flops

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved when running at the
        bound: useful model FLOPs / (peak * bound-time), per device."""
        t = self.t_bound
        if t <= 0:
            return 0.0
        return (self.model_flops / self.chips) / (PEAK_FLOPS * t)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes_hbm": self.bytes_hbm,
            "bytes_coll": self.bytes_coll, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step: 6ND train / 2ND inference (+ exact
    quadratic attention and SSD terms, which dominate at 32k+)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    base = mult * n_active * tokens
    # attention quadratic term: 2*2*hd*(Hq)*sum_over_queries(kv_len)
    attn_layers = sum(1 for mx, _ in cfg.layer_kinds() if mx == "attn")
    if attn_layers:
        hd, hq = cfg.resolved_head_dim, cfg.n_heads
        if shape.is_decode:
            kv_per_q = shape.seq_len
            qtok = shape.global_batch
        else:
            kv_per_q = shape.seq_len / 2  # causal average
            qtok = tokens
        attn = (mult / 1.5 if shape.kind == "train" else 2) * 2 * hd * hq * kv_per_q * qtok * attn_layers
        base += attn
    # SSD state term: per token 2*d_inner*N (state update) + 2*d_inner*N (out)
    ssm_layers = sum(1 for mx, _ in cfg.layer_kinds() if mx == "ssm")
    if ssm_layers:
        base += mult * 2 * cfg.d_inner * cfg.ssm_state * tokens * ssm_layers
    return float(base)
