"""Fused RNN-cell Pallas kernels (Pipeline-O1 realized in hardware terms).

The paper pipelines the stages inside the RNN with FIFOs; on TPU the
analogous win is issuing all gate matmuls as ONE MXU-shaped matmul against
the concatenated gate weights and applying every elementwise gate op while
the tile is still in VMEM/VREGs — no HBM round trip between "stages".

Weights use constant index maps (VMEM-resident across grid steps — the
LUTRAM analogue); the batch/node dim streams in (TB, ·) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_kernel(x_ref, h_ref, wx_ref, wh_ref, b_ref, out_ref):
    x = x_ref[...]                # (TB, Din)
    h = h_ref[...]                # (TB, H)
    gx = x @ wx_ref[...] + b_ref[...][None, :]   # (TB, 3H)
    gh = h @ wh_ref[...]
    hdim = h.shape[1]
    rx, zx, nx = gx[:, :hdim], gx[:, hdim:2 * hdim], gx[:, 2 * hdim:]
    rh, zh, nh = gh[:, :hdim], gh[:, hdim:2 * hdim], gh[:, 2 * hdim:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    out_ref[...] = (1.0 - z) * n + z * h


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def fused_gru_pallas(x, h, wx, wh, b, *, tb: int = 128, interpret: bool = False):
    bsz, din = x.shape
    hdim = h.shape[1]
    assert bsz % tb == 0, (bsz, tb)
    grid = (bsz // tb,)
    row = lambda i: (i, 0)
    res2 = lambda i: (0, 0)
    res1 = lambda i: (0,)
    return pl.pallas_call(
        _gru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, din), row),
            pl.BlockSpec((tb, hdim), row),
            pl.BlockSpec((din, 3 * hdim), res2),
            pl.BlockSpec((hdim, 3 * hdim), res2),
            pl.BlockSpec((3 * hdim,), res1),
        ],
        out_specs=pl.BlockSpec((tb, hdim), row),
        out_shape=jax.ShapeDtypeStruct((bsz, hdim), x.dtype),
        interpret=interpret,
    )(x, h, wx, wh, b)


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out_ref, c_out_ref):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    gates = x @ wx_ref[...] + h @ wh_ref[...] + b_ref[...][None, :]
    hdim = h.shape[1]
    i = gates[:, :hdim]
    f = gates[:, hdim:2 * hdim]
    g = gates[:, 2 * hdim:3 * hdim]
    o = gates[:, 3 * hdim:]
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_out_ref[...] = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    c_out_ref[...] = c_new


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def fused_lstm_pallas(x, h, c, wx, wh, b, *, tb: int = 128, interpret: bool = False):
    bsz, din = x.shape
    hdim = h.shape[1]
    assert bsz % tb == 0, (bsz, tb)
    grid = (bsz // tb,)
    row = lambda i: (i, 0)
    res2 = lambda i: (0, 0)
    res1 = lambda i: (0,)
    return pl.pallas_call(
        _lstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, din), row),
            pl.BlockSpec((tb, hdim), row),
            pl.BlockSpec((tb, hdim), row),
            pl.BlockSpec((din, 4 * hdim), res2),
            pl.BlockSpec((hdim, 4 * hdim), res2),
            pl.BlockSpec((4 * hdim,), res1),
        ],
        out_specs=[
            pl.BlockSpec((tb, hdim), row),
            pl.BlockSpec((tb, hdim), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, hdim), x.dtype),
            jax.ShapeDtypeStruct((bsz, hdim), x.dtype),
        ],
        interpret=interpret,
    )(x, h, c, wx, wh, b)
