"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

All oracles consume the same padded ELL layout as the kernels:
  neigh_idx  (N, K) int32 — source node per (dst, slot); 0 on padding
  neigh_coef (N, K) f32   — GCN normalization; 0 on padding (kills the lane)
  neigh_eidx (N, K) int32 — edge index for edge-feature lookup; 0 on padding
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_gather_msgs(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg=None):
    """(N, K, D) messages: coef * (x[src] + edge_msg[eidx])."""
    g = x[neigh_idx]  # (N, K, D)
    if edge_msg is not None:
        g = g + edge_msg[neigh_eidx]
    return g * neigh_coef[..., None]


def ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg=None):
    """MP stage: agg[v] = sum_k coef[v,k] * (x[idx[v,k]] + emsg[eidx[v,k]])."""
    return ell_gather_msgs(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg).sum(axis=1)


def fused_gru(x, h, wx, wh, b):
    gx = x @ wx + b
    gh = h @ wh
    rx, zx, nx = jnp.split(gx, 3, axis=-1)
    rh, zh, nh = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    return (1.0 - z) * n + z * h


def fused_lstm(x, h, c, wx, wh, b):
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def dgnn_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, c, wx, wh, b,
                    edge_msg=None):
    """GCRN-M2 V2 step: ELL-aggregate x and h, gate transform, LSTM update."""
    agg_x = ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg)
    agg_h = ell_spmm(neigh_idx, neigh_coef, neigh_eidx, h, None)
    gates = agg_x @ wx + agg_h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def stacked_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h,
                       w_gcn, b_gcn, wx, wh, b, edge_msg=None):
    """Stacked-DGNN V2 step: ELL-aggregate, linear node transform, GRU."""
    agg = ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg)
    nt = agg @ w_gcn + b_gcn
    return fused_gru(nt, h, wx, wh, b)
