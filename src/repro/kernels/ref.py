"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

All oracles consume the same padded ELL layout as the kernels:
  neigh_idx  (N, K) int32 — source node per (dst, slot); 0 on padding
  neigh_coef (N, K) f32   — GCN normalization; 0 on padding (kills the lane)
  neigh_eidx (N, K) int32 — edge index for edge-feature lookup; 0 on padding
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_gather_msgs(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg=None):
    """(N, K, D) messages: coef * (x[src] + edge_msg[eidx])."""
    g = x[neigh_idx]  # (N, K, D)
    if edge_msg is not None:
        g = g + edge_msg[neigh_eidx]
    return g * neigh_coef[..., None]


def ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg=None):
    """MP stage: agg[v] = sum_k coef[v,k] * (x[idx[v,k]] + emsg[eidx[v,k]])."""
    return ell_gather_msgs(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg).sum(axis=1)


def fused_gru(x, h, wx, wh, b):
    gx = x @ wx + b
    gh = h @ wh
    rx, zx, nx = jnp.split(gx, 3, axis=-1)
    rh, zh, nh = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    return (1.0 - z) * n + z * h


def fused_lstm(x, h, c, wx, wh, b):
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def dgnn_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, c, wx, wh, b,
                    edge_msg=None):
    """GCRN-M2 V2 step: ELL-aggregate x and h, gate transform, LSTM update."""
    agg_x = ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg)
    agg_h = ell_spmm(neigh_idx, neigh_coef, neigh_eidx, h, None)
    gates = agg_x @ wx + agg_h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def stacked_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h,
                       w_gcn, b_gcn, wx, wh, b, edge_msg=None):
    """Stacked-DGNN V2 step: ELL-aggregate, linear node transform, GRU."""
    agg = ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg)
    nt = agg @ w_gcn + b_gcn
    return fused_gru(nt, h, wx, wh, b)


# ---------------------------------------------------------------- V3 ----
# Stream oracles: the per-step V2 math plus the renumber-table-guided
# gather/scatter against the global node-state store, scanned over T.
# Ground truth for the stream engine (stream_fused.REGISTRY), whose only
# difference is that the state never leaves VMEM between steps. One
# (solo, batched) oracle pair per registered family — ops.py's
# _STREAM_DISPATCH pairs them with the engine launchers, and force-ref
# mode routes here (the XLA production path) for every family at once.

def _gather_rows(store, renumber, mask):
    safe = jnp.where(renumber >= 0, renumber, 0)
    return jnp.take(store, safe, axis=0) * mask[:, None]


def _scatter_rows(store, renumber, val):
    idx = jnp.where(renumber >= 0, renumber, store.shape[0])
    return store.at[idx].set(val, mode="drop")


def gcrn_stream_ref(neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber,
                    node_mask, h0, c0, wx, wh, b, edge_msg=None):
    """GCRN stream: (T, n, ...) snapshot arrays, (n_global, H) state stores.

    Returns (per-step h outputs (T, n, H), final h store, final c store).
    """
    xs = dict(idx=neigh_idx, coef=neigh_coef, eidx=neigh_eidx, x=node_feat,
              ren=renumber, mask=node_mask)
    if edge_msg is not None:
        xs["em"] = edge_msg

    def body(carry, s):
        h_store, c_store = carry
        h = _gather_rows(h_store, s["ren"], s["mask"])
        c = _gather_rows(c_store, s["ren"], s["mask"])
        h_new, c_new = dgnn_fused_step(s["idx"], s["coef"], s["eidx"], s["x"],
                                       h, c, wx, wh, b, s.get("em"))
        m = s["mask"][:, None]
        h_new, c_new = h_new * m, c_new * m
        return (_scatter_rows(h_store, s["ren"], h_new),
                _scatter_rows(c_store, s["ren"], c_new)), h_new

    (hT, cT), outs = jax.lax.scan(body, (h0, c0), xs)
    return outs, hT, cT


def gcrn_stream_batched_ref(neigh_idx, neigh_coef, neigh_eidx, node_feat,
                            renumber, node_mask, h0, c0, wx, wh, b,
                            edge_msg=None):
    """B independent GCRN streams: (B, T, n, ...) arrays, (B, G, H) stores.

    vmap of the single-stream oracle — ground truth for the batched stream
    kernel's no-cross-stream-leakage contract.
    """
    if edge_msg is None:
        fn = lambda i, c, e, x, r, m, h_, c_0: gcrn_stream_ref(
            i, c, e, x, r, m, h_, c_0, wx, wh, b)
        return jax.vmap(fn)(neigh_idx, neigh_coef, neigh_eidx, node_feat,
                            renumber, node_mask, h0, c0)
    fn = lambda i, c, e, x, r, m, h_, c_0, em: gcrn_stream_ref(
        i, c, e, x, r, m, h_, c_0, wx, wh, b, em)
    return jax.vmap(fn)(neigh_idx, neigh_coef, neigh_eidx, node_feat,
                        renumber, node_mask, h0, c0, edge_msg)


def stacked_stream_ref(neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber,
                       node_mask, h0, w_gcn, b_gcn, wx, wh, b, edge_msg=None):
    """Stacked stream: last GCN layer + GRU per step over the global h store.

    Returns (per-step h outputs (T, n, H), final h store).
    """
    xs = dict(idx=neigh_idx, coef=neigh_coef, eidx=neigh_eidx, x=node_feat,
              ren=renumber, mask=node_mask)
    if edge_msg is not None:
        xs["em"] = edge_msg

    def body(h_store, s):
        h = _gather_rows(h_store, s["ren"], s["mask"])
        h_new = stacked_fused_step(s["idx"], s["coef"], s["eidx"], s["x"], h,
                                   w_gcn, b_gcn, wx, wh, b, s.get("em"))
        h_new = h_new * s["mask"][:, None]
        return _scatter_rows(h_store, s["ren"], h_new), h_new

    hT, outs = jax.lax.scan(body, h0, xs)
    return outs, hT


def stacked_stream_batched_ref(neigh_idx, neigh_coef, neigh_eidx, node_feat,
                               renumber, node_mask, h0, w_gcn, b_gcn,
                               wx, wh, b, edge_msg=None):
    """B independent stacked streams: vmap of the single-stream oracle."""
    if edge_msg is None:
        fn = lambda i, c, e, x, r, m, h_: stacked_stream_ref(
            i, c, e, x, r, m, h_, w_gcn, b_gcn, wx, wh, b)
        return jax.vmap(fn)(neigh_idx, neigh_coef, neigh_eidx, node_feat,
                            renumber, node_mask, h0)
    fn = lambda i, c, e, x, r, m, h_, em: stacked_stream_ref(
        i, c, e, x, r, m, h_, w_gcn, b_gcn, wx, wh, b, em)
    return jax.vmap(fn)(neigh_idx, neigh_coef, neigh_eidx, node_feat,
                        renumber, node_mask, h0, edge_msg)


def evolve_stream_ref(neigh_idx, neigh_coef, node_feat, node_mask, live,
                      weights0, b_gcn, gru_wx, gru_wh, gru_b,
                      edge_aggs=None):
    """EvolveGCN stream oracle: (T, n, ...) snapshot arrays, per-layer
    evolving weights as the carry.

    Per step t: the L-layer GCN consumes the CURRENT weights (agg @ W_l +
    b_l, ReLU between layers, masked every layer — identical to
    core.gcn.gcn_forward_weights with the edge term pre-aggregated into
    ``edge_aggs[l]`` (T, n, din_l)), then the matrix-GRU evolves every
    layer's weight for step t+1. ``live`` (T,) gates the evolution: a
    no-op (all-padding) snapshot leaves the weights untouched, so serve
    tail padding never advances the recurrence. Ground truth for the
    weights-resident stream kernel, whose only difference is that the
    weights never leave VMEM between steps.

    Returns (per-step outputs (T, n, out_dim), final weights tuple).
    """
    L = len(weights0)
    xs = dict(idx=neigh_idx, coef=neigh_coef, x=node_feat, mask=node_mask,
              live=live)
    if edge_aggs is not None:
        for i, ea in enumerate(edge_aggs):
            xs[f"ea{i}"] = ea

    def body(ws, s):
        x = s["x"]
        m = s["mask"][:, None]
        for i in range(L):
            agg = (x[s["idx"]] * s["coef"][..., None]).sum(axis=1)
            ea = s.get(f"ea{i}")
            if ea is not None:
                agg = agg + ea
            h = agg @ ws[i] + b_gcn[i]
            if i < L - 1:
                h = jax.nn.relu(h)
            x = h * m
        evolved = tuple(
            fused_gru(w.T, w.T, wx, wh, b).T
            for w, wx, wh, b in zip(ws, gru_wx, gru_wh, gru_b))
        ws_next = tuple(
            jnp.where(s["live"] > 0, wn, w) for wn, w in zip(evolved, ws))
        return ws_next, x

    wT, outs = jax.lax.scan(body, tuple(weights0), xs)
    return outs, wT


def evolve_stream_batched_ref(neigh_idx, neigh_coef, node_feat, node_mask,
                              live, weights0, b_gcn, gru_wx, gru_wh, gru_b,
                              edge_aggs=None):
    """B independent EvolveGCN streams: (B, T, ...) arrays, per-layer
    (B, din_l, dout_l) weights — vmap of the single-stream oracle (GRU
    params and GCN biases shared across streams)."""
    if edge_aggs is None:
        fn = lambda i, c, x, m, lv, ws: evolve_stream_ref(
            i, c, x, m, lv, ws, b_gcn, gru_wx, gru_wh, gru_b)
        return jax.vmap(fn)(neigh_idx, neigh_coef, node_feat, node_mask,
                            live, tuple(weights0))
    fn = lambda i, c, x, m, lv, ws, ea: evolve_stream_ref(
        i, c, x, m, lv, ws, b_gcn, gru_wx, gru_wh, gru_b, ea)
    return jax.vmap(fn)(neigh_idx, neigh_coef, node_feat, node_mask, live,
                        tuple(weights0), tuple(edge_aggs))


def tgn_stream_ref(neigh_idx, neigh_coef, neigh_ts, node_feat, renumber,
                   node_mask, mem0, freq, w_in, wx, wh, b):
    """TGN event-stream oracle: (T, n, ...) padded event batches
    (graph/events.pad_event_block), node-memory store as the carry.

    Per event batch: every touched node aggregates its event partners'
    t-1 memory and the sinusoidal time encoding cos(ts * freq) of its
    events (coef-weighted, so dead lanes contribute exactly zero), feeds
    the GRU against its own t-1 memory row, and scatters the new memory
    back at its renumber row only — untouched global rows carry over.

    Returns (per-batch memory outputs (T, n, H), final memory store).
    """
    xs = dict(idx=neigh_idx, coef=neigh_coef, ts=neigh_ts, x=node_feat,
              ren=renumber, mask=node_mask)

    def body(store, s):
        mem = _gather_rows(store, s["ren"], s["mask"])
        agg_m = (mem[s["idx"]] * s["coef"][..., None]).sum(axis=1)
        enc = jnp.cos(s["ts"][..., None] * freq[None, None, :])
        agg_e = (enc * s["coef"][..., None]).sum(axis=1)
        inp = s["x"] @ w_in + agg_m + agg_e
        m_new = fused_gru(inp, mem, wx, wh, b) * s["mask"][:, None]
        return _scatter_rows(store, s["ren"], m_new), m_new

    memT, outs = jax.lax.scan(body, mem0, xs)
    return outs, memT


def tgn_stream_batched_ref(neigh_idx, neigh_coef, neigh_ts, node_feat,
                           renumber, node_mask, mem0, freq, w_in,
                           wx, wh, b):
    """B independent TGN event streams: vmap of the single-stream oracle
    (frequencies, input projection and GRU params shared across streams)."""
    fn = lambda i, c, t, x, r, m, m0: tgn_stream_ref(
        i, c, t, x, r, m, m0, freq, w_in, wx, wh, b)
    return jax.vmap(fn)(neigh_idx, neigh_coef, neigh_ts, node_feat,
                        renumber, node_mask, mem0)


def static_gcn_stream_ref(neigh_idx, neigh_coef, node_feat, node_mask,
                          weights, b_gcn, edge_aggs=None):
    """Static-GCN oracle: (T, n, ...) INDEPENDENT snapshots (no carry,
    no recurrence) through the L-layer GCN — agg @ W_l + b_l, ReLU
    between layers, masked every layer, last layer linear. T is 1 on the
    engine path (static families fold snapshots onto the batch axis);
    the oracle accepts any T since the steps are independent.

    Returns (per-snapshot outputs (T, n, out_dim),) — a 1-tuple, to keep
    the (outs, *final_states) dispatch shape with zero states.
    """
    L = len(weights)
    xs = dict(idx=neigh_idx, coef=neigh_coef, x=node_feat, mask=node_mask)
    if edge_aggs is not None:
        for i, ea in enumerate(edge_aggs):
            xs[f"ea{i}"] = ea

    def step(s):
        x = s["x"]
        m = s["mask"][:, None]
        for i in range(L):
            agg = (x[s["idx"]] * s["coef"][..., None]).sum(axis=1)
            ea = s.get(f"ea{i}")
            if ea is not None:
                agg = agg + ea
            h = agg @ weights[i] + b_gcn[i]
            if i < L - 1:
                h = jax.nn.relu(h)
            x = h * m
        return x

    return (jax.vmap(step)(xs),)


def static_gcn_stream_batched_ref(neigh_idx, neigh_coef, node_feat,
                                  node_mask, weights, b_gcn,
                                  edge_aggs=None):
    """B batches of independent static snapshots: vmap of the solo oracle
    (weights shared across the batch — params, not state)."""
    if edge_aggs is None:
        fn = lambda i, c, x, m: static_gcn_stream_ref(
            i, c, x, m, weights, b_gcn)
        return jax.vmap(fn)(neigh_idx, neigh_coef, node_feat, node_mask)
    fn = lambda i, c, x, m, ea: static_gcn_stream_ref(
        i, c, x, m, weights, b_gcn, ea)
    return jax.vmap(fn)(neigh_idx, neigh_coef, node_feat, node_mask,
                        tuple(edge_aggs))
