"""Pallas TPU kernels for the paper's compute hot spots.

csr_spmm.py    ELL SpMM (message passing)         + oracle in ref.py
fused_rnn.py   fused GRU / LSTM cells (O1)        + oracle in ref.py
dgnn_fused.py  V2 fused GNN+RNN step (node queue) + oracle in ref.py
ops.py         jit'd public wrappers (interpret on non-TPU backends)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
