"""Pallas TPU kernels for the paper's compute hot spots.

csr_spmm.py      ELL SpMM (message passing)         + oracle in ref.py
fused_rnn.py     fused GRU / LSTM cells (O1)        + oracle in ref.py
dgnn_fused.py    V2 fused GNN+RNN step (node queue) + oracle in ref.py
stream_fused.py  V3 time-fused stream (VMEM-resident recurrent state)
                 + stream oracles in ref.py
ops.py           jit'd public wrappers (interpret on non-TPU backends,
                 auto-padding for ragged node counts)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
