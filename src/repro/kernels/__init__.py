"""Pallas TPU kernels for the paper's compute hot spots.

csr_spmm.py      ELL SpMM (message passing)         + oracle in ref.py
fused_rnn.py     fused GRU / LSTM cells (O1)        + oracle in ref.py
dgnn_fused.py    V2 fused GNN+RNN step (node queue) + oracle in ref.py
stream_fused.py  V3 stream engine: ONE generic time-fused kernel + the
                 per-family cell-spec REGISTRY (VMEM-resident recurrent
                 state, D-axis blocking for oversized stores; contract in
                 docs/stream_engine.md) + stream oracles in ref.py
ops.py           jit'd public wrappers (interpret on non-TPU backends,
                 auto-padding for ragged node counts); V3 dispatches
                 through stream_steps[_batched](family, ...)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
