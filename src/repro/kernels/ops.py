"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; elsewhere (this CPU container) they
run in interpret mode, which executes the kernel body in Python with the
same tiling — the correctness contract tests rely on. ``force_ref=True``
routes to the pure-jnp oracle (used by the XLA production path when the
Pallas path is not profitable, e.g. tiny snapshots under vmap).

Ragged node counts are handled here: row-tiled inputs are auto-padded to
the node tile ``tn`` (the sink-row coef-0 convention of graph/padding.py:
padded lanes carry coef 0, padded rows are sliced off the outputs), so
callers never need ``n % tn == 0``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import csr_spmm as _spmm
from repro.kernels import dgnn_fused as _fused
from repro.kernels import fused_rnn as _rnn
from repro.kernels import ref as _ref
from repro.kernels import stream_fused as _stream


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


_FORCE_REF = False


def set_force_ref(flag: bool) -> None:
    """Route ALL kernel wrappers to the pure-jnp oracles (the XLA
    production path) until reset. Benchmarks flip this on CPU hosts, where
    interpret-mode Pallas wall time measures the interpreter rather than
    the dataflow; per-call ``force_ref=True`` stays available for targeted
    use. Affects functions traced AFTER the flip (jit caches keep whatever
    path they captured)."""
    global _FORCE_REF
    _FORCE_REF = flag


# ------------------------------------------------------- fault hook ----
# Launch-site fault injection for the serve engine's chaos harness
# (serve/faults.py). When a hook is installed at TRACE time, the stream
# dispatch embeds an io_callback ahead of the launch, so the hook fires
# at RUN time on every execution of the jitted program — a raised fault
# fails the real launch (wrapped in the backend's callback error), and a
# sleeping hook delays it (deadline tests). The embedded callback reads
# the CURRENT hook on each run, so restoring the hook to None turns
# already-traced programs back into no-ops.

_FAULT_HOOK = None


def set_fault_hook(hook):
    """Install (or clear, with None) the stream-launch fault hook:
    ``hook(family=..., batched=..., force_ref=...)``, called inside every
    stream-engine dispatch. Returns the previous hook so callers can
    scope the installation (the serve engine installs around its run
    loops). Chaos testing only — never installed in production paths."""
    global _FAULT_HOOK
    prev, _FAULT_HOOK = _FAULT_HOOK, hook
    return prev


def _call_fault_hook(family: str, batched: bool, force_ref: bool):
    import numpy as np

    hook = _FAULT_HOOK
    if hook is not None:
        hook(family=family, batched=batched, force_ref=force_ref)
    return np.int32(0)


def _with_fault_probe(run, family: str, batched: bool, force_ref: bool):
    """Sequence the fault hook into the traced program (io_callback: runs
    every execution, never DCE'd). Deliberately NOT ``ordered=True``:
    every serve launch is synchronous (block_until_ready before the next),
    and the ordered token chain would carry a failed probe's error into a
    LATER healthy launch — exactly the cross-launch contamination the
    fault-isolation layer must not manufacture itself."""
    from jax.experimental import io_callback

    def probed(*a):
        io_callback(
            lambda: _call_fault_hook(family, batched, force_ref),
            jax.ShapeDtypeStruct((), jnp.int32))
        return run(*a)

    return probed


# single shared copies of the round-up / constant-fill padding helpers
# (stream_fused owns them; ops re-exports under its historical names)
_pad_to = _stream._pad_dim


def _pad_rows(n: int, tn: int) -> int:
    return _stream._round_up(n, tn)


def ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg=None, *,
             tn: int = 128, force_ref: bool = False):
    if force_ref or _FORCE_REF:
        return _ref.ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg)
    n = neigh_idx.shape[0]
    n2 = _pad_rows(n, tn)
    out = _spmm.ell_spmm_pallas(
        _pad_to(neigh_idx, n2, 0), _pad_to(neigh_coef, n2, 0),
        _pad_to(neigh_eidx, n2, 0), x,
        edge_msg, tn=tn, interpret=_interpret())
    return out[:n]


def fused_gru(x, h, wx, wh, b, *, tb: int = 128, force_ref: bool = False):
    if force_ref or _FORCE_REF:
        return _ref.fused_gru(x, h, wx, wh, b)
    return _rnn.fused_gru_pallas(x, h, wx, wh, b, tb=tb, interpret=_interpret())


def fused_lstm(x, h, c, wx, wh, b, *, tb: int = 128, force_ref: bool = False):
    if force_ref or _FORCE_REF:
        return _ref.fused_lstm(x, h, c, wx, wh, b)
    return _rnn.fused_lstm_pallas(x, h, c, wx, wh, b, tb=tb, interpret=_interpret())


def dgnn_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, c, wx, wh, b,
                    edge_msg=None, *, tn: int = 128, force_ref: bool = False):
    if force_ref or _FORCE_REF:
        return _ref.dgnn_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, c,
                                    wx, wh, b, edge_msg)
    n = neigh_idx.shape[0]
    n2 = _pad_rows(n, tn)
    h_new, c_new = _fused.gcrn_fused_pallas(
        _pad_to(neigh_idx, n2, 0), _pad_to(neigh_coef, n2, 0),
        _pad_to(neigh_eidx, n2, 0), x, h, _pad_to(c, n2, 0),
        wx, wh, b, edge_msg, tn=tn, interpret=_interpret())
    return h_new[:n], c_new[:n]


def stacked_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, w_gcn, b_gcn,
                       wx, wh, b, edge_msg=None, *, tn: int = 128,
                       force_ref: bool = False):
    if force_ref or _FORCE_REF:
        return _ref.stacked_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h,
                                       w_gcn, b_gcn, wx, wh, b, edge_msg)
    n = neigh_idx.shape[0]
    n2 = _pad_rows(n, tn)
    out = _fused.stacked_fused_pallas(
        _pad_to(neigh_idx, n2, 0), _pad_to(neigh_coef, n2, 0),
        _pad_to(neigh_eidx, n2, 0), x, _pad_to(h, n2, 0),
        w_gcn, b_gcn, wx, wh, b, edge_msg, tn=tn, interpret=_interpret())
    return out[:n]


# ------------------------------------------------------------ V3 stream ----
# ONE pair of public entry points — stream_steps / stream_steps_batched —
# dispatching through the stream-engine registry (stream_fused.REGISTRY)
# by family name instead of family-named wrappers. The force-ref gate sits
# at this single entry, so no family branch can silently run the Pallas
# path under force-ref (the regression tests/test_registry.py pins).

def _pad_stream(neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber,
                node_mask, tn: int):
    """Auto-pad the node axis of a (..., n, k)/(..., n) snapshot stream.

    Works for both the single-stream (T, n, ...) and the batched
    (B, T, n, ...) layouts: the node axis is always -2 on the ELL/feature
    arrays and -1 on the per-node row arrays.
    """
    n = neigh_idx.shape[-2]
    n2 = _pad_rows(n, tn)
    return (n,
            _pad_to(neigh_idx, n2, -2), _pad_to(neigh_coef, n2, -2),
            _pad_to(neigh_eidx, n2, -2), _pad_to(node_feat, n2, -2),
            _pad_to(renumber, n2, -1, fill=-1), _pad_to(node_mask, n2, -1))


def _stream_index_tables(renumber, neigh_idx, n_global: int):
    """Precompute the kernel's global-id tables from the renumber stream.

    ``neigh_gidx``: global id of each ELL lane's source node (safe 0 where
    the lane is padding — its coef is 0). ``row_gidx``: global row of each
    local node, ``n_global`` (the drop sentinel) on padding rows. Leading
    axes (T,) or (B, T) pass through untouched.
    """
    ren_safe = jnp.where(renumber >= 0, renumber, 0).astype(jnp.int32)
    flat = neigh_idx.reshape(*neigh_idx.shape[:-2], -1)
    neigh_gidx = jnp.take_along_axis(ren_safe, flat,
                                     axis=-1).reshape(neigh_idx.shape)
    row_gidx = jnp.where(renumber >= 0, renumber, n_global).astype(jnp.int32)
    return neigh_gidx.astype(jnp.int32), row_gidx


def _gcrn_launch(batched, neigh_idx, neigh_coef, neigh_eidx, node_feat,
                 renumber, node_mask, h0, c0, wx, wh, b, edge_msg=None, *,
                 tn: int, td, residency: str = "vmem", depth: int = 2):
    """Pad/pack + engine launch for the integrated (GC-LSTM) family."""
    if not batched:
        em = None if edge_msg is None else edge_msg[None]
        outs, hT, cT = _gcrn_launch(
            True, neigh_idx[None], neigh_coef[None], neigh_eidx[None],
            node_feat[None], renumber[None], node_mask[None], h0[None],
            c0[None], wx, wh, b, em, tn=tn, td=td, residency=residency,
            depth=depth)
        return outs[0], hT[0], cT[0]
    n, idx, coef, eidx, x, ren, mask = _pad_stream(
        neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber, node_mask, tn)
    gidx, rowg = _stream_index_tables(ren, idx, h0.shape[1])
    h = h0.shape[-1]
    outs, hT, cT = _stream.stream_call(
        "gcrn", idx, gidx, coef, eidx, x, rowg, mask, h0, c0, wx, wh, b,
        edge_msg, tn=tn, td=td, interpret=_interpret(), residency=residency,
        depth=depth)
    return outs[:, :, :n, :h], hT[..., :h], cT[..., :h]


def _stacked_launch(batched, neigh_idx, neigh_coef, neigh_eidx, node_feat,
                    renumber, node_mask, h0, w_gcn, b_gcn, wx, wh, b,
                    edge_msg=None, *, tn: int, td,
                    residency: str = "vmem", depth: int = 2):
    """Pad/pack + engine launch for the stacked (GCN -> GRU) family."""
    if not batched:
        em = None if edge_msg is None else edge_msg[None]
        outs, hT = _stacked_launch(
            True, neigh_idx[None], neigh_coef[None], neigh_eidx[None],
            node_feat[None], renumber[None], node_mask[None], h0[None],
            w_gcn, b_gcn, wx, wh, b, em, tn=tn, td=td,
            residency=residency, depth=depth)
        return outs[0], hT[0]
    n, idx, coef, eidx, x, ren, mask = _pad_stream(
        neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber, node_mask, tn)
    _, rowg = _stream_index_tables(ren, idx, h0.shape[1])
    h = h0.shape[-1]
    outs, hT = _stream.stream_call(
        "stacked", idx, coef, eidx, x, rowg, mask, h0, w_gcn, b_gcn,
        wx, wh, b, edge_msg, tn=tn, td=td, interpret=_interpret(),
        residency=residency, depth=depth)
    return outs[:, :, :n, :h], hT[..., :h]


# ---------------------------------------- V3 weights-resident stream ----

def _pad_matrix_gru_params(wx, wh, b, dmax: int):
    """Zero-pad square matrix-GRU cell params (din -> din) to dmax PER
    GATE BLOCK, so the padded cell splits its gates at dmax boundaries
    and the valid region evolves exactly as the unpadded cell. Padded
    weight ROWS evolve to zero under the padded cell (their gate inputs
    are identically zero), which is the invariant the kernel's padded
    matmuls rely on."""
    def pad_gates(m):
        blocks = jnp.split(m, 3, axis=1)
        return jnp.concatenate(
            [_pad_to(_pad_to(g, dmax, 0), dmax, 1) for g in blocks], axis=1)

    b3 = jnp.split(b, 3)
    return (pad_gates(wx), pad_gates(wh),
            jnp.concatenate([_pad_to(g, dmax, 0) for g in b3]))


def _stack_padded(mats, dmax: int, batched: bool):
    """Stack per-layer (optionally per-stream) matrices into one
    (L, dmax, dmax) / (B, L, dmax, dmax) zero-padded array."""
    axis = 1 if batched else 0
    return jnp.stack([_pad_to(_pad_to(w, dmax, -2), dmax, -1) for w in mats],
                     axis=axis)


def _evolve_pack(neigh_idx, neigh_coef, node_feat, node_mask, weights,
                 b_gcn, gru_wx, gru_wh, gru_b, edge_aggs, tn: int,
                 td, batched: bool):
    """Shared padding/packing for the weights-resident stream family. All
    layer widths are zero-padded into one common square ``dmax`` (rounded
    up to a ``td`` multiple so the engine's d axis tiles it evenly)."""
    n = neigh_idx.shape[-2]
    n2 = _pad_rows(n, tn)
    dims = [(w.shape[-2], w.shape[-1]) for w in weights]
    dmax = max(max(d) for d in dims)
    if td is not None:
        dmax = ((dmax + td - 1) // td) * td
    idx = _pad_to(neigh_idx, n2, -2)
    coef = _pad_to(neigh_coef, n2, -2)
    x = _pad_to(_pad_to(node_feat, n2, -2), dmax, -1)
    mask = _pad_to(node_mask, n2, -1)
    w0 = _stack_padded(weights, dmax, batched)
    bg = jnp.stack([_pad_to(bb, dmax, 0) for bb in b_gcn])
    if edge_aggs is None:
        eagg = None  # static has_edge=False specialization in the kernel
    else:
        eagg = jnp.stack(
            [_pad_to(_pad_to(ea, n2, -2), dmax, -1) for ea in edge_aggs],
            axis=-3)
    gwx, gwh, gb = zip(*[_pad_matrix_gru_params(wx, wh, bb, dmax)
                         for wx, wh, bb in zip(gru_wx, gru_wh, gru_b)])
    return (n, dims, idx, coef, x, mask, w0, bg, eagg,
            jnp.stack(gwx), jnp.stack(gwh), jnp.stack(gb))


def _evolve_unpack(outs, wT, n: int, dims, out_dim: int, batched: bool):
    """Slice kernel-padded outputs/weights back to their true shapes."""
    outs = outs[..., :n, :out_dim]
    sl = (slice(None),) if batched else ()
    weights = tuple(wT[sl + (i, slice(0, di), slice(0, do))]
                    for i, (di, do) in enumerate(dims))
    return outs, weights


def _evolve_launch(batched, neigh_idx, neigh_coef, node_feat, node_mask,
                   live, weights, b_gcn, gru_wx, gru_wh, gru_b,
                   edge_aggs=None, *, tn: int, td,
                   residency: str = "vmem", depth: int = 2):
    """Pad/pack + engine launch for the weights-evolved family.

    ``weights``/``b_gcn``/``gru_*`` are per-layer lists (true, unpadded
    shapes; batched adds a leading B axis to ``weights`` leaves);
    ``edge_aggs`` is the per-layer pre-aggregated edge-message term or
    None; ``live`` gates the in-kernel matrix-GRU evolution so no-op tail
    snapshots leave the weights untouched."""
    if not batched:
        ea = None if edge_aggs is None else [a[None] for a in edge_aggs]
        outs, wT = _evolve_launch(
            True, neigh_idx[None], neigh_coef[None], node_feat[None],
            node_mask[None], jnp.asarray(live)[None],
            [w[None] for w in weights], b_gcn, gru_wx, gru_wh, gru_b, ea,
            tn=tn, td=td, residency=residency, depth=depth)
        return outs[0], tuple(w[0] for w in wT)
    n, dims, idx, coef, x, mask, w0, bg, eagg, gwx, gwh, gb = _evolve_pack(
        neigh_idx, neigh_coef, node_feat, node_mask, weights, b_gcn,
        gru_wx, gru_wh, gru_b, edge_aggs, tn, td, batched=True)
    outs, wT = _stream.stream_call(
        "evolve", idx, coef, x, mask, jnp.asarray(live, jnp.int32), w0, bg,
        gwx, gwh, gb, eagg, tn=tn, td=td, interpret=_interpret(),
        residency=residency, depth=depth)
    return _evolve_unpack(outs, wT, n, dims, dims[-1][1], batched=True)


# ----------------------------------------- temporal-contract launchers ----

def _tgn_launch(batched, neigh_idx, neigh_coef, neigh_ts, node_feat,
                renumber, node_mask, mem0, freq, w_in, wx, wh, b, *,
                tn: int, td, residency: str = "vmem", depth: int = 2):
    """Pad/pack + engine launch for the event-stream (TGN) family.

    The T axis sequences EVENT BATCHES (graph/events.pad_event_block):
    ``neigh_ts`` carries per-event-lane timestamps in the slot dense
    families use for edge indices — same (..., n, k) shape, validated
    here, zero on dead lanes (their coef is 0, so the time encoding of a
    padded event contributes exactly zero)."""
    if neigh_ts.shape != neigh_idx.shape:
        raise ValueError(
            f"tgn event timestamps must match the ELL lane shape: "
            f"ts {neigh_ts.shape} vs idx {neigh_idx.shape}")
    if not jnp.issubdtype(jnp.asarray(neigh_ts).dtype, jnp.floating):
        raise ValueError(
            f"tgn event timestamps must be floating, got "
            f"{jnp.asarray(neigh_ts).dtype}")
    if not batched:
        outs, memT = _tgn_launch(
            True, neigh_idx[None], neigh_coef[None], neigh_ts[None],
            node_feat[None], renumber[None], node_mask[None], mem0[None],
            freq, w_in, wx, wh, b, tn=tn, td=td, residency=residency,
            depth=depth)
        return outs[0], memT[0]
    # ts rides the eidx slot of the shared padder (same node-axis layout)
    n, idx, coef, ts, x, ren, mask = _pad_stream(
        neigh_idx, neigh_coef, neigh_ts, node_feat, renumber, node_mask, tn)
    gidx, rowg = _stream_index_tables(ren, idx, mem0.shape[1])
    h = mem0.shape[-1]
    outs, memT = _stream.stream_call(
        "tgn", gidx, coef, ts, x, rowg, mask, mem0, freq, w_in, wx, wh, b,
        tn=tn, td=td, interpret=_interpret(), residency=residency,
        depth=depth)
    return outs[:, :, :n, :h], memT[..., :h]


def _static_pack(neigh_idx, neigh_coef, node_feat, node_mask, weights,
                 b_gcn, edge_aggs, tn: int, td):
    """Padding/packing for the static (no-recurrence) family: the same
    common-square ``dmax`` layout as the weights-evolved pack, minus the
    GRU params and the live flag — weights are shared params, not
    per-stream state."""
    n = neigh_idx.shape[-2]
    n2 = _pad_rows(n, tn)
    dims = [(w.shape[-2], w.shape[-1]) for w in weights]
    dmax = max(max(d) for d in dims)
    if td is not None:
        dmax = ((dmax + td - 1) // td) * td
    idx = _pad_to(neigh_idx, n2, -2)
    coef = _pad_to(neigh_coef, n2, -2)
    x = _pad_to(_pad_to(node_feat, n2, -2), dmax, -1)
    mask = _pad_to(node_mask, n2, -1)
    w = _stack_padded(weights, dmax, batched=False)    # (L, dmax, dmax)
    bg = jnp.stack([_pad_to(bb, dmax, 0) for bb in b_gcn])
    if edge_aggs is None:
        eagg = None  # static has_edge=False specialization in the kernel
    else:
        eagg = jnp.stack(
            [_pad_to(_pad_to(ea, n2, -2), dmax, -1) for ea in edge_aggs],
            axis=-3)
    return n, dims, idx, coef, x, mask, w, bg, eagg


def _static_launch(batched, neigh_idx, neigh_coef, node_feat, node_mask,
                   weights, b_gcn, edge_aggs=None, *, tn: int, td,
                   residency: str = "vmem", depth: int = 2):
    """Pad/pack + engine launch for the static (no-recurrence) family.

    T must be 1 on the engine path (the kernel raises otherwise):
    independent snapshots fold onto the batch axis, which is what makes
    the serve express lane a plain co-batched launch with no state
    checkpointing. Returns a 1-tuple ``(outs,)`` — zero final states."""
    if not batched:
        ea = None if edge_aggs is None else [a[None] for a in edge_aggs]
        (outs,) = _static_launch(
            True, neigh_idx[None], neigh_coef[None], node_feat[None],
            node_mask[None], weights, b_gcn, ea, tn=tn, td=td,
            residency=residency, depth=depth)
        return (outs[0],)
    n, dims, idx, coef, x, mask, w, bg, eagg = _static_pack(
        neigh_idx, neigh_coef, node_feat, node_mask, weights, b_gcn,
        edge_aggs, tn, td)
    (outs,) = _stream.stream_call(
        "static_gcn", idx, coef, x, mask, w, bg, eagg,
        tn=tn, td=td, interpret=_interpret(), residency=residency,
        depth=depth)
    return (outs[..., :n, :dims[-1][1]],)


# ------------------------------------------------- unified stream entry ----
# family name -> ((solo oracle, batched oracle), engine launcher,
# batched-arg index set, ragged-axis index map). The oracle column is the
# XLA production path; the launcher column pads, packs, and dispatches
# through stream_fused.REGISTRY. The batched-arg set lists the positional
# args whose leaves carry a leading B axis (DeviceSpec shards exactly
# those); the ragged map names the (coef, mask, renumber, live) arg
# positions the per-stream ``lengths`` masking rewrites.

_STREAM_DISPATCH = {
    "gcrn": ((_ref.gcrn_stream_ref, _ref.gcrn_stream_batched_ref),
             _gcrn_launch, frozenset(range(8)) | {11},
             dict(coef=1, mask=5, ren=4, live=None)),
    "stacked": ((_ref.stacked_stream_ref, _ref.stacked_stream_batched_ref),
                _stacked_launch, frozenset(range(7)) | {12},
                dict(coef=1, mask=5, ren=4, live=None)),
    "evolve": ((_ref.evolve_stream_ref, _ref.evolve_stream_batched_ref),
               _evolve_launch, frozenset(range(6)) | {10},
               dict(coef=1, mask=3, ren=None, live=4)),
    "tgn": ((_ref.tgn_stream_ref, _ref.tgn_stream_batched_ref),
            _tgn_launch, frozenset(range(7)),
            dict(coef=1, mask=5, ren=4, live=None)),
    "static_gcn": ((_ref.static_gcn_stream_ref,
                    _ref.static_gcn_stream_batched_ref),
                   _static_launch, frozenset(range(4)) | {6},
                   dict(coef=1, mask=3, ren=None, live=None)),
}


def stream_families() -> tuple:
    """Families servable by the stream engine (== stream_fused.REGISTRY)."""
    return tuple(sorted(_STREAM_DISPATCH))


def family_temporal(family: str) -> str:
    """The family's declared time semantics ("dense" | "event" |
    "static") from its registry cell spec — the single source of truth
    the plan layer and the serve engine read instead of assuming
    dense-T."""
    if family not in _stream.REGISTRY:
        raise KeyError(f"unknown stream-engine family {family!r}; "
                       f"registered: {stream_families()}")
    return _stream.REGISTRY[family].temporal


def _apply_lengths(family: str, args: tuple, lengths) -> tuple:
    """Turn the T tail of each stream in a (B, T, ...) batch into no-op
    snapshots: steps t >= lengths[b] get coef 0 / mask 0 / renumber -1
    (and live 0 for weights-evolved families), which is exactly the
    empty-snapshot no-op contract the engine already honours — so the tail
    CONTENT is irrelevant and callers can pad ragged streams with anything
    shape-compatible instead of manufacturing empty snapshots."""
    axes = _STREAM_DISPATCH[family][3]
    lengths = jnp.asarray(lengths, jnp.int32)
    coef = args[axes["coef"]]
    t_axis = jnp.arange(coef.shape[1], dtype=jnp.int32)
    live = t_axis[None, :] < lengths[:, None]          # (B, T)
    out = list(args)
    out[axes["coef"]] = jnp.asarray(coef) * live[:, :, None, None]
    mi = axes["mask"]
    out[mi] = jnp.asarray(args[mi]) * live[:, :, None]
    if axes["ren"] is not None:
        ri = axes["ren"]
        out[ri] = jnp.where(live[:, :, None], jnp.asarray(args[ri]), -1)
    if axes["live"] is not None:
        li = axes["live"]
        out[li] = jnp.asarray(args[li]) * live.astype(jnp.int32)
    return tuple(out)


def _shard_batch(family: str, run, args, device):
    """Wrap a batched stream launch in shard_map over the DeviceSpec mesh:
    the leading B grid axis splits across devices (streams are
    independent — no collectives), shared params replicate. Covers the
    Pallas engine AND the force-ref oracle path identically."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_stream_mesh

    B = args[0].shape[0]
    if B % device.n_devices:
        raise ValueError(
            f"stream batch B={B} not divisible by DeviceSpec.n_devices="
            f"{device.n_devices}")
    batch_args = _STREAM_DISPATCH[family][2]
    in_specs = tuple(P(device.axis) if i in batch_args else P()
                     for i in range(len(args)))
    return shard_map(run, mesh=make_stream_mesh(device), in_specs=in_specs,
                     out_specs=P(device.axis), check_rep=False)


def _stream_dispatch(family: str, batched: bool, args, kwargs, *, tn, td,
                     force_ref, lengths=None, device=None,
                     residency: str = "vmem", depth: int = 2):
    if family not in _STREAM_DISPATCH:
        raise KeyError(f"unknown stream-engine family {family!r}; "
                       f"registered: {stream_families()}")
    oracles, launch = _STREAM_DISPATCH[family][:2]
    if batched and lengths is not None:
        args = _apply_lengths(family, args, lengths)
    ref = bool(force_ref or _FORCE_REF)
    if ref:
        # single force-ref gate for EVERY family and batching mode: the
        # engine launcher (and thus pallas_call) is unreachable from here.
        run = lambda *a: oracles[1 if batched else 0](*a, **kwargs)
    else:
        run = lambda *a: launch(batched, *a, **kwargs, tn=tn, td=td,
                                residency=residency, depth=depth)
    if _FAULT_HOOK is not None:
        run = _with_fault_probe(run, family, batched, ref)
    if batched and device is not None and device.n_devices > 1:
        if kwargs:
            raise ValueError("keyword stream args are unsupported under "
                             "DeviceSpec sharding; pass them positionally")
        run = _shard_batch(family, run, args, device)
    return run(*args)


def stream_steps(family: str, *args, tn: int = 128, td=None,
                 state_residency: str = "vmem", buffer_depth=None,
                 force_ref: bool = False, **kwargs):
    """Time-fused V3 stream (one stream): T snapshots through ONE launch of
    the generic stream engine, dispatched by ``family``
    (``stream_fused.REGISTRY``). The family's recurrent state (node-state
    store, or EvolveGCN's evolving weights) crosses HBM exactly twice per
    stream instead of twice per step. ``td`` blocks the state feature axis
    for VMEM-oversized stores (None = fully resident); blocked and
    unblocked layouts compute identical results. ``state_residency``
    picks where the store LIVES across the stream: "vmem" (resident
    scratch) or "hbm_paged" (HBM store aliased in-place, ``(n_global,
    td)`` windows DMA-staged through a ``buffer_depth``-deep VMEM ring —
    bit-identical outputs, requires ``td``; ``buffer_depth=None`` means
    depth 2).

    Family argument lists (same order as the kernels/ref.py oracles):
      gcrn     (idx, coef, eidx, x, renumber, mask, h0, c0, wx, wh, b,
                edge_msg=None) -> (outs, hT, cT)
      stacked  (idx, coef, eidx, x, renumber, mask, h0, w_gcn, b_gcn,
                wx, wh, b, edge_msg=None) -> (outs, hT)
      evolve   (idx, coef, x, mask, live, weights, b_gcn, gru_wx, gru_wh,
                gru_b, edge_aggs=None) -> (outs, weights_T)
      tgn      (idx, coef, ts, x, renumber, mask, mem0, freq, w_in,
                wx, wh, b) -> (outs, memT)            [temporal="event":
                the T axis sequences ragged event batches, ts carries
                per-event-lane timestamps]
      static_gcn (idx, coef, x, mask, weights, b_gcn, edge_aggs=None)
                -> (outs,)                            [temporal="static":
                T must be 1; fold snapshots onto the batch axis]
    """
    return _stream_dispatch(family, False, args, kwargs, tn=tn, td=td,
                            force_ref=force_ref, residency=state_residency,
                            depth=2 if buffer_depth is None else buffer_depth)


def stream_steps_batched(family: str, *args, tn: int = 128, td=None,
                         lengths=None, device=None,
                         state_residency: str = "vmem", buffer_depth=None,
                         force_ref: bool = False, **kwargs):
    """B independent time-fused streams in ONE engine launch (the batch is
    a leading grid dimension; weights shared, one resident state per
    stream). Same family argument lists as ``stream_steps`` with a leading
    (B, ...) axis on stream arrays and per-stream state.

    ``lengths`` ((B,) ints) makes the launch RAGGED over T: stream b's
    steps past ``lengths[b]`` execute as no-ops (coef/mask zeroed,
    renumber -1, live 0 — inside the traced program, so the tail content
    of the stacked arrays is irrelevant and a length-0 row is a pure
    padding stream). ``device`` (launch/mesh.DeviceSpec) shards the
    leading B axis across devices via shard_map; streams are independent,
    so the sharded launch is bit-identical to the unsharded one."""
    return _stream_dispatch(family, True, args, kwargs, tn=tn, td=td,
                            force_ref=force_ref, lengths=lengths,
                            device=device, residency=state_residency,
                            depth=2 if buffer_depth is None else buffer_depth)
