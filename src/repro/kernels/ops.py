"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; elsewhere (this CPU container) they
run in interpret mode, which executes the kernel body in Python with the
same tiling — the correctness contract tests rely on. ``force_ref=True``
routes to the pure-jnp oracle (used by the XLA production path when the
Pallas path is not profitable, e.g. tiny snapshots under vmap).

Ragged node counts are handled here: row-tiled inputs are auto-padded to
the node tile ``tn`` (the sink-row coef-0 convention of graph/padding.py:
padded lanes carry coef 0, padded rows are sliced off the outputs), so
callers never need ``n % tn == 0``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import csr_spmm as _spmm
from repro.kernels import dgnn_fused as _fused
from repro.kernels import fused_rnn as _rnn
from repro.kernels import ref as _ref
from repro.kernels import stream_fused as _stream


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


_FORCE_REF = False


def set_force_ref(flag: bool) -> None:
    """Route ALL kernel wrappers to the pure-jnp oracles (the XLA
    production path) until reset. Benchmarks flip this on CPU hosts, where
    interpret-mode Pallas wall time measures the interpreter rather than
    the dataflow; per-call ``force_ref=True`` stays available for targeted
    use. Affects functions traced AFTER the flip (jit caches keep whatever
    path they captured)."""
    global _FORCE_REF
    _FORCE_REF = flag


def _pad_rows(n: int, tn: int) -> int:
    return ((n + tn - 1) // tn) * tn


def _pad_to(a, n2: int, axis: int, fill=0):
    """Pad ``a`` to ``n2`` rows along ``axis`` with a constant fill."""
    n = a.shape[axis]
    if n == n2:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, n2 - n)
    return jnp.pad(a, widths, constant_values=fill)


def ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg=None, *,
             tn: int = 128, force_ref: bool = False):
    if force_ref or _FORCE_REF:
        return _ref.ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg)
    n = neigh_idx.shape[0]
    n2 = _pad_rows(n, tn)
    out = _spmm.ell_spmm_pallas(
        _pad_to(neigh_idx, n2, 0), _pad_to(neigh_coef, n2, 0),
        _pad_to(neigh_eidx, n2, 0), x,
        edge_msg, tn=tn, interpret=_interpret())
    return out[:n]


def fused_gru(x, h, wx, wh, b, *, tb: int = 128, force_ref: bool = False):
    if force_ref or _FORCE_REF:
        return _ref.fused_gru(x, h, wx, wh, b)
    return _rnn.fused_gru_pallas(x, h, wx, wh, b, tb=tb, interpret=_interpret())


def fused_lstm(x, h, c, wx, wh, b, *, tb: int = 128, force_ref: bool = False):
    if force_ref or _FORCE_REF:
        return _ref.fused_lstm(x, h, c, wx, wh, b)
    return _rnn.fused_lstm_pallas(x, h, c, wx, wh, b, tb=tb, interpret=_interpret())


def dgnn_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, c, wx, wh, b,
                    edge_msg=None, *, tn: int = 128, force_ref: bool = False):
    if force_ref or _FORCE_REF:
        return _ref.dgnn_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, c,
                                    wx, wh, b, edge_msg)
    n = neigh_idx.shape[0]
    n2 = _pad_rows(n, tn)
    h_new, c_new = _fused.gcrn_fused_pallas(
        _pad_to(neigh_idx, n2, 0), _pad_to(neigh_coef, n2, 0),
        _pad_to(neigh_eidx, n2, 0), x, h, _pad_to(c, n2, 0),
        wx, wh, b, edge_msg, tn=tn, interpret=_interpret())
    return h_new[:n], c_new[:n]


def stacked_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, w_gcn, b_gcn,
                       wx, wh, b, edge_msg=None, *, tn: int = 128,
                       force_ref: bool = False):
    if force_ref or _FORCE_REF:
        return _ref.stacked_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h,
                                       w_gcn, b_gcn, wx, wh, b, edge_msg)
    n = neigh_idx.shape[0]
    n2 = _pad_rows(n, tn)
    out = _fused.stacked_fused_pallas(
        _pad_to(neigh_idx, n2, 0), _pad_to(neigh_coef, n2, 0),
        _pad_to(neigh_eidx, n2, 0), x, _pad_to(h, n2, 0),
        w_gcn, b_gcn, wx, wh, b, edge_msg, tn=tn, interpret=_interpret())
    return out[:n]


# ------------------------------------------------------------ V3 stream ----

def _pad_stream(neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber,
                node_mask, tn: int):
    """Auto-pad the node axis of a (..., n, k)/(..., n) snapshot stream.

    Works for both the single-stream (T, n, ...) and the batched
    (B, T, n, ...) layouts: the node axis is always -2 on the ELL/feature
    arrays and -1 on the per-node row arrays.
    """
    n = neigh_idx.shape[-2]
    n2 = _pad_rows(n, tn)
    return (n,
            _pad_to(neigh_idx, n2, -2), _pad_to(neigh_coef, n2, -2),
            _pad_to(neigh_eidx, n2, -2), _pad_to(node_feat, n2, -2),
            _pad_to(renumber, n2, -1, fill=-1), _pad_to(node_mask, n2, -1))


def _stream_index_tables(renumber, neigh_idx, n_global: int):
    """Precompute the kernel's global-id tables from the renumber stream.

    ``neigh_gidx``: global id of each ELL lane's source node (safe 0 where
    the lane is padding — its coef is 0). ``row_gidx``: global row of each
    local node, ``n_global`` (the drop sentinel) on padding rows. Leading
    axes (T,) or (B, T) pass through untouched.
    """
    ren_safe = jnp.where(renumber >= 0, renumber, 0).astype(jnp.int32)
    flat = neigh_idx.reshape(*neigh_idx.shape[:-2], -1)
    neigh_gidx = jnp.take_along_axis(ren_safe, flat,
                                     axis=-1).reshape(neigh_idx.shape)
    row_gidx = jnp.where(renumber >= 0, renumber, n_global).astype(jnp.int32)
    return neigh_gidx.astype(jnp.int32), row_gidx


def dgnn_stream_steps(neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber,
                      node_mask, h0, c0, wx, wh, b, edge_msg=None, *,
                      tn: int = 128, force_ref: bool = False):
    """Time-fused GCRN stream (V3): T snapshots through one kernel launch.

    The h/c global stores cross HBM exactly once per stream instead of once
    per step. Returns (per-step h (T, n, H), final h store, final c store).
    """
    if force_ref or _FORCE_REF:
        return _ref.gcrn_stream_ref(neigh_idx, neigh_coef, neigh_eidx,
                                    node_feat, renumber, node_mask, h0, c0,
                                    wx, wh, b, edge_msg)
    n, idx, coef, eidx, x, ren, mask = _pad_stream(
        neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber, node_mask, tn)
    gidx, rowg = _stream_index_tables(ren, idx, h0.shape[0])
    outs, hT, cT = _stream.gcrn_stream_pallas(
        idx, gidx, coef, eidx, x, rowg, mask, h0, c0, wx, wh, b, edge_msg,
        tn=tn, interpret=_interpret())
    return outs[:, :n], hT, cT


def stacked_stream_steps(neigh_idx, neigh_coef, neigh_eidx, node_feat,
                         renumber, node_mask, h0, w_gcn, b_gcn, wx, wh, b,
                         edge_msg=None, *, tn: int = 128,
                         force_ref: bool = False):
    """Time-fused stacked stream (V3): last GCN layer + GRU for T snapshots
    in one kernel launch, h store VMEM-resident throughout.

    Returns (per-step h (T, n, H), final h store).
    """
    if force_ref or _FORCE_REF:
        return _ref.stacked_stream_ref(neigh_idx, neigh_coef, neigh_eidx,
                                       node_feat, renumber, node_mask, h0,
                                       w_gcn, b_gcn, wx, wh, b, edge_msg)
    n, idx, coef, eidx, x, ren, mask = _pad_stream(
        neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber, node_mask, tn)
    _, rowg = _stream_index_tables(ren, idx, h0.shape[0])
    outs, hT = _stream.stacked_stream_pallas(
        idx, coef, eidx, x, rowg, mask, h0, w_gcn, b_gcn, wx, wh, b, edge_msg,
        tn=tn, interpret=_interpret())
    return outs[:, :n], hT


# -------------------------------------------------- V3 batched streams ----

def dgnn_stream_steps_batched(neigh_idx, neigh_coef, neigh_eidx, node_feat,
                              renumber, node_mask, h0, c0, wx, wh, b,
                              edge_msg=None, *, tn: int = 128,
                              force_ref: bool = False):
    """B independent time-fused GCRN streams in ONE kernel launch.

    Arrays carry a leading (B, T, ...) layout; h0/c0 are (B, n_global, H) —
    one recurrent state store per stream, each crossing HBM exactly twice.
    Returns (per-step h (B, T, n, H), final h (B, G, H), final c (B, G, H)).
    """
    if force_ref or _FORCE_REF:
        return _ref.gcrn_stream_batched_ref(neigh_idx, neigh_coef, neigh_eidx,
                                            node_feat, renumber, node_mask,
                                            h0, c0, wx, wh, b, edge_msg)
    n, idx, coef, eidx, x, ren, mask = _pad_stream(
        neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber, node_mask, tn)
    gidx, rowg = _stream_index_tables(ren, idx, h0.shape[1])
    outs, hT, cT = _stream.gcrn_stream_batched_pallas(
        idx, gidx, coef, eidx, x, rowg, mask, h0, c0, wx, wh, b, edge_msg,
        tn=tn, interpret=_interpret())
    return outs[:, :, :n], hT, cT


def stacked_stream_steps_batched(neigh_idx, neigh_coef, neigh_eidx, node_feat,
                                 renumber, node_mask, h0, w_gcn, b_gcn,
                                 wx, wh, b, edge_msg=None, *, tn: int = 128,
                                 force_ref: bool = False):
    """B independent time-fused stacked streams in ONE kernel launch.

    Returns (per-step h (B, T, n, H), final h store (B, G, H)).
    """
    if force_ref or _FORCE_REF:
        return _ref.stacked_stream_batched_ref(
            neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber, node_mask,
            h0, w_gcn, b_gcn, wx, wh, b, edge_msg)
    n, idx, coef, eidx, x, ren, mask = _pad_stream(
        neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber, node_mask, tn)
    _, rowg = _stream_index_tables(ren, idx, h0.shape[1])
    outs, hT = _stream.stacked_stream_batched_pallas(
        idx, coef, eidx, x, rowg, mask, h0, w_gcn, b_gcn, wx, wh, b, edge_msg,
        tn=tn, interpret=_interpret())
    return outs[:, :, :n], hT
