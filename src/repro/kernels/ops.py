"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; elsewhere (this CPU container) they
run in interpret mode, which executes the kernel body in Python with the
same tiling — the correctness contract tests rely on. ``force_ref=True``
routes to the pure-jnp oracle (used by the XLA production path when the
Pallas path is not profitable, e.g. tiny snapshots under vmap).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import csr_spmm as _spmm
from repro.kernels import dgnn_fused as _fused
from repro.kernels import fused_rnn as _rnn
from repro.kernels import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(n: int, tn: int) -> int:
    return ((n + tn - 1) // tn) * tn


def ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg=None, *,
             tn: int = 128, force_ref: bool = False):
    if force_ref:
        return _ref.ell_spmm(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg)
    n = neigh_idx.shape[0]
    assert n % tn == 0, f"pad n_pad ({n}) to a multiple of the node tile ({tn})"
    return _spmm.ell_spmm_pallas(neigh_idx, neigh_coef, neigh_eidx, x,
                                 edge_msg, tn=tn, interpret=_interpret())


def fused_gru(x, h, wx, wh, b, *, tb: int = 128, force_ref: bool = False):
    if force_ref:
        return _ref.fused_gru(x, h, wx, wh, b)
    return _rnn.fused_gru_pallas(x, h, wx, wh, b, tb=tb, interpret=_interpret())


def fused_lstm(x, h, c, wx, wh, b, *, tb: int = 128, force_ref: bool = False):
    if force_ref:
        return _ref.fused_lstm(x, h, c, wx, wh, b)
    return _rnn.fused_lstm_pallas(x, h, c, wx, wh, b, tb=tb, interpret=_interpret())


def dgnn_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, c, wx, wh, b,
                    edge_msg=None, *, tn: int = 128, force_ref: bool = False):
    if force_ref:
        return _ref.dgnn_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, c,
                                    wx, wh, b, edge_msg)
    return _fused.gcrn_fused_pallas(neigh_idx, neigh_coef, neigh_eidx, x, h, c,
                                    wx, wh, b, edge_msg, tn=tn,
                                    interpret=_interpret())


def stacked_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h, w_gcn, b_gcn,
                       wx, wh, b, edge_msg=None, *, tn: int = 128,
                       force_ref: bool = False):
    if force_ref:
        return _ref.stacked_fused_step(neigh_idx, neigh_coef, neigh_eidx, x, h,
                                       w_gcn, b_gcn, wx, wh, b, edge_msg)
    return _fused.stacked_fused_pallas(neigh_idx, neigh_coef, neigh_eidx, x, h,
                                       w_gcn, b_gcn, wx, wh, b, edge_msg,
                                       tn=tn, interpret=_interpret())
