"""DGNN-Booster V2 fused step kernels: the node-queue FIFO as a VMEM tile.

One Pallas kernel per DGNN family fuses, per node tile:
  MP   — ELL aggregation over VMEM-resident x (and h for GCRN),
  NT   — the gate / node-transform matmul,
  RNN  — the recurrent elementwise update,
so the GNN-output embedding for a tile of nodes never leaves VMEM before
the RNN consumes it — the exact dataflow the paper builds with FIFOs
between the GNN PEs and RNN PEs, with Pallas' BlockSpec double-buffering
playing the role of the queue's back-pressure.

gcrn variant   (GC-LSTM):  h',c' = LSTM(aggx @ wx + aggh @ wh + b, c)
stacked variant (GCN->GRU): h'   = GRU(agg @ w_gcn + b_gcn, h)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg(idx, coef, x):
    tn, k = idx.shape
    g = jnp.take(x, idx.reshape(-1), axis=0).reshape(tn, k, x.shape[1])
    return (g * coef[..., None]).sum(axis=1)


def _agg_edge(idx, coef, eidx, x, em):
    tn, k = idx.shape
    g = jnp.take(x, idx.reshape(-1), axis=0).reshape(tn, k, x.shape[1])
    ge = jnp.take(em, eidx.reshape(-1), axis=0).reshape(tn, k, x.shape[1])
    return ((g + ge) * coef[..., None]).sum(axis=1)


def _gcrn_kernel(has_edge, idx_ref, coef_ref, eidx_ref, x_ref, h_ref, c_ref,
                 wx_ref, wh_ref, b_ref, emsg_ref, h_out_ref, c_out_ref):
    idx, coef, eidx = idx_ref[...], coef_ref[...], eidx_ref[...]
    x, h_full, c = x_ref[...], h_ref[...], c_ref[...]
    if has_edge:
        agg_x = _agg_edge(idx, coef, eidx, x, emsg_ref[...])
    else:
        agg_x = _agg(idx, coef, x)
    agg_h = _agg(idx, coef, h_full)
    gates = agg_x @ wx_ref[...] + agg_h @ wh_ref[...] + b_ref[...][None, :]
    hdim = h_full.shape[1]
    i = gates[:, :hdim]
    f = gates[:, hdim:2 * hdim]
    g = gates[:, 2 * hdim:3 * hdim]
    o = gates[:, 3 * hdim:]
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_out_ref[...] = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    c_out_ref[...] = c_new


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def gcrn_fused_pallas(neigh_idx, neigh_coef, neigh_eidx, x, h, c, wx, wh, b,
                      edge_msg=None, *, tn: int = 128, interpret: bool = False):
    n, k = neigh_idx.shape
    din, hdim = x.shape[1], h.shape[1]
    assert n % tn == 0
    grid = (n // tn,)
    row = lambda i: (i, 0)
    res2 = lambda i: (0, 0)
    res1 = lambda i: (0,)
    has_edge = edge_msg is not None
    if not has_edge:
        edge_msg = jnp.zeros((8, din), x.dtype)  # unused placeholder
    e = edge_msg.shape[0]
    return pl.pallas_call(
        functools.partial(_gcrn_kernel, has_edge),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, k), row),
            pl.BlockSpec((tn, k), row),
            pl.BlockSpec((tn, k), row),
            pl.BlockSpec((n, din), res2),   # x resident (BRAM analogue)
            pl.BlockSpec((n, hdim), res2),  # h resident (aggregated over)
            pl.BlockSpec((tn, hdim), row),  # c streams per tile
            pl.BlockSpec((din, 4 * hdim), res2),
            pl.BlockSpec((hdim, 4 * hdim), res2),
            pl.BlockSpec((4 * hdim,), res1),
            pl.BlockSpec((e, din), res2),
        ],
        out_specs=[
            pl.BlockSpec((tn, hdim), row),
            pl.BlockSpec((tn, hdim), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, hdim), x.dtype),
            jax.ShapeDtypeStruct((n, hdim), x.dtype),
        ],
        interpret=interpret,
    )(neigh_idx, neigh_coef, neigh_eidx, x, h, c, wx, wh, b, edge_msg)


def _stacked_kernel(has_edge, idx_ref, coef_ref, eidx_ref, x_ref, h_ref,
                    wg_ref, bg_ref, wx_ref, wh_ref, b_ref, emsg_ref, out_ref):
    idx, coef, eidx = idx_ref[...], coef_ref[...], eidx_ref[...]
    x, h = x_ref[...], h_ref[...]
    if has_edge:
        agg = _agg_edge(idx, coef, eidx, x, emsg_ref[...])
    else:
        agg = _agg(idx, coef, x)
    nt = agg @ wg_ref[...] + bg_ref[...][None, :]   # NT stage (linear)
    gx = nt @ wx_ref[...] + b_ref[...][None, :]
    gh = h @ wh_ref[...]
    hdim = h.shape[1]
    rx, zx, nx = gx[:, :hdim], gx[:, hdim:2 * hdim], gx[:, 2 * hdim:]
    rh, zh, nh = gh[:, :hdim], gh[:, hdim:2 * hdim], gh[:, 2 * hdim:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    nn = jnp.tanh(nx + r * nh)
    out_ref[...] = (1.0 - z) * nn + z * h


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def stacked_fused_pallas(neigh_idx, neigh_coef, neigh_eidx, x, h,
                         w_gcn, b_gcn, wx, wh, b, edge_msg=None, *,
                         tn: int = 128, interpret: bool = False):
    n, k = neigh_idx.shape
    din, hdim = x.shape[1], h.shape[1]
    dmid = w_gcn.shape[1]
    assert n % tn == 0
    grid = (n // tn,)
    row = lambda i: (i, 0)
    res2 = lambda i: (0, 0)
    res1 = lambda i: (0,)
    has_edge = edge_msg is not None
    if not has_edge:
        edge_msg = jnp.zeros((8, din), x.dtype)
    e = edge_msg.shape[0]
    return pl.pallas_call(
        functools.partial(_stacked_kernel, has_edge),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, k), row),
            pl.BlockSpec((tn, k), row),
            pl.BlockSpec((tn, k), row),
            pl.BlockSpec((n, din), res2),
            pl.BlockSpec((tn, hdim), row),  # h only needed for own nodes
            pl.BlockSpec((din, dmid), res2),
            pl.BlockSpec((dmid,), res1),
            pl.BlockSpec((dmid, 3 * hdim), res2),
            pl.BlockSpec((hdim, 3 * hdim), res2),
            pl.BlockSpec((3 * hdim,), res1),
            pl.BlockSpec((e, din), res2),
        ],
        out_specs=pl.BlockSpec((tn, hdim), row),
        out_shape=jax.ShapeDtypeStruct((n, hdim), x.dtype),
        interpret=interpret,
    )(neigh_idx, neigh_coef, neigh_eidx, x, h, w_gcn, b_gcn, wx, wh, b, edge_msg)
