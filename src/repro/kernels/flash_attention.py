"""Flash attention (forward) Pallas kernel for TPU.

The prefill/long-context hot spot: the baseline q-chunked XLA path
materializes (per q-chunk) an O(chunk x S) score tensor in HBM-visible
buffers and computes the full causal upper triangle. This kernel keeps the
running (m, l, acc) statistics in VMEM scratch across the kv grid
dimension, streams K/V tiles HBM->VMEM via BlockSpec double-buffering, and
skips fully-masked kv tiles (`pl.when`), so:

  HBM bytes: O(S*d) streamed once per q tile  (vs O(S^2) scores)
  FLOPs:     ~half (causal skip), exactly accounted by `flops_bytes()`
             since XLA cost analysis cannot see inside a custom call.

Grid: (batch*heads, nq, nk) with nk innermost (sequential accumulation).
GQA: callers pass K/V already grouped per q-head index (the wrapper maps
q-head -> kv-head by integer division in an index_map, no repeat in HBM).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip tiles strictly above the diagonal
    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def body():
        q = q_ref[0]                       # (bq, d)
        k = k_ref[0]                       # (bk, d)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        s = s / math.sqrt(q.shape[-1])
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "group",
                                              "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, bq: int = 512,
                           bk: int = 512, group: int = 1,
                           interpret: bool = False):
    """q (B*Hq, S, d), k/v (B*Hkv, Skv, d) -> (B*Hq, S, d).

    GQA without a KV repeat in HBM: q heads are laid out kv-head-major
    (B, Hkv, G) and the K/V BlockSpec index_map divides the grid's bh index
    by ``group`` — each kv tile is simply re-fetched (VMEM) for its G query
    heads.
    """
    bh, s, d = q.shape
    skv = k.shape[1]
    bq = min(bq, s)
    bk = min(bk, skv)
    assert s % bq == 0 and skv % bk == 0
    assert bh % group == 0 and k.shape[0] == bh // group
    grid = (bh, s // bq, skv // bk)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running sum l
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


# ----------------------------------------------------------- backward ----


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                      acc_ref, *, bq: int, bk: int, causal: bool):
    """Forward that also emits the logsumexp rows (bwd residual)."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def body():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / math.sqrt(q.shape[-1])
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def _flash_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dk_ref, dv_ref, dka_ref, dva_ref, *,
                      bq: int, bk: int, causal: bool, scale: float, nq: int):
    ki = pl.program_id(1)
    qs = pl.program_id(2)      # folded (group, q-tile) stream
    qi = qs % nq               # actual q-tile index (causal positions)

    @pl.when(qs == 0)
    def init():
        dka_ref[...] = jnp.zeros_like(dka_ref)
        dva_ref[...] = jnp.zeros_like(dva_ref)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def body():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0]
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                     # (bq, bk)
        dva_ref[...] += jnp.dot(p.T.astype(do.dtype), do,
                                preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dka_ref[...] += jnp.dot(ds.T.astype(q.dtype), q,
                                preferred_element_type=jnp.float32)

    @pl.when(qs == pl.num_programs(2) - 1)
    def flush():
        dk_ref[0] = dka_ref[...].astype(dk_ref.dtype)
        dv_ref[0] = dva_ref[...].astype(dv_ref.dtype)


def _flash_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                     dq_ref, dqa_ref, *, bq: int, bk: int, causal: bool,
                     scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def init():
        dqa_ref[...] = jnp.zeros_like(dqa_ref)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def body():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0]
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dqa_ref[...] += jnp.dot(ds.astype(k.dtype), k,
                                preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def flush():
        dq_ref[0] = dqa_ref[...].astype(dq_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_mha(q, k, v, causal: bool = True, bq: int = 512, bk: int = 512,
              group: int = 1, interpret: bool = False):
    """Differentiable flash attention. Shapes as flash_attention_pallas."""
    o, _ = _flash_fwd(q, k, v, causal, bq, bk, group, interpret)
    return o


def _flash_fwd(q, k, v, causal, bq, bk, group, interpret):
    bh, s, d = q.shape
    skv = k.shape[1]
    bq = min(bq, s)
    bk = min(bk, skv)
    grid = (bh, s // bq, skv // bk)
    kernel = functools.partial(_flash_fwd_kernel, bq=bq, bk=bk, causal=causal)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, (q, k, v, o, lse)


def _flash_fwd_vjp(q, k, v, causal, bq, bk, group, interpret):
    o, res = _flash_fwd(q, k, v, causal, bq, bk, group, interpret)
    return o, res


def _flash_bwd(causal, bq, bk, group, interpret, res, do):
    q, k, v, o, lse = res
    bh, s, d = q.shape
    skv = k.shape[1]
    bq = min(bq, s)
    bk = min(bk, skv)
    scale = 1.0 / math.sqrt(d)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    bhkv = k.shape[0]
    # dk/dv pass: grid over kv tiles, q innermost. For GQA each kv tile
    # accumulates over ALL q heads in its group: fold the group into the q
    # stream by mapping grid dim 2 over (group * nq) q tiles.
    nq, nk = s // bq, skv // bk
    dkv_kernel = functools.partial(_flash_dkv_kernel, bq=bq, bk=bk,
                                   causal=causal, scale=scale, nq=nq)
    qmap = lambda b, j, i, g=group, n=nq: (b * g + i // n, i % n, 0)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(bhkv, nk, group * nq),
        in_specs=[
            pl.BlockSpec((1, bq, d), qmap),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bq, d), qmap),
            pl.BlockSpec((1, bq), lambda b, j, i, g=group, n=nq: (b * g + i // n, i % n)),
            pl.BlockSpec((1, bq), lambda b, j, i, g=group, n=nq: (b * g + i // n, i % n)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bhkv, skv, d), k.dtype),
            jax.ShapeDtypeStruct((bhkv, skv, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dq_kernel = functools.partial(_flash_dq_kernel, bq=bq, bk=bk,
                                  causal=causal, scale=scale)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


flash_mha.defvjp(_flash_fwd_vjp, _flash_bwd)


def flops_bytes(b: int, hq: int, hkv: int, s: int, d: int, *,
                causal: bool = True, bq: int = 512, bk: int = 512) -> dict:
    """Exact work/traffic of the kernel (XLA cannot see inside the call).

    FLOPs: 4*d per (q,k) pair over executed tiles (qk^T + pv).
    HBM bytes: q/o tiles once, K/V tiles once per executed (q,k) tile pair.
    """
    nq, nk = s // bq, s // bk
    pairs = 0
    for i in range(nq):
        for j in range(nk):
            if not causal or j * bk <= i * bq + bq - 1:
                pairs += 1
    flops = 4.0 * b * hq * pairs * bq * bk * d
    bytes_kv = 2.0 * b * hkv * nq * 0 + 2.0 * b * hq * pairs * bk * d * 2  # K+V tiles (bf16)
    bytes_qo = 2.0 * b * hq * s * d * 2
    return {"flops": flops, "bytes": bytes_kv + bytes_qo, "tile_pairs": pairs}
