"""DGNN-Booster V3 stream engine: ONE time-fused kernel, per-family cell specs.

The paper's central claim is a *generic* accelerator framework: one hardware
template whose dataflows cover the discrete-time DGNN families, not one
bespoke design per model. This module is that template's TPU edition. A
single kernel body — ``_stream_engine_kernel`` — owns the stream protocol:

  * grid layout ``(B, T, L, d_pad//td, n_pad//tn)`` (stream batch, time,
    GNN layer, state-feature block, node tile), every axis "arbitrary"
    (sequential on one core) so the recurrent state in VMEM scratch is
    serially reused across streams by construction;
  * stream-boundary **init** (each stream loads its own state at its first
    program) and **drain** (each (l, d) window writes its final state block
    at the stream's last program);
  * **ping-pong scratch parity** for neighbour-aggregated states (read the
    t-1 buffer, write the t buffer, swapped by t's parity — the V1
    ping-pong carry pushed down into the kernel);
  * **live-gating**: the between-snapshot weight-evolution hook only runs
    on live snapshots, so serve no-op tail padding never advances the
    recurrence;
  * **residency policy**: which tensors stay VMEM-resident across the T
    axis (node-state stores, evolving weights) vs stream per step.

The three DGNN families are *declarative cell specs* registered in
``REGISTRY`` — recurrent state tensors plus a per-step cell body (and, for
the weights-evolved family, a between-snapshot evolution hook). Callers
(kernels/ops.py, core/*.py, serve/engine.py) dispatch through the registry
via ``stream_call(family, ...)``; no family-named kernel exists.

D-axis blocking (VMEM-oversized state stores)
---------------------------------------------
When the ``(n_global, hidden)`` state store exceeds VMEM, the hidden axis
is blocked onto the ``d`` grid dimension (``td`` columns per block). Cell
bodies address state exclusively through ``(n_global, td)`` column windows
— the unit at which the store can page on hardware builds — and the gate
weights are re-packed host-side into per-block gate tiles
``(D, rows, n_gates*td)`` so each program's weight/gate working set is
``td``-sized. The blocking is exact, NOT a block-diagonal approximation:
the hidden-to-gate matmul still consumes the full-width t-1 state (with
D > 1 the per-tile aggregation is computed once per (t, j) at ``d == 0``
into a cache scratch and re-read by the other d blocks; single-block
layouts compute it inline with no cache scratch), only the gate columns
and state writes are blocked. EvolveGCN's matrix-GRU evolves each weight
COLUMN independently (columns are the GRU batch), so its per-(l, d-block)
evolution is exact as well, and the documented padded-rows-stay-zero
invariant holds per block. ``td=None`` (one block) reproduces the fully
resident layout bit-for-bit.

Batch axis: a LEADING GRID DIMENSION, not ``jax.vmap`` — the vmap batching
rule prepends its axis to the grid while forwarding ``compiler_params``
unchanged, so the declared ``dimension_semantics`` would no longer cover
the axes the ping-pong parity argument depends on. See
docs/stream_engine.md for the full grid contract, the per-family scratch
residency table, and the drain/live-gating semantics.

Correctness contract: identical math to the per-step V2 path + the models'
gather/scatter, verified against kernels/ref.py stream oracles and the
differential harness (v3 ≡ baseline ≡ batched-v3 row-sliced, blocked ≡
unblocked).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# per-tile ELL aggregation over a step-resident feature table (local ids):
# shared with the per-step V2 kernels, same math by construction.
from repro.graph.padding import round_up as _round_up
from repro.kernels.dgnn_fused import _agg as _agg_local
from repro.kernels.dgnn_fused import _agg_edge as _agg_local_edge


def _agg_store(gidx, coef, store):
    """ELL aggregation straight out of the global VMEM store (global ids).

    Lanes with coef != 0 always reference real (renumbered) nodes, so the
    store row equals the masked local h the per-step path would gather;
    coef-0 padding lanes are killed regardless of the row they point at.
    """
    tn, k = gidx.shape
    g = jnp.take(store, gidx.reshape(-1), axis=0).reshape(tn, k, store.shape[1])
    return (g * coef[..., None]).sum(axis=1)


def _pad_dim(a, n2: int, axis: int, fill=0):
    """Pad ``a`` to ``n2`` entries along ``axis`` with a constant fill
    (shared with kernels/ops.py — the single copy of this helper)."""
    n = a.shape[axis]
    if n == n2:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, n2 - n)
    return jnp.pad(a, widths, constant_values=fill)


def _pack_gate_blocks(w, n_gates: int, td: int):
    """Re-pack a gate-concatenated weight ``(rows, n_gates*h)`` into
    per-d-block gate tiles ``(D, rows, n_gates*td)``.

    Block d holds columns [d*td, (d+1)*td) of EVERY gate, concatenated in
    gate order, so the kernel splits its gate tensor at ``td`` boundaries
    — the per-block edition of the fused-gate layout. Gate columns are
    zero-padded to D*td; padded gate columns produce zero pre-activations,
    which is what keeps the padded state columns at zero (see the cell
    bodies)."""
    rows = w.shape[0]
    gs = jnp.split(w, n_gates, axis=-1)
    d_pad = _round_up(gs[0].shape[-1], td)
    gs = [_pad_dim(g, d_pad, -1).reshape(rows, d_pad // td, td) for g in gs]
    packed = jnp.concatenate(gs, axis=-1)        # (rows, D, n_gates*td)
    return jnp.moveaxis(packed, 1, 0)            # (D, rows, n_gates*td)


def _pack_gate_bias(b, n_gates: int, td: int):
    """(n_gates*h,) -> (D, n_gates*td) per-block gate bias."""
    return _pack_gate_blocks(b[None], n_gates, td)[:, 0]


# ------------------------------------------------------------------------
# Registry data model: a family is a declarative cell spec.

@dataclass(frozen=True)
class StateDef:
    """One recurrent state tensor of a family.

    kind:
      "pingpong"  neighbour-aggregated node state: within a step every
                  tile must see the t-1 store while tiles write the t
                  store, so the engine keeps an A/B pair swapped by t's
                  parity (scratch ``(n_global, d_pad)`` each).
      "row"       own-row node state (each row read/written by exactly
                  one tile per step): a single ``(n_global, d_pad)``
                  buffer suffices.
      "weights"   per-layer evolving weight matrices ``(L, d_pad, d_pad)``
                  (EvolveGCN), drained per (l, d-block).
    """

    name: str
    kind: str


#: the temporal contracts a family may declare (CellSpec.temporal):
#:   "dense"   dense snapshot stream — T sequences a per-step recurrence
#:             (ragged streams masked in-launch via ``lengths``);
#:   "event"   ragged event stream — T sequences event BATCHES, per-event
#:             timestamps drive the time encoding, state updates touch
#:             only the event endpoints (``lengths`` generalizes from
#:             ragged-T to ragged per-event batches);
#:   "static"  no recurrence at all — T must be 1, the engine's state
#:             init/drain and evolve hooks are vacuous (zero StateDefs),
#:             and independent snapshots fold onto the B axis (the serve
#:             engine's express lane).
TEMPORAL_MODES = ("dense", "event", "static")


@dataclass(frozen=True)
class CellSpec:
    """A DGNN family expressed against the stream engine.

    ``build(*arrays, tn, td)`` assembles the launch (inputs, block specs,
    scratch, meta) and binds the family's ``cell`` (per-program body) and
    optional ``evolve`` (between-snapshot hook, live-gated by the engine).

    ``temporal`` declares the family's time semantics (one of
    ``TEMPORAL_MODES``) — the engine derives its per-mode behavior from
    this declaration instead of assuming a dense snapshot stream: a
    "static" family must carry zero StateDefs and no evolve hook (checked
    at registration and again at launch), an "event" family's T axis
    counts event batches, and only "dense"/"event" families own recurrent
    state the serve engine must checkpoint.
    """

    name: str
    resident: str                 # what stays on-chip across T (for docs)
    states: tuple[StateDef, ...]
    build: Callable
    temporal: str = "dense"


@dataclass(frozen=True)
class _StateMeta:
    kind: str
    in_idx: int     # position of the state's initial value in the inputs
    out_idx: int    # position of the drained final state in the outputs
    scr_idx: int    # first scratch slot (pingpong uses scr_idx, scr_idx+1)


@dataclass(frozen=True)
class _Meta:
    n_in: int
    n_out: int
    states: tuple[_StateMeta, ...]
    live_idx: Optional[int]       # input index of the (B, T) live flag
    td: int
    temporal: str = "dense"       # must equal the CellSpec's declaration


@dataclass
class _Launch:
    grid: tuple
    inputs: tuple
    in_specs: list
    out_specs: list
    out_shape: list
    scratch: list
    meta: _Meta
    cell: Callable
    evolve: Optional[Callable]


class _Engine:
    """Per-program view of the engine grid handed to cell/evolve hooks."""

    def __init__(self, meta: _Meta):
        self.meta = meta
        self.td = meta.td
        self.t = pl.program_id(1)
        self.l = pl.program_id(2)
        self.d = pl.program_id(3)
        self.j = pl.program_id(4)
        self.n_layers = pl.num_programs(2)
        self.n_dblocks = pl.num_programs(3)
        self.n_tiles = pl.num_programs(4)
        # state after step t-1 lives in the A buffer on even t
        self.even = (self.t % 2) == 0
        self.blk = pl.ds(self.d * meta.td, meta.td)
        # each stream loads its state at its own first program (full width:
        # later d blocks read the full t-1 store through the caches)
        self.stream_start = jnp.logical_and(
            self.t == 0, jnp.logical_and(self.d == 0, self.j == 0))
        self.first_dblock = self.d == 0
        self.last_tile = self.j == self.n_tiles - 1
        # last (t, j) program of the CURRENT stream — drain point for the
        # (l, d) window's state block
        self.stream_done = jnp.logical_and(
            self.t == pl.num_programs(1) - 1, self.last_tile)

    # ---------------------------------------------------- state views ----

    def dslice(self, val, axis: int = -1):
        """This program's td-column window of a full-width VALUE."""
        return jax.lax.dynamic_slice_in_dim(val, self.d * self.td, self.td,
                                            axis=axis)

    def state_read(self, scr, i: int):
        """Full-width t-1 view of state ``i`` (cache-fill at d == 0)."""
        sm = self.meta.states[i]
        if sm.kind == "pingpong":
            return jnp.where(self.even, scr[sm.scr_idx][...],
                             scr[sm.scr_idx + 1][...])
        return scr[sm.scr_idx][...]

    def state_window(self, scr, i: int):
        """This (d) column window of state ``i`` (t-1 view for pingpong)."""
        sm = self.meta.states[i]
        if sm.kind == "pingpong":
            return jnp.where(self.even, scr[sm.scr_idx][:, self.blk],
                             scr[sm.scr_idx + 1][:, self.blk])
        return scr[sm.scr_idx][:, self.blk]

    def state_scatter(self, scr, i: int, rowg, val):
        """Scatter this (d, tile) block of the new state; rowg == n_global
        marks padding rows (the sink convention) and mode="drop" discards
        them. Pingpong states write the step's parity-selected buffer."""
        sm = self.meta.states[i]
        blk = self.blk
        if sm.kind == "pingpong":
            a_ref, b_ref = scr[sm.scr_idx], scr[sm.scr_idx + 1]

            @pl.when(self.even)
            def _wr_b():
                b_ref[:, blk] = b_ref[:, blk].at[rowg].set(val, mode="drop")

            @pl.when(jnp.logical_not(self.even))
            def _wr_a():
                a_ref[:, blk] = a_ref[:, blk].at[rowg].set(val, mode="drop")
        else:
            s_ref = scr[sm.scr_idx]
            s_ref[:, blk] = s_ref[:, blk].at[rowg].set(val, mode="drop")


# ------------------------------------------------------------------------
# THE stream-engine kernel body. The only Pallas kernel in this module:
# every family runs through it; family code enters via cell/evolve hooks.

def _stream_engine_kernel(cell, evolve, meta: _Meta, *refs):
    ins = refs[:meta.n_in]
    outs = refs[meta.n_in:meta.n_in + meta.n_out]
    scr = refs[meta.n_in + meta.n_out:]
    eng = _Engine(meta)

    # --- stream-boundary init (engine-owned): every stream re-initializes
    # the scratch from its OWN state block at its first program, so streams
    # reuse the buffers serially and each restarts the ping-pong at even
    # parity. Weight states init per layer (each l has its own first
    # program on the (d==0, j==0) plane).
    for sm in meta.states:
        in_ref = ins[sm.in_idx]

        @pl.when(eng.stream_start)
        def _init(sm=sm, in_ref=in_ref):
            if sm.kind == "pingpong":
                scr[sm.scr_idx][...] = in_ref[0]
            elif sm.kind == "row":
                scr[sm.scr_idx][...] = in_ref[0]
            else:  # weights: full (d_pad, d_pad) block of layer l
                scr[sm.scr_idx][pl.ds(eng.l, 1)] = in_ref[0]

    # --- ping-pong copy-forward (engine-owned): at the start of each step
    # copy the read window into the write window so rows this snapshot
    # does not touch carry over; tiles then overwrite only their own rows.
    for sm in meta.states:
        if sm.kind != "pingpong":
            continue
        a_ref, b_ref = scr[sm.scr_idx], scr[sm.scr_idx + 1]

        @pl.when(jnp.logical_and(eng.j == 0, eng.even))
        def _fwd_ab(a_ref=a_ref, b_ref=b_ref):
            b_ref[:, eng.blk] = a_ref[:, eng.blk]

        @pl.when(jnp.logical_and(eng.j == 0, jnp.logical_not(eng.even)))
        def _fwd_ba(a_ref=a_ref, b_ref=b_ref):
            a_ref[:, eng.blk] = b_ref[:, eng.blk]

    # --- the family's per-(t, l, d, j) cell body
    cell(eng, ins, outs, scr)

    # --- between-snapshot evolution (weights-evolved families), gated by
    # the live flag: no-op (all-padding) snapshots are not steps of the
    # stream and must never advance the recurrence.
    if evolve is not None:
        live = ins[meta.live_idx][0, 0] > 0

        @pl.when(jnp.logical_and(eng.last_tile, live))
        def _evolve():
            evolve(eng, ins, scr)

    # --- drain (engine-owned): this stream's last program of each (l, d)
    # window writes the final state block (AFTER the final live step's
    # update/evolution) back to HBM.
    for sm in meta.states:
        out_ref = outs[sm.out_idx]

        @pl.when(eng.stream_done)
        def _drain(sm=sm, out_ref=out_ref):
            if sm.kind == "pingpong":
                a_ref, b_ref = scr[sm.scr_idx], scr[sm.scr_idx + 1]
                out_ref[0] = jnp.where(eng.even, b_ref[:, eng.blk],
                                       a_ref[:, eng.blk])
            elif sm.kind == "row":
                out_ref[0] = scr[sm.scr_idx][:, eng.blk]
            else:
                out_ref[0, 0] = scr[sm.scr_idx][pl.ds(eng.l, 1), :,
                                                eng.blk][0]


@functools.partial(jax.jit,
                   static_argnames=("family", "tn", "td", "interpret"))
def stream_call(family: str, *args, tn: int = 128, td: Optional[int] = None,
                interpret: bool = False):
    """Run a (B, T, ...) snapshot-stream batch through the stream engine.

    The single registry dispatch point: ``family`` selects a cell spec
    whose ``build`` assembles the launch; the engine kernel body is shared.
    ``td`` blocks the state feature axis (None = one block, fully
    resident). Callers go through kernels/ops.py, which owns padding,
    oracle routing, and output slicing.
    """
    spec = REGISTRY[family]
    launch = spec.build(*args, tn=tn, td=td)
    if launch.meta.temporal != spec.temporal:
        raise ValueError(
            f"family {family!r} built a launch declaring temporal="
            f"{launch.meta.temporal!r} but its cell spec declares "
            f"{spec.temporal!r}")
    if spec.temporal == "static" and (launch.meta.states
                                      or launch.evolve is not None):
        raise ValueError(
            f"static family {family!r} must launch with zero state "
            "tensors and no evolve hook")
    kernel = functools.partial(_stream_engine_kernel, launch.cell,
                               launch.evolve, launch.meta)
    return pl.pallas_call(
        kernel,
        grid=launch.grid,
        in_specs=launch.in_specs,
        out_specs=launch.out_specs,
        out_shape=launch.out_shape,
        scratch_shapes=launch.scratch,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",) * len(launch.grid)),
        interpret=interpret,
    )(*launch.inputs)


# ------------------------------------------------------------------------
# GCRN (GC-LSTM): integrated family. Neighbour-aggregated h (ping-pong
# pair) + own-row c. The hidden-to-gate matmul consumes the FULL-width t-1
# store (aggregated once per (t, j) into the caches at d == 0); gate
# columns and state writes are d-blocked.

def _gcrn_cell(has_edge, cached, eng, ins, outs, scr):
    (idx_ref, gidx_ref, coef_ref, eidx_ref, x_ref, rowg_ref, mask_ref,
     _h0, _c0, wx_ref, wh_ref, b_ref, emsg_ref) = ins
    out_ref = outs[0]

    idx, gidx = idx_ref[0, 0], gidx_ref[0, 0]
    coef, eidx = coef_ref[0, 0], eidx_ref[0, 0]
    rowg = rowg_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    tn = idx.shape[0]
    rows = pl.ds(eng.j * tn, tn)

    def _aggregate():
        x = x_ref[0, 0]
        agg_x = (_agg_local_edge(idx, coef, eidx, x, emsg_ref[0, 0])
                 if has_edge else _agg_local(idx, coef, x))
        return agg_x, _agg_store(gidx, coef, eng.state_read(scr, 0))

    if cached:  # D > 1: aggregate once per (t, j); d > 0 re-reads
        cax, cah = scr[3], scr[4]

        @pl.when(eng.first_dblock)
        def _fill_caches():
            cax[rows], cah[rows] = _aggregate()

        agg_x, agg_h = cax[rows], cah[rows]
    else:       # single d block: inline, no scratch round-trip
        agg_x, agg_h = _aggregate()

    td = eng.td
    gates = agg_x @ wx_ref[0] + agg_h @ wh_ref[0] + b_ref[0][None, :]
    i = gates[:, :td]
    f = gates[:, td:2 * td]
    g = gates[:, 2 * td:3 * td]
    o = gates[:, 3 * td:]

    n_global = scr[2].shape[0]
    row_safe = jnp.where(rowg < n_global, rowg, 0)
    c_old = jnp.take(eng.state_window(scr, 1), row_safe, axis=0) * mask
    c_new = (jax.nn.sigmoid(f) * c_old + jax.nn.sigmoid(i) * jnp.tanh(g)) * mask
    h_new = (jax.nn.sigmoid(o) * jnp.tanh(c_new)) * mask

    eng.state_scatter(scr, 0, rowg, h_new)
    eng.state_scatter(scr, 1, rowg, c_new)
    out_ref[0, 0] = h_new


def _gcrn_build(neigh_idx, neigh_gidx, neigh_coef, neigh_eidx, node_feat,
                row_gidx, node_mask, h0, c0, wx, wh, b, edge_msg=None, *,
                tn: int, td: Optional[int]):
    B, T, n, k = neigh_idx.shape
    din, h = node_feat.shape[3], h0.shape[2]
    G = h0.shape[1]
    assert n % tn == 0
    td = h if td is None else td
    d_pad = _round_up(h, td)
    D = d_pad // td
    grid = (B, T, 1, D, n // tn)

    h0p = _pad_dim(h0, d_pad, -1)
    c0p = _pad_dim(c0, d_pad, -1)
    wxp = _pack_gate_blocks(wx, 4, td)                    # (D, din, 4td)
    whp = _pack_gate_blocks(_pad_dim(wh, d_pad, 0), 4, td)  # (D, d_pad, 4td)
    bp = _pack_gate_bias(b, 4, td)                        # (D, 4td)

    has_edge = edge_msg is not None
    if not has_edge:
        edge_msg = jnp.zeros((B, T, 8, din), node_feat.dtype)
    e = edge_msg.shape[2]

    tile = lambda bi, t, l, d, j: (bi, t, j, 0)
    step = lambda bi, t, l, d, j: (bi, t, 0, 0)
    row = lambda bi, t, l, d, j: (bi, t, j)
    state_in = lambda bi, t, l, d, j: (bi, 0, 0)
    state_out = lambda bi, t, l, d, j: (bi, 0, d)
    out_tile = lambda bi, t, l, d, j: (bi, t, j, d)
    dblk = lambda bi, t, l, d, j: (d, 0, 0)
    dblk1 = lambda bi, t, l, d, j: (d, 0)

    meta = _Meta(
        n_in=13, n_out=3,
        states=(_StateMeta("pingpong", in_idx=7, out_idx=1, scr_idx=0),
                _StateMeta("row", in_idx=8, out_idx=2, scr_idx=2)),
        live_idx=None, td=td)
    return _Launch(
        grid=grid,
        inputs=(neigh_idx, neigh_gidx, neigh_coef, neigh_eidx, node_feat,
                row_gidx, node_mask, h0p, c0p, wxp, whp, bp, edge_msg),
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),        # neigh_idx (local)
            pl.BlockSpec((1, 1, tn, k), tile),        # neigh_gidx (global)
            pl.BlockSpec((1, 1, tn, k), tile),        # neigh_coef
            pl.BlockSpec((1, 1, tn, k), tile),        # neigh_eidx
            pl.BlockSpec((1, 1, n, din), step),       # node_feat, per (b, t)
            pl.BlockSpec((1, 1, tn), row),            # row_gidx
            pl.BlockSpec((1, 1, tn), row),            # node_mask
            pl.BlockSpec((1, G, d_pad), state_in),    # h0, per stream
            pl.BlockSpec((1, G, d_pad), state_in),    # c0, per stream
            pl.BlockSpec((1, din, 4 * td), dblk),     # wx gate tile, per d
            pl.BlockSpec((1, d_pad, 4 * td), dblk),   # wh gate tile, per d
            pl.BlockSpec((1, 4 * td), dblk1),         # bias gate tile
            pl.BlockSpec((1, 1, e, din), step),       # edge messages
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, td), out_tile),   # per-step h outputs
            pl.BlockSpec((1, G, td), state_out),      # final h, per (b, d)
            pl.BlockSpec((1, G, td), state_out),      # final c, per (b, d)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, d_pad), node_feat.dtype),
            jax.ShapeDtypeStruct((B, G, d_pad), h0.dtype),
            jax.ShapeDtypeStruct((B, G, d_pad), c0.dtype),
        ],
        scratch=[
            pltpu.VMEM((G, d_pad), h0.dtype),         # h ping
            pltpu.VMEM((G, d_pad), h0.dtype),         # h pong
            pltpu.VMEM((G, d_pad), c0.dtype),         # c (own-row)
        ] + ([
            pltpu.VMEM((n, din), node_feat.dtype),    # agg_x cache
            pltpu.VMEM((n, d_pad), h0.dtype),         # agg_h cache
        ] if D > 1 else []),
        meta=meta,
        cell=functools.partial(_gcrn_cell, has_edge, D > 1),
        evolve=None,
    )


# ------------------------------------------------------------------------
# Stacked DGNN (GCN -> GRU): own-row h only. The GRU's hidden-to-gate
# matmul reads the FULL-width t-1 row, cached at d == 0 BEFORE this step's
# first write (rows are tile-owned, so the cache of a tile's rows is never
# clobbered by other tiles).

def _stacked_cell(has_edge, cached, eng, ins, outs, scr):
    (idx_ref, coef_ref, eidx_ref, x_ref, rowg_ref, mask_ref, _h0,
     wg_ref, bg_ref, wx_ref, wh_ref, b_ref, emsg_ref) = ins
    out_ref = outs[0]
    h_scr = scr[0]

    idx, coef, eidx = idx_ref[0, 0], coef_ref[0, 0], eidx_ref[0, 0]
    rowg = rowg_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    tn = idx.shape[0]
    rows = pl.ds(eng.j * tn, tn)
    n_global = h_scr.shape[0]
    row_safe = jnp.where(rowg < n_global, rowg, 0)

    def _transform():
        x = x_ref[0, 0]
        agg = (_agg_local_edge(idx, coef, eidx, x, emsg_ref[0, 0])
               if has_edge else _agg_local(idx, coef, x))
        nt = agg @ wg_ref[...] + bg_ref[...][None, :]
        # t-1 own rows, gathered BEFORE this step's first write to them
        return nt, jnp.take(h_scr[...], row_safe, axis=0) * mask

    if cached:  # D > 1: once per (t, j); d > 0 re-reads
        cnt, chold = scr[1], scr[2]

        @pl.when(eng.first_dblock)
        def _fill_caches():
            cnt[rows], chold[rows] = _transform()

        nt, h_old_full = cnt[rows], chold[rows]
    else:       # single d block: read-then-write in one program
        nt, h_old_full = _transform()

    td = eng.td
    gx = nt @ wx_ref[0] + b_ref[0][None, :]
    gh = h_old_full @ wh_ref[0]
    rx, zx, nx = gx[:, :td], gx[:, td:2 * td], gx[:, 2 * td:]
    rh, zh, nh = gh[:, :td], gh[:, td:2 * td], gh[:, 2 * td:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    nn = jnp.tanh(nx + r * nh)
    h_old = eng.dslice(h_old_full)
    h_new = ((1.0 - z) * nn + z * h_old) * mask

    eng.state_scatter(scr, 0, rowg, h_new)
    out_ref[0, 0] = h_new


def _stacked_build(neigh_idx, neigh_coef, neigh_eidx, node_feat, row_gidx,
                   node_mask, h0, w_gcn, b_gcn, wx, wh, b, edge_msg=None, *,
                   tn: int, td: Optional[int]):
    B, T, n, k = neigh_idx.shape
    din, h = node_feat.shape[3], h0.shape[2]
    dmid = w_gcn.shape[1]
    G = h0.shape[1]
    assert n % tn == 0
    td = h if td is None else td
    d_pad = _round_up(h, td)
    D = d_pad // td
    grid = (B, T, 1, D, n // tn)

    h0p = _pad_dim(h0, d_pad, -1)
    wxp = _pack_gate_blocks(wx, 3, td)                      # (D, dmid, 3td)
    whp = _pack_gate_blocks(_pad_dim(wh, d_pad, 0), 3, td)  # (D, d_pad, 3td)
    bp = _pack_gate_bias(b, 3, td)                          # (D, 3td)

    has_edge = edge_msg is not None
    if not has_edge:
        edge_msg = jnp.zeros((B, T, 8, din), node_feat.dtype)
    e = edge_msg.shape[2]

    tile = lambda bi, t, l, d, j: (bi, t, j, 0)
    step = lambda bi, t, l, d, j: (bi, t, 0, 0)
    row = lambda bi, t, l, d, j: (bi, t, j)
    state_in = lambda bi, t, l, d, j: (bi, 0, 0)
    state_out = lambda bi, t, l, d, j: (bi, 0, d)
    out_tile = lambda bi, t, l, d, j: (bi, t, j, d)
    res2 = lambda bi, t, l, d, j: (0, 0)
    res1 = lambda bi, t, l, d, j: (0,)
    dblk = lambda bi, t, l, d, j: (d, 0, 0)
    dblk1 = lambda bi, t, l, d, j: (d, 0)

    meta = _Meta(
        n_in=13, n_out=2,
        states=(_StateMeta("row", in_idx=6, out_idx=1, scr_idx=0),),
        live_idx=None, td=td)
    return _Launch(
        grid=grid,
        inputs=(neigh_idx, neigh_coef, neigh_eidx, node_feat, row_gidx,
                node_mask, h0p, w_gcn, b_gcn, wxp, whp, bp, edge_msg),
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),
            pl.BlockSpec((1, 1, tn, k), tile),
            pl.BlockSpec((1, 1, tn, k), tile),
            pl.BlockSpec((1, 1, n, din), step),
            pl.BlockSpec((1, 1, tn), row),
            pl.BlockSpec((1, 1, tn), row),
            pl.BlockSpec((1, G, d_pad), state_in),     # h0, per stream
            pl.BlockSpec((din, dmid), res2),           # GCN weight (full)
            pl.BlockSpec((dmid,), res1),               # GCN bias
            pl.BlockSpec((1, dmid, 3 * td), dblk),     # wx gate tile, per d
            pl.BlockSpec((1, d_pad, 3 * td), dblk),    # wh gate tile, per d
            pl.BlockSpec((1, 3 * td), dblk1),          # bias gate tile
            pl.BlockSpec((1, 1, e, din), step),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, td), out_tile),
            pl.BlockSpec((1, G, td), state_out),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, d_pad), node_feat.dtype),
            jax.ShapeDtypeStruct((B, G, d_pad), h0.dtype),
        ],
        scratch=[
            pltpu.VMEM((G, d_pad), h0.dtype),          # h (own-row)
        ] + ([
            pltpu.VMEM((n, dmid), node_feat.dtype),    # node-transform cache
            pltpu.VMEM((n, d_pad), h0.dtype),          # t-1 h-row cache
        ] if D > 1 else []),
        meta=meta,
        cell=functools.partial(_stacked_cell, has_edge, D > 1),
        evolve=None,
    )


# ------------------------------------------------------------------------
# EvolveGCN: weights-resident family. No node-resident recurrent state —
# the recurrence is over the per-layer GCN weights W_l^t, evolved by a
# matrix-GRU between snapshots (live-gated by the engine). The L grid axis
# sequences the multi-layer GCN's cross-tile dependency over a ping-pong
# activation scratch; the d axis blocks W's COLUMNS, which the matrix-GRU
# evolves independently (columns are the GRU batch), so per-(l, d-block)
# evolution is exact. Padding convention: all widths zero-padded into a
# common square d_pad; GRU params padded PER GATE BLOCK
# (ops._pad_matrix_gru_params); zero-padded weight ROWS stay zero under
# evolution per block (their gate inputs are identically 0), keeping junk
# activation columns out of valid output columns.

def _evolve_cell(has_edge, cached, eng, ins, outs, scr):
    (idx_ref, coef_ref, x_ref, mask_ref, _live, _w0, bg_ref, eagg_ref,
     _wx, _wh, _bp) = ins
    out_ref = outs[0]
    w_scr, xa, xb = scr[0], scr[1], scr[2]
    l, j = eng.l, eng.j
    d_pad = xa.shape[1]

    # layer-0 activations are this step's node features: (re)load the ping
    # buffer at the first program of every step.
    @pl.when(jnp.logical_and(l == 0, jnp.logical_and(eng.first_dblock,
                                                     j == 0)))
    def _init_x():
        xa[...] = x_ref[0, 0]

    leven = (l % 2) == 0  # even layers read A / write B, odd the reverse
    idx, coef = idx_ref[0, 0], coef_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    tn, k = idx.shape
    rows = pl.ds(j * tn, tn)

    def _aggregate():
        x_prev = jnp.where(leven, xa[...], xb[...])
        g = jnp.take(x_prev, idx.reshape(-1),
                     axis=0).reshape(tn, k, d_pad)
        out = (g * coef[..., None]).sum(axis=1)
        return out + eagg_ref[0, 0, 0] if has_edge else out

    if cached:  # D > 1: aggregate once per (t, l, j); d > 0 re-reads
        cagg = scr[3]

        @pl.when(eng.first_dblock)
        def _fill_cache():
            cagg[rows] = _aggregate()

        agg = cagg[rows]
    else:       # single d block: inline, no scratch round-trip
        agg = _aggregate()

    w_blk = w_scr[pl.ds(l, 1), :, eng.blk][0]           # (d_pad, td)
    h = agg @ w_blk + bg_ref[0][None, :]
    h = jnp.where(l == eng.n_layers - 1, h, jnp.maximum(h, 0.0)) * mask

    @pl.when(jnp.logical_not(leven))
    def _wr_a():
        xa[rows, eng.blk] = h

    @pl.when(leven)
    def _wr_b():
        xb[rows, eng.blk] = h

    # model output = last layer's (masked, linear) activations
    @pl.when(l == eng.n_layers - 1)
    def _out():
        out_ref[0, 0] = h


def _evolve_evolve(eng, ins, scr):
    """Matrix-GRU evolution of W_l's (d) column block for step t+1, after
    the last tile of layer l consumed W_l^t. Identical math to
    rnn.matrix_gru on the valid region: W's columns are the GRU batch, so
    the block evolves independently; gate blocks split at d_pad (params
    padded per gate block by ops._pad_matrix_gru_params)."""
    wx_ref, wh_ref, bp_ref = ins[8], ins[9], ins[10]
    w_scr = scr[0]
    wt = w_scr[pl.ds(eng.l, 1), :, eng.blk][0].T       # (td, d_pad)
    d = wt.shape[1]
    gx = wt @ wx_ref[0] + bp_ref[0][None, :]
    gh = wt @ wh_ref[0]
    rx, zx, nx = gx[:, :d], gx[:, d:2 * d], gx[:, 2 * d:]
    rh, zh, nh = gh[:, :d], gh[:, d:2 * d], gh[:, 2 * d:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    nvec = jnp.tanh(nx + r * nh)
    w_scr[pl.ds(eng.l, 1), :, eng.blk] = (((1.0 - z) * nvec + z * wt).T)[None]


def _evolve_build(neigh_idx, neigh_coef, node_feat, node_mask, live,
                  w0, b_gcn, gru_wx, gru_wh, gru_b, edge_agg=None, *,
                  tn: int, td: Optional[int]):
    """Inputs pre-padded to the common square d_pad (a td multiple) by
    kernels/ops.py: node_feat (B, T, n, d_pad); w0 (B, L, d_pad, d_pad) —
    each stream's primed evolving weights, entering and leaving the chip
    exactly once per stream; gru params padded per gate block; live (B, T)
    int32 — 1 where the snapshot is real, 0 on no-op tail padding."""
    B, T, n, k = neigh_idx.shape
    L, d_pad = w0.shape[1], w0.shape[2]
    assert n % tn == 0
    td = d_pad if td is None else td
    assert d_pad % td == 0
    D = d_pad // td
    grid = (B, T, L, D, n // tn)

    tile = lambda bi, t, l, d, j: (bi, t, j, 0)
    step = lambda bi, t, l, d, j: (bi, t, 0, 0)
    row = lambda bi, t, l, d, j: (bi, t, j)
    flag = lambda bi, t, l, d, j: (bi, t)
    w_in = lambda bi, t, l, d, j: (bi, l, 0, 0)
    w_out = lambda bi, t, l, d, j: (bi, l, 0, d)
    out_tile = lambda bi, t, l, d, j: (bi, t, j, d)
    layer_res3 = lambda bi, t, l, d, j: (l, 0, 0)
    layer_blk = lambda bi, t, l, d, j: (l, d)

    has_edge = edge_agg is not None
    if has_edge:
        eagg_map = lambda bi, t, l, d, j: (bi, t, l, j, 0)
    else:
        # one pinned (revisited) dummy block instead of (B,T,L,n,d_pad)
        # of streamed zeros; the kernel never reads it.
        edge_agg = jnp.zeros((1, 1, 1, tn, d_pad), node_feat.dtype)
        eagg_map = lambda bi, t, l, d, j: (0, 0, 0, 0, 0)

    meta = _Meta(
        n_in=11, n_out=2,
        states=(_StateMeta("weights", in_idx=5, out_idx=1, scr_idx=0),),
        live_idx=4, td=td)
    return _Launch(
        grid=grid,
        inputs=(neigh_idx, neigh_coef, node_feat, node_mask, live,
                w0, b_gcn, edge_agg, gru_wx, gru_wh, gru_b),
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),            # neigh_idx (local)
            pl.BlockSpec((1, 1, tn, k), tile),            # neigh_coef
            pl.BlockSpec((1, 1, n, d_pad), step),         # node_feat
            pl.BlockSpec((1, 1, tn), row),                # node_mask
            pl.BlockSpec((1, 1), flag),                   # live flag
            pl.BlockSpec((1, 1, d_pad, d_pad), w_in),     # W0, per (b, l)
            pl.BlockSpec((1, td), layer_blk),             # GCN bias tile
            pl.BlockSpec((1, 1, 1, tn, d_pad), eagg_map),  # edge agg
            pl.BlockSpec((1, d_pad, 3 * d_pad), layer_res3),  # GRU wx
            pl.BlockSpec((1, d_pad, 3 * d_pad), layer_res3),  # GRU wh
            pl.BlockSpec((1, 3 * d_pad), lambda bi, t, l, d, j: (l, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, td), out_tile),       # per-step outputs
            pl.BlockSpec((1, 1, d_pad, td), w_out),       # final weights
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, d_pad), node_feat.dtype),
            jax.ShapeDtypeStruct((B, L, d_pad, d_pad), w0.dtype),
        ],
        scratch=[
            pltpu.VMEM((L, d_pad, d_pad), w0.dtype),   # resident evolving W
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # activation ping
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # activation pong
        ] + ([
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # aggregation cache
        ] if D > 1 else []),
        meta=meta,
        cell=functools.partial(_evolve_cell, has_edge, D > 1),
        evolve=_evolve_evolve,
    )


# ------------------------------------------------------------------------
# TGN (event-driven temporal GNN): the "event" temporal contract. The T
# grid axis sequences EVENT BATCHES, not snapshots — each step is a ragged
# batch of timestamped events laid out as ELL rows over the touched nodes
# (graph/events.pad_event_block), so ``lengths`` generalizes from ragged-T
# snapshot streams to ragged event streams. Per event batch, every touched
# node aggregates its event partners' t-1 memory plus a sinusoidal TIME
# ENCODING of the per-event timestamps (cos(t * freq_d), learnable per-dim
# frequencies — the TGAT/TGN functional form), feeds a GRU, and updates
# ONLY its own node-memory row (untouched rows carry over through the
# ping-pong copy-forward; padding rows scatter-drop). Dead (coef-0) event
# lanes contribute exactly zero to both aggregations, whatever timestamp
# they carry — the property tests pin this.

def _tgn_cell(cached, eng, ins, outs, scr):
    (gidx_ref, coef_ref, ts_ref, x_ref, rowg_ref, mask_ref, _m0,
     freq_ref, win_ref, wx_ref, wh_ref, b_ref) = ins
    out_ref = outs[0]

    gidx, coef, ts = gidx_ref[0, 0], coef_ref[0, 0], ts_ref[0, 0]
    rowg = rowg_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    tn = gidx.shape[0]
    rows = pl.ds(eng.j * tn, tn)
    n_global = scr[0].shape[0]
    row_safe = jnp.where(rowg < n_global, rowg, 0)

    def _compute():
        store = eng.state_read(scr, 0)       # full-width t-1 memory
        agg_m = _agg_store(gidx, coef, store)
        # sinusoidal time encoding per event lane; padded freq columns
        # give cos(0)=1 but only ever multiply zero-padded wx rows
        enc = jnp.cos(ts[..., None] * freq_ref[0][None, None, :])
        agg_e = (enc * coef[..., None]).sum(axis=1)
        x_tile = jax.lax.dynamic_slice_in_dim(x_ref[0, 0], eng.j * tn, tn,
                                              axis=0)
        inp = x_tile @ win_ref[...] + agg_m + agg_e
        mem_own = jnp.take(store, row_safe, axis=0) * mask
        return inp, mem_own

    if cached:  # D > 1: compute once per (t, j); d > 0 re-reads
        cinp, cmem = scr[2], scr[3]

        @pl.when(eng.first_dblock)
        def _fill_caches():
            cinp[rows], cmem[rows] = _compute()

        inp, mem_own = cinp[rows], cmem[rows]
    else:       # single d block: inline, no scratch round-trip
        inp, mem_own = _compute()

    td = eng.td
    gx = inp @ wx_ref[0] + b_ref[0][None, :]
    gh = mem_own @ wh_ref[0]
    rx, zx, nx = gx[:, :td], gx[:, td:2 * td], gx[:, 2 * td:]
    rh, zh, nh = gh[:, :td], gh[:, td:2 * td], gh[:, 2 * td:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    nn = jnp.tanh(nx + r * nh)
    m_new = ((1.0 - z) * nn + z * eng.dslice(mem_own)) * mask

    eng.state_scatter(scr, 0, rowg, m_new)
    out_ref[0, 0] = m_new


def _tgn_build(neigh_gidx, neigh_coef, neigh_ts, node_feat, row_gidx,
               node_mask, mem0, freq, w_in, wx, wh, b, *,
               tn: int, td: Optional[int]):
    """Event-stream launch: (B, T, n, k) ELL event batches with per-lane
    timestamps; the node-memory store (B, G, h) is the single pingpong
    state, entering and leaving the chip once per stream."""
    B, T, n, k = neigh_gidx.shape
    din, h = node_feat.shape[3], mem0.shape[2]
    G = mem0.shape[1]
    assert n % tn == 0
    td = h if td is None else td
    d_pad = _round_up(h, td)
    D = d_pad // td
    grid = (B, T, 1, D, n // tn)

    mem0p = _pad_dim(mem0, d_pad, -1)
    freq_p = _pad_dim(freq, d_pad, 0)[None]           # (1, d_pad): 2-D ref
    win_p = _pad_dim(w_in, d_pad, -1)
    wxp = _pack_gate_blocks(_pad_dim(wx, d_pad, 0), 3, td)  # (D, d_pad, 3td)
    whp = _pack_gate_blocks(_pad_dim(wh, d_pad, 0), 3, td)  # (D, d_pad, 3td)
    bp = _pack_gate_bias(b, 3, td)                          # (D, 3td)

    tile = lambda bi, t, l, d, j: (bi, t, j, 0)
    step = lambda bi, t, l, d, j: (bi, t, 0, 0)
    row = lambda bi, t, l, d, j: (bi, t, j)
    state_in = lambda bi, t, l, d, j: (bi, 0, 0)
    state_out = lambda bi, t, l, d, j: (bi, 0, d)
    out_tile = lambda bi, t, l, d, j: (bi, t, j, d)
    res2 = lambda bi, t, l, d, j: (0, 0)
    dblk = lambda bi, t, l, d, j: (d, 0, 0)
    dblk1 = lambda bi, t, l, d, j: (d, 0)

    meta = _Meta(
        n_in=12, n_out=2,
        states=(_StateMeta("pingpong", in_idx=6, out_idx=1, scr_idx=0),),
        live_idx=None, td=td, temporal="event")
    return _Launch(
        grid=grid,
        inputs=(neigh_gidx, neigh_coef, neigh_ts, node_feat, row_gidx,
                node_mask, mem0p, freq_p, win_p, wxp, whp, bp),
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),        # partner gidx (global)
            pl.BlockSpec((1, 1, tn, k), tile),        # event coef (1/deg)
            pl.BlockSpec((1, 1, tn, k), tile),        # event timestamps
            pl.BlockSpec((1, 1, n, din), step),       # touched-node features
            pl.BlockSpec((1, 1, tn), row),            # row_gidx
            pl.BlockSpec((1, 1, tn), row),            # node_mask
            pl.BlockSpec((1, G, d_pad), state_in),    # mem0, per stream
            pl.BlockSpec((1, d_pad), res2),           # time-enc frequencies
            pl.BlockSpec((din, d_pad), res2),         # input projection
            pl.BlockSpec((1, d_pad, 3 * td), dblk),   # wx gate tile, per d
            pl.BlockSpec((1, d_pad, 3 * td), dblk),   # wh gate tile, per d
            pl.BlockSpec((1, 3 * td), dblk1),         # bias gate tile
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, td), out_tile),   # per-batch mem outputs
            pl.BlockSpec((1, G, td), state_out),      # final memory, per (b, d)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, d_pad), node_feat.dtype),
            jax.ShapeDtypeStruct((B, G, d_pad), mem0.dtype),
        ],
        scratch=[
            pltpu.VMEM((G, d_pad), mem0.dtype),       # mem ping
            pltpu.VMEM((G, d_pad), mem0.dtype),       # mem pong
        ] + ([
            pltpu.VMEM((n, d_pad), node_feat.dtype),  # GRU-input cache
            pltpu.VMEM((n, d_pad), mem0.dtype),       # own-row mem cache
        ] if D > 1 else []),
        meta=meta,
        cell=functools.partial(_tgn_cell, D > 1),
        evolve=None,
    )


# ------------------------------------------------------------------------
# Static GCN (GenGNN-style): the "static" temporal contract — no
# recurrence, zero StateDefs, no evolve hook; the engine's state
# init/copy-forward/drain loops are vacuously empty. T must be 1:
# independent snapshots fold onto the B axis instead (the serve express
# lane), so a "stream" of static graphs is just a batch. The L grid axis
# sequences the multi-layer GCN over the evolve-style activation ping-pong
# scratch, but the per-layer weights come straight from INPUT refs
# (BlockSpec-indexed by (l, d)) — nothing is resident across steps.

def _static_cell(has_edge, cached, eng, ins, outs, scr):
    (idx_ref, coef_ref, x_ref, mask_ref, w_ref, bg_ref, eagg_ref) = ins
    out_ref = outs[0]
    xa, xb = scr[0], scr[1]
    l, j = eng.l, eng.j
    d_pad = xa.shape[1]

    # layer-0 activations are the snapshot's node features
    @pl.when(jnp.logical_and(l == 0, jnp.logical_and(eng.first_dblock,
                                                     j == 0)))
    def _init_x():
        xa[...] = x_ref[0, 0]

    leven = (l % 2) == 0  # even layers read A / write B, odd the reverse
    idx, coef = idx_ref[0, 0], coef_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    tn, k = idx.shape
    rows = pl.ds(j * tn, tn)

    def _aggregate():
        x_prev = jnp.where(leven, xa[...], xb[...])
        g = jnp.take(x_prev, idx.reshape(-1),
                     axis=0).reshape(tn, k, d_pad)
        out = (g * coef[..., None]).sum(axis=1)
        return out + eagg_ref[0, 0, 0] if has_edge else out

    if cached:  # D > 1: aggregate once per (l, j); d > 0 re-reads
        cagg = scr[2]

        @pl.when(eng.first_dblock)
        def _fill_cache():
            cagg[rows] = _aggregate()

        agg = cagg[rows]
    else:       # single d block: inline, no scratch round-trip
        agg = _aggregate()

    h = agg @ w_ref[0] + bg_ref[0][None, :]
    h = jnp.where(l == eng.n_layers - 1, h, jnp.maximum(h, 0.0)) * mask

    @pl.when(jnp.logical_not(leven))
    def _wr_a():
        xa[rows, eng.blk] = h

    @pl.when(leven)
    def _wr_b():
        xb[rows, eng.blk] = h

    # model output = last layer's (masked, linear) activations
    @pl.when(l == eng.n_layers - 1)
    def _out():
        out_ref[0, 0] = h


def _static_build(neigh_idx, neigh_coef, node_feat, node_mask,
                  weights, b_gcn, edge_agg=None, *,
                  tn: int, td: Optional[int]):
    """Inputs pre-padded to the common square d_pad by kernels/ops.py:
    node_feat (B, 1, n, d_pad); weights (L, d_pad, d_pad) stacked per
    layer, SHARED across the batch (params, not state)."""
    B, T, n, k = neigh_idx.shape
    if T != 1:
        raise ValueError(
            f"static family runs with T == 1, got T={T}: a static-GCN "
            "'stream' has no recurrence — fold independent snapshots onto "
            "the batch axis instead (core.gcn.StaticGCN.step_stream does)")
    L, d_pad = weights.shape[0], weights.shape[1]
    assert n % tn == 0
    td = d_pad if td is None else td
    assert d_pad % td == 0
    D = d_pad // td
    grid = (B, 1, L, D, n // tn)

    tile = lambda bi, t, l, d, j: (bi, t, j, 0)
    step = lambda bi, t, l, d, j: (bi, t, 0, 0)
    row = lambda bi, t, l, d, j: (bi, t, j)
    out_tile = lambda bi, t, l, d, j: (bi, t, j, d)
    layer_wblk = lambda bi, t, l, d, j: (l, 0, d)
    layer_blk = lambda bi, t, l, d, j: (l, d)

    has_edge = edge_agg is not None
    if has_edge:
        eagg_map = lambda bi, t, l, d, j: (bi, t, l, j, 0)
    else:
        # one pinned (revisited) dummy block; the kernel never reads it.
        edge_agg = jnp.zeros((1, 1, 1, tn, d_pad), node_feat.dtype)
        eagg_map = lambda bi, t, l, d, j: (0, 0, 0, 0, 0)

    meta = _Meta(
        n_in=7, n_out=1, states=(),
        live_idx=None, td=td, temporal="static")
    return _Launch(
        grid=grid,
        inputs=(neigh_idx, neigh_coef, node_feat, node_mask,
                weights, b_gcn, edge_agg),
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),            # neigh_idx (local)
            pl.BlockSpec((1, 1, tn, k), tile),            # neigh_coef
            pl.BlockSpec((1, 1, n, d_pad), step),         # node_feat
            pl.BlockSpec((1, 1, tn), row),                # node_mask
            pl.BlockSpec((1, d_pad, td), layer_wblk),     # W_l column block
            pl.BlockSpec((1, td), layer_blk),             # GCN bias tile
            pl.BlockSpec((1, 1, 1, tn, d_pad), eagg_map),  # edge agg
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, td), out_tile),       # per-snapshot outs
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1, n, d_pad), node_feat.dtype),
        ],
        scratch=[
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # activation ping
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # activation pong
        ] + ([
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # aggregation cache
        ] if D > 1 else []),
        meta=meta,
        cell=functools.partial(_static_cell, has_edge, D > 1),
        evolve=None,
    )


# ------------------------------------------------------------------------
# The registry: every DGNN family the stream engine serves. Adding a
# family = registering a cell spec here (CI runs the registry tests for
# every entry, so an untested spec fails the build).

REGISTRY: dict[str, CellSpec] = {
    "gcrn": CellSpec(
        name="gcrn",
        resident="node-state store: h (ping-pong pair) + c (own-row)",
        states=(StateDef("h", "pingpong"), StateDef("c", "row")),
        build=_gcrn_build,
        temporal="dense"),
    "stacked": CellSpec(
        name="stacked",
        resident="node-state store: h (own-row)",
        states=(StateDef("h", "row"),),
        build=_stacked_build,
        temporal="dense"),
    "evolve": CellSpec(
        name="evolve",
        resident="per-layer evolving weights W_l (matrix-GRU in-kernel)",
        states=(StateDef("weights", "weights"),),
        build=_evolve_build,
        temporal="dense"),
    "tgn": CellSpec(
        name="tgn",
        resident="node-memory store: mem (ping-pong pair)",
        states=(StateDef("mem", "pingpong"),),
        build=_tgn_build,
        temporal="event"),
    "static_gcn": CellSpec(
        name="static_gcn",
        resident="none (stateless; activation ping-pong scratch only)",
        states=(),
        build=_static_build,
        temporal="static"),
}


def _validate_registry() -> None:
    """Structural invariants on the declarative temporal contract,
    checked once at import: a spec that lies about its mode fails before
    any launch does."""
    for name, spec in REGISTRY.items():
        if spec.temporal not in TEMPORAL_MODES:
            raise ValueError(
                f"family {name!r} declares unknown temporal mode "
                f"{spec.temporal!r}; expected one of {TEMPORAL_MODES}")
        if spec.temporal == "static" and spec.states:
            raise ValueError(
                f"static family {name!r} must declare zero StateDefs, "
                f"got {[s.name for s in spec.states]}")
        if spec.temporal != "static" and not spec.states:
            raise ValueError(
                f"{spec.temporal} family {name!r} declares no StateDefs: "
                "recurrence without state is a contract violation")


_validate_registry()
