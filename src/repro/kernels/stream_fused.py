"""DGNN-Booster V3 stream engine: ONE time-fused kernel, per-family cell specs.

The paper's central claim is a *generic* accelerator framework: one hardware
template whose dataflows cover the discrete-time DGNN families, not one
bespoke design per model. This module is that template's TPU edition. A
single kernel body — ``_stream_engine_kernel`` — owns the stream protocol:

  * grid layout ``(B, T, L, d_pad//td, n_pad//tn)`` (stream batch, time,
    GNN layer, state-feature block, node tile), every axis "arbitrary"
    (sequential on one core) so the recurrent state in VMEM scratch is
    serially reused across streams by construction;
  * stream-boundary **init** (each stream loads its own state at its first
    program) and **drain** (each (l, d) window writes its final state block
    at the stream's last program);
  * **ping-pong scratch parity** for neighbour-aggregated states (read the
    t-1 buffer, write the t buffer, swapped by t's parity — the V1
    ping-pong carry pushed down into the kernel);
  * **live-gating**: the between-snapshot weight-evolution hook only runs
    on live snapshots, so serve no-op tail padding never advances the
    recurrence;
  * **residency policy**: which tensors stay VMEM-resident across the T
    axis (node-state stores, evolving weights) vs stream per step.

The three DGNN families are *declarative cell specs* registered in
``REGISTRY`` — recurrent state tensors plus a per-step cell body (and, for
the weights-evolved family, a between-snapshot evolution hook). Callers
(kernels/ops.py, core/*.py, serve/engine.py) dispatch through the registry
via ``stream_call(family, ...)``; no family-named kernel exists.

D-axis blocking (VMEM-oversized state stores)
---------------------------------------------
When the ``(n_global, hidden)`` state store exceeds VMEM, the hidden axis
is blocked onto the ``d`` grid dimension (``td`` columns per block). Cell
bodies address state exclusively through ``(n_global, td)`` column windows
— the paging unit of the HBM residency policy below — and the gate
weights are re-packed host-side into per-block gate tiles
``(D, rows, n_gates*td)`` so each program's weight/gate working set is
``td``-sized. The blocking is exact, NOT a block-diagonal approximation:
the hidden-to-gate matmul still consumes the full-width t-1 state (with
D > 1 the per-tile aggregation is computed once per (t, j) at ``d == 0``
into a cache scratch and re-read by the other d blocks; single-block
layouts compute it inline with no cache scratch), only the gate columns
and state writes are blocked. EvolveGCN's matrix-GRU evolves each weight
COLUMN independently (columns are the GRU batch), so its per-(l, d-block)
evolution is exact as well, and the documented padded-rows-stay-zero
invariant holds per block. ``td=None`` (one block) reproduces the fully
resident layout bit-for-bit.

HBM-paged residency (``residency="hbm_paged"``)
-----------------------------------------------
D-axis blocking shrinks the *working window* but the full state store
still occupies VMEM scratch, capping ``n_global × hidden`` at VMEM size.
The ``hbm_paged`` residency policy makes the same move the FPGA lineage
makes with DDR/HBM-resident state and multi-buffered streaming: paged
stores stay in HBM for the whole stream — the state enters the kernel as
an operand with ``memory_space=pltpu.ANY``, aliased in-place onto an
output via ``input_output_aliases`` — and the engine stages exactly the
``(n_global, td)`` column window each program needs through explicit
``pltpu.make_async_copy`` DMA:

  * **stage-in** (per step, at each (l, d) window's first tile): the read
    view's window is DMA'd into a VMEM staging buffer; for ping-pong
    states the read PLANE of an HBM A/B plane pair is selected by t's
    parity, and the stage-in doubles as the copy-forward (untouched rows
    ride staging into the write plane);
  * **cell windows**: ``state_window``/``state_scatter``/``state_block``
    resolve to the staging buffer — cell bodies are residency-agnostic;
  * **ring-buffered full-width reads**: states declared ``full_read``
    (the t-1 store feeding aggregations/gates) sweep ALL D windows
    through a ``depth``-deep ring of staging buffers —
    ``_Engine.paged_fill`` starts window w+depth's copy before computing
    window w (depth 2 = double-buffered, 4 = quad) — and the per-window
    fill writes the same cache columns the resident path fills, so the
    float math is bit-identical;
  * **write-back** (at the window's last tile, after the cell and the
    live-gated evolve hook): the dirty staging window is DMA'd to the
    write view (ping-pong: the opposite plane; row/weights: in place).

Only the read ring is depth-buffered; stage-in and write-back are
synchronous (start+wait) — the write must land before the next (d)
window reuses the staging buffer. Per paged state the scratch cost is
``(1 [+ depth if full_read]) × (n_global, td)`` staging plus DMA
semaphores — independent of ``d_pad`` — instead of the full store, which
is the unlock for stores larger than VMEM (``stream_call`` enforces the
``VMEM_BUDGET_BYTES`` scratch budget). Requires ``td`` blocking;
undefined for the "static" temporal contract (zero StateDefs — nothing
to page). ``hbm_paged`` ≡ ``vmem`` bit-for-bit is pinned per family by
tests/test_paged.py, solo + batched + ragged.

Batch axis: a LEADING GRID DIMENSION, not ``jax.vmap`` — the vmap batching
rule prepends its axis to the grid while forwarding ``compiler_params``
unchanged, so the declared ``dimension_semantics`` would no longer cover
the axes the ping-pong parity argument depends on. See
docs/stream_engine.md for the full grid contract, the per-family scratch
residency table, and the drain/live-gating semantics.

Correctness contract: identical math to the per-step V2 path + the models'
gather/scatter, verified against kernels/ref.py stream oracles and the
differential harness (v3 ≡ baseline ≡ batched-v3 row-sliced, blocked ≡
unblocked).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# per-tile ELL aggregation over a step-resident feature table (local ids):
# shared with the per-step V2 kernels, same math by construction.
from repro.graph.padding import round_up as _round_up
from repro.kernels.dgnn_fused import _agg as _agg_local
from repro.kernels.dgnn_fused import _agg_edge as _agg_local_edge


def _agg_store(gidx, coef, store):
    """ELL aggregation straight out of the global VMEM store (global ids).

    Lanes with coef != 0 always reference real (renumbered) nodes, so the
    store row equals the masked local h the per-step path would gather;
    coef-0 padding lanes are killed regardless of the row they point at.
    """
    tn, k = gidx.shape
    g = jnp.take(store, gidx.reshape(-1), axis=0).reshape(tn, k, store.shape[1])
    return (g * coef[..., None]).sum(axis=1)


def _pad_dim(a, n2: int, axis: int, fill=0):
    """Pad ``a`` to ``n2`` entries along ``axis`` with a constant fill
    (shared with kernels/ops.py — the single copy of this helper)."""
    n = a.shape[axis]
    if n == n2:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, n2 - n)
    return jnp.pad(a, widths, constant_values=fill)


def _pack_gate_blocks(w, n_gates: int, td: int):
    """Re-pack a gate-concatenated weight ``(rows, n_gates*h)`` into
    per-d-block gate tiles ``(D, rows, n_gates*td)``.

    Block d holds columns [d*td, (d+1)*td) of EVERY gate, concatenated in
    gate order, so the kernel splits its gate tensor at ``td`` boundaries
    — the per-block edition of the fused-gate layout. Gate columns are
    zero-padded to D*td; padded gate columns produce zero pre-activations,
    which is what keeps the padded state columns at zero (see the cell
    bodies)."""
    rows = w.shape[0]
    gs = jnp.split(w, n_gates, axis=-1)
    d_pad = _round_up(gs[0].shape[-1], td)
    gs = [_pad_dim(g, d_pad, -1).reshape(rows, d_pad // td, td) for g in gs]
    packed = jnp.concatenate(gs, axis=-1)        # (rows, D, n_gates*td)
    return jnp.moveaxis(packed, 1, 0)            # (D, rows, n_gates*td)


def _pack_gate_bias(b, n_gates: int, td: int):
    """(n_gates*h,) -> (D, n_gates*td) per-block gate bias."""
    return _pack_gate_blocks(b[None], n_gates, td)[:, 0]


# ------------------------------------------------------------------------
# Registry data model: a family is a declarative cell spec.

@dataclass(frozen=True)
class StateDef:
    """One recurrent state tensor of a family.

    kind:
      "pingpong"  neighbour-aggregated node state: within a step every
                  tile must see the t-1 store while tiles write the t
                  store, so the engine keeps an A/B pair swapped by t's
                  parity (scratch ``(n_global, d_pad)`` each).
      "row"       own-row node state (each row read/written by exactly
                  one tile per step): a single ``(n_global, d_pad)``
                  buffer suffices.
      "weights"   per-layer evolving weight matrices ``(L, d_pad, d_pad)``
                  (EvolveGCN), drained per (l, d-block).

    full_read: the cell body consumes the FULL-width t-1 view of this
    state (aggregations / hidden-to-gate matmuls), not just the current
    (d) window. Under ``hbm_paged`` residency such states sweep all D
    windows through the depth-buffered DMA ring (``_Engine.paged_fill``)
    into the family's cache scratch.
    """

    name: str
    kind: str
    full_read: bool = False


#: the temporal contracts a family may declare (CellSpec.temporal):
#:   "dense"   dense snapshot stream — T sequences a per-step recurrence
#:             (ragged streams masked in-launch via ``lengths``);
#:   "event"   ragged event stream — T sequences event BATCHES, per-event
#:             timestamps drive the time encoding, state updates touch
#:             only the event endpoints (``lengths`` generalizes from
#:             ragged-T to ragged per-event batches);
#:   "static"  no recurrence at all — T must be 1, the engine's state
#:             init/drain and evolve hooks are vacuous (zero StateDefs),
#:             and independent snapshots fold onto the B axis (the serve
#:             engine's express lane).
TEMPORAL_MODES = ("dense", "event", "static")

#: state-residency policies (the plan's ``state_residency`` field):
#:   "vmem"       resident: the full store lives in VMEM scratch across
#:                the T axis (the original layout);
#:   "hbm_paged"  paged: the store stays in HBM (ANY-memory-space operand
#:                aliased in-place) and the engine DMA-stages the
#:                ``(n_global, td)`` column windows through a small ring
#:                of VMEM staging buffers (see the module docstring).
RESIDENCY_MODES = ("vmem", "hbm_paged")

#: legal DMA staging-ring depths under ``hbm_paged`` (the plan's
#: ``buffer_depth``): 1 = synchronous per-window copies (the no-overlap
#: baseline the benchmark sweep measures against), 2 = double-buffered
#: (window d+1 copies in while window d computes), 4 = quad-buffered.
BUFFER_DEPTHS = (1, 2, 4)

#: VMEM scratch budget enforced at launch assembly: a resident layout
#: whose scratch exceeds this must page (``residency="hbm_paged"``).
#: Module-level so tests can tighten it to exercise the oversized-store
#: path at CI-friendly sizes; 16 MiB is the per-core hardware figure.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


# ------------------------------------------------------------------------
# Ping-pong plane parity, as pure functions. The HBM plane pair of a paged
# pingpong state and the host-side final-plane select must agree on one
# parity scheme; keeping all three derivations here (and nowhere else)
# makes the parity a checkable contract — repro.analysis simulates a
# T-step stream through these helpers and cross-checks read-after-write
# consistency, and the engine's read/write views call them directly.

def paged_read_plane(t):
    """Plane of a paged pingpong pair holding the t-1 state at step t
    (the step's READ view). Plane 0 holds the initial state (builds stack
    ``[state0, zeros]``), so step 0 reads plane 0."""
    return t % 2


def paged_write_plane(t):
    """Plane step t's updates land in (the step's WRITE view) — always
    the opposite plane of ``paged_read_plane(t)``."""
    return 1 - (t % 2)


def paged_final_plane(t_steps: int) -> int:
    """Plane holding the final state after a ``t_steps``-long stream:
    whatever plane the last step wrote. ``stream_call`` slices this plane
    out of the returned (B, 2, G, d_pad) pair host-side."""
    return paged_write_plane(t_steps - 1)


# ------------------------------------------------------------------------
# Trace recorder hooks. ``repro.analysis`` verifies the paged DMA protocol
# (start/wait pairing, ring-slot reuse ordering, alias coverage) WITHOUT
# device execution: it installs a recorder and abstractly evaluates a
# launch (``jax.eval_shape``), so the kernel body's Python-level protocol
# runs at trace time while every DMA start/wait is logged. Production
# launches pay nothing: with no recorder installed ``_async_copy`` returns
# the raw ``pltpu.make_async_copy`` object.

_TRACE_RECORDER = None


def set_trace_recorder(rec):
    """Install a trace recorder (``None`` clears). The recorder sees
    ``rec.launch(family, launch)`` per assembled launch and
    ``rec.dma(event, op=..., state=..., window=..., slot=...)`` per DMA
    start/wait issued by the paged engine. Returns the previous recorder
    so callers can restore it. NOTE: recording happens at kernel TRACE
    time — clear ``stream_call``'s jit cache around a recorded sweep or a
    cached trace will replay silently with no events."""
    global _TRACE_RECORDER
    prev = _TRACE_RECORDER
    _TRACE_RECORDER = rec
    return prev


class _TracedCopy:
    """A ``make_async_copy`` wrapper that logs start/wait to the recorder
    before issuing the real DMA op (trace-time passthrough)."""

    def __init__(self, cp, rec, tag):
        self._cp = cp
        self._rec = rec
        self._tag = tag

    def start(self):
        self._rec.dma("start", **self._tag)
        self._cp.start()

    def wait(self):
        self._rec.dma("wait", **self._tag)
        self._cp.wait()


def _async_copy(src, dst, sem, *, op, state, window=None, slot=None):
    """The engine's single DMA constructor: ``pltpu.make_async_copy``
    plus the (no-op by default) trace hook. ``op`` names the protocol
    site ("stage_in" / "write_back" / "ring"), ``state`` the StateDef
    index, ``window``/``slot`` the ring position for ring copies."""
    cp = pltpu.make_async_copy(src, dst, sem)
    if _TRACE_RECORDER is None:
        return cp
    return _TracedCopy(cp, _TRACE_RECORDER,
                       dict(op=op, state=state, window=window, slot=slot))


@dataclass(frozen=True)
class CellSpec:
    """A DGNN family expressed against the stream engine.

    ``build(*arrays, tn, td)`` assembles the launch (inputs, block specs,
    scratch, meta) and binds the family's ``cell`` (per-program body) and
    optional ``evolve`` (between-snapshot hook, live-gated by the engine).

    ``temporal`` declares the family's time semantics (one of
    ``TEMPORAL_MODES``) — the engine derives its per-mode behavior from
    this declaration instead of assuming a dense snapshot stream: a
    "static" family must carry zero StateDefs and no evolve hook (checked
    at registration and again at launch), an "event" family's T axis
    counts event batches, and only "dense"/"event" families own recurrent
    state the serve engine must checkpoint.
    """

    name: str
    resident: str                 # what stays on-chip across T (for docs)
    states: tuple[StateDef, ...]
    build: Callable
    temporal: str = "dense"


@dataclass(frozen=True)
class _StateMeta:
    kind: str
    in_idx: int     # position of the state's initial value in the inputs
    out_idx: int    # position of the drained final state in the outputs
    scr_idx: int    # resident: first scratch slot (pingpong uses scr_idx,
                    # scr_idx+1); paged: the (G, td) staging slot
    ring_idx: int = -1   # paged full_read states: (depth, G, td) DMA ring
    sem_idx: int = -1    # paged states: DMA semaphore array (depth+1,) —
                         # slots [0, depth) ring, slot depth stage-in/
                         # write-back


@dataclass(frozen=True)
class _Meta:
    n_in: int
    n_out: int
    states: tuple[_StateMeta, ...]
    live_idx: Optional[int]       # input index of the (B, T) live flag
    td: int
    temporal: str = "dense"       # must equal the CellSpec's declaration
    paged: bool = False           # hbm_paged residency selected
    depth: int = 1                # DMA staging-ring depth (paged only)
    g_rows: int = 0               # state-store rows G (node families)


@dataclass
class _Launch:
    grid: tuple
    inputs: tuple
    in_specs: list
    out_specs: list
    out_shape: list
    scratch: list
    meta: _Meta
    cell: Callable
    evolve: Optional[Callable]
    aliases: dict = field(default_factory=dict)  # input→output aliasing
                                                 # (paged in-place stores)


class _Engine:
    """Per-program view of the engine grid handed to cell/evolve hooks."""

    def __init__(self, meta: _Meta, outs=None, scr=None):
        self.meta = meta
        self.td = meta.td
        self.paged = meta.paged
        self.g_rows = meta.g_rows
        self._outs = outs
        self._scr = scr
        self.b = pl.program_id(0)
        self.t = pl.program_id(1)
        self.l = pl.program_id(2)
        self.d = pl.program_id(3)
        self.j = pl.program_id(4)
        self.n_layers = pl.num_programs(2)
        self.n_dblocks = pl.num_programs(3)
        self.n_tiles = pl.num_programs(4)
        # state after step t-1 lives in the A buffer on even t
        self.even = (self.t % 2) == 0
        self.blk = pl.ds(self.d * meta.td, meta.td)
        # each stream loads its state at its own first program (full width:
        # later d blocks read the full t-1 store through the caches)
        self.stream_start = jnp.logical_and(
            self.t == 0, jnp.logical_and(self.d == 0, self.j == 0))
        self.first_dblock = self.d == 0
        self.last_tile = self.j == self.n_tiles - 1
        # last (t, j) program of the CURRENT stream — drain point for the
        # (l, d) window's state block
        self.stream_done = jnp.logical_and(
            self.t == pl.num_programs(1) - 1, self.last_tile)

    # ---------------------------------------------------- state views ----

    def dslice(self, val, axis: int = -1):
        """This program's td-column window of a full-width VALUE."""
        return jax.lax.dynamic_slice_in_dim(val, self.d * self.td, self.td,
                                            axis=axis)

    def state_read(self, scr, i: int):
        """Full-width t-1 view of state ``i`` (cache-fill at d == 0)."""
        if self.paged:
            raise RuntimeError(
                "full-width state_read is unavailable under hbm_paged "
                "residency — sweep the windows with paged_fill instead")
        sm = self.meta.states[i]
        if sm.kind == "pingpong":
            return jnp.where(self.even, scr[sm.scr_idx][...],
                             scr[sm.scr_idx + 1][...])
        return scr[sm.scr_idx][...]

    def state_window(self, scr, i: int):
        """This (d) column window of state ``i`` (t-1 view for pingpong).
        Paged: the staged window (stage-in'd from the HBM read view at the
        window's first tile, so it holds the t-1 values)."""
        sm = self.meta.states[i]
        if self.paged:
            return scr[sm.scr_idx][...]
        if sm.kind == "pingpong":
            return jnp.where(self.even, scr[sm.scr_idx][:, self.blk],
                             scr[sm.scr_idx + 1][:, self.blk])
        return scr[sm.scr_idx][:, self.blk]

    def state_scatter(self, scr, i: int, rowg, val):
        """Scatter this (d, tile) block of the new state; rowg == n_global
        marks padding rows (the sink convention) and mode="drop" discards
        them. Pingpong states write the step's parity-selected buffer;
        paged states scatter into the staging window (written back to the
        HBM write view at the window's last tile)."""
        sm = self.meta.states[i]
        blk = self.blk
        if self.paged:
            stg = scr[sm.scr_idx]
            stg[...] = stg[...].at[rowg].set(val, mode="drop")
            return
        if sm.kind == "pingpong":
            a_ref, b_ref = scr[sm.scr_idx], scr[sm.scr_idx + 1]

            @pl.when(self.even)
            def _wr_b():
                b_ref[:, blk] = b_ref[:, blk].at[rowg].set(val, mode="drop")

            @pl.when(jnp.logical_not(self.even))
            def _wr_a():
                a_ref[:, blk] = a_ref[:, blk].at[rowg].set(val, mode="drop")
        else:
            s_ref = scr[sm.scr_idx]
            s_ref[:, blk] = s_ref[:, blk].at[rowg].set(val, mode="drop")

    def state_block(self, scr, i: int):
        """Layer l's (d_pad, td) column block of a weights-kind state."""
        sm = self.meta.states[i]
        if self.paged:
            return scr[sm.scr_idx][...]
        return scr[sm.scr_idx][pl.ds(self.l, 1), :, self.blk][0]

    def state_block_store(self, scr, i: int, val):
        """Store layer l's evolved (d_pad, td) column block."""
        sm = self.meta.states[i]
        if self.paged:
            scr[sm.scr_idx][...] = val
        else:
            scr[sm.scr_idx][pl.ds(self.l, 1), :, self.blk] = val[None]

    # --------------------------------------------- paged DMA protocol ----
    # The HBM-resident view of paged state i is its ALIASED OUTPUT ref
    # (memory_space=ANY): reads and writes both go through it, so the
    # store evolves in place across the stream. Plane layouts: pingpong
    # (B, 2, G, d_pad) — plane t%2 is step t's read view, 1-(t%2) its
    # write view (the A/B parity argument verbatim, lifted to HBM); row
    # (B, 1, G, d_pad); weights (B, L, d_pad, d_pad).

    def _hbm(self, i: int):
        return self._outs[self.meta.states[i].out_idx]

    def _read_view(self, i: int, wblk):
        """HBM read view of state i's column window ``wblk`` (t-1)."""
        sm = self.meta.states[i]
        hbm = self._hbm(i)
        if sm.kind == "pingpong":
            return hbm.at[self.b, paged_read_plane(self.t), :, wblk]
        if sm.kind == "row":
            return hbm.at[self.b, 0, :, wblk]
        return hbm.at[self.b, self.l, :, wblk]

    def _write_view(self, i: int):
        """HBM write view of state i's CURRENT (d) window (step t)."""
        sm = self.meta.states[i]
        hbm = self._hbm(i)
        if sm.kind == "pingpong":
            return hbm.at[self.b, paged_write_plane(self.t), :, self.blk]
        if sm.kind == "row":
            return hbm.at[self.b, 0, :, self.blk]
        return hbm.at[self.b, self.l, :, self.blk]

    def stage_in(self, i: int):
        """Synchronous DMA of the current (d) window's t-1 values into
        the staging buffer (the window's first tile). For pingpong states
        this doubles as the copy-forward: rows the step does not scatter
        ride staging into the write plane at write-back."""
        sm = self.meta.states[i]
        sem = self._scr[sm.sem_idx].at[self.meta.depth]
        cp = _async_copy(self._read_view(i, self.blk),
                         self._scr[sm.scr_idx], sem,
                         op="stage_in", state=i)
        cp.start()
        cp.wait()

    def write_back(self, i: int):
        """Synchronous DMA of the dirty staging window to the HBM write
        view (the window's last tile, after cell + evolve). Synchronous
        on purpose: the next (d) window reuses the staging buffer."""
        sm = self.meta.states[i]
        sem = self._scr[sm.sem_idx].at[self.meta.depth]
        cp = _async_copy(self._scr[sm.scr_idx],
                         self._write_view(i), sem,
                         op="write_back", state=i)
        cp.start()
        cp.wait()

    def paged_fill(self, i: int, fill):
        """Ring-buffered sweep over ALL D column windows of paged state
        i's t-1 (read) view: ``fill(w, wblk, window)`` runs per window w
        with ``window`` the (G, td) staged value, while window w+depth's
        DMA is already in flight (depth 2 = double-, 4 = quad-buffered;
        depth 1 degenerates to synchronous per-window copies). The
        per-window fill writes disjoint cache columns, so the float math
        matches the resident full-width fill bit-for-bit."""
        sm = self.meta.states[i]
        ring = self._scr[sm.ring_idx]
        sems = self._scr[sm.sem_idx]
        depth = self.meta.depth
        n_win = self.n_dblocks
        dmas = {}

        def _start(w):
            slot = w % depth
            dma = _async_copy(
                self._read_view(i, pl.ds(w * self.td, self.td)),
                ring.at[slot], sems.at[slot],
                op="ring", state=i, window=w, slot=slot)
            dma.start()
            dmas[w] = dma

        for w in range(min(depth, n_win)):
            _start(w)
        for w in range(n_win):
            dmas.pop(w).wait()
            fill(w, pl.ds(w * self.td, self.td), ring[w % depth])
            if w + depth < n_win:
                _start(w + depth)


# ------------------------------------------------------------------------
# THE stream-engine kernel body. The only Pallas kernel in this module:
# every family runs through it; family code enters via cell/evolve hooks.

def _stream_engine_kernel(cell, evolve, meta: _Meta, *refs):
    ins = refs[:meta.n_in]
    outs = refs[meta.n_in:meta.n_in + meta.n_out]
    scr = refs[meta.n_in + meta.n_out:]
    eng = _Engine(meta, outs, scr)

    if meta.paged:
        # --- paged stage-in (engine-owned): the state lives in HBM (the
        # aliased ANY-space output ref), so there is no stream init and no
        # resident copy-forward — at each (l, d) window's first tile the
        # t-1 window is DMA'd into VMEM staging. For pingpong states the
        # stage-in from the read plane IS the copy-forward (write-back
        # pushes untouched rows into the write plane with the rest).
        for i in range(len(meta.states)):

            @pl.when(eng.j == 0)
            def _stage(i=i):
                eng.stage_in(i)
    else:
        # --- stream-boundary init (engine-owned): every stream
        # re-initializes the scratch from its OWN state block at its first
        # program, so streams reuse the buffers serially and each restarts
        # the ping-pong at even parity. Weight states init per layer (each
        # l has its own first program on the (d==0, j==0) plane).
        for sm in meta.states:
            in_ref = ins[sm.in_idx]

            @pl.when(eng.stream_start)
            def _init(sm=sm, in_ref=in_ref):
                if sm.kind == "pingpong":
                    scr[sm.scr_idx][...] = in_ref[0]
                elif sm.kind == "row":
                    scr[sm.scr_idx][...] = in_ref[0]
                else:  # weights: full (d_pad, d_pad) block of layer l
                    scr[sm.scr_idx][pl.ds(eng.l, 1)] = in_ref[0]

        # --- ping-pong copy-forward (engine-owned): at the start of each
        # step copy the read window into the write window so rows this
        # snapshot does not touch carry over; tiles then overwrite only
        # their own rows.
        for sm in meta.states:
            if sm.kind != "pingpong":
                continue
            a_ref, b_ref = scr[sm.scr_idx], scr[sm.scr_idx + 1]

            @pl.when(jnp.logical_and(eng.j == 0, eng.even))
            def _fwd_ab(a_ref=a_ref, b_ref=b_ref):
                b_ref[:, eng.blk] = a_ref[:, eng.blk]

            @pl.when(jnp.logical_and(eng.j == 0, jnp.logical_not(eng.even)))
            def _fwd_ba(a_ref=a_ref, b_ref=b_ref):
                a_ref[:, eng.blk] = b_ref[:, eng.blk]

    # --- the family's per-(t, l, d, j) cell body
    cell(eng, ins, outs, scr)

    # --- between-snapshot evolution (weights-evolved families), gated by
    # the live flag: no-op (all-padding) snapshots are not steps of the
    # stream and must never advance the recurrence.
    if evolve is not None:
        live = ins[meta.live_idx][0, 0] > 0

        @pl.when(jnp.logical_and(eng.last_tile, live))
        def _evolve():
            evolve(eng, ins, scr)

    if meta.paged:
        # --- paged write-back (engine-owned): every (l, d) window's last
        # tile DMAs the dirty staging window to the HBM write view (after
        # the cell and the live-gated evolve hook). There is no separate
        # drain — the store evolves in place; ``stream_call`` selects the
        # final plane of pingpong pairs host-side from T's parity.
        for i in range(len(meta.states)):

            @pl.when(eng.last_tile)
            def _wb(i=i):
                eng.write_back(i)
    else:
        # --- drain (engine-owned): this stream's last program of each
        # (l, d) window writes the final state block (AFTER the final
        # live step's update/evolution) back to HBM.
        for sm in meta.states:
            out_ref = outs[sm.out_idx]

            @pl.when(eng.stream_done)
            def _drain(sm=sm, out_ref=out_ref):
                if sm.kind == "pingpong":
                    a_ref, b_ref = scr[sm.scr_idx], scr[sm.scr_idx + 1]
                    out_ref[0] = jnp.where(eng.even, b_ref[:, eng.blk],
                                           a_ref[:, eng.blk])
                elif sm.kind == "row":
                    out_ref[0] = scr[sm.scr_idx][:, eng.blk]
                else:
                    out_ref[0, 0] = scr[sm.scr_idx][pl.ds(eng.l, 1), :,
                                                    eng.blk][0]


def launch_scratch_bytes(launch: _Launch) -> int:
    """Total VMEM scratch bytes of an assembled launch (semaphore scratch
    lives in semaphore memory and is excluded). The ground truth the
    plan-time estimator ``stream_vmem_bytes`` is tested against."""
    total = 0
    for s in launch.scratch:
        if getattr(s, "memory_space", None) != pltpu.VMEM:
            continue
        total += int(jnp.dtype(s.dtype).itemsize) * int(
            functools.reduce(lambda a, b: a * b, s.shape, 1))
    return total


def stream_vmem_bytes(family: str, *, g_rows: int = 0, n_pad: int = 0,
                      d_pad: int = 0, din: int = 0, dmid: int = 0,
                      n_layers: int = 1, td: Optional[int] = None,
                      residency: str = "vmem", depth: int = 2,
                      itemsize: int = 4) -> int:
    """Plan-time VMEM scratch estimate per family/residency/blocking —
    the per-family scratch tables (docs/stream_engine.md) as a formula.
    Bit-equal to ``launch_scratch_bytes`` of the assembled launch
    (tests/test_paged.py pins this for every family and variant).

    ``g_rows`` counts the state-store rows (n_global + sentinel) of node
    families; ``n_pad`` the padded per-step node count; ``din``/``dmid``
    the gcrn aggregation-input / stacked GCN-mid widths."""
    paged = residency == "hbm_paged"
    if paged and family == "static_gcn":
        raise ValueError("static_gcn has no state to page")
    t = td if td is not None else d_pad
    n_win = -(-d_pad // t) if t else 1  # ceil
    cached = n_win > 1
    cells = 0
    if family == "gcrn":
        if paged:
            cells = (2 + depth) * g_rows * t + n_pad * (din + d_pad)
        else:
            cells = 3 * g_rows * d_pad + (
                n_pad * (din + d_pad) if cached else 0)
    elif family == "stacked":
        if paged:
            cells = (1 + depth) * g_rows * t + n_pad * (dmid + d_pad)
        else:
            cells = g_rows * d_pad + (
                n_pad * (dmid + d_pad) if cached else 0)
    elif family == "evolve":
        if paged:
            cells = d_pad * t + 3 * n_pad * d_pad
        else:
            cells = (n_layers * d_pad * d_pad + 2 * n_pad * d_pad
                     + (n_pad * d_pad if cached else 0))
    elif family == "tgn":
        if paged:
            cells = (1 + depth) * g_rows * t + 2 * n_pad * d_pad
        else:
            cells = 2 * g_rows * d_pad + (
                2 * n_pad * d_pad if cached else 0)
    elif family == "static_gcn":
        cells = 2 * n_pad * d_pad + (n_pad * d_pad if cached else 0)
    else:
        raise KeyError(family)
    return cells * itemsize


@functools.partial(jax.jit,
                   static_argnames=("family", "tn", "td", "interpret",
                                    "residency", "depth"))
def stream_call(family: str, *args, tn: int = 128, td: Optional[int] = None,
                interpret: bool = False, residency: str = "vmem",
                depth: int = 2):
    """Run a (B, T, ...) snapshot-stream batch through the stream engine.

    The single registry dispatch point: ``family`` selects a cell spec
    whose ``build`` assembles the launch; the engine kernel body is shared.
    ``td`` blocks the state feature axis (None = one block, fully
    resident); ``residency`` selects where the state store lives across
    the stream ("vmem" resident scratch / "hbm_paged" DMA-staged windows,
    ``depth``-deep read ring — see the module docstring). Callers go
    through kernels/ops.py, which owns padding, oracle routing, and
    output slicing.
    """
    spec = REGISTRY[family]
    if residency not in RESIDENCY_MODES:
        raise ValueError(
            f"unknown state residency {residency!r}; expected one of "
            f"{RESIDENCY_MODES}")
    paged = residency == "hbm_paged"
    if paged:
        if spec.temporal == "static":
            raise ValueError(
                f"state_residency='hbm_paged' is undefined for static "
                f"family {family!r}: zero StateDefs — there is no "
                "recurrent store to page")
        if td is None:
            raise ValueError(
                "state_residency='hbm_paged' requires td blocking: td "
                "is the (n_global, td) paging window the DMA ring "
                "stages (td=None keeps the store fully VMEM-resident)")
        if depth not in BUFFER_DEPTHS:
            raise ValueError(
                f"buffer_depth must be one of {BUFFER_DEPTHS}, "
                f"got {depth}")
    launch = spec.build(*args, tn=tn, td=td, residency=residency,
                        depth=depth)
    if launch.meta.temporal != spec.temporal:
        raise ValueError(
            f"family {family!r} built a launch declaring temporal="
            f"{launch.meta.temporal!r} but its cell spec declares "
            f"{spec.temporal!r}")
    if spec.temporal == "static" and (launch.meta.states
                                      or launch.evolve is not None):
        raise ValueError(
            f"static family {family!r} must launch with zero state "
            "tensors and no evolve hook")
    scratch_bytes = launch_scratch_bytes(launch)
    if scratch_bytes > VMEM_BUDGET_BYTES:
        hint = ("shrink td" if paged else
                "page the state store with plan(state_residency="
                "'hbm_paged', td=...)")
        raise ValueError(
            f"family {family!r} ({residency}, td={td}) needs "
            f"{scratch_bytes} bytes of VMEM scratch, over the "
            f"{VMEM_BUDGET_BYTES}-byte budget — {hint}")
    if _TRACE_RECORDER is not None:
        _TRACE_RECORDER.launch(family, launch)
    kernel = functools.partial(_stream_engine_kernel, launch.cell,
                               launch.evolve, launch.meta)
    res = pl.pallas_call(
        kernel,
        grid=launch.grid,
        in_specs=launch.in_specs,
        out_specs=launch.out_specs,
        out_shape=launch.out_shape,
        scratch_shapes=launch.scratch,
        input_output_aliases=launch.aliases,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",) * len(launch.grid)),
        interpret=interpret,
    )(*launch.inputs)
    if paged:
        # node-state planes come back as (B, P, G, d_pad): select the
        # plane the last step wrote (static in T) so callers see the
        # resident output shapes; weights evolved in place, no planes.
        res = list(res)
        t_steps = launch.grid[1]
        for sm in launch.meta.states:
            if sm.kind == "pingpong":
                res[sm.out_idx] = res[sm.out_idx][:, paged_final_plane(t_steps)]
            elif sm.kind == "row":
                res[sm.out_idx] = res[sm.out_idx][:, 0]
    return res


# ------------------------------------------------------------------------
# GCRN (GC-LSTM): integrated family. Neighbour-aggregated h (ping-pong
# pair) + own-row c. The hidden-to-gate matmul consumes the FULL-width t-1
# store (aggregated once per (t, j) into the caches at d == 0); gate
# columns and state writes are d-blocked.

def _gcrn_cell(has_edge, cached, eng, ins, outs, scr):
    (idx_ref, gidx_ref, coef_ref, eidx_ref, x_ref, rowg_ref, mask_ref,
     _h0, _c0, wx_ref, wh_ref, b_ref, emsg_ref) = ins
    out_ref = outs[0]

    idx, gidx = idx_ref[0, 0], gidx_ref[0, 0]
    coef, eidx = coef_ref[0, 0], eidx_ref[0, 0]
    rowg = rowg_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    tn = idx.shape[0]
    rows = pl.ds(eng.j * tn, tn)

    def _agg_x():
        x = x_ref[0, 0]
        return (_agg_local_edge(idx, coef, eidx, x, emsg_ref[0, 0])
                if has_edge else _agg_local(idx, coef, x))

    if cached:  # D > 1 or paged: aggregate once per (t, j); d > 0 re-reads
        cax, cah = scr[3], scr[4]

        @pl.when(eng.first_dblock)
        def _fill_caches():
            cax[rows] = _agg_x()
            if eng.paged:
                # sweep the t-1 h store's windows through the DMA ring;
                # the aggregation is columnwise, so per-window fills of
                # disjoint cache columns equal the full-width fill
                def _one(w, wblk, sval):
                    cah[rows, wblk] = _agg_store(gidx, coef, sval)

                eng.paged_fill(0, _one)
            else:
                cah[rows] = _agg_store(gidx, coef, eng.state_read(scr, 0))

        agg_x, agg_h = cax[rows], cah[rows]
    else:       # single d block: inline, no scratch round-trip
        agg_x = _agg_x()
        agg_h = _agg_store(gidx, coef, eng.state_read(scr, 0))

    td = eng.td
    gates = agg_x @ wx_ref[0] + agg_h @ wh_ref[0] + b_ref[0][None, :]
    i = gates[:, :td]
    f = gates[:, td:2 * td]
    g = gates[:, 2 * td:3 * td]
    o = gates[:, 3 * td:]

    n_global = eng.g_rows
    row_safe = jnp.where(rowg < n_global, rowg, 0)
    c_old = jnp.take(eng.state_window(scr, 1), row_safe, axis=0) * mask
    c_new = (jax.nn.sigmoid(f) * c_old + jax.nn.sigmoid(i) * jnp.tanh(g)) * mask
    h_new = (jax.nn.sigmoid(o) * jnp.tanh(c_new)) * mask

    eng.state_scatter(scr, 0, rowg, h_new)
    eng.state_scatter(scr, 1, rowg, c_new)
    out_ref[0, 0] = h_new


def _gcrn_build(neigh_idx, neigh_gidx, neigh_coef, neigh_eidx, node_feat,
                row_gidx, node_mask, h0, c0, wx, wh, b, edge_msg=None, *,
                tn: int, td: Optional[int], residency: str = "vmem",
                depth: int = 2):
    B, T, n, k = neigh_idx.shape
    din, h = node_feat.shape[3], h0.shape[2]
    G = h0.shape[1]
    assert n % tn == 0
    paged = residency == "hbm_paged"
    td = h if td is None else td
    d_pad = _round_up(h, td)
    D = d_pad // td
    cached = D > 1 or paged
    grid = (B, T, 1, D, n // tn)

    h0p = _pad_dim(h0, d_pad, -1)
    c0p = _pad_dim(c0, d_pad, -1)
    wxp = _pack_gate_blocks(wx, 4, td)                    # (D, din, 4td)
    whp = _pack_gate_blocks(_pad_dim(wh, d_pad, 0), 4, td)  # (D, d_pad, 4td)
    bp = _pack_gate_bias(b, 4, td)                        # (D, 4td)

    has_edge = edge_msg is not None
    if not has_edge:
        edge_msg = jnp.zeros((B, T, 8, din), node_feat.dtype)
    e = edge_msg.shape[2]

    tile = lambda bi, t, l, d, j: (bi, t, j, 0)
    step = lambda bi, t, l, d, j: (bi, t, 0, 0)
    row = lambda bi, t, l, d, j: (bi, t, j)
    state_in = lambda bi, t, l, d, j: (bi, 0, 0)
    state_out = lambda bi, t, l, d, j: (bi, 0, d)
    out_tile = lambda bi, t, l, d, j: (bi, t, j, d)
    dblk = lambda bi, t, l, d, j: (d, 0, 0)
    dblk1 = lambda bi, t, l, d, j: (d, 0)

    if paged:
        # HBM-resident stores: h as an A/B plane pair (stage-in reads the
        # t%2 plane, write-back the other), c as a single plane; both
        # aliased in-place onto their outputs. scr layout keeps the cache
        # slots at the resident positions (3, 4).
        h_in = jnp.stack([h0p, jnp.zeros_like(h0p)], axis=1)
        c_in = c0p[:, None]
        state_in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)] * 2
        state_out_specs = [pl.BlockSpec(memory_space=pltpu.ANY)] * 2
        state_out_shape = [
            jax.ShapeDtypeStruct((B, 2, G, d_pad), h0.dtype),
            jax.ShapeDtypeStruct((B, 1, G, d_pad), c0.dtype),
        ]
        states = (_StateMeta("pingpong", in_idx=7, out_idx=1, scr_idx=0,
                             ring_idx=2, sem_idx=5),
                  _StateMeta("row", in_idx=8, out_idx=2, scr_idx=1,
                             sem_idx=6))
        state_scratch = [
            pltpu.VMEM((G, td), h0.dtype),            # h staging window
            pltpu.VMEM((G, td), c0.dtype),            # c staging window
            pltpu.VMEM((depth, G, td), h0.dtype),     # h read ring
        ]
        sem_scratch = [pltpu.SemaphoreType.DMA((depth + 1,)),
                       pltpu.SemaphoreType.DMA((depth + 1,))]
        aliases = {7: 1, 8: 2}
    else:
        h_in, c_in = h0p, c0p
        state_in_specs = [pl.BlockSpec((1, G, d_pad), state_in)] * 2
        state_out_specs = [pl.BlockSpec((1, G, td), state_out)] * 2
        state_out_shape = [
            jax.ShapeDtypeStruct((B, G, d_pad), h0.dtype),
            jax.ShapeDtypeStruct((B, G, d_pad), c0.dtype),
        ]
        states = (_StateMeta("pingpong", in_idx=7, out_idx=1, scr_idx=0),
                  _StateMeta("row", in_idx=8, out_idx=2, scr_idx=2))
        state_scratch = [
            pltpu.VMEM((G, d_pad), h0.dtype),         # h ping
            pltpu.VMEM((G, d_pad), h0.dtype),         # h pong
            pltpu.VMEM((G, d_pad), c0.dtype),         # c (own-row)
        ]
        sem_scratch = []
        aliases = {}

    meta = _Meta(
        n_in=13, n_out=3, states=states,
        live_idx=None, td=td, paged=paged, depth=depth, g_rows=G)
    return _Launch(
        grid=grid,
        inputs=(neigh_idx, neigh_gidx, neigh_coef, neigh_eidx, node_feat,
                row_gidx, node_mask, h_in, c_in, wxp, whp, bp, edge_msg),
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),        # neigh_idx (local)
            pl.BlockSpec((1, 1, tn, k), tile),        # neigh_gidx (global)
            pl.BlockSpec((1, 1, tn, k), tile),        # neigh_coef
            pl.BlockSpec((1, 1, tn, k), tile),        # neigh_eidx
            pl.BlockSpec((1, 1, n, din), step),       # node_feat, per (b, t)
            pl.BlockSpec((1, 1, tn), row),            # row_gidx
            pl.BlockSpec((1, 1, tn), row),            # node_mask
            state_in_specs[0],                        # h0 / h plane pair
            state_in_specs[1],                        # c0 / c plane
            pl.BlockSpec((1, din, 4 * td), dblk),     # wx gate tile, per d
            pl.BlockSpec((1, d_pad, 4 * td), dblk),   # wh gate tile, per d
            pl.BlockSpec((1, 4 * td), dblk1),         # bias gate tile
            pl.BlockSpec((1, 1, e, din), step),       # edge messages
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, td), out_tile),   # per-step h outputs
            state_out_specs[0],                       # final h
            state_out_specs[1],                       # final c
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, d_pad), node_feat.dtype),
        ] + state_out_shape,
        scratch=state_scratch + ([
            pltpu.VMEM((n, din), node_feat.dtype),    # agg_x cache
            pltpu.VMEM((n, d_pad), h0.dtype),         # agg_h cache
        ] if cached else []) + sem_scratch,
        meta=meta,
        cell=functools.partial(_gcrn_cell, has_edge, cached),
        evolve=None,
        aliases=aliases,
    )


# ------------------------------------------------------------------------
# Stacked DGNN (GCN -> GRU): own-row h only. The GRU's hidden-to-gate
# matmul reads the FULL-width t-1 row, cached at d == 0 BEFORE this step's
# first write (rows are tile-owned, so the cache of a tile's rows is never
# clobbered by other tiles).

def _stacked_cell(has_edge, cached, eng, ins, outs, scr):
    (idx_ref, coef_ref, eidx_ref, x_ref, rowg_ref, mask_ref, _h0,
     wg_ref, bg_ref, wx_ref, wh_ref, b_ref, emsg_ref) = ins
    out_ref = outs[0]
    h_scr = scr[0]

    idx, coef, eidx = idx_ref[0, 0], coef_ref[0, 0], eidx_ref[0, 0]
    rowg = rowg_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    tn = idx.shape[0]
    rows = pl.ds(eng.j * tn, tn)
    n_global = eng.g_rows
    row_safe = jnp.where(rowg < n_global, rowg, 0)

    def _node_transform():
        x = x_ref[0, 0]
        agg = (_agg_local_edge(idx, coef, eidx, x, emsg_ref[0, 0])
               if has_edge else _agg_local(idx, coef, x))
        return agg @ wg_ref[...] + bg_ref[...][None, :]

    def _gather_rows(store):
        # t-1 own rows, gathered BEFORE this step's first write to them
        return jnp.take(store, row_safe, axis=0) * mask

    if cached:  # D > 1 or paged: once per (t, j); d > 0 re-reads
        cnt, chold = scr[1], scr[2]

        @pl.when(eng.first_dblock)
        def _fill_caches():
            cnt[rows] = _node_transform()
            if eng.paged:
                # sweep the t-1 h store's windows through the DMA ring;
                # the gather is columnwise, so per-window fills of
                # disjoint cache columns equal the full-width fill
                def _one(w, wblk, sval):
                    chold[rows, wblk] = _gather_rows(sval)

                eng.paged_fill(0, _one)
            else:
                chold[rows] = _gather_rows(h_scr[...])

        nt, h_old_full = cnt[rows], chold[rows]
    else:       # single d block: read-then-write in one program
        nt = _node_transform()
        h_old_full = _gather_rows(h_scr[...])

    td = eng.td
    gx = nt @ wx_ref[0] + b_ref[0][None, :]
    gh = h_old_full @ wh_ref[0]
    rx, zx, nx = gx[:, :td], gx[:, td:2 * td], gx[:, 2 * td:]
    rh, zh, nh = gh[:, :td], gh[:, td:2 * td], gh[:, 2 * td:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    nn = jnp.tanh(nx + r * nh)
    h_old = eng.dslice(h_old_full)
    h_new = ((1.0 - z) * nn + z * h_old) * mask

    eng.state_scatter(scr, 0, rowg, h_new)
    out_ref[0, 0] = h_new


def _stacked_build(neigh_idx, neigh_coef, neigh_eidx, node_feat, row_gidx,
                   node_mask, h0, w_gcn, b_gcn, wx, wh, b, edge_msg=None, *,
                   tn: int, td: Optional[int], residency: str = "vmem",
                   depth: int = 2):
    B, T, n, k = neigh_idx.shape
    din, h = node_feat.shape[3], h0.shape[2]
    dmid = w_gcn.shape[1]
    G = h0.shape[1]
    assert n % tn == 0
    paged = residency == "hbm_paged"
    td = h if td is None else td
    d_pad = _round_up(h, td)
    D = d_pad // td
    cached = D > 1 or paged
    grid = (B, T, 1, D, n // tn)

    h0p = _pad_dim(h0, d_pad, -1)
    wxp = _pack_gate_blocks(wx, 3, td)                      # (D, dmid, 3td)
    whp = _pack_gate_blocks(_pad_dim(wh, d_pad, 0), 3, td)  # (D, d_pad, 3td)
    bp = _pack_gate_bias(b, 3, td)                          # (D, 3td)

    has_edge = edge_msg is not None
    if not has_edge:
        edge_msg = jnp.zeros((B, T, 8, din), node_feat.dtype)
    e = edge_msg.shape[2]

    tile = lambda bi, t, l, d, j: (bi, t, j, 0)
    step = lambda bi, t, l, d, j: (bi, t, 0, 0)
    row = lambda bi, t, l, d, j: (bi, t, j)
    state_in = lambda bi, t, l, d, j: (bi, 0, 0)
    state_out = lambda bi, t, l, d, j: (bi, 0, d)
    out_tile = lambda bi, t, l, d, j: (bi, t, j, d)
    res2 = lambda bi, t, l, d, j: (0, 0)
    res1 = lambda bi, t, l, d, j: (0,)
    dblk = lambda bi, t, l, d, j: (d, 0, 0)
    dblk1 = lambda bi, t, l, d, j: (d, 0)

    if paged:
        # HBM-resident own-row store as a single plane, aliased in-place
        # onto its output; caches stay at the resident positions (1, 2).
        h_in = h0p[:, None]
        h_in_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        h_out_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        h_out_shape = jax.ShapeDtypeStruct((B, 1, G, d_pad), h0.dtype)
        states = (_StateMeta("row", in_idx=6, out_idx=1, scr_idx=0,
                             ring_idx=3, sem_idx=4),)
        state_scratch = [pltpu.VMEM((G, td), h0.dtype)]   # h staging window
        ring_scratch = [pltpu.VMEM((depth, G, td), h0.dtype)]  # h read ring
        sem_scratch = [pltpu.SemaphoreType.DMA((depth + 1,))]
        aliases = {6: 1}
    else:
        h_in = h0p
        h_in_spec = pl.BlockSpec((1, G, d_pad), state_in)
        h_out_spec = pl.BlockSpec((1, G, td), state_out)
        h_out_shape = jax.ShapeDtypeStruct((B, G, d_pad), h0.dtype)
        states = (_StateMeta("row", in_idx=6, out_idx=1, scr_idx=0),)
        state_scratch = [pltpu.VMEM((G, d_pad), h0.dtype)]  # h (own-row)
        ring_scratch = []
        sem_scratch = []
        aliases = {}

    meta = _Meta(
        n_in=13, n_out=2, states=states,
        live_idx=None, td=td, paged=paged, depth=depth, g_rows=G)
    return _Launch(
        grid=grid,
        inputs=(neigh_idx, neigh_coef, neigh_eidx, node_feat, row_gidx,
                node_mask, h_in, w_gcn, b_gcn, wxp, whp, bp, edge_msg),
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),
            pl.BlockSpec((1, 1, tn, k), tile),
            pl.BlockSpec((1, 1, tn, k), tile),
            pl.BlockSpec((1, 1, n, din), step),
            pl.BlockSpec((1, 1, tn), row),
            pl.BlockSpec((1, 1, tn), row),
            h_in_spec,                                 # h0 / h plane
            pl.BlockSpec((din, dmid), res2),           # GCN weight (full)
            pl.BlockSpec((dmid,), res1),               # GCN bias
            pl.BlockSpec((1, dmid, 3 * td), dblk),     # wx gate tile, per d
            pl.BlockSpec((1, d_pad, 3 * td), dblk),    # wh gate tile, per d
            pl.BlockSpec((1, 3 * td), dblk1),          # bias gate tile
            pl.BlockSpec((1, 1, e, din), step),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, td), out_tile),
            h_out_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, d_pad), node_feat.dtype),
            h_out_shape,
        ],
        scratch=state_scratch + ([
            pltpu.VMEM((n, dmid), node_feat.dtype),    # node-transform cache
            pltpu.VMEM((n, d_pad), h0.dtype),          # t-1 h-row cache
        ] if cached else []) + ring_scratch + sem_scratch,
        meta=meta,
        cell=functools.partial(_stacked_cell, has_edge, cached),
        evolve=None,
        aliases=aliases,
    )


# ------------------------------------------------------------------------
# EvolveGCN: weights-resident family. No node-resident recurrent state —
# the recurrence is over the per-layer GCN weights W_l^t, evolved by a
# matrix-GRU between snapshots (live-gated by the engine). The L grid axis
# sequences the multi-layer GCN's cross-tile dependency over a ping-pong
# activation scratch; the d axis blocks W's COLUMNS, which the matrix-GRU
# evolves independently (columns are the GRU batch), so per-(l, d-block)
# evolution is exact. Padding convention: all widths zero-padded into a
# common square d_pad; GRU params padded PER GATE BLOCK
# (ops._pad_matrix_gru_params); zero-padded weight ROWS stay zero under
# evolution per block (their gate inputs are identically 0), keeping junk
# activation columns out of valid output columns.

def _evolve_cell(has_edge, cached, eng, ins, outs, scr):
    (idx_ref, coef_ref, x_ref, mask_ref, _live, _w0, bg_ref, eagg_ref,
     _wx, _wh, _bp) = ins
    out_ref = outs[0]
    xa, xb = scr[1], scr[2]
    l, j = eng.l, eng.j
    d_pad = xa.shape[1]

    # layer-0 activations are this step's node features: (re)load the ping
    # buffer at the first program of every step.
    @pl.when(jnp.logical_and(l == 0, jnp.logical_and(eng.first_dblock,
                                                     j == 0)))
    def _init_x():
        xa[...] = x_ref[0, 0]

    leven = (l % 2) == 0  # even layers read A / write B, odd the reverse
    idx, coef = idx_ref[0, 0], coef_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    tn, k = idx.shape
    rows = pl.ds(j * tn, tn)

    def _aggregate():
        x_prev = jnp.where(leven, xa[...], xb[...])
        g = jnp.take(x_prev, idx.reshape(-1),
                     axis=0).reshape(tn, k, d_pad)
        out = (g * coef[..., None]).sum(axis=1)
        return out + eagg_ref[0, 0, 0] if has_edge else out

    if cached:  # D > 1: aggregate once per (t, l, j); d > 0 re-reads
        cagg = scr[3]

        @pl.when(eng.first_dblock)
        def _fill_cache():
            cagg[rows] = _aggregate()

        agg = cagg[rows]
    else:       # single d block: inline, no scratch round-trip
        agg = _aggregate()

    w_blk = eng.state_block(scr, 0)                     # (d_pad, td)
    h = agg @ w_blk + bg_ref[0][None, :]
    h = jnp.where(l == eng.n_layers - 1, h, jnp.maximum(h, 0.0)) * mask

    @pl.when(jnp.logical_not(leven))
    def _wr_a():
        xa[rows, eng.blk] = h

    @pl.when(leven)
    def _wr_b():
        xb[rows, eng.blk] = h

    # model output = last layer's (masked, linear) activations
    @pl.when(l == eng.n_layers - 1)
    def _out():
        out_ref[0, 0] = h


def _evolve_evolve(eng, ins, scr):
    """Matrix-GRU evolution of W_l's (d) column block for step t+1, after
    the last tile of layer l consumed W_l^t. Identical math to
    rnn.matrix_gru on the valid region: W's columns are the GRU batch, so
    the block evolves independently; gate blocks split at d_pad (params
    padded per gate block by ops._pad_matrix_gru_params)."""
    wx_ref, wh_ref, bp_ref = ins[8], ins[9], ins[10]
    wt = eng.state_block(scr, 0).T                     # (td, d_pad)
    d = wt.shape[1]
    gx = wt @ wx_ref[0] + bp_ref[0][None, :]
    gh = wt @ wh_ref[0]
    rx, zx, nx = gx[:, :d], gx[:, d:2 * d], gx[:, 2 * d:]
    rh, zh, nh = gh[:, :d], gh[:, d:2 * d], gh[:, 2 * d:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    nvec = jnp.tanh(nx + r * nh)
    eng.state_block_store(scr, 0, ((1.0 - z) * nvec + z * wt).T)


def _evolve_build(neigh_idx, neigh_coef, node_feat, node_mask, live,
                  w0, b_gcn, gru_wx, gru_wh, gru_b, edge_agg=None, *,
                  tn: int, td: Optional[int], residency: str = "vmem",
                  depth: int = 2):
    """Inputs pre-padded to the common square d_pad (a td multiple) by
    kernels/ops.py: node_feat (B, T, n, d_pad); w0 (B, L, d_pad, d_pad) —
    each stream's primed evolving weights, entering and leaving the chip
    exactly once per stream; gru params padded per gate block; live (B, T)
    int32 — 1 where the snapshot is real, 0 on no-op tail padding."""
    B, T, n, k = neigh_idx.shape
    L, d_pad = w0.shape[1], w0.shape[2]
    assert n % tn == 0
    paged = residency == "hbm_paged"
    td = d_pad if td is None else td
    assert d_pad % td == 0
    D = d_pad // td
    cached = D > 1 or paged
    grid = (B, T, L, D, n // tn)

    tile = lambda bi, t, l, d, j: (bi, t, j, 0)
    step = lambda bi, t, l, d, j: (bi, t, 0, 0)
    row = lambda bi, t, l, d, j: (bi, t, j)
    flag = lambda bi, t, l, d, j: (bi, t)
    w_in = lambda bi, t, l, d, j: (bi, l, 0, 0)
    w_out = lambda bi, t, l, d, j: (bi, l, 0, d)
    out_tile = lambda bi, t, l, d, j: (bi, t, j, d)
    layer_res3 = lambda bi, t, l, d, j: (l, 0, 0)
    layer_blk = lambda bi, t, l, d, j: (l, d)

    has_edge = edge_agg is not None
    if has_edge:
        eagg_map = lambda bi, t, l, d, j: (bi, t, l, j, 0)
    else:
        # one pinned (revisited) dummy block instead of (B,T,L,n,d_pad)
        # of streamed zeros; the kernel never reads it.
        edge_agg = jnp.zeros((1, 1, 1, tn, d_pad), node_feat.dtype)
        eagg_map = lambda bi, t, l, d, j: (0, 0, 0, 0, 0)

    if paged:
        # HBM-resident evolving W, evolved IN PLACE in the aliased
        # (B, L, d_pad, d_pad) output: stage-in pulls layer l's (d) column
        # block into a (d_pad, td) staging window, the evolve hook updates
        # staging, write-back pushes it home. No read ring: the cell only
        # ever consumes its own (l, d) block, never the full width.
        w_in_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        w_out_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        states = (_StateMeta("weights", in_idx=5, out_idx=1, scr_idx=0,
                             sem_idx=4),)
        state_scratch = [pltpu.VMEM((d_pad, td), w0.dtype)]  # W staging
        sem_scratch = [pltpu.SemaphoreType.DMA((depth + 1,))]
        aliases = {5: 1}
    else:
        w_in_spec = pl.BlockSpec((1, 1, d_pad, d_pad), w_in)
        w_out_spec = pl.BlockSpec((1, 1, d_pad, td), w_out)
        states = (_StateMeta("weights", in_idx=5, out_idx=1, scr_idx=0),)
        state_scratch = [pltpu.VMEM((L, d_pad, d_pad), w0.dtype)]
        sem_scratch = []
        aliases = {}

    meta = _Meta(
        n_in=11, n_out=2, states=states,
        live_idx=4, td=td, paged=paged, depth=depth, g_rows=0)
    return _Launch(
        grid=grid,
        inputs=(neigh_idx, neigh_coef, node_feat, node_mask, live,
                w0, b_gcn, edge_agg, gru_wx, gru_wh, gru_b),
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),            # neigh_idx (local)
            pl.BlockSpec((1, 1, tn, k), tile),            # neigh_coef
            pl.BlockSpec((1, 1, n, d_pad), step),         # node_feat
            pl.BlockSpec((1, 1, tn), row),                # node_mask
            pl.BlockSpec((1, 1), flag),                   # live flag
            w_in_spec,                                    # W0, per (b, l)
            pl.BlockSpec((1, td), layer_blk),             # GCN bias tile
            pl.BlockSpec((1, 1, 1, tn, d_pad), eagg_map),  # edge agg
            pl.BlockSpec((1, d_pad, 3 * d_pad), layer_res3),  # GRU wx
            pl.BlockSpec((1, d_pad, 3 * d_pad), layer_res3),  # GRU wh
            pl.BlockSpec((1, 3 * d_pad), lambda bi, t, l, d, j: (l, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, td), out_tile),       # per-step outputs
            w_out_spec,                                   # final weights
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, d_pad), node_feat.dtype),
            jax.ShapeDtypeStruct((B, L, d_pad, d_pad), w0.dtype),
        ],
        scratch=state_scratch + [
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # activation ping
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # activation pong
        ] + ([
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # aggregation cache
        ] if cached else []) + sem_scratch,
        meta=meta,
        cell=functools.partial(_evolve_cell, has_edge, cached),
        evolve=_evolve_evolve,
        aliases=aliases,
    )


# ------------------------------------------------------------------------
# TGN (event-driven temporal GNN): the "event" temporal contract. The T
# grid axis sequences EVENT BATCHES, not snapshots — each step is a ragged
# batch of timestamped events laid out as ELL rows over the touched nodes
# (graph/events.pad_event_block), so ``lengths`` generalizes from ragged-T
# snapshot streams to ragged event streams. Per event batch, every touched
# node aggregates its event partners' t-1 memory plus a sinusoidal TIME
# ENCODING of the per-event timestamps (cos(t * freq_d), learnable per-dim
# frequencies — the TGAT/TGN functional form), feeds a GRU, and updates
# ONLY its own node-memory row (untouched rows carry over through the
# ping-pong copy-forward; padding rows scatter-drop). Dead (coef-0) event
# lanes contribute exactly zero to both aggregations, whatever timestamp
# they carry — the property tests pin this.

def _tgn_cell(cached, eng, ins, outs, scr):
    (gidx_ref, coef_ref, ts_ref, x_ref, rowg_ref, mask_ref, _m0,
     freq_ref, win_ref, wx_ref, wh_ref, b_ref) = ins
    out_ref = outs[0]

    gidx, coef, ts = gidx_ref[0, 0], coef_ref[0, 0], ts_ref[0, 0]
    rowg = rowg_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    tn = gidx.shape[0]
    rows = pl.ds(eng.j * tn, tn)
    n_global = eng.g_rows
    row_safe = jnp.where(rowg < n_global, rowg, 0)

    def _inputs():
        # sinusoidal time encoding per event lane; padded freq columns
        # give cos(0)=1 but only ever multiply zero-padded wx rows
        enc = jnp.cos(ts[..., None] * freq_ref[0][None, None, :])
        agg_e = (enc * coef[..., None]).sum(axis=1)
        x_tile = jax.lax.dynamic_slice_in_dim(x_ref[0, 0], eng.j * tn, tn,
                                              axis=0)
        return x_tile @ win_ref[...], agg_e

    if cached:  # D > 1 or paged: compute once per (t, j); d > 0 re-reads
        cinp, cmem = scr[2], scr[3]

        @pl.when(eng.first_dblock)
        def _fill_caches():
            xw, agg_e = _inputs()
            if eng.paged:
                # sweep the t-1 memory's windows through the DMA ring;
                # every term is columnwise and the sum association
                # ((x@win + agg_m) + agg_e) matches the resident fill,
                # so per-window fills are bit-identical
                def _one(w, wblk, sval):
                    agg_m = _agg_store(gidx, coef, sval)
                    cols = slice(w * eng.td, (w + 1) * eng.td)
                    cinp[rows, wblk] = (xw[:, cols] + agg_m) + agg_e[:, cols]
                    cmem[rows, wblk] = jnp.take(sval, row_safe,
                                                axis=0) * mask

                eng.paged_fill(0, _one)
            else:
                store = eng.state_read(scr, 0)   # full-width t-1 memory
                cinp[rows] = (xw + _agg_store(gidx, coef, store)) + agg_e
                cmem[rows] = jnp.take(store, row_safe, axis=0) * mask

        inp, mem_own = cinp[rows], cmem[rows]
    else:       # single d block: inline, no scratch round-trip
        store = eng.state_read(scr, 0)           # full-width t-1 memory
        xw, agg_e = _inputs()
        inp = (xw + _agg_store(gidx, coef, store)) + agg_e
        mem_own = jnp.take(store, row_safe, axis=0) * mask

    td = eng.td
    gx = inp @ wx_ref[0] + b_ref[0][None, :]
    gh = mem_own @ wh_ref[0]
    rx, zx, nx = gx[:, :td], gx[:, td:2 * td], gx[:, 2 * td:]
    rh, zh, nh = gh[:, :td], gh[:, td:2 * td], gh[:, 2 * td:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    nn = jnp.tanh(nx + r * nh)
    m_new = ((1.0 - z) * nn + z * eng.dslice(mem_own)) * mask

    eng.state_scatter(scr, 0, rowg, m_new)
    out_ref[0, 0] = m_new


def _tgn_build(neigh_gidx, neigh_coef, neigh_ts, node_feat, row_gidx,
               node_mask, mem0, freq, w_in, wx, wh, b, *,
               tn: int, td: Optional[int], residency: str = "vmem",
               depth: int = 2):
    """Event-stream launch: (B, T, n, k) ELL event batches with per-lane
    timestamps; the node-memory store (B, G, h) is the single pingpong
    state, entering and leaving the chip once per stream."""
    B, T, n, k = neigh_gidx.shape
    din, h = node_feat.shape[3], mem0.shape[2]
    G = mem0.shape[1]
    assert n % tn == 0
    paged = residency == "hbm_paged"
    td = h if td is None else td
    d_pad = _round_up(h, td)
    D = d_pad // td
    cached = D > 1 or paged
    grid = (B, T, 1, D, n // tn)

    mem0p = _pad_dim(mem0, d_pad, -1)
    freq_p = _pad_dim(freq, d_pad, 0)[None]           # (1, d_pad): 2-D ref
    win_p = _pad_dim(w_in, d_pad, -1)
    wxp = _pack_gate_blocks(_pad_dim(wx, d_pad, 0), 3, td)  # (D, d_pad, 3td)
    whp = _pack_gate_blocks(_pad_dim(wh, d_pad, 0), 3, td)  # (D, d_pad, 3td)
    bp = _pack_gate_bias(b, 3, td)                          # (D, 3td)

    tile = lambda bi, t, l, d, j: (bi, t, j, 0)
    step = lambda bi, t, l, d, j: (bi, t, 0, 0)
    row = lambda bi, t, l, d, j: (bi, t, j)
    state_in = lambda bi, t, l, d, j: (bi, 0, 0)
    state_out = lambda bi, t, l, d, j: (bi, 0, d)
    out_tile = lambda bi, t, l, d, j: (bi, t, j, d)
    res2 = lambda bi, t, l, d, j: (0, 0)
    dblk = lambda bi, t, l, d, j: (d, 0, 0)
    dblk1 = lambda bi, t, l, d, j: (d, 0)

    if paged:
        # HBM-resident memory store as an A/B plane pair, aliased in-place
        # onto its output; caches stay at the resident positions (2, 3).
        m_in = jnp.stack([mem0p, jnp.zeros_like(mem0p)], axis=1)
        m_in_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        m_out_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        m_out_shape = jax.ShapeDtypeStruct((B, 2, G, d_pad), mem0.dtype)
        states = (_StateMeta("pingpong", in_idx=6, out_idx=1, scr_idx=0,
                             ring_idx=1, sem_idx=4),)
        state_scratch = [
            pltpu.VMEM((G, td), mem0.dtype),            # mem staging window
            pltpu.VMEM((depth, G, td), mem0.dtype),     # mem read ring
        ]
        sem_scratch = [pltpu.SemaphoreType.DMA((depth + 1,))]
        aliases = {6: 1}
    else:
        m_in = mem0p
        m_in_spec = pl.BlockSpec((1, G, d_pad), state_in)
        m_out_spec = pl.BlockSpec((1, G, td), state_out)
        m_out_shape = jax.ShapeDtypeStruct((B, G, d_pad), mem0.dtype)
        states = (_StateMeta("pingpong", in_idx=6, out_idx=1, scr_idx=0),)
        state_scratch = [
            pltpu.VMEM((G, d_pad), mem0.dtype),       # mem ping
            pltpu.VMEM((G, d_pad), mem0.dtype),       # mem pong
        ]
        sem_scratch = []
        aliases = {}

    meta = _Meta(
        n_in=12, n_out=2, states=states,
        live_idx=None, td=td, temporal="event", paged=paged, depth=depth,
        g_rows=G)
    return _Launch(
        grid=grid,
        inputs=(neigh_gidx, neigh_coef, neigh_ts, node_feat, row_gidx,
                node_mask, m_in, freq_p, win_p, wxp, whp, bp),
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),        # partner gidx (global)
            pl.BlockSpec((1, 1, tn, k), tile),        # event coef (1/deg)
            pl.BlockSpec((1, 1, tn, k), tile),        # event timestamps
            pl.BlockSpec((1, 1, n, din), step),       # touched-node features
            pl.BlockSpec((1, 1, tn), row),            # row_gidx
            pl.BlockSpec((1, 1, tn), row),            # node_mask
            m_in_spec,                                # mem0 / mem plane pair
            pl.BlockSpec((1, d_pad), res2),           # time-enc frequencies
            pl.BlockSpec((din, d_pad), res2),         # input projection
            pl.BlockSpec((1, d_pad, 3 * td), dblk),   # wx gate tile, per d
            pl.BlockSpec((1, d_pad, 3 * td), dblk),   # wh gate tile, per d
            pl.BlockSpec((1, 3 * td), dblk1),         # bias gate tile
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, td), out_tile),   # per-batch mem outputs
            m_out_spec,                               # final memory
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, d_pad), node_feat.dtype),
            m_out_shape,
        ],
        scratch=state_scratch + ([
            pltpu.VMEM((n, d_pad), node_feat.dtype),  # GRU-input cache
            pltpu.VMEM((n, d_pad), mem0.dtype),       # own-row mem cache
        ] if cached else []) + sem_scratch,
        meta=meta,
        cell=functools.partial(_tgn_cell, cached),
        evolve=None,
        aliases=aliases,
    )


# ------------------------------------------------------------------------
# Static GCN (GenGNN-style): the "static" temporal contract — no
# recurrence, zero StateDefs, no evolve hook; the engine's state
# init/copy-forward/drain loops are vacuously empty. T must be 1:
# independent snapshots fold onto the B axis instead (the serve express
# lane), so a "stream" of static graphs is just a batch. The L grid axis
# sequences the multi-layer GCN over the evolve-style activation ping-pong
# scratch, but the per-layer weights come straight from INPUT refs
# (BlockSpec-indexed by (l, d)) — nothing is resident across steps.

def _static_cell(has_edge, cached, eng, ins, outs, scr):
    (idx_ref, coef_ref, x_ref, mask_ref, w_ref, bg_ref, eagg_ref) = ins
    out_ref = outs[0]
    xa, xb = scr[0], scr[1]
    l, j = eng.l, eng.j
    d_pad = xa.shape[1]

    # layer-0 activations are the snapshot's node features
    @pl.when(jnp.logical_and(l == 0, jnp.logical_and(eng.first_dblock,
                                                     j == 0)))
    def _init_x():
        xa[...] = x_ref[0, 0]

    leven = (l % 2) == 0  # even layers read A / write B, odd the reverse
    idx, coef = idx_ref[0, 0], coef_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    tn, k = idx.shape
    rows = pl.ds(j * tn, tn)

    def _aggregate():
        x_prev = jnp.where(leven, xa[...], xb[...])
        g = jnp.take(x_prev, idx.reshape(-1),
                     axis=0).reshape(tn, k, d_pad)
        out = (g * coef[..., None]).sum(axis=1)
        return out + eagg_ref[0, 0, 0] if has_edge else out

    if cached:  # D > 1: aggregate once per (l, j); d > 0 re-reads
        cagg = scr[2]

        @pl.when(eng.first_dblock)
        def _fill_cache():
            cagg[rows] = _aggregate()

        agg = cagg[rows]
    else:       # single d block: inline, no scratch round-trip
        agg = _aggregate()

    h = agg @ w_ref[0] + bg_ref[0][None, :]
    h = jnp.where(l == eng.n_layers - 1, h, jnp.maximum(h, 0.0)) * mask

    @pl.when(jnp.logical_not(leven))
    def _wr_a():
        xa[rows, eng.blk] = h

    @pl.when(leven)
    def _wr_b():
        xb[rows, eng.blk] = h

    # model output = last layer's (masked, linear) activations
    @pl.when(l == eng.n_layers - 1)
    def _out():
        out_ref[0, 0] = h


def _static_build(neigh_idx, neigh_coef, node_feat, node_mask,
                  weights, b_gcn, edge_agg=None, *,
                  tn: int, td: Optional[int], residency: str = "vmem",
                  depth: int = 2):
    """Inputs pre-padded to the common square d_pad by kernels/ops.py:
    node_feat (B, 1, n, d_pad); weights (L, d_pad, d_pad) stacked per
    layer, SHARED across the batch (params, not state)."""
    if residency != "vmem":
        raise ValueError(
            "static_gcn has no state to page; residency must be 'vmem'")
    B, T, n, k = neigh_idx.shape
    if T != 1:
        raise ValueError(
            f"static family runs with T == 1, got T={T}: a static-GCN "
            "'stream' has no recurrence — fold independent snapshots onto "
            "the batch axis instead (core.gcn.StaticGCN.step_stream does)")
    L, d_pad = weights.shape[0], weights.shape[1]
    assert n % tn == 0
    td = d_pad if td is None else td
    assert d_pad % td == 0
    D = d_pad // td
    grid = (B, 1, L, D, n // tn)

    tile = lambda bi, t, l, d, j: (bi, t, j, 0)
    step = lambda bi, t, l, d, j: (bi, t, 0, 0)
    row = lambda bi, t, l, d, j: (bi, t, j)
    out_tile = lambda bi, t, l, d, j: (bi, t, j, d)
    layer_wblk = lambda bi, t, l, d, j: (l, 0, d)
    layer_blk = lambda bi, t, l, d, j: (l, d)

    has_edge = edge_agg is not None
    if has_edge:
        eagg_map = lambda bi, t, l, d, j: (bi, t, l, j, 0)
    else:
        # one pinned (revisited) dummy block; the kernel never reads it.
        edge_agg = jnp.zeros((1, 1, 1, tn, d_pad), node_feat.dtype)
        eagg_map = lambda bi, t, l, d, j: (0, 0, 0, 0, 0)

    meta = _Meta(
        n_in=7, n_out=1, states=(),
        live_idx=None, td=td, temporal="static")
    return _Launch(
        grid=grid,
        inputs=(neigh_idx, neigh_coef, node_feat, node_mask,
                weights, b_gcn, edge_agg),
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),            # neigh_idx (local)
            pl.BlockSpec((1, 1, tn, k), tile),            # neigh_coef
            pl.BlockSpec((1, 1, n, d_pad), step),         # node_feat
            pl.BlockSpec((1, 1, tn), row),                # node_mask
            pl.BlockSpec((1, d_pad, td), layer_wblk),     # W_l column block
            pl.BlockSpec((1, td), layer_blk),             # GCN bias tile
            pl.BlockSpec((1, 1, 1, tn, d_pad), eagg_map),  # edge agg
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, td), out_tile),       # per-snapshot outs
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1, n, d_pad), node_feat.dtype),
        ],
        scratch=[
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # activation ping
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # activation pong
        ] + ([
            pltpu.VMEM((n, d_pad), node_feat.dtype),   # aggregation cache
        ] if D > 1 else []),
        meta=meta,
        cell=functools.partial(_static_cell, has_edge, D > 1),
        evolve=None,
    )


# ------------------------------------------------------------------------
# The registry: every DGNN family the stream engine serves. Adding a
# family = registering a cell spec here (CI runs the registry tests for
# every entry, so an untested spec fails the build).

REGISTRY: dict[str, CellSpec] = {
    "gcrn": CellSpec(
        name="gcrn",
        resident="node-state store: h (ping-pong pair) + c (own-row)",
        states=(StateDef("h", "pingpong", full_read=True),
                StateDef("c", "row")),
        build=_gcrn_build,
        temporal="dense"),
    "stacked": CellSpec(
        name="stacked",
        resident="node-state store: h (own-row)",
        states=(StateDef("h", "row", full_read=True),),
        build=_stacked_build,
        temporal="dense"),
    "evolve": CellSpec(
        name="evolve",
        resident="per-layer evolving weights W_l (matrix-GRU in-kernel)",
        states=(StateDef("weights", "weights"),),
        build=_evolve_build,
        temporal="dense"),
    "tgn": CellSpec(
        name="tgn",
        resident="node-memory store: mem (ping-pong pair)",
        states=(StateDef("mem", "pingpong", full_read=True),),
        build=_tgn_build,
        temporal="event"),
    "static_gcn": CellSpec(
        name="static_gcn",
        resident="none (stateless; activation ping-pong scratch only)",
        states=(),
        build=_static_build,
        temporal="static"),
}


def _validate_registry() -> None:
    """Structural invariants on the declarative temporal contract,
    checked once at import: a spec that lies about its mode fails before
    any launch does."""
    for name, spec in REGISTRY.items():
        if spec.temporal not in TEMPORAL_MODES:
            raise ValueError(
                f"family {name!r} declares unknown temporal mode "
                f"{spec.temporal!r}; expected one of {TEMPORAL_MODES}")
        if spec.temporal == "static" and spec.states:
            raise ValueError(
                f"static family {name!r} must declare zero StateDefs, "
                f"got {[s.name for s in spec.states]}")
        if spec.temporal != "static" and not spec.states:
            raise ValueError(
                f"{spec.temporal} family {name!r} declares no StateDefs: "
                "recurrence without state is a contract violation")


_validate_registry()
