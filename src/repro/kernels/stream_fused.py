"""DGNN-Booster V3 time-fused stream kernels: BRAM-resident recurrent state.

The V2 kernels (dgnn_fused.py) fuse MP+NT+RNN *within* one snapshot but are
re-invoked per time step from a scan, so the recurrent node-state store
(h, and c for GCRN) round-trips HBM T times per stream — exactly the DRAM
traffic the paper's BRAM+FIFO design eliminates. Here the WHOLE snapshot
stream runs inside a single ``pallas_call`` with grid ``(B, T, n_pad//tn)``:

  * each step's ELL tiles (neigh_idx / neigh_coef / neigh_eidx / node_feat /
    renumber rows / node_mask) stream along the T grid axis via their
    BlockSpec index maps (the paper's snapshot DMA),
  * the global node-state store lives in VMEM **scratch** and never leaves
    the chip between snapshots — the TPU edition of the paper's BRAM-
    resident embeddings; the renumber-table-guided DRAM fetch/writeback
    becomes a VMEM-internal gather/scatter.

Because step t+1's aggregation reads h produced by step t, the T axis is
sequential (``dimension_semantics`` marks every axis "arbitrary"). The GCRN
variant aggregates over *neighbours'* h, so within a step every tile must
see the t-1 store while tiles write the t store: a VMEM ping-pong pair
(read h[t-1] from one buffer, write h[t] into the other, swapped by t's
parity) — the V1 ping-pong carry of core/dataflow.py pushed down into the
kernel. c (GCRN) and h (stacked GRU) are touched only at a node's own row,
each row owned by exactly one tile per step (renumbering is injective), so
a single buffer suffices for them.

Batch axis (B independent streams, the production throughput axis)
------------------------------------------------------------------
The batch of streams is a LEADING GRID DIMENSION of the same kernel, not a
``jax.vmap`` over the unbatched ``pallas_call``. Both execute correctly in
interpret mode, but the vmap batching rule prepends its axis to the grid
(``grid=(axis_size, *grid)``) while forwarding ``compiler_params``
unchanged — so the ``dimension_semantics`` tuple we declare would no longer
describe the axes the ping-pong parity argument depends on, and the scratch
lifecycle across the vmapped axis becomes an implementation detail of the
batching rule rather than something the kernel states. With an explicit B
axis we declare all three axes "arbitrary" (sequential on one core) and the
state scratch is *serially reused per stream by construction*: at each
stream's own ``(t==0, j==0)`` the scratch is re-initialized from that
stream's h0/c0 block, and at its ``(T-1, J-1)`` it drains to that stream's
hT/cT block, so no state ever aliases between streams and each stream
restarts the ping-pong at even parity. One launch amortizes the weight
loads across all B streams and keeps the recurrent state's HBM traffic at
2 transfers *per stream*, independent of T. The unbatched entry points are
the B=1 special case of the same kernel body.

Correctness contract: identical math to the per-step V2 path + the models'
gather/scatter, verified against kernels/ref.py stream oracles and the
differential harness (v3 ≡ baseline ≡ batched-v3 row-sliced).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# per-tile ELL aggregation over a step-resident feature table (local ids):
# shared with the per-step V2 kernels, same math by construction.
from repro.kernels.dgnn_fused import _agg as _agg_local
from repro.kernels.dgnn_fused import _agg_edge as _agg_local_edge


def _agg_store(gidx, coef, store):
    """ELL aggregation straight out of the global VMEM store (global ids).

    Lanes with coef != 0 always reference real (renumbered) nodes, so the
    store row equals the masked local h the per-step path would gather;
    coef-0 padding lanes are killed regardless of the row they point at.
    """
    tn, k = gidx.shape
    g = jnp.take(store, gidx.reshape(-1), axis=0).reshape(tn, k, store.shape[1])
    return (g * coef[..., None]).sum(axis=1)


def _stream_done(t_axis: int = 1, j_axis: int = 2):
    """Last (t, j) program of the CURRENT stream — drain point for its state."""
    t = pl.program_id(t_axis)
    j = pl.program_id(j_axis)
    return jnp.logical_and(t == pl.num_programs(t_axis) - 1,
                           j == pl.num_programs(j_axis) - 1)


def _gcrn_stream_kernel(has_edge,
                        idx_ref, gidx_ref, coef_ref, eidx_ref, x_ref,
                        rowg_ref, mask_ref, h0_ref, c0_ref,
                        wx_ref, wh_ref, b_ref, emsg_ref,
                        out_ref, hT_ref, cT_ref,
                        ha_ref, hb_ref, c_ref):
    t, j = pl.program_id(1), pl.program_id(2)
    n_global = h0_ref.shape[1]
    even = (t % 2) == 0  # state after step t-1 lives in A on even t

    # every stream re-initializes the scratch from its OWN h0/c0 block at
    # its (t==0, j==0), so streams reuse the buffers serially and each one
    # starts the ping-pong at even parity.
    @pl.when(jnp.logical_and(t == 0, j == 0))
    def _init():
        ha_ref[...] = h0_ref[0]
        c_ref[...] = c0_ref[0]

    # copy-forward at the start of each step so rows this snapshot does not
    # touch carry over; tiles then overwrite only their own rows.
    @pl.when(jnp.logical_and(j == 0, even))
    def _fwd_ab():
        hb_ref[...] = ha_ref[...]

    @pl.when(jnp.logical_and(j == 0, jnp.logical_not(even)))
    def _fwd_ba():
        ha_ref[...] = hb_ref[...]

    idx, gidx = idx_ref[0, 0], gidx_ref[0, 0]
    coef, eidx = coef_ref[0, 0], eidx_ref[0, 0]
    x = x_ref[0, 0]
    rowg = rowg_ref[0, 0]
    mask = mask_ref[0, 0][:, None]

    h_prev = jnp.where(even, ha_ref[...], hb_ref[...])  # untouched t-1 slot
    if has_edge:
        agg_x = _agg_local_edge(idx, coef, eidx, x, emsg_ref[0, 0])
    else:
        agg_x = _agg_local(idx, coef, x)
    agg_h = _agg_store(gidx, coef, h_prev)

    gates = agg_x @ wx_ref[...] + agg_h @ wh_ref[...] + b_ref[...][None, :]
    hdim = h_prev.shape[1]
    i = gates[:, :hdim]
    f = gates[:, hdim:2 * hdim]
    g = gates[:, 2 * hdim:3 * hdim]
    o = gates[:, 3 * hdim:]

    row_safe = jnp.where(rowg < n_global, rowg, 0)
    c_old = jnp.take(c_ref[...], row_safe, axis=0) * mask
    c_new = (jax.nn.sigmoid(f) * c_old + jax.nn.sigmoid(i) * jnp.tanh(g)) * mask
    h_new = (jax.nn.sigmoid(o) * jnp.tanh(c_new)) * mask

    # scatter back into the write slot; rowg == n_global marks padding rows
    # (the sink convention) and mode="drop" discards them.
    @pl.when(even)
    def _wr_b():
        hb_ref[...] = hb_ref[...].at[rowg].set(h_new, mode="drop")

    @pl.when(jnp.logical_not(even))
    def _wr_a():
        ha_ref[...] = ha_ref[...].at[rowg].set(h_new, mode="drop")

    c_ref[...] = c_ref[...].at[rowg].set(c_new, mode="drop")
    out_ref[0, 0] = h_new

    @pl.when(_stream_done())
    def _drain():
        hT_ref[0] = jnp.where(even, hb_ref[...], ha_ref[...])
        cT_ref[0] = c_ref[...]


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def gcrn_stream_batched_pallas(neigh_idx, neigh_gidx, neigh_coef, neigh_eidx,
                               node_feat, row_gidx, node_mask, h0, c0,
                               wx, wh, b, edge_msg=None, *, tn: int = 128,
                               interpret: bool = False):
    """B independent whole-stream GCRN (GC-LSTM) runs in one pallas_call.

    Shapes: neigh_* (B, T, n, k); node_feat (B, T, n, din); row_gidx /
    node_mask (B, T, n); h0/c0 (B, n_global, hdim) — one global state store
    per stream, each entering and leaving the chip exactly once. Weights
    are shared across streams and loaded once per launch.
    """
    B, T, n, k = neigh_idx.shape
    din, hdim = node_feat.shape[3], h0.shape[2]
    n_global = h0.shape[1]
    assert n % tn == 0
    grid = (B, T, n // tn)
    tile = lambda bi, t, j: (bi, t, j, 0)
    step = lambda bi, t, j: (bi, t, 0, 0)
    row = lambda bi, t, j: (bi, t, j)
    state = lambda bi, t, j: (bi, 0, 0)
    res2 = lambda bi, t, j: (0, 0)
    res1 = lambda bi, t, j: (0,)
    has_edge = edge_msg is not None
    if not has_edge:
        edge_msg = jnp.zeros((B, T, 8, din), node_feat.dtype)
    e = edge_msg.shape[2]
    return pl.pallas_call(
        functools.partial(_gcrn_stream_kernel, has_edge),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),       # neigh_idx (local)
            pl.BlockSpec((1, 1, tn, k), tile),       # neigh_gidx (global)
            pl.BlockSpec((1, 1, tn, k), tile),       # neigh_coef
            pl.BlockSpec((1, 1, tn, k), tile),       # neigh_eidx
            pl.BlockSpec((1, 1, n, din), step),      # node_feat, per (b, t)
            pl.BlockSpec((1, 1, tn), row),           # row_gidx
            pl.BlockSpec((1, 1, tn), row),           # node_mask
            pl.BlockSpec((1, n_global, hdim), state),  # h0, per stream
            pl.BlockSpec((1, n_global, hdim), state),  # c0, per stream
            pl.BlockSpec((din, 4 * hdim), res2),
            pl.BlockSpec((hdim, 4 * hdim), res2),
            pl.BlockSpec((4 * hdim,), res1),
            pl.BlockSpec((1, 1, e, din), step),      # edge messages, per (b, t)
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, hdim), tile),       # per-step h outputs
            pl.BlockSpec((1, n_global, hdim), state),   # final h store
            pl.BlockSpec((1, n_global, hdim), state),   # final c store
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, hdim), node_feat.dtype),
            jax.ShapeDtypeStruct((B, n_global, hdim), h0.dtype),
            jax.ShapeDtypeStruct((B, n_global, hdim), c0.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_global, hdim), h0.dtype),   # h ping
            pltpu.VMEM((n_global, hdim), h0.dtype),   # h pong
            pltpu.VMEM((n_global, hdim), c0.dtype),   # c (single buffer)
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(neigh_idx, neigh_gidx, neigh_coef, neigh_eidx, node_feat,
      row_gidx, node_mask, h0, c0, wx, wh, b, edge_msg)


def gcrn_stream_pallas(neigh_idx, neigh_gidx, neigh_coef, neigh_eidx,
                       node_feat, row_gidx, node_mask, h0, c0, wx, wh, b,
                       edge_msg=None, *, tn: int = 128,
                       interpret: bool = False):
    """Whole-stream GCRN (GC-LSTM): the B=1 case of the batched kernel.

    Shapes: neigh_* (T, n, k); node_feat (T, n, din); row_gidx/node_mask
    (T, n); h0/c0 (n_global, hdim) — the global state store, entering and
    leaving the chip exactly once per stream.
    """
    em = None if edge_msg is None else edge_msg[None]
    outs, hT, cT = gcrn_stream_batched_pallas(
        neigh_idx[None], neigh_gidx[None], neigh_coef[None], neigh_eidx[None],
        node_feat[None], row_gidx[None], node_mask[None], h0[None], c0[None],
        wx, wh, b, em, tn=tn, interpret=interpret)
    return outs[0], hT[0], cT[0]


def _stacked_stream_kernel(has_edge,
                           idx_ref, coef_ref, eidx_ref, x_ref,
                           rowg_ref, mask_ref, h0_ref,
                           wg_ref, bg_ref, wx_ref, wh_ref, b_ref, emsg_ref,
                           out_ref, hT_ref, hs_ref):
    t, j = pl.program_id(1), pl.program_id(2)
    n_global = h0_ref.shape[1]

    # serial scratch reuse across streams: each stream re-loads its own h0.
    @pl.when(jnp.logical_and(t == 0, j == 0))
    def _init():
        hs_ref[...] = h0_ref[0]

    idx, coef, eidx = idx_ref[0, 0], coef_ref[0, 0], eidx_ref[0, 0]
    x = x_ref[0, 0]
    rowg = rowg_ref[0, 0]
    mask = mask_ref[0, 0][:, None]

    if has_edge:
        agg = _agg_local_edge(idx, coef, eidx, x, emsg_ref[0, 0])
    else:
        agg = _agg_local(idx, coef, x)
    nt = agg @ wg_ref[...] + bg_ref[...][None, :]

    # the GRU only reads a node's own h row, each row written by exactly one
    # tile per step, so no ping-pong is needed here.
    row_safe = jnp.where(rowg < n_global, rowg, 0)
    h_old = jnp.take(hs_ref[...], row_safe, axis=0) * mask

    gx = nt @ wx_ref[...] + b_ref[...][None, :]
    gh = h_old @ wh_ref[...]
    hdim = h_old.shape[1]
    rx, zx, nx = gx[:, :hdim], gx[:, hdim:2 * hdim], gx[:, 2 * hdim:]
    rh, zh, nh = gh[:, :hdim], gh[:, hdim:2 * hdim], gh[:, 2 * hdim:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    nn = jnp.tanh(nx + r * nh)
    h_new = ((1.0 - z) * nn + z * h_old) * mask

    hs_ref[...] = hs_ref[...].at[rowg].set(h_new, mode="drop")
    out_ref[0, 0] = h_new

    @pl.when(_stream_done())
    def _drain():
        hT_ref[0] = hs_ref[...]


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def stacked_stream_batched_pallas(neigh_idx, neigh_coef, neigh_eidx,
                                  node_feat, row_gidx, node_mask, h0,
                                  w_gcn, b_gcn, wx, wh, b, edge_msg=None, *,
                                  tn: int = 128, interpret: bool = False):
    """B independent stacked-DGNN streams (GCN last layer -> GRU) in one
    pallas_call; one VMEM-resident h store per stream, reused serially."""
    B, T, n, k = neigh_idx.shape
    din, hdim = node_feat.shape[3], h0.shape[2]
    dmid = w_gcn.shape[1]
    n_global = h0.shape[1]
    assert n % tn == 0
    grid = (B, T, n // tn)
    tile = lambda bi, t, j: (bi, t, j, 0)
    step = lambda bi, t, j: (bi, t, 0, 0)
    row = lambda bi, t, j: (bi, t, j)
    state = lambda bi, t, j: (bi, 0, 0)
    res2 = lambda bi, t, j: (0, 0)
    res1 = lambda bi, t, j: (0,)
    has_edge = edge_msg is not None
    if not has_edge:
        edge_msg = jnp.zeros((B, T, 8, din), node_feat.dtype)
    e = edge_msg.shape[2]
    return pl.pallas_call(
        functools.partial(_stacked_stream_kernel, has_edge),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),
            pl.BlockSpec((1, 1, tn, k), tile),
            pl.BlockSpec((1, 1, tn, k), tile),
            pl.BlockSpec((1, 1, n, din), step),
            pl.BlockSpec((1, 1, tn), row),
            pl.BlockSpec((1, 1, tn), row),
            pl.BlockSpec((1, n_global, hdim), state),
            pl.BlockSpec((din, dmid), res2),
            pl.BlockSpec((dmid,), res1),
            pl.BlockSpec((dmid, 3 * hdim), res2),
            pl.BlockSpec((hdim, 3 * hdim), res2),
            pl.BlockSpec((3 * hdim,), res1),
            pl.BlockSpec((1, 1, e, din), step),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, hdim), tile),
            pl.BlockSpec((1, n_global, hdim), state),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, hdim), node_feat.dtype),
            jax.ShapeDtypeStruct((B, n_global, hdim), h0.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_global, hdim), h0.dtype),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(neigh_idx, neigh_coef, neigh_eidx, node_feat, row_gidx, node_mask,
      h0, w_gcn, b_gcn, wx, wh, b, edge_msg)


def stacked_stream_pallas(neigh_idx, neigh_coef, neigh_eidx, node_feat,
                          row_gidx, node_mask, h0, w_gcn, b_gcn, wx, wh, b,
                          edge_msg=None, *, tn: int = 128,
                          interpret: bool = False):
    """Whole-stream stacked DGNN: the B=1 case of the batched kernel."""
    em = None if edge_msg is None else edge_msg[None]
    outs, hT = stacked_stream_batched_pallas(
        neigh_idx[None], neigh_coef[None], neigh_eidx[None], node_feat[None],
        row_gidx[None], node_mask[None], h0[None], w_gcn, b_gcn, wx, wh, b,
        em, tn=tn, interpret=interpret)
    return outs[0], hT[0]


# ----------------------------------------------------------------------
# EvolveGCN: weights-resident stream kernel.
#
# The weights-evolved family carries no node-resident recurrent state —
# its recurrence is over the per-layer GCN weight matrices W_l^t, evolved
# by a matrix-GRU between snapshots. The per-step schedule therefore
# round-trips every W_l through HBM twice per snapshot (2T per stream),
# the exact per-step weight-update bottleneck of arXiv:2210.03900. Here
# the evolving weights live in VMEM scratch for the whole stream: grid
# (B, T, L, n_pad//tn) with a layer axis L so the multi-layer GCN's
# cross-tile dependency (layer l's aggregation reads layer l-1's output
# for EVERY node) is sequenced by the grid rather than recomputed per
# tile. Per-step activations ping-pong between two full-(n_pad) VMEM
# buffers by layer parity; the matrix-GRU evolution runs in-kernel at
# each live step's last tile program, so W_l crosses HBM exactly twice
# per stream (initial load + final drain).
#
# Padding convention: every layer's weight matrix is zero-padded into a
# common (dmax, dmax) square (dmax = max layer width) so the L weights
# stack into one scratch buffer indexed by the layer grid axis. The GRU
# gate matrices are padded PER GATE BLOCK (ops._pad_matrix_gru_params):
# gx/gh are then split at dmax boundaries inside the kernel and the
# valid region evolves exactly as the unpadded cell. Zero-padded weight
# ROWS stay zero under evolution (their gate inputs are identically 0,
# giving h_new = 0.5 * tanh(0) + 0.5 * 0 = 0), which is what keeps
# junk activation columns from leaking into valid output columns.
#
# No-op tail snapshots (serve chunk padding) must leave the evolving
# weights untouched — unlike the node-state kernels, where padding rows
# simply scatter-drop, weight evolution is per-step, so each step
# carries an explicit ``live`` flag (n_nodes > 0) gating the evolution.


def _matrix_gru_padded(w, wxp, whp, bp):
    """EvolveGCN-O weight evolution on a (dmax, dmax) zero-padded W.

    Identical math to rnn.matrix_gru on the valid region: columns of W
    are the GRU batch; gate blocks split at dmax (params padded per gate
    block by ops._pad_matrix_gru_params).
    """
    d = w.shape[0]
    wt = w.T  # (dout_pad, din_pad): batch of column vectors
    gx = wt @ wxp + bp[None, :]
    gh = wt @ whp
    rx, zx, nx = gx[:, :d], gx[:, d:2 * d], gx[:, 2 * d:]
    rh, zh, nh = gh[:, :d], gh[:, d:2 * d], gh[:, 2 * d:]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    return ((1.0 - z) * n + z * wt).T


def _evolve_stream_kernel(has_edge,
                          idx_ref, coef_ref, x_ref, mask_ref, live_ref,
                          w0_ref, bg_ref, eagg_ref, wx_ref, wh_ref, bgr_ref,
                          out_ref, wT_ref,
                          w_ref, xa_ref, xb_ref):
    t, l, j = pl.program_id(1), pl.program_id(2), pl.program_id(3)
    n_layers = pl.num_programs(2)
    n_tiles = pl.num_programs(3)
    dmax = xa_ref.shape[1]

    # weight residency: each stream loads its OWN primed W_l block once,
    # at its (t==0, j==0) program of layer l — streams reuse the scratch
    # serially, exactly like the node-state kernels above.
    @pl.when(jnp.logical_and(t == 0, j == 0))
    def _init_w():
        w_ref[pl.ds(l, 1)] = w0_ref[0]

    # layer-0 activations are this step's node features: (re)load the
    # ping buffer at the first program of every step.
    @pl.when(jnp.logical_and(l == 0, j == 0))
    def _init_x():
        xa_ref[...] = x_ref[0, 0]

    even = (l % 2) == 0  # even layers read A / write B, odd the reverse
    idx, coef = idx_ref[0, 0], coef_ref[0, 0]
    mask = mask_ref[0, 0][:, None]
    w = w_ref[pl.ds(l, 1)][0]

    x_prev = jnp.where(even, xa_ref[...], xb_ref[...])
    tn, k = idx.shape
    g = jnp.take(x_prev, idx.reshape(-1), axis=0).reshape(tn, k, dmax)
    agg = (g * coef[..., None]).sum(axis=1)
    if has_edge:
        agg = agg + eagg_ref[0, 0, 0]
    h = agg @ w + bg_ref[0][None, :]
    h = jnp.where(l == n_layers - 1, h, jnp.maximum(h, 0.0)) * mask

    @pl.when(jnp.logical_not(even))
    def _wr_a():
        xa_ref[pl.ds(j * tn, tn)] = h

    @pl.when(even)
    def _wr_b():
        xb_ref[pl.ds(j * tn, tn)] = h

    # model output = last layer's (masked, linear) activations
    @pl.when(l == n_layers - 1)
    def _out():
        out_ref[0, 0] = h

    # weight evolution BETWEEN snapshots: after the last tile of layer l
    # consumed W_l^t, evolve it in place for step t+1. No-op (all-padding)
    # snapshots are not steps of the stream — their ``live`` flag gates
    # the evolution off, so serve-side tail padding never advances W.
    @pl.when(jnp.logical_and(j == n_tiles - 1, live_ref[0, 0] > 0))
    def _evolve():
        w_ref[pl.ds(l, 1)] = _matrix_gru_padded(
            w, wx_ref[0], wh_ref[0], bgr_ref[0])[None]

    # drain: this stream's last program of layer l writes the evolved
    # weight (state AFTER the final live step) back to HBM.
    @pl.when(_stream_done(t_axis=1, j_axis=3))
    def _drain():
        wT_ref[0, 0] = w_ref[pl.ds(l, 1)][0]


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def evolve_stream_batched_pallas(neigh_idx, neigh_coef, node_feat, node_mask,
                                 live, w0, b_gcn, gru_wx, gru_wh, gru_b,
                                 edge_agg=None, *, tn: int = 128,
                                 interpret: bool = False):
    """B independent whole-stream EvolveGCN runs in one pallas_call.

    Shapes (all widths zero-padded to the common dmax by kernels/ops.py):
      neigh_idx/neigh_coef (B, T, n, k); node_feat (B, T, n, dmax);
      node_mask (B, T, n); live (B, T) int32 — 1 where the snapshot is
      real, 0 on no-op tail padding; w0 (B, L, dmax, dmax) — each
      stream's primed evolving weights, entering and leaving the chip
      exactly once per stream; b_gcn (L, dmax); gru_wx/gru_wh
      (L, dmax, 3*dmax) and gru_b (L, 3*dmax), padded per gate block;
      edge_agg (B, T, L, n, dmax) — per-layer pre-aggregated
      edge-message term sum_k coef * (edge_feat @ w_edge_l)[eidx], or
      None for edge-free configs (a tiny pinned dummy block is streamed
      instead of a full zero tensor, mirroring the sibling kernels'
      static has_edge specialization).

    Returns (per-step outputs (B, T, n, dmax), final weights
    (B, L, dmax, dmax)).
    """
    B, T, n, k = neigh_idx.shape
    L, dmax = w0.shape[1], w0.shape[2]
    assert n % tn == 0
    grid = (B, T, L, n // tn)
    tile = lambda bi, t, l, j: (bi, t, j, 0)
    step = lambda bi, t, l, j: (bi, t, 0, 0)
    row = lambda bi, t, l, j: (bi, t, j)
    flag = lambda bi, t, l, j: (bi, t)
    layer4 = lambda bi, t, l, j: (bi, l, 0, 0)
    layer_res3 = lambda bi, t, l, j: (l, 0, 0)
    layer_res2 = lambda bi, t, l, j: (l, 0)
    has_edge = edge_agg is not None
    if has_edge:
        eagg_map = lambda bi, t, l, j: (bi, t, l, j, 0)
    else:
        # one pinned (revisited) dummy block instead of (B,T,L,n,dmax)
        # of streamed zeros; the kernel never reads it.
        edge_agg = jnp.zeros((1, 1, 1, tn, dmax), node_feat.dtype)
        eagg_map = lambda bi, t, l, j: (0, 0, 0, 0, 0)
    return pl.pallas_call(
        functools.partial(_evolve_stream_kernel, has_edge),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tn, k), tile),          # neigh_idx (local)
            pl.BlockSpec((1, 1, tn, k), tile),          # neigh_coef
            pl.BlockSpec((1, 1, n, dmax), step),        # node_feat, per (b, t)
            pl.BlockSpec((1, 1, tn), row),              # node_mask
            pl.BlockSpec((1, 1), flag),                 # live flag, per (b, t)
            pl.BlockSpec((1, 1, dmax, dmax), layer4),   # W0, per (stream, l)
            pl.BlockSpec((1, dmax), layer_res2),        # GCN bias, per l
            pl.BlockSpec((1, 1, 1, tn, dmax), eagg_map),  # edge agg, per (b,t,l)
            pl.BlockSpec((1, dmax, 3 * dmax), layer_res3),  # GRU wx, per l
            pl.BlockSpec((1, dmax, 3 * dmax), layer_res3),  # GRU wh, per l
            pl.BlockSpec((1, 3 * dmax), layer_res2),        # GRU b, per l
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tn, dmax), tile),       # per-step outputs
            pl.BlockSpec((1, 1, dmax, dmax), layer4),   # final weights
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, n, dmax), node_feat.dtype),
            jax.ShapeDtypeStruct((B, L, dmax, dmax), w0.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((L, dmax, dmax), w0.dtype),   # resident evolving W_l
            pltpu.VMEM((n, dmax), node_feat.dtype),  # activation ping
            pltpu.VMEM((n, dmax), node_feat.dtype),  # activation pong
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",) * 4),
        interpret=interpret,
    )(neigh_idx, neigh_coef, node_feat, node_mask, live,
      w0, b_gcn, edge_agg, gru_wx, gru_wh, gru_b)


def evolve_stream_pallas(neigh_idx, neigh_coef, node_feat, node_mask, live,
                         w0, b_gcn, gru_wx, gru_wh, gru_b, edge_agg=None, *,
                         tn: int = 128, interpret: bool = False):
    """Whole-stream EvolveGCN: the B=1 case of the batched kernel."""
    ea = None if edge_agg is None else edge_agg[None]
    outs, wT = evolve_stream_batched_pallas(
        neigh_idx[None], neigh_coef[None], node_feat[None], node_mask[None],
        live[None], w0[None], b_gcn, gru_wx, gru_wh, gru_b, ea,
        tn=tn, interpret=interpret)
    return outs[0], wT[0]
