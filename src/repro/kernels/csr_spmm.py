"""ELL SpMM Pallas kernel — the MP (message passing) hot spot.

TPU adaptation of the paper's on-FPGA CSR message passing (DESIGN §2): the
whole snapshot's node features are VMEM-resident (the BRAM analogue — a
padded snapshot is a few hundred KB), fixed-width ELL rows replace CSR so
every grid step works on a rectangular (TN, K) tile, and Pallas' automatic
BlockSpec pipelining double-buffers the per-tile index/coef fetches against
compute — the hardware-managed version of the paper's GL/MP overlap.

The row gather `x[idx]` lowers to Mosaic's dynamic-gather on TPU; on other
backends the kernel runs in interpret mode (see ops.py). Tiles:
  grid = (N // TN,)
  idx/coef tiles (TN, K) stream per step; x stays resident (constant index
  map); out tile (TN, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(idx_ref, coef_ref, x_ref, out_ref):
    idx = idx_ref[...]            # (TN, K) int32
    coef = coef_ref[...]          # (TN, K) f32
    x = x_ref[...]                # (N, D) f32, VMEM-resident
    tn, k = idx.shape
    g = jnp.take(x, idx.reshape(-1), axis=0).reshape(tn, k, x.shape[1])
    out_ref[...] = (g * coef[..., None]).sum(axis=1)


def _spmm_edge_kernel(idx_ref, coef_ref, eidx_ref, x_ref, emsg_ref, out_ref):
    idx = idx_ref[...]
    coef = coef_ref[...]
    eidx = eidx_ref[...]
    x = x_ref[...]
    em = emsg_ref[...]            # (E, D) projected edge messages
    tn, k = idx.shape
    g = jnp.take(x, idx.reshape(-1), axis=0).reshape(tn, k, x.shape[1])
    ge = jnp.take(em, eidx.reshape(-1), axis=0).reshape(tn, k, x.shape[1])
    out_ref[...] = ((g + ge) * coef[..., None]).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def ell_spmm_pallas(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg=None, *,
                    tn: int = 128, interpret: bool = False):
    n, k = neigh_idx.shape
    d = x.shape[1]
    assert n % tn == 0, (n, tn)
    grid = (n // tn,)
    row_tile = lambda i: (i, 0)
    resident = lambda i: (0, 0)
    out_shape = jax.ShapeDtypeStruct((n, d), x.dtype)
    if edge_msg is None:
        return pl.pallas_call(
            _spmm_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tn, k), row_tile),
                pl.BlockSpec((tn, k), row_tile),
                pl.BlockSpec((n, d), resident),
            ],
            out_specs=pl.BlockSpec((tn, d), row_tile),
            out_shape=out_shape,
            interpret=interpret,
        )(neigh_idx, neigh_coef, x)
    e = edge_msg.shape[0]
    return pl.pallas_call(
        _spmm_edge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, k), row_tile),
            pl.BlockSpec((tn, k), row_tile),
            pl.BlockSpec((tn, k), row_tile),
            pl.BlockSpec((n, d), resident),
            pl.BlockSpec((e, d), resident),
        ],
        out_specs=pl.BlockSpec((tn, d), row_tile),
        out_shape=out_shape,
        interpret=interpret,
    )(neigh_idx, neigh_coef, neigh_eidx, x, edge_msg)
