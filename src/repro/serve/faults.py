"""Deterministic fault injection + typed serve-boundary errors.

The serve engine's fault-isolation contract (docs/serve_robustness.md) is
only trustworthy if every fault site can be driven on demand, so the
chaos tests pin it. This module provides:

  * :class:`FaultPlan` / :class:`FaultSpec` — a seeded, site-addressable
    description of which serve-path operations fail, threaded through the
    typed ``repro.api.StreamPlan`` (``fault_plan=``). Sites mirror the
    engine's failure surface:

      ``preprocess``  host-side snapshot preprocessing (producer thread)
      ``bucket``      bucket selection (no-fit / mis-sized snapshots)
      ``launch``      the stream-kernel launch itself (fired INSIDE the
                      traced program via the ``kernels/ops`` fault hook,
                      so it hits the real dispatch layer, not a serve-side
                      mock)
      ``evolve``      the post-launch state-commit phase (the site whose
                      recovery must prove rollback: a replayed chunk may
                      never double-evolve recurrent state)

  * :class:`FaultInjector` — the mutable runtime counterpart: counts
    matching probes per spec and raises :class:`InjectedFault` (or sleeps,
    for deadline tests) when a spec's occurrence window is hit. Given the
    same probe sequence the same faults fire — determinism comes from
    occurrence counting, not wall clocks; ``seed`` exists so chaos
    harnesses can derive reproducible RANDOM placements (tenant/site
    choices) before building the plan.

  * typed serve-boundary errors: :class:`SnapshotValidationError` (and
    :func:`validate_snapshot`, the serve-boundary input gate) and
    :class:`LaunchTimeout` (deadline exceeded on a stream launch).

Nothing here imports the engine — the engine imports this, so fault
machinery stays usable from tests and benchmarks without a server.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

FAULT_SITES = ("preprocess", "bucket", "launch", "evolve")
FAULT_SCOPES = ("any", "batched", "kernel")


class ServeFault(RuntimeError):
    """Base of the serve engine's typed fault exceptions. ``tenant`` is
    the addressed tenant id (None = unattributable) — the supervisor uses
    it to quarantine the failed member instead of the whole batch."""

    def __init__(self, message: str, *, tenant=None, site: Optional[str] = None):
        super().__init__(message)
        self.tenant = tenant
        self.site = site


class InjectedFault(ServeFault):
    """A fault fired by a :class:`FaultInjector` (chaos testing only)."""


class LaunchTimeout(ServeFault):
    """A stream-kernel launch exceeded the plan's ``launch_timeout_ms``.

    JAX launches cannot be cancelled mid-flight, so the deadline is
    enforced on completion: the overdue result is DISCARDED (never
    committed to tenant state) and the launch is treated as failed —
    retried, degraded, or quarantined per the plan's recovery policy.
    """


class SnapshotValidationError(ServeFault, ValueError):
    """A snapshot rejected at the serve boundary (malformed input), with
    the offending tenant attached so supervision can quarantine it."""


def validate_snapshot(snap, n_global: int, tenant=None) -> None:
    """Serve-boundary input gate: reject malformed COO snapshots BEFORE
    they reach ``renumber_and_normalize``/``to_ell``/the kernel, where
    negative or out-of-range ids would silently scatter-drop or gather
    garbage. Raises :class:`SnapshotValidationError` carrying the tenant.

    Checks: src/dst shape agreement, negative node ids, ids >= n_global
    (the global feature-table / state-store row count), non-finite edge
    features.
    """
    src = np.asarray(snap.src)
    dst = np.asarray(snap.dst)
    ef = np.asarray(snap.edge_feat)

    def bad(reason):
        who = "" if tenant is None else f"tenant {tenant!r}: "
        raise SnapshotValidationError(
            f"{who}snapshot t={getattr(snap, 't_index', '?')} rejected: "
            f"{reason}", tenant=tenant, site="preprocess")

    if src.shape != dst.shape or src.ndim != 1:
        bad(f"src/dst shape mismatch {src.shape} vs {dst.shape}")
    if ef.shape[0] != src.shape[0]:
        bad(f"edge_feat has {ef.shape[0]} rows for {src.shape[0]} edges")
    if src.size:
        lo = int(min(src.min(), dst.min()))
        hi = int(max(src.max(), dst.max()))
        if lo < 0:
            bad(f"negative node id {lo}")
        if hi >= n_global:
            bad(f"node id {hi} out of range (n_global={n_global})")
    if ef.size and not np.isfinite(ef).all():
        bad("non-finite edge features")


@dataclass(frozen=True)
class FaultSpec:
    """One addressable fault: fires at ``site`` on the ``index``-th
    matching probe (per-spec occurrence counter) and for ``count``
    consecutive matching probes after it — so a transient fault
    (``count=1``) is survived by one retry while a persistent one
    (``count`` large) exhausts retries and exercises quarantine or the
    degradation ladder.

    ``tenant`` addresses the fault (None = untargeted: matches any probe
    and is unattributable, so the supervisor cannot quarantine a single
    member for it). ``scope`` narrows launch-site probes: ``"batched"``
    fires only on launches carrying more than one live tenant (the ladder
    then recovers via solo launches), ``"kernel"`` only on non-force-ref
    launches (the ladder then recovers via the XLA oracle rung).
    ``delay_ms > 0`` sleeps instead of raising — the deadline-test knob
    for ``launch_timeout_ms``.
    """

    site: str
    tenant: Optional[str] = None
    index: int = 0
    count: int = 1
    scope: str = "any"
    delay_ms: float = 0.0
    message: str = "injected fault"

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"sites: {FAULT_SITES}")
        if self.scope not in FAULT_SCOPES:
            raise ValueError(f"unknown fault scope {self.scope!r}; "
                             f"scopes: {FAULT_SCOPES}")
        if self.scope != "any" and self.site != "launch":
            raise ValueError(f"scope {self.scope!r} only narrows 'launch' "
                             f"probes; site is {self.site!r}")
        if not (isinstance(self.index, int) and self.index >= 0):
            raise ValueError(f"index={self.index!r}: need an int >= 0")
        if not (isinstance(self.count, int) and self.count >= 1):
            raise ValueError(f"count={self.count!r}: need an int >= 1")
        if not self.delay_ms >= 0:
            raise ValueError(f"delay_ms={self.delay_ms!r}: need >= 0")


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic chaos schedule: a tuple of :class:`FaultSpec` plus
    the seed the placements were drawn from (recorded so a failing chaos
    run is reproducible from its plan alone). Frozen like the StreamPlan
    that carries it; build the runtime counter state with
    :meth:`injector`."""

    specs: tuple = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))
        for s in self.specs:
            if not isinstance(s, FaultSpec):
                raise ValueError(f"FaultPlan.specs needs FaultSpecs; got "
                                 f"{s!r}")
        if not isinstance(self.seed, int):
            raise ValueError(f"seed={self.seed!r}: need an int")

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)

    def sites(self) -> frozenset:
        return frozenset(s.site for s in self.specs)


@dataclass
class FaultInjector:
    """Runtime occurrence counters for one :class:`FaultPlan`.

    ``probe(site, ...)`` is called by the serve engine at each fault site;
    every spec that matches the probe's (site, tenants, scope) coordinates
    advances its own counter, and a counter inside ``[index, index+count)``
    fires the fault. The last fired fault is stashed so the supervisor can
    attribute an exception that crossed the XLA callback boundary (where
    the original ``InjectedFault`` is wrapped) via :meth:`take_fired`.
    """

    plan: FaultPlan
    _counts: list = field(default_factory=list)
    _fired: Optional[InjectedFault] = None

    def __post_init__(self):
        import threading

        self._counts = [0] * len(self.plan.specs)
        # probes arrive from concurrent producer threads AND the device
        # loop; occurrence counting must not race
        self._lock = threading.Lock()

    def rng(self) -> np.random.Generator:
        """Seeded generator for random (but reproducible) placements."""
        return np.random.default_rng(self.plan.seed)

    def _matches(self, spec: FaultSpec, site, tenants, n_live, force_ref):
        if spec.site != site:
            return False
        if spec.tenant is not None and spec.tenant not in tenants:
            return False
        if spec.scope == "batched" and not (n_live is not None and n_live > 1):
            return False
        if spec.scope == "kernel" and force_ref:
            return False
        return True

    def probe(self, site: str, tenants=(), n_live=None, force_ref=False):
        """Advance every matching spec's counter; fire the first spec whose
        occurrence window is hit. Delay specs sleep (deadline injection)
        instead of raising; at most one fault is raised per probe."""
        import time

        to_raise = None
        delay_s = 0.0
        with self._lock:
            for i, spec in enumerate(self.plan.specs):
                if not self._matches(spec, site, tenants, n_live, force_ref):
                    continue
                n = self._counts[i]
                self._counts[i] += 1
                if not (spec.index <= n < spec.index + spec.count):
                    continue
                if spec.delay_ms > 0:
                    delay_s += spec.delay_ms / 1e3
                    continue
                if to_raise is None:
                    to_raise = InjectedFault(
                        f"{spec.message} (site={site}, "
                        f"tenant={spec.tenant!r}, occurrence {n})",
                        tenant=spec.tenant, site=site)
            if to_raise is not None:
                self._fired = to_raise
        if delay_s > 0:
            time.sleep(delay_s)  # deadline injection (outside the lock)
        if to_raise is not None:
            raise to_raise

    def take_fired(self) -> Optional[InjectedFault]:
        """Pop the last fault this injector raised (attribution across the
        XLA callback boundary, where exception types are rewrapped)."""
        fired, self._fired = self._fired, None
        return fired
