from repro.serve.engine import ServeStats, SnapshotServer
from repro.serve.lm_serve import generate, make_serve_step

__all__ = ["ServeStats", "SnapshotServer", "generate", "make_serve_step"]
