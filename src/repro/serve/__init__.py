from repro.serve.engine import ServeStats, SnapshotServer
from repro.serve.faults import (
    FAULT_SCOPES,
    FAULT_SITES,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    LaunchTimeout,
    ServeFault,
    SnapshotValidationError,
    validate_snapshot,
)
from repro.serve.lm_serve import generate, make_serve_step
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.state_pool import PoolOverflow, TenantStatePool
from repro.serve.supervision import (
    SupervisionPolicy,
    TenantResult,
    TenantSupervisor,
)

__all__ = [
    "ContinuousScheduler",
    "FAULT_SCOPES",
    "FAULT_SITES",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "LaunchTimeout",
    "PoolOverflow",
    "ServeFault",
    "ServeStats",
    "SnapshotServer",
    "SnapshotValidationError",
    "SupervisionPolicy",
    "TenantResult",
    "TenantStatePool",
    "TenantSupervisor",
    "generate",
    "make_serve_step",
    "validate_snapshot",
]
