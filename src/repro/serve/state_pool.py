"""Paged pool of per-tenant recurrent state for the continuous scheduler.

The multi-tenant serve engine keeps one recurrent-state store per tenant
(h/c node states, or EvolveGCN's evolving weight matrices). With millions
of tenants those stores cannot all stay device-resident: the pool bounds
how many are (``plan.state_pool_pages`` pages, one tenant's full state
per page — the vLLM block-table idea at tenant granularity, which is the
granularity the stream kernel loads state at), spills the least-recently
-scheduled tenants to host memory, and transparently restores a spilled
tenant the next time the scheduler composes it into a launch.

Eviction reuses the supervision checkpoint machinery
(``TenantSupervisor.evict_to_host`` / ``recover_from_host``): a spill is
the same reference checkpoint a chunk launch takes, materialized on the
host; recovery re-uploads it. f32 state round-trips the host copy
bit-for-bit, so a tenant that was evicted and recovered mid-stream serves
outputs identical to one that stayed resident — the differential tests
pin exactly that.

The pool owns the BLOCK TABLE: ``sid -> "device" | "host"``. The device
side is the engine's ordinary ``states`` dict (the launch path is
unchanged — ``_stage_group`` still reads ``states[sid]``); the host side
is ``self.host_pages``. ``acquire`` is the only way states move, so a
checkpoint taken for an in-flight launch can never be evicted under it:
the scheduler acquires the tick's working set BEFORE the supervised
launch, and eviction only ever picks tenants OUTSIDE the set being
acquired.
"""
from __future__ import annotations

from collections import OrderedDict

from repro.serve.supervision import TenantSupervisor

# capacity multiplier for HBM-paged tenants: with
# ``plan.state_residency="hbm_paged"`` the stream kernel keeps each
# tenant's recurrent store in HBM (only a (n_global, td) staging window
# transits VMEM), so a "device page" is an HBM allocation — orders of
# magnitude roomier than the VMEM-resident budget ``state_pool_pages``
# was sized against. The pool lifts its effective capacity by this
# factor rather than asking every caller to re-derive a page budget.
HBM_PAGE_FACTOR = 8


class PoolOverflow(RuntimeError):
    """A working set larger than the pool was requested."""


class TenantStatePool:
    """Fixed-capacity paging of the per-tenant recurrent-state dict.

    ``states`` is the engine's device-resident state dict (mutated in
    place); ``pages=None`` disables eviction (every tenant stays
    resident — the pool is then pure bookkeeping). ``residency`` is the
    plan's ``state_residency``: ``"hbm_paged"`` tenants' device pages are
    HBM pages, so the pool's effective capacity is
    ``pages * HBM_PAGE_FACTOR`` (``capacity``); the nominal ``pages``
    budget is kept for stats/reporting.
    """

    def __init__(self, states: dict, pages: int | None,
                 supervisor: TenantSupervisor, residency: str = "vmem"):
        if pages is not None and pages < 1:
            raise ValueError(f"pages={pages!r}: need >= 1 or None")
        self.states = states
        self.pages = pages
        self.residency = residency
        self.capacity = (None if pages is None
                         else pages * HBM_PAGE_FACTOR
                         if residency == "hbm_paged" else pages)
        self.sup = supervisor
        self.host_pages: dict = {}
        # LRU order over RESIDENT tenants (oldest first)
        self._lru: OrderedDict = OrderedDict(
            (sid, None) for sid in sorted(states, key=repr))
        if self.capacity is not None and len(states) > self.capacity:
            # over-committed from the start: spill down to capacity before
            # the first tick (arbitrary-but-deterministic victim order)
            for sid in list(self._lru):
                if len(self._lru) <= self.capacity:
                    break
                self._evict(sid)

    # ---------------------------------------------------------- queries ----

    @property
    def resident(self) -> set:
        return set(self._lru)

    def location(self, sid) -> str:
        """Block-table lookup: 'device' or 'host'."""
        if sid in self._lru:
            return "device"
        if sid in self.host_pages:
            return "host"
        raise KeyError(f"tenant {sid!r} is not in the pool")

    # ---------------------------------------------------------- paging ----

    def _evict(self, sid) -> None:
        self.host_pages[sid] = self.sup.evict_to_host(self.states, sid)
        del self._lru[sid]

    def _recover(self, sid) -> None:
        self.sup.recover_from_host(self.states, sid,
                                   self.host_pages.pop(sid))
        self._lru[sid] = None

    def acquire(self, sids) -> None:
        """Make every tenant in ``sids`` device-resident (recovering host
        pages), evicting least-recently-scheduled tenants OUTSIDE the set
        as needed, and mark the set most-recently used. Raises
        :class:`PoolOverflow` if the set alone exceeds the pool — the
        scheduler bounds its tick working set to the pool size, so hitting
        this means a scheduler bug, not load."""
        working = list(dict.fromkeys(sids))
        if self.capacity is not None and len(working) > self.capacity:
            raise PoolOverflow(
                f"working set of {len(working)} tenants exceeds the "
                f"{self.capacity}-page state pool")
        for sid in working:
            if sid not in self._lru:
                if self.capacity is not None:
                    keep = set(working)
                    while len(self._lru) >= self.capacity:
                        victim = next(s for s in self._lru if s not in keep)
                        self._evict(victim)
                self._recover(sid)
        for sid in working:  # MRU update
            self._lru.move_to_end(sid)

    def flush(self) -> None:
        """Restore every host page to the device-resident dict (end of the
        serve run: the engine returns the full ``states`` dict, wherever
        each tenant's pages lived mid-run). Recovery counters move with
        it, so forced end-of-run restores stay visible in the stats."""
        for sid in list(self.host_pages):
            self._recover(sid)
