"""Continuous-batching device loop for the multi-tenant serve engine.

The round-based ``run_multi`` loop has a BARRIER between rounds: every
active tenant must deliver its next chunk (or EOF) before any launch
happens, so one tenant with a long snapshot backlog — a client replaying
history, a reconnect after downtime — stalls every incremental tenant
behind its full prefill, and a tenant whose producer is slow stalls the
round outright.

This module replaces the barrier with ITERATION-LEVEL scheduling (the
vLLM/sglang continuous-batching idea, at snapshot-stream granularity):

  tick loop    Each engine tick composes a fresh ragged StreamPlan batch
               from whatever snapshots are READY — no waiting for
               stragglers; a tenant joins a launch with a 1-snapshot chunk
               if that is all it has. Chunk-boundary invariance makes this
               safe: serving a stream in chunks of ANY lengths is
               bit-identical to any other chunking (pinned by the
               differential tests), so tick composition is a pure
               scheduling decision, never a numerics one.

  chunked      A backlogged tenant (more than ``stream_chunk`` snapshots
  prefill      queued) is served at most ``plan.prefill_chunk`` snapshots
               per tick instead of a full chunk, so its backlog drains
               INTERLEAVED with other tenants' incremental steps rather
               than monopolizing launches — bounded per-tick share, lower
               p99 for everyone else.

  paged state  Per-tenant recurrent state lives in a fixed-size paged pool
  pool         (``plan.state_pool_pages`` device-resident tenants,
               serve/state_pool.TenantStatePool). The tick working set is
               capped at the pool size; least-recently-scheduled tenants
               outside it are spilled to host via the supervisor's
               checkpoint machinery and transparently recovered when next
               scheduled — f32 round-trips bit-exactly, so eviction is
               invisible in the outputs.

  fairness     Ready tenants are served least-recently-scheduled first, so
               under pool pressure the working set round-robins instead of
               starving whoever sorts last.

Everything below the tick — bucketing, promotion, the supervised
stage/commit launch with checkpoint/rollback, retries, quarantine, the
degradation ladder — is the SAME engine code the round loop uses
(``SnapshotServer._run_group_supervised`` and friends), so the fault
contract of docs/serve_robustness.md holds unchanged under this
scheduler; the chaos lane pins it.
"""
from __future__ import annotations

import queue
import time
from collections import deque

from repro.graph.padding import promote_bucket_groups
from repro.serve.state_pool import TenantStatePool
from repro.serve.supervision import TenantSupervisor

# idle backoff while every backlog is empty but producers are still
# running (host prep slower than the device loop)
_IDLE_SLEEP_S = 5e-4


class ContinuousScheduler:
    """One continuous-batching serve run over a ``SnapshotServer``.

    Stateless between runs — ``SnapshotServer.run_multi`` constructs one
    per call when ``plan.scheduler == "continuous"``.
    """

    def __init__(self, server):
        self.srv = server

    # ---------------------------------------------------------- admission ----

    def _admit(self, qs, backlog, eof, active, sup: TenantSupervisor) -> None:
        """Drain every active producer queue non-blocking into the
        per-tenant backlogs. EOF marks the tenant draining; a producer
        exception (validation, no-fit bucket, injected fault) quarantines
        the tenant per policy and discards its backlog. Items from
        already-quarantined tenants are never admitted."""
        for sid in sorted(active):
            if sid in eof:
                continue
            while True:
                try:
                    item = qs[sid].get_nowait()
                except queue.Empty:
                    break
                if item is None:
                    eof.add(sid)
                    break
                if isinstance(item, BaseException):
                    eof.add(sid)
                    backlog[sid].clear()
                    sup.quarantine(sid, item,
                                   site=getattr(item, "site", None))
                    break
                backlog[sid].append(item)

    # --------------------------------------------------------------- run ----

    def run(self, params, states: dict, streams: dict, *,
            express_streams=None, express_params=None) -> tuple:
        """Serve ``streams`` to completion; same contract and return shape
        as the round-based ``run_multi`` (and bit-identical outputs/final
        states per tenant).

        Express tenants (``express_streams``, static family — see
        ``run_multi``) join the SAME tick loop: their backlogs admit and
        drain like everyone else's, but they bypass the state-pool
        working-set cap (stateless tenants hold no pages) and each tick's
        ready express slots co-batch into one dedicated stateless launch.
        """
        srv = self.srv
        if not srv._use_stream_batched():
            raise ValueError("the continuous scheduler requires the v3 "
                             "stream engine (plan validation enforces this)")
        sids = sorted(streams)
        x_sids = sorted(express_streams or {})
        x_set = set(x_sids)
        t_start = time.perf_counter()
        srv._t0_run, srv._commit_ms = t_start, {}
        qs, pre_ms, stop, threads = srv._spawn_producers(streams)
        if x_sids:
            xqs, x_threads = srv._spawn_express_producers(
                express_streams, stop, pre_ms)
            qs = {**qs, **xqs}
            threads = threads + x_threads
        outs: dict = {sid: [] for sid in sids + x_sids}
        lat: list = []
        ctr = {"live": 0, "padded": 0, "promoted": 0, "launches": 0,
               "timeouts": 0, "degraded": 0, "ticks": 0, "prefill": 0}
        sup = TenantSupervisor(sids + x_sids, srv._policy, outputs=outs)
        pool = TenantStatePool(states, srv.state_pool_pages, sup,
                               residency=srv.plan.state_residency)
        backlog: dict = {sid: deque() for sid in sids + x_sids}
        eof: set = set()
        last_tick = {sid: 0 for sid in sids + x_sids}
        active = set(sids) | x_set
        tick_no = 0
        try:
            with srv._fault_window():
                while active:
                    self._admit(qs, backlog, eof, active, sup)
                    for sid in list(active):
                        if not sup.ok(sid):
                            backlog[sid].clear()
                            active.discard(sid)
                        elif sid in eof and not backlog[sid]:
                            active.discard(sid)  # stream fully served
                    ready = [sid for sid in active if backlog[sid]]
                    if not ready:
                        if active:
                            time.sleep(_IDLE_SLEEP_S)
                        continue
                    # fairness under pool pressure: least-recently-
                    # scheduled first. Only RECURRENT tenants compete for
                    # the state-pool working set — stateless express
                    # tenants hold no pages, so they bypass the cap and
                    # ride every tick they have slots ready.
                    ready.sort(key=lambda s: (last_tick[s], repr(s)))
                    x_ready = [s for s in ready if s in x_set]
                    ready = [s for s in ready if s not in x_set]
                    if pool.capacity is not None:
                        # EFFECTIVE capacity: hbm_paged plans hold
                        # HBM_PAGE_FACTOR× more resident tenants per
                        # nominal page (see state_pool.TenantStatePool)
                        ready = ready[:pool.capacity]
                    tick_no += 1
                    ctr["ticks"] += 1
                    x_group: list = []
                    for sid in x_ready:
                        chunk: list = []
                        while backlog[sid] and len(chunk) < srv.stream_chunk:
                            ps, _ = backlog[sid].popleft()
                            chunk.append(ps)
                        x_group.append((sid, chunk))
                        last_tick[sid] = tick_no
                    if x_group:
                        srv._run_express_group(express_params, x_group,
                                               outs, lat, ctr, sup)
                    chunks: dict = {}
                    for sid in ready:
                        prefill = (srv.prefill_chunk is not None
                                   and len(backlog[sid]) > srv.stream_chunk)
                        quota = (srv.prefill_chunk if prefill
                                 else srv.stream_chunk)
                        chunk: list = []
                        dims: list = []
                        while backlog[sid] and len(chunk) < quota:
                            ls, d = backlog[sid].popleft()
                            chunk.append(ls)
                            dims.append(d)
                        chunks[sid] = (chunk, dims)
                        if prefill:
                            ctr["prefill"] += 1
                        last_tick[sid] = tick_no
                    # page the tick's working set in BEFORE any checkpoint
                    # is taken; evicts LRU tenants outside the set
                    pool.acquire(list(chunks))
                    groups: dict = {}
                    for sid, (chunk, dims) in sorted(chunks.items()):
                        bucket = srv._chunk_bucket(dims)
                        groups.setdefault(bucket, []).append(
                            (sid, chunk, bucket))
                    if (srv.promote_buckets is not None
                            and srv.buckets is not None):
                        before = {b: len(m) for b, m in groups.items()}
                        groups = promote_bucket_groups(
                            groups, srv.buckets, srv.promote_buckets,
                            cost=srv._promotion_cost(params))
                        ctr["promoted"] += sum(
                            len(m) - before.get(b, 0)
                            for b, m in groups.items())
                    for bucket in sorted(groups):
                        srv._run_group_supervised(params, states,
                                                  groups[bucket], outs,
                                                  lat, ctr, sup)
        finally:
            # every tenant's state returns device-resident, wherever its
            # pages lived mid-run; then deterministic producer shutdown
            pool.flush()
            srv._shutdown(stop, list(qs.values()), threads)
        total = (time.perf_counter() - t_start) * 1e3
        return states, outs, srv._make_stats(lat, pre_ms, total, ctr, sup)
