"""LM serving: batched greedy decode against KV caches / SSM states."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import RuntimeConfig, decode_step, init_caches


def make_serve_step(cfg: ModelConfig, rt: RuntimeConfig):
    """Returns jitted (params, tokens (B,1), caches) -> (next (B,1), caches)."""

    @functools.partial(jax.jit, donate_argnums=(2,))
    def step(params, tokens, caches):
        logits, caches = decode_step(params, cfg, rt, tokens, caches)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return nxt, caches

    return step


def generate(params, cfg: ModelConfig, rt: RuntimeConfig, prompt: jax.Array,
             steps: int, skv: int):
    """Greedy generation: feeds the prompt token by token, then samples.

    prompt: (B, P) int32. Returns (B, steps) int32.
    """
    b, plen = prompt.shape
    caches = init_caches(cfg, rt, b, skv)
    step = make_serve_step(cfg, rt)
    tok = prompt[:, :1]
    out = []
    for i in range(plen + steps - 1):
        nxt, caches = step(params, tok, caches)
        if i + 1 < plen:
            tok = prompt[:, i + 1 : i + 2]  # teacher-forced prompt phase
        else:
            tok = nxt
            out.append(nxt)
    return jnp.concatenate(out, axis=1) if out else jnp.zeros((b, 0), jnp.int32)
