"""Tenant supervision for the multi-tenant serve engine.

The engine's recovery contract (docs/serve_robustness.md): a per-tenant
failure anywhere on the serve path — malformed snapshot, no-fit bucket,
failed or overdue launch, mid-commit crash — quarantines THAT tenant and
the batch continues; recurrent state is checkpointed before every chunk
launch and rolled back on failure, so a replayed chunk can never
double-evolve state (the EvolveGCN regression class PR 3's harness pins).

This module owns the bookkeeping half of that contract:

  * :class:`TenantResult` — one tenant's outcome: served outputs, the
    quarantining error (None = healthy), and the recovery counters
    (retries, rollbacks, degraded launches).
  * :class:`SupervisionPolicy` — the plan-derived recovery knobs
    (``supervision``/``max_retries``/``retry_backoff_ms``/
    ``launch_timeout_ms``/``degrade``).
  * :class:`TenantSupervisor` — quarantine state + checkpoint/rollback of
    the per-tenant recurrent-state dict. JAX arrays are immutable, so a
    checkpoint is a dict of REFERENCES taken before the commit phase;
    rollback restores those references over whatever the interrupted
    commit managed to write.

The launch/degrade driver itself lives once in the engine
(``SnapshotServer._run_group_supervised``) — GenGNN's framing: recovery
machinery in the generic engine, not per model family.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Degradation ladder rungs, slowest-recovery last: the batched stream
# launch, a solo (B=1) stream launch per member, the pure-XLA oracle via
# the kernels/ops force-ref gate. Later rungs are slower but share no
# failure mode with the kernel path.
LADDER = ("batched", "solo", "oracle")


@dataclass
class TenantResult:
    """One tenant's serve outcome. ``outputs`` is the SAME list object the
    engine returns in its outputs dict, so partial results served before a
    quarantine stay visible. ``error is None`` means healthy."""

    sid: object
    outputs: list = field(default_factory=list)
    error: Optional[BaseException] = None
    failed_site: Optional[str] = None
    retries: int = 0
    rollbacks: int = 0
    degraded_launches: int = 0
    evictions: int = 0   # state pages spilled to host (continuous scheduler)
    recoveries: int = 0  # state pages restored from host

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclass(frozen=True)
class SupervisionPolicy:
    """Plan-derived recovery policy (see docs/api.md for field docs)."""

    isolate: bool = False          # quarantine per tenant vs raise (strict)
    max_retries: int = 0           # same-group retries before escalating
    backoff_ms: float = 10.0       # exponential backoff base
    timeout_ms: Optional[float] = None  # per-launch deadline (None = off)
    degrade: bool = False          # enable the solo/oracle ladder rungs

    @classmethod
    def from_plan(cls, plan) -> "SupervisionPolicy":
        return cls(isolate=plan.supervision == "isolate",
                   max_retries=plan.max_retries,
                   backoff_ms=plan.retry_backoff_ms,
                   timeout_ms=plan.launch_timeout_ms,
                   degrade=plan.degrade)

    def backoff_s(self, attempt: int) -> float:
        """Exponential backoff before retry ``attempt`` (1-based), in
        seconds."""
        return self.backoff_ms * (2 ** (attempt - 1)) / 1e3


class TenantSupervisor:
    """Quarantine + checkpoint/rollback bookkeeping for one serve run.

    One instance per ``run``/``run_multi`` call; the engine consults
    ``alive``/``ok`` to stop scheduling a quarantined tenant and folds the
    per-tenant counters into ``ServeStats`` at the end.
    """

    def __init__(self, sids, policy: SupervisionPolicy,
                 outputs: Optional[dict] = None):
        self.policy = policy
        self.results = {
            sid: TenantResult(sid, outputs=outputs[sid]
                              if outputs is not None else [])
            for sid in sids
        }

    # ------------------------------------------------------- queries ----

    def ok(self, sid) -> bool:
        return self.results[sid].ok

    def alive(self, sids) -> list:
        return [sid for sid in sids if self.results[sid].ok]

    @property
    def quarantined(self) -> dict:
        return {sid: r for sid, r in self.results.items() if not r.ok}

    # -------------------------------------------- checkpoint/rollback ----

    def checkpoint(self, states: dict, sids) -> dict:
        """Snapshot the recurrent state of ``sids`` before a chunk launch.
        JAX arrays are immutable, so holding the references is a complete
        copy-free checkpoint: any commit writes replace dict entries, they
        never mutate the checkpointed arrays."""
        return {sid: states[sid] for sid in sids}

    def rollback(self, states: dict, ckpt: dict) -> None:
        """Restore every checkpointed tenant's state (undoing whatever a
        failed commit wrote) and count the rollback per tenant — the
        retry will replay the chunk from exactly the pre-launch state, so
        recurrent state (h/c, evolving W) advances at most once per
        served snapshot."""
        for sid, state in ckpt.items():
            states[sid] = state
            self.results[sid].rollbacks += 1

    # -------------------------------------------- eviction / recovery ----
    #
    # Eviction is checkpointing under a different name: spilling a
    # tenant's recurrent state out of the device-resident pool takes the
    # SAME reference checkpoint a chunk launch takes, then materializes it
    # on the host; recovery re-uploads it bit-for-bit (f32 round-trips the
    # host copy exactly). The paged tenant-state pool
    # (serve/state_pool.TenantStatePool) drives these.

    def evict_to_host(self, states: dict, sid) -> dict:
        """Spill ``sid``'s recurrent state to a host-resident page: take
        the reference checkpoint, materialize it as numpy, and REMOVE the
        device entry. Returns the host page (a numpy pytree)."""
        ckpt = self.checkpoint(states, [sid])
        page = jax.tree.map(lambda a: np.asarray(a), ckpt[sid])
        del states[sid]
        self.results[sid].evictions += 1
        return page

    def recover_from_host(self, states: dict, sid, page) -> None:
        """Restore an evicted tenant's state from its host page (the
        inverse of :meth:`evict_to_host`; bit-identical round trip)."""
        states[sid] = jax.tree.map(jnp.asarray, page)
        self.results[sid].recoveries += 1

    # ------------------------------------------------------ recording ----

    def note_retry(self, sids, attempt: int, sleep: bool = True) -> None:
        """Count a retry for every member and apply exponential backoff."""
        for sid in sids:
            self.results[sid].retries += 1
        if sleep and self.policy.backoff_ms > 0:
            time.sleep(self.policy.backoff_s(attempt))

    def note_degraded(self, sid) -> None:
        self.results[sid].degraded_launches += 1

    def quarantine(self, sid, error: BaseException,
                   site: Optional[str] = None) -> None:
        """Mark ``sid`` failed. Under the strict policy the error is
        re-raised instead (fault isolation is opt-in: plan
        ``supervision="isolate"``)."""
        if not self.policy.isolate:
            raise error
        r = self.results[sid]
        if r.ok:  # first failure wins; later noise keeps the root cause
            r.error = error
            r.failed_site = site if site is not None else getattr(
                error, "site", None)

    # ---------------------------------------------------------- stats ----

    def totals(self) -> dict:
        """Aggregate counters for ServeStats."""
        rs = self.results.values()
        return {
            "retries": sum(r.retries for r in rs),
            "rollbacks": sum(r.rollbacks for r in rs),
            "degraded_launches": sum(r.degraded_launches for r in rs),
            "evictions": sum(r.evictions for r in rs),
            "recoveries": sum(r.recoveries for r in rs),
            "tenant_errors": {sid: r.error for sid, r in self.results.items()
                              if not r.ok},
        }
