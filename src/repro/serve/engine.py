"""DGNN snapshot-stream serving engine — the paper's deployment mode.

Implements the §IV-D task-scheduling scheme:
  host thread ("CPU tasks"): slice the temporal COO stream into snapshots,
    renumber + normalize, build ELL, pad into the bucket — irregular,
    control-heavy work;
  device loop ("FPGA tasks"): the jitted DGNN step (format-consuming dense
    compute) pulls prepared snapshots from a DOUBLE-BUFFERED queue, so
    graph loading overlaps inference (the paper's GL/GNN overlap, host
    edition — the in-graph edition is the V1 ping-pong carry).

Bucketed padding: with ``buckets`` set, each snapshot is padded into the
smallest bucket that fits (graph/padding.choose_bucket) instead of the
worst-case shape — small snapshots stop paying big-snapshot compute. The
jit cache holds one compiled step per bucket.

V3 fast path: when the engine runs the time-fused stream dataflow
(mode="v3" and the model exposes ``step_stream``), consecutive same-bucket
snapshots are batched into fixed-T chunks (tail padded with no-op empty
snapshots) and the WHOLE chunk is handed to the stream kernel in one
launch, so the recurrent state crosses HBM once per chunk, not per
snapshot.

Also hosts the batched-streams production mode: many independent dynamic
graphs served concurrently, streams sharded over (pod, data).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Iterable, Optional

import jax
import numpy as np

from repro.configs.dgnn import DGNNConfig
from repro.core.dataflow import build_model, stack_time
from repro.graph.coo import COOSnapshot
from repro.graph.csr import max_in_degree, renumber_and_normalize
from repro.graph.padding import (
    PaddedSnapshot,
    choose_bucket,
    empty_like_padded,
    pad_snapshot,
)


@dataclass
class ServeStats:
    per_snapshot_ms: list
    preprocess_ms: list
    total_ms: float

    @property
    def mean_latency_ms(self) -> float:
        return float(np.mean(self.per_snapshot_ms)) if self.per_snapshot_ms else 0.0


class SnapshotServer:
    """Streaming DGNN inference over a snapshot iterator."""

    def __init__(self, cfg: DGNNConfig, feat_table: np.ndarray,
                 n_global: int, mode: Optional[str] = None,
                 n_pad: int = 640, e_pad: int = 4096, k_max: int = 64,
                 queue_depth: int = 2,
                 buckets: Optional[tuple] = None,
                 stream_chunk: int = 8):
        self.cfg = cfg
        self.mode = mode or cfg.dataflow
        self.model = build_model(cfg, n_global=n_global)
        self.feat_table = feat_table
        self.n_pad, self.e_pad, self.k_max = n_pad, e_pad, k_max
        self.buckets = buckets  # ((n_pad, e_pad, k_max), ...) smallest-first
        self.stream_chunk = stream_chunk
        self.queue_depth = queue_depth  # 2 == ping-pong buffers
        self._step = jax.jit(
            lambda p, s, snap: self.model.step(p, s, snap, mode=self.mode))
        self._stream_step = jax.jit(
            lambda p, s, sT: self.model.step_stream(p, s, sT))

    def init(self, rng):
        params = self.model.init(rng)
        state = self.model.init_state(params, mode=self.mode)
        return params, state

    # ------------------------------------------------------ host thread ----

    def _preprocess(self, snap: COOSnapshot) -> PaddedSnapshot:
        # shapes must be static so the jitted step never recompiles (the
        # "snapshot fits in BRAM" contract; overflow = the bucket chooser
        # picked wrong and should raise). With ``buckets`` the shapes are
        # static PER BUCKET: one compiled step per bucket in the jit cache.
        ls = renumber_and_normalize(snap)
        if self.buckets is not None:
            n_pad, e_pad, k_max = choose_bucket(
                ls.n_nodes, ls.src.shape[0], max_in_degree(ls), self.buckets)
        else:
            n_pad, e_pad, k_max = self.n_pad, self.e_pad, self.k_max
        return pad_snapshot(ls, self.feat_table, n_pad, e_pad, k_max)

    # ------------------------------------------------------ device loop ----

    def _use_stream(self) -> bool:
        return self.mode == "v3" and hasattr(self.model, "step_stream")

    def _run_chunk(self, params, state, chunk: list, outs: list, lat: list):
        """Feed one same-bucket chunk to the time-fused stream kernel.

        Short flushes (tail of the stream, or a bucket change on a
        bucket-alternating stream) pad T up to the next power of two, not
        all the way to ``stream_chunk`` — at most 2× no-op steps while the
        jit cache stays bounded at log2(stream_chunk)+1 chunk lengths per
        bucket.
        """
        real = len(chunk)
        target = 1
        while target < real:
            target *= 2
        target = min(target, self.stream_chunk)
        while len(chunk) < target:  # no-op tail padding
            chunk.append(empty_like_padded(chunk[0]))
        t0 = time.perf_counter()
        state, out_T = self._stream_step(params, state, stack_time(chunk))
        jax.block_until_ready(out_T)
        dt = (time.perf_counter() - t0) * 1e3 / real
        out_np = np.asarray(out_T)
        for t in range(real):
            outs.append(out_np[t])
            lat.append(dt)
        return state

    def run(self, params, state, snaps: Iterable[COOSnapshot]) -> tuple:
        """Returns (final_state, outputs list, ServeStats)."""
        q: queue.Queue = queue.Queue(maxsize=self.queue_depth)
        pre_ms: list = []

        def producer():
            try:
                for s in snaps:
                    t0 = time.perf_counter()
                    ps = self._preprocess(s)
                    pre_ms.append((time.perf_counter() - t0) * 1e3)
                    q.put(ps)
                q.put(None)
            except BaseException as exc:  # propagate, don't hang the consumer
                q.put(exc)

        th = threading.Thread(target=producer, daemon=True)
        t_start = time.perf_counter()
        th.start()
        outs, lat = [], []
        use_stream = self._use_stream()
        chunk: list = []
        while True:
            ps = q.get()
            if ps is None:
                break
            if isinstance(ps, BaseException):
                th.join()
                raise ps  # e.g. choose_bucket: no bucket fits the snapshot
            if not use_stream:
                t0 = time.perf_counter()
                state, out = self._step(params, state, ps)
                jax.block_until_ready(out)
                lat.append((time.perf_counter() - t0) * 1e3)
                outs.append(np.asarray(out))
                continue
            # v3: gather same-bucket runs into fixed-T chunks
            bucket = (ps.n_pad, ps.e_pad, ps.k_max)
            if chunk and (chunk[0].n_pad, chunk[0].e_pad, chunk[0].k_max) != bucket:
                state = self._run_chunk(params, state, chunk, outs, lat)
                chunk = []
            chunk.append(ps)
            if len(chunk) == self.stream_chunk:
                state = self._run_chunk(params, state, chunk, outs, lat)
                chunk = []
        if chunk:
            state = self._run_chunk(params, state, chunk, outs, lat)
        th.join()
        total = (time.perf_counter() - t_start) * 1e3
        return state, outs, ServeStats(lat, pre_ms, total)
