"""DGNN snapshot-stream serving engine — the paper's deployment mode.

Implements the §IV-D task-scheduling scheme:
  host thread ("CPU tasks"): slice the temporal COO stream into snapshots,
    renumber + normalize, build ELL, pad into the bucket — irregular,
    control-heavy work;
  device loop ("FPGA tasks"): the jitted DGNN step (format-consuming dense
    compute) pulls prepared snapshots from a DOUBLE-BUFFERED queue, so
    graph loading overlaps inference (the paper's GL/GNN overlap, host
    edition — the in-graph edition is the V1 ping-pong carry).

Bucketed padding: with ``buckets`` set, each snapshot is padded into the
smallest bucket that fits (graph/padding.choose_bucket) instead of the
worst-case shape — small snapshots stop paying big-snapshot compute. The
jit cache holds one compiled step per bucket.

V3 fast path: when the engine runs the time-fused stream dataflow
(plan level "v3" — the stream-engine families), consecutive same-bucket
snapshots are batched into fixed-T chunks (tail padded with no-op empty
snapshots) and the WHOLE chunk is handed to the stream kernel in one
launch, so the recurrent state crosses HBM once per chunk, not per
snapshot.

Multi-tenant batched serving (``run_multi``): many independent clients'
snapshot streams served concurrently. Each client stream gets its own
host preprocessing thread and its own recurrent state store; the device
loop proceeds in rounds, co-buckets each stream's next chunk
(choose_bucket_batch), groups same-bucket chunks across clients, and
hands each group to ONE batched V3 launch — the batch axis is a leading
grid dimension of the stream kernel, so B streams cost one kernel launch
and one weight load while every stream's state store still crosses HBM
exactly twice per chunk. Per-stream outputs are returned in per-stream
order (rounds are sequential and each stream's snapshots are consumed in
order). All three DGNN families take this batched launch through the SAME
stream-engine kernel (kernels/stream_fused.REGISTRY — the model's
``stream_family`` selects its cell spec): GCRN and stacked models keep
their node-state store resident, EvolveGCN its evolving weight matrices
(the in-kernel evolution is live-gated, so the no-op tail snapshots
padding a chunk never advance the weights).

Cross-bucket batching (``promote_buckets``): with bucketed padding, a
round's smaller-bucket chunks may be PROMOTED into the next-larger
occupied bucket — re-padded to the bigger shape so they join that
bucket's in-flight batched launch — trading padding overhead (guarded by
a max padded-compute ratio, graph/padding.promote_bucket_groups) for one
fewer device dispatch per round. The guard compares per-bucket costs:
the static ``bucket_cost`` padded-compute proxy by default, or — with
``promotion_guard="measured"`` in the plan — per-bucket step times from a
tiny warmup calibration (one timed launch per bucket, static proxy kept
as the fallback). ServeStats reports live vs padded snapshot slots and
launch counts per run so the overhead stays visible instead of hiding in
throughput.

Configuration is a typed ``repro.api.StreamPlan`` — the server is a
consumer of a ``BoosterSession`` (``SnapshotServer(session=...)``, or the
historical keyword surface, which builds the equivalent plan/session).
Chunk tails and batch-padding rows are expressed through the plan's
ragged-``lengths`` capability: every batched launch carries the true
per-stream lengths and the engine masks the dead slots in-launch, so the
host never manufactures empty tail snapshots.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dgnn import DGNNConfig
from repro.core.dataflow import stack_time
from repro.graph.coo import COOSnapshot
from repro.graph.csr import max_in_degree, renumber_and_normalize
from repro.graph.padding import (
    PaddedSnapshot,
    bucket_cost,
    choose_bucket,
    choose_bucket_batch,
    empty_padded,
    pad_snapshot,
    pow2_target,
    promote_bucket_groups,
    stack_streams,
)


@dataclass
class ServeStats:
    per_snapshot_ms: list
    preprocess_ms: list
    total_ms: float
    # no-op-tail waste signal: how many snapshot slots of the batched V3
    # launches were real vs padding (T tails + no-op batch rows), so
    # promoted-bucket and D-blocked rows expose their padding overhead
    # instead of hiding it in throughput.
    live_snapshots: int = 0
    padded_snapshots: int = 0
    promoted_chunks: int = 0  # chunks promoted to a larger bucket
    launches: int = 0         # stream-kernel launches (v3 paths)

    @property
    def mean_latency_ms(self) -> float:
        return float(np.mean(self.per_snapshot_ms)) if self.per_snapshot_ms else 0.0


class SnapshotServer:
    """Streaming DGNN inference over a snapshot iterator.

    A consumer of ``repro.api.BoosterSession``: all policy — dataflow
    level, tiling, buckets, chunking, promotion — comes from the
    session's typed ``StreamPlan``. The historical keyword surface
    (cfg + mode + padding kwargs) is kept as a deprecated shim that
    builds the equivalent plan/session.
    """

    def __init__(self, cfg: Optional[DGNNConfig] = None,
                 feat_table: Optional[np.ndarray] = None,
                 n_global: Optional[int] = None,
                 mode: Optional[str] = None,
                 n_pad: int = 640, e_pad: int = 4096, k_max: int = 64,
                 queue_depth: int = 2,
                 buckets: Optional[tuple] = None,
                 stream_chunk: int = 8,
                 promote_buckets: Optional[float] = None,
                 promotion_guard: str = "static", *,
                 plan=None, session=None):
        from repro import api

        if session is None:
            if cfg is None:
                raise ValueError("SnapshotServer needs a BoosterSession "
                                 "(session=) or a DGNNConfig")
            if n_global is None:
                raise ValueError("SnapshotServer needs n_global (the "
                                 "global node-store size) on the config "
                                 "surface — an undersized default would "
                                 "silently scatter-drop high node ids")
            if plan is None:
                # deprecated keyword surface -> the equivalent typed plan
                plan = api.plan(
                    cfg, level=mode if mode is not None else cfg.dataflow,
                    n_pad=n_pad, e_pad=e_pad, k_max=k_max,
                    queue_depth=queue_depth, buckets=buckets,
                    stream_chunk=stream_chunk,
                    promote_buckets=promote_buckets,
                    promotion_guard=promotion_guard)
            session = api.BoosterSession(cfg, plan, n_global=n_global,
                                         feat_table=feat_table)
        self.session = session
        self.plan = session.plan
        if self.plan.device.n_devices > 1:
            # the serve loops pick their own launch batch sizes (B=1
            # chunks, pow2 tenant rounds), which need not divide
            # n_devices — reject up front instead of crashing mid-serve.
            raise ValueError(
                "DeviceSpec sharding is a batched-launch capability "
                "(BoosterSession.run_batched / api.run_arrays); the "
                "serving engine does not shard its launches")
        self.cfg = session.cfg
        self.model = session.model
        self.feat_table = (feat_table if feat_table is not None
                           else session.feat_table)
        if self.feat_table is None:
            raise ValueError("SnapshotServer needs the global feat_table")
        # plan-derived knobs (kept as attributes for callers/tests)
        self.mode = self.plan.level
        self.n_pad, self.e_pad = self.plan.n_pad, self.plan.e_pad
        self.k_max = self.plan.k_max
        self.buckets = self.plan.buckets
        self.stream_chunk = self.plan.stream_chunk
        self.queue_depth = self.plan.queue_depth
        self.promote_buckets = self.plan.promote_buckets
        self._bucket_ms: Optional[dict] = None  # measured-guard calibration
        self._step = jax.jit(
            lambda p, s, snap: self.model.step(p, s, snap, mode=self.mode))
        # every v3 serve launch takes the batched ragged-T entry: chunk
        # tails and batch-padding rows are dead ``lengths`` slots masked
        # in-launch, not host-built empty snapshots.
        self._stream_step_batched = jax.jit(
            lambda p, s, sBT, lens: self.model.step_stream_batched(
                p, s, sBT, tn=self.plan.tn, td=self.plan.td, lengths=lens))

    def init(self, rng):
        return self.session.init(rng)

    # ------------------------------------------------------ host thread ----

    def _preprocess(self, snap: COOSnapshot) -> PaddedSnapshot:
        # shapes must be static so the jitted step never recompiles (the
        # "snapshot fits in BRAM" contract; overflow = the bucket chooser
        # picked wrong and should raise). With ``buckets`` the shapes are
        # static PER BUCKET: one compiled step per bucket in the jit cache.
        ls = renumber_and_normalize(snap)
        if self.buckets is not None:
            n_pad, e_pad, k_max = choose_bucket(
                ls.n_nodes, ls.src.shape[0], max_in_degree(ls), self.buckets)
        else:
            n_pad, e_pad, k_max = self.n_pad, self.e_pad, self.k_max
        return pad_snapshot(ls, self.feat_table, n_pad, e_pad, k_max)

    # ------------------------------------------------------ device loop ----

    def _use_stream(self) -> bool:
        # mode v3 requires the model's family to be registered with the
        # stream engine (all three are). Raising here keeps an
        # unregistered family LOUD instead of silently degrading to the
        # per-snapshot loop — the silent-fallback class PR 3 deleted.
        from repro.kernels.stream_fused import REGISTRY

        if self.mode != "v3":
            return False
        if self.model.stream_family not in REGISTRY:
            raise KeyError(
                f"plan level 'v3' but family {self.model.stream_family!r} "
                f"has no stream-engine cell spec; registered: "
                f"{sorted(REGISTRY)}")
        return True

    def _launch_ragged(self, params, states_B, per_stream: list,
                       lengths: np.ndarray):
        """ONE batched ragged-T stream launch: ``per_stream`` are (T, ...)
        stacked chunks of equal padded shape, ``lengths`` their true live
        lengths (0 = pure batch-padding row). The dead slots are masked
        in-launch by the plan's ragged capability."""
        batch_BT = stack_streams(per_stream)
        return self._stream_step_batched(params, states_B, batch_BT,
                                         jnp.asarray(lengths, jnp.int32))

    def _run_chunk(self, params, state, chunk: list, outs: list, lat: list,
                   ctr: dict):
        """Feed one same-bucket chunk to the time-fused stream kernel
        (a B=1 ragged launch).

        Short flushes (tail of the stream, or a bucket change on a
        bucket-alternating stream) pad T up to the next power of two, not
        all the way to ``stream_chunk`` — at most 2× dead slots while the
        jit cache stays bounded at log2(stream_chunk)+1 chunk lengths per
        bucket. The tail repeats the last snapshot; its content is
        ignored (masked by ``lengths``).
        """
        real = len(chunk)
        target = pow2_target(real, cap=self.stream_chunk)
        chunk = chunk + [chunk[-1]] * (target - real)
        ctr["live"] += real
        ctr["padded"] += target - real
        ctr["launches"] += 1
        state_B = jax.tree.map(lambda a: a[None], state)
        t0 = time.perf_counter()
        state_B, out_BT = self._launch_ragged(
            params, state_B, [stack_time(chunk)], np.asarray([real]))
        jax.block_until_ready(out_BT)
        dt = (time.perf_counter() - t0) * 1e3 / real
        out_np = np.asarray(out_BT)
        for t in range(real):
            outs.append(out_np[0, t])
            lat.append(dt)
        return jax.tree.map(lambda a: a[0], state_B)

    def run(self, params, state, snaps: Iterable[COOSnapshot]) -> tuple:
        """Returns (final_state, outputs list, ServeStats)."""
        # the v3 device loop consumes ``stream_chunk`` snapshots per kernel
        # launch; a queue_depth-sized queue would stall the producer at 2
        # staged snapshots while a whole chunk runs, killing the §IV-D
        # host/device overlap — size for a full chunk ahead, like run_multi.
        # Per-snapshot modes keep the caller's queue_depth memory bound.
        depth = (max(self.queue_depth, self.stream_chunk)
                 if self._use_stream() else self.queue_depth)
        q: queue.Queue = queue.Queue(maxsize=depth)
        pre_ms: list = []

        def producer():
            try:
                for s in snaps:
                    t0 = time.perf_counter()
                    ps = self._preprocess(s)
                    pre_ms.append((time.perf_counter() - t0) * 1e3)
                    q.put(ps)
                q.put(None)
            except BaseException as exc:  # propagate, don't hang the consumer
                q.put(exc)

        th = threading.Thread(target=producer, daemon=True)
        t_start = time.perf_counter()
        th.start()
        outs, lat = [], []
        ctr = {"live": 0, "padded": 0, "promoted": 0, "launches": 0}
        use_stream = self._use_stream()
        chunk: list = []
        while True:
            ps = q.get()
            if ps is None:
                break
            if isinstance(ps, BaseException):
                th.join()
                raise ps  # e.g. choose_bucket: no bucket fits the snapshot
            if not use_stream:
                t0 = time.perf_counter()
                state, out = self._step(params, state, ps)
                jax.block_until_ready(out)
                lat.append((time.perf_counter() - t0) * 1e3)
                outs.append(np.asarray(out))
                continue
            # v3: gather same-bucket runs into fixed-T chunks
            bucket = (ps.n_pad, ps.e_pad, ps.k_max)
            if chunk and (chunk[0].n_pad, chunk[0].e_pad, chunk[0].k_max) != bucket:
                state = self._run_chunk(params, state, chunk, outs, lat, ctr)
                chunk = []
            chunk.append(ps)
            if len(chunk) == self.stream_chunk:
                state = self._run_chunk(params, state, chunk, outs, lat, ctr)
                chunk = []
        if chunk:
            state = self._run_chunk(params, state, chunk, outs, lat, ctr)
        th.join()
        total = (time.perf_counter() - t_start) * 1e3
        return state, outs, ServeStats(lat, pre_ms, total,
                                       live_snapshots=ctr["live"],
                                       padded_snapshots=ctr["padded"],
                                       promoted_chunks=ctr["promoted"],
                                       launches=ctr["launches"])

    # ------------------------------------------- multi-tenant device loop ----

    def _use_stream_batched(self) -> bool:
        # every registered family batches through the same engine kernel;
        # only the engine MODE decides (non-v3 modes keep the per-snapshot
        # device loop).
        return self._use_stream()

    def _chunk_bucket(self, dims: list) -> tuple:
        """Bucket covering a whole chunk of (n, e, k) dims (one static shape
        per chunk so the chunk can batch with same-bucket chunks of other
        streams)."""
        if self.buckets is not None:
            return choose_bucket_batch(dims, self.buckets)
        return (self.n_pad, self.e_pad, self.k_max)

    # ------------------------------------------- promotion cost guard ----

    def _calibrate_bucket_times(self, params) -> Optional[dict]:
        """Measure per-bucket stream-kernel step time with a tiny warmup:
        one empty-snapshot B=1 chunk per bucket, compiled then timed.
        The measured times replace the static ``bucket_cost`` proxy in the
        promotion guard (plan.promotion_guard == "measured"); returns None
        (static fallback) if any bucket fails to calibrate."""
        din = self.feat_table.shape[1]
        de = self.cfg.edge_dim
        T = pow2_target(self.stream_chunk, cap=self.stream_chunk)
        times: dict = {}
        try:
            for bucket in self.buckets:
                chunk = [empty_padded(*bucket, din, de)] * T
                state = self.model.init_state(params, mode=self.mode)
                state_B = jax.tree.map(lambda a: a[None], state)
                run = lambda: self._launch_ragged(
                    params, state_B, [stack_time(chunk)], np.asarray([T]))
                jax.block_until_ready(run())  # compile + warm
                t0 = time.perf_counter()
                jax.block_until_ready(run())
                times[bucket] = max((time.perf_counter() - t0) * 1e3 / T,
                                    1e-6)
        except Exception:
            return None  # static proxy fallback
        return times

    def _promotion_cost(self, params):
        """Cost function for promote_bucket_groups: measured per-bucket
        step times when the plan asks for the adaptive guard (calibrated
        lazily, once), else the static padded-compute proxy."""
        if self.plan.promotion_guard != "measured":
            return bucket_cost
        if self._bucket_ms is None:
            self._bucket_ms = self._calibrate_bucket_times(params)
        if self._bucket_ms is None:
            return bucket_cost  # calibration failed: static fallback
        return lambda b: self._bucket_ms[b]

    def _run_group_batched(self, params, states: dict, group: list,
                           outs: dict, lat: list, ctr: dict):
        """One batched V3 launch over same-bucket chunks of several streams.

        ``group`` is [(sid, [LocalSnapshot, ...], bucket), ...]. Each
        stream's chunk is padded to the shared bucket and stacked to a
        (B, T, ...) batch with the per-stream states alongside; T is the
        common power-of-two target and the BATCH axis is pow2-padded too,
        so the jit cache stays bounded at log2 sizes per (bucket, T)
        instead of compiling one program per distinct client count as
        tenants join and finish. Raggedness is carried by ``lengths``
        (stream b live for lengths[b] steps, padding rows live for 0) and
        masked in-launch — no host-built empty snapshots. Row b of the
        launch result is that stream's output in stream order.
        """
        bucket = group[0][2]
        real_lens = [len(chunk) for _, chunk, _ in group]
        target = pow2_target(max(real_lens), cap=self.stream_chunk)
        b_real = len(group)
        b_target = pow2_target(b_real)
        per_stream = []
        for _, chunk, _ in group:
            # fixed-bucket items arrive pre-padded from the producer thread
            # (host-prep overlap); bucketed items pad here, once the chunk
            # bucket is known.
            padded = [ls if isinstance(ls, PaddedSnapshot)
                      else pad_snapshot(ls, self.feat_table, *bucket)
                      for ls in chunk]
            # ragged T: tail slots repeat the last snapshot — dead
            # ``lengths`` slots, masked in-launch, content irrelevant
            padded = padded + [padded[-1]] * (target - len(padded))
            per_stream.append(stack_time(padded))
        # batch-axis padding = length-0 streams (results discarded)
        per_stream.extend([per_stream[0]] * (b_target - b_real))
        lengths = np.asarray(real_lens + [0] * (b_target - b_real), np.int32)
        ctr["live"] += sum(real_lens)
        ctr["padded"] += b_target * target - sum(real_lens)
        ctr["launches"] += 1
        zero_state = jax.tree.map(jnp.zeros_like, states[group[0][0]])
        states_B = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0),
            *([states[sid] for sid, _, _ in group]
              + [zero_state] * (b_target - b_real)))
        t0 = time.perf_counter()
        states_B, out_BT = self._launch_ragged(params, states_B, per_stream,
                                               lengths)
        jax.block_until_ready(out_BT)
        dt = (time.perf_counter() - t0) * 1e3 / sum(real_lens)
        out_np = np.asarray(out_BT)
        for b, (sid, _, _) in enumerate(group):
            states[sid] = jax.tree.map(lambda a, b=b: a[b], states_B)
            for t in range(real_lens[b]):
                outs[sid].append(out_np[b, t])
                lat.append(dt)

    def run_multi(self, params, states: dict, streams: dict) -> tuple:
        """Serve many independent client streams concurrently.

        ``streams``: {stream_id: iterable of COOSnapshot}; ``states``:
        {stream_id: recurrent state} (one store per tenant — state is never
        shared across clients). Returns (states, {stream_id: [outputs]},
        ServeStats). Outputs per stream are in that stream's snapshot order.

        Device loop: rounds of up-to-``stream_chunk`` snapshots per stream;
        same-bucket chunks from different streams batch into one V3 launch.
        """
        sids = sorted(streams)
        qs = {sid: queue.Queue(maxsize=max(self.queue_depth,
                                           self.stream_chunk))
              for sid in sids}
        pre_ms: list = []
        stop = threading.Event()

        def _put(q, item):
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def producer(sid):
            try:
                for s in streams[sid]:
                    t0 = time.perf_counter()
                    ls = renumber_and_normalize(s)
                    dims = (ls.n_nodes, ls.src.shape[0], max_in_degree(ls))
                    if self.buckets is not None:
                        choose_bucket(*dims, self.buckets)  # fail fast
                    else:
                        # fixed bucket known up front: pad here so the host
                        # prep fully overlaps device work (the bucketed
                        # case defers padding until the chunk bucket — max
                        # over its members — is known on the device loop).
                        ls = pad_snapshot(ls, self.feat_table, self.n_pad,
                                          self.e_pad, self.k_max)
                    pre_ms.append((time.perf_counter() - t0) * 1e3)
                    if not _put(qs[sid], (ls, dims)):
                        return
                _put(qs[sid], None)
            except BaseException as exc:  # propagate, don't hang the consumer
                _put(qs[sid], exc)

        threads = [threading.Thread(target=producer, args=(sid,), daemon=True)
                   for sid in sids]
        t_start = time.perf_counter()
        for th in threads:
            th.start()
        outs: dict = {sid: [] for sid in sids}
        lat: list = []
        ctr = {"live": 0, "padded": 0, "promoted": 0, "launches": 0}
        active = set(sids)
        batched = self._use_stream_batched()
        try:
            while active:
                # one round: pull the next chunk of every active stream
                chunks = {}
                for sid in sorted(active):
                    chunk: list = []
                    dims: list = []
                    while len(chunk) < self.stream_chunk:
                        item = qs[sid].get()
                        if item is None:
                            active.discard(sid)
                            break
                        if isinstance(item, BaseException):
                            active.discard(sid)
                            raise item
                        chunk.append(item[0])
                        dims.append(item[1])
                        if not batched and chunk:
                            break  # non-v3 per-snapshot loop: no chunking
                    if chunk:
                        chunks[sid] = (chunk, dims)
                if not chunks:
                    continue
                if not batched:
                    # non-v3 engine modes: round-robin per-snapshot stepping
                    for sid, (chunk, dims) in sorted(chunks.items()):
                        for ls, d in zip(chunk, dims):
                            ps = (ls if isinstance(ls, PaddedSnapshot)
                                  else pad_snapshot(ls, self.feat_table,
                                                    *self._chunk_bucket([d])))
                            t0 = time.perf_counter()
                            states[sid], out = self._step(params, states[sid],
                                                          ps)
                            jax.block_until_ready(out)
                            lat.append((time.perf_counter() - t0) * 1e3)
                            outs[sid].append(np.asarray(out))
                    continue
                # group same-bucket chunks across streams -> one launch each
                groups: dict = {}
                for sid, (chunk, dims) in sorted(chunks.items()):
                    bucket = self._chunk_bucket(dims)
                    groups.setdefault(bucket, []).append((sid, chunk, bucket))
                if self.promote_buckets is not None and self.buckets is not None:
                    # cross-bucket batching: promote smaller-bucket chunks
                    # into the next-larger in-flight bucket (guarded by the
                    # per-bucket cost ratio — measured step times under the
                    # plan's adaptive guard, else the static padded-compute
                    # proxy) so they join its launch instead of paying
                    # their own dispatch.
                    before = {b: len(m) for b, m in groups.items()}
                    groups = promote_bucket_groups(groups, self.buckets,
                                                   self.promote_buckets,
                                                   cost=self._promotion_cost(
                                                       params))
                    ctr["promoted"] += sum(
                        len(m) - before.get(b, 0) for b, m in groups.items())
                for bucket in sorted(groups):
                    self._run_group_batched(params, states, groups[bucket],
                                            outs, lat, ctr)
        finally:
            stop.set()
            for th in threads:
                th.join(timeout=5.0)
        total = (time.perf_counter() - t_start) * 1e3
        return states, outs, ServeStats(lat, pre_ms, total,
                                        live_snapshots=ctr["live"],
                                        padded_snapshots=ctr["padded"],
                                        promoted_chunks=ctr["promoted"],
                                        launches=ctr["launches"])
