"""DGNN snapshot-stream serving engine — the paper's deployment mode.

Implements the §IV-D task-scheduling scheme:
  host thread ("CPU tasks"): slice the temporal COO stream into snapshots,
    renumber + normalize, build ELL, pad into the bucket — irregular,
    control-heavy work;
  device loop ("FPGA tasks"): the jitted DGNN step (format-consuming dense
    compute) pulls prepared snapshots from a DOUBLE-BUFFERED queue, so
    graph loading overlaps inference (the paper's GL/GNN overlap, host
    edition — the in-graph edition is the V1 ping-pong carry).

Bucketed padding: with ``buckets`` set, each snapshot is padded into the
smallest bucket that fits (graph/padding.choose_bucket) instead of the
worst-case shape — small snapshots stop paying big-snapshot compute. The
jit cache holds one compiled step per bucket.

V3 fast path: when the engine runs the time-fused stream dataflow
(plan level "v3" — the stream-engine families), consecutive same-bucket
snapshots are batched into fixed-T chunks (tail padded with no-op empty
snapshots) and the WHOLE chunk is handed to the stream kernel in one
launch, so the recurrent state crosses HBM once per chunk, not per
snapshot.

Multi-tenant batched serving (``run_multi``): many independent clients'
snapshot streams served concurrently. Each client stream gets its own
host preprocessing thread and its own recurrent state store; the device
loop proceeds in rounds, co-buckets each stream's next chunk
(choose_bucket_batch), groups same-bucket chunks across clients, and
hands each group to ONE batched V3 launch — the batch axis is a leading
grid dimension of the stream kernel, so B streams cost one kernel launch
and one weight load while every stream's state store still crosses HBM
exactly twice per chunk. Per-stream outputs are returned in per-stream
order (rounds are sequential and each stream's snapshots are consumed in
order). All three DGNN families take this batched launch through the SAME
stream-engine kernel (kernels/stream_fused.REGISTRY — the model's
``stream_family`` selects its cell spec): GCRN and stacked models keep
their node-state store resident, EvolveGCN its evolving weight matrices
(the in-kernel evolution is live-gated, so the no-op tail snapshots
padding a chunk never advance the weights).

Cross-bucket batching (``promote_buckets``): with bucketed padding, a
round's smaller-bucket chunks may be PROMOTED into the next-larger
occupied bucket — re-padded to the bigger shape so they join that
bucket's in-flight batched launch — trading padding overhead (guarded by
a max padded-compute ratio, graph/padding.promote_bucket_groups) for one
fewer device dispatch per round. The guard compares per-bucket costs:
the static ``bucket_cost`` padded-compute proxy by default, or — with
``promotion_guard="measured"`` in the plan — per-bucket step times from a
tiny warmup calibration (one timed launch per bucket, static proxy kept
as the fallback). ServeStats reports live vs padded snapshot slots and
launch counts per run so the overhead stays visible instead of hiding in
throughput.

Fault isolation and recovery (docs/serve_robustness.md): every chunk
launch goes through a SUPERVISED runner. Snapshots are validated at the
serve boundary (serve/faults.validate_snapshot — malformed input raises a
typed ``SnapshotValidationError`` carrying the tenant id); per-tenant
recurrent state is CHECKPOINTED before each chunk commit and ROLLED BACK
on any failure, so a replayed chunk can never double-evolve state; failed
launches are retried with exponential backoff (plan ``max_retries`` /
``retry_backoff_ms``), bounded by a per-launch deadline (plan
``launch_timeout_ms`` — enforced on completion, overdue results are
discarded, never committed); a persistent fault attributable to one
tenant QUARANTINES that tenant (plan ``supervision="isolate"``) while the
co-batched healthy tenants are transparently retried without the failed
member; an unattributable kernel-path failure walks the graceful
DEGRADATION LADDER (plan ``degrade=True``): batched v3 -> solo v3 -> the
pure-XLA oracle via the kernels/ops force-ref gate, serving
correct-but-slower results instead of erroring. Every recovery action is
visible in ``ServeStats`` (per-tenant errors, retries, rollbacks,
degraded launches, timeouts); the deterministic fault-injection harness
(plan ``fault_plan`` -> serve/faults.FaultInjector) drives each site on
demand so chaos tests pin all of the above.

Configuration is a typed ``repro.api.StreamPlan`` — the server is a
consumer of a ``BoosterSession`` (``SnapshotServer(session=...)``, or the
historical keyword surface, which builds the equivalent plan/session).
Chunk tails and batch-padding rows are expressed through the plan's
ragged-``lengths`` capability: every batched launch carries the true
per-stream lengths and the engine masks the dead slots in-launch, so the
host never manufactures empty tail snapshots.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dgnn import DGNNConfig
from repro.core.dataflow import stack_time
from repro.graph.coo import COOSnapshot
from repro.graph.csr import max_in_degree, renumber_and_normalize
from repro.graph.padding import (
    PaddedSnapshot,
    bucket_cost,
    choose_bucket,
    choose_bucket_batch,
    empty_padded,
    pad_snapshot,
    pow2_target,
    promote_bucket_groups,
    stack_streams,
)
from repro.kernels import ops as kops
from repro.serve.faults import LaunchTimeout, validate_snapshot
from repro.serve.supervision import SupervisionPolicy, TenantSupervisor

# sid the single-tenant ``run`` path supervises its stream under (one
# namespace for probes/results across both entry points)
SOLO_SID = "stream"

# how long shutdown keeps drain-joining producer threads before giving up
# with a warning (threads cannot be killed in Python; a producer stuck in
# USER iterator code past this is reported, not silently leaked)
_SHUTDOWN_DEADLINE_S = 5.0


@dataclass
class ServeStats:
    per_snapshot_ms: list
    preprocess_ms: list
    total_ms: float
    # no-op-tail waste signal: how many snapshot slots of the batched V3
    # launches were real vs padding (T tails + no-op batch rows), so
    # promoted-bucket and D-blocked rows expose their padding overhead
    # instead of hiding it in throughput.
    live_snapshots: int = 0
    padded_snapshots: int = 0
    promoted_chunks: int = 0  # chunks promoted to a larger bucket
    launches: int = 0         # stream-kernel launches (v3 paths)
    # express-lane signals: static-family chunks are stateless, so they
    # co-batch into dedicated launches with no checkpoint/rollback around
    # them; ``launches_by_family`` splits ALL launches by stream family
    # (express launches count under the express session's family too).
    express_launches: int = 0
    launches_by_family: dict = field(default_factory=dict)
    # fault-isolation / recovery signals (docs/serve_robustness.md)
    retries: int = 0            # failed chunk attempts that were replayed
    rollbacks: int = 0          # per-tenant state rollbacks
    degraded_launches: int = 0  # solo/oracle ladder launches that served
    timeouts: int = 0           # launches past the plan deadline
    # per-tenant outcomes: {sid: supervision.TenantResult} — errors of
    # quarantined tenants, per-tenant recovery counters, output lists
    tenants: dict = field(default_factory=dict)
    # measured-guard calibration fell back to the static proxy (repr of
    # the LAST error; None = calibration ok or never requested)
    calibration_fallback: Optional[str] = None
    # continuous-scheduler signals (docs/serve_scheduler.md)
    ticks: int = 0            # engine ticks (0 under the round scheduler)
    prefill_chunks: int = 0   # backlog chunks served under the prefill quota
    evictions: int = 0        # tenant-state pages spilled to host
    recoveries: int = 0       # tenant-state pages restored from host
    # per-tenant commit timestamps: {sid: [ms since run start, one per
    # committed snapshot, in stream order]} — sojourn latency is this minus
    # the caller's arrival clock (benchmarks/kernel_bench does exactly that)
    commit_ms: dict = field(default_factory=dict)

    @property
    def mean_latency_ms(self) -> float:
        return float(np.mean(self.per_snapshot_ms)) if self.per_snapshot_ms else 0.0

    @property
    def tenant_errors(self) -> dict:
        """{sid: error} for every quarantined tenant."""
        return {sid: r.error for sid, r in self.tenants.items() if not r.ok}


class SnapshotServer:
    """Streaming DGNN inference over a snapshot iterator.

    A consumer of ``repro.api.BoosterSession``: all policy — dataflow
    level, tiling, buckets, chunking, promotion, fault
    isolation/recovery — comes from the session's typed ``StreamPlan``.
    The historical keyword surface (cfg + mode + padding kwargs) is kept
    as a deprecated shim that builds the equivalent plan/session.
    """

    def __init__(self, cfg: Optional[DGNNConfig] = None,
                 feat_table: Optional[np.ndarray] = None,
                 n_global: Optional[int] = None,
                 mode: Optional[str] = None,
                 n_pad: int = 640, e_pad: int = 4096, k_max: int = 64,
                 queue_depth: int = 2,
                 buckets: Optional[tuple] = None,
                 stream_chunk: int = 8,
                 promote_buckets: Optional[float] = None,
                 promotion_guard: str = "static",
                 scheduler: str = "rounds",
                 state_pool_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None, *,
                 plan=None, session=None, express=None):
        from repro import api

        if session is None:
            warnings.warn(
                "the SnapshotServer keyword surface (cfg + mode + padding "
                "kwargs) is deprecated: build a typed plan and pass "
                "SnapshotServer(session=BoosterSession(cfg, plan, ...))",
                DeprecationWarning, stacklevel=2)
            if cfg is None:
                raise ValueError("SnapshotServer needs a BoosterSession "
                                 "(session=) or a DGNNConfig")
            if n_global is None:
                raise ValueError("SnapshotServer needs n_global (the "
                                 "global node-store size) on the config "
                                 "surface — an undersized default would "
                                 "silently scatter-drop high node ids")
            if plan is None:
                # deprecated keyword surface -> the equivalent typed plan
                plan = api.plan(
                    cfg, level=mode if mode is not None else cfg.dataflow,
                    n_pad=n_pad, e_pad=e_pad, k_max=k_max,
                    queue_depth=queue_depth, buckets=buckets,
                    stream_chunk=stream_chunk,
                    promote_buckets=promote_buckets,
                    promotion_guard=promotion_guard,
                    scheduler=scheduler,
                    state_pool_pages=state_pool_pages,
                    prefill_chunk=prefill_chunk)
            session = api.BoosterSession(cfg, plan, n_global=n_global,
                                         feat_table=feat_table)
        self.session = session
        self.plan = session.plan
        if self.plan.device.n_devices > 1:
            # the serve loops pick their own launch batch sizes (B=1
            # chunks, pow2 tenant rounds), which need not divide
            # n_devices — reject up front instead of crashing mid-serve.
            raise ValueError(
                "DeviceSpec sharding is a batched-launch capability "
                "(BoosterSession.run_batched / api.run_arrays); the "
                "serving engine does not shard its launches")
        self.cfg = session.cfg
        self.model = session.model
        self.feat_table = (feat_table if feat_table is not None
                           else session.feat_table)
        if self.feat_table is None:
            raise ValueError("SnapshotServer needs the global feat_table")
        # plan-derived knobs (kept as attributes for callers/tests)
        self.mode = self.plan.level
        self.n_pad, self.e_pad = self.plan.n_pad, self.plan.e_pad
        self.k_max = self.plan.k_max
        self.buckets = self.plan.buckets
        self.stream_chunk = self.plan.stream_chunk
        self.queue_depth = self.plan.queue_depth
        self.promote_buckets = self.plan.promote_buckets
        self.scheduler = self.plan.scheduler
        self.state_pool_pages = self.plan.state_pool_pages
        self.prefill_chunk = self.plan.prefill_chunk
        self._bucket_ms: Optional[dict] = None  # measured-guard calibration
        self._calib_error: Optional[str] = None  # fallback-to-static reason
        self._policy = SupervisionPolicy.from_plan(self.plan)
        self._injector = (self.plan.fault_plan.injector()
                          if self.plan.fault_plan is not None else None)
        self._fault_exempt = False   # calibration launches skip probes
        self._launch_ctx: tuple = ()  # live sids of the in-flight launch
        self._warmed: set = set()    # launch signatures past first compile
        self._t0_run = 0.0           # run-start clock for commit stamps
        self._commit_ms: dict = {}   # {sid: [commit ms since run start]}
        self._step = jax.jit(
            lambda p, s, snap: self.model.step(p, s, snap, mode=self.mode))
        # every v3 serve launch takes the batched ragged-T entry: chunk
        # tails and batch-padding rows are dead ``lengths`` slots masked
        # in-launch, not host-built empty snapshots. The force-ref twin is
        # the degradation ladder's oracle rung (pure-XLA production path).
        self._stream_step_batched = jax.jit(
            lambda p, s, sBT, lens: self.model.step_stream_batched(
                p, s, sBT, tn=self.plan.tn, td=self.plan.td, lengths=lens,
                state_residency=self.plan.state_residency,
                buffer_depth=self.plan.buffer_depth))
        self._stream_step_batched_ref = jax.jit(
            lambda p, s, sBT, lens: self.model.step_stream_batched(
                p, s, sBT, tn=self.plan.tn, td=self.plan.td, lengths=lens,
                state_residency=self.plan.state_residency,
                buffer_depth=self.plan.buffer_depth,
                force_ref=True))
        # ------------------------------------------------ express lane ----
        # a second, STATIC-family BoosterSession: its tenants are
        # stateless, so their snapshots co-batch — each one an independent
        # T=1 slot of a dedicated launch with no checkpoint/rollback
        # around it (see ``run_multi``'s ``express_streams``).
        self.express = express
        if express is not None:
            if express.plan.temporal != "static":
                raise ValueError(
                    "express= takes a BoosterSession of a STATIC-temporal "
                    f"family; {express.model.stream_family!r} declares "
                    f"temporal={express.plan.temporal!r}")
            if express.plan.level != "v3":
                raise ValueError("the express lane is a stream-engine "
                                 "path: the express plan must be level "
                                 f"'v3', got {express.plan.level!r}")
            if express.plan.device.n_devices > 1:
                raise ValueError("the express lane does not shard its "
                                 "launches (see the session sharding note "
                                 "above)")
            if express.plan.buckets is not None:
                raise ValueError(
                    "the express lane co-batches every static slot into "
                    "ONE shape; give the express plan a fixed bucket "
                    "(buckets=None)")
            self._express_feat = (express.feat_table
                                  if express.feat_table is not None
                                  else self.feat_table)
            xp = express.plan
            self._express_step = jax.jit(
                lambda p, sBT, lens: express.model.step_stream_batched(
                    p, {}, sBT, tn=xp.tn, td=xp.td, lengths=lens)[1])

    def init(self, rng):
        return self.session.init(rng)

    # ------------------------------------------------- fault injection ----

    def _probe(self, site: str, tenant=None) -> None:
        """Host-side fault-site probe (preprocess/bucket/evolve sites;
        launch-site probes fire inside the traced program via the
        kernels/ops fault hook).

        Deliberately does NOT consult ``_fault_exempt``: calibration never
        reaches a host site, but it flips that flag on the device loop
        while producer threads run host probes concurrently — gating here
        would let a calibration window swallow a concurrent tenant's
        preprocess/bucket occurrence counts (the stats/occurrence-window
        leak the calibration-isolation regression test pins). Only
        ``_launch_probe`` is gated, and only calibration launches run
        under the flag, on the same thread that sets it."""
        if self._injector is not None:
            self._injector.probe(
                site, tenants=() if tenant is None else (tenant,))

    def _launch_probe(self, *, family, batched, force_ref) -> None:
        """The kernels/ops fault hook: fires at RUN time inside every
        stream-engine dispatch, with the engine supplying the live-tenant
        context of the in-flight launch."""
        del family, batched  # scope is judged on live tenants, not shape
        if self._injector is None or self._fault_exempt:
            return
        sids = self._launch_ctx
        self._injector.probe("launch", tenants=sids, n_live=len(sids),
                             force_ref=force_ref)

    @contextmanager
    def _fault_window(self):
        """Install the ops-layer launch hook for the duration of a serve
        run (only when the fault plan addresses the launch site), and
        restore the previous hook on every exit path."""
        if (self._injector is None
                or "launch" not in self.plan.fault_plan.sites()):
            yield
            return
        prev = kops.set_fault_hook(self._launch_probe)
        try:
            yield
        finally:
            kops.set_fault_hook(prev)

    def _attribution(self, exc: BaseException) -> BaseException:
        """Map a launch exception to its root fault: an injected fault
        crosses the XLA callback boundary rewrapped, so ask the injector
        what fired; otherwise the exception speaks for itself."""
        if self._injector is not None:
            fired = self._injector.take_fired()
            if fired is not None:
                return fired
        return exc

    # ------------------------------------------------------ host thread ----

    def _preprocess(self, snap: COOSnapshot,
                    tenant=SOLO_SID) -> PaddedSnapshot:
        # shapes must be static so the jitted step never recompiles (the
        # "snapshot fits in BRAM" contract; overflow = the bucket chooser
        # picked wrong and should raise). With ``buckets`` the shapes are
        # static PER BUCKET: one compiled step per bucket in the jit cache.
        self._probe("preprocess", tenant=tenant)
        validate_snapshot(snap, self.feat_table.shape[0], tenant=tenant)
        ls = renumber_and_normalize(snap)
        if self.buckets is not None:
            self._probe("bucket", tenant=tenant)
            n_pad, e_pad, k_max = choose_bucket(
                ls.n_nodes, ls.src.shape[0], max_in_degree(ls), self.buckets)
        else:
            n_pad, e_pad, k_max = self.n_pad, self.e_pad, self.k_max
        return pad_snapshot(ls, self.feat_table, n_pad, e_pad, k_max)

    # -------------------------------------------------------- shutdown ----

    @staticmethod
    def _drain(q: queue.Queue) -> None:
        try:
            while True:
                q.get_nowait()
        except queue.Empty:
            pass

    def _shutdown(self, stop: threading.Event, queues: list,
                  threads: list) -> None:
        """Deterministic producer shutdown, run on EVERY exit path: signal
        stop, then drain-join until every producer thread has exited (a
        producer blocked on a full queue wakes on the drain; one blocked
        on the stop-aware put wakes on the event). A thread still alive
        past the deadline is stuck in user iterator code — warned about,
        since Python offers no way to kill it."""
        stop.set()
        deadline = time.perf_counter() + _SHUTDOWN_DEADLINE_S
        alive = [th for th in threads if th.is_alive()]
        while alive and time.perf_counter() < deadline:
            for q in queues:
                self._drain(q)
            for th in alive:
                th.join(timeout=0.05)
            alive = [th for th in alive if th.is_alive()]
        for th in alive:
            warnings.warn(f"serve producer thread {th.name!r} did not exit "
                          "within the shutdown deadline (stuck in the "
                          "stream iterator?)", RuntimeWarning)

    # ------------------------------------------------------ device loop ----

    def _use_stream(self) -> bool:
        # mode v3 requires the model's family to be registered with the
        # stream engine (all three are). Raising here keeps an
        # unregistered family LOUD instead of silently degrading to the
        # per-snapshot loop — the silent-fallback class PR 3 deleted.
        from repro.kernels.stream_fused import REGISTRY

        if self.mode != "v3":
            return False
        if self.model.stream_family not in REGISTRY:
            raise KeyError(
                f"plan level 'v3' but family {self.model.stream_family!r} "
                f"has no stream-engine cell spec; registered: "
                f"{sorted(REGISTRY)}")
        return True

    def _launch_ragged(self, params, states_B, per_stream: list,
                       lengths: np.ndarray, force_ref: bool = False):
        """ONE batched ragged-T stream launch: ``per_stream`` are (T, ...)
        stacked chunks of equal padded shape, ``lengths`` their true live
        lengths (0 = pure batch-padding row). The dead slots are masked
        in-launch by the plan's ragged capability. ``force_ref`` routes to
        the jitted oracle twin (degraded-mode rung)."""
        batch_BT = stack_streams(per_stream)
        fn = (self._stream_step_batched_ref if force_ref
              else self._stream_step_batched)
        return fn(params, states_B, batch_BT,
                  jnp.asarray(lengths, jnp.int32))

    # -------------------------------------------------- supervised launch ----

    def _count_launch(self, ctr: dict, family: str) -> None:
        ctr["launches"] += 1
        bf = ctr.setdefault("by_family", {})
        bf[family] = bf.get(family, 0) + 1

    def _stage_group(self, params, states: dict, group: list,
                     force_ref: bool = False) -> tuple:
        """Launch one batched V3 group WITHOUT committing anything: build
        the (B, T) batch, run it, and return the staged per-tenant results
        ``(staged_states, staged_outs, dt_per_snapshot_ms, live, padded)``.
        Commit/rollback is the supervised runner's job, so a failure here
        (or after, in the commit phase) leaves tenant state untouched.

        ``group`` is [(sid, [LocalSnapshot | PaddedSnapshot, ...],
        bucket), ...]. Each stream's chunk is padded to the shared bucket
        and stacked to a (B, T, ...) batch with the per-stream states
        alongside; T is the common power-of-two target and the BATCH axis
        is pow2-padded too, so the jit cache stays bounded at log2 sizes
        per (bucket, T) instead of compiling one program per distinct
        client count as tenants join and finish. Raggedness is carried by
        ``lengths`` (stream b live for lengths[b] steps, padding rows live
        for 0) and masked in-launch — no host-built empty snapshots. Row b
        of the launch result is that stream's output in stream order.

        The plan's ``launch_timeout_ms`` deadline is enforced on
        completion (JAX launches cannot be cancelled): an overdue result
        raises ``LaunchTimeout`` and is DISCARDED by the caller. The first
        launch of each (bucket, T, B, path) signature is exempt — it pays
        one-time compilation.
        """
        bucket = group[0][2]
        real_lens = [len(chunk) for _, chunk, _ in group]
        target = pow2_target(max(real_lens), cap=self.stream_chunk)
        b_real = len(group)
        b_target = pow2_target(b_real)
        per_stream = []
        for _, chunk, _ in group:
            # fixed-bucket items arrive pre-padded from the producer thread
            # (host-prep overlap); bucketed items pad here, once the chunk
            # bucket is known.
            padded = [ls if isinstance(ls, PaddedSnapshot)
                      else pad_snapshot(ls, self.feat_table, *bucket)
                      for ls in chunk]
            # ragged T: tail slots repeat the last snapshot — dead
            # ``lengths`` slots, masked in-launch, content irrelevant
            padded = padded + [padded[-1]] * (target - len(padded))
            per_stream.append(stack_time(padded))
        # batch-axis padding = length-0 streams (results discarded)
        per_stream.extend([per_stream[0]] * (b_target - b_real))
        lengths = np.asarray(real_lens + [0] * (b_target - b_real), np.int32)
        zero_state = jax.tree.map(jnp.zeros_like, states[group[0][0]])
        states_B = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0),
            *([states[sid] for sid, _, _ in group]
              + [zero_state] * (b_target - b_real)))
        key = (bucket, target, b_target, force_ref)
        warmed = key in self._warmed
        self._launch_ctx = tuple(sid for sid, _, _ in group)
        try:
            t0 = time.perf_counter()
            states_B, out_BT = self._launch_ragged(params, states_B,
                                                   per_stream, lengths,
                                                   force_ref=force_ref)
            jax.block_until_ready(out_BT)
            dt_ms = (time.perf_counter() - t0) * 1e3
        finally:
            self._launch_ctx = ()
        self._warmed.add(key)
        timeout = self._policy.timeout_ms
        if timeout is not None and warmed and dt_ms > timeout:
            raise LaunchTimeout(
                f"launch took {dt_ms:.1f}ms > launch_timeout_ms={timeout}"
                f" (bucket={bucket}, B={b_target}, T={target}); result "
                "discarded", site="launch")
        out_np = np.asarray(out_BT)
        staged_states = {
            sid: jax.tree.map(lambda a, b=b: a[b], states_B)
            for b, (sid, _, _) in enumerate(group)}
        staged_outs = {sid: [out_np[b, t] for t in range(real_lens[b])]
                       for b, (sid, _, _) in enumerate(group)}
        live = sum(real_lens)
        padded_slots = b_target * target - live
        return staged_states, staged_outs, dt_ms / live, live, padded_slots

    def _commit_group(self, states: dict, group: list, staged: tuple,
                      outs: dict, lat: list, ctr: dict, sup: TenantSupervisor,
                      degraded: bool = False) -> None:
        """Commit one staged group: the ``evolve`` fault site sits inside
        the state-commit loop, so an injected (or real) mid-commit failure
        leaves ``states`` partially written — exactly what the
        supervisor's checkpoint/rollback must undo for the replay to
        evolve state exactly once per served snapshot."""
        staged_states, staged_outs, dt, live, padded_slots = staged
        for sid, _, _ in group:
            self._probe("evolve", tenant=sid)
            states[sid] = staged_states[sid]
        # commit wall-clock (ms since run start) recorded per snapshot —
        # only after the whole evolve loop, so a rolled-back commit never
        # stamps timestamps for outputs it did not serve
        now_ms = (time.perf_counter() - self._t0_run) * 1e3
        for sid, chunk, _ in group:
            outs[sid].extend(staged_outs[sid])
            self._commit_ms.setdefault(sid, []).extend([now_ms] * len(chunk))
            lat.extend([dt] * len(chunk))
            if degraded:
                sup.note_degraded(sid)
        ctr["live"] += live
        ctr["padded"] += padded_slots
        if degraded:
            ctr["degraded"] += 1

    def _degrade_group(self, params, states: dict, members: list,
                       outs: dict, lat: list, ctr: dict,
                       sup: TenantSupervisor, cause: BaseException) -> None:
        """The degradation ladder's lower rungs, per member: a solo (B=1)
        v3 launch isolates the batch from a poisoned co-tenant; if the
        kernel path itself is the fault, the pure-XLA oracle (force-ref
        gate) serves correct-but-slower results. A member that fails every
        rung is quarantined (isolate) or raises (strict) with the LAST
        error as cause."""
        static = self.plan.temporal == "static"
        for member in members:
            sid = member[0]
            err = cause
            for force_ref in (False, True):
                ckpt = None if static else sup.checkpoint(states, [sid])
                try:
                    self._count_launch(ctr, self.model.stream_family)
                    staged = self._stage_group(params, states, [member],
                                               force_ref=force_ref)
                    self._commit_group(states, [member], staged, outs, lat,
                                       ctr, sup, degraded=True)
                    break
                except Exception as exc:
                    err = self._attribution(exc)
                    if isinstance(err, LaunchTimeout):
                        ctr["timeouts"] += 1
                    if ckpt is not None:
                        sup.rollback(states, ckpt)
            else:
                sup.quarantine(sid, err,
                               site=getattr(err, "site", "launch"))

    def _run_group_supervised(self, params, states: dict, group: list,
                              outs: dict, lat: list, ctr: dict,
                              sup: TenantSupervisor) -> None:
        """One batched V3 group under the supervision contract:

          1. checkpoint every member's recurrent state;
          2. stage the batched launch + commit (the happy path);
          3. on failure: roll back, then retry the SAME group up to
             ``max_retries`` times with exponential backoff (a transient
             fault is survived in place, replaying from the checkpoint);
          4. retries exhausted + fault attributable to one member: that
             tenant is quarantined and the remaining members are
             transparently retried without it;
          5. retries exhausted + unattributable: walk the degradation
             ladder (plan ``degrade=True``), else quarantine the whole
             group (isolate) / raise (strict).
        """
        members = [m for m in group if sup.ok(m[0])]
        attempt = 0
        # the static temporal contract has NOTHING to checkpoint — tenant
        # state is empty and never advances — so the express-lane promise
        # (no checkpoint/rollback overhead around stateless launches)
        # holds for a static-family session on the regular path too.
        static = self.plan.temporal == "static"
        while members:
            sids = [sid for sid, _, _ in members]
            ckpt = None if static else sup.checkpoint(states, sids)
            try:
                self._count_launch(ctr, self.model.stream_family)
                staged = self._stage_group(params, states, members)
                self._commit_group(states, members, staged, outs, lat, ctr,
                                   sup)
                return
            except Exception as exc:
                err = self._attribution(exc)
                if isinstance(err, LaunchTimeout):
                    ctr["timeouts"] += 1
                if ckpt is not None:
                    sup.rollback(states, ckpt)
                attempt += 1
                if attempt <= self._policy.max_retries:
                    sup.note_retry(sids, attempt)
                    continue
                tenant = getattr(err, "tenant", None)
                if tenant is not None and tenant in sids:
                    # persistent fault pinned to one member: quarantine it,
                    # retry the healthy co-batch without it
                    sup.quarantine(tenant, err,
                                   site=getattr(err, "site", "launch"))
                    members = [m for m in members if m[0] != tenant]
                    attempt = 0
                    continue
                if self._policy.degrade:
                    self._degrade_group(params, states, members, outs, lat,
                                        ctr, sup, err)
                    return
                # no ladder: the whole group fails together
                for sid in sids:
                    sup.quarantine(sid, err,
                                   site=getattr(err, "site", "launch"))
                return

    def _run_chunk(self, params, states: dict, chunk: list, outs: dict,
                   lat: list, ctr: dict, sup: TenantSupervisor) -> None:
        """Feed one same-bucket single-tenant chunk to the time-fused
        stream kernel (a B=1 supervised launch).

        Short flushes (tail of the stream, or a bucket change on a
        bucket-alternating stream) pad T up to the next power of two, not
        all the way to ``stream_chunk`` — at most 2x dead slots while the
        jit cache stays bounded at log2(stream_chunk)+1 chunk lengths per
        bucket. The tail repeats the last snapshot; its content is
        ignored (masked by ``lengths``).
        """
        bucket = (chunk[0].n_pad, chunk[0].e_pad, chunk[0].k_max)
        self._run_group_supervised(params, states,
                                   [(SOLO_SID, chunk, bucket)], outs, lat,
                                   ctr, sup)

    def _make_stats(self, lat, pre_ms, total, ctr,
                    sup: Optional[TenantSupervisor]) -> ServeStats:
        totals = sup.totals() if sup is not None else {}
        return ServeStats(
            lat, pre_ms, total,
            live_snapshots=ctr["live"], padded_snapshots=ctr["padded"],
            promoted_chunks=ctr["promoted"], launches=ctr["launches"],
            express_launches=ctr.get("express", 0),
            launches_by_family=dict(ctr.get("by_family", {})),
            retries=totals.get("retries", 0),
            rollbacks=totals.get("rollbacks", 0),
            degraded_launches=totals.get("degraded_launches", 0),
            timeouts=ctr.get("timeouts", 0),
            tenants=dict(sup.results) if sup is not None else {},
            calibration_fallback=self._calib_error,
            ticks=ctr.get("ticks", 0),
            prefill_chunks=ctr.get("prefill", 0),
            evictions=totals.get("evictions", 0),
            recoveries=totals.get("recoveries", 0),
            commit_ms=dict(self._commit_ms))

    def run(self, params, state, snaps: Iterable[COOSnapshot]) -> tuple:
        """Returns (final_state, outputs list, ServeStats).

        Single-tenant edition of the supervision contract: the stream is
        supervised under the sid ``"stream"`` — with the default strict
        policy every failure raises (after a clean shutdown); with plan
        ``supervision="isolate"`` a terminal failure stops the stream and
        returns the partial outputs with the error recorded in
        ``stats.tenants["stream"]``.
        """
        # the v3 device loop consumes ``stream_chunk`` snapshots per kernel
        # launch; a queue_depth-sized queue would stall the producer at 2
        # staged snapshots while a whole chunk runs, killing the §IV-D
        # host/device overlap — size for a full chunk ahead, like run_multi.
        # Per-snapshot modes keep the caller's queue_depth memory bound.
        depth = (max(self.queue_depth, self.stream_chunk)
                 if self._use_stream() else self.queue_depth)
        q: queue.Queue = queue.Queue(maxsize=depth)
        pre_ms: list = []
        stop = threading.Event()

        def _put(item):
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def producer():
            try:
                for s in snaps:
                    t0 = time.perf_counter()
                    ps = self._preprocess(s)
                    pre_ms.append((time.perf_counter() - t0) * 1e3)
                    if not _put(ps):
                        return
                _put(None)
            except BaseException as exc:  # propagate, don't hang the consumer
                _put(exc)

        th = threading.Thread(target=producer, daemon=True,
                              name=f"dgnn-serve-producer-{SOLO_SID}")
        t_start = time.perf_counter()
        self._t0_run, self._commit_ms = t_start, {}
        th.start()
        outs: list = []
        lat: list = []
        ctr = {"live": 0, "padded": 0, "promoted": 0, "launches": 0,
               "timeouts": 0, "degraded": 0}
        sup = TenantSupervisor([SOLO_SID], self._policy,
                               outputs={SOLO_SID: outs})
        states = {SOLO_SID: state}
        outs_d = {SOLO_SID: outs}
        use_stream = self._use_stream()
        chunk: list = []
        try:
            with self._fault_window():
                while sup.ok(SOLO_SID):
                    ps = q.get()
                    if ps is None:
                        break
                    if isinstance(ps, BaseException):
                        # e.g. validation / no-fit bucket: strict raises,
                        # isolate records and stops the stream
                        sup.quarantine(SOLO_SID, ps,
                                       site=getattr(ps, "site", None))
                        break
                    if not use_stream:
                        ckpt = sup.checkpoint(states, [SOLO_SID])
                        try:
                            t0 = time.perf_counter()
                            states[SOLO_SID], out = self._step(
                                params, states[SOLO_SID], ps)
                            jax.block_until_ready(out)
                            lat.append((time.perf_counter() - t0) * 1e3)
                            outs.append(np.asarray(out))
                        except Exception as exc:
                            sup.rollback(states, ckpt)
                            sup.quarantine(SOLO_SID, self._attribution(exc))
                        continue
                    # v3: gather same-bucket runs into fixed-T chunks
                    bucket = (ps.n_pad, ps.e_pad, ps.k_max)
                    if chunk and (chunk[0].n_pad, chunk[0].e_pad,
                                  chunk[0].k_max) != bucket:
                        self._run_chunk(params, states, chunk, outs_d, lat,
                                        ctr, sup)
                        chunk = []
                    chunk.append(ps)
                    if len(chunk) == self.stream_chunk:
                        self._run_chunk(params, states, chunk, outs_d, lat,
                                        ctr, sup)
                        chunk = []
                if chunk and sup.ok(SOLO_SID):
                    self._run_chunk(params, states, chunk, outs_d, lat, ctr,
                                    sup)
        finally:
            self._shutdown(stop, [q], [th])
        total = (time.perf_counter() - t_start) * 1e3
        return states[SOLO_SID], outs, self._make_stats(lat, pre_ms, total,
                                                        ctr, sup)

    # ------------------------------------------- multi-tenant device loop ----

    def _use_stream_batched(self) -> bool:
        # every registered family batches through the same engine kernel;
        # only the engine MODE decides (non-v3 modes keep the per-snapshot
        # device loop).
        return self._use_stream()

    def _chunk_bucket(self, dims: list) -> tuple:
        """Bucket covering a whole chunk of (n, e, k) dims (one static shape
        per chunk so the chunk can batch with same-bucket chunks of other
        streams)."""
        if self.buckets is not None:
            return choose_bucket_batch(dims, self.buckets)
        return (self.n_pad, self.e_pad, self.k_max)

    # ------------------------------------------- promotion cost guard ----

    def _calibrate_bucket_times(self, params) -> Optional[dict]:
        """Measure per-bucket stream-kernel step time with a tiny warmup:
        one empty-snapshot B=1 chunk per bucket, compiled then timed.
        The measured times replace the static ``bucket_cost`` proxy in the
        promotion guard (plan.promotion_guard == "measured"); returns None
        (static fallback) if any bucket fails to calibrate — the fallback
        is WARNED about and recorded in ``ServeStats.calibration_fallback``
        instead of failing silently.

        Calibration launches are WARM-UP, not serving: they go straight
        through ``_launch_ragged`` (never ``_stage_group``), so they touch
        neither ``ServeStats.launches`` nor ``per_snapshot_ms``, and the
        ``_fault_exempt`` window keeps them out of launch-site occurrence
        counting — stats and fault windows on a run are identical with
        ``promotion_guard`` "measured" or "static" (pinned by the
        calibration-isolation regression test)."""
        din = self.feat_table.shape[1]
        de = self.cfg.edge_dim
        T = pow2_target(self.stream_chunk, cap=self.stream_chunk)
        times: dict = {}
        self._fault_exempt = True  # calibration is not a serve launch
        try:
            for bucket in self.buckets:
                chunk = [empty_padded(*bucket, din, de)] * T
                state = self.model.init_state(params, mode=self.mode)
                state_B = jax.tree.map(lambda a: a[None], state)
                run = lambda: self._launch_ragged(
                    params, state_B, [stack_time(chunk)], np.asarray([T]))
                jax.block_until_ready(run())  # compile + warm
                t0 = time.perf_counter()
                jax.block_until_ready(run())
                times[bucket] = max((time.perf_counter() - t0) * 1e3 / T,
                                    1e-6)
        except Exception as exc:
            self._calib_error = repr(exc)
            warnings.warn(
                "measured promotion-guard calibration failed; falling back "
                f"to the static bucket_cost proxy: {exc!r}", RuntimeWarning)
            return None  # static proxy fallback
        finally:
            self._fault_exempt = False
        return times

    def _measured_cost(self, bucket: tuple) -> float:
        """Per-bucket cost under the measured guard, falling back to the
        static ``bucket_cost`` proxy PER MISS: a bucket absent from the
        calibration table (first seen after calibration ran) must not
        crash the promotion pass with a bare KeyError mid-serve — it gets
        the static estimate, and the miss is warned about and recorded in
        ``ServeStats.calibration_fallback``."""
        try:
            return self._bucket_ms[bucket]
        except KeyError:
            self._calib_error = (f"bucket {bucket!r} missing from the "
                                 "measured calibration table")
            warnings.warn(
                f"measured promotion guard: {self._calib_error}; using the "
                "static bucket_cost proxy for it", RuntimeWarning)
            return bucket_cost(bucket)

    def _promotion_cost(self, params):
        """Cost function for promote_bucket_groups: measured per-bucket
        step times when the plan asks for the adaptive guard (calibrated
        lazily, once), else the static padded-compute proxy. Measured
        lookups degrade per miss instead of raising (``_measured_cost``)."""
        if self.plan.promotion_guard != "measured":
            return bucket_cost
        if self._bucket_ms is None and self._calib_error is None:
            self._bucket_ms = self._calibrate_bucket_times(params)
        if self._bucket_ms is None:
            return bucket_cost  # calibration failed: static fallback
        return self._measured_cost

    def _spawn_producers(self, streams: dict) -> tuple:
        """Start one host preprocessing thread per tenant stream (shared
        by the round-based and continuous device loops). Returns
        ``(queues, pre_ms, stop_event, threads)`` with the threads already
        running. Each queue carries ``(LocalSnapshot | PaddedSnapshot,
        dims)`` items in stream order, then ``None`` at end-of-stream — or
        a ``BaseException`` if the producer failed (validation, no-fit
        bucket, injected fault), which the device loop turns into a
        quarantine/raise per policy."""
        sids = sorted(streams)
        qs = {sid: queue.Queue(maxsize=max(self.queue_depth,
                                           self.stream_chunk))
              for sid in sids}
        pre_ms: list = []
        stop = threading.Event()

        def _put(q, item):
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def producer(sid):
            try:
                for s in streams[sid]:
                    t0 = time.perf_counter()
                    self._probe("preprocess", tenant=sid)
                    validate_snapshot(s, self.feat_table.shape[0],
                                      tenant=sid)
                    ls = renumber_and_normalize(s)
                    dims = (ls.n_nodes, ls.src.shape[0], max_in_degree(ls))
                    if self.buckets is not None:
                        self._probe("bucket", tenant=sid)
                        choose_bucket(*dims, self.buckets)  # fail fast
                    else:
                        # fixed bucket known up front: pad here so the host
                        # prep fully overlaps device work (the bucketed
                        # case defers padding until the chunk bucket — max
                        # over its members — is known on the device loop).
                        ls = pad_snapshot(ls, self.feat_table, self.n_pad,
                                          self.e_pad, self.k_max)
                    pre_ms.append((time.perf_counter() - t0) * 1e3)
                    if not _put(qs[sid], (ls, dims)):
                        return
                _put(qs[sid], None)
            except BaseException as exc:  # propagate, don't hang the consumer
                _put(qs[sid], exc)

        threads = [threading.Thread(target=producer, args=(sid,), daemon=True,
                                    name=f"dgnn-serve-producer-{sid}")
                   for sid in sids]
        for th in threads:
            th.start()
        return qs, pre_ms, stop, threads

    # ---------------------------------------------------- express lane ----

    def _spawn_express_producers(self, streams: dict, stop, pre_ms) -> tuple:
        """Producer threads for the stateless express tenants. Always
        fixed-bucket (the lane co-batches every slot into one shape, so
        padding happens host-side, fully overlapped). Items mirror the
        recurrent producers' ``(payload, dims)`` shape so both feed the
        same admission code; ``stop`` is the shared shutdown event."""
        xp = self.express.plan
        sids = sorted(streams)
        qs = {sid: queue.Queue(maxsize=max(self.queue_depth,
                                           self.stream_chunk))
              for sid in sids}

        def _put(q, item):
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def producer(sid):
            try:
                for s in streams[sid]:
                    t0 = time.perf_counter()
                    self._probe("preprocess", tenant=sid)
                    validate_snapshot(s, self._express_feat.shape[0],
                                      tenant=sid)
                    ls = renumber_and_normalize(s)
                    ps = pad_snapshot(ls, self._express_feat, xp.n_pad,
                                      xp.e_pad, xp.k_max)
                    pre_ms.append((time.perf_counter() - t0) * 1e3)
                    if not _put(qs[sid], (ps, None)):
                        return
                _put(qs[sid], None)
            except BaseException as exc:  # propagate, don't hang the consumer
                _put(qs[sid], exc)

        threads = [threading.Thread(target=producer, args=(sid,), daemon=True,
                                    name=f"dgnn-serve-express-{sid}")
                   for sid in sids]
        for th in threads:
            th.start()
        return qs, threads

    def _run_express_group(self, params_x, group: list, outs: dict,
                           lat: list, ctr: dict,
                           sup: TenantSupervisor) -> None:
        """ONE express-lane launch: ``group`` is [(sid, [PaddedSnapshot,
        ...]), ...] — every snapshot of every member becomes an
        independent T=1 slot on the BATCH axis of a single static-family
        stream launch (B pow2-padded with dead length-0 slots). The
        tenants are stateless, so no checkpoint is taken and nothing is
        rolled back; failures follow the usual retry → attribute →
        quarantine path minus the state machinery and the degradation
        ladder (there is no cheaper rung below a stateless launch)."""
        members = [m for m in group if sup.ok(m[0])]
        attempt = 0
        while members:
            slots = [(sid, ps) for sid, chunk in members for ps in chunk]
            sids = sorted({sid for sid, _ in slots})
            b_real = len(slots)
            b_target = pow2_target(b_real)
            per_slot = [stack_time([ps]) for _, ps in slots]
            per_slot.extend([per_slot[0]] * (b_target - b_real))
            lengths = np.asarray([1] * b_real + [0] * (b_target - b_real),
                                 np.int32)
            key = ("express", b_target)
            warmed = key in self._warmed
            self._launch_ctx = tuple(sids)
            try:
                self._count_launch(ctr, self.express.model.stream_family)
                ctr["express"] = ctr.get("express", 0) + 1
                t0 = time.perf_counter()
                out_BT = self._express_step(params_x,
                                            stack_streams(per_slot),
                                            jnp.asarray(lengths, jnp.int32))
                jax.block_until_ready(out_BT)
                dt_ms = (time.perf_counter() - t0) * 1e3
                self._warmed.add(key)
                timeout = self._policy.timeout_ms
                if timeout is not None and warmed and dt_ms > timeout:
                    raise LaunchTimeout(
                        f"express launch took {dt_ms:.1f}ms > "
                        f"launch_timeout_ms={timeout} (B={b_target}); "
                        "result discarded", site="launch")
                out_np = np.asarray(out_BT)
                now_ms = (time.perf_counter() - self._t0_run) * 1e3
                for b, (sid, _) in enumerate(slots):
                    outs[sid].append(out_np[b, 0])
                    self._commit_ms.setdefault(sid, []).append(now_ms)
                lat.extend([dt_ms / b_real] * b_real)
                ctr["live"] += b_real
                ctr["padded"] += b_target - b_real
                return
            except Exception as exc:
                err = self._attribution(exc)
                if isinstance(err, LaunchTimeout):
                    ctr["timeouts"] += 1
                attempt += 1
                if attempt <= self._policy.max_retries:
                    sup.note_retry(sids, attempt)
                    continue
                tenant = getattr(err, "tenant", None)
                if tenant is not None and tenant in sids:
                    sup.quarantine(tenant, err,
                                   site=getattr(err, "site", "launch"))
                    members = [m for m in members if m[0] != tenant]
                    attempt = 0
                    continue
                for sid in sids:
                    sup.quarantine(sid, err,
                                   site=getattr(err, "site", "launch"))
                return
            finally:
                self._launch_ctx = ()

    def _check_express_args(self, streams: dict, express_streams) -> list:
        """Validate the run_multi express arguments; returns the express
        sids (empty when the lane is unused)."""
        if not express_streams:
            return []
        if self.express is None:
            raise ValueError("express_streams= needs the express lane "
                             "configured: SnapshotServer(..., express="
                             "<static BoosterSession>)")
        clash = set(express_streams) & set(streams)
        if clash:
            raise ValueError(f"stream ids {sorted(map(repr, clash))} appear "
                             "in both streams and express_streams")
        return sorted(express_streams)

    def run_multi(self, params, states: dict, streams: dict, *,
                  express_streams: Optional[dict] = None,
                  express_params=None) -> tuple:
        """Serve many independent client streams concurrently.

        ``streams``: {stream_id: iterable of COOSnapshot}; ``states``:
        {stream_id: recurrent state} (one store per tenant — state is never
        shared across clients). Returns (states, {stream_id: [outputs]},
        ServeStats). Outputs per stream are in that stream's snapshot order.

        Two device loops, selected by ``plan.scheduler``:

        ``"rounds"`` (default): rounds of up-to-``stream_chunk`` snapshots
        per stream with a barrier between rounds; same-bucket chunks from
        different streams batch into one V3 launch.

        ``"continuous"``: iteration-level scheduling — no round barrier; a
        tick composes a fresh batch from whatever is READY, long backlogs
        are served in ``prefill_chunk``-bounded chunks interleaved with
        other tenants' steps, and per-tenant recurrent state lives in a
        paged pool (``state_pool_pages``) with LRU eviction to host and
        transparent recovery (serve/scheduler.ContinuousScheduler,
        docs/serve_scheduler.md). Outputs and final states are
        bit-identical to the round scheduler's.

        Both are supervised per the plan's fault-isolation policy (see the
        module docstring): with ``supervision="isolate"`` a failing tenant
        is quarantined — its error lands in ``stats.tenants[sid]``, its
        outputs stop at the last committed chunk — and the surviving
        tenants are unaffected; the strict default re-raises the first
        failure after a clean shutdown.

        EXPRESS LANE: with the server built over a second STATIC-family
        session (``SnapshotServer(..., express=<static BoosterSession>)``),
        ``express_streams`` ({sid: iterable of COOSnapshot}, disjoint from
        ``streams``) are served through it with ``express_params``. Static
        tenants are stateless — every snapshot is an independent T=1 slot —
        so each round/tick co-batches ALL ready express snapshots into one
        dedicated launch with no checkpoint/rollback around it, counted in
        ``ServeStats.express_launches`` / ``launches_by_family``. Express
        outputs land in the same outputs dict, in stream order.
        """
        x_sids = self._check_express_args(streams, express_streams)
        if self.plan.scheduler == "continuous":
            from repro.serve.scheduler import ContinuousScheduler

            return ContinuousScheduler(self).run(
                params, states, streams, express_streams=express_streams,
                express_params=express_params)
        return self._run_multi_rounds(params, states, streams,
                                      express_streams if x_sids else None,
                                      express_params)

    def _run_multi_rounds(self, params, states: dict, streams: dict,
                          express_streams: Optional[dict] = None,
                          express_params=None) -> tuple:
        """The round-based multi-tenant device loop (plan.scheduler ==
        "rounds"); see ``run_multi`` for the contract."""
        sids = sorted(streams)
        x_sids = sorted(express_streams or {})
        t_start = time.perf_counter()
        self._t0_run, self._commit_ms = t_start, {}
        qs, pre_ms, stop, threads = self._spawn_producers(streams)
        xqs: dict = {}
        if x_sids:
            xqs, x_threads = self._spawn_express_producers(
                express_streams, stop, pre_ms)
            threads = threads + x_threads
        outs: dict = {sid: [] for sid in sids + x_sids}
        lat: list = []
        ctr = {"live": 0, "padded": 0, "promoted": 0, "launches": 0,
               "timeouts": 0, "degraded": 0}
        sup = TenantSupervisor(sids + x_sids, self._policy, outputs=outs)
        active = set(sids)
        x_active = set(x_sids)
        batched = self._use_stream_batched()
        try:
            with self._fault_window():
                while active or x_active:
                    # express round: every express tenant's next chunk of
                    # T=1 slots, co-batched into ONE stateless launch
                    x_group: list = []
                    for sid in sorted(x_active):
                        chunk = []
                        while len(chunk) < self.stream_chunk:
                            item = xqs[sid].get()
                            if item is None:
                                x_active.discard(sid)
                                break
                            if isinstance(item, BaseException):
                                x_active.discard(sid)
                                chunk = []
                                sup.quarantine(sid, item,
                                               site=getattr(item, "site",
                                                            None))
                                break
                            chunk.append(item[0])
                        if chunk:
                            x_group.append((sid, chunk))
                    if x_group:
                        self._run_express_group(express_params, x_group,
                                                outs, lat, ctr, sup)
                        x_active -= set(sup.quarantined)
                    if not active:
                        continue
                    # one round: pull the next chunk of every active stream
                    chunks = {}
                    for sid in sorted(active):
                        chunk: list = []
                        dims: list = []
                        while len(chunk) < self.stream_chunk:
                            item = qs[sid].get()
                            if item is None:
                                active.discard(sid)
                                break
                            if isinstance(item, BaseException):
                                # producer-side failure (validation,
                                # no-fit bucket, injected fault): strict
                                # raises; isolate quarantines THIS tenant
                                # — outputs stop at the last committed
                                # chunk, the round continues without it
                                active.discard(sid)
                                chunk = []
                                sup.quarantine(sid, item,
                                               site=getattr(item, "site",
                                                            None))
                                break
                            chunk.append(item[0])
                            dims.append(item[1])
                            if not batched and chunk:
                                break  # non-v3 loop: no chunking
                        if chunk:
                            chunks[sid] = (chunk, dims)
                    if not chunks:
                        continue
                    if not batched:
                        # non-v3 engine modes: round-robin per-snapshot
                        # stepping, checkpointed per snapshot
                        for sid, (chunk, dims) in sorted(chunks.items()):
                            if not sup.ok(sid):
                                continue
                            ckpt = sup.checkpoint(states, [sid])
                            try:
                                for ls, d in zip(chunk, dims):
                                    ps = (ls if isinstance(ls, PaddedSnapshot)
                                          else pad_snapshot(
                                              ls, self.feat_table,
                                              *self._chunk_bucket([d])))
                                    ckpt = sup.checkpoint(states, [sid])
                                    t0 = time.perf_counter()
                                    states[sid], out = self._step(
                                        params, states[sid], ps)
                                    jax.block_until_ready(out)
                                    lat.append(
                                        (time.perf_counter() - t0) * 1e3)
                                    outs[sid].append(np.asarray(out))
                            except Exception as exc:
                                sup.rollback(states, ckpt)
                                sup.quarantine(sid, self._attribution(exc))
                                active.discard(sid)
                        continue
                    # group same-bucket chunks across streams -> one
                    # supervised launch each
                    groups: dict = {}
                    for sid, (chunk, dims) in sorted(chunks.items()):
                        bucket = self._chunk_bucket(dims)
                        groups.setdefault(bucket, []).append(
                            (sid, chunk, bucket))
                    if (self.promote_buckets is not None
                            and self.buckets is not None):
                        # cross-bucket batching: promote smaller-bucket
                        # chunks into the next-larger in-flight bucket
                        # (guarded by the per-bucket cost ratio — measured
                        # step times under the plan's adaptive guard, else
                        # the static padded-compute proxy) so they join its
                        # launch instead of paying their own dispatch.
                        before = {b: len(m) for b, m in groups.items()}
                        groups = promote_bucket_groups(
                            groups, self.buckets, self.promote_buckets,
                            cost=self._promotion_cost(params))
                        ctr["promoted"] += sum(
                            len(m) - before.get(b, 0)
                            for b, m in groups.items())
                    for bucket in sorted(groups):
                        self._run_group_supervised(params, states,
                                                   groups[bucket], outs,
                                                   lat, ctr, sup)
                    # tenants quarantined by the launch path stop being
                    # scheduled (their producers are drained at shutdown)
                    active -= set(sup.quarantined)
        finally:
            self._shutdown(stop, list(qs.values()) + list(xqs.values()),
                           threads)
        total = (time.perf_counter() - t_start) * 1e3
        return states, outs, self._make_stats(lat, pre_ms, total, ctr, sup)
