"""DGNN snapshot-stream serving engine — the paper's deployment mode.

Implements the §IV-D task-scheduling scheme:
  host thread ("CPU tasks"): slice the temporal COO stream into snapshots,
    renumber + normalize, build ELL, pad into the bucket — irregular,
    control-heavy work;
  device loop ("FPGA tasks"): the jitted DGNN step (format-consuming dense
    compute) pulls prepared snapshots from a DOUBLE-BUFFERED queue, so
    graph loading overlaps inference (the paper's GL/GNN overlap, host
    edition — the in-graph edition is the V1 ping-pong carry).

Also hosts the batched-streams production mode: many independent dynamic
graphs served concurrently, streams sharded over (pod, data).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Iterable, Optional

import jax
import numpy as np

from repro.configs.dgnn import DGNNConfig
from repro.core.dataflow import build_model
from repro.graph.coo import COOSnapshot
from repro.graph.csr import max_in_degree, renumber_and_normalize
from repro.graph.padding import PaddedSnapshot, pad_snapshot


@dataclass
class ServeStats:
    per_snapshot_ms: list
    preprocess_ms: list
    total_ms: float

    @property
    def mean_latency_ms(self) -> float:
        return float(np.mean(self.per_snapshot_ms)) if self.per_snapshot_ms else 0.0


class SnapshotServer:
    """Streaming DGNN inference over a snapshot iterator."""

    def __init__(self, cfg: DGNNConfig, feat_table: np.ndarray,
                 n_global: int, mode: Optional[str] = None,
                 n_pad: int = 640, e_pad: int = 4096, k_max: int = 64,
                 queue_depth: int = 2):
        self.cfg = cfg
        self.mode = mode or cfg.dataflow
        self.model = build_model(cfg, n_global=n_global)
        self.feat_table = feat_table
        self.n_pad, self.e_pad, self.k_max = n_pad, e_pad, k_max
        self.queue_depth = queue_depth  # 2 == ping-pong buffers
        self._step = jax.jit(
            lambda p, s, snap: self.model.step(p, s, snap, mode=self.mode))

    def init(self, rng):
        params = self.model.init(rng)
        state = self.model.init_state(params, mode=self.mode)
        return params, state

    # ------------------------------------------------------ host thread ----

    def _preprocess(self, snap: COOSnapshot) -> PaddedSnapshot:
        # fixed bucket: shapes must be static so the jitted step never
        # recompiles (the "snapshot fits in BRAM" contract; overflow = the
        # bucket chooser picked wrong and should raise)
        ls = renumber_and_normalize(snap)
        return pad_snapshot(ls, self.feat_table, self.n_pad, self.e_pad,
                            self.k_max)

    def run(self, params, state, snaps: Iterable[COOSnapshot]) -> tuple:
        """Returns (final_state, outputs list, ServeStats)."""
        q: queue.Queue = queue.Queue(maxsize=self.queue_depth)
        pre_ms: list = []

        def producer():
            for s in snaps:
                t0 = time.perf_counter()
                ps = self._preprocess(s)
                pre_ms.append((time.perf_counter() - t0) * 1e3)
                q.put(ps)
            q.put(None)

        th = threading.Thread(target=producer, daemon=True)
        t_start = time.perf_counter()
        th.start()
        outs, lat = [], []
        while True:
            ps = q.get()
            if ps is None:
                break
            t0 = time.perf_counter()
            state, out = self._step(params, state, ps)
            jax.block_until_ready(out)
            lat.append((time.perf_counter() - t0) * 1e3)
            outs.append(np.asarray(out))
        th.join()
        total = (time.perf_counter() - t_start) * 1e3
        return state, outs, ServeStats(lat, pre_ms, total)
