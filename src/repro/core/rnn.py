"""Recurrent cells (GRU / LSTM) with staged vs fused gate computation.

``fused=False`` computes each gate's matmul separately — this models the
paper's *unpipelined* RNN baseline where stages run back-to-back.
``fused=True`` is the Pipeline-O1 optimization: all gates issued as one
concatenated matmul + one fused elementwise block (the MXU analogue of the
paper's FIFO-connected pipelined RNN stages: no bubbles between small ops).
The two paths are bit-identical in math (same weights, concatenated).

The matrix-GRU used by EvolveGCN-O reuses the same cell: columns of the
weight matrix are the batch, the matrix is both input and hidden state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _glorot(rng, shape):
    scale = jnp.sqrt(2.0 / (shape[0] + shape[-1]))
    return jax.random.normal(rng, shape, jnp.float32) * scale


def init_gru(rng, din: int, hidden: int) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "wx": _glorot(k1, (din, 3 * hidden)),    # [r | z | n]
        "wh": _glorot(k2, (hidden, 3 * hidden)),
        "b": jnp.zeros((3 * hidden,), jnp.float32),
    }


def gru_cell(params: dict, x: jax.Array, h: jax.Array, *, fused: bool = True) -> jax.Array:
    hdim = h.shape[-1]
    if fused:
        gx = x @ params["wx"] + params["b"]
        gh = h @ params["wh"]
        rx, zx, nx = jnp.split(gx, 3, axis=-1)
        rh, zh, nh = jnp.split(gh, 3, axis=-1)
    else:
        wxr, wxz, wxn = jnp.split(params["wx"], 3, axis=-1)
        whr, whz, whn = jnp.split(params["wh"], 3, axis=-1)
        br, bz, bn = jnp.split(params["b"], 3, axis=-1)
        rx, zx, nx = x @ wxr + br, x @ wxz + bz, x @ wxn + bn
        rh, zh, nh = h @ whr, h @ whz, h @ whn
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    return (1.0 - z) * n + z * h


def init_lstm(rng, din: int, hidden: int) -> dict:
    k1, k2 = jax.random.split(rng)
    b = jnp.zeros((4 * hidden,), jnp.float32)
    # forget-gate bias 1.0 (standard)
    b = b.at[hidden : 2 * hidden].set(1.0)
    return {
        "wx": _glorot(k1, (din, 4 * hidden)),    # [i | f | g | o]
        "wh": _glorot(k2, (hidden, 4 * hidden)),
        "b": b,
    }


def lstm_gates(params: dict, x: jax.Array, h: jax.Array, *, fused: bool = True) -> jax.Array:
    if fused:
        return x @ params["wx"] + h @ params["wh"] + params["b"]
    wx4 = jnp.split(params["wx"], 4, axis=-1)
    wh4 = jnp.split(params["wh"], 4, axis=-1)
    b4 = jnp.split(params["b"], 4, axis=-1)
    return jnp.concatenate(
        [x @ a + h @ c + d for a, c, d in zip(wx4, wh4, b4)], axis=-1
    )


def lstm_apply_gates(gates: jax.Array, c: jax.Array) -> tuple[jax.Array, jax.Array]:
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def lstm_cell(params: dict, x: jax.Array, h: jax.Array, c: jax.Array, *,
              fused: bool = True) -> tuple[jax.Array, jax.Array]:
    return lstm_apply_gates(lstm_gates(params, x, h, fused=fused), c)


def matrix_gru(params: dict, w: jax.Array, *, fused: bool = True) -> jax.Array:
    """EvolveGCN-O weight evolution: W^t = GRU(input=W^{t-1}, hidden=W^{t-1}).

    ``w`` is (din, dout); columns are the GRU batch, so the cell runs on
    w^T with feature dim = din. Cell params are square (din -> din).
    """
    wt = w.T  # (dout, din): batch of column vectors
    out = gru_cell(params, wt, wt, fused=fused)
    return out.T
