"""Event-driven temporal GNN with node memory (TGN/TGAT lineage).

The "event" temporal contract's model: instead of graph snapshots, the
stream is a sequence of EVENT BATCHES (graph/events.PaddedEventBlock —
timestamped interactions padded into the engine's ELL row layout over the
batch's touched nodes). Per batch, every touched node

  1. aggregates its event partners' previous memory (mean over its
     events in the batch),
  2. aggregates the sinusoidal TIME ENCODING of its events,
     ``cos(t * freq_d)`` with learnable log-spaced per-dim frequencies
     (the TGAT functional form),
  3. feeds ``x @ W_in + agg_mem + agg_time`` and its own previous memory
     through a GRU,

and writes the new memory back at its global row only — untouched nodes
carry their memory forward unchanged. The recurrent state is the global
node-memory store ``(n_global, hidden)``; under the stream engine
(level="v3") it stays VMEM-resident across all T event batches, crossing
HBM twice per stream, and ragged event streams ride the engine's
``lengths`` masking exactly like ragged-T snapshot streams.

Dataflow modes: baseline (per-batch XLA step) and v3 (the time-fused
stream engine) — the event family has no historical module-overlap or
intra-step-fusion ladders.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.dgnn import DGNNConfig
from repro.core import rnn as R
from repro.graph.events import PaddedEventBlock


def init_time_encoding(hidden: int) -> jax.Array:
    """Deterministic log-spaced frequencies 10^0 .. 10^-4 (the TGAT
    initialization); learnable thereafter — they live in params."""
    return (1.0 / (10.0 ** jnp.linspace(0.0, 4.0, hidden))).astype(
        jnp.float32)


class TGNModel:
    # cell spec this model dispatches to in the stream-engine registry
    stream_family = "tgn"

    def __init__(self, cfg: DGNNConfig, impl: str = "xla",
                 n_global: int = 4096):
        assert cfg.dgnn_type == "event_memory"
        self.cfg = cfg
        self.impl = impl
        self.n_global = n_global

    def init(self, rng) -> dict:
        cfg = self.cfg
        kw, kg = jax.random.split(rng)
        scale = 1.0 / jnp.sqrt(cfg.in_dim)
        return {
            "freq": init_time_encoding(cfg.hidden),
            "w_in": jax.random.uniform(kw, (cfg.in_dim, cfg.hidden),
                                       jnp.float32, -scale, scale),
            "gru": R.init_gru(kg, cfg.hidden, cfg.hidden),
        }

    def init_state(self, params: dict, mode: str = "baseline") -> dict:
        mem = jnp.zeros((self.n_global, self.cfg.hidden), jnp.float32)
        return {"mem": mem}

    # ---------------------------------------------------- per batch ----

    def _gather(self, store, blk):
        safe = jnp.where(blk.renumber >= 0, blk.renumber, 0)
        return store[safe] * blk.node_mask[:, None]

    def _scatter(self, store, blk, val):
        idx = jnp.where(blk.renumber >= 0, blk.renumber, self.n_global)
        return store.at[idx].set(val, mode="drop")

    def step(self, params: dict, state: dict, blk: PaddedEventBlock, *,
             mode: str = "baseline") -> tuple[dict, jax.Array]:
        """One event batch through the XLA path (every mode computes the
        same math; v3 only changes where the memory store lives)."""
        mem = self._gather(state["mem"], blk)
        coef = blk.neigh_coef[..., None]
        agg_m = (mem[blk.neigh_idx] * coef).sum(axis=1)
        enc = jnp.cos(blk.neigh_ts[..., None] * params["freq"][None, None, :])
        agg_e = (enc * coef).sum(axis=1)
        inp = blk.node_feat @ params["w_in"] + agg_m + agg_e
        m_new = R.gru_cell(params["gru"], inp, mem,
                           fused=mode != "baseline")
        m_new = m_new * blk.node_mask[:, None]
        return {"mem": self._scatter(state["mem"], blk, m_new)}, m_new

    # ------------------------------------------------- stream engine ----

    def _stream(self, params: dict, state: dict, blocks, batched: bool,
                tn=128, td="cfg", lengths=None, device=None,
                state_residency="vmem", buffer_depth=None,
                force_ref=False):
        from repro.kernels import ops as kops

        td = self.cfg.stream_td if td == "cfg" else td
        g = params["gru"]
        args = (blocks.neigh_idx, blocks.neigh_coef, blocks.neigh_ts,
                blocks.node_feat, blocks.renumber, blocks.node_mask,
                state["mem"], params["freq"], params["w_in"],
                g["wx"], g["wh"], g["b"])
        if batched:
            outs, mem_T = kops.stream_steps_batched(
                self.stream_family, *args, tn=tn, td=td, lengths=lengths,
                device=device,
                state_residency=state_residency, buffer_depth=buffer_depth,
                force_ref=force_ref)
        else:
            outs, mem_T = kops.stream_steps(self.stream_family, *args,
                                            tn=tn, td=td,
                                            state_residency=state_residency,
                                            buffer_depth=buffer_depth,
                                            force_ref=force_ref)
        return {"mem": mem_T}, outs

    def step_stream(self, params: dict, state: dict,
                    blocks_T: PaddedEventBlock, *, tn=128, td="cfg",
                    state_residency="vmem", buffer_depth=None
                    ) -> tuple[dict, jax.Array]:
        """V3: the whole (T, ...) event-batch stream through the engine,
        the node-memory store VMEM-resident across batches."""
        return self._stream(params, state, blocks_T, batched=False, tn=tn,
                            td=td, state_residency=state_residency,
                            buffer_depth=buffer_depth)

    def step_stream_batched(self, params: dict, state: dict,
                            blocks_BT: PaddedEventBlock, *, tn=128,
                            td="cfg", lengths=None, device=None,
                            state_residency="vmem", buffer_depth=None,
                            force_ref=False) -> tuple[dict, jax.Array]:
        """Batched V3: B independent event streams, ragged via
        ``lengths`` (now counting EVENT BATCHES, not snapshots)."""
        return self._stream(params, state, blocks_BT, batched=True, tn=tn,
                            td=td, lengths=lengths, device=device,
                            state_residency=state_residency,
                            buffer_depth=buffer_depth,
                            force_ref=force_ref)
