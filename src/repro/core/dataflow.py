"""Dataflow engines: sequential baseline, Pipeline-O1, V1, V2, V3.

These wrap a DGNN model's per-snapshot step into a scan over the snapshot
stream, reproducing the paper's ablation levels (Fig. 6):

  baseline     strict GNN/RNN chain per time step, staged RNN gates.
  o1           Pipeline-O1: fused RNN gate pipeline.
  v1 (o2)      Pipeline-O2 for stacked/weights-evolved DGNNs: module-level
               overlap of GNN and RNN in ADJACENT time steps. For
               weights-evolved models the overlap is expressed through the
               primed carry (see core/evolvegcn.py); for stacked models it
               is classic software pipelining with a one-step pipeline
               register (prologue/epilogue below).
  v2 (o2)      Pipeline-O2 for stacked/integrated DGNNs: intra-step fusion
               (node-queue analogue) via the fused Pallas kernel.
  v3           Time-fused stream: the whole T-step stream runs inside ONE
               launch of the generic stream-engine kernel
               (kernels/stream_fused.py) with the recurrent state living
               in VMEM scratch between snapshots — the paper's
               BRAM-resident intermediate results. Every model exposes it
               as ``step_stream`` and dispatches by its ``stream_family``
               through the engine's cell-spec REGISTRY: GCRN/stacked keep
               the (n_global, H) node-state store resident (h/c cross HBM
               once per stream instead of once per step), and EvolveGCN
               keeps its per-layer evolving weight matrices resident with
               the matrix-GRU evolution running in-kernel between
               snapshots (W_l crosses HBM twice per stream instead of
               twice per step). State stores larger than VMEM stream in
               (n_global, td) column tiles via the engine's D grid axis
               (cfg.stream_td; see docs/stream_engine.md).

Ablation summary (what each level removes from the critical path):

  level     | scope of fusion       | recurrent-state HBM traffic
  baseline  | none                  | 2T transfers / stream (in + out each step)
  o1        | RNN gate pipeline     | 2T
  v1        | adjacent-step overlap | 2T (pipeline register added)
  v2        | intra-step GNN+RNN    | 2T (gate tensor stays in VMEM)
  v3        | whole stream          | 2  (state resident across all T steps)

(for EvolveGCN the "recurrent state" column reads on the evolving weight
matrices instead of the node-state store — same 2T -> 2 reduction.)

All modes compute IDENTICAL outputs for the same params/stream — that is
the correctness contract the paper verifies against PyTorch, and what our
tests assert. The difference is the critical path / fusion structure, which
shows up in the lowered HLO (benchmarks/fig6_ablation.py measures it).

Snapshot streams are pytrees with a leading T axis (same padding bucket);
multi-stream batching adds a B axis (``run_plan_batched``): v3 runs the
whole (B, T) batch in ONE batched stream-kernel launch — optionally
RAGGED over T (per-stream lengths) and sharded over devices (DeviceSpec),
both carried by the plan — while other levels vmap the per-stream scan.

Dispatch is by typed StreamPlan (repro.api): ``run_plan`` /
``run_plan_batched`` execute a validated plan; the historical mode-string
entry points ``run_stream`` / ``run_batched`` survive as deprecated shims
that build the equivalent plan.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.dgnn import DGNNConfig
from repro.core.evolvegcn import EvolveGCN
from repro.core.gcn import StaticGCN
from repro.core.gcrn import GCRN
from repro.core.stacked import StackedDGNN
from repro.core.tgn import TGNModel

Model = Any  # EvolveGCN | GCRN | StackedDGNN | StaticGCN | TGNModel


def build_model(cfg: DGNNConfig, impl: str = "xla", n_global: int = 4096) -> Model:
    if cfg.dgnn_type == "weights_evolved":
        return EvolveGCN(cfg, impl=impl)
    if cfg.dgnn_type == "integrated":
        return GCRN(cfg, impl=impl, n_global=n_global)
    if cfg.dgnn_type == "stacked":
        return StackedDGNN(cfg, impl=impl, n_global=n_global)
    if cfg.dgnn_type == "static":
        return StaticGCN(cfg, impl=impl, n_global=n_global)
    if cfg.dgnn_type == "event_memory":
        return TGNModel(cfg, impl=impl, n_global=n_global)
    raise ValueError(cfg.dgnn_type)


def _scan_steps(model: Model, params, state0, snaps_T, mode: str):
    def body(state, snap):
        new_state, out = model.step(params, state, snap, mode=mode)
        return new_state, out

    return jax.lax.scan(body, state0, snaps_T)


def _run_stacked_v1(model: StackedDGNN, params, state0, snaps_T):
    """Software-pipelined stacked DGNN: GCN(G^t) overlaps GRU(X^{t-1}).

    Pipeline register: (X^{t-1}, snap^{t-1}). Prologue computes X^0;
    body t>=1 computes X^t (GNN) and consumes X^{t-1} (RNN) — two
    independent subgraphs inside one scan iteration. Epilogue drains the
    last X. Outputs are identical to the sequential schedule.
    """
    first = jax.tree.map(lambda a: a[0], snaps_T)
    rest = jax.tree.map(lambda a: a[1:], snaps_T)
    x0 = model.gnn(params, first)  # prologue

    def body(carry, snap):
        state, x_prev, snap_prev = carry
        # independent: GNN on this step's graph, RNN on last step's output
        x_t = model.gnn(params, snap)
        new_state, h = model.rnn(params, state, snap_prev, x_prev, fused=True)
        return (new_state, x_t, snap), h

    (state, x_last, snap_last), outs = jax.lax.scan(body, (state0, x0, first), rest)
    state, h_last = model.rnn(params, state, snap_last, x_last, fused=True)  # epilogue
    outs = jnp.concatenate([outs, h_last[None]], axis=0)
    return state, outs


def run_plan(model: Model, params, state0, snaps_T, plan):
    """Execute a typed StreamPlan (repro.api) on one (T, ...) stream.

    The plan's ``level`` selects the dataflow engine and its ``tn``/``td``
    the engine tiling; validity was established when the plan was built,
    so there is no mode-string dispatch left to go wrong here. Returns
    (final_state, outputs (T, n_pad, out_dim)).
    """
    if plan.lengths is not None:
        raise ValueError("plan carries ragged lengths — a batched-launch "
                         "capability; use run_plan_batched")
    if plan.level == "v1" and isinstance(model, StackedDGNN):
        return _run_stacked_v1(model, params, state0, snaps_T)
    if plan.level == "v3":
        # every family has a time-fused stream engine: node-state-resident
        # for GCRN/stacked, weights-resident for EvolveGCN.
        return model.step_stream(params, state0, snaps_T, tn=plan.tn,
                                 td=plan.td,
                                 state_residency=plan.state_residency,
                                 buffer_depth=plan.buffer_depth)
    return _scan_steps(model, params, state0, snaps_T, plan.level)


def run_plan_batched(model: Model, params, states0, snaps_BT, plan,
                     lengths=None):
    """Execute a StreamPlan on B independent streams: snaps arrays are
    (B, T, ...), states (B, ...). Params are shared across streams;
    recurrent state is not. This is the production throughput axis
    (DESIGN §4).

    level="v3" dispatches to the model's ``step_stream_batched`` — the
    batch axis becomes a leading grid dimension of ONE time-fused kernel
    launch (kernels/stream_fused.py), so every stream's recurrent state
    still crosses HBM exactly twice — carrying the plan's two
    batch-capabilities: ``lengths`` (ragged per-stream T, masked in-launch)
    and ``device`` (DeviceSpec sharding of the B grid axis). Other levels
    vmap the per-stream engine (equal T only)."""
    # static families carry an EMPTY state pytree — the batch size then
    # comes from the snapshot leaves instead.
    leaves = jax.tree.leaves(states0) or jax.tree.leaves(snaps_BT)
    B = leaves[0].shape[0]
    if B != plan.batch:
        raise ValueError(f"plan.batch={plan.batch} but the state batch "
                         f"is {B}")
    lengths = plan.lengths if lengths is None else lengths
    if plan.level == "v3":
        lens = None if lengths is None else jnp.asarray(lengths, jnp.int32)
        return model.step_stream_batched(params, states0, snaps_BT,
                                         tn=plan.tn, td=plan.td,
                                         lengths=lens, device=plan.device,
                                         state_residency=plan.state_residency,
                                         buffer_depth=plan.buffer_depth)
    if lengths is not None:
        raise ValueError("ragged lengths need the stream engine "
                         f"(level='v3'); level={plan.level!r}")
    fn = lambda st, sT: run_plan(model, params, st, sT, plan)
    return jax.vmap(fn)(states0, snaps_BT)


# ------------------------------------------------- deprecated shims ----
# The historical mode-string surface. New code builds a typed plan
# (repro.api.plan / BoosterSession); these shims construct the equivalent
# plan and execute it, so their outputs are bit-identical to the plan
# path by construction.

def _shim_plan(model: Model, mode: str, batch: int = 1):
    from repro import api

    return api.plan(family=model.stream_family, level=mode,
                    td=model.cfg.stream_td, batch=batch)


def run_stream(model: Model, params, state0, snaps_T, mode: str = "baseline"):
    """Deprecated: build a repro.api.StreamPlan instead (this shim does,
    then executes it). Returns (final_state, outputs (T, n_pad, out_dim))."""
    import warnings

    warnings.warn(
        "core.dataflow.run_stream is deprecated: build a typed plan "
        "(repro.api.plan / BoosterSession.run) instead",
        DeprecationWarning, stacklevel=2)
    return run_plan(model, params, state0, snaps_T, _shim_plan(model, mode))


def run_batched(model: Model, params, states0, snaps_TB, mode: str = "baseline"):
    """Deprecated: build a repro.api.StreamPlan instead (this shim does,
    then executes it). Batched streams in the historical (T, B, ...)
    layout; see ``run_plan_batched`` for the (B, T, ...) plan executor."""
    import warnings

    warnings.warn(
        "core.dataflow.run_batched is deprecated: build a typed plan "
        "(repro.api.plan / BoosterSession.run_batched) instead",
        DeprecationWarning, stacklevel=2)
    snaps_BT = jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), snaps_TB)
    leaves = jax.tree.leaves(states0) or jax.tree.leaves(snaps_BT)
    B = leaves[0].shape[0]
    state, outs_BT = run_plan_batched(model, params, states0, snaps_BT,
                                      _shim_plan(model, mode, batch=B))
    return state, jnp.swapaxes(outs_BT, 0, 1)


def init_states_batched(model: Model, params, n_streams: int,
                        mode: str = "baseline"):
    """Stack ``n_streams`` independent recurrent states along a leading B
    axis (each stream starts from the model's fresh state)."""
    s0 = model.init_state(params, mode=mode)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_streams,) + a.shape), s0)


def stack_time(padded_snaps: list) -> Any:
    """Stack per-step PaddedSnapshots (same bucket) along a leading T axis."""
    import numpy as np

    return jax.tree.map(lambda *xs: np.stack(xs, axis=0), *padded_snaps)
