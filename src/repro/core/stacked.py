"""Stacked DGNN (GCRN-M1 / WD-GCN style) — GNN feeds a per-node GRU.

The third discrete-time DGNN type of Table I, included so the framework
covers the whole taxonomy (both V1 and V2 apply to it):

    X^t = GCN(G^t)                 (independent across time)
    h^t = GRU(X^t, h^{t-1})        (chained across time, per node)

Dataflow modes:
  baseline   GCN then GRU, chained inside every step.
  o1         + fused-gate GRU.
  v1         software-pipelined: the scan body computes GCN(G^{t}) and
             GRU(X^{t-1}) concurrently (X carried in the state, one-step
             prologue/epilogue handled in core/dataflow.py).
  v2         intra-step fusion via the Pallas fused kernel (GRU variant).
  v3         time fusion (``step_stream``): last GCN layer + GRU for the
             whole stream in one Pallas kernel, the global h store
             VMEM-resident across all T steps (kernels/stream_fused.py).
             Earlier GCN layers are time-independent and run vmapped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.dgnn import DGNNConfig
from repro.core import gcn as G
from repro.core import rnn as R
from repro.graph.padding import PaddedSnapshot


class StackedDGNN:
    # cell spec this model dispatches to in the stream-engine registry
    stream_family = "stacked"

    def __init__(self, cfg: DGNNConfig, impl: str = "xla", n_global: int = 4096):
        assert cfg.dgnn_type == "stacked"
        self.cfg = cfg
        self.impl = impl
        self.n_global = n_global

    def init(self, rng) -> dict:
        cfg = self.cfg
        keys = jax.random.split(rng, cfg.n_gnn_layers + 1)
        layers = []
        din = cfg.in_dim
        for l in range(cfg.n_gnn_layers):
            layers.append(G.init_gcn_layer(keys[l], din, cfg.hidden, cfg.edge_dim if l == 0 else 0))
            din = cfg.hidden
        return {"gcn": layers, "gru": R.init_gru(keys[-1], cfg.hidden, cfg.hidden)}

    def init_state(self, params: dict, mode: str = "baseline") -> dict:
        # v1's pipeline register (X^{t-1}) is managed by core/dataflow.py,
        # not stored here — the recurrent state is just the global h store.
        h = jnp.zeros((self.n_global, self.cfg.hidden), jnp.float32)
        return {"h": h}

    def _gather(self, store, snap):
        safe = jnp.where(snap.renumber >= 0, snap.renumber, 0)
        return store[safe] * snap.node_mask[:, None]

    def _scatter(self, store, snap, val):
        idx = jnp.where(snap.renumber >= 0, snap.renumber, self.n_global)
        return store.at[idx].set(val, mode="drop")

    def gnn(self, params: dict, snap: PaddedSnapshot) -> jax.Array:
        return G.gcn_forward(params["gcn"], snap, snap.node_feat, impl=self.impl)

    def rnn(self, params: dict, state: dict, snap: PaddedSnapshot, x: jax.Array,
            *, fused: bool) -> tuple[dict, jax.Array]:
        h = self._gather(state["h"], snap)
        h_new = R.gru_cell(params["gru"], x, h, fused=fused) * snap.node_mask[:, None]
        return {"h": self._scatter(state["h"], snap, h_new)}, h_new

    def step(self, params: dict, state: dict, snap: PaddedSnapshot, *,
             mode: str = "baseline") -> tuple[dict, jax.Array]:
        if mode == "v2":
            from repro.kernels import ops as kops

            w_edge = params["gcn"][0].get("w_edge")
            # single-layer GNN fast path feeds the fused kernel; deeper GNNs
            # stream their last layer through it.
            x = snap.node_feat
            for p in params["gcn"][:-1]:
                x = G.gcn_layer(p, snap, x, impl=self.impl)
            p_last = params["gcn"][-1]
            h = self._gather(state["h"], snap)
            edge_msg = (snap.edge_feat @ w_edge) if (w_edge is not None and len(params["gcn"]) == 1) else None
            h_new = kops.stacked_fused_step(
                snap.neigh_idx, snap.neigh_coef, snap.neigh_eidx,
                x, h,
                p_last["w"], p_last["b"],
                params["gru"]["wx"], params["gru"]["wh"], params["gru"]["b"],
                edge_msg,
            )
            h_new = h_new * snap.node_mask[:, None]
            return {"h": self._scatter(state["h"], snap, h_new)}, h_new
        fused = mode in ("o1", "v1")
        x = self.gnn(params, snap)
        new_state, h_new = self.rnn(params, state, snap, x, fused=fused)
        return new_state, h_new

    def _stream(self, params: dict, state: dict, snaps, batched: bool,
                tn=128, td="cfg", lengths=None, device=None,
                state_residency="vmem", buffer_depth=None,
                force_ref=False):
        """Shared plumbing for the (batched) stream-engine dispatch.

        GCN layers before the last have no temporal dependence, so they
        run vmapped outside the kernel (doubly vmapped when batched: time-
        AND stream-independent); the last layer + GRU + store
        gather/scatter execute inside the engine with h resident in
        VMEM."""
        from repro.kernels import ops as kops

        td = self.cfg.stream_td if td == "cfg" else td
        gcn_vmap = jax.vmap if not batched else (
            lambda f: jax.vmap(jax.vmap(f)))
        x = snaps.node_feat
        for p in params["gcn"][:-1]:
            x = gcn_vmap(
                lambda s, xx, p=p: G.gcn_layer(p, s, xx, impl=self.impl)
            )(snaps, x)
        p_last = params["gcn"][-1]
        w_edge = params["gcn"][0].get("w_edge")
        edge_msg = (snaps.edge_feat @ w_edge
                    if (w_edge is not None and len(params["gcn"]) == 1)
                    else None)
        args = (snaps.neigh_idx, snaps.neigh_coef, snaps.neigh_eidx,
                x, snaps.renumber, snaps.node_mask, state["h"],
                p_last["w"], p_last["b"],
                params["gru"]["wx"], params["gru"]["wh"], params["gru"]["b"],
                edge_msg)
        if batched:
            outs_h, h_T = kops.stream_steps_batched(
                self.stream_family, *args, tn=tn, td=td, lengths=lengths,
                device=device,
                state_residency=state_residency, buffer_depth=buffer_depth,
                force_ref=force_ref)
        else:
            outs_h, h_T = kops.stream_steps(self.stream_family, *args,
                                            tn=tn, td=td,
                                            state_residency=state_residency,
                                            buffer_depth=buffer_depth,
                                            force_ref=force_ref)
        return {"h": h_T}, outs_h

    def step_stream(self, params: dict, state: dict, snaps_T: PaddedSnapshot,
                    *, tn=128, td="cfg", state_residency="vmem",
                    buffer_depth=None) -> tuple[dict, jax.Array]:
        """V3: whole (T, ...) stream through the stream engine."""
        return self._stream(params, state, snaps_T, batched=False, tn=tn,
                            td=td, state_residency=state_residency,
                            buffer_depth=buffer_depth)

    def step_stream_batched(self, params: dict, state: dict,
                            snaps_BT: PaddedSnapshot, *, tn=128, td="cfg",
                            lengths=None, device=None,
                            state_residency="vmem", buffer_depth=None,
                            force_ref=False) -> tuple[dict, jax.Array]:
        """Batched V3: B independent streams — (B, T, ...) leaves, state
        leaves (B, n_global, H) — through one launch of the batched stream
        engine. ``lengths`` runs the launch ragged over T; ``device``
        (DeviceSpec) shards the batch axis; ``force_ref`` takes the XLA
        oracle path (the serve engine's degraded-mode rung)."""
        return self._stream(params, state, snaps_BT, batched=True, tn=tn,
                            td=td, lengths=lengths, device=device,
                            state_residency=state_residency,
                            buffer_depth=buffer_depth,
                            force_ref=force_ref)
