"""GCN spatial encoder with message passing + edge embeddings.

The paper implements its GNN with the GenGNN message-passing mechanism and
highlights edge-embedding support. We follow the paper's stage split:

  MP (message passing): for each node v, agg[v] = sum over in-edges (u->v)
      of coef(u,v) * (x[u] + proj(edge_feat)), with coef the symmetric GCN
      normalization (precomputed host-side during renumbering);
  NT (node transform): h'[v] = act(agg[v] @ W + b).

Two device paths compute the same math:
  impl="xla"    edge-parallel gather + segment_sum (reference, used by the
                pjit production path — XLA fuses it well on TPU),
  impl="pallas" the ELL SpMM Pallas kernel (kernels/csr_spmm.py), the V2
                building block with VMEM-resident node features.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.padding import PaddedSnapshot


def init_gcn_layer(rng, din: int, dout: int, edge_dim: int) -> dict:
    kw, ke = jax.random.split(rng)
    scale = 1.0 / jnp.sqrt(din)
    p = {
        "w": jax.random.uniform(kw, (din, dout), jnp.float32, -scale, scale),
        "b": jnp.zeros((dout,), jnp.float32),
    }
    if edge_dim:
        escale = 1.0 / jnp.sqrt(edge_dim)
        p["w_edge"] = jax.random.uniform(ke, (edge_dim, din), jnp.float32, -escale, escale)
    return p


def propagate_segment(snap: PaddedSnapshot, x: jax.Array, w_edge=None) -> jax.Array:
    """MP stage, edge-parallel reference: (e_pad) gathers + segment_sum."""
    msgs = x[snap.src]
    if w_edge is not None:
        msgs = msgs + snap.edge_feat @ w_edge
    msgs = msgs * snap.coef[:, None]
    return jax.ops.segment_sum(msgs, snap.dst, num_segments=x.shape[0])


def propagate_ell(snap: PaddedSnapshot, x: jax.Array, w_edge=None) -> jax.Array:
    """MP stage via the ELL layout (same layout the Pallas kernel consumes)."""
    from repro.kernels import ops as kops

    edge_msg = snap.edge_feat @ w_edge if w_edge is not None else None
    return kops.ell_spmm(snap.neigh_idx, snap.neigh_coef, snap.neigh_eidx, x, edge_msg)


def gcn_layer(params: dict, snap: PaddedSnapshot, x: jax.Array, *,
              act=jax.nn.relu, impl: str = "xla") -> jax.Array:
    """One GCN layer: MP then NT (the paper's stage order)."""
    w_edge = params.get("w_edge")
    if impl == "pallas":
        agg = propagate_ell(snap, x, w_edge)
    else:
        agg = propagate_segment(snap, x, w_edge)
    h = agg @ params["w"] + params["b"]
    if act is not None:
        h = act(h)
    return h * snap.node_mask[:, None]


def gcn_forward(layers: list[dict], snap: PaddedSnapshot, x: jax.Array, *,
                impl: str = "xla") -> jax.Array:
    """Multi-layer GCN; last layer linear (standard GCN head)."""
    for i, p in enumerate(layers):
        last = i == len(layers) - 1
        x = gcn_layer(p, snap, x, act=None if last else jax.nn.relu, impl=impl)
    return x


def gcn_forward_weights(layers: list[dict], weights: list[jax.Array],
                        snap: PaddedSnapshot, x: jax.Array, *,
                        impl: str = "xla") -> jax.Array:
    """GCN forward with externally supplied weight matrices (EvolveGCN:
    the evolved ``weights`` replace params['w'] layer by layer)."""
    for i, (p, w) in enumerate(zip(layers, weights)):
        last = i == len(layers) - 1
        q = dict(p, w=w)
        x = gcn_layer(q, snap, x, act=None if last else jax.nn.relu, impl=impl)
    return x
