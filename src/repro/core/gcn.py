"""GCN spatial encoder with message passing + edge embeddings.

The paper implements its GNN with the GenGNN message-passing mechanism and
highlights edge-embedding support. We follow the paper's stage split:

  MP (message passing): for each node v, agg[v] = sum over in-edges (u->v)
      of coef(u,v) * (x[u] + proj(edge_feat)), with coef the symmetric GCN
      normalization (precomputed host-side during renumbering);
  NT (node transform): h'[v] = act(agg[v] @ W + b).

Two device paths compute the same math:
  impl="xla"    edge-parallel gather + segment_sum (reference, used by the
                pjit production path — XLA fuses it well on TPU),
  impl="pallas" the ELL SpMM Pallas kernel (kernels/csr_spmm.py), the V2
                building block with VMEM-resident node features.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.padding import PaddedSnapshot


def init_gcn_layer(rng, din: int, dout: int, edge_dim: int) -> dict:
    kw, ke = jax.random.split(rng)
    scale = 1.0 / jnp.sqrt(din)
    p = {
        "w": jax.random.uniform(kw, (din, dout), jnp.float32, -scale, scale),
        "b": jnp.zeros((dout,), jnp.float32),
    }
    if edge_dim:
        escale = 1.0 / jnp.sqrt(edge_dim)
        p["w_edge"] = jax.random.uniform(ke, (edge_dim, din), jnp.float32, -escale, escale)
    return p


def propagate_segment(snap: PaddedSnapshot, x: jax.Array, w_edge=None) -> jax.Array:
    """MP stage, edge-parallel reference: (e_pad) gathers + segment_sum."""
    msgs = x[snap.src]
    if w_edge is not None:
        msgs = msgs + snap.edge_feat @ w_edge
    msgs = msgs * snap.coef[:, None]
    return jax.ops.segment_sum(msgs, snap.dst, num_segments=x.shape[0])


def propagate_ell(snap: PaddedSnapshot, x: jax.Array, w_edge=None) -> jax.Array:
    """MP stage via the ELL layout (same layout the Pallas kernel consumes)."""
    from repro.kernels import ops as kops

    edge_msg = snap.edge_feat @ w_edge if w_edge is not None else None
    return kops.ell_spmm(snap.neigh_idx, snap.neigh_coef, snap.neigh_eidx, x, edge_msg)


def gcn_layer(params: dict, snap: PaddedSnapshot, x: jax.Array, *,
              act=jax.nn.relu, impl: str = "xla") -> jax.Array:
    """One GCN layer: MP then NT (the paper's stage order)."""
    w_edge = params.get("w_edge")
    if impl == "pallas":
        agg = propagate_ell(snap, x, w_edge)
    else:
        agg = propagate_segment(snap, x, w_edge)
    h = agg @ params["w"] + params["b"]
    if act is not None:
        h = act(h)
    return h * snap.node_mask[:, None]


def gcn_forward(layers: list[dict], snap: PaddedSnapshot, x: jax.Array, *,
                impl: str = "xla") -> jax.Array:
    """Multi-layer GCN; last layer linear (standard GCN head)."""
    for i, p in enumerate(layers):
        last = i == len(layers) - 1
        x = gcn_layer(p, snap, x, act=None if last else jax.nn.relu, impl=impl)
    return x


def gcn_forward_weights(layers: list[dict], weights: list[jax.Array],
                        snap: PaddedSnapshot, x: jax.Array, *,
                        impl: str = "xla") -> jax.Array:
    """GCN forward with externally supplied weight matrices (EvolveGCN:
    the evolved ``weights`` replace params['w'] layer by layer)."""
    for i, (p, w) in enumerate(zip(layers, weights)):
        last = i == len(layers) - 1
        q = dict(p, w=w)
        x = gcn_layer(q, snap, x, act=None if last else jax.nn.relu, impl=impl)
    return x


class StaticGCN:
    """The "static" temporal contract's model: a plain multi-layer GCN,
    no recurrence, zero state (GenGNN-style non-temporal traffic).

    A "stream" of static snapshots is just a batch of independent graphs:
    ``step_stream`` folds the T axis onto the engine's batch axis (every
    slot T=1 — the static cell spec rejects anything else) and
    ``step_stream_batched`` folds (B, T) onto (B*T, 1), converting the
    plan's ragged ``lengths`` into per-slot 0/1 liveness. Every dataflow
    level computes the identical forward — the point of the family is the
    serve engine's EXPRESS lane: stateless chunks co-batch into one
    launch with no checkpoint/rollback overhead (serve/engine.py).
    """

    # cell spec this model dispatches to in the stream-engine registry
    stream_family = "static_gcn"

    def __init__(self, cfg, impl: str = "xla", n_global: int = 4096):
        assert cfg.dgnn_type == "static"
        self.cfg = cfg
        self.impl = impl
        self.n_global = n_global

    def init(self, rng) -> dict:
        cfg = self.cfg
        keys = jax.random.split(rng, cfg.n_gnn_layers)
        layers = []
        din = cfg.in_dim
        for l in range(cfg.n_gnn_layers):
            dout = cfg.out_dim if l == cfg.n_gnn_layers - 1 else cfg.hidden
            layers.append(init_gcn_layer(keys[l], din, dout,
                                         cfg.edge_dim if l == 0 else 0))
            din = dout
        return {"gcn": layers}

    def init_state(self, params: dict, mode: str = "baseline") -> dict:
        return {}  # stateless: the engine skips init/copy-forward/drain

    def step(self, params: dict, state: dict, snap: PaddedSnapshot, *,
             mode: str = "baseline") -> tuple[dict, jax.Array]:
        return state, gcn_forward(params["gcn"], snap, snap.node_feat,
                                  impl=self.impl)

    # ------------------------------------------------- stream engine ----

    def _edge_aggs(self, params: dict, snaps):
        """Per-layer pre-aggregated edge-message term (additive in the
        ELL aggregation, so it factors out of the kernel); zero for
        layers without edge weights (only layer 0 projects edges)."""
        if params["gcn"][0].get("w_edge") is None:
            return None
        eidx = snaps.neigh_eidx
        lead = eidx.shape[:-2]
        n, k = eidx.shape[-2:]
        flat = eidx.reshape(*lead, n * k, 1)
        aggs = []
        for p in params["gcn"]:
            we = p.get("w_edge")
            if we is None:
                aggs.append(jnp.zeros((*lead, n, p["w"].shape[0]),
                                      jnp.float32))
                continue
            emsg = snaps.edge_feat @ we
            g = jnp.take_along_axis(emsg, flat, axis=-2)
            g = g.reshape(*lead, n, k, emsg.shape[-1])
            aggs.append((g * snaps.neigh_coef[..., None]).sum(axis=-2))
        return aggs

    @staticmethod
    def _check_residency(state_residency, buffer_depth):
        # accepted for interface parity with the stateful families, but a
        # static family has no recurrent store to page
        if state_residency != "vmem" or buffer_depth is not None:
            raise ValueError(
                "state_residency='hbm_paged' is undefined for static "
                "family 'static_gcn': zero StateDefs — there is no "
                "recurrent store to page")

    def _stream_args(self, params: dict, snaps):
        return (snaps.neigh_idx, snaps.neigh_coef, snaps.node_feat,
                snaps.node_mask, [p["w"] for p in params["gcn"]],
                [p["b"] for p in params["gcn"]],
                self._edge_aggs(params, snaps))

    def step_stream(self, params: dict, state: dict,
                    snaps_T: PaddedSnapshot, *, tn=128, td="cfg",
                    state_residency="vmem", buffer_depth=None
                    ) -> tuple[dict, jax.Array]:
        """V3: T independent snapshots fold onto the engine's batch axis
        (one launch, T batch slots of a single T=1 step each)."""
        from repro.kernels import ops as kops

        self._check_residency(state_residency, buffer_depth)
        td = self.cfg.stream_td if td == "cfg" else td
        snaps_B1 = jax.tree.map(lambda a: jnp.asarray(a)[:, None], snaps_T)
        (outs,) = kops.stream_steps_batched(
            self.stream_family, *self._stream_args(params, snaps_B1),
            tn=tn, td=td)
        return state, outs[:, 0]

    def step_stream_batched(self, params: dict, state: dict,
                            snaps_BT: PaddedSnapshot, *, tn=128, td="cfg",
                            lengths=None, device=None,
                            state_residency="vmem", buffer_depth=None,
                            force_ref=False) -> tuple[dict, jax.Array]:
        """Batched V3: (B, T) independent snapshots fold onto (B*T, 1);
        ragged ``lengths`` (per-stream T) become per-slot 0/1 liveness.
        ``state`` passes through untouched (empty per slot)."""
        from repro.kernels import ops as kops

        self._check_residency(state_residency, buffer_depth)
        td = self.cfg.stream_td if td == "cfg" else td
        leaf = jax.tree.leaves(snaps_BT)[0]
        B, T = leaf.shape[0], leaf.shape[1]
        folded = jax.tree.map(
            lambda a: jnp.asarray(a).reshape((B * T, 1) + a.shape[2:]),
            snaps_BT)
        slot_lens = None
        if lengths is not None:
            lens = jnp.asarray(lengths, jnp.int32)
            t_axis = jnp.arange(T, dtype=jnp.int32)
            slot_lens = (t_axis[None, :] < lens[:, None]).astype(
                jnp.int32).reshape(B * T)
        (outs,) = kops.stream_steps_batched(
            self.stream_family, *self._stream_args(params, folded),
            tn=tn, td=td, lengths=slot_lens, device=device,
            force_ref=force_ref)
        return state, outs.reshape((B, T) + outs.shape[2:])
