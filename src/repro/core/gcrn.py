"""GCRN-M2 — the integrated DGNN (DGNN-Booster V2 base model).

Graph-convolutional LSTM (eq. (3) of the paper): every gate matmul of the
LSTM is a graph convolution, with GNN1 acting on the input features and
GNN2 on the hidden state:

    gates = GC_x(x^t; G^t) + GC_h(h^{t-1}; G^t) + b
    c^t   = sigmoid(f)*c^{t-1} + sigmoid(i)*tanh(g)
    h^t   = sigmoid(o)*tanh(c^t)

Per-node recurrent state lives in a *global* store (n_global, H); the
renumber table gathers the active rows before the step and scatters the
updated rows back — the paper's renumber-table-guided DRAM fetch/writeback.

Dataflow modes:
  baseline   staged gates (four separate convolution matmuls per input).
  o1         fused gates (one concatenated matmul per input).
  v2         + intra-step GNN/RNN fusion (DGNN-Booster V2): aggregation,
             gate transform, and the LSTM elementwise update execute
             per node tile inside one Pallas kernel (kernels/dgnn_fused.py)
             — the node-queue FIFO becomes a VMEM-resident tile. Identical
             math, no HBM round-trip for the gate tensor.
  v3         + time fusion (``step_stream``): the whole snapshot stream runs
             in ONE Pallas kernel (kernels/stream_fused.py) with the h/c
             global stores living in VMEM scratch across all T steps — the
             BRAM-resident recurrent state of the paper. The store crosses
             HBM once per stream instead of once per step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.dgnn import DGNNConfig
from repro.core import gcn as G
from repro.core import rnn as R
from repro.graph.padding import PaddedSnapshot


class GCRN:
    # cell spec this model dispatches to in the stream-engine registry
    # (kernels/stream_fused.REGISTRY, via kernels/ops.stream_steps)
    stream_family = "gcrn"

    def __init__(self, cfg: DGNNConfig, impl: str = "xla", n_global: int = 4096):
        assert cfg.dgnn_type == "integrated"
        self.cfg = cfg
        self.impl = impl
        self.n_global = n_global

    def init(self, rng) -> dict:
        cfg = self.cfg
        kx, ke, ko = jax.random.split(rng, 3)
        # one LSTM param set: wx is GNN1's gate transform (input conv path),
        # wh is GNN2's (hidden conv path) — matching eq. (3)'s two GNNs.
        p = {
            "lstm": R.init_lstm(kx, cfg.in_dim, cfg.hidden),
            "head": {
                "w": jax.random.normal(ko, (cfg.hidden, cfg.out_dim), jnp.float32)
                * (1.0 / jnp.sqrt(cfg.hidden)),
                "b": jnp.zeros((cfg.out_dim,), jnp.float32),
            },
        }
        if cfg.edge_dim:
            escale = 1.0 / jnp.sqrt(cfg.edge_dim)
            p["w_edge"] = jax.random.uniform(ke, (cfg.edge_dim, cfg.in_dim),
                                             jnp.float32, -escale, escale)
        return p

    def init_state(self, params: dict, mode: str = "baseline") -> dict:
        h = jnp.zeros((self.n_global, self.cfg.hidden), jnp.float32)
        c = jnp.zeros((self.n_global, self.cfg.hidden), jnp.float32)
        return {"h": h, "c": c}

    def _gather(self, store: jax.Array, snap: PaddedSnapshot) -> jax.Array:
        safe = jnp.where(snap.renumber >= 0, snap.renumber, 0)
        return store[safe] * snap.node_mask[:, None]

    def _scatter(self, store: jax.Array, snap: PaddedSnapshot, val: jax.Array) -> jax.Array:
        idx = jnp.where(snap.renumber >= 0, snap.renumber, self.n_global)
        return store.at[idx].set(val, mode="drop")

    def step(self, params: dict, state: dict, snap: PaddedSnapshot, *,
             mode: str = "baseline") -> tuple[dict, jax.Array]:
        cfg = self.cfg
        h = self._gather(state["h"], snap)
        c = self._gather(state["c"], snap)
        x = snap.node_feat
        w_edge = params.get("w_edge")

        if mode == "v2":
            from repro.kernels import ops as kops

            edge_msg = snap.edge_feat @ w_edge if w_edge is not None else None
            h_new, c_new = kops.dgnn_fused_step(
                snap.neigh_idx, snap.neigh_coef, snap.neigh_eidx,
                x, h, c,
                params["lstm"]["wx"], params["lstm"]["wh"],
                params["lstm"]["b"], edge_msg,
            )
        else:
            fused = mode == "o1"
            # GNN1: aggregate input features; GNN2: aggregate hidden state
            if self.impl == "pallas":
                agg_x = G.propagate_ell(snap, x, w_edge)
                agg_h = G.propagate_ell(snap, h, None)
            else:
                agg_x = G.propagate_segment(snap, x, w_edge)
                agg_h = G.propagate_segment(snap, h, None)
            gates = R.lstm_gates(params["lstm"], agg_x, agg_h, fused=fused)
            h_new, c_new = R.lstm_apply_gates(gates, c)

        m = snap.node_mask[:, None]
        h_new, c_new = h_new * m, c_new * m
        out = h_new @ params["head"]["w"] + params["head"]["b"]
        new_state = {
            "h": self._scatter(state["h"], snap, h_new),
            "c": self._scatter(state["c"], snap, c_new),
        }
        return new_state, out * m

    def _stream(self, params: dict, state: dict, snaps, batched: bool,
                tn=128, td="cfg", lengths=None, device=None,
                state_residency="vmem", buffer_depth=None,
                force_ref=False):
        """Shared plumbing for the (batched) stream-engine dispatch: the
        engine is selected by ``stream_family`` from the registry; the
        D-axis block size defaults to cfg.stream_td (None = fully
        resident) unless a plan overrides it."""
        from repro.kernels import ops as kops

        td = self.cfg.stream_td if td == "cfg" else td
        w_edge = params.get("w_edge")
        edge_msg = snaps.edge_feat @ w_edge if w_edge is not None else None
        args = (snaps.neigh_idx, snaps.neigh_coef, snaps.neigh_eidx,
                snaps.node_feat, snaps.renumber, snaps.node_mask,
                state["h"], state["c"],
                params["lstm"]["wx"], params["lstm"]["wh"],
                params["lstm"]["b"], edge_msg)
        if batched:
            outs_h, h_T, c_T = kops.stream_steps_batched(
                self.stream_family, *args, tn=tn, td=td, lengths=lengths,
                device=device,
                state_residency=state_residency, buffer_depth=buffer_depth,
                force_ref=force_ref)
        else:
            outs_h, h_T, c_T = kops.stream_steps(self.stream_family, *args,
                                                 tn=tn, td=td,
                                                 state_residency=state_residency,
                                                 buffer_depth=buffer_depth,
                                                 force_ref=force_ref)
        out = outs_h @ params["head"]["w"] + params["head"]["b"]
        mask = snaps.node_mask
        if lengths is not None:
            # ragged T: the masking happens inside the launch; mirror it on
            # the host-side output mask so dead-tail rows read as zero.
            live = (jnp.arange(mask.shape[1])[None, :]
                    < jnp.asarray(lengths)[:, None])
            mask = mask * live[:, :, None]
        return {"h": h_T, "c": c_T}, out * mask[..., None]

    def step_stream(self, params: dict, state: dict, snaps_T: PaddedSnapshot,
                    *, tn=128, td="cfg", state_residency="vmem",
                    buffer_depth=None) -> tuple[dict, jax.Array]:
        """V3: run a whole (T, ...) snapshot stream through the stream
        engine; h/c stay resident across steps (gather/scatter included) —
        in VMEM scratch, or HBM-paged when ``state_residency`` says so."""
        return self._stream(params, state, snaps_T, batched=False, tn=tn,
                            td=td, state_residency=state_residency,
                            buffer_depth=buffer_depth)

    def step_stream_batched(self, params: dict, state: dict,
                            snaps_BT: PaddedSnapshot, *, tn=128, td="cfg",
                            lengths=None, device=None,
                            state_residency="vmem", buffer_depth=None,
                            force_ref=False) -> tuple[dict, jax.Array]:
        """Batched V3: B independent snapshot streams — (B, T, ...) leaves,
        state leaves (B, n_global, H) — through ONE launch of the batched
        stream engine (weights shared, one VMEM-resident store per
        stream). Row b of the result is bit-close to running stream b alone
        through ``step_stream``. ``lengths`` runs the launch ragged over T;
        ``device`` (DeviceSpec) shards the batch axis; ``force_ref`` takes
        the XLA oracle path (the serve engine's degraded-mode rung)."""
        return self._stream(params, state, snaps_BT, batched=True, tn=tn,
                            td=td, lengths=lengths, device=device,
                            state_residency=state_residency,
                            buffer_depth=buffer_depth,
                            force_ref=force_ref)
