"""The paper's primary contribution: DGNN dataflow engines + base models."""
from repro.core.dataflow import (
    build_model,
    init_states_batched,
    run_batched,
    run_plan,
    run_plan_batched,
    run_stream,
    stack_time,
)
from repro.core.evolvegcn import EvolveGCN
from repro.core.gcrn import GCRN
from repro.core.stacked import StackedDGNN

__all__ = [
    "build_model", "run_plan", "run_plan_batched", "run_stream",
    "run_batched", "init_states_batched", "stack_time",
    "EvolveGCN", "GCRN", "StackedDGNN",
]
