"""EvolveGCN-O — the weights-evolved DGNN (DGNN-Booster V1 base model).

Per GCN layer l, a matrix-GRU evolves the layer weight:
    W_l^t = GRU(W_l^{t-1})            (temporal encoding)
    H^t   = GCN(W^t, G^t)             (spatial encoding)

Dataflow modes (see core/dataflow.py for the scan wrappers):
  baseline   strict chain inside one step: evolve -> GCN (paper Fig. 3).
  o1         + fused-gate GRU (Pipeline-O1).
  v1         + module overlap (Pipeline-O2 / DGNN-Booster V1): the state
             carries *already evolved* weights W^t, so GCN(W^t, G^t) and
             GRU(W^t) -> W^{t+1} are dataflow-independent inside the scan
             body — the ping-pong-buffer schedule. Outputs are identical
             to baseline (the state is primed by one evolution).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.dgnn import DGNNConfig
from repro.core import gcn as G
from repro.core import rnn as R
from repro.graph.padding import PaddedSnapshot


def layer_dims(cfg: DGNNConfig) -> list[tuple[int, int]]:
    dims = []
    din = cfg.in_dim
    for l in range(cfg.n_gnn_layers):
        dout = cfg.out_dim if l == cfg.n_gnn_layers - 1 else cfg.hidden
        dims.append((din, dout))
        din = dout
    return dims


class EvolveGCN:
    def __init__(self, cfg: DGNNConfig, impl: str = "xla"):
        assert cfg.dgnn_type == "weights_evolved"
        self.cfg = cfg
        self.impl = impl

    def init(self, rng) -> dict:
        dims = layer_dims(self.cfg)
        keys = jax.random.split(rng, 2 * len(dims))
        layers, grus = [], []
        for l, (din, dout) in enumerate(dims):
            layers.append(G.init_gcn_layer(keys[2 * l], din, dout, self.cfg.edge_dim))
            grus.append(R.init_gru(keys[2 * l + 1], din, din))
        return {"gcn": layers, "gru": grus}

    def init_state(self, params: dict, mode: str = "baseline") -> dict:
        """Recurrent state: the evolving weight matrices (per stream).

        v1 primes the pipeline by evolving once, so that inside the scan
        body the GCN consumes W^t while the GRU produces W^{t+1}; outputs
        then match baseline exactly. v3 (the time-fused stream engine) has
        no node-resident recurrent state to keep in VMEM for this family —
        the recurrence is over the weight matrices, whose evolution is a
        tiny matrix-GRU — so it falls back to the v1 overlapped schedule
        (see core/dataflow.py) and needs the same priming.
        """
        weights = [p["w"] for p in params["gcn"]]
        if mode in ("v1", "v3"):
            weights = [
                R.matrix_gru(g, w, fused=True)
                for g, w in zip(params["gru"], weights)
            ]
        return {"weights": weights}

    def step(self, params: dict, state: dict, snap: PaddedSnapshot, *,
             mode: str = "baseline") -> tuple[dict, jax.Array]:
        # v3 falls back to the v1 overlapped schedule (see init_state): the
        # state is primed identically, so treating them apart would evolve
        # the weights twice per step.
        fused = mode in ("o1", "v1", "v3")
        if mode in ("v1", "v3"):
            # DGNN-Booster V1: GCN and GRU are independent given the carry.
            w_now = state["weights"]
            out = G.gcn_forward_weights(params["gcn"], w_now, snap,
                                        snap.node_feat, impl=self.impl)
            w_next = [R.matrix_gru(g, w, fused=True)
                      for g, w in zip(params["gru"], w_now)]
            return {"weights": w_next}, out
        # baseline / o1: evolve THEN apply — the sequential critical path.
        w_now = [R.matrix_gru(g, w, fused=fused)
                 for g, w in zip(params["gru"], state["weights"])]
        out = G.gcn_forward_weights(params["gcn"], w_now, snap,
                                    snap.node_feat, impl=self.impl)
        return {"weights": w_now}, out
