"""EvolveGCN-O — the weights-evolved DGNN (DGNN-Booster V1 base model).

Per GCN layer l, a matrix-GRU evolves the layer weight:
    W_l^t = GRU(W_l^{t-1})            (temporal encoding)
    H^t   = GCN(W^t, G^t)             (spatial encoding)

Dataflow modes (see core/dataflow.py for the scan wrappers):
  baseline   strict chain inside one step: evolve -> GCN (paper Fig. 3).
  o1         + fused-gate GRU (Pipeline-O1).
  v1         + module overlap (Pipeline-O2 / DGNN-Booster V1): the state
             carries *already evolved* weights W^t, so GCN(W^t, G^t) and
             GRU(W^t) -> W^{t+1} are dataflow-independent inside the scan
             body — the ping-pong-buffer schedule. Outputs are identical
             to baseline (the state is primed by one evolution).
  v3         time fusion (``step_stream``): the whole snapshot stream runs
             in ONE weights-resident Pallas kernel
             (kernels/stream_fused.py): the per-layer evolving weights
             W_l^t live in VMEM scratch across all T steps, the
             matrix-GRU evolution runs in-kernel between snapshots, and
             the multi-layer GCN consumes the resident weights — each W_l
             crosses HBM twice per stream (primed load + evolved drain)
             instead of twice per step. Same primed-carry convention as
             v1, so v1 and v3 states are interchangeable at chunk
             boundaries (the serve engine relies on this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.dgnn import DGNNConfig
from repro.core import gcn as G
from repro.core import rnn as R
from repro.graph.padding import PaddedSnapshot


def layer_dims(cfg: DGNNConfig) -> list[tuple[int, int]]:
    dims = []
    din = cfg.in_dim
    for l in range(cfg.n_gnn_layers):
        dout = cfg.out_dim if l == cfg.n_gnn_layers - 1 else cfg.hidden
        dims.append((din, dout))
        din = dout
    return dims


class EvolveGCN:
    # cell spec this model dispatches to in the stream-engine registry
    stream_family = "evolve"

    def __init__(self, cfg: DGNNConfig, impl: str = "xla"):
        assert cfg.dgnn_type == "weights_evolved"
        self.cfg = cfg
        self.impl = impl

    def init(self, rng) -> dict:
        dims = layer_dims(self.cfg)
        keys = jax.random.split(rng, 2 * len(dims))
        layers, grus = [], []
        for l, (din, dout) in enumerate(dims):
            layers.append(G.init_gcn_layer(keys[2 * l], din, dout, self.cfg.edge_dim))
            grus.append(R.init_gru(keys[2 * l + 1], din, din))
        return {"gcn": layers, "gru": grus}

    def init_state(self, params: dict, mode: str = "baseline") -> dict:
        """Recurrent state: the evolving weight matrices (per stream).

        v1 primes the pipeline by evolving once, so that inside the scan
        body the GCN consumes W^t while the GRU produces W^{t+1}; outputs
        then match baseline exactly. v3 (the weights-resident stream
        kernel) uses the SAME primed convention: the kernel consumes the
        incoming weights at its first snapshot without evolving them and
        evolves at the END of every live step — priming once here and
        evolving in-kernel would otherwise double-evolve (the regression
        the differential harness pins).
        """
        weights = [p["w"] for p in params["gcn"]]
        if mode in ("v1", "v3"):
            weights = [
                R.matrix_gru(g, w, fused=True)
                for g, w in zip(params["gru"], weights)
            ]
        return {"weights": weights}

    def step(self, params: dict, state: dict, snap: PaddedSnapshot, *,
             mode: str = "baseline") -> tuple[dict, jax.Array]:
        # mode="v3" streams route through step_stream (the weights-resident
        # kernel); per-STEP v3 semantics equal the v1 overlapped schedule
        # (same primed carry), so a v3 state stepped here stays exchangeable
        # with the stream kernel's.
        fused = mode in ("o1", "v1", "v3")
        # an EMPTY snapshot is a no-op in every engine: outputs are masked
        # to zero and the weights do not evolve — the same contract the
        # stream kernel's live flag enforces, so all modes stay identical
        # even on streams containing empty (or no-op padding) snapshots.
        live = snap.n_nodes > 0
        if mode in ("v1", "v3"):
            # DGNN-Booster V1: GCN and GRU are independent given the carry.
            w_now = state["weights"]
            out = G.gcn_forward_weights(params["gcn"], w_now, snap,
                                        snap.node_feat, impl=self.impl)
            w_next = [jnp.where(live, R.matrix_gru(g, w, fused=True), w)
                      for g, w in zip(params["gru"], w_now)]
            return {"weights": w_next}, out
        # baseline / o1: evolve THEN apply — the sequential critical path.
        w_now = [jnp.where(live, R.matrix_gru(g, w, fused=fused), w)
                 for g, w in zip(params["gru"], state["weights"])]
        out = G.gcn_forward_weights(params["gcn"], w_now, snap,
                                    snap.node_feat, impl=self.impl)
        return {"weights": w_now}, out

    def _edge_aggs(self, params: dict, snaps: PaddedSnapshot):
        """Per-layer pre-aggregated edge-message term for the stream
        kernel: sum_k coef[v,k] * (edge_feat @ w_edge_l)[eidx[v,k]], shape
        (..., n, din_l) with any leading (T,) / (B, T) axes. The edge
        contribution is additive in the ELL aggregation, so it factors out
        of the kernel (which then only gathers node activations)."""
        if not self.cfg.edge_dim:
            return None
        eidx = snaps.neigh_eidx
        lead = eidx.shape[:-2]
        n, k = eidx.shape[-2:]
        flat = eidx.reshape(*lead, n * k, 1)
        aggs = []
        for p in params["gcn"]:
            emsg = snaps.edge_feat @ p["w_edge"]     # (..., e, din_l)
            g = jnp.take_along_axis(emsg, flat, axis=-2)
            g = g.reshape(*lead, n, k, emsg.shape[-1])
            aggs.append((g * snaps.neigh_coef[..., None]).sum(axis=-2))
        return aggs

    def _run_stream_kernel(self, params: dict, state: dict,
                           snaps: PaddedSnapshot, batched: bool,
                           tn=128, td="cfg", lengths=None, device=None,
                           state_residency="vmem", buffer_depth=None,
                           force_ref=False) -> tuple[dict, jax.Array]:
        """Shared plumbing for the (batched) stream-engine dispatch:
        live flags (n_nodes > 0 — no-op padding snapshots must not evolve
        the weights), per-layer param lists, edge aggregates."""
        from repro.kernels import ops as kops

        td = self.cfg.stream_td if td == "cfg" else td
        live = (snaps.n_nodes > 0).astype(jnp.int32)
        args = (snaps.neigh_idx, snaps.neigh_coef, snaps.node_feat,
                snaps.node_mask, live, list(state["weights"]),
                [p["b"] for p in params["gcn"]],
                [g["wx"] for g in params["gru"]],
                [g["wh"] for g in params["gru"]],
                [g["b"] for g in params["gru"]],
                self._edge_aggs(params, snaps))
        if batched:
            outs, wT = kops.stream_steps_batched(
                self.stream_family, *args, tn=tn, td=td, lengths=lengths,
                device=device,
                state_residency=state_residency, buffer_depth=buffer_depth,
                force_ref=force_ref)
        else:
            outs, wT = kops.stream_steps(self.stream_family, *args,
                                         tn=tn, td=td,
                                         state_residency=state_residency,
                                         buffer_depth=buffer_depth,
                                         force_ref=force_ref)
        return {"weights": list(wT)}, outs

    def step_stream(self, params: dict, state: dict, snaps_T: PaddedSnapshot,
                    *, tn=128, td="cfg", state_residency="vmem",
                    buffer_depth=None) -> tuple[dict, jax.Array]:
        """V3: run a whole (T, ...) snapshot stream through the
        weights-resident kernel; the evolving W_l stay in VMEM across
        steps and the matrix-GRU evolution runs in-kernel between
        snapshots."""
        return self._run_stream_kernel(params, state, snaps_T, batched=False,
                                       tn=tn, td=td,
                                       state_residency=state_residency,
                                       buffer_depth=buffer_depth)

    def step_stream_batched(self, params: dict, state: dict,
                            snaps_BT: PaddedSnapshot, *, tn=128, td="cfg",
                            lengths=None, device=None,
                            state_residency="vmem", buffer_depth=None,
                            force_ref=False) -> tuple[dict, jax.Array]:
        """Batched V3: B independent streams — (B, T, ...) leaves, weight
        state leaves (B, din_l, dout_l) — through ONE launch of the
        batched weights-resident kernel (GRU params shared, one resident
        weight set per stream). Row b of the result is bit-close to
        running stream b alone through ``step_stream``. ``lengths`` runs
        the launch ragged over T; ``device`` (DeviceSpec) shards the
        batch axis; ``force_ref`` takes the XLA oracle path (the serve
        engine's degraded-mode rung)."""
        return self._run_stream_kernel(params, state, snaps_BT, batched=True,
                                       tn=tn, td=td, lengths=lengths,
                                       device=device,
                                       state_residency=state_residency,
                                       buffer_depth=buffer_depth,
                                       force_ref=force_ref)
