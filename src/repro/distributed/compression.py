"""Gradient compression: int8 all-reduce with error feedback, bf16 cast.

Under GSPMD, data-parallel gradient reduction is fused into the backward
pass automatically, so compression must be expressed as an EXPLICIT
collective: ``compressed_psum`` is a shard_map building block that
quantizes (int8 + per-block absmax scale), psums the codes, and
dequantizes, carrying an error-feedback residual so the bias vanishes over
steps. The DGNN trainer uses it end-to-end (replicated params, batch
sharded over streams); for the LM path it is available to a manual-DP
train step and benchmarked in benchmarks/compression_bench.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_BLOCK = 256


def _quant(x: jax.Array):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    npad = (n + _BLOCK - 1) // _BLOCK * _BLOCK
    padded = jnp.pad(flat, (0, npad - n)).reshape(-1, _BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(padded), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(padded / scale[:, None]), -127, 127).astype(jnp.int8)
    err = (padded - q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n].reshape(x.shape)
    return q, scale, err


def _dequant(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def _quant_with_scale(x: jax.Array, scale: jax.Array):
    """Quantize with a GIVEN per-block scale; returns (codes, residual)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    npad = (n + _BLOCK - 1) // _BLOCK * _BLOCK
    padded = jnp.pad(flat, (0, npad - n)).reshape(-1, _BLOCK)
    q = jnp.clip(jnp.round(padded / scale[:, None]), -127, 127).astype(jnp.int8)
    err = (padded - q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n].reshape(x.shape)
    return q, err


def compressed_psum(x: jax.Array, residual: jax.Array, axis: str):
    """int8-compressed psum over ``axis`` with error feedback.

    Call INSIDE shard_map. Returns (mean-reduced value, new residual).
    Protocol: (1) pmax the per-block absmax scales (tiny), (2) every shard
    quantizes against the SHARED scale, (3) psum the int8 codes in int32,
    (4) dequantize. The only loss is local quantization error, which is
    exactly what the error-feedback residual carries to the next step —
    the estimate is unbiased over steps. Wire bytes: ~1/4 of fp32.
    """
    y = x + residual
    flat = y.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    npad = (n + _BLOCK - 1) // _BLOCK * _BLOCK
    padded = jnp.pad(flat, (0, npad - n)).reshape(-1, _BLOCK)
    local_scale = jnp.maximum(jnp.max(jnp.abs(padded), axis=1) / 127.0, 1e-12)
    scale = jax.lax.pmax(local_scale, axis)          # shared per-block scale
    q, err = _quant_with_scale(y, scale)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis)
    cnt = jax.lax.psum(1, axis)
    mean = _dequant(qsum, scale, y.shape) / cnt
    return mean, err


def bf16_psum(x: jax.Array, axis: str) -> jax.Array:
    """Half-precision gradient reduction (2x wire bytes saved)."""
    return jax.lax.psum(x.astype(jnp.bfloat16), axis).astype(jnp.float32) / jax.lax.psum(1, axis)


def make_compressed_grad_fn(loss_fn, mesh, batch_axes=("data",),
                            scheme: str = "int8"):
    """Wrap a per-example loss into a shard_map'd compressed-DP grad fn.

    loss_fn(params, batch) -> scalar (mean over local batch).
    Returns grad_fn(params, residuals, batch) -> (grads, new_residuals, loss).
    params replicated; batch sharded on its leading axis over ``batch_axes``.
    """
    from jax.experimental.shard_map import shard_map

    axis = batch_axes[0]

    def body(params, residuals, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        if scheme == "int8":
            flat_g, treedef = jax.tree.flatten(grads)
            flat_r = jax.tree.leaves(residuals)
            outs = [compressed_psum(g, r, axis) for g, r in zip(flat_g, flat_r)]
            grads = jax.tree.unflatten(treedef, [o[0] for o in outs])
            new_res = jax.tree.unflatten(treedef, [o[1] for o in outs])
        elif scheme == "bf16":
            grads = jax.tree.map(lambda g: bf16_psum(g, axis), grads)
            new_res = residuals
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
            new_res = residuals
        return grads, new_res, loss

    rep = P()

    def grad_fn(params, residuals, batch):
        batch_specs = jax.tree.map(lambda _: P(axis), batch)
        return shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: rep, params),
                      jax.tree.map(lambda _: rep, residuals),
                      batch_specs),
            out_specs=(jax.tree.map(lambda _: rep, params),
                       jax.tree.map(lambda _: rep, residuals),
                       rep),
            check_rep=False,
        )(params, residuals, batch)

    return grad_fn


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
