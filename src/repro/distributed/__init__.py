from repro.distributed.api import (
    Axes,
    current_mesh,
    named_sharding,
    resolve_spec,
    shard,
    sharding_ctx,
    tree_shardings,
    DEFAULT_RULES,
)

__all__ = [
    "Axes", "current_mesh", "named_sharding", "resolve_spec", "shard",
    "sharding_ctx", "tree_shardings", "DEFAULT_RULES",
]
