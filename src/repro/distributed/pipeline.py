"""Pipeline parallelism (GPipe-style) over a 'stage' mesh axis.

The assigned production meshes (16x16, 2x16x16) don't carry a stage axis —
DP x TP(+FSDP) covers every assigned arch — so PP is not wired into the
dry-run. It exists as a first-class building block for deeper-than-memory
models on other meshes (DESIGN §6), implemented the jax-native way:

  - layers are grouped into S stages; stage s's parameters are sharded to
    mesh axis 'stage' index s (one stage per stage-axis slice);
  - a lax.scan over (microbatches + S - 1) clock ticks shifts activations
    stage-to-stage with ppermute (the classic skewed-pipeline schedule);
  - every tick, ALL stages run their block on their current microbatch —
    bubbles at the ends are masked out.

``pipeline()`` is written against shard_map: callers provide the per-stage
block function and stacked per-stage params.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline(block_fn: Callable, mesh, n_stages: int, n_micro: int,
             stage_axis: str = "stage"):
    """Build a pipelined forward: (stage_params, x_micro) -> y_micro.

    block_fn(params_slice, x) -> y — one stage's computation.
    stage_params: pytree with leading dim n_stages (sharded over the stage
    axis). x_micro: (n_micro, mb, ...) microbatched input (replicated over
    the stage axis; only stage 0 consumes it).
    """

    def per_shard(params, xs):
        # params: this stage's slice (leading dim 1); xs: (n_micro, mb, ...)
        sid = jax.lax.axis_index(stage_axis)
        p = jax.tree.map(lambda a: a[0], params)
        mb_shape = xs.shape[1:]
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros(mb_shape, xs.dtype)          # current stage input
        outs = jnp.zeros((n_micro, *mb_shape), xs.dtype)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when valid)
            x_in = jnp.where(t < n_micro,
                             xs[jnp.minimum(t, n_micro - 1)],
                             jnp.zeros(mb_shape, xs.dtype))
            cur = jnp.where(sid == 0, x_in, buf)
            y = block_fn(p, cur)
            # shift to the next stage
            nxt = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage emits microbatch t - (n_stages - 1)
            out_idx = t - (n_stages - 1)
            valid = (sid == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                valid,
                lambda o: o.at[jnp.maximum(out_idx, 0)].set(y),
                lambda o: o,
                outs)
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast via psum-mask
        mask = (sid == n_stages - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, stage_axis)

    return shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False,
    )


def reference_stack(block_fn: Callable, stage_params, xs):
    """Unpipelined oracle: run stages sequentially on each microbatch."""
    def one(x):
        for s in range(jax.tree.leaves(stage_params)[0].shape[0]):
            p = jax.tree.map(lambda a: a[s], stage_params)
            x = block_fn(p, x)
        return x

    return jax.vmap(one)(xs)
