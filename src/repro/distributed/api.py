"""Logical-axis sharding API (MaxText-style rules, with safety fallbacks).

Model code annotates activations/params with LOGICAL axis names; a rules
table maps them to mesh axes. ``shard(x, *names)`` inserts a sharding
constraint when a mesh context is active and silently degrades to
replication for any dim that does not divide the mapped mesh axes — the
divisibility policy of DESIGN §5 (padding helpers in configs handle the
dims we care about; anything else falls back rather than failing).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Union[str, None, tuple]

# default rules: logical name -> mesh axis (str) or tuple of mesh axes
DEFAULT_RULES: tuple[tuple[str, Union[str, tuple, None]], ...] = (
    ("batch", ("pod", "data")),
    ("seq", None),
    ("seq_sp", "model"),        # sequence-parallel residual stream
    ("kv_seq", None),           # decode KV cache sequence dim
    ("kv_seq_dp", ("pod", "data")),  # long-context batch=1: shard cache seq over data
    ("embed", None),
    ("embed_fsdp", "data"),     # FSDP: weights' embed dim over data
    ("heads", "model"),
    ("kv_heads", "model"),
    ("head_dim", None),
    ("ffn", "model"),
    ("experts", "model"),
    ("vocab", "model"),
    ("ssm_inner", "model"),
    ("ssm_heads", "model"),
    ("ssm_state", None),
    ("conv_dim", "model"),
    ("layers", None),
    ("stream", ("pod", "data")),  # batched DGNN streams
    ("node", None),
    ("feat", "model"),          # wide-DGNN feature dim
)


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[dict] = None):
    old_mesh, old_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES) if rules is None else dict(rules)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old_mesh, old_rules


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _mesh_axes_size(mesh: Mesh, axes: Union[str, tuple, None]) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return size


def resolve_spec(shape: Sequence[int], names: Sequence[Logical],
                 mesh: Optional[Mesh] = None, rules: Optional[dict] = None) -> P:
    """Logical names -> PartitionSpec with divisibility fallback."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    assert len(shape) == len(names), (shape, names)
    entries = []
    for dim, name in zip(shape, names):
        if name is None:
            entries.append(None)
            continue
        axes = rules.get(name)
        if axes is None:
            entries.append(None)
            continue
        if mesh is not None:
            sz = _mesh_axes_size(mesh, axes)
            if sz == 1 or dim % sz != 0:
                entries.append(None)
                continue
            # drop mesh axes already absent
            present = set(mesh.axis_names)
            if isinstance(axes, str):
                axes_t = (axes,)
            else:
                axes_t = tuple(axes)
            axes_t = tuple(a for a in axes_t if a in present)
            if not axes_t:
                entries.append(None)
                continue
            entries.append(axes_t[0] if len(axes_t) == 1 else axes_t)
        else:
            entries.append(axes if isinstance(axes, str) else tuple(axes))
    return P(*entries)


def shard(x: jax.Array, *names: Logical) -> jax.Array:
    """Constrain ``x`` to the sharding the rules give its logical axes."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = resolve_spec(x.shape, names, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(shape: Sequence[int], names: Sequence[Logical],
                   mesh: Optional[Mesh] = None) -> NamedSharding:
    mesh = mesh or _CTX.mesh
    assert mesh is not None
    return NamedSharding(mesh, resolve_spec(shape, names, mesh))


class Axes:
    """Logical-axis annotation leaf (kept opaque to pytree traversal)."""

    __slots__ = ("names",)

    def __init__(self, *names: Logical):
        self.names = tuple(names)

    def __repr__(self) -> str:
        return f"Axes{self.names}"

    def __eq__(self, other):
        return isinstance(other, Axes) and self.names == other.names

    def __hash__(self):
        return hash(self.names)


def tree_shardings(tree_shapes, tree_axes, mesh: Optional[Mesh] = None):
    """Pytree of shapes (arrays/ShapeDtypeStructs) + matching pytree with
    ``Axes`` leaves -> NamedShardings for jit in_/out_shardings."""
    mesh = mesh or _CTX.mesh
    return jax.tree.map(
        lambda shp, ax: named_sharding(shp.shape, ax.names, mesh),
        tree_shapes, tree_axes,
        is_leaf=lambda v: isinstance(v, Axes),
    )
