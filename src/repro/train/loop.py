"""Fault-tolerant training loop.

Features (DESIGN §6):
  - resume-from-latest on start (elastic: restores onto the current mesh);
  - periodic async checkpoints + preemption handler (SIGTERM/SIGINT force a
    final blocking save before exit);
  - per-step wall-time log with a configurable straggler deadline — steps
    exceeding it are counted and reported (at fleet scale the scheduler
    consumes this signal to evict slow hosts; here it is the hook + test);
  - donated carry state (params/opt buffers updated in place).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.optim import AdamWConfig, apply_updates, init_opt_state


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_last: int = 3
    log_every: int = 10
    straggler_deadline_s: float = 60.0


@dataclass
class TrainResult:
    final_step: int
    losses: list
    step_times: list
    straggler_steps: int
    resumed_from: Optional[int]


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                    donate: bool = True):
    """loss_fn(params, batch) -> scalar. Returns jitted step fn."""

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = apply_updates(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def train(loss_fn: Callable, params, batches: Iterable,
          opt_cfg: AdamWConfig, loop_cfg: TrainLoopConfig,
          axes: Any = None, mesh=None) -> tuple[Any, TrainResult]:
    """Run the loop; returns (final_params, TrainResult)."""
    mgr = None
    resumed_from = None
    opt_state = init_opt_state(params, opt_cfg)
    start_step = 0
    if loop_cfg.checkpoint_dir:
        mgr = CheckpointManager(loop_cfg.checkpoint_dir, loop_cfg.keep_last)
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, {"params": params, "opt": opt_state},
                                axes=None, mesh=None)
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            resumed_from = latest

    step_fn = make_train_step(loss_fn, opt_cfg)

    stop = {"flag": False}

    def handler(signum, frame):
        stop["flag"] = True

    old_term = signal.signal(signal.SIGTERM, handler)

    losses, times = [], []
    stragglers = 0
    step = start_step
    try:
        it = iter(batches)
        while step < loop_cfg.total_steps:
            try:
                batch = next(it)
            except StopIteration:
                break
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            step += 1
            losses.append(loss)
            times.append(dt)
            if dt > loop_cfg.straggler_deadline_s:
                stragglers += 1
            if mgr and step % loop_cfg.checkpoint_every == 0:
                mgr.save(step, {"params": params, "opt": opt_state})
            if stop["flag"]:
                break
    finally:
        if mgr:
            mgr.save(step, {"params": params, "opt": opt_state}, blocking=True)
        signal.signal(signal.SIGTERM, old_term)

    return params, TrainResult(step, losses, times, stragglers, resumed_from)
