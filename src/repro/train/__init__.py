from repro.train.loop import TrainLoopConfig, TrainResult, make_train_step, train

__all__ = ["TrainLoopConfig", "TrainResult", "make_train_step", "train"]
