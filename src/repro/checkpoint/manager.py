"""Fault-tolerant checkpointing: atomic, async, elastic.

- Atomic: write to ``<dir>/tmp.<step>`` then os.rename — a crash mid-save
  never corrupts the latest checkpoint.
- Async: device->host transfer is synchronous (cheap), the file write runs
  on a background thread so the train loop isn't blocked.
- Elastic: arrays are saved UNSHARDED with their logical-axis names; on
  restore they are device_put with shardings resolved against whatever
  mesh is currently available — a 512-chip checkpoint restores onto 256
  chips (or 1 CPU) without conversion.

Format: one .npz per checkpoint (flattened key paths) + meta.json.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.distributed.api import Axes, named_sharding


def _flatten(tree, prefix="") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat: dict, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/") for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        seq = [_unflatten_into(v, flat, f"{prefix}{i}/") for i, v in enumerate(template)]
        return type(template)(seq)
    return flat[prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save ----

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot to host memory now; write to disk (a)synchronously."""
        host = jax.tree.map(lambda a: np.asarray(a), tree)
        self.wait()  # one in-flight save at a time
        self._thread = threading.Thread(
            target=self._write, args=(step, host), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host_tree) -> None:
        flat = _flatten(host_tree)
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "time": time.time(),
                       "keys": sorted(flat)}, f)
        if os.path.exists(final):
            return
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            import shutil

            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore ----

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d{10})", name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any, axes: Any = None,
                mesh=None) -> Any:
        """Load into the structure of ``template``; reshard onto ``mesh``
        using the logical ``axes`` tree when given (elastic restore)."""
        path = os.path.join(self.dir, f"step_{step:010d}", "arrays.npz")
        data = np.load(path)
        flat = {k: data[k] for k in data.files}
        host = _unflatten_into(template, flat)
        if mesh is None or axes is None:
            return jax.tree.map(jax.numpy.asarray, host)

        def put(arr, ax):
            return jax.device_put(arr, named_sharding(arr.shape, ax.names, mesh))

        return jax.tree.map(put, host, axes,
                            is_leaf=lambda v: isinstance(v, Axes))
