"""Generic LM-family model: dense / MoE / SSM / hybrid / encoder-only.

One implementation covers all 10 assigned architectures, wired from
ModelConfig.layer_kinds(). Layers are stored STACKED per period position
(period = lcm of the interleave patterns, e.g. 8 for jamba) and executed
either scanned (fast compile, used for running models) or unrolled
(accurate cost_analysis/collective accounting, used by the dry-run —
XLA's HloCostAnalysis does not multiply while-loop bodies by trip count).

Memory posture (DESIGN §5/§6):
  - residual stream is sequence-sharded over the model axis (Megatron-SP);
  - per-layer remat for train (only layer boundaries saved);
  - cross-entropy is computed in sequence chunks with vocab-sharded logits
    (never materializes (B, S, V));
  - decode caches: (B, Skv, Hkv, hd) bf16, sequence-sharded for long_500k.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.api import Axes, shard
from repro.nn import attention as ATT
from repro.nn import mamba2 as SSM
from repro.nn import mlp as MLP
from repro.nn import moe as MOE
from repro.nn.layers import (
    ACT_DTYPE,
    embed_lookup,
    init_embedding,
    init_lm_head,
    init_rms_norm,
    rms_norm,
    vocab_mask,
)


@dataclass(frozen=True)
class RuntimeConfig:
    """Runtime/distribution choices (orthogonal to the architecture)."""

    tp: int = 1                 # model-axis size (drives padding)
    scan_layers: bool = True    # False -> unrolled (dry-run accounting)
    remat: bool = True          # per-layer rematerialization for train
    attn_chunk: int = 2048      # q-chunk for long-sequence attention
    attn_impl: str = "xla"      # "flash" = Pallas kernel (fwd-only: prefill/serve)
    flash_bq: int = 512         # flash q tile (KV HBM traffic ~ S^2*d/bq)
    flash_bk: int = 512         # flash kv tile
    moe_impl: str = "auto"      # "dense" | "ep" | "auto"
    fsdp: bool = False          # shard weights' embed dim over 'data'
    long_ctx: bool = False      # sequence-shard the decode KV cache
    loss_chunk: int = 512       # seq chunk for chunked cross-entropy
    param_dtype: str = "fp32"   # "bf16" for the ~400B class: bf16 weights +
                                # bf16 grads + int8 moments (DESIGN §6)
    grad_accum: int = 1         # microbatches per step (activation memory
                                # divider; grads accumulate in param dtype)
    moe_cf_send: float = 1.25   # EP dispatch capacity factor (all_to_all)
    moe_cf_local: float = 1.25  # EP local expert-bucket capacity factor
    bwd_bf16: bool = False      # demote the backward residual-stream chain
                                # (and its collectives) to bf16 (§Perf)
    kv_quant: bool = False      # int8 KV cache (decode; §Perf)


# ------------------------------------------------------------- params ----


def _init_layer(key, cfg: ModelConfig, rt: RuntimeConfig, mixer: str, mlp: str):
    p, ax = {}, {}
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p["norm1"], ax["norm1"] = init_rms_norm(cfg.d_model)
    if mixer == "attn":
        p["attn"], ax["attn"] = ATT.init_attention(k1, cfg, rt.tp)
    else:
        p["ssm"], ax["ssm"] = SSM.init_mamba(k1, cfg)
    if mlp != "none":
        p["norm2"], ax["norm2"] = init_rms_norm(cfg.d_model)
        if mlp == "dense":
            p["mlp"], ax["mlp"] = MLP.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.n_layers)
        else:
            p["moe"], ax["moe"] = MOE.init_moe(k2, cfg, rt.tp)
    return p, ax


def init_params(cfg: ModelConfig, rt: RuntimeConfig, rng) -> tuple[dict, dict]:
    """Returns (params, axes). Layer params stacked per period position."""
    kinds = cfg.layer_kinds()
    period = cfg.scan_period()
    nb = cfg.n_layers // period
    keys = jax.random.split(rng, cfg.n_layers + 3)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    vp = cfg.padded_vocab()
    # embed table always present (even embeddings-input archs decode tokens)
    params["embed"], axes["embed"] = init_embedding(keys[-1], vp, cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"], axes["head"] = init_lm_head(keys[-2], cfg.d_model, vp)
    params["final_norm"], axes["final_norm"] = init_rms_norm(cfg.d_model)
    axes["final_norm"] = Axes(None)
    blocks, blocks_ax = [], []
    for pos in range(period):
        per_block = []
        ax_ref = None
        for b in range(nb):
            li = b * period + pos
            mixer, mlp = kinds[li]
            pl, al = _init_layer(keys[li], cfg, rt, mixer, mlp)
            per_block.append(pl)
            ax_ref = al
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_block)
        blocks.append(stacked)
        # prepend the stacked "layers" axis to every leaf's Axes
        blocks_ax.append(jax.tree.map(
            lambda a: Axes("layers", *a.names), ax_ref,
            is_leaf=lambda v: isinstance(v, Axes)))
    params["blocks"] = blocks
    axes["blocks"] = blocks_ax
    if rt.param_dtype == "bf16":
        params = jax.tree.map(lambda a: a.astype(jnp.bfloat16), params)
    return params, axes


# ------------------------------------------------------------ forward ----


def _layer_apply(pl: dict, cfg: ModelConfig, rt: RuntimeConfig,
                 mixer: str, mlp: str, x: jax.Array, positions,
                 cache: Optional[dict]):
    h = rms_norm(x, pl["norm1"], cfg.norm_eps)
    if mixer == "attn":
        y, new_cache = ATT.attention_block(
            pl["attn"], cfg, h, positions,
            attn_chunk=rt.attn_chunk, cache=cache, long_ctx=rt.long_ctx,
            attn_impl=rt.attn_impl, flash_bq=rt.flash_bq, flash_bk=rt.flash_bk)
    else:
        y, new_cache = SSM.mamba_block(pl["ssm"], cfg, h, state=cache)
    x = x + y
    x = shard(x, "batch", "seq_sp", None)
    if mlp != "none":
        h2 = rms_norm(x, pl["norm2"], cfg.norm_eps)
        if mlp == "dense":
            y2 = MLP.mlp_block(pl["mlp"], h2)
        else:
            y2 = MOE.moe_block(pl["moe"], cfg, h2, impl=rt.moe_impl,
                               fsdp=rt.fsdp, cf_send=rt.moe_cf_send,
                               cf_local=rt.moe_cf_local)
        x = x + y2
        x = shard(x, "batch", "seq_sp", None)
    return x, new_cache


def backbone(params: dict, cfg: ModelConfig, rt: RuntimeConfig, x: jax.Array,
             positions, caches: Optional[list] = None,
             train: bool = False):
    """x: (B, S, D) -> (B, S, D); threads per-layer caches when decoding."""
    kinds = cfg.layer_kinds()
    period = cfg.scan_period()
    nb = cfg.n_layers // period
    x = shard(x.astype(ACT_DTYPE), "batch", "seq_sp", None)
    new_caches: Optional[list] = None if caches is None else []

    if rt.scan_layers and caches is None and not train:
        # scanned inference path (compile-time friendly)
        def body(carry, block_slices):
            h = carry
            for pos in range(period):
                mixer, mlp = kinds[pos]
                h, _ = _layer_apply(block_slices[pos], cfg, rt, mixer, mlp,
                                    h, positions, None)
            return h, None

        x, _ = jax.lax.scan(body, x, tuple(params["blocks"]))
        return x, None

    if rt.scan_layers and caches is None and train:
        def body_t(carry, block_slices):
            h = carry
            for pos in range(period):
                mixer, mlp = kinds[pos]
                h, _ = _layer_apply(block_slices[pos], cfg, rt, mixer, mlp,
                                    h, positions, None)
            return h, None

        body_t = jax.checkpoint(body_t) if rt.remat else body_t
        x, _ = jax.lax.scan(body_t, x, tuple(params["blocks"]))
        return x, None

    # unrolled path (dry-run accounting; also the decode path)
    collected: dict[int, list] = {pos: [] for pos in range(period)}
    for b in range(nb):
        for pos in range(period):
            li = b * period + pos
            mixer, mlp = kinds[li]
            pl = jax.tree.map(lambda a, b=b: a[b], params["blocks"][pos])
            cache = None
            if caches is not None:
                cache = jax.tree.map(lambda a, b=b: a[b], caches[pos])

            def apply_fn(pl_, x_, cache_, mixer=mixer, mlp=mlp):
                return _layer_apply(pl_, cfg, rt, mixer, mlp, x_, positions, cache_)

            if train and rt.remat:
                apply_fn = jax.checkpoint(apply_fn)
            x, new_cache = apply_fn(pl, x, cache)
            if caches is not None:
                collected[pos].append(new_cache)
    if caches is not None:
        new_caches = [
            jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *collected[pos])
            for pos in range(period)
        ]
    return x, new_caches


def _inputs_to_stream(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    if "embeds" in batch:
        return batch["embeds"].astype(ACT_DTYPE)
    return embed_lookup(params["embed"], batch["tokens"])


def _head_weight(params, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def logits_fn(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full logits (only for small S / decode — never for train loss)."""
    w = _head_weight(params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(ACT_DTYPE)).astype(jnp.float32)
    logits = logits + vocab_mask(w.shape[1], cfg.vocab_size)
    return shard(logits, "batch", None, "vocab")


def chunked_ce_loss(params, cfg: ModelConfig, rt: RuntimeConfig,
                    x: jax.Array, targets: jax.Array,
                    mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE over (B, S) without materializing (B, S, V)."""
    b, s, d = x.shape
    w = _head_weight(params, cfg)
    vmask = vocab_mask(w.shape[1], cfg.vocab_size)
    c = min(rt.loss_chunk, s)
    assert s % c == 0
    nc = s // c
    xc = x.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, c).transpose(1, 0, 2)
    if mask is None:
        mk = jnp.ones((nc, b, c), jnp.float32)
    else:
        mk = mask.reshape(b, nc, c).transpose(1, 0, 2).astype(jnp.float32)

    @jax.checkpoint  # recompute per-chunk logits in bwd: residual = x chunk
    def one(args):
        xi, ti, mi = args
        lg = jnp.einsum("bcd,dv->bcv", xi, w.astype(ACT_DTYPE)).astype(jnp.float32)
        lg = lg + vmask
        lg = shard(lg, "batch", None, "vocab")
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, ti[..., None], axis=-1)[..., 0]
        return ((lse - gold) * mi).sum(), mi.sum()

    losses, counts = jax.lax.map(one, (xc, tc, mk))
    return losses.sum() / jnp.maximum(counts.sum(), 1.0)


def loss_fn(params, cfg: ModelConfig, rt: RuntimeConfig, batch: dict) -> jax.Array:
    from repro.nn.layers import bf16_cotangent

    x = _inputs_to_stream(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h, _ = backbone(params, cfg, rt, x, positions, train=True)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if rt.bwd_bf16:
        # the loss head promotes cotangents to fp32; round them back to bf16
        # before they flow into the (long) backward residual chain
        h = bf16_cotangent(h)
    return chunked_ce_loss(params, cfg, rt, h, batch["targets"], batch.get("mask"))


def prefill_step(params, cfg: ModelConfig, rt: RuntimeConfig, batch: dict):
    """Forward pass producing last-position logits (+ caches for handoff)."""
    x = _inputs_to_stream(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h, _ = backbone(params, cfg, rt, x, positions, train=False)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return logits_fn(params, cfg, h[:, -1:, :])


def init_caches(cfg: ModelConfig, rt: RuntimeConfig, batch: int, skv: int):
    """Decode caches, stacked per period position (mirrors params['blocks'])."""
    kinds = cfg.layer_kinds()
    period = cfg.scan_period()
    nb = cfg.n_layers // period
    caches: list = []
    for pos in range(period):
        mixer, _ = kinds[pos]
        if mixer == "attn":
            one = ATT.init_decode_cache(cfg, batch, skv, rt.tp,
                                        quant=rt.kv_quant)
        else:
            one = SSM.init_mamba_state(cfg, batch)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (nb, *a.shape)), one))
    return caches


def cache_axes(cfg: ModelConfig, rt: RuntimeConfig) -> list:
    """Logical axes for the cache pytree (for dry-run in_shardings)."""
    kinds = cfg.layer_kinds()
    period = cfg.scan_period()
    kv_ax = "kv_seq_dp" if rt.long_ctx else "kv_seq"
    out = []
    for pos in range(period):
        mixer, _ = kinds[pos]
        if mixer == "attn":
            ax = {
                "k": Axes("layers", "batch", kv_ax, "kv_heads", None),
                "v": Axes("layers", "batch", kv_ax, "kv_heads", None),
                "len": Axes("layers"),
            }
            if rt.kv_quant:
                ax["k_s"] = Axes("layers", "batch", kv_ax, "kv_heads")
                ax["v_s"] = Axes("layers", "batch", kv_ax, "kv_heads")
            out.append(ax)
        else:
            out.append({
                "conv": Axes("layers", "batch", None, "conv_dim"),
                "ssm": Axes("layers", "batch", "ssm_heads", None, None),
            })
    return out


def decode_step(params, cfg: ModelConfig, rt: RuntimeConfig, tokens: jax.Array,
                caches: list):
    """One new token per sequence against the caches. tokens: (B, 1)."""
    x = embed_lookup(params["embed"], tokens)
    # position = current cache length (attn layers carry it; ssm-only models
    # track positions implicitly, rope unused there)
    pos = None
    for c in caches:
        if c is not None and "len" in c:
            pos = c["len"][0]
            break
    if pos is None:
        pos = jnp.zeros((), jnp.int32)
    positions = jnp.broadcast_to(pos[None], (tokens.shape[0], 1))
    h, new_caches = backbone(params, cfg, rt, x, positions, caches=caches,
                             train=False)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, h)
    return logits, new_caches
