from repro.models.lm import (
    RuntimeConfig,
    backbone,
    cache_axes,
    chunked_ce_loss,
    decode_step,
    init_caches,
    init_params,
    loss_fn,
    prefill_step,
)

__all__ = [
    "RuntimeConfig", "backbone", "cache_axes", "chunked_ce_loss",
    "decode_step", "init_caches", "init_params", "loss_fn", "prefill_step",
]
