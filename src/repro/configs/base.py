"""Config dataclasses for model architectures and input shapes.

Every assigned architecture gets one module in this package exporting
``CONFIG``; the registry in ``__init__`` collects them. Shapes are global
(the assignment pairs every LM arch with the same 4 shapes).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field


def pad_to(x: int, mult: int) -> int:
    """Smallest multiple of ``mult`` that is >= x."""
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    """A generic LM-family architecture description.

    ``family`` selects the high-level wiring:
      dense  - attention + dense MLP every layer
      moe    - attention + MoE MLP (per ``moe_every``)
      ssm    - Mamba2 mixer only (no MLP when d_ff == 0)
      hybrid - Mamba2 + attention interleave (``attn_every``), MoE per
               ``moe_every``
      vlm    - dense backbone, input_mode="embeddings" for train/prefill
      audio  - encoder-only dense backbone, input_mode="embeddings"
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # MoE on layers with index % moe_every == moe_every-1
    n_shared_experts: int = 0
    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    attn_every: int = 0  # hybrid: attention on layers with index % attn_every == attn_offset
    attn_offset: int = 3
    # modality frontend (stub per assignment: embeddings provided directly)
    input_mode: str = "tokens"  # "tokens" | "embeddings"
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def supports_decode(self) -> bool:
        return self.causal

    @property
    def subquadratic(self) -> bool:
        """Whether long_500k decode is admissible.

        Pure full-attention archs are skipped for long_500k per the
        assignment. SSM and hybrid (mostly-SSM) archs run it.
        """
        return self.family in ("ssm", "hybrid")

    def layer_kinds(self) -> list[tuple[str, str]]:
        """Per-layer (mixer, mlp) kinds.

        mixer in {"attn", "ssm"}; mlp in {"dense", "moe", "none"}.
        """
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                mixer = "ssm"
            elif self.family == "hybrid":
                mixer = "attn" if (self.attn_every and i % self.attn_every == self.attn_offset) else "ssm"
            else:
                mixer = "attn"
            if self.d_ff == 0:
                mlp = "none"
            elif self.n_experts and (i % self.moe_every == self.moe_every - 1):
                mlp = "moe"
            else:
                mlp = "dense"
            kinds.append((mixer, mlp))
        return kinds

    def scan_period(self) -> int:
        """Layers are scanned in super-blocks of this period (homogeneous
        across blocks). lcm of the interleave patterns."""
        p = 1
        if self.family == "hybrid" and self.attn_every:
            p = math.lcm(p, self.attn_every)
        if self.n_experts and self.moe_every > 1:
            p = math.lcm(p, self.moe_every)
        assert self.n_layers % p == 0, (self.name, p, self.n_layers)
        return p

    # ---- padding for the TP axis (divisibility policy; see DESIGN §5) ----
    def padded_vocab(self, mult: int = 256) -> int:
        return pad_to(self.vocab_size, mult)

    def padded_heads(self, tp: int) -> int:
        return pad_to(self.n_heads, tp) if self.n_heads % tp else self.n_heads

    def padded_kv_heads(self, tp: int) -> int:
        # repeat KV heads up to tp when fewer than tp (standard TP serving)
        if self.n_kv_heads >= tp:
            return pad_to(self.n_kv_heads, tp) if self.n_kv_heads % tp else self.n_kv_heads
        return tp

    def padded_experts(self, tp: int) -> int:
        if not self.n_experts:
            return 0
        return pad_to(self.n_experts, tp) if self.n_experts % tp else self.n_experts

    def param_count(self) -> int:
        """Total parameter count N (exact for our wiring, unpadded dims)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        for mixer, mlp in self.layer_kinds():
            total += d  # pre-mixer norm
            if mixer == "attn":
                total += d * self.n_heads * hd  # q
                total += 2 * d * self.n_kv_heads * hd  # k, v
                total += self.n_heads * hd * d  # o
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
            else:  # ssm
                di, ng, st, nh = self.d_inner, self.ssm_ngroups, self.ssm_state, self.ssm_nheads
                total += d * (2 * di + 2 * ng * st + nh)  # in_proj
                total += self.ssm_conv * (di + 2 * ng * st)  # conv
                total += 3 * nh  # A_log, D, dt_bias
                total += di  # gated norm
                total += di * d  # out_proj
            if mlp != "none":
                total += d  # pre-mlp norm
            if mlp == "dense":
                total += 3 * d * self.d_ff
            elif mlp == "moe":
                total += self.n_experts * 3 * d * self.d_ff
                total += d * self.n_experts  # router
                total += self.n_shared_experts * 3 * d * self.d_ff
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top_k experts count)."""
        if not self.n_experts:
            return self.param_count()
        dense_moe_delta = 0
        for _, mlp in self.layer_kinds():
            if mlp == "moe":
                dense_moe_delta += (self.n_experts - self.top_k) * 3 * self.d_model * self.d_ff
        return self.param_count() - dense_moe_delta


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    period = cfg.scan_period()
    changes = dict(
        n_layers=2 * period,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=128,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_chunk=8,
        name=cfg.name + "-smoke",
    )
    return dataclasses.replace(cfg, **changes)
