"""llama4-maverick-400b-a17b — MoE 128e top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.

Following the released Maverick wiring, MoE layers interleave with dense
layers (moe_every=2) and each MoE layer carries one always-on shared expert;
this reproduces the ~400B total / ~17B active split that "400b-a17b" names
(128e top-1 on every layer would be ~770B total). Noted in DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    top_k=1,
    moe_every=2,
    n_shared_experts=1,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
