"""hubert-xlarge — encoder-only audio transformer. [arXiv:2106.07447; unverified]

48L d_model=1280 16H (kv=16, i.e. MHA) d_ff=5120 vocab=504.
Audio: the conv waveform frontend is a STUB per the assignment —
input_specs() provides precomputed frame embeddings. Encoder-only:
no decode shapes (skipped per the assignment).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    input_mode="embeddings",
    norm_eps=1e-5,
    source="arXiv:2106.07447; unverified",
)
