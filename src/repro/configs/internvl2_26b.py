"""internvl2-26b — InternViT + InternLM2 backbone. [arXiv:2404.16821; hf]

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
VLM: the InternViT frontend is a STUB per the assignment — input_specs()
provides precomputed patch embeddings for train/prefill; decode is ordinary
token decode against the prefused cache.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    input_mode="embeddings",
    rope_theta=1000000.0,
    source="arXiv:2404.16821; hf",
)
