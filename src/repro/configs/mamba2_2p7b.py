"""mamba2-2.7b — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]
64L d_model=2560, d_ff=0 (pure Mamba2 blocks), vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, headdim 64 -> 80 SSD heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_ngroups=1,
    tie_embeddings=True,
    norm_eps=1e-5,
    source="arXiv:2405.21060; unverified",
)
