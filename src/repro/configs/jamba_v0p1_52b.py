"""jamba-v0.1-52b — Mamba + attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Period-8 blocks: attention at in-block offset 3 (4 attn layers of 32),
MoE every 2nd layer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,
    attn_offset=3,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_ngroups=1,
    source="arXiv:2403.19887; hf",
)
