"""Architecture + shape registry.

``get_config(name)`` returns the full-size ModelConfig for any of the 10
assigned architectures; ``SHAPES`` holds the 4 assigned input shapes;
``runnable_cells()`` enumerates the (arch x shape) dry-run grid with the
assignment's skip rules applied.
"""
from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, reduce_for_smoke
from repro.configs.dgnn import (
    BC_ALPHA,
    DATASETS,
    DGNN_CONFIGS,
    EVOLVEGCN,
    GCRN_M2,
    UCI,
    DatasetConfig,
    DGNNConfig,
)

from repro.configs import (  # noqa: E402  (registry imports)
    deepseek_coder_33b,
    granite_moe_3b,
    hubert_xlarge,
    internvl2_26b,
    jamba_v0p1_52b,
    llama4_maverick_400b,
    mamba2_2p7b,
    phi3_mini_3p8b,
    qwen2p5_14b,
    qwen3_32b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        mamba2_2p7b,
        deepseek_coder_33b,
        phi3_mini_3p8b,
        qwen2p5_14b,
        qwen3_32b,
        granite_moe_3b,
        llama4_maverick_400b,
        jamba_v0p1_52b,
        internvl2_26b,
        hubert_xlarge,
    )
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}") from None


def list_archs() -> list[str]:
    return sorted(ARCHS)


def cell_status(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """'run' or a skip reason, per the assignment's rules."""
    if shape.is_decode and cfg.is_encoder_only:
        return "skip: encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "skip: long_500k needs sub-quadratic attention (pure full-attention arch)"
    return "run"


def runnable_cells() -> list[tuple[str, str]]:
    out = []
    for a in list_archs():
        for s in SHAPES.values():
            if cell_status(ARCHS[a], s) == "run":
                out.append((a, s.name))
    return out


__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "list_archs",
    "cell_status",
    "runnable_cells",
    "reduce_for_smoke",
    "DGNN_CONFIGS",
    "DATASETS",
    "EVOLVEGCN",
    "GCRN_M2",
    "BC_ALPHA",
    "UCI",
    "DGNNConfig",
    "DatasetConfig",
]
