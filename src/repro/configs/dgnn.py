"""DGNN model + dataset configs (the paper's own models).

EvolveGCN-O (DGNN-Booster V1 base model): GCN spatial encoder whose weights
are evolved by a GRU. GCRN-M2 (DGNN-Booster V2 base model): graph-conv LSTM.
Dataset stats mirror Table III of the paper (BC-Alpha, UCI).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class DGNNConfig:
    name: str
    # "stacked" | "integrated" | "weights_evolved" (dense snapshot
    # streams) | "static" (T=1, no recurrence) | "event_memory" (ragged
    # timestamped event streams) — api.family_for maps each onto its
    # stream-engine registry family, whose cell spec declares the
    # matching temporal contract.
    dgnn_type: str
    gnn: str               # "gcn"
    rnn: str               # "gru" | "lstm" | "none"
    dataflow: str          # preferred engine: "v1" | "v2" | "v3"
    in_dim: int = 64       # raw node-feature dim
    hidden: int = 128      # GNN/RNN hidden width
    n_gnn_layers: int = 2
    edge_dim: int = 8      # edge-embedding dim (0 = no edge features)
    out_dim: int = 64      # task head output (link-pred embedding dim)
    # static padding buckets (TPU needs static shapes; see graph/padding.py)
    max_nodes: int = 640   # >= Table III max nodes (578)
    max_edges: int = 2048  # >= Table III max edges (1686)
    n_streams: int = 1     # batched independent dynamic-graph streams
    # V3 stream-engine D-axis block size: column width of the recurrent
    # state windows when the (n_global, hidden) store exceeds VMEM (see
    # docs/stream_engine.md). None = one block, fully resident.
    stream_td: int | None = None


EVOLVEGCN = DGNNConfig(
    name="evolvegcn",
    dgnn_type="weights_evolved",
    gnn="gcn",
    rnn="gru",
    dataflow="v1",
)

GCRN_M2 = DGNNConfig(
    name="gcrn-m2",
    dgnn_type="integrated",
    gnn="gcn",
    rnn="lstm",
    dataflow="v2",
)

# third taxonomy row of Table I (GCRN-M1 / WD-GCN style); both V1 and V2
# apply — included so the framework covers the whole taxonomy.
STACKED = DGNNConfig(
    name="stacked-gcn-gru",
    dgnn_type="stacked",
    gnn="gcn",
    rnn="gru",
    dataflow="v1",
)

# degenerate static family (GenGNN-style, no recurrence): T=1 snapshots
# fold onto the engine's batch axis — the serve express lane's workload.
STATIC_GCN = DGNNConfig(
    name="static-gcn",
    dgnn_type="static",
    gnn="gcn",
    rnn="none",
    dataflow="v3",
)

# event-driven temporal GNN (TGN/TGAT lineage): timestamped event
# batches over a global node-memory store. NOT in DGNN_CONFIGS — the
# snapshot-stream harness has no timestamps; tests build event streams
# through graph/events.py (tests/test_temporal.py).
TGN = DGNNConfig(
    name="tgn",
    dgnn_type="event_memory",
    gnn="tgn",
    rnn="gru",
    dataflow="v3",
    edge_dim=0,
)


@dataclass(frozen=True)
class DatasetConfig:
    """Synthetic temporal-graph generator parameters matching Table III."""

    name: str
    avg_nodes: int
    avg_edges: int
    max_nodes: int
    max_edges: int
    snapshots: int
    seed: int = 0


BC_ALPHA = DatasetConfig("bc-alpha", 107, 232, 578, 1686, 137, seed=1)
UCI = DatasetConfig("uci", 118, 269, 501, 1534, 192, seed=2)

DGNN_CONFIGS = {c.name: c for c in (EVOLVEGCN, GCRN_M2, STACKED, STATIC_GCN)}
DATASETS = {d.name: d for d in (BC_ALPHA, UCI)}
