from repro.optim.adamw import (
    AdamWConfig,
    apply_updates,
    dequantize_blockwise,
    global_norm,
    init_opt_state,
    opt_state_axes,
    quantize_blockwise,
    schedule,
)

__all__ = [
    "AdamWConfig", "apply_updates", "init_opt_state", "opt_state_axes",
    "schedule", "global_norm", "quantize_blockwise", "dequantize_blockwise",
]
