"""AdamW with optional quantized moments (no external deps).

``state_dtype``:
  fp32   exact moments (default).
  bf16   half-cost moments.
  int8   blockwise-quantized moments (8-bit-Adam style): int8 codes with
         per-block absmax scales (block = 256 elements). Needed for the
         ~400B-class archs to fit fp32 params + moments on a 16GB/chip pod
         (DESIGN §6; the dry-run memory analysis depends on it).

Optimizer state is stored as FLAT LISTS aligned with
``jax.tree.flatten(params)`` so quantized leaves (dicts of q/scale) never
confuse pytree traversal of the param structure.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

_BLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "fp32"  # fp32 | bf16 | int8
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


# ---------------------------------------------------------- quantization ----


def _block_for(last_dim: int) -> int:
    """Largest divisor of last_dim that is <= _BLOCK (axis-preserving)."""
    b = min(last_dim, _BLOCK)
    while last_dim % b:
        b -= 1
    return max(b, 1)


def quantize_blockwise(x: jax.Array) -> dict:
    """int8 absmax quantization in blocks along the LAST dim.

    Axis-preserving: q keeps the param's shape (int8), scale has shape
    param.shape[:-1] + (last/block,) — so both inherit the param's sharding
    (the leading dims carry the TP/FSDP axes). This is what lets the int8
    moments of a 400B model shard exactly like its weights.
    """
    if x.ndim == 0:
        x = x[None]
    block = _block_for(x.shape[-1])
    nb = x.shape[-1] // block
    xb = x.astype(jnp.float32).reshape(*x.shape[:-1], nb, block)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(x.shape), "scale": scale.astype(jnp.float32)}


def dequantize_blockwise(qd: dict, shape) -> jax.Array:
    q = qd["q"]
    scale = qd["scale"]
    block = q.shape[-1] // scale.shape[-1] if q.ndim == scale.ndim else q.shape[-1]
    nb = scale.shape[-1]
    xb = q.astype(jnp.float32).reshape(*q.shape[:-1], nb, q.shape[-1] // nb)
    return (xb * scale[..., None]).reshape(q.shape).reshape(shape)


def _encode(x: jax.Array, dtype: str, moment: str = "m"):
    if dtype == "fp32":
        return x.astype(jnp.float32)
    if dtype == "bf16":
        return x.astype(jnp.bfloat16)
    # int8 mode: first moment int8 (values within a block are same-scale);
    # second moment bf16 — its dynamic range breaks absmax-linear int8
    # (8-bit-Adam uses dynamic-exponent codes for v; bf16 is the jnp-native
    # equivalent). Memory: 1 + 2 bytes/param vs 8 fp32.
    if moment == "v":
        return x.astype(jnp.bfloat16)
    return quantize_blockwise(x)


def _decode(s, dtype: str, shape, moment: str = "m") -> jax.Array:
    if dtype in ("fp32", "bf16") or moment == "v":
        return s.astype(jnp.float32)
    return dequantize_blockwise(s, shape)


# -------------------------------------------------------------- adamw ----


def init_opt_state(params, cfg: AdamWConfig) -> dict:
    leaves = jax.tree.leaves(params)
    z = [_encode(jnp.zeros(l.shape, jnp.float32), cfg.state_dtype, "m")
         for l in leaves]
    z2 = [_encode(jnp.zeros(l.shape, jnp.float32), cfg.state_dtype, "v")
          for l in leaves]
    return {"m": z, "v": z2, "step": jnp.zeros((), jnp.int32)}


def opt_state_axes(params_axes, cfg: AdamWConfig) -> dict:
    """Logical-axis tree matching init_opt_state's structure.

    int8 moments inherit the param's axes (q keeps the shape; scale keeps
    the leading dims, last dim axis dropped to None — divisibility fallback
    covers the blocked tail).
    """
    from repro.distributed.api import Axes

    leaves = [l for l in jax.tree.leaves(
        params_axes, is_leaf=lambda v: isinstance(v, Axes))]

    def one(ax: "Axes", moment: str):
        names = ax.names if len(ax.names) else (None,)
        if cfg.state_dtype in ("fp32", "bf16") or moment == "v":
            return Axes(*names)
        return {"q": Axes(*names), "scale": Axes(*names)}

    ms = [one(a, "m") for a in leaves]
    vs = [one(a, "v") for a in leaves]
    return {"m": ms, "v": vs, "step": Axes()}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(params, grads, opt_state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = jax.tree.leaves(grads)
    assert len(p_leaves) == len(g_leaves)
    new_p, new_m, new_v = [], [], []
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    # token chain: optimization_barrier serializes per-leaf updates so the
    # transient fp32 decode of the (possibly quantized) moments peaks at ONE
    # leaf, not the whole state (critical for the 400B-class memory fit)
    def leaf_update(p, g, m_s, v_s):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * _decode(m_s, cfg.state_dtype, p.shape, "m") + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(v_s, cfg.state_dtype, p.shape, "v") + (1 - cfg.b2) * g * g
        delta = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, _encode(m, cfg.state_dtype, "m"), _encode(v, cfg.state_dtype, "v")

    token = jnp.zeros((), jnp.float32)
    for p, g, m_s, v_s in zip(p_leaves, g_leaves, opt_state["m"], opt_state["v"]):
        g, m_s, v_s, token = jax.lax.optimization_barrier((g, m_s, v_s, token))
        if p.ndim >= 3 and p.shape[0] <= 128 and p.size > 10**8:
            # huge stacked-block leaf: stream the update over the leading
            # (layers) dim so the fp32 moment decode peaks at one block
            newp, m_new, v_new = jax.lax.map(
                lambda args: leaf_update(*args), (p, g, m_s, v_s))
        else:
            newp, m_new, v_new = leaf_update(p, g, m_s, v_s)
        token = newp.ravel()[0].astype(jnp.float32)
        new_p.append(newp)
        new_m.append(m_new)
        new_v.append(v_new)
    metrics = {"grad_norm": gnorm, "lr": lr, "step": step}
    return (jax.tree.unflatten(treedef, new_p),
            {"m": new_m, "v": new_v, "step": step}, metrics)
