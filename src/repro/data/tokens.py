"""Synthetic LM data pipeline (container is offline; deterministic).

Zipf-distributed token streams with local n-gram structure so the loss has
something to learn; shifted next-token targets; device placement with the
batch sharding of the active mesh.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.api import current_mesh, named_sharding


def synthetic_lm_batches(cfg: ModelConfig, batch: int, seq: int,
                         seed: int = 0, mesh=None) -> Iterator[dict]:
    """Yields {"tokens": (B,S), "targets": (B,S)} (or embeds for stub-frontend
    archs) forever."""
    rng = np.random.default_rng(seed)
    vocab = cfg.vocab_size
    mesh = mesh or current_mesh()
    # a fixed random bigram table gives learnable structure
    fanout = 32
    table = rng.integers(0, vocab, size=(vocab, fanout))
    while True:
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = rng.zipf(1.3, size=batch) % vocab
        choice = rng.integers(0, fanout, size=(batch, seq))
        noise = rng.random((batch, seq)) < 0.1
        rand_tok = rng.integers(0, vocab, size=(batch, seq))
        for t in range(seq):
            nxt = table[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        out = {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }
        if cfg.input_mode == "embeddings":
            emb = rng.normal(0, 1, (batch, seq, cfg.d_model)).astype(np.float32)
            out = {"embeds": emb, "targets": out["targets"]}
        if mesh is not None:
            def put(a):
                names = ("batch",) + (None,) * (a.ndim - 1)
                return jax.device_put(a, named_sharding(a.shape, names, mesh))
            out = {k: put(v) for k, v in out.items()}
        yield out
