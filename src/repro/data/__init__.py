from repro.data.tokens import synthetic_lm_batches

__all__ = ["synthetic_lm_batches"]
