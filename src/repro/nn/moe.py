"""Mixture-of-Experts with expert parallelism over the model axis.

Two execution paths, same math (softmax -> top-k -> renormalized combine):

dense    every expert computed on every token, combined with the top-k
         mask — exact, O(E) waste; only for smoke-test-sized configs.

ep       the production path, a shard_map over the mesh:
           1. route tokens locally (router weights replicated),
           2. bucket token copies by destination model-shard (sort +
              within-bucket position, capacity-dropped — GShard-style),
           3. all_to_all over 'model' to the expert-owning shards,
           4. locally re-bucket by expert and run the SwiGLU as one
              rectangular batched matmul per shard (MXU-friendly),
           5. all_to_all back, gate, and scatter-add into the output.
         FSDP'd expert weights are all-gathered over 'data' (bf16) inside
         the shard_map — explicit ZeRO-3.

Capacity factors make every buffer static-shape; dropped token copies lose
their expert contribution (their gate mass is renormalized over survivors
at combine). Bucket waste (cf_send * cf_local) is deliberate baseline
slack and a hillclimb lever (EXPERIMENTS §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.api import Axes, current_mesh, shard
from repro.nn.layers import ACT_DTYPE, normal_init
from repro.nn.mlp import init_mlp, mlp_block


def init_moe(key, cfg: ModelConfig, tp: int):
    d, f = cfg.d_model, cfg.d_ff
    e_pad = cfg.padded_experts(tp)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    down_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {
        "router": normal_init(k1, (d, e_pad), 0.02),
        "w_gate": normal_init(k2, (e_pad, d, f), 0.02),
        "w_up": normal_init(k3, (e_pad, d, f), 0.02),
        "w_down": normal_init(k4, (e_pad, f, d), down_scale),
    }
    ax = {
        "router": Axes(None, None),
        "w_gate": Axes("experts", "embed_fsdp", None),
        "w_up": Axes("experts", "embed_fsdp", None),
        "w_down": Axes("experts", None, "embed_fsdp"),
    }
    if cfg.n_shared_experts:
        ps, axs = init_mlp(k5, d, cfg.n_shared_experts * f, cfg.n_layers)
        p["shared"] = ps
        ax["shared"] = axs
    return p, ax


def _route(router_w, x2, cfg: ModelConfig):
    """x2 (t, d) -> (gates (t,k) fp32 renormalized, eidx (t,k) int32)."""
    from repro.nn.layers import LOWMEM_NORM

    if LOWMEM_NORM:
        # no fp32 copy of the whole token stream: bf16 matmul with fp32
        # accumulation (router logits are tiny)
        logits = jnp.einsum("td,de->te", x2.astype(ACT_DTYPE),
                            router_w.astype(ACT_DTYPE),
                            preferred_element_type=jnp.float32)
    else:
        logits = (x2.astype(jnp.float32) @ router_w.astype(jnp.float32))
    emask = jnp.where(jnp.arange(logits.shape[-1]) < cfg.n_experts, 0.0, -1e9)
    probs = jax.nn.softmax(logits + emask, axis=-1)
    gates, eidx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, eidx


# ---------------------------------------------------------------- dense ----


def _moe_dense(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    gates, eidx = _route(p["router"], x2, cfg)
    e_pad = p["w_gate"].shape[0]
    # combine weights (t, E): scatter top-k gates
    comb = (jax.nn.one_hot(eidx, e_pad, dtype=jnp.float32) * gates[..., None]).sum(axis=1)
    g = jnp.einsum("td,edf->tef", x2, p["w_gate"].astype(ACT_DTYPE))
    u = jnp.einsum("td,edf->tef", x2, p["w_up"].astype(ACT_DTYPE))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(ACT_DTYPE))
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), comb)
    return out.astype(x.dtype).reshape(b, s, d)


# ------------------------------------------------------------------- ep ----


def _bucket_by(dest: jax.Array, n_buckets: int, capacity: int):
    """Sort ids by bucket; return (order, slot, valid) where slot is the
    flat position dest*capacity + within-bucket-position (OOB when dropped)."""
    order = jnp.argsort(dest, stable=True)
    d_sorted = dest[order]
    first = jnp.searchsorted(d_sorted, jnp.arange(n_buckets), side="left")
    pos = jnp.arange(dest.shape[0]) - first[d_sorted]
    valid = pos < capacity
    slot = jnp.where(valid, d_sorted * capacity + pos, n_buckets * capacity)
    return order, slot, valid


def _ep_body(x, router_w, w_gate, w_up, w_down, *, cfg: ModelConfig, tp: int,
             e_pad: int, cap_send: int, cap_local: int, fsdp: bool):
    """Per-shard body under shard_map. x: (b_l, s_l, d) local block."""
    if fsdp:
        # explicit ZeRO-3: gather the FSDP-sharded dim (D for gate/up at
        # axis 1, D for down at axis 2) over 'data', in bf16
        w_gate = jax.lax.all_gather(w_gate.astype(ACT_DTYPE), "data", axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up.astype(ACT_DTYPE), "data", axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down.astype(ACT_DTYPE), "data", axis=2, tiled=True)
    else:
        w_gate = w_gate.astype(ACT_DTYPE)
        w_up = w_up.astype(ACT_DTYPE)
        w_down = w_down.astype(ACT_DTYPE)
    e_loc = e_pad // tp
    b_l, s_l, d = x.shape
    t = b_l * s_l
    x2 = x.reshape(t, d)
    gates, eidx = _route(router_w, x2, cfg)            # (t,k)
    k = cfg.top_k
    tok = jnp.repeat(jnp.arange(t), k)                 # (t*k,)
    e_flat = eidx.reshape(-1)
    dest = e_flat // e_loc
    order, slot, valid = _bucket_by(dest, tp, cap_send)
    # send buffers (+1 trash row dropped at gather-back)
    send_x = jnp.zeros((tp * cap_send + 1, d), ACT_DTYPE)
    send_e = jnp.zeros((tp * cap_send + 1,), jnp.int32)
    send_x = send_x.at[slot].set(x2[tok[order]].astype(ACT_DTYPE), mode="drop")
    send_e = send_e.at[slot].set(e_flat[order] % e_loc, mode="drop")
    recv_x = jax.lax.all_to_all(
        send_x[: tp * cap_send].reshape(tp, cap_send, d), "model", 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(
        send_e[: tp * cap_send].reshape(tp, cap_send), "model", 0, 0, tiled=False)
    t2 = tp * cap_send
    rx = recv_x.reshape(t2, d)
    re = recv_e.reshape(t2)
    # local re-bucket by expert -> rectangular batched matmul
    order2, slot2, valid2 = _bucket_by(re, e_loc, cap_local)
    bx = jnp.zeros((e_loc * cap_local + 1, d), ACT_DTYPE)
    bx = bx.at[slot2].set(rx[order2], mode="drop")
    bx = bx[: e_loc * cap_local].reshape(e_loc, cap_local, d)
    g = jnp.einsum("ecd,edf->ecf", bx, w_gate)
    u = jnp.einsum("ecd,edf->ecf", bx, w_up)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)
    # un-bucket locally: y back to recv slots
    y2 = jnp.zeros((t2, d), ACT_DTYPE)
    y2 = y2.at[order2].set(
        jnp.where(valid2[:, None], y.reshape(-1, d)[jnp.minimum(slot2, e_loc * cap_local - 1)], 0))
    back = jax.lax.all_to_all(y2.reshape(tp, cap_send, d), "model", 0, 0, tiled=False)
    back2 = back.reshape(t2, d)
    # gate + scatter-add into the t local tokens
    from repro.nn.layers import LOWMEM_NORM

    acc_dt = ACT_DTYPE if LOWMEM_NORM else jnp.float32
    contrib = jnp.where(valid[:, None],
                        back2[jnp.minimum(slot, t2 - 1)], 0)  # (t*k, d) in sorted order
    g_sorted = gates.reshape(-1)[order]
    out = jnp.zeros((t, d), acc_dt)
    out = out.at[tok[order]].add(contrib.astype(acc_dt)
                                 * g_sorted[:, None].astype(acc_dt))
    return out.astype(x.dtype).reshape(b_l, s_l, d)


def _mesh_axis_size(mesh, name: str) -> int:
    if mesh is None or name not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def moe_block(p: dict, cfg: ModelConfig, x: jax.Array, *,
              impl: str = "auto", fsdp: bool = False,
              cf_send: float = 1.25, cf_local: float = 1.25) -> jax.Array:
    """MoE sublayer (no norm/residual). x: (B, S, D)."""
    mesh = current_mesh()
    tp = _mesh_axis_size(mesh, "model")
    use_ep = (impl == "ep") or (impl == "auto" and tp > 1)
    if use_ep:
        from jax.experimental.shard_map import shard_map

        e_pad = p["w_gate"].shape[0]
        b, s, d = x.shape
        dp = _mesh_axis_size(mesh, "data") * _mesh_axis_size(mesh, "pod")
        dp_eff = dp if b % dp == 0 else 1        # b=1 decode: replicate batch
        sp = tp if s % tp == 0 else 1
        t_local = (b // dp_eff) * (s // sp)
        cap_send = max(8, int(math.ceil(t_local * cfg.top_k * cf_send / tp)))
        cap_local = max(8, int(math.ceil(cap_send * tp * cf_local / (e_pad // tp))))
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        x_spec = P(batch_axes if dp_eff > 1 else None,
                   "model" if sp > 1 else None, None)
        w_spec = P("model", "data" if fsdp else None, None)
        body = functools.partial(
            _ep_body, cfg=cfg, tp=tp, e_pad=e_pad,
            cap_send=cap_send, cap_local=cap_local, fsdp=fsdp)
        y = shard_map(
            body, mesh=mesh,
            in_specs=(x_spec, P(None, None), w_spec, w_spec,
                      P("model", None, "data" if fsdp else None)),
            out_specs=x_spec,
            check_rep=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    else:
        y = _moe_dense(p, cfg, x)
    if cfg.n_shared_experts:
        y = y + mlp_block(p["shared"], x)
    return y
