"""GQA attention: grouped einsum, q-chunked long-sequence path, KV-cache
decode with sequence-sharded caches for long contexts.

Never materializes the KV-head repeat: queries reshape to
(B, S, Hkv, group, hd) and scores are computed per KV head group.

Three paths:
  full      plain softmax attention (S small: train_4k, smoke tests)
  chunked   lax.map over query chunks, each attending the full (masked) KV —
            O(S * chunk) live memory; the baseline for prefill_32k. Causal
            waste (upper-triangle compute) is visible in the roofline and is
            a hillclimb lever (see kernels/flash_attention.py).
  decode    one-token query against a cache laid out (B, Skv, Hkv, hd);
            softmax reductions over a sharded Skv are handled by GSPMD
            (flash-decoding-style partial combines) when the cache is
            sequence-sharded (long_500k, batch=1).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import Axes, shard
from repro.nn.layers import ACT_DTYPE, apply_rope, normal_init, rms_norm

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, tp: int):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq = cfg.padded_heads(tp)
    hkv = cfg.padded_kv_heads(tp)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    o_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {
        "wq": normal_init(k1, (d, hq, hd), 0.02),
        "wk": normal_init(k2, (d, hkv, hd), 0.02),
        "wv": normal_init(k3, (d, hkv, hd), 0.02),
        "wo": normal_init(k4, (hq, hd, d), o_scale),
    }
    ax = {
        "wq": Axes("embed_fsdp", "heads", None),
        "wk": Axes("embed_fsdp", "kv_heads", None),
        "wv": Axes("embed_fsdp", "kv_heads", None),
        "wo": Axes("heads", None, "embed_fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), jnp.float32)
        p["bk"] = jnp.zeros((hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((hkv, hd), jnp.float32)
        ax["bq"] = Axes("heads", None)
        ax["bk"] = Axes("kv_heads", None)
        ax["bv"] = Axes("kv_heads", None)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
        ax["q_norm"] = Axes(None)
        ax["k_norm"] = Axes(None)
    return p, ax


def _project_qkv(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    """x: (B, S, D) -> q (B,S,Hq,hd), k,v (B,S,Hkv,hd); RoPE + qk_norm."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(ACT_DTYPE))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(ACT_DTYPE))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(ACT_DTYPE))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(ACT_DTYPE)
        k = k + p["bk"].astype(ACT_DTYPE)
        v = v + p["bv"].astype(ACT_DTYPE)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        # RoPE for decoders; also used as the positional scheme for the
        # encoder-only archs (stand-in for HuBERT's conv pos-emb; DESIGN §5)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Sq,Hq,hd), k (B,Sk,Hkv,hd) -> (B,Hkv,G,Sq,Sk) fp32 logits."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    s = jnp.einsum("bqhgk,bshk->bhgqs", qg, k).astype(jnp.float32)
    return s / math.sqrt(hd)


def _grouped_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs (B,Hkv,G,Sq,Sk), v (B,Sk,Hkv,hd) -> (B,Sq,Hq,hd)."""
    b, hkv, g, sq, sk = probs.shape
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hkv * g, v.shape[-1])


def full_attention(q, k, v, *, causal: bool, q_offset: int = 0) -> jax.Array:
    scores = _grouped_scores(q, k)
    if causal:
        sq, sk = scores.shape[-2], scores.shape[-1]
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _grouped_out(probs, v)


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 2048) -> jax.Array:
    """lax.map over query chunks; each chunk attends the full masked KV."""
    b, s, hq, hd = q.shape
    if s <= chunk:
        return full_attention(q, k, v, causal=causal)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, hq, hd).transpose(1, 0, 2, 3, 4)  # (nc,B,c,H,hd)

    @jax.checkpoint  # probs recomputed in bwd: residual = one q chunk
    def one(args):
        i, qi = args
        return full_attention(qi, k, v, causal=causal, q_offset=i * chunk)

    outs = jax.lax.map(one, (jnp.arange(nc), qc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, hq, hd)


def decode_attention(q, k_cache, v_cache, kv_len) -> jax.Array:
    """q (B,1,Hq,hd) vs cache (B,Skv,Hkv,hd); positions >= kv_len masked.

    Written as an ordinary softmax so GSPMD handles a sequence-sharded
    cache (long_500k) by partial-max/partial-sum collectives.
    """
    scores = _grouped_scores(q, k_cache)                 # (B,Hkv,G,1,Skv)
    skv = k_cache.shape[1]
    mask = jnp.arange(skv)[None, :] < jnp.asarray(kv_len)[..., None]  # (B,Skv) or (1,Skv)
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _grouped_out(probs, v_cache)


def flash_attention(q, k, v, *, causal: bool, bq: int = 512, bk: int = 512):
    """Pallas flash kernel, shard_map'd over (batch, heads) when a mesh is
    active. q (B,S,Hq,hd), k/v (B,S,Hkv,hd); heads kv-major like the
    grouped-einsum path."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.api import current_mesh
    from repro.kernels.flash_attention import flash_mha

    interpret = jax.default_backend() != "tpu"

    def local(q_, k_, v_):
        b, s, hq, hd = q_.shape
        hkv = k_.shape[2]
        g = hq // hkv
        q2 = q_.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
        k2 = k_.transpose(0, 2, 1, 3).reshape(b * hkv, k_.shape[1], hd)
        v2 = v_.transpose(0, 2, 1, 3).reshape(b * hkv, v_.shape[1], hd)
        # differentiable (custom-vjp flash bwd kernels) -> usable for train
        o = flash_mha(q2, k2, v2, causal, bq, bk, g, interpret)
        return o.reshape(b, hq, s, hd).transpose(0, 2, 1, 3)

    mesh = current_mesh()
    if mesh is None:
        return local(q, k, v)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if (batch_axes and q.shape[0] % _axes_size(mesh, batch_axes) == 0) else None
    hspec = "model" if "model" in mesh.axis_names and q.shape[2] % _axes_size(mesh, ("model",)) == 0 else None
    qs = P(bspec, None, hspec, None)
    return shard_map(local, mesh=mesh, in_specs=(qs, qs, qs), out_specs=qs,
                     check_rep=False)(q, k, v)


def _axes_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def attention_block(p: dict, cfg: ModelConfig, x: jax.Array, positions, *,
                    attn_chunk: int = 2048, cache: Optional[dict] = None,
                    long_ctx: bool = False, attn_impl: str = "xla",
                    flash_bq: int = 512, flash_bk: int = 512):
    """Full attention sublayer (no norm/residual). Returns (out, new_cache).

    cache (decode): {"k": (B,Skv,Hkv,hd) bf16, "v": same, "len": (B,) or ()}
    """
    q, k, v = _project_qkv(p, cfg, x, positions)
    if cache is not None:
        pos = cache["len"]
        kv_ax = "kv_seq_dp" if long_ctx else "kv_seq"
        quant = "k_s" in cache
        if quant:
            kq, ks = _kv_quantize(k)
            vq, vs = _kv_quantize(v)
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, pos, axis=1)
            ks_c = jax.lax.dynamic_update_slice_in_dim(cache["k_s"], ks, pos, axis=1)
            vs_c = jax.lax.dynamic_update_slice_in_dim(cache["v_s"], vs, pos, axis=1)
            k_full = _kv_dequantize(k_cache, ks_c)
            v_full = _kv_dequantize(v_cache, vs_c)
            k_full = shard(k_full, "batch", kv_ax, "kv_heads", None)
            v_full = shard(v_full, "batch", kv_ax, "kv_heads", None)
            out = decode_attention(q, k_full, v_full, pos + 1)
            new_cache = {"k": k_cache, "v": v_cache, "k_s": ks_c, "v_s": vs_c,
                         "len": pos + 1}
        else:
            # write the single new (k, v) at position pos. For a
            # sequence-sharded cache (long_ctx) use the shard-local one-hot
            # blend (no collective); otherwise dynamic_update_slice touches
            # only one page.
            if long_ctx:
                k_cache = _write_kv(cache["k"], k, pos)
                v_cache = _write_kv(cache["v"], v, pos)
            else:
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
            k_cache = shard(k_cache, "batch", kv_ax, "kv_heads", None)
            v_cache = shard(v_cache, "batch", kv_ax, "kv_heads", None)
            out = decode_attention(q, k_cache, v_cache, pos + 1)
            new_cache = {"k": k_cache, "v": v_cache, "len": pos + 1}
    else:
        if attn_impl == "flash":
            out = flash_attention(q, k, v, causal=cfg.causal, bq=flash_bq,
                                  bk=flash_bk)
        elif x.shape[1] > attn_chunk:
            out = chunked_attention(q, k, v, causal=cfg.causal, chunk=attn_chunk)
        else:
            out = full_attention(q, k, v, causal=cfg.causal)
        new_cache = None
    out = shard(out, "batch", None, "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(ACT_DTYPE))
    return y, new_cache


def _write_kv(cache: jax.Array, new: jax.Array, pos) -> jax.Array:
    """cache (B,Skv,Hkv,hd); new (B,1,Hkv,hd); write at seq position pos."""
    b = cache.shape[0]
    onehot = (jnp.arange(cache.shape[1]) == pos).astype(cache.dtype)  # (Skv,)
    return cache * (1 - onehot)[None, :, None, None] + new.astype(cache.dtype) * onehot[None, :, None, None]


def init_decode_cache(cfg: ModelConfig, batch: int, skv: int, tp: int,
                      dtype=jnp.bfloat16, quant: bool = False):
    hkv, hd = cfg.padded_kv_heads(tp), cfg.resolved_head_dim
    if quant:
        # int8 KV cache with per-(token, head) absmax scales: 8x less HBM
        # than fp32 / 2x less than bf16, and the decode memory bound is the
        # cache read (EXPERIMENTS §Perf, decode iteration)
        return {
            "k": jnp.zeros((batch, skv, hkv, hd), jnp.int8),
            "v": jnp.zeros((batch, skv, hkv, hd), jnp.int8),
            "k_s": jnp.zeros((batch, skv, hkv), jnp.bfloat16),
            "v_s": jnp.zeros((batch, skv, hkv), jnp.bfloat16),
            "len": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, skv, hkv, hd), dtype),
        "v": jnp.zeros((batch, skv, hkv, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def _kv_quantize(x: jax.Array):
    """x (B,1,Hkv,hd) -> (int8 codes, bf16 scale (B,1,Hkv))."""
    s = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s.astype(jnp.bfloat16)


def _kv_dequantize(q: jax.Array, s: jax.Array) -> jax.Array:
    return (q.astype(jnp.bfloat16) * s.astype(jnp.bfloat16)[..., None])
