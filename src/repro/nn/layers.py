"""Common NN layers: norms, RoPE, embeddings, init helpers.

Every init function returns (params, axes) — two same-structure dicts, the
second holding ``Axes`` logical-axis leaves consumed by distributed/api.
Compute follows the bf16-activations / fp32-norms-and-softmax convention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.api import Axes

ACT_DTYPE = jnp.bfloat16


def normal_init(key, shape, scale: float):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(jnp.float32)


# §Perf iteration switch (per-process; the dry-run sets it from overrides so
# baseline cells stay baseline): low-mem norm avoids any full-width fp32
# intermediate, keeping residual-stream collectives bf16 on the wire.
LOWMEM_NORM = False


def set_lowmem_norm(v: bool) -> None:
    global LOWMEM_NORM
    LOWMEM_NORM = bool(v)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf32 = x.astype(jnp.float32)
    var = jnp.mean(xf32 * xf32, axis=-1, keepdims=True)
    if LOWMEM_NORM:
        # fp32 statistics, but the (B,S,D) tensor is only touched in its own
        # dtype -> forward all-gathers / backward reduce-scatters stay bf16
        inv = (jax.lax.rsqrt(var + eps)).astype(x.dtype)
        return x * inv * scale.astype(x.dtype)
    return ((xf32 * jax.lax.rsqrt(var + eps))
            * scale.astype(jnp.float32)).astype(x.dtype)


@jax.custom_vjp
def bf16_cotangent(x: jax.Array) -> jax.Array:
    """Identity whose backward rounds the cotangent through bf16.

    Placed at the backbone->loss boundary it demotes the entire backward
    residual-stream chain (and thus every backward TP collective) from the
    fp32 the loss head promotes to, to bf16 — 2x less gradient-activation
    wire/HBM traffic. Parameter gradients keep their dtype.
    """
    return x


def _bf16_ct_fwd(x):
    # zero-size token carries the primal dtype (dtypes aren't JAX types)
    return x, jnp.zeros((0,), x.dtype)


def _bf16_ct_bwd(tok, g):
    # demote to bf16 (the wire dtype), then to the primal dtype if narrower
    g = g.astype(jnp.bfloat16)
    return (g if tok.dtype == jnp.bfloat16 else g.astype(tok.dtype),)


bf16_cotangent.defvjp(_bf16_ct_fwd, _bf16_ct_bwd)


def init_rms_norm(d: int):
    return jnp.ones((d,), jnp.float32), Axes(None)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S).

    Trig is always fp32 (position precision); under LOWMEM_NORM the wide
    (B,S,H,hd) elementwise chain runs in x.dtype instead of fp32 — §Perf
    iteration D4 (rope was ~25% of per-layer HBM bytes at 32k)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    wide_dt = x.dtype if LOWMEM_NORM else jnp.float32
    cos = jnp.cos(ang)[..., None, :].astype(wide_dt)     # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :].astype(wide_dt)
    x1, x2 = jnp.split(x.astype(wide_dt), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_embedding(key, vocab_pad: int, d: int):
    w = normal_init(key, (vocab_pad, d), 0.02)
    return w, Axes("vocab", "embed_fsdp")


def embed_lookup(w: jax.Array, tokens: jax.Array) -> jax.Array:
    return w.astype(ACT_DTYPE)[tokens]


def init_lm_head(key, d: int, vocab_pad: int):
    w = normal_init(key, (d, vocab_pad), 0.02)
    return w, Axes("embed_fsdp", "vocab")


def vocab_mask(vocab_pad: int, vocab: int) -> jax.Array:
    """0 for real vocab entries, -inf (large negative) for padding columns."""
    return jnp.where(jnp.arange(vocab_pad) < vocab, 0.0, -1e9).astype(jnp.float32)
