"""SwiGLU MLP."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import Axes, shard
from repro.nn.layers import ACT_DTYPE, normal_init


def init_mlp(key, d: int, f: int, n_layers: int):
    k1, k2, k3 = jax.random.split(key, 3)
    down_scale = 0.02 / math.sqrt(2 * n_layers)
    p = {
        "w_gate": normal_init(k1, (d, f), 0.02),
        "w_up": normal_init(k2, (d, f), 0.02),
        "w_down": normal_init(k3, (f, d), down_scale),
    }
    ax = {
        "w_gate": Axes("embed_fsdp", "ffn"),
        "w_up": Axes("embed_fsdp", "ffn"),
        "w_down": Axes("ffn", "embed_fsdp"),
    }
    return p, ax


def mlp_block(p: dict, x: jax.Array) -> jax.Array:
    """x (B,S,D) -> (B,S,D); intermediate sharded on ffn/model axis."""
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(ACT_DTYPE))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(ACT_DTYPE))
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", None, "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(ACT_DTYPE))
