"""Mamba2 mixer: chunked SSD (state-space duality) + O(1) decode.

The chunked SSD algorithm is itself the paper's V1 insight transplanted
(DESIGN §2, §Arch-applicability): within a chunk, computation is dense and
parallel (MXU matmuls, no recurrence); only a small (nheads, P, N) state
crosses chunk boundaries through a short scan — the "recurrent small op
overlapped with parallel large op" split that DGNN-Booster exploits
between RNN and GNN.

Shapes: x (B, S, D) -> in_proj -> z (B,S,d_inner), xb (B,S,d_inner),
B/C (B,S,G,N), dt (B,S,H). Heads H = d_inner / P (P = ssm_head_dim).
Chunked scan with chunk length Q:
  intra-chunk: Y_intra = (C B^T ⊙ decay-mask) @ X   (dense, per chunk)
  inter-chunk: states S_c = (decay-weighted B X^T) accumulated by a scan
               over chunks; Y_inter = C @ S_carried.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import Axes, shard
from repro.nn.layers import ACT_DTYPE, normal_init, rms_norm


def init_mamba(key, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    conv_ch = di + 2 * g * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * g * n + h
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    # dt bias: softplus^-1 of dt in [1e-3, 1e-1] (mamba2 init)
    u = jax.random.uniform(k3, (h,), jnp.float32)
    dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    p = {
        "in_proj": normal_init(k1, (d, d_in_proj), 0.02),
        "conv_w": normal_init(k2, (cfg.ssm_conv, conv_ch), 0.2),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias,
        "ssm_norm": jnp.ones((di,), jnp.float32),
        "out_proj": normal_init(k4, (di, d), out_scale),
    }
    ax = {
        "in_proj": Axes("embed_fsdp", "ssm_inner"),
        "conv_w": Axes(None, "conv_dim"),
        "conv_b": Axes("conv_dim",),
        "A_log": Axes("ssm_heads",),
        "D": Axes("ssm_heads",),
        "dt_bias": Axes("ssm_heads",),
        "ssm_norm": Axes("ssm_inner",),
        "out_proj": Axes("ssm_inner", "embed_fsdp"),
    }
    return p, ax


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * g * n]
    dt = proj[..., di + di + 2 * g * n :]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. xbc (B,S,C), w (K,C).

    Under LOWMEM (the §Perf m4 switch) this is a single grouped
    conv_general_dilated — one fused op instead of K shifted multiply-adds
    (whose autodiff chain materialized ~4x the tensor in fp32)."""
    from repro.nn.layers import LOWMEM_NORM

    k = w.shape[0]
    if LOWMEM_NORM:
        c = xbc.shape[-1]
        out = jax.lax.conv_general_dilated(
            xbc, w[:, None, :].astype(xbc.dtype),  # (K, 1, C) HIO-ish
            window_strides=(1,), padding=[(k - 1, 0)],
            dimension_numbers=("NWC", "WIO", "NWC"),
            feature_group_count=c)
        return jax.nn.silu(out + b.astype(xbc.dtype))
    out = xbc * w[k - 1].astype(xbc.dtype)
    for i in range(1, k):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * w[k - 1 - i].astype(xbc.dtype)
    return jax.nn.silu(out + b.astype(xbc.dtype))


# §Perf switch (per-process, set by the dry-run from overrides): compute the
# SSD state/output einsums in bf16 (fp32 accumulation via
# preferred_element_type) instead of full fp32.
SSD_BF16 = False


def set_ssd_bf16(v: bool) -> None:
    global SSD_BF16
    SSD_BF16 = bool(v)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    xh (B,S,H,P), dt (B,S,H) (post-softplus), A (H,) negative,
    Bm/Cm (B,S,G,N). Returns y (B,S,H,P), final_state (B,H,P,N).
    """
    b, s, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    if s % chunk:
        # pad to a chunk multiple with dt=0 steps: decay=1 and update=0, so
        # the final state is untouched; padded outputs are sliced off below
        pad = chunk - s % chunk
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_orig, s = s, xh.shape[1]
    nc = s // chunk
    rep = h // g
    # chunk-major layout so lax.map streams one chunk at a time — bounds the
    # O(q^2) intra-chunk buffers to a single chunk (the V1 lesson: dense
    # intra-chunk work is independent across chunks; only the small state
    # recurrence serializes).
    xc = xh.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)      # (nc,b,q,h,p)
    dtc = dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)           # (nc,b,q,h)
    Bc = Bm.reshape(b, nc, chunk, g, n).transpose(1, 0, 2, 3, 4)
    Cc = Cm.reshape(b, nc, chunk, g, n).transpose(1, 0, 2, 3, 4)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint  # the O(q^2) intra-chunk buffers are recomputed in bwd
    def per_chunk(args):
        xq, dtq, Bq, Cq = args            # (b,q,h,p), (b,q,h), (b,q,g,n) x2
        cums = jnp.cumsum(dtq * A, axis=1)                # (b,q,h)
        li = cums[:, :, None, :] - cums[:, None, :, :]    # (b,q,q,h)
        L = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0).astype(ACT_DTYPE)
        Bh = jnp.repeat(Bq, rep, axis=2)                  # (b,q,h,n)
        Ch = jnp.repeat(Cq, rep, axis=2)
        scores = jnp.einsum("bqhn,bkhn->bqkh", Ch, Bh).astype(ACT_DTYPE)
        ydt = (dtq[..., None] * xq).astype(ACT_DTYPE)     # (b,q,h,p)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", scores * L, ydt).astype(jnp.float32)
        decay_to_end = jnp.exp(cums[:, -1:, :] - cums)    # (b,q,h)
        if SSD_BF16:
            state = jnp.einsum(
                "bqhn,bqh,bqhp->bhpn",
                Bh.astype(ACT_DTYPE), (decay_to_end * dtq).astype(ACT_DTYPE),
                xq.astype(ACT_DTYPE),
                preferred_element_type=jnp.float32)
        else:
            state = jnp.einsum("bqhn,bqh,bqhp->bhpn", Bh, decay_to_end * dtq, xq)
        return y_intra, state, cums

    y_intra, states, cums_all = jax.lax.map(per_chunk, (xc, dtc, Bc, Cc))
    # states (nc,b,h,p,n); cums_all (nc,b,q,h)
    chunk_decay = jnp.exp(cums_all[:, :, -1, :])          # (nc,b,h)

    def scan_body(carry, inp):
        st, dec = inp                                     # (b,h,p,n), (b,h)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                 # emit state ENTERING chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, entering = jax.lax.scan(scan_body, init, (states, chunk_decay))
    # inter-chunk output (no q^2 term): y_inter = C . entering-state . decay
    decay_from_start = jnp.exp(cums_all)                  # (nc,b,q,h)
    Ch_all = jnp.repeat(Cc, rep, axis=3)                  # (nc,b,q,h,n)
    if SSD_BF16:
        y_inter = jnp.einsum(
            "cbqhn,cbhpn,cbqh->cbqhp", Ch_all.astype(ACT_DTYPE),
            entering.astype(ACT_DTYPE), decay_from_start.astype(ACT_DTYPE),
            preferred_element_type=jnp.float32)
    else:
        y_inter = jnp.einsum("cbqhn,cbhpn,cbqh->cbqhp", Ch_all, entering,
                             decay_from_start)
    y = (y_intra + y_inter).transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y[:, :s_orig], final


def mamba_block(p: dict, cfg: ModelConfig, x: jax.Array, *,
                state: dict | None = None):
    """Mamba2 sublayer (no norm/residual). Returns (out, new_state).

    state (decode): {"conv": (B, K-1, C), "ssm": (B, H, P, N)}.
    """
    di, gn, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    pp = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(ACT_DTYPE))
    proj = shard(proj, "batch", None, "ssm_inner")
    z, xbc, dt = _split_proj(cfg, proj)
    A = -jnp.exp(p["A_log"])  # (H,)
    if state is None:
        xbc_raw = xbc
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xpart = xbc[..., :di]
        Bm = xbc[..., di : di + gn * n].reshape(*xbc.shape[:2], gn, n)
        Cm = xbc[..., di + gn * n :].reshape(*xbc.shape[:2], gn, n)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
        xh = xpart.reshape(*xpart.shape[:2], h, pp)
        y, final = ssd_chunked(xh.astype(jnp.float32), dtv, A,
                               Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                               cfg.ssm_chunk)
        y = y + xh.astype(jnp.float32) * p["D"][:, None]
        # prefill -> decode handoff state (conv state is pre-activation taps)
        new_state = {
            "conv": xbc_raw[:, -(cfg.ssm_conv - 1):, :].astype(ACT_DTYPE),
            "ssm": final,
        }
    else:
        # one-token recurrent update
        conv_state = state["conv"]  # (B, K-1, C)
        window = jnp.concatenate([conv_state, xbc], axis=1)  # (B, K, C)
        w = p["conv_w"].astype(window.dtype)
        conv_out = jax.nn.silu((window * w[None]).sum(axis=1, keepdims=True)
                               + p["conv_b"].astype(window.dtype))
        new_conv = window[:, 1:]
        xpart = conv_out[..., :di]
        Bm = conv_out[..., di : di + gn * n].reshape(-1, 1, gn, n)
        Cm = conv_out[..., di + gn * n :].reshape(-1, 1, gn, n)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,1,H)
        xh = xpart.reshape(-1, 1, h, pp).astype(jnp.float32)
        rep = h // gn
        Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # (B,1,H,N)
        Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
        dA = jnp.exp(dtv[:, 0, :] * A)  # (B,H)
        ssm = state["ssm"].astype(jnp.float32)  # (B,H,P,N)
        upd = jnp.einsum("bhn,bhp->bhpn", Bh[:, 0] * dtv[:, 0, :, None], xh[:, 0])
        ssm_new = ssm * dA[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", Ch[:, 0], ssm_new)[:, None]  # (B,1,H,P)
        y = y + xh * p["D"][:, None]
        new_state = {"conv": new_conv, "ssm": ssm_new.astype(state["ssm"].dtype)}
    y = y.astype(ACT_DTYPE)
    # gated per-head RMS norm (TP-local: normalizes within heads; DESIGN §5)
    yh = y.reshape(*y.shape[:2], h, pp)
    sc = p["ssm_norm"].reshape(h, pp)
    yn = _gated_norm(yh, z, sc, cfg.norm_eps)
    y = yn.reshape(*yn.shape[:2], di).astype(ACT_DTYPE)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(ACT_DTYPE))
    return out, new_state


def _gated_norm(yh, z, scale_h, eps):
    """RMSNorm(y * silu(z)) per head (norm over head_dim only)."""
    zh = z.reshape(yh.shape)
    g = yh * jax.nn.silu(zh.astype(jnp.float32)).astype(yh.dtype)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    return (gf * jax.lax.rsqrt(var + eps) * scale_h).astype(yh.dtype)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, gn, n = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    conv_ch = di + 2 * gn * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), ACT_DTYPE),
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_head_dim, n), dtype),
    }
