"""Kernel-contract passes — static verification of the stream engine's
launch/DMA protocol, with a recording shim and NO device execution.

Every ``(family, residency, buffer_depth, td)`` point of the registry is
driven through launch assembly and kernel TRACING only: the pass installs
``stream_fused.set_trace_recorder`` and abstractly evaluates the launch
(``jax.eval_shape``), so the kernel body's Python-level paged protocol
(``stage_in`` / ``paged_fill`` / ``write_back``) runs and logs every
``pltpu.make_async_copy`` start/wait while no kernel ever executes.

Checked per point:
  * every DMA start has a matching wait before trace end, and stage-in /
    write-back stay synchronous pairs (``dma-unpaired-start``);
  * the read ring covers windows 0..D-1 in order and never reissues a
    ring slot while its previous copy is outstanding, under depths 1/2/4
    (``dma-ring-order``);
  * every paged state stages in and writes back — and a vmem launch
    issues no DMA at all (``dma-missing-site``);
  * every HBM-resident StateDef (and every ANY-memory-space input) is
    covered by ``input_output_aliases`` (``hbm-alias-coverage``);
  * ping-pong plane parity is consistent with the t grid axis: read/write
    planes alternate, step t reads what t-1 wrote starting from plane 0,
    the host-side final-plane select matches the simulated write parity,
    and paged plane pairs carry the right plane count
    (``pingpong-parity``);
  * the plan-time ``stream_vmem_bytes`` estimate equals the assembled
    VMEM scratch byte-exact (``vmem-bytes-drift``);
  * ``static`` temporal families declare zero StateDefs, no evolve hook,
    no aliases (``static-zero-states``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax

from repro.analysis import cases
from repro.analysis.core import Finding, Rule
from repro.kernels import ops, stream_fused

STREAM_FUSED_PATH = "src/repro/kernels/stream_fused.py"

RULES = {r.id: r for r in (
    Rule("dma-unpaired-start", "contracts", "error",
         "A make_async_copy start without a matching wait leaves the DMA "
         "in flight when its buffer/semaphore is reused — data races on "
         "real hardware that interpret-mode tests cannot see."),
    Rule("dma-ring-order", "contracts", "error",
         "The depth-buffered read ring must sweep windows in order and "
         "never restart a ring slot whose previous copy is outstanding "
         "(wait(w) strictly before start(w+depth))."),
    Rule("dma-missing-site", "contracts", "error",
         "Every paged state must stage in and write back exactly its "
         "window per program; a vmem launch must issue no DMA at all."),
    Rule("hbm-alias-coverage", "contracts", "error",
         "A paged store lives in HBM only via input_output_aliases; an "
         "unaliased ANY-space state input silently doubles HBM traffic "
         "and breaks evolve-in-place semantics."),
    Rule("pingpong-parity", "contracts", "error",
         "Read plane t%2 / write plane 1-t%2 / final plane after T steps "
         "must form one consistent parity scheme anchored at plane 0 — "
         "an off-by-one returns the stale state plane."),
    Rule("vmem-bytes-drift", "contracts", "error",
         "plan()'s stream_vmem_bytes budget check is only sound if it "
         "matches the assembled launch's VMEM scratch byte-exact."),
    Rule("static-zero-states", "contracts", "error",
         "The 'static' temporal contract means zero StateDefs, no evolve "
         "hook, nothing aliased — recurrence without declared state "
         "breaks serve checkpointing and the express lane."),
    Rule("launch-assembly-error", "contracts", "error",
         "A registry point that fails to assemble (or has no analysis "
         "fixture) cannot be verified — the point itself is the finding."),
)}


@dataclass(frozen=True)
class Point:
    """One contract-sweep coordinate."""

    family: str
    residency: str
    depth: Optional[int]
    td: Optional[int]

    def label(self) -> str:
        tag = f"{self.family}/{self.residency}/td={self.td}"
        return tag if self.depth is None else f"{tag}/depth={self.depth}"


def registry_points(registry=None):
    """The full sweep: both vmem blockings for every family, plus every
    legal buffer depth under hbm_paged for stateful families."""
    registry = stream_fused.REGISTRY if registry is None else registry
    pts = []
    for family in sorted(registry):
        spec = registry[family]
        pts.append(Point(family, "vmem", None, None))
        pts.append(Point(family, "vmem", None, cases.TD))
        if spec.temporal != "static":
            for depth in stream_fused.BUFFER_DEPTHS:
                pts.append(Point(family, "hbm_paged", depth, cases.TD))
    return pts


class LaunchRecorder:
    """The recording shim stream_fused's trace hooks feed."""

    def __init__(self):
        self.launches = []
        self.events = []

    def launch(self, family, launch):
        self.launches.append((family, launch))

    def dma(self, event, **tag):
        self.events.append({"event": event, **tag})


def trace_point(point: Point, registry=None) -> LaunchRecorder:
    """Assemble + trace one sweep point under the recorder. Abstract
    evaluation only — no kernel executes, no buffers materialize."""
    args = cases.stream_args(point.family)
    kw = dict(tn=cases.TN, td=point.td)
    if point.residency == "hbm_paged":
        kw.update(state_residency="hbm_paged", buffer_depth=point.depth)
    rec = LaunchRecorder()
    prev = stream_fused.set_trace_recorder(rec)
    stream_fused.stream_call.clear_cache()
    try:
        jax.eval_shape(lambda: ops.stream_steps(point.family, *args, **kw))
    finally:
        stream_fused.set_trace_recorder(prev)
        stream_fused.stream_call.clear_cache()
    return rec


def _find(rule: str, msg: str, path: str = STREAM_FUSED_PATH,
          line: int = 0) -> Finding:
    r = RULES[rule]
    return Finding(rule, r.group, r.severity, path, line, msg)


# ---------------------------------------------------------- sub-checks --

def check_registry_declarations(registry=None):
    """static families declare zero StateDefs / no state-less recurrence
    (re-checked here so an injected spec that bypassed import-time
    validation still surfaces)."""
    registry = stream_fused.REGISTRY if registry is None else registry
    out = []
    for family in sorted(registry):
        spec = registry[family]
        if spec.temporal == "static" and spec.states:
            out.append(_find(
                "static-zero-states",
                f"static family {family!r} declares StateDefs "
                f"{[s.name for s in spec.states]} — the static contract "
                "is zero recurrent state"))
    return out


def check_parity_helpers():
    """Simulate a stream through the exported parity helpers: step t must
    read the plane step t-1 wrote (anchored at plane 0), and the
    host-side final-plane select must land on the simulated final plane."""
    sf = stream_fused
    out = []
    plane = 0  # builds stack [state0, zeros]: plane 0 holds the t=0 read
    for t in range(8):
        r, w = sf.paged_read_plane(t), sf.paged_write_plane(t)
        if r != plane or w == r or w not in (0, 1):
            out.append(_find(
                "pingpong-parity",
                f"paged plane chain breaks at t={t}: read_plane={r} "
                f"write_plane={w} but the live state sits in plane "
                f"{plane}"))
            break
        plane = w
    plane = 0
    for t_steps in range(1, 9):
        plane = sf.paged_write_plane(t_steps - 1)
        if sf.paged_final_plane(t_steps) != plane:
            out.append(_find(
                "pingpong-parity",
                f"host-side final-plane select disagrees with the "
                f"simulated write parity at T={t_steps}: "
                f"paged_final_plane={sf.paged_final_plane(t_steps)}, "
                f"last write plane={plane}"))
            break
    return out


def _check_launch(point: Point, launch) -> list:
    """Alias coverage, plane counts, scratch-byte estimate, static
    emptiness — all static properties of the assembled _Launch."""
    out = []
    meta = launch.meta
    lbl = point.label()
    spec_states = {sm.in_idx: sm for sm in meta.states}

    if meta.temporal == "static":
        if meta.states or launch.evolve is not None or launch.aliases:
            out.append(_find(
                "static-zero-states",
                f"{lbl}: static launch carries states="
                f"{len(meta.states)}, evolve={launch.evolve is not None}, "
                f"aliases={dict(launch.aliases)}"))

    if meta.paged:
        for sm in meta.states:
            if launch.aliases.get(sm.in_idx) != sm.out_idx:
                out.append(_find(
                    "hbm-alias-coverage",
                    f"{lbl}: paged state (kind={sm.kind}, input "
                    f"{sm.in_idx}) is not aliased onto output "
                    f"{sm.out_idx} — the HBM store would not evolve "
                    "in place"))
        for idx, spec in enumerate(launch.in_specs):
            is_any = getattr(spec, "memory_space", None) is stream_fused.pltpu.ANY
            if is_any and idx not in spec_states and idx not in launch.aliases:
                out.append(_find(
                    "hbm-alias-coverage",
                    f"{lbl}: ANY-memory-space input {idx} is neither a "
                    "declared state nor aliased to an output"))
        # plane-count layout of the HBM pair must match the state kind
        for sm in meta.states:
            shape = launch.out_shape[sm.out_idx].shape
            want = {"pingpong": 2, "row": 1}.get(sm.kind)
            if want is not None and shape[1] != want:
                out.append(_find(
                    "pingpong-parity",
                    f"{lbl}: {sm.kind} state output carries {shape[1]} "
                    f"plane(s), expected {want} (shape {shape})"))
    elif launch.aliases:
        out.append(_find(
            "hbm-alias-coverage",
            f"{lbl}: vmem launch declares aliases {dict(launch.aliases)} "
            "— in-place aliasing is a paged-residency contract"))

    dims = _launch_dims(point.family, launch)
    if dims is not None:
        est = stream_fused.stream_vmem_bytes(
            point.family, td=meta.td, residency=point.residency,
            depth=meta.depth, **dims)
        got = stream_fused.launch_scratch_bytes(launch)
        if est != got:
            out.append(_find(
                "vmem-bytes-drift",
                f"{lbl}: stream_vmem_bytes estimates {est} B but the "
                f"assembled launch allocates {got} B of VMEM scratch — "
                "plan()'s budget check is lying"))
    return out


def _launch_dims(family: str, launch):
    """Recover the stream_vmem_bytes inputs from the assembled launch
    (grid + shapes), not from the fixture — so the check also covers the
    ops-level padding between fixture and launch."""
    meta = launch.meta
    out0 = launch.out_shape[0].shape          # (B, T, n_pad, d_pad)
    dims = dict(g_rows=meta.g_rows, n_pad=out0[2], d_pad=out0[3],
                n_layers=launch.grid[2], din=0, dmid=0)
    ins = launch.inputs
    if family == "gcrn":
        dims["din"] = ins[4].shape[3]
    elif family in ("stacked", "tgn"):
        dims["din"] = ins[3].shape[3]
        if family == "stacked":
            dims["dmid"] = ins[7].shape[1]
    elif family not in ("evolve", "static_gcn"):
        return None  # unknown family: no estimator formula to check
    return dims


def _check_dma(point: Point, launch, events) -> list:
    """Replay the recorded start/wait stream against the protocol."""
    out = []
    meta = launch.meta
    lbl = point.label()
    if not meta.paged:
        if events:
            out.append(_find(
                "dma-missing-site",
                f"{lbl}: vmem launch issued {len(events)} DMA event(s) — "
                "resident layouts must not touch the DMA engine"))
        return out

    n_win = launch.grid[3]
    outstanding = {}
    ring_started, ring_waited = {}, {}
    for ev in events:
        key = (ev["op"], ev["state"], ev.get("slot"))
        if ev["event"] == "start":
            if key in outstanding:
                rule = ("dma-ring-order" if ev["op"] == "ring"
                        else "dma-unpaired-start")
                out.append(_find(
                    rule,
                    f"{lbl}: {ev['op']} DMA re-started on state "
                    f"{ev['state']} slot {ev.get('slot')} (window "
                    f"{ev.get('window')}) while the previous copy is "
                    "still outstanding"))
            outstanding[key] = ev
            if ev["op"] == "ring":
                ring_started.setdefault(ev["state"], []).append(ev["window"])
        else:
            if key not in outstanding:
                out.append(_find(
                    "dma-unpaired-start",
                    f"{lbl}: {ev['op']} DMA wait on state {ev['state']} "
                    f"slot {ev.get('slot')} with no outstanding start"))
            outstanding.pop(key, None)
            if ev["op"] == "ring":
                ring_waited.setdefault(ev["state"], []).append(ev["window"])
    for key, ev in outstanding.items():
        out.append(_find(
            "dma-unpaired-start",
            f"{lbl}: {ev['op']} DMA started on state {ev['state']} slot "
            f"{ev.get('slot')} but never waited before trace end"))

    by_state_op = {}
    for ev in events:
        by_state_op.setdefault((ev["state"], ev["op"]), []).append(ev)
    for i, sm in enumerate(meta.states):
        for op in ("stage_in", "write_back"):
            if not by_state_op.get((i, op)):
                out.append(_find(
                    "dma-missing-site",
                    f"{lbl}: paged state {i} (kind={sm.kind}) never "
                    f"issued a {op} DMA — the HBM store and VMEM "
                    "staging window would desynchronize"))
        if sm.ring_idx >= 0:
            started = ring_started.get(i, [])
            waited = ring_waited.get(i, [])
            if sorted(started) != list(range(n_win)):
                out.append(_find(
                    "dma-ring-order",
                    f"{lbl}: ring sweep of state {i} started windows "
                    f"{started}, expected every window 0..{n_win - 1} "
                    "exactly once"))
            if waited != sorted(waited) or sorted(waited) != list(range(n_win)):
                out.append(_find(
                    "dma-ring-order",
                    f"{lbl}: ring sweep of state {i} waited windows "
                    f"{waited} — windows must complete in order "
                    f"0..{n_win - 1}"))
    return out


def run_contracts(registry=None, points=None,
                  rules: Optional[frozenset] = None) -> list:
    """The full contract pass: registry declarations, parity helpers,
    then every sweep point through the recording shim."""
    registry = stream_fused.REGISTRY if registry is None else registry
    findings = list(check_registry_declarations(registry))
    findings += check_parity_helpers()
    pts = registry_points(registry) if points is None else points
    for point in pts:
        try:
            rec = trace_point(point, registry)
        except Exception as exc:  # any trace failure becomes a finding
            findings.append(_find(
                "launch-assembly-error",
                f"{point.label()}: launch assembly/trace failed: "
                f"{type(exc).__name__}: {exc}"))
            continue
        if not rec.launches:
            findings.append(_find(
                "launch-assembly-error",
                f"{point.label()}: no launch captured — dispatch "
                "bypassed stream_call (force-ref gate left on?)"))
            continue
        for family, launch in rec.launches:
            findings.extend(_check_launch(point, launch))
            findings.extend(_check_dma(point, launch, rec.events))
    if rules is not None:
        findings = [f for f in findings if f.rule in rules]
    return findings
