"""CLI entry point: ``python -m repro.analysis``.

Exit status: 0 = clean, 1 = findings, 2 = bad invocation. Under GitHub
Actions (``GITHUB_ACTIONS=true``) annotations are emitted alongside the
chosen format so findings land on the PR diff.
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

from repro.analysis import ALL_RULES, run_all, select_rules
from repro.analysis.core import GROUPS


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checker + AST linter for the "
                    "DGNN-Booster stream engine, plan surface, and serve "
                    "layer")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text", help="report format (default: text)")
    ap.add_argument("--rules", default=None, metavar="SPEC",
                    help="comma-separated rule ids and/or group names "
                         f"({', '.join(GROUPS)}); default: everything")
    ap.add_argument("--root", default=None, metavar="DIR",
                    help="repo root to analyze (default: autodetected)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(ALL_RULES):
            r = ALL_RULES[rid]
            print(f"{rid:28s} {r.group:9s} {r.severity:7s} {r.rationale}")
        return 0

    rules = select_rules(ALL_RULES, args.rules)
    root = Path(args.root) if args.root else None
    report = run_all(root=root, rules=rules)

    if args.format == "json":
        print(report.to_json())
    elif args.format == "github":
        print(report.to_github() or "analysis clean")
    else:
        print(report.to_text())
    if (os.environ.get("GITHUB_ACTIONS") == "true"
            and args.format != "github" and report.findings):
        print(report.to_github(), file=sys.stderr)
    return 1 if report.findings else 0


if __name__ == "__main__":
    sys.exit(main())
