"""Drift passes — cross-artifact consistency the test suite cannot see.

The repo's contract surface is spread over four artifacts that evolve
independently: the ``StreamPlan`` dataclass, its field table in
docs/api.md, the CI family matrix, and the tests/harness.py case
builders. Each pass re-derives one pairing and reports divergence:

  * ``plan-doc-drift``      StreamPlan fields <-> the docs/api.md
                            "Plan fields" table (both directions);
  * ``family-levels-drift`` api.FAMILY_LEVELS keys <-> the kernel
                            registry;
  * ``ci-matrix-drift``     the ci.yml ``family: [...]`` matrix <-> the
                            registry;
  * ``harness-case-drift``  the ``family == "..."`` branches of
                            tests/harness.py stream_kernel_case (and its
                            fixture twin repro/analysis/cases.py) <-> the
                            registry.

Every artifact path is a parameter so tests can point a pass at a
drifted copy without touching the tree.
"""
from __future__ import annotations

import ast
import re
from dataclasses import fields as dc_fields
from pathlib import Path
from typing import Optional

from repro import api
from repro.analysis.core import Finding, Rule
from repro.kernels import stream_fused

RULES = {r.id: r for r in (
    Rule("plan-doc-drift", "drift", "error",
         "docs/api.md's plan-field table is the user-facing contract; a "
         "StreamPlan field missing from it (or a documented field that no "
         "longer exists) means the docs lie about the API."),
    Rule("family-levels-drift", "drift", "error",
         "api.FAMILY_LEVELS must key exactly the kernel registry — a "
         "registered family without a level ladder cannot be planned, and "
         "a ladder without a family is dead dispatch surface."),
    Rule("ci-matrix-drift", "drift", "error",
         "The CI family matrix must enumerate the whole registry, or a "
         "family ships without per-family CI coverage."),
    Rule("harness-case-drift", "drift", "error",
         "tests/harness.py stream_kernel_case and the analyzer's own "
         "fixture module must both build cases for every registered "
         "family, or sweep tests silently skip it."),
)}

#: the api.md table section the plan-field pass parses.
PLAN_TABLE_HEADING = "## Plan fields"

_BACKTICK = re.compile(r"`([A-Za-z_][A-Za-z0-9_]*)`")
_CI_MATRIX = re.compile(r"^\s*family:\s*\[([^\]]*)\]", re.M)


def _find(rule: str, path: str, line: int, msg: str) -> Finding:
    r = RULES[rule]
    return Finding(rule, r.group, r.severity, path, line, msg)


def _read(root: Path, rel: str) -> Optional[str]:
    try:
        return (root / rel).read_text()
    except OSError:
        return None


def parse_plan_table(text: str):
    """-> {field_name: 1-indexed line} from the plan-field table: every
    backticked identifier in the FIRST cell of each body row under the
    heading (one row may document several fields, e.g. n_pad/e_pad/k_max)."""
    fields, in_table = {}, False
    for i, line in enumerate(text.splitlines(), start=1):
        if line.startswith(PLAN_TABLE_HEADING):
            in_table = True
            continue
        if not in_table:
            continue
        if line.startswith("#"):        # next section: table is over
            break
        s = line.strip()
        if not s.startswith("|"):
            continue
        first = s.strip("|").split("|", 1)[0]
        if set(first.strip()) <= {"-", ":", " "} or "field" == first.strip():
            continue                    # separator / header row
        for name in _BACKTICK.findall(first):
            fields.setdefault(name, i)
    return fields


def check_plan_docs(root: Path, api_md: str = "docs/api.md",
                    plan_cls=api.StreamPlan) -> list:
    text = _read(root, api_md)
    if text is None:
        return [_find("plan-doc-drift", api_md, 0,
                      f"{api_md} not found — the plan-field table is the "
                      "documented API contract")]
    doc = parse_plan_table(text)
    live = [f.name for f in dc_fields(plan_cls)]
    heading_line = next(
        (i for i, line in enumerate(text.splitlines(), start=1)
         if line.startswith(PLAN_TABLE_HEADING)), 0)
    out = []
    for name in live:
        if name not in doc:
            out.append(_find(
                "plan-doc-drift", api_md, heading_line,
                f"StreamPlan field `{name}` has no row in the "
                f"{PLAN_TABLE_HEADING!r} table"))
    for name, line in sorted(doc.items(), key=lambda kv: kv[1]):
        if name not in live:
            out.append(_find(
                "plan-doc-drift", api_md, line,
                f"documented plan field `{name}` does not exist on "
                "StreamPlan — stale row"))
    return out


def _set_drift(rule, path, line, label, got, want):
    out = []
    missing, extra = want - got, got - want
    if missing:
        out.append(_find(rule, path, line,
                         f"{label} is missing registered families: "
                         f"{sorted(missing)}"))
    if extra:
        out.append(_find(rule, path, line,
                         f"{label} names unregistered families: "
                         f"{sorted(extra)}"))
    return out


def check_family_levels(registry=None, levels=None) -> list:
    registry = stream_fused.REGISTRY if registry is None else registry
    levels = api.FAMILY_LEVELS if levels is None else levels
    return _set_drift("family-levels-drift", "src/repro/api.py", 0,
                      "api.FAMILY_LEVELS", set(levels), set(registry))


def check_ci_matrix(root: Path, ci_yml: str = ".github/workflows/ci.yml",
                    registry=None) -> list:
    registry = stream_fused.REGISTRY if registry is None else registry
    text = _read(root, ci_yml)
    if text is None:
        return [_find("ci-matrix-drift", ci_yml, 0,
                      f"{ci_yml} not found — no per-family CI coverage")]
    m = _CI_MATRIX.search(text)
    if not m:
        return [_find("ci-matrix-drift", ci_yml, 0,
                      "no `family: [...]` matrix found in the workflow")]
    line = text[:m.start()].count("\n") + 1
    got = {t.strip() for t in m.group(1).split(",") if t.strip()}
    return _set_drift("ci-matrix-drift", ci_yml, line,
                      "the CI family matrix", got, set(registry))


def _case_families(tree: ast.AST, fn_name: str):
    """String constants compared against a name ``family`` inside the
    given builder function (``if family == "gcrn": ...`` branches)."""
    fams, line = set(), 0
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == fn_name:
            line = node.lineno
            for cmp_ in ast.walk(node):
                if (isinstance(cmp_, ast.Compare)
                        and isinstance(cmp_.left, ast.Name)
                        and cmp_.left.id == "family"
                        and len(cmp_.comparators) == 1
                        and isinstance(cmp_.comparators[0], ast.Constant)
                        and isinstance(cmp_.comparators[0].value, str)):
                    fams.add(cmp_.comparators[0].value)
            break
    return fams, line


def check_harness_cases(root: Path, harness_py: str = "tests/harness.py",
                        cases_py: str = "src/repro/analysis/cases.py",
                        registry=None) -> list:
    registry = stream_fused.REGISTRY if registry is None else registry
    out = []
    for rel, fn in ((harness_py, "stream_kernel_case"),
                    (cases_py, "stream_args")):
        text = _read(root, rel)
        if text is None:
            out.append(_find("harness-case-drift", rel, 0,
                             f"{rel} not found — no case builders to check"))
            continue
        try:
            tree = ast.parse(text, filename=rel)
        except SyntaxError as e:
            out.append(_find("harness-case-drift", rel, e.lineno or 0,
                             f"unparseable: {e.msg}"))
            continue
        fams, line = _case_families(tree, fn)
        missing = set(registry) - fams
        if missing:
            out.append(_find(
                "harness-case-drift", rel, line,
                f"{fn}() in {rel} has no branch for registered "
                f"families {sorted(missing)} — sweeps silently skip them"))
    return out


def run_drift(root: Path, registry=None,
              rules: Optional[frozenset] = None, **paths) -> list:
    """All four drift passes. ``paths`` forwards per-artifact overrides
    (api_md=, ci_yml=, harness_py=, cases_py=) for tests."""
    findings = []
    findings += check_plan_docs(root, **{k: v for k, v in paths.items()
                                         if k in ("api_md",)})
    findings += check_family_levels(registry)
    findings += check_ci_matrix(root, registry=registry,
                                **{k: v for k, v in paths.items()
                                   if k in ("ci_yml",)})
    findings += check_harness_cases(root, registry=registry,
                                    **{k: v for k, v in paths.items()
                                       if k in ("harness_py", "cases_py")})
    if rules is not None:
        findings = [f for f in findings if f.rule in rules]
    return findings
