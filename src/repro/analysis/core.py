"""Finding/rule data model and report plumbing for ``repro.analysis``.

The analyzer mirrors the paper's framework-level legality checking
(HLS dataflow legality, BRAM budgets, stream handshakes) in software:
three pass GROUPS, each a set of RULES —

  "contracts"  kernel-contract passes: every (family, residency,
               buffer_depth, td) registry point is traced through launch
               assembly with a recording shim (no device execution) and
               checked against the paged DMA protocol, alias coverage,
               the scratch-byte estimator, and the temporal contract
               (analysis/contracts.py);
  "lint"       repo AST lint over src/examples/benchmarks — structural
               invariants the CI greps used to approximate, plus general
               hygiene rules (analysis/lint.py);
  "drift"      cross-artifact drift: the StreamPlan dataclass vs the
               docs/api.md field table, and the family registry vs the
               CI matrix and the tests/harness.py case builders
               (analysis/drift.py).

Findings are plain data (rule id, severity, path, line, message) so the
CLI can render text, stable JSON, or GitHub annotations from the same
report. Suppression: a ``# booster: ignore[rule-id]`` comment on the
finding's line (lint rules only — contract/drift findings have no
meaningful source line to waive).
"""
from __future__ import annotations

import json
import re
import sys
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Iterable, Optional

GROUPS = ("contracts", "lint", "drift")

#: suppression comment: ``# booster: ignore[rule-id]`` (comma-separated
#: ids allowed). Anchored to the finding's own line.
_SUPPRESS_RE = re.compile(r"#\s*booster:\s*ignore\[([a-z0-9_\-, ]+)\]")


@dataclass(frozen=True)
class Rule:
    """One analyzer rule: identity + severity + the rationale the docs
    catalog renders."""

    id: str
    group: str          # one of GROUPS
    severity: str       # "error" | "warning"
    rationale: str


@dataclass(frozen=True)
class Finding:
    """One violation. ``path`` is repo-relative ("" for registry-level
    contract findings with no source anchor); ``line`` is 1-indexed
    (0 when no line applies)."""

    rule: str
    group: str
    severity: str
    path: str
    line: int
    message: str

    def sort_key(self):
        return (GROUPS.index(self.group), self.rule, self.path, self.line,
                self.message)


@dataclass
class Report:
    findings: list = field(default_factory=list)
    suppressed: int = 0
    rules_run: tuple = ()

    def sorted(self) -> list:
        return sorted(self.findings, key=Finding.sort_key)

    def to_json(self) -> str:
        """Stable machine-readable form: sorted findings, no timestamps,
        no absolute paths — byte-identical across runs on the same tree."""
        return json.dumps(
            {"version": 1,
             "rules_run": sorted(self.rules_run),
             "counts": {"findings": len(self.findings),
                        "suppressed": self.suppressed},
             "findings": [asdict(f) for f in self.sorted()]},
            indent=2, sort_keys=True)

    def to_text(self) -> str:
        lines = []
        for f in self.sorted():
            anchor = f"{f.path}:{f.line}: " if f.path else ""
            lines.append(f"{anchor}{f.severity}[{f.rule}] {f.message}")
        lines.append(f"{len(self.findings)} finding(s), "
                     f"{self.suppressed} suppressed, "
                     f"{len(self.rules_run)} rule(s) run")
        return "\n".join(lines)

    def to_github(self) -> str:
        """GitHub workflow-command annotations (``::error file=..``)."""
        out = []
        for f in self.sorted():
            kind = "error" if f.severity == "error" else "warning"
            loc = f"file={f.path},line={f.line}" if f.path else "file=."
            out.append(f"::{kind} {loc}::[{f.rule}] {f.message}")
        return "\n".join(out)


def suppressed_ids(source_line: str) -> frozenset:
    """Rule ids waived by a ``# booster: ignore[...]`` comment on the
    given source line (empty if none)."""
    m = _SUPPRESS_RE.search(source_line)
    if not m:
        return frozenset()
    return frozenset(x.strip() for x in m.group(1).split(",") if x.strip())


def apply_suppressions(findings: Iterable[Finding], root: Path,
                       report: Report) -> list:
    """Drop findings whose source line carries a matching suppression
    comment; count the drops in the report."""
    kept, cache = [], {}
    for f in findings:
        ids = frozenset()
        if f.path and f.line > 0:
            p = root / f.path
            if p not in cache:
                try:
                    cache[p] = p.read_text().splitlines()
                except OSError:
                    cache[p] = []
            lines = cache[p]
            if 0 < f.line <= len(lines):
                ids = suppressed_ids(lines[f.line - 1])
        if f.rule in ids:
            report.suppressed += 1
        else:
            kept.append(f)
    return kept


def repo_root() -> Path:
    """The repo checkout this installed/`PYTHONPATH=src` package lives in
    (…/src/repro/analysis/core.py -> …)."""
    return Path(__file__).resolve().parents[3]


def select_rules(all_rules: dict, spec: Optional[str]) -> frozenset:
    """Resolve a ``--rules`` spec (comma-separated rule ids and/or group
    names; None = everything) to a set of rule ids."""
    if not spec:
        return frozenset(all_rules)
    chosen = set()
    for tok in (t.strip() for t in spec.split(",")):
        if not tok:
            continue
        if tok in GROUPS:
            chosen |= {rid for rid, r in all_rules.items()
                       if r.group == tok}
        elif tok in all_rules:
            chosen.add(tok)
        else:
            print(f"unknown rule or group {tok!r}; known groups: "
                  f"{', '.join(GROUPS)}; known rules: "
                  f"{', '.join(sorted(all_rules))}", file=sys.stderr)
            raise SystemExit(2)  # bad invocation, distinct from findings
    return frozenset(chosen)
