"""repro.analysis — static contract checker + AST linter for the stream
engine, plan surface, and serve layer.

Run it as ``python -m repro.analysis [--format text|json|github]
[--rules contracts,lint,drift|rule-id,...]``; exits non-zero when any
finding survives suppression. See docs/static_analysis.md for the rule
catalog and the contract-pass <-> runtime-test division of labor.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

from repro.analysis import contracts, drift, lint
from repro.analysis.core import (Finding, Report, Rule, apply_suppressions,
                                 repo_root, select_rules)

__all__ = ["ALL_RULES", "Finding", "Report", "Rule", "run_all", "repo_root",
           "select_rules"]

#: every rule the analyzer knows, across the three pass groups.
ALL_RULES = {**contracts.RULES, **lint.RULES, **drift.RULES}


def run_all(root: Optional[Path] = None,
            rules: Optional[frozenset] = None) -> Report:
    """Run every selected pass group over the repo at ``root`` and return
    the suppression-filtered Report."""
    root = repo_root() if root is None else Path(root)
    rules = frozenset(ALL_RULES) if rules is None else rules
    report = Report(rules_run=tuple(sorted(rules)))
    findings = []
    if rules & set(lint.RULES):
        findings += lint.run_lint(root, rules=rules)
    if rules & set(contracts.RULES):
        findings += contracts.run_contracts(rules=rules)
    if rules & set(drift.RULES):
        findings += drift.run_drift(root, rules=rules)
    report.findings = apply_suppressions(findings, root, report)
    return report
