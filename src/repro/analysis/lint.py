"""Repo AST lint (stdlib ``ast``, no new deps) — the "lint" pass group.

Subsumes the four grep-based CI guards (family-named stream kernels
outside the registry module, the single-kernel-body count, raw
``mode="vN"`` dispatch, direct ``stream_steps`` calls) as real syntax
rules, and adds the hygiene rules greps could not express: bare/overbroad
``except`` outside the allowlisted supervision sites, mutable default
arguments, and ``jnp`` ops inside Pallas kernel bodies that have no TPU
lowering (or a strictly better ``lax``/indexing form).

Rule anatomy: every rule is a function ``(relpath, tree, lines) ->
[Finding]`` registered in ``RULES``/``CHECKS`` with a severity and a
rationale (rendered by docs/static_analysis.md). Suppress a single
finding with ``# booster: ignore[rule-id]`` on its line — the shipped
tree carries zero suppressions, and tests/test_analysis.py pins that
every rule fires on an injected violation.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Optional

from repro.analysis.core import Finding, Rule

#: directories scanned by default, relative to the repo root. tests/ is
#: deliberately out of scope: the deprecated mode-string shims are pinned
#: there on purpose.
LINT_ROOTS = ("src", "examples", "benchmarks")

#: supervision sites where catching ``Exception``/``BaseException`` is
#: the point (tenant fault isolation, suite harness catch-and-report) —
#: the broad-except rule skips these files entirely. Everything else
#: must catch the specific expected errors.
BROAD_EXCEPT_ALLOWLIST = frozenset({
    "src/repro/serve/engine.py",   # tenant supervision / producer shutdown
    "benchmarks/run.py",           # suite harness: record failure, exit 1
    "src/repro/analysis/contracts.py",  # sweep: any trace failure -> finding
})

#: the registry module that owns the one stream-engine kernel body.
STREAM_FUSED = "src/repro/kernels/stream_fused.py"

#: family-named stream def pattern (the old CI grep, as a name match) and
#: its oracle exemption (kernels/ref.py ``*_stream*_ref[s]`` functions).
_FAMILY_STREAM = re.compile(
    r"^_?[a-z_]*(gcrn|stacked|evolve|dgnn|tgn|static)[a-z_]*_stream[a-z_]*$")
_REF_SUFFIX = re.compile(r"_refs?$")

_KERNEL_DEF = re.compile(r"^[a-z_]*_kernel$")

#: ``jnp`` ops with no Pallas TPU lowering or a strictly better in-kernel
#: form (``lax`` scans/concats, explicit static slices): shape
#: restructuring and data-dependent ops. Element-wise math, ``jnp.dot``,
#: ``jnp.take`` and friends lower fine and stay allowed.
JNP_KERNEL_DENYLIST = frozenset({
    "einsum", "sort", "argsort", "unique", "nonzero", "cumsum", "cumprod",
    "pad", "concatenate", "stack", "tile", "repeat", "roll", "split",
    "moveaxis", "append", "delete", "insert", "resize",
})

RULES = {r.id: r for r in (
    Rule("stream-def-outside-registry", "lint", "error",
         "Family code lives in stream_fused.REGISTRY as declarative cell "
         "specs; a family-named stream kernel/launcher anywhere else in "
         "src/ resurrects the pre-registry copy-paste (XLA oracles named "
         "*_stream*_ref are exempt)."),
    Rule("single-kernel-body", "lint", "error",
         "kernels/stream_fused.py owns exactly ONE Pallas kernel body "
         "(_stream_engine_kernel): the generic-framework claim is that "
         "families differ only in cell specs, never in kernel bodies."),
    Rule("mode-string-dispatch", "lint", "error",
         "Surface code (examples/, benchmarks/, src/repro/serve/) goes "
         "through typed StreamPlans; raw mode=\"vN\" dataflow dispatch is "
         "confined to the deprecated shims and the plan executors."),
    Rule("direct-stream-steps", "lint", "error",
         "Direct ops.stream_steps[_batched] calls bypass plan validation; "
         "surface code uses api.run_arrays / BoosterSession instead."),
    Rule("broad-except", "lint", "error",
         "Bare ``except:`` or ``except (Base)Exception`` hides real bugs "
         "(including the paged-DMA contract errors stream_call raises). "
         "Catch the specific expected errors; only the allowlisted "
         "supervision sites may catch everything."),
    Rule("mutable-default-arg", "lint", "error",
         "A mutable default ([] / {} / set()) is shared across calls — "
         "state leaks between launches. Use None (or a tuple) and "
         "construct inside the function."),
    Rule("jnp-in-kernel-body", "lint", "warning",
         "Inside a Pallas kernel body, shape-restructuring / "
         "data-dependent jnp ops (einsum, concatenate, sort, cumsum, …) "
         "either fail to lower on TPU or hide a relayout; use lax "
         "equivalents or static slices on the host side."),
    Rule("syntax-error", "lint", "error",
         "A file in the lint scope failed to parse — nothing else can be "
         "checked until it does."),
)}


def _iter_files(root: Path, files=None):
    """Yield (relpath, source) for the lint scope. ``files`` overrides
    discovery (tests inject single-snippet trees)."""
    if files is not None:
        paths = [Path(f) for f in files]
    else:
        paths = []
        for top in LINT_ROOTS:
            base = root / top
            if base.is_dir():
                paths.extend(sorted(base.rglob("*.py")))
    for p in paths:
        p = p if p.is_absolute() else root / p
        if "__pycache__" in p.parts:
            continue
        try:
            yield p.relative_to(root).as_posix(), p.read_text()
        except (OSError, ValueError):
            continue


def _is_kernel_body(fn: ast.FunctionDef) -> bool:
    """Heuristic for Pallas kernel bodies / engine hooks: a parameter
    named ``eng``/``refs`` or ending in ``_ref(s)``, or a ``*_kernel`` /
    ``*_cell`` function name."""
    names = [a.arg for a in (fn.args.posonlyargs + fn.args.args
                             + fn.args.kwonlyargs)]
    if fn.args.vararg:
        names.append(fn.args.vararg.arg)
    if any(n in ("eng", "refs") or _REF_SUFFIX.search(n) for n in names):
        return True
    return fn.name.endswith("_kernel") or fn.name.endswith("_cell")


def _find(rule: str, path: str, node, msg: str) -> Finding:
    r = RULES[rule]
    return Finding(rule, r.group, r.severity, path,
                   getattr(node, "lineno", 0), msg)


# ------------------------------------------------------------------ rules

def _chk_stream_def(path, tree, lines):
    if not path.startswith("src/") or path == STREAM_FUSED:
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if _FAMILY_STREAM.match(node.name) and not _REF_SUFFIX.search(node.name):
            out.append(_find(
                "stream-def-outside-registry", path, node,
                f"family-named stream def `{node.name}` outside "
                f"{STREAM_FUSED} — register a cell spec instead"))
    return out


def _chk_single_kernel(path, tree, lines):
    if path != STREAM_FUSED:
        return []
    kernels = [n for n in tree.body
               if isinstance(n, ast.FunctionDef) and _KERNEL_DEF.match(n.name)]
    if len(kernels) == 1:
        return []
    anchor = kernels[1] if len(kernels) > 1 else tree
    names = [k.name for k in kernels] or ["<none>"]
    return [_find("single-kernel-body", path, anchor,
                  f"expected exactly 1 stream-engine kernel body, found "
                  f"{len(kernels)}: {', '.join(names)}")]


_SERVE_SCOPE = ("examples/", "benchmarks/", "src/repro/serve/")


def _chk_mode_string(path, tree, lines):
    if not path.startswith(_SERVE_SCOPE):
        return []

    def _is_vn(node):
        return (isinstance(node, ast.Constant) and isinstance(node.value, str)
                and re.fullmatch(r"v[0-9]+", node.value))

    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "mode" and _is_vn(kw.value):
                    out.append(_find(
                        "mode-string-dispatch", path, kw.value,
                        f'raw mode="{kw.value.value}" dispatch — build a '
                        "StreamPlan (api.plan) instead"))
        elif isinstance(node, ast.Assign):
            if (any(isinstance(t, ast.Name) and t.id == "mode"
                    for t in node.targets) and _is_vn(node.value)):
                out.append(_find(
                    "mode-string-dispatch", path, node,
                    f'mode = "{node.value.value}" assignment — build a '
                    "StreamPlan (api.plan) instead"))
    return out


def _chk_stream_steps(path, tree, lines):
    if not path.startswith(_SERVE_SCOPE):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = (fn.id if isinstance(fn, ast.Name)
                else fn.attr if isinstance(fn, ast.Attribute) else None)
        if name in ("stream_steps", "stream_steps_batched"):
            out.append(_find(
                "direct-stream-steps", path, node,
                f"direct {name}() call outside the plan executors — use "
                "api.run_arrays(plan(...), *arrays)"))
    return out


def _chk_broad_except(path, tree, lines):
    if path in BROAD_EXCEPT_ALLOWLIST:
        return []

    def _broad(expr) -> Optional[str]:
        if expr is None:
            return "bare except:"
        if isinstance(expr, ast.Name) and expr.id in ("Exception",
                                                      "BaseException"):
            return f"except {expr.id}"
        if isinstance(expr, ast.Tuple):
            for e in expr.elts:
                b = _broad(e)
                if b and b != "bare except:":
                    return b
        return None

    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler):
            b = _broad(node.type)
            if b:
                out.append(_find(
                    "broad-except", path, node,
                    f"{b} outside the supervision allowlist — catch the "
                    "specific expected errors (and log what was caught)"))
    return out


def _chk_mutable_default(path, tree, lines):
    def _mutable(node) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set,
                             ast.ListComp, ast.DictComp, ast.SetComp)):
            return True
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in ("list", "dict", "set"))

    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            continue
        name = getattr(node, "name", "<lambda>")
        for d in list(node.args.defaults) + [d for d in node.args.kw_defaults
                                             if d is not None]:
            if _mutable(d):
                out.append(_find(
                    "mutable-default-arg", path, d,
                    f"mutable default argument in `{name}` — shared "
                    "across calls; default to None/() instead"))
    return out


def _chk_jnp_in_kernel(path, tree, lines):
    if not path.startswith("src/repro/kernels/"):
        return []
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef) or not _is_kernel_body(fn):
            continue
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "jnp"
                    and node.func.attr in JNP_KERNEL_DENYLIST):
                out.append(_find(
                    "jnp-in-kernel-body", path, node,
                    f"jnp.{node.func.attr} inside kernel body "
                    f"`{fn.name}` — no TPU Pallas lowering / hides a "
                    "relayout; use the lax equivalent or hoist host-side"))
    return out


CHECKS = (_chk_stream_def, _chk_single_kernel, _chk_mode_string,
          _chk_stream_steps, _chk_broad_except, _chk_mutable_default,
          _chk_jnp_in_kernel)


def run_lint(root: Path, files=None, rules: Optional[frozenset] = None):
    """Run the lint rules over the repo (or an injected file list).
    Returns raw findings — suppression filtering happens in core."""
    findings = []
    for relpath, source in _iter_files(root, files):
        try:
            tree = ast.parse(source, filename=relpath)
        except SyntaxError as e:
            f = Finding("syntax-error", "lint", "error", relpath,
                        e.lineno or 0, f"unparseable file: {e.msg}")
            if rules is None or f.rule in rules:
                findings.append(f)
            continue
        lines = source.splitlines()
        for chk in CHECKS:
            found = chk(relpath, tree, lines)
            if rules is not None:
                found = [f for f in found if f.rule in rules]
            findings.extend(found)
    return findings
