"""Minimal per-family launch fixtures for the kernel-contract passes.

The contract sweep never executes a kernel — it only needs arguments
that ASSEMBLE: shapes obeying the family contracts (ELL layout, gate
widths, the nonzero-coef-references-masked-row invariant) at the
smallest sizes that still exercise D-blocking (h == 2*td) and multiple
node tiles. Kept independent of tests/harness.py on purpose: the
analyzer is a src/ subsystem and must not import the test tree (the
drift passes cross-check that harness builders and this module cover
the same registry).

Every array is deterministic (seeded numpy) so contract findings are
reproducible run-to-run.
"""
from __future__ import annotations

import numpy as np

# small but structured: 2 node tiles (n/tn), D = d_pad/td = 2 blocks,
# odd T to exercise both ping-pong parities.
N, K, T, TN, TD, H, DIN, DMID = 32, 4, 3, 16, 8, 16, 8, 12


def _ell_stream(rng, T=T, n=N, k=K, e=4 * N, din=DIN, n_global=None):
    """(idx, coef, eidx, x, renumber, mask) padded ELL stream with ragged
    per-step node counts and valid renumber rows — the same contract
    tests/harness.random_ell_stream builds, at fixture size."""
    G = n_global if n_global is not None else 2 * n + 9
    arrs = {key: [] for key in ("idx", "coef", "eidx", "x", "ren", "mask")}
    for _ in range(T):
        nr = int(rng.integers(max(n // 3, 1), n + 1))
        idx = rng.integers(0, nr, (n, k)).astype(np.int32)
        coef = (rng.uniform(size=(n, k))
                * (rng.uniform(size=(n, k)) > 0.4)).astype(np.float32)
        coef[nr:] = 0.0
        x = rng.normal(size=(n, din)).astype(np.float32)
        x[nr:] = 0.0
        ren = np.full(n, -1, np.int32)
        ren[:nr] = rng.permutation(G)[:nr]
        mask = np.zeros(n, np.float32)
        mask[:nr] = 1.0
        eidx = rng.integers(0, e, (n, k)).astype(np.int32)
        for key, v in zip(("idx", "coef", "eidx", "x", "ren", "mask"),
                          (idx, coef, eidx, x, ren, mask)):
            arrs[key].append(v)
    return tuple(np.stack(arrs[key]) for key in
                 ("idx", "coef", "eidx", "x", "ren", "mask"))


def _rand(rng, shape, scale):
    return (rng.normal(size=shape) * scale).astype(np.float32)


def stream_args(family: str, seed: int = 0):
    """ops.stream_steps-ready solo argument list for one registry family
    (raises KeyError for families without a fixture — the contract pass
    reports that as a finding rather than crashing the sweep)."""
    rng = np.random.default_rng(seed)
    G = 2 * N + 9
    if family == "gcrn":
        S = _ell_stream(rng)
        return (*S, _rand(rng, (G, H), 0.5), _rand(rng, (G, H), 0.5),
                _rand(rng, (DIN, 4 * H), 0.2), _rand(rng, (H, 4 * H), 0.2),
                _rand(rng, (4 * H,), 0.1))
    if family == "stacked":
        S = _ell_stream(rng)
        return (*S, _rand(rng, (G, H), 0.5), _rand(rng, (DIN, DMID), 0.2),
                _rand(rng, (DMID,), 0.1), _rand(rng, (DMID, 3 * H), 0.2),
                _rand(rng, (H, 3 * H), 0.2), _rand(rng, (3 * H,), 0.1))
    if family == "evolve":
        dims = [(DIN, H), (H, TD)]
        idx, coef, _eidx, x, _ren, mask = _ell_stream(rng)
        live = np.ones(T, np.int32)
        ws = [_rand(rng, d, 0.3) for d in dims]
        bg = [_rand(rng, (d[1],), 0.1) for d in dims]
        gwx = [_rand(rng, (d[0], 3 * d[0]), 0.2) for d in dims]
        gwh = [_rand(rng, (d[0], 3 * d[0]), 0.2) for d in dims]
        gb = [_rand(rng, (3 * d[0],), 0.1) for d in dims]
        return (idx, coef, x, mask, live, ws, bg, gwx, gwh, gb)
    if family == "tgn":
        idx, coef, _eidx, x, ren, mask = _ell_stream(rng)
        ts = rng.uniform(0.0, 8.0, idx.shape).astype(np.float32)
        return (idx, coef, ts, x, ren, mask,
                _rand(rng, (G, H), 0.5),
                np.abs(_rand(rng, (H,), 0.5)) + 0.05,
                _rand(rng, (DIN, H), 0.2), _rand(rng, (H, 3 * H), 0.2),
                _rand(rng, (H, 3 * H), 0.2), _rand(rng, (3 * H,), 0.1))
    if family == "static_gcn":
        dims = [(DIN, H), (H, TD)]
        idx, coef, _eidx, x, _ren, mask = _ell_stream(rng, T=1)
        ws = [_rand(rng, d, 0.3) for d in dims]
        bs = [_rand(rng, (d[1],), 0.1) for d in dims]
        return (idx, coef, x, mask, ws, bs, None)
    raise KeyError(
        f"no contract-pass fixture for stream family {family!r}: a cell "
        "spec was registered without analysis coverage — add a builder "
        "in repro/analysis/cases.py")
