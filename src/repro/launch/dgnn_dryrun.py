import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""DGNN production-scale dry-run: the paper's technique on the pod mesh.

A single BC-Alpha snapshot cannot fill a chip; the production axis is
BATCHED STREAMS (DESIGN §4): B independent dynamic graphs advance one
snapshot per step, streams sharded over (pod, data), feature dims over
model for wide variants. This lowers+compiles the batched V1/V2 serve
steps on the 16x16 and 2x16x16 meshes and emits the same roofline record
as the LM cells.

  python -m repro.launch.dgnn_dryrun [--model gcrn-m2] [--streams 4096]
"""
import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.dgnn import DGNN_CONFIGS
from repro.distributed.api import DEFAULT_RULES, sharding_ctx, named_sharding
from repro.graph.padding import PaddedSnapshot
from repro.launch.dryrun import OUT_DIR, _measure
from repro.launch.mesh import make_production_mesh
from repro.roofline import Roofline


def snapshot_specs(b: int, n_pad: int, e_pad: int, k_max: int, din: int,
                   de: int, mesh):
    """ShapeDtypeStructs + shardings for a stream-batched PaddedSnapshot."""
    def spec(shape, dtype, names):
        return (jax.ShapeDtypeStruct(shape, dtype),
                named_sharding(shape, names, mesh))

    fields = {
        "src": ((b, e_pad), jnp.int32, ("stream", None)),
        "dst": ((b, e_pad), jnp.int32, ("stream", None)),
        "coef": ((b, e_pad), jnp.float32, ("stream", None)),
        "edge_feat": ((b, e_pad, de), jnp.float32, ("stream", None, None)),
        "neigh_idx": ((b, n_pad, k_max), jnp.int32, ("stream", None, None)),
        "neigh_coef": ((b, n_pad, k_max), jnp.float32, ("stream", None, None)),
        "neigh_eidx": ((b, n_pad, k_max), jnp.int32, ("stream", None, None)),
        "node_feat": ((b, n_pad, din), jnp.float32, ("stream", None, "feat")),
        "node_mask": ((b, n_pad), jnp.float32, ("stream", None)),
        "renumber": ((b, n_pad), jnp.int32, ("stream", None)),
        "n_nodes": ((b,), jnp.int32, ("stream",)),
        "n_edges": ((b,), jnp.int32, ("stream",)),
    }
    sds, shards = {}, {}
    for k, (shape, dtype, names) in fields.items():
        sds[k], shards[k] = spec(shape, dtype, names)
    snap_sds = PaddedSnapshot(**sds)
    snap_shard = PaddedSnapshot(**shards)
    return snap_sds, snap_shard


def run(model_name: str, streams: int, mode: str, multi_pod: bool,
        n_global: int = 640) -> dict:
    # n_global is PER-STREAM here: each stream is an independent small
    # dynamic graph (its own node-state store); the production axis is the
    # stream count, not one giant graph.
    from repro.core import build_model

    cfg = DGNN_CONFIGS[model_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = build_model(cfg, n_global=n_global)
    rec = {"arch": f"dgnn-{model_name}", "shape": f"streams_{streams}",
           "mesh": "2x16x16" if multi_pod else "16x16", "status": "run",
           "mode": mode}
    with sharding_ctx(mesh):
        params = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        pshard = jax.tree.map(
            lambda s: named_sharding(s.shape, (None,) * len(s.shape), mesh),
            params)
        state = jax.eval_shape(lambda: model.init_state(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params),
            mode=mode))
        # per-stream recurrent state: leading streams axis
        state = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((streams, *s.shape), s.dtype), state)
        sshard = jax.tree.map(
            lambda s: named_sharding(s.shape, ("stream",) + (None,) * (len(s.shape) - 1), mesh),
            state)
        snap_sds, snap_shard = snapshot_specs(
            streams, 640, 4096, 64, cfg.in_dim, cfg.edge_dim, mesh)

        def step(p, st, snap):
            return jax.vmap(lambda s1, s2: model.step(p, s1, s2, mode=mode),
                            in_axes=(0, 0))(st, snap)

        t0 = time.time()
        lowered = jax.jit(step, in_shardings=(pshard, sshard, snap_shard),
                          donate_argnums=(1,)).lower(params, state, snap_sds)
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t0
        mem = compiled.memory_analysis()
        if mem is not None:
            rec["memory"] = {
                "temp_size_in_bytes": int(mem.temp_size_in_bytes),
                "argument_size_in_bytes": int(mem.argument_size_in_bytes),
                "per_device_bytes": int(mem.temp_size_in_bytes + mem.argument_size_in_bytes),
            }
        m = _measure(compiled)
    # useful flops: MP (2*e*d) + NT/gates matmuls per stream per step
    e_eff, n_eff = 2 * 269 + 118, 118  # UCI-scale avg (edges incl reverse+loops)
    if model_name == "gcrn-m2":
        useful = streams * (2 * e_eff * cfg.in_dim + 2 * e_eff * cfg.hidden
                            + 2 * n_eff * (cfg.in_dim + cfg.hidden) * 4 * cfg.hidden)
    else:
        useful = streams * (2 * e_eff * cfg.in_dim
                            + 2 * n_eff * cfg.in_dim * cfg.hidden * 2)
    rl = Roofline(flops=m["flops"], bytes_hbm=m["bytes"],
                  bytes_coll=m["coll_bytes"], chips=chips,
                  model_flops=float(useful))
    rec["roofline"] = rl.to_dict()
    rec["collectives"] = {"bytes_by_op": m["coll_by_op"]}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="gcrn-m2", choices=sorted(DGNN_CONFIGS))
    ap.add_argument("--streams", type=int, default=4096)
    ap.add_argument("--mode", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    mode = args.mode or DGNN_CONFIGS[args.model].dataflow
    rec = run(args.model, args.streams, mode, args.multi_pod)
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = "mp" if args.multi_pod else "sp"
    out = os.path.join(OUT_DIR, f"dgnn-{args.model}__streams_{args.streams}__{tag}.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    print(json.dumps(rec.get("roofline"), indent=2))
    print("memory:", rec.get("memory"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
