"""Abstract step builders + input specs for the dry-run and launchers.

Everything here works on ShapeDtypeStructs (jax.eval_shape) — no device
allocation ever happens for the full-size configs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.api import Axes
from repro.models import (
    RuntimeConfig,
    cache_axes,
    decode_step,
    init_caches,
    init_params,
    loss_fn,
    prefill_step,
)
from repro.optim import AdamWConfig, apply_updates, init_opt_state, opt_state_axes


# --------------------------------------------------------------- specs ----


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> tuple[dict, dict]:
    """(ShapeDtypeStruct batch, Axes batch) for one input shape."""
    b = shape.global_batch
    if shape.is_decode:
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        axes = {"tokens": Axes("batch", None)}
        return specs, axes
    s = shape.seq_len
    if cfg.input_mode == "embeddings":
        specs = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)}
        axes = {"embeds": Axes("batch", None, None)}
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        axes = {"tokens": Axes("batch", None)}
    if shape.kind == "train":
        specs["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        axes["targets"] = Axes("batch", None)
    return specs, axes


def abstract_params(cfg: ModelConfig, rt: RuntimeConfig) -> tuple[Any, Any]:
    """(param ShapeDtypeStructs, Axes tree) without allocating."""
    box = {}

    def f(key):
        p, ax = init_params(cfg, rt, key)
        box["ax"] = ax
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["ax"]


def abstract_opt_state(param_shapes, params_axes, opt_cfg: AdamWConfig):
    shapes = jax.eval_shape(lambda: init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), param_shapes),
        opt_cfg))
    return shapes, opt_state_axes(params_axes, opt_cfg)


def abstract_caches(cfg: ModelConfig, rt: RuntimeConfig, batch: int, skv: int):
    shapes = jax.eval_shape(lambda: init_caches(cfg, rt, batch, skv))
    return shapes, cache_axes(cfg, rt)


# --------------------------------------------------------------- steps ----


def make_train_step_fn(cfg: ModelConfig, rt: RuntimeConfig, opt_cfg: AdamWConfig):
    a = rt.grad_accum

    def step(params, opt_state, batch):
        if a <= 1:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, rt, batch))(params)
        else:
            # microbatched gradient accumulation: divides every per-token
            # transient (attention probs, residual cotangents) by `a`
            micro = jax.tree.map(
                lambda x: x.reshape(a, x.shape[0] // a, *x.shape[1:]), batch)

            def body(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(
                    lambda p: loss_fn(p, cfg, rt, mb))(params)
                gacc = jax.tree.map(lambda x, y: x + y.astype(x.dtype), gacc, g)
                return (gacc, lacc + l), None

            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            (gsum, lsum), _ = jax.lax.scan(body, (gz, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / a, gsum)
            loss = lsum / a
        params, opt_state, metrics = apply_updates(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss
    return step


def make_prefill_fn(cfg: ModelConfig, rt: RuntimeConfig):
    def step(params, batch):
        return prefill_step(params, cfg, rt, batch)
    return step


def make_decode_fn(cfg: ModelConfig, rt: RuntimeConfig):
    def step(params, caches, batch):
        logits, caches = decode_step(params, cfg, rt, batch["tokens"], caches)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return nxt, caches
    return step
