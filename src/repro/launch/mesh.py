"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is a
second data-parallel axis with slower (DCI) links — collectives crossing it
are what the multi-pod dry-run must prove out.

Functions, not module constants: importing this module never touches jax
device state (smoke tests see 1 CPU device; only dryrun.py forces 512).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh


@dataclass(frozen=True)
class DeviceSpec:
    """Device sharding of a batched stream launch.

    ``n_devices`` shards the LEADING B grid axis of the stream engine
    (kernels/ops.stream_steps_batched) via shard_map over a 1-D data mesh:
    each device runs an independent slice of the stream batch (streams
    never communicate — their recurrent states are per-stream), so the
    sharded launch is bit-identical to the unsharded one. The default
    (n_devices=1) is the plain single-device launch with no mesh at all.
    ``axis`` is the mesh axis name (the 'data' axis of the production
    meshes above).
    """

    n_devices: int = 1
    axis: str = "data"


def make_stream_mesh(spec: DeviceSpec) -> Mesh:
    """1-D mesh for sharding a stream batch per ``DeviceSpec``."""
    devs = jax.devices()
    if len(devs) < spec.n_devices:
        raise RuntimeError(
            f"DeviceSpec wants {spec.n_devices} devices, have {len(devs)} — "
            "use XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU")
    return jax.make_mesh((spec.n_devices,), (spec.axis,),
                         devices=devs[:spec.n_devices])


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devs)} — "
            "run under dryrun.py (XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_host_mesh() -> Mesh:
    """1x1 mesh on the real local device (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
