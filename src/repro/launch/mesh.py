"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is a
second data-parallel axis with slower (DCI) links — collectives crossing it
are what the multi-pod dry-run must prove out.

Functions, not module constants: importing this module never touches jax
device state (smoke tests see 1 CPU device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devs)} — "
            "run under dryrun.py (XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_host_mesh() -> Mesh:
    """1x1 mesh on the real local device (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
