"""Training launcher.

Trains any registered architecture with the fault-tolerant loop on the
locally available devices (CPU smoke-scale by default; pass --full to use
the real config — on a pod that is the production entry point, on this
container it will lower but not fit, use dryrun.py instead).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --steps 50
  PYTHONPATH=src python -m repro.launch.train --dgnn evolvegcn --steps 200
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, list_archs, reduce_for_smoke
from repro.data import synthetic_lm_batches
from repro.models import RuntimeConfig, init_params, loss_fn
from repro.optim import AdamWConfig
from repro.train import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="use the full-size config (pod-scale)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--state-dtype", default="fp32",
                    choices=["fp32", "bf16", "int8"])
    args = ap.parse_args()

    cfg = ARCHS[args.arch] if args.full else reduce_for_smoke(ARCHS[args.arch])
    rt = RuntimeConfig(tp=1, scan_layers=True, remat=args.full,
                       attn_chunk=min(2048, args.seq), moe_impl="dense",
                       loss_chunk=min(128, args.seq))
    params, _ = init_params(cfg, rt, jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.batch}x{args.seq} tokens/step")

    batches = synthetic_lm_batches(cfg, args.batch, args.seq)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 10),
                      total_steps=args.steps, state_dtype=args.state_dtype)
    loop = TrainLoopConfig(total_steps=args.steps,
                           checkpoint_every=max(10, args.steps // 4),
                           checkpoint_dir=args.ckpt)
    params, res = train(lambda p, b: loss_fn(p, cfg, rt, b), params,
                        batches, opt, loop)
    k = max(1, len(res.losses) // 10)
    print(f"steps={res.final_step} resumed_from={res.resumed_from}")
    print(f"loss first{k}={np.mean(res.losses[:k]):.4f} "
          f"last{k}={np.mean(res.losses[-k:]):.4f}")
    print(f"mean step {np.mean(res.step_times[1:])*1e3:.1f} ms, "
          f"stragglers {res.straggler_steps}")


if __name__ == "__main__":
    main()
