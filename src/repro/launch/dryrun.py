import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  - build the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  - resolve the runtime config (FSDP + int8 moments for the big archs,
    sequence-sharded KV for long_500k),
  - jit the right step (train / prefill / decode) against ShapeDtypeStructs
    with NamedShardings from the logical-axis rules,
  - .lower().compile() — success proves the sharding config is coherent,
  - record memory_analysis, cost_analysis, parsed collective bytes, and the
    roofline terms (with itemized trip-count corrections) to JSON.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--jobs 4]      # full sweep, subprocesses
"""
import argparse
import json
import logging
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_status, get_config
from repro.distributed.api import DEFAULT_RULES, sharding_ctx, tree_shardings
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro.models import RuntimeConfig
from repro.optim import AdamWConfig
from repro.roofline import (Roofline, collective_bytes, cost_analysis_dict,
                            model_flops)
from repro.roofline.corrections import total_corrections

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

FSDP_THRESHOLD = 8e9        # params; above this shard weights over 'data'
INT8_THRESHOLD = 100e9      # params; above this quantize optimizer moments


def resolve_runtime(cfg, shape, overrides: dict | None = None) -> tuple[RuntimeConfig, AdamWConfig, dict]:
    n = cfg.param_count()
    fsdp = n > FSDP_THRESHOLD
    big = n > INT8_THRESHOLD
    rt = RuntimeConfig(
        tp=16,
        scan_layers=False,           # unrolled: exact per-layer accounting
        remat=True,
        attn_chunk=2048,
        moe_impl="ep",
        fsdp=fsdp,
        long_ctx=(shape.name == "long_500k"),
        loss_chunk=512,
        param_dtype="bf16" if big else "fp32",
        grad_accum=8 if big else 1,
    )
    opt = AdamWConfig(state_dtype="int8" if big else "fp32")
    rules = dict(DEFAULT_RULES)
    if not fsdp:
        rules["embed_fsdp"] = None
    if overrides:
        import dataclasses as _dc

        if overrides.get("rt"):
            rt = _dc.replace(rt, **overrides["rt"])
        rules.update(overrides.get("rules", {}))
        if overrides.get("opt"):
            opt = _dc.replace(opt, **overrides["opt"])
        if overrides.get("norm_lowmem"):
            from repro.nn.layers import set_lowmem_norm

            set_lowmem_norm(True)
        if overrides.get("ssd_bf16"):
            from repro.nn.mamba2 import set_ssd_bf16

            set_ssd_bf16(True)
    return rt, opt, rules


def _compile_once(cfg, shape, rt, opt_cfg, rules, mesh):
    """jit+lower+compile one step; returns (compiled, lower_s, compile_s)."""
    t0 = time.time()
    with sharding_ctx(mesh, rules):
        pshapes, paxes = S.abstract_params(cfg, rt)
        pshard = tree_shardings(pshapes, paxes, mesh)
        bspecs, baxes = S.batch_specs(cfg, shape)
        bshard = tree_shardings(bspecs, baxes, mesh)
        if shape.kind == "train":
            oshapes, oaxes = S.abstract_opt_state(pshapes, paxes, opt_cfg)
            oshard = tree_shardings(oshapes, oaxes, mesh)
            fn = S.make_train_step_fn(cfg, rt, opt_cfg)
            jitted = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(pshapes, oshapes, bspecs)
        elif shape.kind == "prefill":
            fn = S.make_prefill_fn(cfg, rt)
            jitted = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(pshapes, bspecs)
        else:  # decode
            cshapes, caxes = S.abstract_caches(cfg, rt, shape.global_batch,
                                               shape.seq_len)
            cshard = tree_shardings(cshapes, caxes, mesh)
            fn = S.make_decode_fn(cfg, rt)
            jitted = jax.jit(fn, in_shardings=(pshard, cshard, bshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(pshapes, cshapes, bspecs)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    return compiled, t1 - t0, t2 - t1


def _measure(compiled) -> dict:
    ca = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll.total_bytes),
        "coll_by_op": coll.bytes_by_op,
        "coll_counts": coll.count_by_op,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    """One dry-run cell.

    1. FULL model, scan_layers=True: lower+compile on the production mesh —
       the deliverable (sharding coherence + memory_analysis fits).
    2. (single-pod only) 1-block and 2-block UNROLLED variants compile to
       give exact per-layer-block cost/collective deltas; totals linearly
       extrapolate to n_layers blocks (XLA's HloCostAnalysis counts while
       bodies once, so the scanned compile cannot be used for cost). The
       inner chunk loops (attention/SSD/loss maps) are topped up by the
       closed-form trip-count corrections.
    All artifact numbers are PER-DEVICE (verified); roofline reports them
    per device against per-chip peaks.
    """
    import dataclasses

    cfg = get_config(arch)
    if overrides and overrides.get("cfg"):
        import dataclasses as _dc

        cfg = _dc.replace(cfg, **overrides["cfg"])
    shape = SHAPES[shape_name]
    status = cell_status(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": status,
    }
    if status != "run":
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rt, opt_cfg, rules = resolve_runtime(cfg, shape, overrides)

    # --- 1. full-model compile (the deliverable) ---
    rt_full = dataclasses.replace(rt, scan_layers=True)
    compiled, rec["lower_s"], rec["compile_s"] = _compile_once(
        cfg, shape, rt_full, opt_cfg, rules, mesh)
    mem = compiled.memory_analysis()
    if mem is not None:
        for f in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                rec.setdefault("memory", {})[f] = int(v)
        ms = rec.get("memory", {})
        per_dev = ms.get("temp_size_in_bytes", 0) + ms.get("argument_size_in_bytes", 0)
        ms["per_device_bytes"] = int(per_dev)
        ms["fits_16GB"] = bool(per_dev < 16e9)
    del compiled

    if multi_pod:
        return rec  # roofline table is single-pod (per assignment)

    # --- 2. per-block extrapolation compiles ---
    period = cfg.scan_period()
    nb = cfg.n_layers // period
    rt_u = dataclasses.replace(rt, scan_layers=False)
    cfg1 = dataclasses.replace(cfg, n_layers=period)
    cfg2 = dataclasses.replace(cfg, n_layers=2 * period)
    c1, _, t_c1 = _compile_once(cfg1, shape, rt_u, opt_cfg, rules, mesh)
    m1 = _measure(c1)
    del c1
    c2, _, t_c2 = _compile_once(cfg2, shape, rt_u, opt_cfg, rules, mesh)
    m2 = _measure(c2)
    del c2
    rec["extrap_compile_s"] = t_c1 + t_c2

    # grad-accum scan body is counted once by HloCostAnalysis but runs
    # `a` times per step (each on batch/a) -> scale to the full step.
    accum = rt.grad_accum if shape.kind == "train" else 1

    def extrap(key):
        return (m1[key] + (nb - 1) * (m2[key] - m1[key])) * accum

    coll_by_op = {
        k: (m1["coll_by_op"].get(k, 0)
            + (nb - 1) * (m2["coll_by_op"].get(k, 0) - m1["coll_by_op"].get(k, 0))
            ) * accum
        for k in set(m1["coll_by_op"]) | set(m2["coll_by_op"])
    }
    corr = total_corrections(cfg, shape, rt.tp, rt.attn_chunk, rt.loss_chunk,
                             attn_impl=rt.attn_impl, flash_bq=rt.flash_bq,
                             flash_bk=rt.flash_bk)
    flops = extrap("flops") + corr["flops"] / chips
    bytes_hbm = extrap("bytes") + corr["bytes_hbm"] / chips
    rl = Roofline(
        flops=flops, bytes_hbm=bytes_hbm,
        bytes_coll=extrap("coll_bytes"), chips=chips,
        model_flops=model_flops(cfg, shape),
    )
    rec.update(
        measured={"one_block": m1, "two_block": m2, "n_blocks": nb},
        corrections=corr,
        collectives={"bytes_by_op": coll_by_op,
                     "count_by_op_2blk": m2["coll_counts"]},
        roofline=rl.to_dict(),
    )
    return rec


def cell_out_path(arch, shape_name, multi_pod) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = "mp" if multi_pod else "sp"
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{tag}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--overrides", default=None,
                    help="JSON runtime overrides (hillclimb experiments)")
    ap.add_argument("--tag", default=None, help="suffix for the output file")
    args = ap.parse_args()

    if args.all:
        import subprocess

        cells = []
        for arch in sorted(ARCHS):
            for sn in SHAPES:
                for mp in (False, True):
                    cells.append((arch, sn, mp))
        procs: list = []
        failures = []
        for arch, sn, mp in cells:
            out = cell_out_path(arch, sn, mp)
            if os.path.exists(out):
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", sn] + (["--multi-pod"] if mp else [])
            while len(procs) >= args.jobs:
                for p in list(procs):
                    if p[0].poll() is not None:
                        procs.remove(p)
                        if p[0].returncode != 0:
                            failures.append(p[1])
                time.sleep(2)
            procs.append((subprocess.Popen(cmd, env={**os.environ}), (arch, sn, mp)))
            print("launched", arch, sn, "mp" if mp else "sp", flush=True)
        for p, cell in procs:
            if p.wait() != 0:
                failures.append(cell)
        print("failures:", failures)
        return 1 if failures else 0

    overrides = json.loads(args.overrides) if args.overrides else None
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, overrides)
    except (ValueError, TypeError, KeyError, RuntimeError) as e:
        # the expected compile-cell failures: config resolution errors
        # (ValueError/TypeError/KeyError) and XLA lowering/compile errors
        # (XlaRuntimeError is a RuntimeError). Anything else — OOM, bad
        # interpreter state — should crash the sweep loudly.
        logging.warning("dry-run cell %s/%s failed: %s: %s",
                        args.arch, args.shape, type(e).__name__, e)
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "traceback": traceback.format_exc()}
    out = cell_out_path(args.arch, args.shape, args.multi_pod)
    if args.tag:
        out = out.replace(".json", f"__{args.tag}.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    print(json.dumps({k: rec.get(k) for k in
                      ("arch", "shape", "mesh", "status", "compile_s")},
                     indent=None))
    if rec["status"] == "error":
        print(rec["traceback"][-3000:])
        return 1
    if rec["status"].startswith("skip"):
        return 0
    print("roofline:", json.dumps(rec.get("roofline", {}), indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
