"""Serving launcher (the paper's deployment mode).

DGNN mode: stream synthetic BC-Alpha/UCI snapshots through a DGNN-Booster
engine with the host/device task split.
LM mode: batched greedy generation from a registered arch (reduced config
on this container).

  PYTHONPATH=src python -m repro.launch.serve --dgnn gcrn-m2 --dataset uci
  PYTHONPATH=src python -m repro.launch.serve --lm jamba-v0.1-52b --steps 16
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, DATASETS, DGNN_CONFIGS, list_archs, reduce_for_smoke
from repro.graph import generate_temporal_graph, slice_snapshots
from repro.models import RuntimeConfig, init_params
from repro.serve import SnapshotServer, generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dgnn", choices=sorted(DGNN_CONFIGS), default=None)
    ap.add_argument("--dataset", choices=sorted(DATASETS), default="uci")
    ap.add_argument("--mode", default=None, help="baseline|o1|v1|v2")
    ap.add_argument("--snapshots", type=int, default=32)
    ap.add_argument("--lm", choices=list_archs(), default=None)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    if args.lm:
        cfg = reduce_for_smoke(ARCHS[args.lm])
        if not cfg.supports_decode:
            raise SystemExit(f"{args.lm} is encoder-only")
        rt = RuntimeConfig(tp=1, moe_impl="dense", attn_chunk=128)
        params, _ = init_params(cfg, rt, jax.random.PRNGKey(0))
        prompt = jnp.ones((args.batch, 4), jnp.int32)
        toks = generate(params, cfg, rt, prompt, steps=args.steps, skv=256)
        print(f"{args.lm}: generated {toks.shape} tokens")
        print(np.asarray(toks))
        return

    name = args.dgnn or "gcrn-m2"
    ds = DATASETS[args.dataset]
    tg, ft = generate_temporal_graph(ds)
    snaps = slice_snapshots(tg, 1.0)[: args.snapshots]
    srv = SnapshotServer(DGNN_CONFIGS[name], ft, n_global=tg.n_global_nodes,
                         mode=args.mode)
    params, state = srv.init(jax.random.PRNGKey(0))
    _, outs, stats = srv.run(params, state, snaps)
    print(f"{name} ({srv.mode}) on {ds.name}: {len(outs)} snapshots, "
          f"{stats.mean_latency_ms:.3f} ms/snapshot device, "
          f"{np.mean(stats.preprocess_ms):.3f} ms host (overlapped), "
          f"{stats.total_ms:.1f} ms total")


if __name__ == "__main__":
    main()
