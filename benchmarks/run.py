"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.
  table4_latency     Table IV  (per-snapshot latency, dataflow vs baseline)
  fig6_ablation      Fig. 6    (baseline / O1 / O2 incremental speedup)
  table7_dse         Table VII (GNN vs RNN module breakdown)
  roofline_table     (ours)    roofline terms per dry-run cell
  compression_bench  (ours)    gradient-compression wire bytes/fidelity
  kernel_bench       (ours)    kernel reference timings + VMEM accounting
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        compression_bench,
        fig6_ablation,
        kernel_bench,
        roofline_table,
        table4_latency,
        table7_dse,
    )

    print("name,us_per_call,derived")
    suites = [
        ("table4", table4_latency.run),
        ("fig6", fig6_ablation.run),
        ("table7", table7_dse.run),
        ("roofline", roofline_table.run),
        ("compression", compression_bench.run),
        ("kernel", kernel_bench.run),
    ]
    failures = []
    for name, fn in suites:
        try:
            for row in fn():
                print(",".join(str(x) for x in row), flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
