"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.dgnn import BC_ALPHA, UCI, DGNN_CONFIGS, DatasetConfig
from repro.core import build_model, stack_time
from repro.graph import (
    generate_temporal_graph,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)

N_PAD, E_PAD, K_MAX = 640, 4096, 64


def load_stream(ds: DatasetConfig, limit: int | None = None):
    """(temporal graph, feat table, raw snaps, padded time-major stream)."""
    tg, ft = generate_temporal_graph(ds)
    snaps = slice_snapshots(tg, 1.0)
    if limit:
        snaps = snaps[:limit]
    pads = [pad_snapshot(renumber_and_normalize(s), ft, N_PAD, E_PAD, K_MAX)
            for s in snaps]
    return tg, ft, snaps, stack_time(pads)


def time_step_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time (ms) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def per_snapshot_ms(cfg_name: str, ds: DatasetConfig, mode: str,
                    t_steps: int = 16, iters: int = 5) -> float:
    """Mean per-snapshot latency of a full stream scan (ms)."""
    cfg = DGNN_CONFIGS[cfg_name]
    tg, ft, snaps, sT = load_stream(ds, limit=t_steps)
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(0))
    state0 = model.init_state(params, mode=mode)

    from repro.core import run_stream

    run = jax.jit(lambda p, s, x: run_stream(model, p, s, x, mode=mode)[1])
    ms = time_step_fn(run, params, state0, sT, warmup=1, iters=iters)
    return ms / t_steps
