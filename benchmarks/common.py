"""Shared benchmark utilities."""
from __future__ import annotations

import json
import pathlib
import re
import time

import jax
import numpy as np

from repro.configs.dgnn import BC_ALPHA, UCI, DGNN_CONFIGS, DatasetConfig
from repro.core import build_model, stack_time
from repro.graph import (
    generate_temporal_graph,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)

N_PAD, E_PAD, K_MAX = 640, 4096, 64

# machine-readable stream-benchmark ledger at the repo root: one record
# per row name, merged across kernel_bench / fig6_ablation runs so the
# perf trajectory (throughput, live/padded ratio, plan fields) is
# trackable across PRs.
BENCH_STREAMS_PATH = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_streams.json"


def load_stream(ds: DatasetConfig, limit: int | None = None):
    """(temporal graph, feat table, raw snaps, padded time-major stream)."""
    tg, ft = generate_temporal_graph(ds)
    snaps = slice_snapshots(tg, 1.0)
    if limit:
        snaps = snaps[:limit]
    pads = [pad_snapshot(renumber_and_normalize(s), ft, N_PAD, E_PAD, K_MAX)
            for s in snaps]
    return tg, ft, snaps, stack_time(pads)


def time_step_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time (ms) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def per_snapshot_ms(cfg_name: str, ds: DatasetConfig, level: str,
                    t_steps: int = 16, iters: int = 5) -> float:
    """Mean per-snapshot latency of a full stream scan (ms) at one
    dataflow level (executed through a typed StreamPlan)."""
    from repro import api
    from repro.core import run_plan

    cfg = DGNN_CONFIGS[cfg_name]
    plan = api.plan(cfg, level=level)
    tg, ft, snaps, sT = load_stream(ds, limit=t_steps)
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(0))
    state0 = model.init_state(params, mode=level)

    run = jax.jit(lambda p, s, x: run_plan(model, p, s, x, plan)[1])
    ms = time_step_fn(run, params, state0, sT, warmup=1, iters=iters)
    return ms / t_steps


# ----------------------------------------------- BENCH_streams.json ----

def parse_notes(notes: str) -> dict:
    """Best-effort parse of a row's 'k=v,k=v' derived-notes string into
    typed fields (floats where possible; '1.37x'/'4611_snap/s' style
    suffixes stripped)."""
    out = {}
    for part in str(notes).split(","):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        v = v.strip()
        for suffix in ("_snap/s", "x"):
            if v.endswith(suffix):
                v = v[: -len(suffix)]
        try:
            out[k.strip()] = float(v)
        except ValueError:
            out[k.strip()] = v
    return out


def _row_key(name: str, plan: dict | None) -> str:
    """Ledger merge key. Un-planned rows key by name. Planned rows key by
    PLAN SIGNATURE plus the name with run-varying counters (``T8``,
    ``B4`` suffixen) stripped: a re-run of the same config under a
    different sweep length/batch count REPLACES its old row instead of
    accumulating a sibling duplicate, while rows whose plans genuinely
    differ (td, buffer_depth, batch, ...) stay distinct."""
    if plan is None:
        return str(name)
    base = re.sub(r"(?<=[_/])([TB])\d+", r"\1", str(name))
    return base + "::" + json.dumps(plan, sort_keys=True)


def write_stream_bench(rows, plans: dict | None = None,
                       path: pathlib.Path | None = None) -> dict:
    """Merge benchmark rows into the BENCH_streams.json ledger.

    ``rows`` are the (name, us_per_call, notes) triples the suites print;
    ``plans`` maps row name -> StreamPlan.as_dict() for rows executed
    through the plan API. Existing records for other configs are
    preserved (kernel_bench and fig6 both write here), so the file
    accumulates the full stream-perf picture per commit; records for the
    SAME config (see ``_row_key``) are replaced, not duplicated."""
    path = BENCH_STREAMS_PATH if path is None else pathlib.Path(path)
    ledger = {}
    if path.exists():
        for r in json.loads(path.read_text())["rows"]:
            ledger[_row_key(r["name"], r.get("plan"))] = r
    for name, us, notes in rows:
        rec = {"name": name, "us_per_call": float(us), **parse_notes(notes)}
        plan = plans.get(name) if plans else None
        if plan is not None:
            rec["plan"] = plan
        ledger[_row_key(name, plan)] = rec
    ordered = sorted(ledger.values(), key=lambda r: r["name"])
    payload = {"rows": ordered}
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    return payload
