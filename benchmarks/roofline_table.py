"""Aggregate the dry-run JSONs into the roofline table (EXPERIMENTS §Roofline)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(tag: str | None = None) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(f)
        if tag is None and base.count("__") != 2:
            continue
        if tag is not None and not base.endswith(f"__{tag}.json"):
            continue
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def fmt_row(r: dict) -> str:
    a, s = r["arch"], r["shape"]
    if r["status"] != "run":
        return f"| {a} | {s} | — | {r['status'].replace('skip: ', 'skip: ')} |"
    rl = r.get("roofline")
    mem = r.get("memory", {})
    if rl is None:
        fit = "Y" if mem.get("fits_16GB") else "N"
        return f"| {a} | {s} | {r['mesh']} | compile {r.get('compile_s', 0):.0f}s, fits={fit} |"
    return (
        f"| {a} | {s} | {rl['t_compute']*1e3:.1f} | {rl['t_memory']*1e3:.1f} | "
        f"{rl['t_collective']*1e3:.1f} | {rl['bottleneck'][:4]} | "
        f"{rl['useful_ratio']:.2f} | {rl['roofline_fraction']*100:.2f}% | "
        f"{(mem.get('per_device_bytes', 0))/1e9:.1f} |"
    )


def run(tag: str | None = None) -> list[tuple[str, float, str]]:
    rows = []
    for r in load_cells(tag):
        if r["status"] != "run" or "roofline" not in r:
            continue
        rl = r["roofline"]
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            rl["t_bound"] * 1e6 if "t_bound" in rl else max(
                rl["t_compute"], rl["t_memory"], rl["t_collective"]) * 1e6,
            f"bottleneck={rl['bottleneck']},fraction={rl['roofline_fraction']:.4f},useful={rl['useful_ratio']:.3f}",
        ))
    return rows


def markdown(tag: str | None = None) -> str:
    cells = load_cells(tag)
    sp = [c for c in cells if c["mesh"] == "16x16"]
    mp = [c for c in cells if c["mesh"] == "2x16x16"]
    lines = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound | useful | roofline-frac | GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sp:
        lines.append(fmt_row(r))
    lines.append("")
    lines.append("Multi-pod (2x16x16) compile proof:")
    lines.append("| arch | shape | mesh | result |")
    lines.append("|---|---|---|---|")
    for r in mp:
        lines.append(fmt_row(r))
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown())
