"""Kernel microbenchmarks: XLA reference path timings on CPU + the Pallas
kernels' VMEM working-set accounting (the TPU-relevant structural number).

Stream rows execute through typed StreamPlans (repro.api.run_arrays) and
record their plan fields in BENCH_streams.json (``python -m
benchmarks.kernel_bench`` merges the ledger) so the perf trajectory is
machine-trackable across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.kernels import ref

from benchmarks.common import load_stream, time_step_fn, write_stream_bench
from repro.configs.dgnn import BC_ALPHA, DGNNConfig

# row name -> StreamPlan.as_dict() for rows executed through the plan API
# (written into BENCH_streams.json alongside the measurements)
PLANS: dict = {}


def _planned(name: str, plan: api.StreamPlan) -> str:
    PLANS[name] = plan.as_dict()
    return name


def vmem_bytes_spmm(n=640, k=64, d=128, tn=128) -> int:
    """Per-grid-step VMEM bytes for the ELL SpMM BlockSpec tiling."""
    x_resident = n * d * 4
    idx_tile = tn * k * 4 * 2  # idx + eidx
    coef_tile = tn * k * 4
    out_tile = tn * d * 4
    return x_resident + idx_tile + coef_tile + out_tile


def live_padded_counts(node_mask) -> tuple[int, int]:
    """Padded-vs-live snapshot slots of a (batched) stream launch.

    A snapshot slot (b, t) is LIVE when any node is masked in; everything
    else is padding (no-op T tails, no-op batch rows, promoted-bucket
    inflation). Batched rows report both so padding overhead is visible
    instead of hiding in throughput.
    """
    m = np.asarray(node_mask)
    live = int((m.sum(axis=-1) > 0).sum())
    total = int(np.prod(m.shape[:-1]))
    return live, total - live


def vmem_state_block_bytes(n_global: int, hidden: int,
                           td: int | None = None) -> int:
    """Bytes of ONE (n_global, td) state window under D-axis blocking.

    td=None is the fully resident layout ((n_global, hidden) per buffer).
    The window is the PAGING UNIT of ``state_residency="hbm_paged"``:
    each DMA ring slot stages exactly one such window from the
    HBM-resident store (``run_paged_depth_sweep`` sweeps the ring depth),
    so under paging VMEM holds only ``O(depth)`` windows instead of the
    full store.
    """
    return n_global * (hidden if td is None else td) * 4


def recurrent_state_hbm_bytes(T: int, n_global: int, hidden: int,
                              n_states: int = 2, *, time_fused: bool) -> int:
    """HBM bytes moved for the recurrent state stores over one stream.

    Per-step engines (baseline..V2) gather the (n_global, hidden) h store —
    and c for GCRN (``n_states=2``) — out of HBM and scatter it back EVERY
    snapshot: 2*T transfers per state. The time-fused V3 kernel keeps the
    stores in VMEM scratch, so each crosses HBM exactly twice per stream
    (initial load + final drain): a T× reduction, the paper's BRAM win.
    """
    per_transfer = n_global * hidden * 4
    transfers = 2 * n_states if time_fused else 2 * n_states * T
    return transfers * per_transfer


def evolving_weights_hbm_bytes(T: int, dims, *, time_fused: bool) -> int:
    """HBM bytes moved for EvolveGCN's evolving weight matrices per stream.

    Per-step engines (baseline/o1/v1) round-trip every layer's W_l^t
    through HBM each snapshot (the per-step weight-update bottleneck of
    arXiv:2210.03900): 2T transfers per stream. The weights-resident V3
    kernel keeps the W_l in VMEM scratch with the matrix-GRU evolution
    in-kernel, so each crosses HBM exactly twice (primed load + evolved
    drain): the same T× reduction the node-state kernels get.
    """
    per_transfer = sum(di * do * 4 for di, do in dims)
    transfers = 2 if time_fused else 2 * T
    return transfers * per_transfer


def run() -> list[tuple[str, float, str]]:
    rows = []
    tg, ft, snaps, sT = load_stream(BC_ALPHA, limit=2)
    ps = jax.tree.map(lambda a: a[0], sT)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(640, 128)), jnp.float32)
    f = jax.jit(lambda *a: ref.ell_spmm(*a))
    t = time_step_fn(f, ps.neigh_idx, ps.neigh_coef, ps.neigh_eidx, x)
    rows.append(("kernel/ell_spmm_xla_ref", t * 1e3,
                 f"vmem_bytes={vmem_bytes_spmm()} (fits 128KiB*... v5e VMEM 128MB)"))
    wx = jnp.asarray(np.random.default_rng(1).normal(size=(128, 384)), jnp.float32)
    wh = jnp.asarray(np.random.default_rng(2).normal(size=(128, 384)), jnp.float32)
    b = jnp.zeros((384,))
    h = x
    f2 = jax.jit(lambda *a: ref.fused_gru(*a))
    t2 = time_step_fn(f2, x, h, wx, wh, b)
    rows.append(("kernel/fused_gru_xla_ref", t2 * 1e3, "gates=3-in-1 matmul"))
    rows.extend(run_stream_vs_per_step())
    rows.extend(run_paged_depth_sweep())
    rows.extend(run_evolve_stream_vs_per_step())
    rows.extend(run_batched_streams())
    rows.extend(run_evolve_batched_streams())
    rows.extend(run_serve_schedulers())
    return rows


def _gcrn_stream_fixture(t_steps: int, hidden: int):
    """Shared GCRN bench case: the bc-alpha stream plus random gate
    weights and zero h/c stores (reused by the per-step-vs-V3 rows and
    the hbm_paged ring-depth sweep so their timings are comparable)."""
    tg, ft, snaps, sT = load_stream(BC_ALPHA, limit=t_steps)
    G = tg.n_global_nodes
    rngs = np.random.default_rng(3)
    din = sT.node_feat.shape[2]
    wx = jnp.asarray(rngs.normal(size=(din, 4 * hidden)) * 0.1, jnp.float32)
    wh = jnp.asarray(rngs.normal(size=(hidden, 4 * hidden)) * 0.1, jnp.float32)
    b = jnp.zeros((4 * hidden,), jnp.float32)
    h0 = jnp.zeros((G, hidden), jnp.float32)
    c0 = jnp.zeros((G, hidden), jnp.float32)
    return sT, G, wx, wh, b, h0, c0


def run_stream_vs_per_step(t_steps: int = 8, hidden: int = 128
                           ) -> list[tuple[str, float, str]]:
    """Per-step V2 vs time-fused V3 on the same GCRN stream.

    Kernel-level apples-to-apples: the V2 row re-invokes the fused step
    kernel from a scan with the h/c stores gathered/scattered per snapshot
    (the HBM round-trip); the V3 row is ONE stream-kernel launch with the
    stores VMEM-resident. Wall time is CPU-bound here; the structural
    number is the recurrent-state HBM estimate (T× reduction on TPU).
    """
    from repro.kernels import ops

    plan_res = api.plan(family="gcrn", level="v3")
    plan_blk = api.plan(family="gcrn", level="v3", td=hidden // 2)
    sT, G, wx, wh, b, h0, c0 = _gcrn_stream_fixture(t_steps, hidden)

    def v2_scan(h_store, c_store):
        def body(carry, s):
            hs, cs = carry
            safe = jnp.where(s["ren"] >= 0, s["ren"], 0)
            m = s["mask"][:, None]
            h = hs[safe] * m
            c = cs[safe] * m
            h_new, c_new = ops.dgnn_fused_step(
                s["idx"], s["coef"], s["eidx"], s["x"], h, c, wx, wh, b)
            h_new, c_new = h_new * m, c_new * m
            sidx = jnp.where(s["ren"] >= 0, s["ren"], hs.shape[0])
            return (hs.at[sidx].set(h_new, mode="drop"),
                    cs.at[sidx].set(c_new, mode="drop")), h_new

        xs = dict(idx=sT.neigh_idx, coef=sT.neigh_coef, eidx=sT.neigh_eidx,
                  x=sT.node_feat, ren=sT.renumber, mask=sT.node_mask)
        (hs, cs), outs = jax.lax.scan(body, (h_store, c_store), xs)
        return outs, hs, cs

    def v3_stream(h_store, c_store, plan=plan_res):
        return api.run_arrays(
            plan, sT.neigh_idx, sT.neigh_coef, sT.neigh_eidx, sT.node_feat,
            sT.renumber, sT.node_mask, h_store, c_store, wx, wh, b)

    rows = []
    bytes_v2 = recurrent_state_hbm_bytes(t_steps, G, hidden, time_fused=False)
    bytes_v3 = recurrent_state_hbm_bytes(t_steps, G, hidden, time_fused=True)
    live, padded = live_padded_counts(sT.node_mask)
    t_v2 = time_step_fn(jax.jit(v2_scan), h0, c0, iters=5)
    rows.append((f"kernel/gcrn_per_step_v2_T{t_steps}", t_v2 * 1e3,
                 f"state_hbm_bytes={bytes_v2} (h+c in/out every step)"))
    t_v3 = time_step_fn(jax.jit(v3_stream), h0, c0, iters=5)
    rows.append((_planned(f"kernel/gcrn_time_fused_v3_T{t_steps}", plan_res),
                 t_v3 * 1e3,
                 f"state_hbm_bytes={bytes_v3},"
                 f"state_hbm_reduction={bytes_v2 // bytes_v3}x,"
                 f"snaps_live={live},snaps_padded={padded}"))
    # D-blocked layout: same stream, state addressed through (G, td)
    # column windows — the VMEM-oversized-store configuration. Identical
    # outputs (the engine's round-trip contract). The window is the
    # paging unit state_residency="hbm_paged" DMA-stages per ring slot
    # (run_paged_depth_sweep); resident, all windows share one VMEM
    # scratch allocation.
    td = hidden // 2
    t_v3b = time_step_fn(jax.jit(lambda hh, cc: v3_stream(hh, cc,
                                                          plan=plan_blk)),
                         h0, c0, iters=5)
    rows.append((_planned(f"kernel/gcrn_v3_dblocked_td{td}_T{t_steps}",
                          plan_blk), t_v3b * 1e3,
                 f"state_hbm_bytes={bytes_v3},"
                 f"dblock_paging_window_bytes={vmem_state_block_bytes(G, hidden, td)},"
                 f"resident_state_bytes={vmem_state_block_bytes(G, hidden)},"
                 f"snaps_live={live},snaps_padded={padded}"))
    return rows


def run_paged_depth_sweep(t_steps: int = 8, hidden: int = 128,
                          iters: int = 3) -> list[tuple[str, float, str]]:
    """HBM-paged residency × DMA ring depth (1 / 2 / 4) on the same GCRN
    stream as ``run_stream_vs_per_step``, bit-identical outputs by the
    paging contract (tests/test_paged.py).

    depth 1 is the synchronous baseline (each window's copy blocks
    compute), 2 double-buffers (window d+1 stages while d computes), 4
    quad-buffers. CPU wall time measures the interpreter, not DMA
    overlap; the structural numbers are per-window DMA bytes (the ring
    slot's staging transfer), windows per step, ring VMEM footprint, and
    the resident store bytes paging evicts from VMEM.
    """
    td = hidden // 2
    sT, G, wx, wh, b, h0, c0 = _gcrn_stream_fixture(t_steps, hidden)
    window = vmem_state_block_bytes(G, hidden, td)
    n_win = -(-hidden // td)
    rows = []
    for depth in (1, 2, 4):
        plan = api.plan(family="gcrn", level="v3", td=td,
                        state_residency="hbm_paged", buffer_depth=depth)
        fn = jax.jit(lambda hh, cc, p=plan: api.run_arrays(
            p, sT.neigh_idx, sT.neigh_coef, sT.neigh_eidx, sT.node_feat,
            sT.renumber, sT.node_mask, hh, cc, wx, wh, b))
        t = time_step_fn(fn, h0, c0, iters=iters)
        rows.append((
            _planned(f"kernel/gcrn_v3_hbm_paged_d{depth}_td{td}_T{t_steps}",
                     plan), t * 1e3,
            f"dma_window_bytes={window},"
            f"windows_per_step={n_win},"
            f"ring_vmem_bytes={depth * window},"
            f"staging_vmem_bytes={2 * window},"
            f"resident_store_bytes_evicted="
            f"{3 * vmem_state_block_bytes(G, hidden)}"))
    return rows


def _random_evolve_stream(rngs, t_steps: int, n: int, k: int, din: int):
    """Random padded ELL stream (all-live) for the EvolveGCN kernel rows."""
    idx = rngs.integers(0, n, (t_steps, n, k)).astype(np.int32)
    coef = (rngs.uniform(size=(t_steps, n, k)) *
            (rngs.uniform(size=(t_steps, n, k)) > 0.4)).astype(np.float32)
    x = rngs.normal(size=(t_steps, n, din)).astype(np.float32)
    mask = np.ones((t_steps, n), np.float32)
    live = np.ones(t_steps, np.int32)
    return idx, coef, x, mask, live


def _evolve_params(rngs, dims):
    ws = [jnp.asarray(rngs.normal(size=d) * 0.1, jnp.float32) for d in dims]
    bg = [jnp.zeros((d[1],), jnp.float32) for d in dims]
    gwx = [jnp.asarray(rngs.normal(size=(d[0], 3 * d[0])) * 0.1, jnp.float32)
           for d in dims]
    gwh = [jnp.asarray(rngs.normal(size=(d[0], 3 * d[0])) * 0.1, jnp.float32)
           for d in dims]
    gb = [jnp.zeros((3 * d[0],), jnp.float32) for d in dims]
    return ws, bg, gwx, gwh, gb


def run_evolve_stream_vs_per_step(t_steps: int = 8, n: int = 640,
                                  k: int = 32, din: int = 64,
                                  hidden: int = 128, out: int = 64
                                  ) -> list[tuple[str, float, str]]:
    """Per-step v1 schedule vs weights-resident V3 on the same EvolveGCN
    stream.

    The per-step row scans the overlapped v1 schedule (GCN + matrix-GRU
    per snapshot) with the evolving weights re-entering the device every
    step; the V3 row is ONE stream-kernel launch with the W_l
    VMEM-resident and the evolution in-kernel. On CPU BOTH rows route to
    the XLA oracle (set_force_ref) so neither measures the Pallas
    interpreter; wall times then mostly coincide and the structural
    number — the evolving-weights HBM estimate, a T× reduction on TPU —
    is the signal, the family's edition of the paper's BRAM win.
    """
    from repro.kernels import ops

    dims = [(din, hidden), (hidden, out)]
    plan_v3 = api.plan(family="evolve", level="v3")
    rngs = np.random.default_rng(5)
    stream = _random_evolve_stream(rngs, t_steps, n, k, din)
    ws, bg, gwx, gwh, gb = _evolve_params(rngs, dims)

    def per_step(weights):  # v1 schedule: weights cross HBM every step
        return ref.evolve_stream_ref(*stream, weights, bg, gwx, gwh, gb)

    def v3_stream(weights):
        return api.run_arrays(plan_v3, *stream, weights, bg, gwx, gwh, gb)

    bytes_v1 = evolving_weights_hbm_bytes(t_steps, dims, time_fused=False)
    bytes_v3 = evolving_weights_hbm_bytes(t_steps, dims, time_fused=True)
    rows = []
    on_cpu = jax.default_backend() != "tpu"
    ops.set_force_ref(on_cpu)
    try:
        # the per-step row is ALWAYS the XLA scan oracle — that IS the v1
        # schedule's dataflow (weights re-entering the device each step);
        # only the v3 row runs the Pallas kernel (on TPU).
        t_v1 = time_step_fn(jax.jit(per_step), ws, iters=5)
        rows.append((f"kernel/evolve_per_step_v1_T{t_steps}", t_v1 * 1e3,
                     f"path=xla_ref,weights_hbm_bytes={bytes_v1} "
                     "(all W_l in/out every step)"))
        t_v3 = time_step_fn(jax.jit(v3_stream), ws, iters=5)
        rows.append((_planned(f"kernel/evolve_weights_resident_v3_T{t_steps}",
                              plan_v3), t_v3 * 1e3,
                     f"path={'xla_ref' if on_cpu else 'pallas'},"
                     f"weights_hbm_bytes={bytes_v3},"
                     f"weights_hbm_reduction={bytes_v1 // bytes_v3}x"))
    finally:
        ops.set_force_ref(False)
    return rows


def _time_batched_vs_sequential(one, bat, singles, iters: int):
    """Shared scaffold for the 1-batched-dispatch-vs-B-sequential rows:
    warm/compile both jitted programs, then median wall time of B
    sequential dispatches vs ONE batched dispatch. On CPU the kernel
    wrappers route to the XLA oracle for the duration (set_force_ref) —
    interpret-mode Pallas wall time would measure the interpreter, not
    the dataflow. Returns (t_seq_ms, t_batched_ms, path)."""
    import time as _time

    from repro.kernels import ops

    on_cpu = jax.default_backend() != "tpu"
    ops.set_force_ref(on_cpu)
    try:
        for s in singles:  # warmup/compile
            jax.block_until_ready(one(*s))
        jax.block_until_ready(bat())
        ts, tb = [], []
        for _ in range(iters):
            t0 = _time.perf_counter()
            outs = [one(*s) for s in singles]
            jax.block_until_ready(outs)
            ts.append(_time.perf_counter() - t0)
            t0 = _time.perf_counter()
            jax.block_until_ready(bat())
            tb.append(_time.perf_counter() - t0)
    finally:
        ops.set_force_ref(False)
    return (float(np.median(ts)) * 1e3, float(np.median(tb)) * 1e3,
            "xla_ref" if on_cpu else "pallas")


def _dispatch_rows(family: str, B: int, t_steps: int, t_seq: float,
                   t_bat: float, path: str, node_mask=None, plan=None
                   ) -> list[tuple[str, float, str]]:
    total_snaps = B * t_steps
    live, padded = (live_padded_counts(node_mask) if node_mask is not None
                    else (total_snaps, 0))
    batched_name = f"kernel/{family}_v3_batched_B{B}_T{t_steps}"
    if plan is not None:
        batched_name = _planned(batched_name, plan)
    return [
        (f"kernel/{family}_v3_sequential_B{B}_T{t_steps}", t_seq * 1e3,
         f"dispatches={B},path={path},"
         f"throughput={total_snaps / (t_seq / 1e3):.0f}_snap/s"),
        (batched_name, t_bat * 1e3,
         f"dispatches=1,path={path},"
         f"throughput={total_snaps / (t_bat / 1e3):.0f}_snap/s,"
         f"snaps_live={live},snaps_padded={padded},"
         f"speedup_vs_sequential={t_seq / t_bat:.2f}x"),
    ]


def run_evolve_batched_streams(B: int = 8, t_steps: int = 4, n: int = 64,
                               k: int = 8, din: int = 16, hidden: int = 32,
                               out: int = 16, iters: int = 11
                               ) -> list[tuple[str, float, str]]:
    """Batched weights-resident V3 (ONE dispatch, B EvolveGCN streams)
    vs B separate single-stream dispatches — the multi-tenant win for the
    weights-evolved family, in the same small-snapshot regime as the
    GCRN rows. Streams carry DISTINCT evolving weights (each tenant's
    recurrent state) and distinct inputs; GRU params are shared and
    loaded once per launch. The structural numbers (dispatches B -> 1,
    weight-state transfers 2/stream) carry to TPU.
    """
    dims = [(din, hidden), (hidden, out)]
    rngs = np.random.default_rng(6)
    streams = [_random_evolve_stream(rngs, t_steps, n, k, din)
               for _ in range(B)]
    single = [tuple(jnp.asarray(a) for a in s) for s in streams]
    batch = tuple(jnp.asarray(np.stack([s[i] for s in streams]))
                  for i in range(5))
    _, bg, gwx, gwh, gb = _evolve_params(rngs, dims)
    wsB = [jnp.asarray(rngs.normal(size=(B,) + d) * 0.1, jnp.float32)
           for d in dims]

    p1 = api.plan(family="evolve", level="v3")
    pB = api.plan(family="evolve", level="v3", batch=B)
    one = jax.jit(lambda s, w: api.run_arrays(p1, *s, w, bg, gwx, gwh, gb))
    bat = jax.jit(lambda w: api.run_arrays(pB, *batch, w, bg, gwx, gwh, gb))
    t_seq, t_bat, path = _time_batched_vs_sequential(
        one, lambda: bat(wsB),
        [(single[i], [w[i] for w in wsB]) for i in range(B)], iters)
    return _dispatch_rows("evolve", B, t_steps, t_seq, t_bat, path,
                          node_mask=batch[3], plan=pB)


def run_batched_streams(B: int = 8, t_steps: int = 4, n: int = 64,
                        k: int = 8, din: int = 16, hidden: int = 32,
                        n_global: int = 200, iters: int = 11
                        ) -> list[tuple[str, float, str]]:
    """Batched V3 (ONE dispatch, B streams) vs B separate V3 dispatches.

    This measures what the multi-tenant server amortizes, in the regime
    batching exists for: SMALL per-tenant snapshots whose individual
    streams underutilize the device (the low-parallelism bottleneck of
    arXiv:2210.03900). Without batching, B clients cost B device
    dispatches per chunk and B short scans; with the batch grid axis they
    cost one dispatch whose per-step work is B× wider. Streams are B
    distinct random streams (identical inputs would let XLA CSE collapse
    the sequential program and fake the comparison); the structural
    numbers (dispatches B -> 1, recurrent-state HBM transfers 2/stream
    either way) carry over to the TPU build.
    """
    rngs = np.random.default_rng(4)

    def one_stream():
        idx = rngs.integers(0, n, (t_steps, n, k)).astype(np.int32)
        coef = (rngs.uniform(size=(t_steps, n, k)) *
                (rngs.uniform(size=(t_steps, n, k)) > 0.4)).astype(np.float32)
        eidx = rngs.integers(0, 4 * n, (t_steps, n, k)).astype(np.int32)
        x = rngs.normal(size=(t_steps, n, din)).astype(np.float32)
        ren = np.stack([np.sort(rngs.permutation(n_global)[:n])
                        for _ in range(t_steps)]).astype(np.int32)
        mask = np.ones((t_steps, n), np.float32)
        return idx, coef, eidx, x, ren, mask

    streams = [one_stream() for _ in range(B)]
    single = [tuple(jnp.asarray(a) for a in s) for s in streams]
    batch = tuple(jnp.asarray(np.stack([s[i] for s in streams]))
                  for i in range(6))
    wx = jnp.asarray(rngs.normal(size=(din, 4 * hidden)) * 0.1, jnp.float32)
    wh = jnp.asarray(rngs.normal(size=(hidden, 4 * hidden)) * 0.1, jnp.float32)
    b = jnp.zeros((4 * hidden,), jnp.float32)
    h0B = jnp.asarray(rngs.normal(size=(B, n_global, hidden)) * 0.1,
                      jnp.float32)
    c0B = jnp.asarray(rngs.normal(size=(B, n_global, hidden)) * 0.1,
                      jnp.float32)

    p1 = api.plan(family="gcrn", level="v3")
    pB = api.plan(family="gcrn", level="v3", batch=B)
    one = jax.jit(lambda s, hh, cc: api.run_arrays(p1, *s, hh, cc, wx, wh, b))
    bat = jax.jit(lambda hB, cB: api.run_arrays(pB, *batch, hB, cB,
                                                wx, wh, b))
    t_seq, t_bat, path = _time_batched_vs_sequential(
        one, lambda: bat(h0B, c0B),
        [(single[i], h0B[i], c0B[i]) for i in range(B)], iters)
    return _dispatch_rows("gcrn", B, t_steps, t_seq, t_bat, path,
                          node_mask=batch[5], plan=pB)


def run_serve_schedulers(n_backlog: int = 24, n_inc_snaps: int = 6,
                         n_inc_tenants: int = 3, interval_ms: float = 50.0,
                         chunk: int = 4, repeats: int = 2
                         ) -> list[tuple[str, float, str]]:
    """Round-based vs continuous serve scheduler under a SKEWED workload:
    one tenant with a deep snapshot backlog (all available at t=0 — a
    client replaying history) plus latency-sensitive incremental tenants
    whose snapshots ARRIVE one every ``interval_ms``.

    The headline number is the incremental tenants' p99 SOJOURN latency
    (commit wall-clock minus snapshot arrival, from ``ServeStats.
    commit_ms`` and an arrival clock stamped in the stream iterators).
    The round loop gathers a full chunk from EVERY tenant behind a
    barrier before launching, so an incremental snapshot waits for its
    chunk-mates to trickle in; the continuous scheduler serves whatever
    is ready each tick and drains the backlog ``prefill_chunk`` at a time
    in the gaps. Each scheduler gets one unpaced warm-up run (jit cache)
    plus ``repeats`` paced runs, best p99 reported — launch signatures
    depend on tick composition, so a first paced run can still hit a
    stray compile.
    """
    cfg = DGNNConfig(name="bench-sched-gcrn", dgnn_type="integrated",
                     gnn="gcn", rnn="lstm", dataflow="v3", in_dim=4,
                     hidden=8, out_dim=4, n_gnn_layers=1, edge_dim=2)
    from repro.graph.coo import COOSnapshot
    from repro.serve import SnapshotServer

    n_global = 32
    rngs = np.random.default_rng(11)
    feat = np.asarray(rngs.normal(size=(n_global, 4)), np.float32)

    def make_snaps(n_snap, seed):
        r = np.random.default_rng(seed)
        out = []
        for t in range(n_snap):
            e = int(r.integers(3, 7))
            out.append(COOSnapshot(
                src=r.integers(0, n_global, size=e),
                dst=r.choice(n_global, size=e, replace=False),
                edge_feat=np.asarray(r.normal(size=(e, 2)), np.float32),
                t_index=t))
        return out

    tenant_snaps = {"backlog": make_snaps(n_backlog, 100)}
    inc_sids = [f"inc{i}" for i in range(n_inc_tenants)]
    for i, sid in enumerate(inc_sids):
        tenant_snaps[sid] = make_snaps(n_inc_snaps, 200 + i)

    def paced(sid, arrivals):
        def gen():
            for i, s in enumerate(tenant_snaps[sid]):
                time.sleep(interval_ms / 1e3)
                arrivals[(sid, i)] = time.perf_counter()
                yield s
        return gen()

    rows = []
    variants = (("rounds", {}),
                ("continuous", dict(scheduler="continuous",
                                    state_pool_pages=n_inc_tenants + 1,
                                    prefill_chunk=2)))
    for sched, kw in variants:
        # pads sized to the tiny synthetic graphs: launch cost must sit
        # well under the arrival interval, the regime continuous batching
        # exists for (the default 640-node pads would make every launch
        # slower than the arrivals and the device the only bottleneck)
        plan = api.plan(cfg, level="v3", stream_chunk=chunk, queue_depth=64,
                        n_pad=32, e_pad=128, k_max=8, **kw)
        sess = api.BoosterSession(cfg, plan, n_global=n_global,
                                  feat_table=feat)
        srv = SnapshotServer(session=sess)
        params, _ = srv.init(jax.random.PRNGKey(0))

        # warm every (B, T) launch signature a tick could compose (tick
        # composition is timing-dependent, so an un-warmed signature would
        # charge a few hundred ms of CPU compile to whichever snapshot's
        # launch hits it first and poison the latency percentiles)
        from repro.core import stack_time
        ps = srv._preprocess(tenant_snaps["backlog"][0])
        state = srv.model.init_state(params, mode=srv.mode)
        for b_sig in (1, 2, 4):
            for t_sig in (1, 2, 4):
                st_b = jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                                    *([state] * b_sig))
                _, out = srv._launch_ragged(
                    params, st_b, [stack_time([ps] * t_sig)] * b_sig,
                    np.asarray([t_sig] * b_sig, np.int32))
                jax.block_until_ready(out)

        def run_once(pace):
            arrivals: dict = {}
            streams = {"backlog": list(tenant_snaps["backlog"])}
            for sid in inc_sids:
                streams[sid] = (paced(sid, arrivals) if pace
                                else list(tenant_snaps[sid]))
            states = {sid: srv.model.init_state(params, mode=srv.mode)
                      for sid in streams}
            _, outs, stats = srv.run_multi(params, states, streams)
            assert not stats.tenant_errors
            assert all(len(outs[s]) == len(tenant_snaps[s]) for s in streams)
            return arrivals, stats

        run_once(pace=False)  # warm the jit cache / launch signatures
        best = None
        for _ in range(repeats):
            arrivals, stats = run_once(pace=True)
            soj = [stats.commit_ms[sid][i]
                   - (arrivals[(sid, i)] - srv._t0_run) * 1e3
                   for sid in inc_sids for i in range(n_inc_snaps)]
            p99 = float(np.percentile(soj, 99))
            if best is None or p99 < best[0]:
                served = sum(len(v) for v in stats.commit_ms.values())
                best = (p99, float(np.median(soj)), stats, served)
        p99, p50, stats, served = best
        thru = served / (stats.total_ms / 1e3)
        rows.append((_planned(f"serve/sched_{sched}_gcrn_skewed", plan),
                     p99 * 1e3,  # ledger unit is us_per_call
                     f"p99_ms={p99:.2f},p50_ms={p50:.2f},"
                     f"wall_ms={stats.total_ms:.0f},"
                     f"thru={thru:.0f}_snap/s,launches={stats.launches},"
                     f"ticks={stats.ticks},prefill={stats.prefill_chunks},"
                     f"evictions={stats.evictions}"))
    return rows


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(",".join(map(str, r)))
    write_stream_bench(rows, PLANS)
