"""Kernel microbenchmarks: XLA reference path timings on CPU + the Pallas
kernels' VMEM working-set accounting (the TPU-relevant structural number).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

from benchmarks.common import load_stream, time_step_fn
from repro.configs.dgnn import BC_ALPHA


def vmem_bytes_spmm(n=640, k=64, d=128, tn=128) -> int:
    """Per-grid-step VMEM bytes for the ELL SpMM BlockSpec tiling."""
    x_resident = n * d * 4
    idx_tile = tn * k * 4 * 2  # idx + eidx
    coef_tile = tn * k * 4
    out_tile = tn * d * 4
    return x_resident + idx_tile + coef_tile + out_tile


def run() -> list[tuple[str, float, str]]:
    rows = []
    tg, ft, snaps, sT = load_stream(BC_ALPHA, limit=2)
    ps = jax.tree.map(lambda a: a[0], sT)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(640, 128)), jnp.float32)
    f = jax.jit(lambda *a: ref.ell_spmm(*a))
    t = time_step_fn(f, ps.neigh_idx, ps.neigh_coef, ps.neigh_eidx, x)
    rows.append(("kernel/ell_spmm_xla_ref", t * 1e3,
                 f"vmem_bytes={vmem_bytes_spmm()} (fits 128KiB*... v5e VMEM 128MB)"))
    wx = jnp.asarray(np.random.default_rng(1).normal(size=(128, 384)), jnp.float32)
    wh = jnp.asarray(np.random.default_rng(2).normal(size=(128, 384)), jnp.float32)
    b = jnp.zeros((384,))
    h = x
    f2 = jax.jit(lambda *a: ref.fused_gru(*a))
    t2 = time_step_fn(f2, x, h, wx, wh, b)
    rows.append(("kernel/fused_gru_xla_ref", t2 * 1e3, "gates=3-in-1 matmul"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
