"""Table VII analog: GNN vs RNN module latency breakdown (the DSE input).

The paper allocates DSPs per module from this breakdown (more to RNN for
EvolveGCN, more to GNN for GCRN-M2). On TPU the analogous decision is which
module's dims the model axis shards; the breakdown below is the input to
that decision and EXPERIMENTS.md §Perf discusses the choice.
"""
from __future__ import annotations

import jax

from repro.configs.dgnn import BC_ALPHA, DGNN_CONFIGS
from repro.core import build_model
from repro.core import gcn as G
from repro.core import rnn as R

from benchmarks.common import load_stream, time_step_fn


def run(iters: int = 20) -> list[tuple[str, float, str]]:
    tg, ft, snaps, sT = load_stream(BC_ALPHA, limit=4)
    snap0 = jax.tree.map(lambda a: a[0], sT)
    rows = []

    # EvolveGCN: GNN = 2-layer GCN fwd; RNN = matrix GRU evolution
    cfg = DGNN_CONFIGS["evolvegcn"]
    m = build_model(cfg, n_global=tg.n_global_nodes)
    p = m.init(jax.random.PRNGKey(0))
    w = [l["w"] for l in p["gcn"]]
    gnn = jax.jit(lambda pp, ww: G.gcn_forward_weights(pp["gcn"], ww, snap0,
                                                       snap0.node_feat))
    rnn = jax.jit(lambda pp, ww: [R.matrix_gru(g, x) for g, x in zip(pp["gru"], ww)])
    t_gnn = time_step_fn(gnn, p, w, iters=iters)
    t_rnn = time_step_fn(rnn, p, w, iters=iters)
    tot = t_gnn + t_rnn
    rows.append(("table7/evolvegcn/GNN", t_gnn * 1e3, f"share={t_gnn/tot:.0%}"))
    rows.append(("table7/evolvegcn/RNN", t_rnn * 1e3, f"share={t_rnn/tot:.0%}"))

    # GCRN-M2: GNN = the two gate graph-convs; RNN = LSTM elementwise update
    cfg = DGNN_CONFIGS["gcrn-m2"]
    m2 = build_model(cfg, n_global=tg.n_global_nodes)
    p2 = m2.init(jax.random.PRNGKey(0))
    import jax.numpy as jnp

    h = jnp.zeros((snap0.node_feat.shape[0], cfg.hidden))
    c = jnp.zeros_like(h)

    def gnn2(pp):
        ax = G.propagate_segment(snap0, snap0.node_feat, pp.get("w_edge"))
        ah = G.propagate_segment(snap0, h, None)
        return R.lstm_gates(pp["lstm"], ax, ah, fused=True)

    gates = jax.jit(gnn2)(p2)
    rnn2 = jax.jit(lambda g: R.lstm_apply_gates(g, c))
    t_gnn2 = time_step_fn(jax.jit(gnn2), p2, iters=iters)
    t_rnn2 = time_step_fn(rnn2, gates, iters=iters)
    tot2 = t_gnn2 + t_rnn2
    rows.append(("table7/gcrn-m2/GNN", t_gnn2 * 1e3, f"share={t_gnn2/tot2:.0%}"))
    rows.append(("table7/gcrn-m2/RNN", t_rnn2 * 1e3, f"share={t_rnn2/tot2:.0%}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
