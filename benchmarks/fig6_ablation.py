"""Fig. 6 analog: incremental speedup of Pipeline-O1 / Pipeline-O2 / V3.

Baseline: sequential engine, staged RNN gates.
O1: + fused RNN gate pipeline.
O2: + module-level GNN/RNN overlap (V1 for EvolveGCN, V2 for GCRN-M2).
V3: + time fusion — whole stream in one kernel, the recurrent state
    VMEM-resident across snapshots: the node-state store for
    GCRN/stacked, the evolving weight matrices (with the matrix-GRU
    running in-kernel) for EvolveGCN.
All levels compute identical outputs (tests assert it); the measurement is
per-snapshot latency on the same hardware plus the structural
recurrent-state HBM traffic estimate for the time-fused level.
"""
from __future__ import annotations

from repro.configs.dgnn import BC_ALPHA, UCI

from benchmarks.common import per_snapshot_ms

LEVELS = {"evolvegcn": ["baseline", "o1", "v1", "v3"],
          "gcrn-m2": ["baseline", "o1", "v2", "v3"],
          "stacked-gcn-gru": ["baseline", "o1", "v1", "v2", "v3"]}

# What the time-fused v3 engine keeps VMEM-resident, per family: the
# recurrent node-state store, or EvolveGCN's evolving weight matrices.
V3_RESIDENT = {"gcrn-m2": "state", "stacked-gcn-gru": "state",
               "evolvegcn": "weights"}


def run(t_steps: int = 16, iters: int = 5) -> list[tuple[str, float, str]]:
    """Measured wall-clock per level PLUS the structural (critical-path)
    speedup of the O2 overlap.

    This container is a single CPU core: O1's gate fusion shows up in wall
    clock (bigger, fewer matmuls), but O2's module overlap cannot — there is
    no second execution engine to overlap onto. O2's win is structural:
    the scan-body critical path drops from t_GNN + t_RNN to
    max(t_GNN, t_RNN); we report that predicted-overlap speedup from the
    measured module times (table7), which is the quantity the paper's FPGA
    realizes in hardware.
    """
    from benchmarks import table7_dse

    rows = []
    mod = {r[0]: r[1] / 1e3 for r in table7_dse.run()}  # name -> ms
    for name, levels in LEVELS.items():
        for ds in (BC_ALPHA, UCI):
            times = {lv: per_snapshot_ms(name, ds, lv, t_steps, iters)
                     for lv in levels}
            base = times["baseline"]
            for lv in levels:
                derived = f"speedup={base / times[lv]:.2f}x"
                if lv in ("v1", "v2") and f"table7/{name}/GNN" in mod:
                    g, r = mod[f"table7/{name}/GNN"], mod[f"table7/{name}/RNN"]
                    derived += f",structural_overlap_speedup={(g + r) / max(g, r):.2f}x"
                if lv == "v3":
                    # per-step engines move the resident object (node
                    # state, or EvolveGCN's evolving weights) 2T times per
                    # stream, the time-fused kernel twice: T× less HBM.
                    derived += (f",{V3_RESIDENT[name]}"
                                f"_hbm_xfer_reduction={t_steps}x")
                rows.append((f"fig6/{name}/{ds.name}/{lv}", times[lv] * 1e3,
                             derived))
    for name in ("gcrn-m2", "evolvegcn"):
        rows.extend(run_batched_sweep(name))
    return rows


def run_batched_sweep(name: str = "gcrn-m2", t_steps: int = 6,
                      streams=(1, 2, 4), iters: int = 5
                      ) -> list[tuple[str, float, str]]:
    """Throughput-vs-B: batched V3 (ONE dispatch for B independent streams)
    against B separate single-stream V3 dispatches of the same stream set.

    The batched rows measure the tentpole win directly: device dispatches
    drop B -> 1 while every stream's recurrent state still crosses HBM
    exactly twice, so throughput (snapshots/s over the whole batch) grows
    with B faster than sequential replay. Streams carry distinct node
    features (same bucket) — exactly what the multi-tenant server batches.
    On CPU the kernel wrappers route to the XLA oracle (set_force_ref):
    interpret-mode Pallas wall time would measure the interpreter.
    """
    import time as _time

    import jax
    import numpy as np

    from benchmarks.common import load_stream
    from benchmarks.kernel_bench import PLANS, live_padded_counts
    from repro import api
    from repro.configs.dgnn import DGNN_CONFIGS
    from repro.core import (build_model, init_states_batched, run_plan,
                            run_plan_batched)
    from repro.kernels import ops

    cfg = DGNN_CONFIGS[name]
    tg, ft, snaps, sT = load_stream(BC_ALPHA, limit=t_steps)
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    on_cpu = jax.default_backend() != "tpu"
    ops.set_force_ref(on_cpu)
    try:
        p1 = api.plan(cfg, level="v3")
        seq = jax.jit(
            lambda p, s, x: run_plan(model, p, s, x, p1)[1])
        for B in streams:
            pB = api.plan(cfg, level="v3", batch=B)
            bat = jax.jit(
                lambda p, s, x, pB=pB: run_plan_batched(model, p, s, x,
                                                        pB)[1])
            perturbed = [
                jax.tree.map(lambda a: a, sT) for _ in range(B)]
            for i, sp in enumerate(perturbed):
                sp.node_feat = sT.node_feat * (1.0 + 0.01 * i)
            sBT = jax.tree.map(
                lambda *xs: np.stack(xs, axis=0), *perturbed)
            states = init_states_batched(model, params, B, mode=pB.level)
            st1 = model.init_state(params, mode=p1.level)
            for sp in perturbed:  # warmup/compile both programs
                jax.block_until_ready(seq(params, st1, sp))
            jax.block_until_ready(bat(params, states, sBT))
            ts, tb = [], []
            for _ in range(iters):
                t0 = _time.perf_counter()
                outs = [seq(params, st1, sp) for sp in perturbed]
                jax.block_until_ready(outs)
                ts.append(_time.perf_counter() - t0)
                t0 = _time.perf_counter()
                jax.block_until_ready(bat(params, states, sBT))
                tb.append(_time.perf_counter() - t0)
            t_seq = float(np.median(ts)) * 1e3
            t_bat = float(np.median(tb)) * 1e3
            total = B * t_steps
            # padded-vs-live slots of the batched launch: this offline
            # sweep is all-live; serve-side chunk tails, dead ragged-T
            # slots and promoted buckets surface here as snaps_padded > 0.
            live, padded = live_padded_counts(sBT.node_mask)
            name_B = f"fig6/batched_v3/{name}/B{B}"
            PLANS[name_B] = pB.as_dict()
            rows.append((name_B, t_bat * 1e3,
                         f"throughput={total / (t_bat / 1e3):.0f}_snap/s,"
                         f"dispatches=1_vs_{B},"
                         f"snaps_live={live},snaps_padded={padded},"
                         f"speedup_vs_{B}x_sequential={t_seq / t_bat:.2f}x"))
        # hbm_paged mirror of kernel_bench.run_paged_depth_sweep: the
        # largest-B batched launch with the recurrent store HBM-resident,
        # swept over the DMA ring depth (bit-identical outputs by the
        # paging contract; the CPU rows route to the oracle like every
        # other fig6 row, so the plan fields are the payload here).
        B = streams[-1]
        td = p1.td if p1.td is not None else cfg.hidden // 2
        for depth in (1, 2, 4):
            pP = api.plan(cfg, level="v3", batch=B, td=td,
                          state_residency="hbm_paged", buffer_depth=depth)
            pag = jax.jit(
                lambda p, s, x, pP=pP: run_plan_batched(model, p, s, x,
                                                        pP)[1])
            jax.block_until_ready(pag(params, states, sBT))
            tp = []
            for _ in range(iters):
                t0 = _time.perf_counter()
                jax.block_until_ready(pag(params, states, sBT))
                tp.append(_time.perf_counter() - t0)
            t_pag = float(np.median(tp)) * 1e3
            name_P = f"fig6/batched_v3_hbm_paged/{name}/B{B}_d{depth}"
            PLANS[name_P] = pP.as_dict()
            total = B * t_steps
            rows.append((name_P, t_pag * 1e3,
                         f"throughput={total / (t_pag / 1e3):.0f}_snap/s,"
                         f"buffer_depth={depth},td={td},"
                         f"snaps_live={live},snaps_padded={padded}"))
    finally:
        ops.set_force_ref(False)
    return rows


if __name__ == "__main__":
    from benchmarks.common import write_stream_bench
    from benchmarks.kernel_bench import PLANS

    rows = run()
    for r in rows:
        print(",".join(map(str, r)))
    write_stream_bench(rows, PLANS)
