"""Fig. 6 analog: incremental speedup of Pipeline-O1 / Pipeline-O2 / V3.

Baseline: sequential engine, staged RNN gates.
O1: + fused RNN gate pipeline.
O2: + module-level GNN/RNN overlap (V1 for EvolveGCN, V2 for GCRN-M2).
V3: + time fusion — whole stream in one kernel, recurrent state
    VMEM-resident across snapshots (EvolveGCN falls back to V1's
    schedule: its recurrent state is weight matrices, not node rows).
All levels compute identical outputs (tests assert it); the measurement is
per-snapshot latency on the same hardware plus the structural
recurrent-state HBM traffic estimate for the time-fused level.
"""
from __future__ import annotations

from repro.configs.dgnn import BC_ALPHA, UCI

from benchmarks.common import per_snapshot_ms

LEVELS = {"evolvegcn": ["baseline", "o1", "v1", "v3"],
          "gcrn-m2": ["baseline", "o1", "v2", "v3"],
          "stacked-gcn-gru": ["baseline", "o1", "v1", "v2", "v3"]}

# DGNN families whose v3 engine is the real time-fused stream kernel (the
# weights-evolved family falls back to the v1 schedule).
TIME_FUSED = {"gcrn-m2", "stacked-gcn-gru"}


def run(t_steps: int = 16, iters: int = 5) -> list[tuple[str, float, str]]:
    """Measured wall-clock per level PLUS the structural (critical-path)
    speedup of the O2 overlap.

    This container is a single CPU core: O1's gate fusion shows up in wall
    clock (bigger, fewer matmuls), but O2's module overlap cannot — there is
    no second execution engine to overlap onto. O2's win is structural:
    the scan-body critical path drops from t_GNN + t_RNN to
    max(t_GNN, t_RNN); we report that predicted-overlap speedup from the
    measured module times (table7), which is the quantity the paper's FPGA
    realizes in hardware.
    """
    from benchmarks import table7_dse

    rows = []
    mod = {r[0]: r[1] / 1e3 for r in table7_dse.run()}  # name -> ms
    for name, levels in LEVELS.items():
        for ds in (BC_ALPHA, UCI):
            times = {lv: per_snapshot_ms(name, ds, lv, t_steps, iters)
                     for lv in levels}
            base = times["baseline"]
            for lv in levels:
                derived = f"speedup={base / times[lv]:.2f}x"
                if lv in ("v1", "v2") and f"table7/{name}/GNN" in mod:
                    g, r = mod[f"table7/{name}/GNN"], mod[f"table7/{name}/RNN"]
                    derived += f",structural_overlap_speedup={(g + r) / max(g, r):.2f}x"
                if lv == "v3":
                    if name in TIME_FUSED:
                        # per-step engines move the state 2T times/stream,
                        # the time-fused kernel twice: T× less HBM traffic.
                        derived += f",state_hbm_xfer_reduction={t_steps}x"
                    else:
                        derived += ",fallback=v1_schedule"
                rows.append((f"fig6/{name}/{ds.name}/{lv}", times[lv] * 1e3,
                             derived))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
