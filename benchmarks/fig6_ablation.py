"""Fig. 6 analog: incremental speedup of Pipeline-O1 and Pipeline-O2.

Baseline: sequential engine, staged RNN gates.
O1: + fused RNN gate pipeline.
O2: + module-level GNN/RNN overlap (V1 for EvolveGCN, V2 for GCRN-M2).
All three compute identical outputs (tests assert it); the measurement is
per-snapshot latency on the same hardware.
"""
from __future__ import annotations

from repro.configs.dgnn import BC_ALPHA, UCI

from benchmarks.common import per_snapshot_ms

LEVELS = {"evolvegcn": ["baseline", "o1", "v1"],
          "gcrn-m2": ["baseline", "o1", "v2"],
          "stacked-gcn-gru": ["baseline", "o1", "v1", "v2"]}


def run(t_steps: int = 16, iters: int = 5) -> list[tuple[str, float, str]]:
    """Measured wall-clock per level PLUS the structural (critical-path)
    speedup of the O2 overlap.

    This container is a single CPU core: O1's gate fusion shows up in wall
    clock (bigger, fewer matmuls), but O2's module overlap cannot — there is
    no second execution engine to overlap onto. O2's win is structural:
    the scan-body critical path drops from t_GNN + t_RNN to
    max(t_GNN, t_RNN); we report that predicted-overlap speedup from the
    measured module times (table7), which is the quantity the paper's FPGA
    realizes in hardware.
    """
    from benchmarks import table7_dse

    rows = []
    mod = {r[0]: r[1] / 1e3 for r in table7_dse.run()}  # name -> ms
    for name, levels in LEVELS.items():
        for ds in (BC_ALPHA, UCI):
            times = {lv: per_snapshot_ms(name, ds, lv, t_steps, iters)
                     for lv in levels}
            base = times["baseline"]
            for lv in levels:
                derived = f"speedup={base / times[lv]:.2f}x"
                if lv in ("v1", "v2") and f"table7/{name}/GNN" in mod:
                    g, r = mod[f"table7/{name}/GNN"], mod[f"table7/{name}/RNN"]
                    derived += f",structural_overlap_speedup={(g + r) / max(g, r):.2f}x"
                rows.append((f"fig6/{name}/{ds.name}/{lv}", times[lv] * 1e3,
                             derived))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
