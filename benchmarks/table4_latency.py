"""Table IV analog: per-snapshot end-to-end latency, EvolveGCN & GCRN-M2
on BC-Alpha & UCI, paper dataflow vs the sequential baseline.

The paper compares FPGA vs CPU/GPU hardware; this container has one CPU, so
the meaningful reproduction axis is the DATAFLOW: per-snapshot latency of
the DGNN-Booster engine (V1/V2) vs the unoptimized sequential baseline on
identical hardware, plus the serving-engine (host/device split) latency
with preprocessing overlap. Energy (Tables V/VI) needs a power meter and is
reported as FLOP-proxy notes in EXPERIMENTS.md.
"""
from __future__ import annotations

from repro.configs.dgnn import BC_ALPHA, UCI, DGNN_CONFIGS

from benchmarks.common import load_stream, per_snapshot_ms

PAIRS = [("evolvegcn", "v1"), ("gcrn-m2", "v2")]
DATASETS = [BC_ALPHA, UCI]


def run(t_steps: int = 16, iters: int = 5) -> list[tuple[str, float, str]]:
    rows = []
    for name, booster_mode in PAIRS:
        for ds in DATASETS:
            base = per_snapshot_ms(name, ds, "baseline", t_steps, iters)
            boost = per_snapshot_ms(name, ds, booster_mode, t_steps, iters)
            speedup = base / boost if boost else float("nan")
            rows.append((f"table4/{name}/{ds.name}/baseline", base * 1e3,
                         f"ms_per_snapshot={base:.3f}"))
            rows.append((f"table4/{name}/{ds.name}/{booster_mode}", boost * 1e3,
                         f"ms_per_snapshot={boost:.3f},speedup_vs_baseline={speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
