"""Gradient-compression benchmark: wire bytes + fidelity per scheme."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.optim import dequantize_blockwise, quantize_blockwise


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    g = rng.normal(0, 1e-3, (4 * 1024 * 1024,)).astype(np.float32)  # 16 MiB grads
    rows = []
    fp32_bytes = g.nbytes
    # bf16
    bf = jnp.asarray(g).astype(jnp.bfloat16)
    err_bf = float(np.abs(np.asarray(bf, np.float32) - g).max() / np.abs(g).max())
    rows.append(("compression/bf16", 0.0,
                 f"bytes_ratio={2*g.size/fp32_bytes:.2f},rel_err={err_bf:.2e}"))
    # int8 blockwise
    qd = quantize_blockwise(jnp.asarray(g))
    nbytes = qd["q"].size + qd["scale"].size * 4
    back = np.asarray(dequantize_blockwise(qd, g.shape))
    err_q = float(np.abs(back - g).max() / np.abs(g).max())
    rows.append(("compression/int8_blockwise", 0.0,
                 f"bytes_ratio={nbytes/fp32_bytes:.3f},rel_err={err_q:.2e}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
