"""Graph substrate: slicing, renumbering, format conversion, padding."""
import numpy as np
import pytest

from repro.configs.dgnn import BC_ALPHA, UCI
from repro.graph import (
    choose_bucket,
    empty_like_padded,
    generate_temporal_graph,
    max_in_degree,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
    snapshot_stats,
    to_ell,
)


@pytest.fixture(scope="module")
def bc():
    tg, ft = generate_temporal_graph(BC_ALPHA)
    return tg, ft, slice_snapshots(tg, 1.0)


def test_slice_covers_all_edges(bc):
    tg, _, snaps = bc
    assert sum(s.n_edges for s in snaps) == tg.n_edges


def test_snapshot_stats_match_table3_scale(bc):
    _, _, snaps = bc
    st = snapshot_stats(snaps)
    # Table III: BC-Alpha avg 107 nodes / 232 edges, max 578 / 1686
    assert 70 <= st["avg_nodes"] <= 160
    assert 150 <= st["avg_edges"] <= 350
    assert st["max_nodes"] <= BC_ALPHA.max_nodes
    assert st["max_edges"] <= BC_ALPHA.max_edges
    assert st["snapshots"] == BC_ALPHA.snapshots


def test_renumbering_is_dense_and_invertible(bc):
    _, _, snaps = bc
    ls = renumber_and_normalize(snaps[3])
    # local ids form a dense [0, n) space
    assert ls.src.max() < ls.n_nodes and ls.dst.max() < ls.n_nodes
    # renumber table maps back to the original global ids
    orig = set(np.concatenate([snaps[3].src, snaps[3].dst]).tolist())
    assert set(ls.renumber.tolist()) == orig
    # sorted + unique (searchsorted contract)
    assert np.all(np.diff(ls.renumber) > 0)


def test_gcn_normalization_rows(bc):
    _, _, snaps = bc
    ls = renumber_and_normalize(snaps[0])
    # symmetric normalization: sum_j coef(i<-j) * sqrt(d_j/d_i) == 1; check
    # the weaker invariant that the self-loop coef is 1/d for isolated nodes
    deg = np.bincount(ls.dst, minlength=ls.n_nodes)
    assert (deg >= 1).all()  # every node has at least the self-loop
    assert (ls.coef > 0).all()


def test_ell_matches_coo(bc):
    _, _, snaps = bc
    ls = renumber_and_normalize(snaps[1])
    k = max_in_degree(ls)
    idx, coef, eidx = to_ell(ls, 640, k)
    # edge multiset preserved: sum of coefs equal
    assert np.isclose(coef.sum(), ls.coef.sum(), rtol=1e-5)
    # per-node in-degree preserved
    fill = (coef != 0).sum(axis=1)
    deg = np.bincount(ls.dst, minlength=640)
    # zero-coef edges are legal but rare; degree bound must hold
    assert (fill <= deg).all()


def test_ell_overflow_raises(bc):
    _, _, snaps = bc
    ls = renumber_and_normalize(snaps[0])
    with pytest.raises(ValueError):
        to_ell(ls, 640, 1)


def test_pad_snapshot_shapes_and_masks(bc):
    _, ft, snaps = bc
    ls = renumber_and_normalize(snaps[0])
    ps = pad_snapshot(ls, ft, 640, 4096, 64)
    assert ps.node_feat.shape == (640, ft.shape[1])
    assert ps.node_mask.sum() == ls.n_nodes
    assert int(ps.n_nodes) == ls.n_nodes
    # padded edges must be dead (coef 0)
    e = ls.src.shape[0]
    assert np.all(np.asarray(ps.coef)[e:] == 0)
    # renumber padding marked -1
    assert np.all(np.asarray(ps.renumber)[ls.n_nodes:] == -1)


def test_bucket_overflow_raises(bc):
    _, ft, snaps = bc
    ls = renumber_and_normalize(snaps[0])
    with pytest.raises(ValueError):
        pad_snapshot(ls, ft, ls.n_nodes - 1, 4096, 64)


BUCKETS = ((128, 512, 32), (320, 1024, 48), (640, 4096, 96))


def test_choose_bucket_smallest_fit():
    assert choose_bucket(100, 400, 16, BUCKETS) == (128, 512, 32)
    # one dimension overflowing the small bucket promotes the whole snapshot
    assert choose_bucket(100, 400, 33, BUCKETS) == (320, 1024, 48)
    assert choose_bucket(100, 2000, 16, BUCKETS) == (640, 4096, 96)


def test_choose_bucket_exact_fit_boundary():
    # <= is inclusive: a snapshot exactly at the bucket limits still fits
    assert choose_bucket(128, 512, 32, BUCKETS) == (128, 512, 32)
    assert choose_bucket(640, 4096, 96, BUCKETS) == (640, 4096, 96)
    # one past the boundary promotes / raises
    assert choose_bucket(129, 512, 32, BUCKETS) == (320, 1024, 48)


def test_choose_bucket_no_fit_raises():
    with pytest.raises(ValueError):
        choose_bucket(641, 8, 8, BUCKETS)
    with pytest.raises(ValueError):
        choose_bucket(8, 8, 97, BUCKETS)


def test_empty_like_padded_is_noop_snapshot(bc):
    _, ft, snaps = bc
    ls = renumber_and_normalize(snaps[0])
    ps = pad_snapshot(ls, ft, 640, 4096, 64)
    empty = empty_like_padded(ps)
    assert empty.node_feat.shape == ps.node_feat.shape
    assert empty.edge_feat.shape == ps.edge_feat.shape
    assert int(empty.n_nodes) == 0
    assert np.all(np.asarray(empty.node_mask) == 0)
    assert np.all(np.asarray(empty.renumber) == -1)
    assert np.all(np.asarray(empty.neigh_coef) == 0)
