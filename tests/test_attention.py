"""Attention: chunked == full; decode == last row of full; GQA grouping."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.nn import attention as A

CFG = dataclasses.replace(reduce_for_smoke(ARCHS["qwen2.5-14b"]), n_layers=2)


def _qkv(b=2, s=64, hq=4, hkv=2, hd=16):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunk", [16, 32])
def test_chunked_equals_full(causal, chunk):
    q, k, v = _qkv()
    full = A.full_attention(q, k, v, causal=causal)
    ch = A.chunked_attention(q, k, v, causal=causal, chunk=chunk)
    np.testing.assert_allclose(ch, full, atol=1e-5)


def test_gqa_grouping_equals_repeated_kv():
    """Grouped einsum == materialized KV-head repeat."""
    q, k, v = _qkv(hq=8, hkv=2)
    got = A.full_attention(q, k, v, causal=True)
    krep = jnp.repeat(k, 4, axis=2)
    vrep = jnp.repeat(v, 4, axis=2)
    want = A.full_attention(q, krep, vrep, causal=True)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_decode_matches_full_last_row():
    q, k, v = _qkv(s=32)
    full = A.full_attention(q, k, v, causal=True)
    # decode the last position against the cache of all 32 (len = 32)
    out = A.decode_attention(q[:, -1:], k, v, kv_len=32)
    np.testing.assert_allclose(out[:, 0], full[:, -1], atol=1e-5)


def test_decode_masks_beyond_len():
    q, k, v = _qkv(s=32)
    out_short = A.decode_attention(q[:, :1], k, v, kv_len=5)
    k2 = k.at[:, 5:].set(999.0)  # junk beyond len must not matter
    v2 = v.at[:, 5:].set(999.0)
    out_junk = A.decode_attention(q[:, :1], k2, v2, kv_len=5)
    np.testing.assert_allclose(out_short, out_junk, atol=1e-5)


def test_attention_block_incremental_decode_consistency():
    """Feeding tokens one by one through the cache == full causal attention."""
    import repro.nn.layers as L

    cfg = CFG
    p, _ = A.init_attention(jax.random.PRNGKey(0), cfg, tp=1)
    b, s, d = 2, 8, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d), jnp.float32).astype(L.ACT_DTYPE)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    full, _ = A.attention_block(p, cfg, x, positions)
    cache = A.init_decode_cache(cfg, b, s, tp=1, dtype=jnp.float32)
    outs = []
    for t in range(s):
        y, cache = A.attention_block(p, cfg, x[:, t : t + 1],
                                     positions[:, t : t + 1], cache=cache)
        outs.append(y)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc, np.float32),
                               np.asarray(full, np.float32),
                               atol=3e-2, rtol=3e-2)  # bf16 activations


def test_int8_kv_cache_close_to_bf16():
    """int8 KV cache decode ~= exact decode (per-token absmax quant)."""
    cfg = CFG
    p, _ = A.init_attention(jax.random.PRNGKey(0), cfg, tp=1)
    b, s, d = 2, 8, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d), jnp.float32).astype(jnp.bfloat16)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    exact_cache = A.init_decode_cache(cfg, b, s, tp=1, dtype=jnp.float32)
    q_cache = A.init_decode_cache(cfg, b, s, tp=1, quant=True)
    outs_e, outs_q = [], []
    for t in range(s):
        ye, exact_cache = A.attention_block(p, cfg, x[:, t:t+1],
                                            positions[:, t:t+1], cache=exact_cache)
        yq, q_cache = A.attention_block(p, cfg, x[:, t:t+1],
                                        positions[:, t:t+1], cache=q_cache)
        outs_e.append(np.asarray(ye, np.float32))
        outs_q.append(np.asarray(yq, np.float32))
    e = np.concatenate(outs_e, 1)
    q = np.concatenate(outs_q, 1)
    rel = np.abs(e - q).max() / (np.abs(e).max() + 1e-9)
    assert rel < 0.06, rel
