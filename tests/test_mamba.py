"""Mamba2 SSD: chunked scan == naive recurrence; decode == prefill handoff."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.nn import mamba2 as M


def naive_ssm(xh, dt, A, Bm, Cm):
    """Step-by-step recurrence oracle. Shapes as ssd_chunked."""
    b, s, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Bh = np.repeat(np.asarray(Bm), rep, axis=2)
    Ch = np.repeat(np.asarray(Cm), rep, axis=2)
    xh, dt = np.asarray(xh), np.asarray(dt)
    A = np.asarray(A)
    state = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    for t in range(s):
        da = np.exp(dt[:, t] * A)  # (b,h)
        upd = np.einsum("bhn,bh,bhp->bhpn", Bh[:, t], dt[:, t], xh[:, t])
        state = state * da[:, :, None, None] + upd
        ys[:, t] = np.einsum("bhn,bhpn->bhp", Ch[:, t], state)
    return ys, state


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (64, 64)])
def test_ssd_chunked_equals_naive(s, chunk):
    b, h, p, g, n = 2, 4, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[0], (b, s, g, n)) * 0.3
    y, final = M.ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y_ref, final_ref = naive_ssm(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(final), final_ref, atol=2e-2, rtol=2e-2)


def test_mamba_block_decode_continues_prefill():
    cfg = reduce_for_smoke(ARCHS["mamba2-2.7b"])
    p, _ = M.init_mamba(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s + 1, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    # full prefill over s+1 tokens
    y_full, _ = M.mamba_block(p, cfg, x)
    # prefill s tokens, then decode token s+1 against the handoff state
    y_pre, st = M.mamba_block(p, cfg, x[:, :s])
    y_dec, _ = M.mamba_block(p, cfg, x[:, s : s + 1], state=st)
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0], np.float32),
        np.asarray(y_full[:, s], np.float32), atol=5e-2, rtol=5e-2)


def test_decay_bounds():
    """SSD decay factors must lie in (0, 1] — stability invariant."""
    b, s, h = 2, 32, 4
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(0), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(1), (h,)))
    da = jnp.exp(dt * A)
    assert (da > 0).all() and (da <= 1.0).all()
