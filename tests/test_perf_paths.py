"""The §Perf optimization switches must preserve the computed function.

Guards the EXPERIMENTS §Perf claims: flash-train, bwd_bf16, lowmem norm,
fused conv, ssd_bf16 change performance characteristics, not math (within
bf16 rounding).
"""

import pytest as _pytest

pytestmark = _pytest.mark.slow  # LM-side e2e: excluded from the fast CI lane

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.nn.layers as L
import repro.nn.mamba2 as M2
from repro.configs import ARCHS, reduce_for_smoke
from repro.models import RuntimeConfig, init_params, loss_fn

BASE_RT = RuntimeConfig(tp=1, scan_layers=True, remat=False, attn_chunk=64,
                        moe_impl="dense", loss_chunk=8)


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
    }


@pytest.fixture(autouse=True)
def reset_flags():
    yield
    L.set_lowmem_norm(False)
    M2.set_ssd_bf16(False)


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "granite-moe-3b-a800m"])
def test_flash_train_matches_xla(arch):
    cfg = reduce_for_smoke(ARCHS[arch])
    params, _ = init_params(cfg, BASE_RT, jax.random.PRNGKey(0))
    b = _batch(cfg)
    l_ref, g_ref = jax.value_and_grad(lambda p: loss_fn(p, cfg, BASE_RT, b))(params)
    rt2 = dataclasses.replace(BASE_RT, attn_impl="flash", flash_bq=16, flash_bk=16)
    l_fl, g_fl = jax.value_and_grad(lambda p: loss_fn(p, cfg, rt2, b))(params)
    assert abs(float(l_ref) - float(l_fl)) < 3e-3
    r = jax.tree.leaves(g_ref)
    f = jax.tree.leaves(g_fl)
    for a, bb in zip(r, f):
        an, bn = np.asarray(a, np.float32), np.asarray(bb, np.float32)
        denom = np.abs(an).max() + 1e-6
        assert np.abs(an - bn).max() / denom < 0.05


def test_bwd_bf16_and_lowmem_close():
    cfg = reduce_for_smoke(ARCHS["mamba2-2.7b"])
    params, _ = init_params(cfg, BASE_RT, jax.random.PRNGKey(0))
    b = _batch(cfg)
    l_ref = float(loss_fn(params, cfg, BASE_RT, b))
    L.set_lowmem_norm(True)
    M2.set_ssd_bf16(True)
    rt2 = dataclasses.replace(BASE_RT, bwd_bf16=True)
    l_opt, g_opt = jax.value_and_grad(lambda p: loss_fn(p, cfg, rt2, b))(params)
    # forward identical up to bf16 rounding of norm/rope/ssd paths
    assert abs(l_ref - float(l_opt)) / (abs(l_ref) + 1e-9) < 2e-2
    assert all(np.isfinite(np.asarray(g, np.float32)).all()
               for g in jax.tree.leaves(g_opt))


def test_grad_accum_matches_single_batch():
    from repro.launch.steps import make_train_step_fn
    from repro.optim import AdamWConfig

    cfg = reduce_for_smoke(ARCHS["phi3-mini-3.8b"])
    params, _ = init_params(cfg, BASE_RT, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    from repro.optim import init_opt_state

    b = _batch(cfg)
    s1 = make_train_step_fn(cfg, BASE_RT, opt_cfg)
    rt2 = dataclasses.replace(BASE_RT, grad_accum=2)
    s2 = make_train_step_fn(cfg, rt2, opt_cfg)
    p1, _, l1 = s1(params, init_opt_state(params, opt_cfg), b)
    p2, _, l2 = s2(params, init_opt_state(params, opt_cfg), b)
    assert abs(float(l1) - float(l2)) < 5e-3
    for a, bb in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(bb, np.float32), atol=1e-4)
