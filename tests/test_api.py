"""Plan/execute API contract (repro.api).

Four guarantees pinned here:

  1. plan() VALIDATES at construction time: bad families, levels a family
     does not support, misaligned tn/td tiles, inconsistent
     batch/lengths/device combinations all raise from plan() itself —
     nothing survives to launch time;
  2. the typed surface is the same function as the deprecated shims:
     for every family and every dataflow level, BoosterSession.run /
     run_plan produce outputs BIT-IDENTICAL to run_stream(mode=...), and
     run_plan_batched to run_batched(mode=...);
  3. ragged T is exact: a batched v3 launch over unequal ``lengths``
     equals each stream's solo run sliced to its true length — outputs
     AND final recurrent states (no leakage from the dead tail slots);
  4. DeviceSpec sharding is exact: the shard_map'd batched launch over
     fake CPU devices is bit-identical to the unsharded launch
     (subprocess, like tests/test_multidevice.py).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

import harness
from repro import api
from repro.configs.dgnn import DGNN_CONFIGS
from repro.core import run_plan, run_stream
from repro.graph import pow2_target, round_up

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------- plan validation ----

def test_plan_defaults_from_config():
    for name, cfg in DGNN_CONFIGS.items():
        p = api.plan(cfg)
        assert p.family == api.family_for(cfg)
        assert p.level == cfg.dataflow
        assert p.td == cfg.stream_td


@pytest.mark.parametrize("kwargs,match", [
    (dict(family="gat"), "unknown stream-engine family"),
    (dict(family="gcrn", level="v1"), "not defined for family"),
    (dict(family="evolve", level="v2"), "not defined for family"),
    (dict(family="gcrn", level="warp"), "not defined for family"),
    (dict(family="gcrn", tn=0), "node tile"),
    (dict(family="gcrn", tn=12), "node tile"),
    (dict(family="gcrn", td=12), "state-feature block"),
    (dict(family="gcrn", td=-8), "state-feature block"),
    (dict(family="gcrn", batch=0), "batch"),
    (dict(family="gcrn", batch=2, lengths=(3,)), "lengths has 1 entries"),
    (dict(family="gcrn", batch=2, lengths=(0, 0)), "all zero"),
    (dict(family="stacked", level="v2", batch=2, lengths=(3, 2)),
     "stream-engine .v3. capability"),
    (dict(family="gcrn", batch=4, device=api.DeviceSpec(3)),
     "not divisible"),
    (dict(family="stacked", level="v2", batch=2,
          device=api.DeviceSpec(2)), "batch grid axis"),
    (dict(family="gcrn", stream_chunk=0), "stream_chunk"),
    (dict(family="gcrn", buckets=((64, 256, 8), (32, 512, 16))),
     "smallest-first"),
    (dict(family="gcrn", promote_buckets=1.5), "bucketed padding"),
    (dict(family="gcrn", buckets=((64, 256, 8),), promote_buckets=1.5,
          promotion_guard="psychic"), "promotion_guard"),
    (dict(family="gcrn", buckets=((64, 256, 8),),
          promotion_guard="measured"), "without"),
    # --- HBM-paged state residency (the paging PR's validation rules) ---
    (dict(family="gcrn", state_residency="ddr"),
     "state_residency='ddr': expected one of"),
    (dict(family="static_gcn", td=8, state_residency="hbm_paged"),
     "undefined for static family 'static_gcn': zero StateDefs"),
    (dict(family="gcrn", level="baseline", td=8,
          state_residency="hbm_paged"),
     "stream-engine .v3. capability"),
    (dict(family="gcrn", td=None, state_residency="hbm_paged"),
     "requires td blocking"),
    (dict(family="gcrn", td=8, buffer_depth=2),
     "buffer_depth=2 requires state_residency='hbm_paged'"),
    (dict(family="gcrn", td=8, state_residency="hbm_paged",
          buffer_depth=3), r"buffer_depth must be one of \(1, 2, 4\)"),
])
def test_plan_invalid_raises_at_construction(kwargs, match):
    with pytest.raises(ValueError, match=match):
        api.plan(**kwargs)


def test_plan_device_over_host_capacity_raises():
    n = jax.device_count() + 1
    with pytest.raises(ValueError, match="devices"):
        api.plan(family="gcrn", batch=2 * n, device=api.DeviceSpec(n))


def test_plan_is_frozen_and_serializable():
    p = api.plan(family="gcrn", level="v3", batch=2, lengths=(3, 2))
    with pytest.raises(Exception):
        p.level = "v2"
    d = p.as_dict()
    assert d["family"] == "gcrn" and d["lengths"] == (3, 2)
    assert d["device"] == {"n_devices": 1, "axis": "data"}


def test_padding_target_helpers_single_copy():
    """The pow2/round-up rounding lives in graph/padding.py only: serve
    and the kernel wrappers import it (the dedup satellite)."""
    assert [pow2_target(x) for x in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    # a satisfiable cap clamps (result stays >= real) ...
    assert pow2_target(5, cap=6) == 6
    assert pow2_target(8, cap=8) == 8
    # ... but an unsatisfiable cap raises instead of silently returning a
    # target SMALLER than the real length (the truncation bug)
    with pytest.raises(ValueError, match="cap=8 < real=9"):
        pow2_target(9, cap=8)
    assert round_up(1, 32) == 32 and round_up(64, 32) == 64
    from repro.kernels import ops, stream_fused
    from repro.graph import padding

    assert stream_fused._round_up is padding.round_up
    assert ops._pad_rows(9, 8) == 16


# ----------------------------------- session == deprecated shims ----

@pytest.mark.parametrize("name", sorted(DGNN_CONFIGS))
def test_session_levels_match_mode_shims(name):
    """Every dataflow level of every family through BoosterSession is
    bit-identical to the deprecated run_stream(mode=...) shim."""
    case = harness.make_case(name, seed=7, T=3, B=1)
    sT = case.stacked[0]
    for level in harness.MODES[name]:
        st = case.model.init_state(case.params, mode=level)
        want_state, want = run_stream(case.model, case.params, st, sT,
                                      mode=level)
        session = api.BoosterSession(
            case.cfg, api.plan(case.cfg, level=level),
            n_global=case.n_global, params=case.params)
        got = session.run(sT)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"{name} level={level}")
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), session.state, want_state)


def test_session_run_advances_state():
    """run() is streaming: two chunks through one session == one long
    stream through the shim."""
    case = harness.make_case("gcrn-m2", seed=9, T=4, B=1)
    sT = case.stacked[0]
    first = jax.tree.map(lambda a: a[:2], sT)
    rest = jax.tree.map(lambda a: a[2:], sT)
    session = api.BoosterSession(case.cfg, api.plan(case.cfg, level="v3"),
                                 n_global=case.n_global, params=case.params)
    o1, o2 = session.run(first), session.run(rest)
    st = case.model.init_state(case.params, mode="v3")
    _, want = run_stream(case.model, case.params, st, sT, mode="v3")
    np.testing.assert_allclose(
        np.concatenate([np.asarray(o1), np.asarray(o2)]), np.asarray(want),
        atol=1e-5)


# ------------------------------------------------------- ragged T ----

@pytest.mark.parametrize("name", sorted(DGNN_CONFIGS))
def test_ragged_batched_launch_matches_solo_runs(name):
    """One batched v3 launch over UNEQUAL lengths == per-stream solo runs
    sliced to each stream's true length, including final states."""
    case = harness.make_case(name, seed=5, T=5, B=3)
    lens = [5, 3, 2]
    ragged = [jax.tree.map(lambda a, t=t: a[:t], s)
              for s, t in zip(case.stacked, lens)]
    session = api.BoosterSession(case.cfg, api.plan(case.cfg, level="v3"),
                                 n_global=case.n_global, params=case.params)
    states, outs = session.run_batched(ragged)
    p = api.plan(case.cfg, level="v3")
    for b, (stream, t) in enumerate(zip(ragged, lens)):
        st = case.model.init_state(case.params, mode="v3")
        want_state, want = run_plan(case.model, case.params, st, stream, p)
        assert outs[b].shape[0] == t
        np.testing.assert_allclose(outs[b], np.asarray(want), atol=3e-4,
                                   err_msg=f"{name} ragged row {b}")
        jax.tree.map(lambda a, w, b=b: np.testing.assert_allclose(
            np.asarray(a)[b], np.asarray(w), atol=3e-4,
            err_msg=f"{name} ragged state row {b}"), states, want_state)


def test_ragged_plan_rejected_by_solo_executors():
    """lengths is a batched-launch capability: the solo executor rejects a
    ragged plan loudly instead of silently running the dead tail, and
    run_arrays honors lengths even at batch=1 (via the batched entry)."""
    from repro.kernels import ops

    case = harness.make_case("gcrn-m2", seed=3, T=3, B=1)
    p = api.plan(case.cfg, level="v3", batch=1, lengths=(2,))
    with pytest.raises(ValueError, match="batched"):
        run_plan(case.model, case.params,
                 case.model.init_state(case.params, mode="v3"),
                 case.stacked[0], p)
    args, _, _ = harness.stream_kernel_case("gcrn", seed=3, B=1)
    pk = api.plan(family="gcrn", level="v3", tn=32, batch=1, lengths=(2,))
    got = api.run_arrays(pk, *args)
    want = ops.stream_steps_batched("gcrn", *args, tn=32,
                                    lengths=np.asarray([2]))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))


def test_serve_rejects_sharded_plan_and_requires_n_global():
    """The serving engine picks its own launch batch sizes, so a
    DeviceSpec-sharded plan fails at construction (not mid-serve); the
    deprecated config surface still requires n_global."""
    from repro.serve import SnapshotServer

    cfg = DGNN_CONFIGS["gcrn-m2"]
    ft = np.zeros((8, cfg.in_dim), np.float32)
    with pytest.raises(ValueError, match="n_global"):
        SnapshotServer(cfg, ft)
    if jax.device_count() >= 2:  # sharded plan only constructible then
        p = api.plan(cfg, level="v3", batch=2, device=api.DeviceSpec(2))
        with pytest.raises(ValueError, match="does not shard"):
            SnapshotServer(session=api.BoosterSession(
                cfg, p, n_global=8, feat_table=ft))


def test_plan_tn_reaches_the_engine(monkeypatch):
    """plan.tn is threaded through run_plan -> model -> ops (it used to be
    validated but silently dropped in favour of the default 128)."""
    from repro.kernels import ops

    seen = {}
    orig = ops.stream_steps

    def probe(family, *a, tn=128, **k):
        seen["tn"] = tn
        return orig(family, *a, tn=tn, **k)

    monkeypatch.setattr(ops, "stream_steps", probe)
    case = harness.make_case("gcrn-m2", seed=3, T=3, B=1)
    p = api.plan(case.cfg, level="v3", tn=32)
    run_plan(case.model, case.params,
             case.model.init_state(case.params, mode="v3"),
             case.stacked[0], p)
    assert seen["tn"] == 32


def test_ragged_kernel_zero_length_row_is_noop():
    """A length-0 row (the serve batch-padding case) leaves its state
    untouched and its outputs all-zero."""
    from repro.kernels import ops

    args, oracle, _ = harness.stream_kernel_case("gcrn", seed=13, B=2)
    lens = np.asarray([args[0].shape[1], 0], np.int32)
    outs, hT, cT = ops.stream_steps_batched("gcrn", *args, tn=32,
                                            lengths=lens)
    assert np.asarray(outs)[1].max() == 0
    np.testing.assert_array_equal(np.asarray(hT)[1], np.asarray(args[6][1]))
    np.testing.assert_array_equal(np.asarray(cT)[1], np.asarray(args[7][1]))


# ------------------------------------------------ device sharding ----

def _run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), os.path.join(REPO, "tests")])
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_device_spec_sharded_launch_identical():
    """DeviceSpec(n_devices>1) on fake CPU devices: the shard_map'd
    batched launch (Pallas interpret AND force-ref oracle) is
    bit-identical to the unsharded launch, for every family; a plan
    carrying the DeviceSpec validates and executes."""
    out = _run_subprocess("""
        import numpy as np
        import harness
        from repro import api
        from repro.kernels import ops
        dev = api.DeviceSpec(n_devices=2)
        for family in sorted(ops.stream_families()):
            args, oracle, _ = harness.stream_kernel_case(family, seed=3, B=4)
            base = ops.stream_steps_batched(family, *args, tn=32)
            p = api.plan(family=family, level="v3", batch=4, tn=32,
                         device=dev)
            shard = api.run_arrays(p, *args)
            for g, w in zip(shard, base):
                gs = g if isinstance(g, (tuple, list)) else (g,)
                ws = w if isinstance(w, (tuple, list)) else (w,)
                for gg, ww in zip(gs, ws):
                    np.testing.assert_array_equal(np.asarray(gg),
                                                  np.asarray(ww))
            ref = api.run_arrays(p, *args, force_ref=True)
            np.testing.assert_allclose(np.asarray(ref[0]),
                                       np.asarray(oracle(*args)[0]),
                                       atol=1e-5)
            print('OK', family)
    """)
    assert out.count("OK") == 5
