"""Differential harness: every dataflow engine computes the same function.

Random streams (ragged n per step, odd T) for all three model families,
asserting baseline ≡ o1 ≡ v1/v2 ≡ v3 ≡ batched-v3-row-sliced in one place
(tests/harness.py). Kernels run in interpret mode on CPU, so this file IS
the kernel-equivalence coverage of the CI fast lane.
"""
import numpy as np
import pytest

import harness
from repro.graph import DEFAULT_BUCKETS, max_in_degree, renumber_and_normalize


def test_engines_equivalent_random_streams(stream_case):
    """v1 ≡ v2 ≡ v3 ≡ batched-v3 row-sliced for each family (tentpole
    acceptance: batched V3 is bit-close to running each stream alone)."""
    harness.assert_engines_equivalent(stream_case)


@pytest.mark.parametrize("family", sorted(harness.DGNN_CONFIGS))
def test_engines_equivalent_dblocked(family):
    """D-blocked stream engine ≡ XLA baseline, end to end: hidden=32 with
    stream_td=16 forces d//td == 2 for every family, and the full
    differential contract (all engines, batched + solo, outputs AND final
    recurrent states) must still hold with the state stores streamed in
    column tiles."""
    case = harness.make_case(family, seed=13, T=4, B=2, stream_td=16)
    harness.assert_engines_equivalent(case)


def test_batched_v3_streams_are_independent(stream_case):
    """Permuting the batch rows permutes the outputs identically — no
    cross-stream leakage through the serially reused VMEM state scratch."""
    import jax
    import jax.numpy as jnp

    from repro.core import init_states_batched, run_batched

    case = stream_case
    B = len(case.stacked)
    perm = list(range(1, B)) + [0]
    sTB = jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *case.stacked)
    sTB_p = jax.tree.map(
        lambda *xs: jnp.stack(xs, axis=1), *[case.stacked[i] for i in perm])
    states = init_states_batched(case.model, case.params, B, mode="v3")
    _, o = run_batched(case.model, case.params, states, sTB, mode="v3")
    _, o_p = run_batched(case.model, case.params, states, sTB_p, mode="v3")
    for row, src in enumerate(perm):
        np.testing.assert_allclose(np.asarray(o_p)[:, row],
                                   np.asarray(o)[:, src], atol=1e-6)


@pytest.mark.parametrize("seed", [3, 7])
def test_pad_unpad_roundtrip_random_graphs(seed):
    """Plain (no-hypothesis) edition of the padding round-trip invariants,
    so the contract is exercised even where hypothesis is absent."""
    rng = np.random.default_rng(seed)
    for snap in harness.random_coo_stream(rng, T=4, n_pool=120, avg_edges=90,
                                          edge_dim=4):
        ls = renumber_and_normalize(snap)
        bucket = (max(ls.n_nodes, 128), max(ls.src.shape[0], 512),
                  max(max_in_degree(ls), 8))
        harness.check_pad_unpad_roundtrip(ls, rng.normal(
            size=(200, 6)).astype(np.float32), bucket)


def test_choose_bucket_invariants_plain():
    """Plain edition of the bucket-choice invariants on the default chain."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(1, 640))
        e = int(rng.integers(1, 4096))
        k = int(rng.integers(1, 96))
        harness.check_choose_bucket_smallest_fit(n, e, k, DEFAULT_BUCKETS)
    dims = [(int(rng.integers(1, 640)), int(rng.integers(1, 4096)),
             int(rng.integers(1, 96))) for _ in range(6)]
    harness.check_bucket_monotone(dims, DEFAULT_BUCKETS)


def test_evolvegcn_modes_identical_with_empty_snapshot():
    """A genuinely EMPTY (all-padding) snapshot inside a stream is a
    no-op in every engine: zero outputs at that step and frozen evolving
    weights — so baseline/o1/v1/v3 stay identical even though only the
    stream kernel sees an explicit live flag (the per-step engines gate
    the matrix-GRU on n_nodes > 0 to match)."""
    import jax

    from repro.graph import empty_like_padded

    case = harness.make_case("evolvegcn", seed=5, T=4, B=1)
    sT = case.stacked[0]
    empty = empty_like_padded(jax.tree.map(lambda a: a[0], sT))
    with_hole = jax.tree.map(
        lambda a, e: np.concatenate([a[:2], np.asarray(e)[None], a[2:]],
                                    axis=0), sT, empty)
    outs, states = harness.run_all_modes(case.model, case.params, with_hole,
                                         harness.MODES["evolvegcn"])
    harness.assert_modes_match(outs, atol=3e-4, label="evolvegcn empty-step")
    harness.assert_final_states_match(case, states, atol=3e-4,
                                      label="evolvegcn empty-step")
    assert np.abs(outs["baseline"][2]).max() == 0.0  # the hole is a no-op
