"""Multi-device behaviors (shard_map MoE, compressed psum, mini dry-run).

These need >1 XLA device, so each runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (per the assignment,
the main test process must keep seeing 1 device).
"""

import pytest as _pytest

pytestmark = _pytest.mark.slow  # LM-side e2e: excluded from the fast CI lane

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_moe_ep_equals_dense():
    """shard_map EP MoE == dense reference (same routing, ample capacity)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import ARCHS, reduce_for_smoke
        from repro.distributed.api import sharding_ctx
        from repro.nn import moe as M
        cfg = reduce_for_smoke(ARCHS['granite-moe-3b-a800m'])
        cfg = dataclasses.replace(cfg, n_experts=4, top_k=2)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        p, _ = M.init_moe(jax.random.PRNGKey(0), cfg, tp=4)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
        dense = M.moe_block(p, cfg, x, impl='dense')
        with sharding_ctx(mesh):
            ep = M.moe_block(p, cfg, x, impl='ep', cf_send=4.0, cf_local=4.0)
        d, e = np.asarray(dense, np.float32), np.asarray(ep, np.float32)
        err = np.abs(d - e).max() / (np.abs(d).max() + 1e-9)
        assert err < 2e-2, err
        print('OK', err)
    """)
    assert "OK" in out


def test_compressed_psum_error_feedback():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        mesh = jax.make_mesh((8,), ('data',))
        def body(x, r):
            return compressed_psum(x, r, 'data')
        f = jax.jit(shard_map(body, mesh=mesh,
                    in_specs=(P('data'), P('data')), out_specs=(P('data'), P('data')),
                    check_rep=False))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 1024)).astype(np.float32))
        r = jnp.zeros_like(x)
        exact = np.asarray(x).mean(axis=0)
        # error feedback: averaged over steps, compressed mean -> exact mean
        acc = np.zeros(1024, np.float32)
        for i in range(8):
            y, r = f(x, r)
            acc += np.asarray(y[0])
        err = np.abs(acc / 8 - exact).max() / (np.abs(exact).max() + 1e-9)
        assert err < 0.05, err
        print('OK', err)
    """)
    assert "OK" in out


def test_mini_dryrun_8dev_mesh():
    """End-to-end dry-run machinery on a small mesh: lower+compile a reduced
    arch for train and decode, roofline terms finite."""
    out = _run("""
        import jax, dataclasses, numpy as np
        from repro.configs import ARCHS, SHAPES, reduce_for_smoke
        from repro.configs.base import ShapeConfig
        from repro.distributed.api import sharding_ctx, tree_shardings, DEFAULT_RULES
        from repro.launch import steps as S
        from repro.models import RuntimeConfig
        from repro.optim import AdamWConfig
        from repro.roofline import collective_bytes, cost_analysis_dict
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        cfg = reduce_for_smoke(ARCHS['qwen3-32b'])
        rt = RuntimeConfig(tp=4, scan_layers=False, attn_chunk=64, moe_impl='ep', loss_chunk=16)
        shape = ShapeConfig('mini_train', 64, 8, 'train')
        opt = AdamWConfig()
        rules = dict(DEFAULT_RULES); rules['embed_fsdp'] = None
        with sharding_ctx(mesh, rules):
            pshapes, paxes = S.abstract_params(cfg, rt)
            pshard = tree_shardings(pshapes, paxes, mesh)
            bspecs, baxes = S.batch_specs(cfg, shape)
            bshard = tree_shardings(bspecs, baxes, mesh)
            oshapes, oaxes = S.abstract_opt_state(pshapes, paxes, opt)
            oshard = tree_shardings(oshapes, oaxes, mesh)
            fn = S.make_train_step_fn(cfg, rt, opt)
            c = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                        donate_argnums=(0,1)).lower(pshapes, oshapes, bspecs).compile()
            ca = cost_analysis_dict(c)
            st = collective_bytes(c.as_text())
            assert ca['flops'] > 0
            assert st.total_bytes > 0, 'expected collectives on a 2x4 mesh'
            # decode as well
            dshape = ShapeConfig('mini_dec', 64, 8, 'decode')
            cshapes, caxes = S.abstract_caches(cfg, rt, 8, 64)
            cshard = tree_shardings(cshapes, caxes, mesh)
            dfn = S.make_decode_fn(cfg, rt)
            dc = jax.jit(dfn, in_shardings=(pshard, cshard, bshard if False else tree_shardings(*S.batch_specs(cfg, dshape), mesh)),
                         donate_argnums=(1,)).lower(pshapes, cshapes, S.batch_specs(cfg, dshape)[0]).compile()
            assert cost_analysis_dict(dc)['flops'] > 0
        print('OK')
    """)
    assert "OK" in out


def test_pipeline_parallelism_matches_sequential():
    """GPipe pipeline over a 4-stage axis == sequential stage stack."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline, reference_stack
        mesh = jax.make_mesh((4, 2), ('stage', 'data'))
        S, M, MB, D = 4, 6, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        w = jax.random.normal(ks[0], (S, D, D)) * (1.0 / np.sqrt(D))
        b = jax.random.normal(ks[1], (S, D)) * 0.1
        xs = jax.random.normal(ks[2], (M, MB, D))
        params = {'w': w, 'b': b}
        def block(p, x):
            return jnp.tanh(x @ p['w'] + p['b'])
        run = pipeline(block, mesh, n_stages=S, n_micro=M)
        got = run(params, xs)
        want = reference_stack(block, params, xs)
        err = float(jnp.abs(got - want).max())
        assert err < 1e-5, err
        print('OK', err)
    """)
    assert "OK" in out
