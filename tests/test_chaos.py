"""Chaos suite: the serve engine's fault-isolation contract, driven by the
deterministic injection harness (serve/faults.py).

What these tests pin (docs/serve_robustness.md):

  * every fault site (preprocess / bucket / launch / evolve) x every DGNN
    family: the targeted tenant is quarantined, the SURVIVING tenants get
    outputs and final recurrent state BIT-IDENTICAL to a fault-free run;
  * a transient launch fault is retried from the rolled-back checkpoint —
    on EvolveGCN the evolving weights advance exactly once per live
    snapshot (final state equals the fault-free run exactly);
  * a mid-commit ("evolve"-site) fault leaves a partial state write that
    rollback undoes before the replay;
  * the degradation ladder: batched -> solo (a poisoned co-batch) ->
    pure-XLA oracle (a poisoned kernel path), still serving results;
  * launch deadlines: an overdue launch is discarded, counted, retried;
  * shutdown leaves no producer threads behind, on success AND failure;
  * malformed snapshots are rejected at the serve boundary with typed,
    tenant-attributed errors.

``CHAOS_SEED`` (env, default 0) seeds the synthetic streams and the
FaultPlans, so CI can sweep seeds while any single failure reproduces
from its seed alone.
"""
import os
import threading

import jax
import numpy as np
import pytest

from repro import api
from repro.configs.dgnn import DGNNConfig
from repro.graph.coo import COOSnapshot
from repro.graph.padding import bucket_cost
from repro.serve import (
    FaultPlan,
    FaultSpec,
    InjectedFault,
    SnapshotServer,
    SnapshotValidationError,
    validate_snapshot,
)

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))
N_GLOBAL = 32
# streams are generated to always fit the SMALL bucket (<= 6 raw edges,
# distinct dst ids; normalization symmetrizes + adds self-loops, so
# e <= 2*6 + n <= 24), so every chunk of every tenant co-buckets and each
# round produces exactly one batched launch — probe occurrence numbering
# stays deterministic.
BUCKETS = ((16, 32, 8), (32, 64, 8))
SIDS = ("a", "b", "c")
N_SNAP = 4
CHUNK = 2

FAMILIES = {
    "gcrn": DGNNConfig(name="chaos-gcrn", dgnn_type="integrated", gnn="gcn",
                       rnn="lstm", dataflow="v3", in_dim=4, hidden=8,
                       out_dim=4, n_gnn_layers=1, edge_dim=2),
    "stacked": DGNNConfig(name="chaos-stacked", dgnn_type="stacked",
                          gnn="gcn", rnn="gru", dataflow="v3", in_dim=4,
                          hidden=8, out_dim=4, n_gnn_layers=1, edge_dim=2),
    "evolve": DGNNConfig(name="chaos-evolve", dgnn_type="weights_evolved",
                         gnn="gcn", rnn="gru", dataflow="v3", in_dim=4,
                         hidden=8, out_dim=4, n_gnn_layers=1, edge_dim=2),
}

_FEAT = np.asarray(
    np.random.default_rng(CHAOS_SEED).normal(size=(N_GLOBAL, 4)), np.float32)


def _make_snaps(stream_ix, n_snap=N_SNAP):
    r = np.random.default_rng(CHAOS_SEED * 7919 + stream_ix)
    out = []
    for t in range(n_snap):
        e = int(r.integers(3, 7))
        src = r.integers(0, N_GLOBAL, size=e)
        dst = r.choice(N_GLOBAL, size=e, replace=False)  # in-degree 1
        ef = np.asarray(r.normal(size=(e, 2)), np.float32)
        out.append(COOSnapshot(src=src, dst=dst, edge_feat=ef, t_index=t))
    return out


def _streams():
    return {sid: _make_snaps(i) for i, sid in enumerate(SIDS)}


def _server(family, level="v3", **plan_kw):
    cfg = FAMILIES[family]
    plan = api.plan(cfg, level=level, buckets=BUCKETS, stream_chunk=CHUNK,
                    **plan_kw)
    sess = api.BoosterSession(cfg, plan, n_global=N_GLOBAL, feat_table=_FEAT)
    return SnapshotServer(session=sess)


def _init(srv):
    params, _ = srv.init(jax.random.PRNGKey(CHAOS_SEED))
    states = {sid: srv.model.init_state(params, mode=srv.mode)
              for sid in SIDS}
    return params, states


def _assert_tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_no_serve_threads():
    leaked = [t.name for t in threading.enumerate()
              if t.name.startswith("dgnn-serve")]
    assert not leaked, f"leaked serve threads: {leaked}"


@pytest.fixture(scope="module")
def baseline():
    """Fault-free run_multi per family: the oracle the chaos runs'
    survivors must match bit-for-bit."""
    res = {}
    for fam in sorted(FAMILIES):
        srv = _server(fam)
        params, states = _init(srv)
        st, outs, stats = srv.run_multi(params, states, _streams())
        assert not stats.tenant_errors
        assert all(len(v) == N_SNAP for v in outs.values())
        res[fam] = (st, outs)
    _assert_no_serve_threads()
    return res


# --------------------------------------------- site x family isolation ----


@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("site", ["preprocess", "bucket", "launch", "evolve"])
def test_fault_site_isolates_tenant(family, site, baseline):
    """Every fault site x every family: tenant 'b' is quarantined with the
    injected error attributed to it; the survivors' outputs AND final
    recurrent states are bit-identical to the fault-free run."""
    if site in ("preprocess", "bucket"):
        # fires on b's 3rd snapshot: one full chunk of b is served first,
        # proving partial results survive the quarantine
        spec = FaultSpec(site=site, tenant="b", index=2)
    else:
        # persistent: every launch/commit involving b fails, so retrying
        # the healthy co-batch WITHOUT b is the only way forward
        spec = FaultSpec(site=site, tenant="b", index=0, count=99)
    srv = _server(family, supervision="isolate",
                  fault_plan=FaultPlan(specs=(spec,), seed=CHAOS_SEED))
    params, states = _init(srv)
    st, outs, stats = srv.run_multi(params, states, _streams())
    base_st, base_outs = baseline[family]
    assert isinstance(stats.tenants["b"].error, InjectedFault)
    assert stats.tenants["b"].failed_site == site
    assert len(outs["b"]) < N_SNAP
    for sid in ("a", "c"):
        assert stats.tenants[sid].ok
        assert len(outs[sid]) == N_SNAP
        for got, want in zip(outs[sid], base_outs[sid]):
            np.testing.assert_array_equal(got, want)
        _assert_tree_equal(st[sid], base_st[sid])
    _assert_no_serve_threads()


# ------------------------------------------------- retry + rollback ----


def test_transient_launch_fault_retried_evolvegcn(baseline):
    """A transient launch failure on EvolveGCN is survived by one retry
    from the rolled-back checkpoint: no tenant is quarantined and the
    final evolving weights equal the fault-free run EXACTLY — the weights
    advanced once per live snapshot, never twice."""
    fp = FaultPlan(specs=(FaultSpec(site="launch", index=0, count=1),),
                   seed=CHAOS_SEED)
    srv = _server("evolve", supervision="isolate", max_retries=2,
                  retry_backoff_ms=1.0, fault_plan=fp)
    params, states = _init(srv)
    st, outs, stats = srv.run_multi(params, states, _streams())
    base_st, base_outs = baseline["evolve"]
    assert not stats.tenant_errors
    assert stats.retries >= 1 and stats.rollbacks >= 1
    for sid in SIDS:
        for got, want in zip(outs[sid], base_outs[sid]):
            np.testing.assert_array_equal(got, want)
        _assert_tree_equal(st[sid], base_st[sid])


def test_midcommit_evolve_fault_rolls_back_partial_write(baseline):
    """An 'evolve'-site fault fires INSIDE the commit loop, after a
    co-tenant's state was already written: rollback must undo the partial
    commit so the replay serves every tenant exactly once."""
    fp = FaultPlan(
        specs=(FaultSpec(site="evolve", tenant="b", index=0, count=1),),
        seed=CHAOS_SEED)
    srv = _server("evolve", supervision="isolate", max_retries=1,
                  retry_backoff_ms=1.0, fault_plan=fp)
    params, states = _init(srv)
    st, outs, stats = srv.run_multi(params, states, _streams())
    base_st, base_outs = baseline["evolve"]
    assert not stats.tenant_errors
    assert stats.rollbacks >= 1
    for sid in SIDS:
        assert len(outs[sid]) == N_SNAP
        for got, want in zip(outs[sid], base_outs[sid]):
            np.testing.assert_array_equal(got, want)
        _assert_tree_equal(st[sid], base_st[sid])


# ------------------------------------------------- degradation ladder ----


def test_degrade_to_solo_launches(baseline):
    """A fault scoped to BATCHED launches (a poisoned co-batch) walks the
    ladder to solo launches: every tenant is still served, bit-identical,
    with the degradation visible in the stats."""
    fp = FaultPlan(
        specs=(FaultSpec(site="launch", scope="batched", index=0, count=99),),
        seed=CHAOS_SEED)
    srv = _server("gcrn", supervision="isolate", degrade=True, fault_plan=fp)
    params, states = _init(srv)
    st, outs, stats = srv.run_multi(params, states, _streams())
    base_st, base_outs = baseline["gcrn"]
    assert not stats.tenant_errors
    assert stats.degraded_launches >= len(SIDS)
    for sid in SIDS:
        for got, want in zip(outs[sid], base_outs[sid]):
            np.testing.assert_array_equal(got, want)
        _assert_tree_equal(st[sid], base_st[sid])


def test_degrade_to_xla_oracle(baseline):
    """A fault scoped to the KERNEL path (batched AND solo launches fail)
    degrades to the pure-XLA oracle via the force-ref gate: results keep
    flowing, numerically equal to the kernel path within float tolerance."""
    fp = FaultPlan(
        specs=(FaultSpec(site="launch", scope="kernel", index=0, count=999),),
        seed=CHAOS_SEED)
    srv = _server("gcrn", supervision="isolate", degrade=True, fault_plan=fp)
    params, states = _init(srv)
    st, outs, stats = srv.run_multi(params, states, _streams())
    base_st, base_outs = baseline["gcrn"]
    assert not stats.tenant_errors
    assert stats.degraded_launches >= len(SIDS)
    for sid in SIDS:
        assert len(outs[sid]) == N_SNAP
        for got, want in zip(outs[sid], base_outs[sid]):
            np.testing.assert_allclose(got, want, atol=1e-5)
        for x, y in zip(jax.tree_util.tree_leaves(st[sid]),
                        jax.tree_util.tree_leaves(base_st[sid])):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-5)


# ------------------------------------------------------- deadlines ----


def test_launch_timeout_discards_and_retries(baseline):
    """A delay injected into the SECOND launch (the first is exempt — it
    pays compilation) trips the plan deadline: the overdue result is
    discarded, counted, and the chunk is replayed to completion."""
    fp = FaultPlan(
        specs=(FaultSpec(site="launch", index=1, count=1, delay_ms=2000.0),),
        seed=CHAOS_SEED)
    srv = _server("gcrn", supervision="isolate", max_retries=3,
                  retry_backoff_ms=1.0, launch_timeout_ms=1000.0,
                  fault_plan=fp)
    params, states = _init(srv)
    st, outs, stats = srv.run_multi(params, states, _streams())
    base_st, base_outs = baseline["gcrn"]
    assert not stats.tenant_errors
    assert stats.timeouts >= 1
    assert stats.retries >= 1
    for sid in SIDS:
        for got, want in zip(outs[sid], base_outs[sid]):
            np.testing.assert_array_equal(got, want)
        _assert_tree_equal(st[sid], base_st[sid])


# ---------------------------------------------- single-tenant + non-v3 ----


def test_run_isolate_returns_partial_outputs():
    """Single-tenant ``run`` under supervision="isolate": a mid-stream
    fault stops the stream, keeps the already-committed chunk, and records
    the error instead of raising."""
    srv = _server("gcrn")
    params, _ = _init(srv)
    state = srv.model.init_state(params, mode="v3")
    base_state, base_outs, _ = srv.run(params, state, _make_snaps(0))

    fp = FaultPlan(
        specs=(FaultSpec(site="preprocess", tenant="stream", index=2),),
        seed=CHAOS_SEED)
    srv_f = _server("gcrn", supervision="isolate", fault_plan=fp)
    params_f, _ = _init(srv_f)
    state = srv_f.model.init_state(params_f, mode="v3")
    _, outs, stats = srv_f.run(params_f, state, _make_snaps(0))
    assert len(outs) == CHUNK  # first chunk committed before the fault
    assert isinstance(stats.tenants["stream"].error, InjectedFault)
    for got, want in zip(outs, base_outs[:CHUNK]):
        np.testing.assert_array_equal(got, want)
    _assert_no_serve_threads()


def test_run_multi_isolate_nonstream_mode():
    """The per-snapshot (non-v3) device loop honors the same isolation
    contract: a preprocess fault quarantines its tenant, survivors match
    the fault-free run bit-for-bit."""
    srv = _server("gcrn", level="o1")
    params, states = _init(srv)
    _, base_outs, base_stats = srv.run_multi(params, states, _streams())
    assert not base_stats.tenant_errors

    fp = FaultPlan(
        specs=(FaultSpec(site="preprocess", tenant="b", index=2),),
        seed=CHAOS_SEED)
    srv_f = _server("gcrn", level="o1", supervision="isolate", fault_plan=fp)
    params, states = _init(srv_f)
    _, outs, stats = srv_f.run_multi(params, states, _streams())
    assert isinstance(stats.tenants["b"].error, InjectedFault)
    assert len(outs["b"]) < N_SNAP
    for sid in ("a", "c"):
        assert len(outs[sid]) == N_SNAP
        for got, want in zip(outs[sid], base_outs[sid]):
            np.testing.assert_array_equal(got, want)
    _assert_no_serve_threads()


# ------------------------------------------------- shutdown hygiene ----


def test_shutdown_leaves_no_threads_on_strict_failure():
    """The strict path raises — but only AFTER a clean shutdown: no
    producer thread outlives run_multi, queues are drained."""
    fp = FaultPlan(
        specs=(FaultSpec(site="preprocess", tenant="b", index=0),),
        seed=CHAOS_SEED)
    srv = _server("gcrn", fault_plan=fp)  # supervision="strict" default
    params, states = _init(srv)
    with pytest.raises(InjectedFault):
        srv.run_multi(params, states, _streams())
    _assert_no_serve_threads()


# --------------------------------------------- boundary validation ----


def _bad_snap(kind):
    src = np.asarray([1, 2]), np.asarray([3, 4])
    if kind == "shape":
        return COOSnapshot(src=np.asarray([1, 2]), dst=np.asarray([3]),
                           edge_feat=np.ones((2, 2), np.float32), t_index=0)
    if kind == "rows":
        return COOSnapshot(src=src[0], dst=src[1],
                           edge_feat=np.ones((3, 2), np.float32), t_index=0)
    if kind == "negative":
        return COOSnapshot(src=np.asarray([-1, 2]), dst=src[1],
                           edge_feat=np.ones((2, 2), np.float32), t_index=0)
    if kind == "range":
        return COOSnapshot(src=np.asarray([1, N_GLOBAL]), dst=src[1],
                           edge_feat=np.ones((2, 2), np.float32), t_index=0)
    if kind == "nan":
        ef = np.ones((2, 2), np.float32)
        ef[1, 0] = np.nan
        return COOSnapshot(src=src[0], dst=src[1], edge_feat=ef, t_index=0)
    raise AssertionError(kind)


@pytest.mark.parametrize("kind", ["shape", "rows", "negative", "range",
                                  "nan"])
def test_validate_snapshot_rejects(kind):
    with pytest.raises(SnapshotValidationError) as ei:
        validate_snapshot(_bad_snap(kind), N_GLOBAL, tenant="t0")
    assert ei.value.tenant == "t0"
    assert ei.value.site == "preprocess"
    # a healthy snapshot passes
    validate_snapshot(_make_snaps(0)[0], N_GLOBAL)


def test_run_strict_raises_on_malformed_snapshot():
    srv = _server("gcrn")
    params, _ = _init(srv)
    state = srv.model.init_state(params, mode="v3")
    stream = _make_snaps(0)[:1] + [_bad_snap("negative")]
    with pytest.raises(SnapshotValidationError):
        srv.run(params, state, stream)
    _assert_no_serve_threads()


def test_run_multi_isolate_quarantines_malformed_tenant():
    srv = _server("gcrn", supervision="isolate")
    params, states = _init(srv)
    streams = _streams()
    streams["b"] = streams["b"][:1] + [_bad_snap("nan")]
    _, outs, stats = srv.run_multi(params, states, streams)
    err = stats.tenants["b"].error
    assert isinstance(err, SnapshotValidationError)
    assert err.tenant == "b"
    for sid in ("a", "c"):
        assert stats.tenants[sid].ok
        assert len(outs[sid]) == N_SNAP
    _assert_no_serve_threads()


# ------------------------------------------------ plan/spec validation ----


def test_fault_spec_and_plan_validation():
    with pytest.raises(ValueError):
        FaultSpec(site="nope")
    with pytest.raises(ValueError):
        FaultSpec(site="launch", scope="nope")
    with pytest.raises(ValueError):
        FaultSpec(site="evolve", scope="batched")  # scope narrows launch only
    with pytest.raises(ValueError):
        FaultSpec(site="launch", count=0)
    with pytest.raises(ValueError):
        FaultSpec(site="launch", index=-1)
    with pytest.raises(ValueError):
        FaultSpec(site="launch", delay_ms=-1.0)
    with pytest.raises(ValueError):
        FaultPlan(specs=("not a spec",))
    with pytest.raises(ValueError):
        FaultPlan(seed="zero")


def test_plan_validates_supervision_fields():
    cfg = FAMILIES["gcrn"]
    with pytest.raises(ValueError):
        api.plan(cfg, level="v3", supervision="maybe")
    with pytest.raises(ValueError):
        api.plan(cfg, level="v3", max_retries=-1)
    with pytest.raises(ValueError):
        api.plan(cfg, level="v3", retry_backoff_ms=-1.0)
    with pytest.raises(ValueError):
        api.plan(cfg, level="v3", launch_timeout_ms=0.0)
    with pytest.raises(ValueError):
        api.plan(cfg, level="v3", fault_plan="chaos please")


# ------------------------------------------- calibration fallback ----


def test_measured_guard_calibration_failure_warns_and_falls_back():
    """The measured promotion guard must not die (or stay silent) when
    calibration fails: it warns, records the reason, and the static
    bucket_cost proxy takes over."""
    srv = _server("gcrn", promote_buckets=2.0, promotion_guard="measured")
    params, _ = _init(srv)

    def boom(*a, **k):
        raise RuntimeError("calibration kaboom")

    srv._launch_ragged = boom
    with pytest.warns(RuntimeWarning, match="falling back"):
        cost = srv._promotion_cost(params)
    assert cost is bucket_cost
    assert "kaboom" in srv._calib_error
