"""The generalized temporal contract: one stream engine, three time
semantics (kernels/stream_fused.TEMPORAL_MODES).

  dense    snapshot streams, recurrent state advances every step — the
           original gcrn/stacked/evolve families (covered by
           test_differential.py / test_registry.py);
  event    ragged timestamped event batches over a global node-memory
           store (family "tgn", graph/events.py);
  static   T=1, no recurrence, zero StateDefs (family "static_gcn") —
           the serve engine's express lane.

This file pins the two NEW contracts end to end: model-level baseline ≡
v3 differentials (solo, batched, ragged), plan-layer temporal validation,
the deprecated-surface warnings, and the serve express lane under both
schedulers — including the slow-lane ~64-tenant mixed-traffic smoke.
"""
import dataclasses
import threading
import warnings as _warnings

import jax
import numpy as np
import pytest

from repro import api
from repro.configs.dgnn import GCRN_M2, STATIC_GCN, TGN, DatasetConfig
from repro.core import build_model, run_batched, run_stream
from repro.core.tgn import TGNModel
from repro.graph import (
    generate_temporal_graph,
    pad_event_block,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)
from repro.kernels import ops as kops
from repro.serve.engine import SnapshotServer

# ---------------------------------------------------- event streams ----

G_GLOBAL = 40


def random_event_stream(seed: int, T: int, feat_table, n_pad=16, k_max=8):
    """T random event batches over the global id space, padded into one
    shared (n_pad, k_max) bucket and stacked on a leading T axis."""
    rng = np.random.default_rng(seed)
    blocks = []
    for _ in range(T):
        e = int(rng.integers(2, 7))
        src = rng.integers(0, G_GLOBAL, e)
        dst = (src + rng.integers(1, G_GLOBAL, e)) % G_GLOBAL
        ts = rng.uniform(0.0, 10.0, e).astype(np.float32)
        blocks.append(pad_event_block(src, dst, ts, feat_table,
                                      n_pad=n_pad, k_max=k_max))
    return blocks, jax.tree.map(lambda *xs: np.stack(xs), *blocks)


@pytest.fixture(scope="module")
def tgn_case():
    cfg = dataclasses.replace(TGN, in_dim=5, hidden=8, out_dim=8)
    model = TGNModel(cfg, n_global=G_GLOBAL)
    rng = np.random.default_rng(0)
    feat_table = rng.normal(size=(G_GLOBAL, cfg.in_dim)).astype(np.float32)
    params = model.init(jax.random.PRNGKey(1))
    return model, params, feat_table


@pytest.mark.parametrize("td", [None, 4])
def test_tgn_stream_matches_baseline_scan(tgn_case, td):
    """The whole event-batch stream through ONE engine launch (node
    memory VMEM-resident across batches) == the per-batch baseline scan,
    outputs and final global memory."""
    model, params, ft = tgn_case
    blocks, blocks_T = random_event_stream(7, T=5, feat_table=ft)
    state = model.init_state(params)
    outs = []
    for blk in blocks:
        state, o = model.step(params, state, blk, mode="baseline")
        outs.append(np.asarray(o))
    sv3, ov3 = model.step_stream(params, model.init_state(params),
                                 blocks_T, tn=16, td=td)
    np.testing.assert_allclose(np.asarray(ov3), np.stack(outs), atol=2e-5)
    np.testing.assert_allclose(np.asarray(sv3["mem"]),
                               np.asarray(state["mem"]), atol=2e-5)


def test_tgn_batched_ragged_matches_solo(tgn_case):
    """B independent event streams in ONE batched launch, RAGGED over the
    number of event batches (``lengths`` generalized from ragged-T
    snapshots): each live row == its solo run truncated to its length;
    dead tail batches never touch the memory store."""
    model, params, ft = tgn_case
    B, T = 3, 4
    lengths = np.asarray([4, 2, 1], np.int32)
    streams = [random_event_stream(97 * b + 1, T=T, feat_table=ft)
               for b in range(B)]
    blocks_BT = jax.tree.map(lambda *xs: np.stack(xs),
                             *[sT for _, sT in streams])
    states0 = jax.tree.map(
        lambda a: np.broadcast_to(a[None], (B,) + a.shape),
        model.init_state(params))
    stB, oB = model.step_stream_batched(params, states0, blocks_BT, tn=16,
                                        lengths=lengths)
    oB = np.asarray(oB)
    for b in range(B):
        L = int(lengths[b])
        solo_T = jax.tree.map(lambda a, L=L: a[:L], streams[b][1])
        st, o = model.step_stream(params, model.init_state(params),
                                  solo_T, tn=16)
        np.testing.assert_allclose(oB[b, :L], np.asarray(o), atol=2e-5)
        np.testing.assert_allclose(np.asarray(stB["mem"])[b],
                                   np.asarray(st["mem"]), atol=2e-5,
                                   err_msg=f"row {b} memory leaked from "
                                           "dead tail batches")


def test_tgn_launch_validates_timestamps(tgn_case):
    model, params, ft = tgn_case
    _, blocks_T = random_event_stream(3, T=2, feat_table=ft)
    bad = dataclasses.replace(
        blocks_T, neigh_ts=np.asarray(blocks_T.neigh_ts, np.int32))
    with pytest.raises(ValueError, match="floating"):
        model.step_stream(params, model.init_state(params), bad, tn=16)


# ----------------------------------------------------- static family ----

_TINY = DatasetConfig("tiny-temporal", avg_nodes=20, avg_edges=40,
                      max_nodes=48, max_edges=192, snapshots=10, seed=3)
_BUCKET = (64, 512, 64)


@pytest.fixture(scope="module")
def static_case():
    tg, ft = generate_temporal_graph(_TINY, feat_dim=8)
    snaps = slice_snapshots(tg, 1.0)
    cfg = dataclasses.replace(STATIC_GCN, in_dim=8, hidden=16, out_dim=8,
                              edge_dim=8, n_gnn_layers=2)
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(2))
    pads = [pad_snapshot(renumber_and_normalize(s), ft, *_BUCKET)
            for s in snaps]
    return tg, ft, snaps, pads, model, params


def test_static_stream_matches_per_snapshot_forward(static_case):
    """T independent snapshots fold onto the engine batch axis: one
    static_gcn launch == the per-snapshot XLA GCN forward."""
    from repro.core.dataflow import stack_time

    _, _, _, pads, model, params = static_case
    sT = stack_time(pads[:5])
    _, outs = model.step_stream(params, {}, sT, tn=32)
    for t, ps in enumerate(pads[:5]):
        _, want = model.step(params, {}, ps)
        np.testing.assert_allclose(np.asarray(outs)[t], np.asarray(want),
                                   atol=2e-4)


def test_static_batched_ragged_dead_slots_zero(static_case):
    """(B, T) folds onto (B*T, 1); ragged ``lengths`` become per-slot
    liveness and a DEAD slot's output is exactly zero (the mask kills the
    bias term too)."""
    from repro.core.dataflow import stack_time

    _, _, _, pads, model, params = static_case
    B, T = 2, 3
    sBT = jax.tree.map(lambda *xs: np.stack(xs),
                       *[stack_time(pads[b * T:(b + 1) * T])
                         for b in range(B)])
    lengths = np.asarray([3, 1], np.int32)
    _, oB = model.step_stream_batched(params, {}, sBT, tn=32,
                                      lengths=lengths)
    oB = np.asarray(oB)
    for b in range(B):
        for t in range(T):
            _, want = model.step(params, {}, jax.tree.map(
                lambda a: a[b, t], sBT))
            if t < int(lengths[b]):
                np.testing.assert_allclose(oB[b, t], np.asarray(want),
                                           atol=2e-4)
            else:
                np.testing.assert_array_equal(oB[b, t],
                                              np.zeros_like(oB[b, t]))


def test_static_kernel_rejects_multi_step_streams(static_case):
    """The static cell spec's temporal contract is T == 1 — a T>1 stream
    must be folded onto the batch axis by the caller, never silently
    scanned."""
    from repro.core.dataflow import stack_time

    _, _, _, pads, model, params = static_case
    sT = stack_time(pads[:2])
    with pytest.raises(ValueError, match="fold independent snapshots"):
        kops.stream_steps("static_gcn", sT.neigh_idx, sT.neigh_coef,
                          sT.node_feat, sT.node_mask,
                          [p["w"] for p in params["gcn"]],
                          [p["b"] for p in params["gcn"]], None, tn=32)


# ------------------------------------------------- plan temporal layer ----

def test_plan_temporal_derived_from_family():
    assert api.plan(family="gcrn").temporal == "dense"
    assert api.plan(family="tgn", level="v3").temporal == "event"
    p = api.plan(STATIC_GCN)
    assert p.temporal == "static"
    assert p.as_dict()["temporal"] == "static"


def test_plan_temporal_contradiction_raises():
    with pytest.raises(ValueError, match="contradicts"):
        api.plan(family="tgn", level="v3", temporal="dense")
    with pytest.raises(ValueError, match="contradicts"):
        api.plan(family="static_gcn", level="v3", temporal="event")


def test_plan_static_rejects_state_pool():
    with pytest.raises(ValueError, match="state_pool_pages"):
        api.plan(family="static_gcn", level="v3", scheduler="continuous",
                 state_pool_pages=4)


def test_family_temporal_single_source_of_truth():
    from repro.kernels.stream_fused import REGISTRY

    for fam, spec in REGISTRY.items():
        assert kops.family_temporal(fam) == spec.temporal
    with pytest.raises(KeyError):
        kops.family_temporal("gat")


# --------------------------------------------- deprecated-surface pins ----

def test_deprecated_shims_warn(static_case, tgn_case):
    tg, ft, snaps, pads, model, params = static_case
    from repro.core.dataflow import stack_time

    sT = stack_time(pads[:1])
    with pytest.warns(DeprecationWarning, match="run_stream is deprecated"):
        run_stream(model, params, {}, sT, mode="baseline")
    sTB = jax.tree.map(lambda a: np.stack([a, a], axis=1), sT)
    with pytest.warns(DeprecationWarning, match="run_batched is deprecated"):
        run_batched(model, params, {}, sTB, mode="baseline")
    cfg = dataclasses.replace(GCRN_M2, in_dim=8, hidden=16, out_dim=8,
                              edge_dim=8, dataflow="v3")
    with pytest.warns(DeprecationWarning, match="keyword surface"):
        SnapshotServer(cfg, ft, n_global=tg.n_global_nodes,
                       n_pad=_BUCKET[0], e_pad=_BUCKET[1], k_max=_BUCKET[2])
    # the typed session surface stays silent
    plan = api.plan(cfg, level="v3", n_pad=_BUCKET[0], e_pad=_BUCKET[1],
                    k_max=_BUCKET[2])
    sess = api.BoosterSession(cfg, plan, n_global=tg.n_global_nodes,
                              feat_table=ft)
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", DeprecationWarning)
        SnapshotServer(session=sess)


# ------------------------------------------------- serve express lane ----

def _mixed_server(tg, ft, scheduler):
    gcfg = dataclasses.replace(GCRN_M2, in_dim=8, hidden=16, out_dim=8,
                               edge_dim=8, dataflow="v3")
    scfg = dataclasses.replace(STATIC_GCN, in_dim=8, hidden=16, out_dim=8,
                               edge_dim=8)
    gplan = api.plan(gcfg, level="v3", n_pad=_BUCKET[0], e_pad=_BUCKET[1],
                     k_max=_BUCKET[2], stream_chunk=4,
                     supervision="isolate", scheduler=scheduler,
                     state_pool_pages=2 if scheduler == "continuous"
                     else None)
    gsess = api.BoosterSession(gcfg, gplan, n_global=tg.n_global_nodes,
                               feat_table=ft)
    splan = api.plan(scfg, level="v3", n_pad=_BUCKET[0], e_pad=_BUCKET[1],
                     k_max=_BUCKET[2])
    ssess = api.BoosterSession(scfg, splan, n_global=tg.n_global_nodes,
                               feat_table=ft)
    return SnapshotServer(session=gsess, express=ssess), ssess


@pytest.mark.parametrize("scheduler", ["rounds", "continuous"])
def test_serve_express_coexists_with_recurrent(static_case, scheduler):
    """run_multi with a static express tenant co-existing with recurrent
    tenants: express outputs == solo static forwards, recurrent outputs
    unchanged vs a no-express serve, and the launch split is visible in
    ServeStats (express_launches / launches_by_family)."""
    tg, ft, snaps, pads, _, _ = static_case
    srv, ssess = _mixed_server(tg, ft, scheduler)
    params, _ = srv.init(jax.random.PRNGKey(0))
    xparams = ssess.model.init(jax.random.PRNGKey(1))
    streams = {"a": snaps[:6], "b": snaps[2:9]}
    states = {sid: srv.model.init_state(params, mode="v3")
              for sid in streams}
    xstreams = {"x1": snaps[:5], "x2": snaps[1:8]}
    fstates, outs, stats = srv.run_multi(
        params, states, streams, express_streams=xstreams,
        express_params=xparams)
    # express rows: stateless solo forwards, in stream order
    for sid, ss in xstreams.items():
        assert len(outs[sid]) == len(ss)
        for o, s in zip(outs[sid], ss):
            ps = pad_snapshot(renumber_and_normalize(s), ft, *_BUCKET)
            _, want = ssess.model.step(xparams, {}, ps)
            np.testing.assert_allclose(o, np.asarray(want), atol=2e-4,
                                       err_msg=f"{scheduler} {sid}")
    # recurrent rows: identical to serving without the express lane
    for sid, ss in streams.items():
        st = srv.model.init_state(params, mode="v3")
        _, solo, _ = srv.run(params, st, ss)
        assert len(outs[sid]) == len(solo)
        for a, b in zip(outs[sid], solo):
            np.testing.assert_allclose(a, b, atol=2e-4)
    assert stats.express_launches > 0
    assert (stats.launches_by_family.get("static_gcn", 0)
            == stats.express_launches)
    assert stats.launches_by_family.get("gcrn", 0) > 0
    assert stats.launches == sum(stats.launches_by_family.values())


def test_express_lane_validation(static_case):
    tg, ft, snaps, _, _, _ = static_case
    srv, ssess = _mixed_server(tg, ft, "rounds")
    params, _ = srv.init(jax.random.PRNGKey(0))
    xparams = ssess.model.init(jax.random.PRNGKey(1))
    st = {"a": srv.model.init_state(params, mode="v3")}
    with pytest.raises(ValueError, match="both streams and express"):
        srv.run_multi(params, st, {"a": snaps[:2]},
                      express_streams={"a": snaps[:2]},
                      express_params=xparams)
    with pytest.raises(ValueError, match="STATIC-temporal"):
        SnapshotServer(session=srv.session, express=srv.session)
    no_express = SnapshotServer(session=srv.session)
    with pytest.raises(ValueError, match="needs the express lane"):
        no_express.run_multi(params, {}, {}, express_streams={"x": snaps[:1]},
                             express_params=xparams)


@pytest.mark.slow
def test_serve_scale_mixed_traffic_no_thread_leak(static_case):
    """~64 tenants (16 recurrent + 48 static express) through the
    continuous scheduler: every tenant fully served, per-family launch
    counters consistent, and every producer thread joined at exit (no
    thread leak across the run)."""
    tg, ft, snaps, _, _, _ = static_case
    srv, ssess = _mixed_server(tg, ft, "continuous")
    params, _ = srv.init(jax.random.PRNGKey(0))
    xparams = ssess.model.init(jax.random.PRNGKey(1))
    n_rec, n_exp = 16, 48
    streams = {f"r{i:02d}": snaps[i % 4:i % 4 + 2] for i in range(n_rec)}
    states = {sid: srv.model.init_state(params, mode="v3")
              for sid in streams}
    xstreams = {f"x{i:02d}": snaps[i % 6:i % 6 + 1] for i in range(n_exp)}
    before = threading.active_count()
    fstates, outs, stats = srv.run_multi(
        params, states, streams, express_streams=xstreams,
        express_params=xparams)
    for th in threading.enumerate():
        assert not th.name.startswith(("dgnn-serve-producer",
                                       "dgnn-serve-express")), th.name
    assert threading.active_count() <= before
    assert all(len(outs[sid]) == len(streams[sid]) for sid in streams)
    assert all(len(outs[sid]) == len(xstreams[sid]) for sid in xstreams)
    assert stats.express_launches > 0
    assert (stats.launches_by_family.get("static_gcn", 0)
            == stats.express_launches)
    assert stats.launches == sum(stats.launches_by_family.values())
    assert stats.ticks > 0
    assert not stats.tenant_errors
