"""Stream-engine registry contract (kernels/stream_fused.REGISTRY).

Three guarantees pinned here (the CI fast lane runs this file once per
registered family in a matrix, see .github/workflows/ci.yml):

  1. every registered family's cell spec computes the XLA oracle exactly,
     solo AND batched, fully resident AND D-blocked (d//td >= 2) — a
     family registered without a harness case builder fails;
  2. exactly ONE Pallas kernel body exists in stream_fused.py and no
     family-named ``*_stream*kernel`` / ``*_stream*pallas`` definition
     survives anywhere outside the registry module;
  3. ``ops.set_force_ref`` covers the unified entry points: force-ref mode
     NEVER enters ``pallas_call`` for any family or batching mode (the
     forgotten-family-branch regression).
"""
import pathlib
import re

import numpy as np
import pytest

import harness
from repro.kernels import ops, stream_fused

FAMILIES = sorted(stream_fused.REGISTRY)


def _run_case(family, batched, td):
    B = 2 if batched else None
    args, oracle, d = harness.stream_kernel_case(family, seed=3, B=B)
    if td is not None:
        assert d // td >= 2, "case must force a multi-block D layout"
    fn = ops.stream_steps_batched if batched else ops.stream_steps
    got = fn(family, *args, tn=32, td=td)
    want = oracle(*args)
    got_outs, want_outs = np.asarray(got[0]), np.asarray(want[0])
    assert np.isfinite(want_outs).all() and np.abs(want_outs).max() > 0
    np.testing.assert_allclose(got_outs, want_outs, atol=2e-4,
                               err_msg=f"{family} outs")
    for i, (g, w) in enumerate(zip(got[1:], want[1:])):
        # final recurrent states (possibly a tuple of per-layer weights)
        gs = g if isinstance(g, (tuple, list)) else (g,)
        ws = w if isinstance(w, (tuple, list)) else (w,)
        for gg, ww in zip(gs, ws):
            np.testing.assert_allclose(np.asarray(gg), np.asarray(ww),
                                       atol=2e-4,
                                       err_msg=f"{family} state[{i}]")


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("td", [None, 8])
def test_registry_family_solo_matches_oracle(family, td):
    """Solo stream through the engine == XLA oracle, resident (td=None)
    and D-blocked (td=8, d//td >= 2) alike — outputs and final states."""
    _run_case(family, batched=False, td=td)


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("td", [None, 8])
def test_registry_family_batched_matches_oracle(family, td):
    """Batched streams through ONE engine launch == vmapped XLA oracle."""
    _run_case(family, batched=True, td=td)


def test_registry_covers_all_models():
    """Every core model family dispatches to a registered cell spec, and
    the ops dispatch table mirrors the registry exactly."""
    from repro.configs.dgnn import DGNN_CONFIGS
    from repro.core import build_model

    assert ops.stream_families() == tuple(FAMILIES)
    for cfg in DGNN_CONFIGS.values():
        model = build_model(cfg)
        assert model.stream_family in stream_fused.REGISTRY, cfg.name


# ------------------------------------------------ structural checks ----

def _src_files():
    root = pathlib.Path(stream_fused.__file__).resolve().parents[2]
    return sorted(root.rglob("*.py"))


def test_exactly_one_stream_kernel_body():
    """The generic engine is the ONLY Pallas kernel (and the only
    pallas_call site) in stream_fused.py — family code is cell specs."""
    src = pathlib.Path(stream_fused.__file__).read_text()
    kernels = re.findall(r"^def (\w*_kernel)\(", src, re.M)
    assert kernels == ["_stream_engine_kernel"], kernels
    assert src.count("pl.pallas_call(") == 1


def test_no_family_named_stream_kernels_outside_registry():
    """No family-named stream kernel/launcher definition survives outside
    the registry module (oracles in ref.py are ``*_stream*_ref`` — the XLA
    production path — and stay)."""
    pat = re.compile(
        r"^def\s+_?\w*(gcrn|stacked|evolve|dgnn|tgn|static)\w*_stream\w*\(",
        re.M)
    offenders = []
    for f in _src_files():
        if f.name == "stream_fused.py":
            continue
        for m in pat.finditer(f.read_text()):
            if not m.group(0).rstrip("(").endswith(("_ref", "_refs")):
                offenders.append(f"{f.name}: {m.group(0)}")
    assert not offenders, offenders


# ------------------------------------------------ force-ref routing ----

def _boom(*a, **k):
    raise AssertionError("pallas_call entered under force-ref")


def test_force_ref_never_enters_pallas_call(monkeypatch):
    """The single force-ref gate in ops covers EVERY family and batching
    mode: with set_force_ref(True), pallas_call is unreachable (the
    pre-refactor bug was a per-family branch that forgot the check and
    silently benchmarked the Pallas interpreter as the XLA path)."""
    monkeypatch.setattr(stream_fused.pl, "pallas_call", _boom)
    # cached engine executables would bypass the patched pallas_call and
    # blind the probe — force a fresh trace
    stream_fused.stream_call.clear_cache()
    # the probe is live: without force-ref the engine path must trip it
    args, _, _ = harness.stream_kernel_case(FAMILIES[0], seed=5)
    with pytest.raises(Exception, match="pallas_call entered"):
        ops.stream_steps(FAMILIES[0], *args, tn=32)
    ops.set_force_ref(True)
    try:
        for family in FAMILIES:
            for batched in (False, True):
                B = 2 if batched else None
                args, oracle, _ = harness.stream_kernel_case(family, seed=5,
                                                             B=B)
                fn = ops.stream_steps_batched if batched else ops.stream_steps
                got = fn(family, *args, tn=32)
                np.testing.assert_allclose(np.asarray(got[0]),
                                           np.asarray(oracle(*args)[0]),
                                           atol=1e-5)
    finally:
        ops.set_force_ref(False)


def test_unknown_family_raises():
    with pytest.raises(KeyError, match="unknown stream-engine family"):
        ops.stream_steps("gat", None)
