"""Shared fixtures: the differential-test harness cases.

``stream_case`` parametrizes over all three DGNN families; each case is B
independent random snapshot streams (ragged node counts, odd T) padded into
one shared bucket, plus the family's model + params. Cases are built once
per session (engines re-run from fresh state inside each test, so sharing
is safe).
"""
import pytest

from repro.configs.dgnn import DGNN_CONFIGS

import harness


@pytest.fixture(scope="session", params=sorted(DGNN_CONFIGS))
def stream_case(request):
    return harness.make_case(request.param, seed=11, T=5, B=3)
