"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (requirements-dev.txt); skip, don't "
           "abort collection, when absent")
from hypothesis import given, settings, strategies as st

import harness
from repro.graph.coo import COOSnapshot, TemporalGraph, slice_snapshots
from repro.graph.csr import max_in_degree, renumber_and_normalize, to_ell
from repro.graph.padding import choose_bucket
from repro.kernels import ref
from repro.optim import dequantize_blockwise, quantize_blockwise

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def coo_snapshots(draw):
    n_pool = draw(st.integers(4, 200))
    e = draw(st.integers(1, 400))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    src = rng.integers(0, n_pool, e)
    dst = rng.integers(0, n_pool, e)
    keep = src != dst
    if not keep.any():
        src, dst = np.array([0]), np.array([1])
        keep = np.array([True])
    src, dst = src[keep], dst[keep]
    ef = rng.normal(size=(src.size, 4)).astype(np.float32)
    return COOSnapshot(src=src, dst=dst, edge_feat=ef, t_index=0)


@given(coo_snapshots())
def test_renumber_preserves_edge_count_and_density(snap):
    ls = renumber_and_normalize(snap)
    # e' = 2e (reverse edges) + n (self loops)
    assert ls.src.shape[0] == 2 * snap.n_edges + ls.n_nodes
    assert ls.n_nodes == snap.active_nodes().size
    # normalization positive, finite
    assert np.isfinite(ls.coef).all() and (ls.coef > 0).all()


@given(coo_snapshots())
def test_ell_spmm_equals_segment_sum(snap):
    """ELL aggregation == explicit COO segment sum, any random graph."""
    ls = renumber_and_normalize(snap)
    n_pad = max(8, int(np.ceil(ls.n_nodes / 8)) * 8)
    k = max(1, max_in_degree(ls))
    idx, coef, eidx = to_ell(ls, n_pad, k)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n_pad, 16)).astype(np.float32)
    got = np.asarray(ref.ell_spmm(jnp.asarray(idx), jnp.asarray(coef),
                                  jnp.asarray(eidx), jnp.asarray(x)))
    want = np.zeros_like(x)
    np.add.at(want, ls.dst, ls.coef[:, None] * x[ls.src])
    np.testing.assert_allclose(got, want, atol=1e-4)


@given(coo_snapshots(), st.integers(0, 64), st.integers(0, 128),
       st.integers(0, 8))
def test_pad_unpad_roundtrip(snap, dn, de, dk):
    """pad_snapshot -> unpad_snapshot is the identity on the live data for
    ANY fitting bucket, and the padding obeys the sink-row coef-0
    convention (checkers shared with test_differential.py)."""
    ls = renumber_and_normalize(snap)
    bucket = (ls.n_nodes + dn, ls.src.shape[0] + de,
              max(max_in_degree(ls), 1) + dk)
    feat_table = np.random.default_rng(0).normal(
        size=(256, 5)).astype(np.float32)  # covers every global id (< 200)
    harness.check_pad_unpad_roundtrip(ls, feat_table, bucket)


@st.composite
def bucket_chains(draw):
    """Nested (componentwise strictly increasing) bucket chains — the shape
    serve buckets are configured in (smallest-first, each covering the
    previous), for which choose_bucket is monotone."""
    m = draw(st.integers(1, 4))
    n = draw(st.integers(1, 64))
    e = draw(st.integers(1, 256))
    k = draw(st.integers(1, 16))
    chain = []
    for _ in range(m):
        chain.append((n, e, k))
        n += draw(st.integers(1, 64))
        e += draw(st.integers(1, 256))
        k += draw(st.integers(1, 16))
    return tuple(chain)


@given(bucket_chains(), st.data())
def test_choose_bucket_smallest_fit_and_monotone(chain, data):
    last = chain[-1]
    n = data.draw(st.integers(1, last[0]), label="n")
    e = data.draw(st.integers(1, last[1]), label="e")
    k = data.draw(st.integers(1, last[2]), label="k")
    harness.check_choose_bucket_smallest_fit(n, e, k, chain)
    # bucket monotonicity: growing the snapshot never picks an earlier
    # (smaller) bucket of the chain
    n2 = data.draw(st.integers(n, last[0]), label="n2")
    e2 = data.draw(st.integers(e, last[1]), label="e2")
    k2 = data.draw(st.integers(k, last[2]), label="k2")
    order = {b: i for i, b in enumerate(chain)}
    assert (order[choose_bucket(n2, e2, k2, chain)]
            >= order[choose_bucket(n, e, k, chain)])


@given(bucket_chains(), st.data())
def test_choose_bucket_batch_covers_every_member(chain, data):
    """The multi-tenant chunk bucket covers every member's dims and is >=
    every member's individual bucket in chain order."""
    last = chain[-1]
    m = data.draw(st.integers(1, 5), label="batch")
    dims = [(data.draw(st.integers(1, last[0])),
             data.draw(st.integers(1, last[1])),
             data.draw(st.integers(1, last[2]))) for _ in range(m)]
    harness.check_bucket_monotone(dims, chain)


@given(bucket_chains())
def test_choose_bucket_overflow_raises(chain):
    last = chain[-1]
    with pytest.raises(ValueError, match="no bucket fits"):
        choose_bucket(last[0] + 1, 1, 1, chain)


@given(st.integers(0, 1 << 20), st.one_of(st.none(), st.integers(0, 1 << 20)))
def test_pow2_target_never_undersizes(real, cap):
    """pow2_target contract: the padding target is NEVER smaller than the
    real length. Whenever ``cap >= real`` the result satisfies
    ``real <= target <= max(cap, 1)`` and without a cap it is the exact
    next power of two; an unsatisfiable cap (< real) raises instead of
    silently returning it (the serve-chunk truncation bug)."""
    from repro.graph.padding import pow2_target

    if cap is not None and cap < real:
        with pytest.raises(ValueError, match="smaller than the real"):
            pow2_target(real, cap=cap)
        return
    target = pow2_target(real, cap=cap)
    assert target >= real
    assert target >= 1
    if cap is not None:
        assert target <= max(cap, 1)
    else:
        assert target & (target - 1) == 0  # a power of two
        assert target < 2 * max(real, 1)   # the NEXT one, not a later one


@given(st.integers(0, 2**31), st.integers(1, 4))
def test_time_splitter_partition(seed, width):
    rng = np.random.default_rng(seed)
    e = rng.integers(10, 300)
    tg = TemporalGraph(
        src=rng.integers(0, 50, e), dst=rng.integers(0, 50, e),
        time=rng.uniform(0, 20, e), edge_feat=np.zeros((e, 0), np.float32),
        n_global_nodes=50)
    snaps = slice_snapshots(tg, float(width))
    assert sum(s.n_edges for s in snaps) == e  # exact partition
    assert all(s.n_edges > 0 for s in snaps)   # empty windows dropped


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=500))
def test_quantize_roundtrip_bound(vals):
    x = jnp.asarray(np.array(vals, np.float32))
    qd = quantize_blockwise(x)
    back = np.asarray(dequantize_blockwise(qd, x.shape))
    scale = np.asarray(qd["scale"])
    # per-block error bound: half a quantization step
    err = np.abs(back - np.asarray(x))
    assert err.max() <= scale.max() * 0.5 + 1e-6


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(("gcrn", "stacked", "evolve", "tgn", "static_gcn")),
       st.sampled_from((4, 8, 12)), st.integers(0, 2**16))
def test_dblock_tiling_roundtrips_state(family, td, seed):
    """D-axis blocking is a pure layout change: for ANY block size td the
    blocked stream engine returns the SAME per-step outputs and final
    recurrent state as the unblocked (fully resident) kernel — the state
    round-trips the (n_global, td) column tiling identically. The harness
    case widths (d = 24 for node states, dmax = 16 for evolve/static)
    make every sampled td a genuine multi-block layout; td=12
    additionally exercises a d_pad > d remainder block. Covers all THREE
    temporal contracts (dense, event, static) through the one engine."""
    from repro.kernels import ops

    args, _, _ = harness.stream_kernel_case(family, seed=seed, T=2, n=32,
                                            k=3)
    got = ops.stream_steps(family, *args, tn=32, td=td)
    want = ops.stream_steps(family, *args, tn=32, td=None)
    flat_g, _ = jax.tree.flatten(got)
    flat_w, _ = jax.tree.flatten(want)
    for g, w in zip(flat_g, flat_w):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6)


@st.composite
def event_batches(draw):
    """Random timestamped event batches (u, v, t) over a small global id
    space, self-loop free (the event contract rejects them)."""
    G = draw(st.integers(4, 64))
    e = draw(st.integers(1, 40))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    src = rng.integers(0, G, e)
    dst = rng.integers(0, G, e)
    keep = src != dst
    if not keep.any():
        src, dst = np.array([0]), np.array([1])
        keep = np.array([True])
    src, dst = src[keep].astype(np.int32), dst[keep].astype(np.int32)
    ts = rng.uniform(0.0, 50.0, src.size).astype(np.float32)
    return G, src, dst, ts


@given(event_batches(), st.integers(0, 8), st.integers(0, 4))
def test_event_pad_unpad_roundtrip(batch, dn, dk):
    """pad_event_block -> unpad_event_block recovers the exact event
    multiset (canonical src < dst form) for ANY fitting bucket: the
    symmetric lanes the padding adds collapse back to one event each,
    and no padding lane survives the round trip."""
    from repro.graph.events import pad_event_block, unpad_event_block

    G, src, dst, ts = batch
    touched = np.unique(np.concatenate([src, dst]))
    deg = int(np.bincount(np.concatenate([src, dst]), minlength=G).max())
    feat_table = np.random.default_rng(0).normal(
        size=(G, 5)).astype(np.float32)
    blk = pad_event_block(src, dst, ts, feat_table,
                          n_pad=touched.size + dn, k_max=deg + dk)
    want = sorted((int(min(u, v)), int(max(u, v)), np.float32(t))
                  for u, v, t in zip(src, dst, ts))
    got_s, got_d, got_t = unpad_event_block(blk)
    ws, wd, wt = zip(*want)
    np.testing.assert_array_equal(got_s, np.asarray(ws, np.int32))
    np.testing.assert_array_equal(got_d, np.asarray(wd, np.int32))
    np.testing.assert_array_equal(got_t, np.asarray(wt, np.float32))
    # padding invariants: dead lanes coef 0, dead rows mask 0 / ren -1
    coef = np.asarray(blk.neigh_coef)
    n = int(blk.n_nodes)
    assert (coef[n:] == 0).all()
    assert (np.asarray(blk.node_mask)[n:] == 0).all()
    assert (np.asarray(blk.renumber)[n:] == -1).all()


@given(event_batches(), st.integers(0, 2**31))
def test_dead_event_time_encoding_contributes_zero(batch, seed):
    """A padded (coef-0) event lane contributes EXACTLY zero to the time
    encoding and memory aggregation: overwriting every dead lane's
    timestamp with garbage leaves the TGN oracle's outputs and final
    memory bit-identical. This is the event contract's half of the
    sink-row convention — dead data is killed by coef, not by being
    zero."""
    import dataclasses as _dc

    from repro.graph.events import pad_event_block
    from repro.kernels.ref import tgn_stream_ref

    G, src, dst, ts = batch
    touched = np.unique(np.concatenate([src, dst]))
    deg = int(np.bincount(np.concatenate([src, dst]), minlength=G).max())
    rng = np.random.default_rng(seed)
    h, din = 6, 5
    feat_table = rng.normal(size=(G, din)).astype(np.float32)
    blk = pad_event_block(src, dst, ts, feat_table,
                          n_pad=touched.size + 2, k_max=deg + 1)
    coef = np.asarray(blk.neigh_coef)
    garbage = rng.uniform(-1e3, 1e3, coef.shape).astype(np.float32)
    tampered = _dc.replace(
        blk, neigh_ts=np.where(coef == 0, garbage,
                               np.asarray(blk.neigh_ts)))
    args = (rng.normal(size=(G, h)).astype(np.float32) * 0.5,   # mem0
            np.abs(rng.normal(size=h)).astype(np.float32),       # freq
            rng.normal(size=(din, h)).astype(np.float32) * 0.2,  # w_in
            rng.normal(size=(h, 3 * h)).astype(np.float32) * 0.2,
            rng.normal(size=(h, 3 * h)).astype(np.float32) * 0.2,
            rng.normal(size=3 * h).astype(np.float32) * 0.1)

    def run(b):
        sT = jax.tree.map(lambda a: np.asarray(a)[None],
                          (b.neigh_idx, b.neigh_coef, b.neigh_ts,
                           b.node_feat, b.renumber, b.node_mask))
        return tgn_stream_ref(*sT, *args)

    o1, m1 = run(blk)
    o2, m2 = run(tampered)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


@given(st.integers(0, 2**31))
def test_gru_state_bounded(seed):
    """GRU output is a convex combination -> bounded by input magnitudes."""
    from repro.core import rnn as R

    rng = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(rng, 3)
    p = R.init_gru(k1, 8, 8)
    x = jax.random.normal(k2, (4, 8))
    h = jnp.clip(jax.random.normal(k3, (4, 8)), -1, 1)
    out = R.gru_cell(p, x, h)
    bound = jnp.maximum(jnp.abs(h), 1.0)  # |n| <= 1 (tanh), |h| <= bound
    assert (jnp.abs(out) <= bound + 1e-5).all()


@given(st.integers(2, 64), st.integers(0, 2**31))
def test_softmax_ce_lower_bound(vocab, seed):
    """Chunked CE >= 0 and == -log p(target)."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(vocab,)).astype(np.float32)
    t = int(rng.integers(0, vocab))
    lse = np.log(np.exp(logits).sum())
    ce = lse - logits[t]
    assert ce >= -1e-6
