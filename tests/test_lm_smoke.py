"""Per-architecture smoke tests: reduced same-family config, one forward/
train step on CPU, output shapes + no NaNs; decode where supported."""

import pytest as _pytest

pytestmark = _pytest.mark.slow  # LM-side e2e: excluded from the fast CI lane

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, list_archs, reduce_for_smoke
from repro.models import (
    RuntimeConfig,
    decode_step,
    init_caches,
    init_params,
    loss_fn,
    prefill_step,
)

RT = RuntimeConfig(tp=1, scan_layers=True, remat=False, attn_chunk=64,
                   moe_impl="dense", loss_chunk=8)
B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    t = {"targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.input_mode == "embeddings":
        t["embeds"] = jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)), jnp.bfloat16)
    else:
        t["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return t


@pytest.fixture(scope="module")
def smokes():
    return {}


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_shapes_no_nans(arch, smokes):
    cfg = reduce_for_smoke(ARCHS[arch])
    params, axes = init_params(cfg, RT, jax.random.PRNGKey(0))
    smokes[arch] = params
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, RT, _batch(cfg)))(params)
    assert np.isfinite(float(loss))
    gleaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in gleaves)
    # at least one grad is nonzero (model is trainable)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in gleaves)


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_shapes(arch, smokes):
    cfg = reduce_for_smoke(ARCHS[arch])
    params = smokes.get(arch)
    if params is None:
        params = init_params(cfg, RT, jax.random.PRNGKey(0))[0]
    logits = prefill_step(params, cfg, RT, _batch(cfg))
    assert logits.shape == (B, 1, cfg.padded_vocab())
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # vocab padding is masked out
    assert np.asarray(logits, np.float32)[..., cfg.vocab_size:].max() < -1e8


@pytest.mark.parametrize("arch", list_archs())
def test_decode_step(arch, smokes):
    cfg = reduce_for_smoke(ARCHS[arch])
    if not cfg.supports_decode:
        pytest.skip("encoder-only arch has no decode step")
    params = smokes.get(arch)
    if params is None:
        params = init_params(cfg, RT, jax.random.PRNGKey(0))[0]
    caches = init_caches(cfg, RT, B, 64)
    toks = jnp.ones((B, 1), jnp.int32)
    logits, caches2 = decode_step(params, cfg, RT, toks, caches)
    assert logits.shape == (B, 1, cfg.padded_vocab())
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # caches advanced
    leaves1 = jax.tree.leaves(caches)
    leaves2 = jax.tree.leaves(caches2)
    assert any(not np.array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
               for a, b in zip(leaves1, leaves2))


def test_scan_equals_unrolled():
    """The scanned and unrolled executions are the same function."""
    import dataclasses

    cfg = reduce_for_smoke(ARCHS["jamba-v0.1-52b"])  # hardest wiring
    params, _ = init_params(cfg, RT, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l1 = loss_fn(params, cfg, RT, batch)
    rt2 = dataclasses.replace(RT, scan_layers=False)
    l2 = loss_fn(params, cfg, rt2, batch)
    np.testing.assert_allclose(float(l1), float(l2), atol=1e-3, rtol=1e-4)
