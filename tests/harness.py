"""Shared differential-test harness for the dataflow-engine contract.

The paper's correctness claim is that every dataflow level computes the
SAME function — baseline ≡ o1 ≡ v1 ≡ v2 ≡ v3 — and PR 2 extends it with
batched-v3: running B independent streams through one batched stream-kernel
launch must be bit-close to running each stream alone (row-sliced). This
module builds random snapshot streams (ragged node counts per step, odd T,
all three model families) and asserts that contract in one place, replacing
the per-file copy-pasted equivalence loops.

Also hosts the padding/bucket invariant checkers shared by the plain
regression tests (run everywhere) and the hypothesis property tests
(test_property.py, run when hypothesis is installed).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs.dgnn import DGNN_CONFIGS, DGNNConfig
from repro.core import (
    build_model,
    init_states_batched,
    run_batched,
    run_stream,
    stack_time,
)
from repro.graph import (
    COOSnapshot,
    choose_bucket,
    choose_bucket_batch,
    max_in_degree,
    pad_snapshot,
    renumber_and_normalize,
    unpad_snapshot,
)

# Which engines apply per DGNN family — the plan API's validity table is
# the single source of truth (api.plan rejects anything outside it).
# Every family's v3 is a real time-fused stream kernel:
# node-state-resident for GCRN/stacked, weights-resident (in-kernel
# matrix-GRU evolution) for EvolveGCN.
MODES = {name: list(api.FAMILY_LEVELS[api.family_for(cfg)])
         for name, cfg in DGNN_CONFIGS.items()}


def small_config(name: str, stream_td: int | None = None) -> DGNNConfig:
    """Shrunk copy of a real family config so interpret-mode kernels and
    the XLA engines stay fast on CPU. ``stream_td`` blocks the stream
    engine's state feature axis (hidden=32, so stream_td=16 forces
    d//td == 2 for every family — the D-blocked differential cases)."""
    return dataclasses.replace(
        DGNN_CONFIGS[name], in_dim=16, hidden=32, out_dim=8, edge_dim=4,
        n_gnn_layers=2, max_nodes=160, max_edges=1024, stream_td=stream_td)


def random_coo_stream(rng: np.random.Generator, T: int, n_pool: int,
                      avg_edges: int, edge_dim: int) -> list[COOSnapshot]:
    """T random COO snapshots over an ``n_pool``-node global id space.

    Each step is restricted to a random subset of the pool, so the active
    node count is RAGGED across steps (the property the padding/renumber
    machinery must absorb).
    """
    snaps = []
    for t in range(T):
        sub = rng.choice(n_pool,
                         size=int(rng.integers(max(n_pool // 4, 4), n_pool)),
                         replace=False)
        e = int(rng.integers(max(avg_edges // 2, 4), avg_edges + 1))
        src = rng.choice(sub, size=e)
        dst = rng.choice(sub, size=e)
        keep = src != dst
        if not keep.any():
            src, dst = sub[:1], sub[1:2]
            keep = np.ones(1, bool)
        src, dst = src[keep], dst[keep]
        ef = rng.normal(size=(src.size, edge_dim)).astype(np.float32)
        snaps.append(COOSnapshot(src=src, dst=dst, edge_feat=ef, t_index=t))
    return snaps


@dataclass
class StreamCase:
    """One differential-test scenario: a family + B random padded streams."""

    name: str
    cfg: DGNNConfig
    model: object
    params: dict
    n_global: int
    stacked: list          # per stream: PaddedSnapshot pytree with (T, ...) axes


def make_case(name: str, seed: int = 0, T: int = 5, B: int = 3,
              stream_td: int | None = None) -> StreamCase:
    """Build a family's case: B independent random streams, odd T, ragged n,
    shared (same-bucket) padded shapes so the streams can batch.
    ``stream_td`` runs the v3 engine with a D-blocked state layout."""
    cfg = small_config(name, stream_td=stream_td)
    rng = np.random.default_rng(seed)
    n_pool = 96
    feat_table = rng.normal(size=(n_pool, cfg.in_dim)).astype(np.float32)
    raw = [random_coo_stream(rng, T, n_pool, avg_edges=80,
                             edge_dim=cfg.edge_dim) for _ in range(B)]
    locals_ = [[renumber_and_normalize(s) for s in stream] for stream in raw]
    # one bucket across all streams: batching needs identical static shapes
    k_max = max(max_in_degree(ls) for stream in locals_ for ls in stream)
    k_max = max(k_max, 4)
    n_pad = max(ls.n_nodes for stream in locals_ for ls in stream)
    e_pad = max(ls.src.shape[0] for stream in locals_ for ls in stream)
    stacked = [stack_time([pad_snapshot(ls, feat_table, n_pad, e_pad, k_max)
                           for ls in stream]) for stream in locals_]
    model = build_model(cfg, n_global=n_pool)
    params = model.init(jax.random.PRNGKey(seed + 1))
    return StreamCase(name=name, cfg=cfg, model=model, params=params,
                      n_global=n_pool, stacked=stacked)


def run_all_modes(model, params, sT, modes) -> tuple[dict, dict]:
    """Run one stream through every listed engine from a fresh state.

    Returns ({mode: outputs}, {mode: final recurrent state})."""
    outs, states = {}, {}
    for mode in modes:
        st = model.init_state(params, mode=mode)
        fs, o = run_stream(model, params, st, sT, mode=mode)
        outs[mode] = np.asarray(o)
        states[mode] = fs
    return outs, states


def assert_modes_match(outs: dict, atol: float, label: str = ""):
    """All engines' outputs equal the (finite, non-degenerate) baseline."""
    base = outs["baseline"]
    assert np.isfinite(base).all(), label
    assert np.abs(base).max() > 0, label  # non-degenerate
    for mode, o in outs.items():
        np.testing.assert_allclose(o, base, atol=atol,
                                   err_msg=f"{label} mode={mode}")


def assert_final_states_match(case: StreamCase, states: dict, atol: float,
                              label: str = ""):
    """Final recurrent states agree across engines — catching bugs the
    outputs alone cannot (e.g. a wrong extra evolution at the stream
    kernel's drain only corrupts the NEXT chunk).

    GCRN/stacked: every mode ends with the same node-state store.
    EvolveGCN: primed engines (v1, v3) carry identical evolved weights,
    unprimed (baseline, o1) too, and ONE more matrix-GRU evolution of the
    unprimed final equals the primed final — pinning the exact
    one-evolution priming offset. A double (or missing) in-kernel
    evolution in the weights-resident v3 kernel fails here.
    """
    if case.name != "evolvegcn":
        base = states["baseline"]
        for mode, st in states.items():
            jax.tree.map(lambda a, b: np.testing.assert_allclose(
                np.asarray(b), np.asarray(a), atol=atol,
                err_msg=f"{label} state mode={mode}"), base, st)
        return
    from repro.core import rnn as R

    groups = {"primed": [m for m in states if m in ("v1", "v3")],
              "unprimed": [m for m in states if m in ("baseline", "o1")]}
    for gname, group in groups.items():
        for mode in group[1:]:
            for i, (a, b) in enumerate(zip(states[group[0]]["weights"],
                                           states[mode]["weights"])):
                np.testing.assert_allclose(
                    np.asarray(b), np.asarray(a), atol=atol,
                    err_msg=f"{label} {gname} weights[{i}] "
                            f"{mode} != {group[0]}")
    if groups["primed"] and groups["unprimed"]:
        once_more = [
            R.matrix_gru(g, w, fused=True)
            for g, w in zip(case.params["gru"],
                            states[groups["unprimed"][0]]["weights"])]
        for i, (a, b) in enumerate(zip(once_more,
                                       states[groups["primed"][0]]["weights"])):
            np.testing.assert_allclose(
                np.asarray(b), np.asarray(a), atol=atol,
                err_msg=f"{label} weights[{i}] primed != GRU(unprimed) — "
                        "priming/evolution count drifted")


def assert_engines_equivalent(case: StreamCase, atol: float = 3e-4):
    """The full differential contract for one case:

    1. per stream: baseline ≡ every engine the family supports (incl. v3),
       on outputs AND final recurrent states (weights for EvolveGCN);
    2. batched v3 over all B streams in ONE launch ≡ per-stream baseline,
       row-sliced (no cross-stream state leakage), outputs and states.
    """
    per_stream, per_stream_state = [], []
    for b, sT in enumerate(case.stacked):
        outs, states = run_all_modes(case.model, case.params, sT,
                                     MODES[case.name])
        assert_modes_match(outs, atol, label=f"{case.name} stream={b}")
        assert_final_states_match(case, states, atol,
                                  label=f"{case.name} stream={b}")
        per_stream.append(outs["baseline"])
        per_stream_state.append(states["v3"])
    B = len(case.stacked)
    sTB = jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *case.stacked)
    states0 = init_states_batched(case.model, case.params, B, mode="v3")
    stateB, oB = run_batched(case.model, case.params, states0, sTB, mode="v3")
    oB = np.asarray(oB)
    for b in range(B):
        np.testing.assert_allclose(
            oB[:, b], per_stream[b], atol=atol,
            err_msg=f"{case.name} batched-v3 row {b} != solo baseline")
        jax.tree.map(lambda a, s, b=b: np.testing.assert_allclose(
            np.asarray(a)[b], np.asarray(s), atol=atol,
            err_msg=f"{case.name} batched-v3 state row {b} != solo v3"),
            stateB, per_stream_state[b])


def random_ell_stream(seed: int, T: int, n: int, k: int, e: int, din: int,
                      n_global: int):
    """Random (T, ...) padded ELL snapshot stream with valid renumber
    tables: lanes with nonzero coef reference real (masked-in) local nodes,
    matching the to_ell contract the kernels assume. Node counts are ragged
    across steps (rows past each step's n_real carry coef 0 / mask 0).

    Returns (neigh_idx, neigh_coef, neigh_eidx, node_feat, renumber,
    node_mask) as stacked numpy arrays — kernel-level inputs for the stream
    oracles and the time-fused kernels.
    """
    rng = np.random.default_rng(seed)
    arrs = {k_: [] for k_ in ("idx", "coef", "eidx", "x", "ren", "mask")}
    for _ in range(T):
        nr = int(rng.integers(max(n // 3, 1), n + 1))
        idx = rng.integers(0, nr, (n, k)).astype(np.int32)
        coef = (rng.uniform(size=(n, k)) *
                (rng.uniform(size=(n, k)) > 0.4)).astype(np.float32)
        coef[nr:] = 0.0
        x = rng.normal(size=(n, din)).astype(np.float32)
        x[nr:] = 0.0
        ren = np.full(n, -1, np.int32)
        ren[:nr] = rng.permutation(n_global)[:nr]
        mask = np.zeros(n, np.float32)
        mask[:nr] = 1.0
        for k_, v in zip(("idx", "coef", "eidx", "x", "ren", "mask"),
                         (idx, coef, rng.integers(0, e, (n, k)).astype(np.int32),
                          x, ren, mask)):
            arrs[k_].append(v)
    return tuple(np.stack(arrs[k_]) for k_ in ("idx", "coef", "eidx", "x",
                                               "ren", "mask"))


def random_ell_stream_batch(seed: int, B: int, T: int, n: int, k: int,
                            e: int, din: int, n_global: int):
    """B independent random ELL streams stacked on a leading batch axis."""
    streams = [random_ell_stream(seed + 1000 * b, T, n, k, e, din, n_global)
               for b in range(B)]
    return tuple(np.stack([s[i] for s in streams]) for i in range(6))


def random_evolve_inputs(seed, T, n, k, dims, edge=False, noop=()):
    """Random EvolveGCN stream-kernel inputs: ragged n per step, per-layer
    weights/matrix-GRU params, optional per-layer edge aggregates, and
    no-op (all-padding, live=0) steps at the given indices."""
    rng = np.random.default_rng(seed)
    rand = lambda key, shape: jax.random.normal(key, shape, jnp.float32)
    idxs, coefs, xs, masks, lives = [], [], [], [], []
    din = dims[0][0]
    for t in range(T):
        live = 0 if t in noop else 1
        nr = int(rng.integers(max(n // 3, 1), n + 1)) if live else 0
        idx = rng.integers(0, max(nr, 1), (n, k)).astype(np.int32)
        coef = (rng.uniform(size=(n, k)) *
                (rng.uniform(size=(n, k)) > 0.4)).astype(np.float32)
        coef[nr:] = 0.0
        x = rng.normal(size=(n, din)).astype(np.float32)
        x[nr:] = 0.0
        mask = np.zeros(n, np.float32)
        mask[:nr] = 1.0
        idxs.append(idx); coefs.append(coef); xs.append(x)
        masks.append(mask); lives.append(live)
    stream = (np.stack(idxs), np.stack(coefs), np.stack(xs),
              np.stack(masks), np.asarray(lives, np.int32))
    ks = jax.random.split(jax.random.PRNGKey(seed + 1), 5)
    ws = [rand(jax.random.fold_in(ks[0], i), d) * 0.3
          for i, d in enumerate(dims)]
    bg = [rand(jax.random.fold_in(ks[1], i), (d[1],)) * 0.1
          for i, d in enumerate(dims)]
    gwx = [rand(jax.random.fold_in(ks[2], i), (d[0], 3 * d[0])) * 0.2
           for i, d in enumerate(dims)]
    gwh = [rand(jax.random.fold_in(ks[3], i), (d[0], 3 * d[0])) * 0.2
           for i, d in enumerate(dims)]
    gb = [rand(jax.random.fold_in(ks[4], i), (3 * d[0],)) * 0.1
          for i, d in enumerate(dims)]
    ea = None
    if edge:
        ea = [rand(jax.random.fold_in(ks[0], 100 + i), (T, n, d[0])) * 0.1
              for i, d in enumerate(dims)]
    return stream, ws, bg, gwx, gwh, gb, ea


def stream_kernel_case(family: str, seed: int = 0, T: int = 3, B=None,
                       n: int = 64, k: int = 4):
    """Kernel-level differential case for one registered stream-engine
    family: (args, oracle, d) such that
    ``ops.stream_steps[_batched](family, *args, tn=32, td=...)`` must
    equal ``oracle(*args)`` for ANY block size td, and ``d`` is the state
    feature width (pick td <= d // 2 to force a D-blocked layout).

    EVERY kernels/stream_fused.REGISTRY entry needs a builder here — the
    registry tests (tests/test_registry.py, mirrored as a CI matrix lane)
    parametrize over the registry, so registering a new family cell spec
    without adding its differential case fails CI by construction.
    """
    from repro.kernels import ref as _ref

    rand = lambda key, shape, s: np.asarray(
        jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)) * s
    if family == "gcrn":
        din, h, G, e = 12, 24, 2 * n + 9, 4 * n
        S = (random_ell_stream(seed, T, n, k, e, din, G) if B is None
             else random_ell_stream_batch(seed, B, T, n, k, e, din, G))
        lead = () if B is None else (B,)
        args = (*S, rand(seed + 1, lead + (G, h), 0.5),
                rand(seed + 2, lead + (G, h), 0.5),
                rand(seed + 3, (din, 4 * h), 0.2),
                rand(seed + 4, (h, 4 * h), 0.2),
                rand(seed + 5, (4 * h,), 0.1))
        oracle = (_ref.gcrn_stream_ref if B is None
                  else _ref.gcrn_stream_batched_ref)
        return args, oracle, h
    if family == "stacked":
        din, dmid, h, G, e = 12, 16, 24, 2 * n + 9, 4 * n
        S = (random_ell_stream(seed, T, n, k, e, din, G) if B is None
             else random_ell_stream_batch(seed, B, T, n, k, e, din, G))
        lead = () if B is None else (B,)
        args = (*S, rand(seed + 1, lead + (G, h), 0.5),
                rand(seed + 2, (din, dmid), 0.2),
                rand(seed + 3, (dmid,), 0.1),
                rand(seed + 4, (dmid, 3 * h), 0.2),
                rand(seed + 5, (h, 3 * h), 0.2),
                rand(seed + 6, (3 * h,), 0.1))
        oracle = (_ref.stacked_stream_ref if B is None
                  else _ref.stacked_stream_batched_ref)
        return args, oracle, h
    if family == "evolve":
        dims = [(12, 16), (16, 8)]
        if B is None:
            stream, ws, bg, gwx, gwh, gb, _ = random_evolve_inputs(
                seed, T, n, k, dims)
            return ((*stream, ws, bg, gwx, gwh, gb),
                    _ref.evolve_stream_ref, max(max(d) for d in dims))
        per = [random_evolve_inputs(seed + 97 * b, T, n, k, dims)
               for b in range(B)]
        S = tuple(np.stack([p[0][i] for p in per]) for i in range(5))
        _, _, bg, gwx, gwh, gb, _ = per[0]
        wsB = [np.stack([np.asarray(p[1][i]) for p in per])
               for i in range(len(dims))]
        return ((*S, wsB, bg, gwx, gwh, gb),
                _ref.evolve_stream_batched_ref, max(max(d) for d in dims))
    if family == "tgn":
        # event temporal contract: the eidx slot of the ELL layout carries
        # per-event float TIMESTAMPS instead of edge ids. random_ell_stream
        # already guarantees the event contract the kernel assumes (every
        # nonzero-coef lane references a masked-in row).
        din, h, G, e = 12, 24, 2 * n + 9, 4 * n
        S = (random_ell_stream(seed, T, n, k, e, din, G) if B is None
             else random_ell_stream_batch(seed, B, T, n, k, e, din, G))
        idx, coef, _eidx, x, ren, mask = S
        ts = np.random.default_rng(seed + 7).uniform(
            0.0, 8.0, idx.shape).astype(np.float32)
        lead = () if B is None else (B,)
        args = (idx, coef, ts, x, ren, mask,
                rand(seed + 1, lead + (G, h), 0.5),       # mem0
                np.abs(rand(seed + 2, (h,), 0.5)) + 0.05,  # freq
                rand(seed + 3, (din, h), 0.2),             # w_in
                rand(seed + 4, (h, 3 * h), 0.2),           # gru wx
                rand(seed + 5, (h, 3 * h), 0.2),           # gru wh
                rand(seed + 6, (3 * h,), 0.1))             # gru b
        oracle = (_ref.tgn_stream_ref if B is None
                  else _ref.tgn_stream_batched_ref)
        return args, oracle, h
    if family == "static_gcn":
        # static temporal contract: T == 1 by construction (the cell spec
        # rejects anything else — independent snapshots fold onto the
        # batch axis), so the case ignores the T argument.
        dims = [(12, 16), (16, 8)]
        din, e, G = dims[0][0], 4 * n, 2 * n + 9
        S = (random_ell_stream(seed, 1, n, k, e, din, G) if B is None
             else random_ell_stream_batch(seed, B, 1, n, k, e, din, G))
        idx, coef, _eidx, x, _ren, mask = S
        ws = [rand(seed + 10 + i, d, 0.3) for i, d in enumerate(dims)]
        bs = [rand(seed + 20 + i, (d[1],), 0.1) for i, d in enumerate(dims)]
        args = (idx, coef, x, mask, ws, bs, None)
        oracle = (_ref.static_gcn_stream_ref if B is None
                  else _ref.static_gcn_stream_batched_ref)
        return args, oracle, max(max(d) for d in dims)
    raise KeyError(
        f"no kernel-level differential case for stream family {family!r}: "
        "a cell spec was registered in kernels/stream_fused.REGISTRY "
        "without test coverage — add a builder here")


# ------------------------------------------------ padding invariants ----
# Shared by plain regression tests (always run) and hypothesis property
# tests (test_property.py, when hypothesis is installed).

def check_pad_unpad_roundtrip(ls, feat_table: np.ndarray,
                              bucket: tuple[int, int, int]):
    """pad_snapshot -> unpad_snapshot returns the live data unchanged, and
    the padding obeys the sink-row coef-0 convention."""
    n_pad, e_pad, k_max = bucket
    ps = pad_snapshot(ls, feat_table, n_pad, e_pad, k_max)
    up = unpad_snapshot(ps)
    e, n = ls.src.shape[0], ls.n_nodes
    np.testing.assert_array_equal(up["src"], ls.src)
    np.testing.assert_array_equal(up["dst"], ls.dst)
    np.testing.assert_allclose(up["coef"], ls.coef, rtol=1e-6)
    np.testing.assert_allclose(up["edge_feat"], ls.edge_feat, rtol=1e-6)
    np.testing.assert_array_equal(up["renumber"], ls.renumber)
    np.testing.assert_allclose(up["node_feat"], feat_table[ls.renumber],
                               rtol=1e-6)
    # sink-row coef-0 convention on the COO padding
    src, dst, coef = map(np.asarray, (ps.src, ps.dst, ps.coef))
    assert (coef[e:] == 0).all()
    assert (src[e:] == n_pad - 1).all() and (dst[e:] == n_pad - 1).all()
    # node-side padding: mask 0, renumber -1 (scatter-drop sentinel)
    mask, ren = np.asarray(ps.node_mask), np.asarray(ps.renumber)
    assert (mask[:n] == 1).all() and (mask[n:] == 0).all()
    assert (ren[n:] == -1).all()
    # ELL padding lanes are killed by coef 0 and conserve the edge weights
    ncoef = np.asarray(ps.neigh_coef)
    assert (ncoef[n:] == 0).all()
    np.testing.assert_allclose(ncoef.sum(), ls.coef.sum(), rtol=1e-5)


def check_choose_bucket_smallest_fit(n: int, e: int, k: int, buckets):
    """choose_bucket returns the FIRST (smallest) fitting bucket and no
    earlier bucket fits."""
    b = choose_bucket(n, e, k, buckets)
    i = buckets.index(b)
    assert n <= b[0] and e <= b[1] and k <= b[2]
    for earlier in buckets[:i]:
        assert not (n <= earlier[0] and e <= earlier[1] and k <= earlier[2])


def check_bucket_monotone(dims, buckets):
    """choose_bucket is monotone on a nested bucket chain, and the batch
    bucket covers (is >= in chain order than) every member's own bucket."""
    order = {b: i for i, b in enumerate(buckets)}
    bb = choose_bucket_batch(dims, buckets)
    for d in dims:
        own = choose_bucket(*d, buckets)
        assert order[bb] >= order[own]
        assert d[0] <= bb[0] and d[1] <= bb[1] and d[2] <= bb[2]
