"""AdamW: convergence, quantized-state variants, schedule, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    AdamWConfig,
    apply_updates,
    dequantize_blockwise,
    global_norm,
    init_opt_state,
    quantize_blockwise,
    schedule,
)


def _quadratic_losses(state_dtype, steps=60):
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, state_dtype=state_dtype,
                      warmup_steps=0, total_steps=10**6)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 256)), jnp.float32)
    params = {"w": jnp.zeros((4, 256))}
    opt = init_opt_state(params, cfg)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(
            lambda q: jnp.mean((q["w"] - target) ** 2))(p)
        p, o, _ = apply_updates(p, g, o, cfg)
        return p, o, loss

    losses = []
    for _ in range(steps):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("state_dtype", ["fp32", "bf16", "int8"])
def test_adamw_converges(state_dtype):
    losses = _quadratic_losses(state_dtype)
    assert losses[-1] < 0.05 * losses[0], losses[-10:]


def test_int8_matches_fp32_closely():
    a = _quadratic_losses("fp32", steps=30)
    b = _quadratic_losses("int8", steps=30)
    assert abs(a[-1] - b[-1]) < 0.1 * (a[0] + 1e-9) + 0.05


@pytest.mark.parametrize("shape", [(256,), (3, 256), (5, 7, 128), (2, 80)])
def test_quantize_roundtrip_error_bound(shape):
    x = jnp.asarray(np.random.default_rng(1).normal(size=shape), jnp.float32)
    qd = quantize_blockwise(x)
    back = dequantize_blockwise(qd, x.shape)
    assert back.shape == x.shape
    # absmax blockwise: error <= scale/2 elementwise
    err = np.abs(np.asarray(back - x))
    bound = np.asarray(qd["scale"]).max() * 0.5 + 1e-7
    assert err.max() <= bound


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    s = [float(schedule(cfg, jnp.asarray(i))) for i in range(101)]
    assert s[0] == 0.0
    assert abs(s[10] - 1.0) < 0.11
    assert s[100] == pytest.approx(0.1, abs=1e-5)
    assert all(a >= b - 1e-9 for a, b in zip(s[10:], s[11:]))  # monotone decay


def test_grad_clipping_applies():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((8,))}
    opt = init_opt_state(params, cfg)
    huge = {"w": jnp.full((8,), 1e6)}
    p1, _, m = apply_updates(params, huge, opt, cfg)
    assert float(m["grad_norm"]) > 1e6
    # post-clip first-step delta is bounded by lr (adam: |update| ~ lr)
    assert np.abs(np.asarray(p1["w"])).max() < 2 * cfg.lr
