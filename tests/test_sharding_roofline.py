"""Sharding rules resolution + roofline parsing/math."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cell_status, runnable_cells
from repro.distributed.api import Axes, resolve_spec, sharding_ctx
from repro.roofline import Roofline, collective_bytes, model_flops
from repro.roofline.corrections import total_corrections


def test_resolve_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    # with axis size 1 everything degrades to replication
    spec = resolve_spec((64, 128), ("batch", "ffn"), mesh)
    assert spec == P(None, None)


def test_resolve_spec_no_mesh_passthrough():
    spec = resolve_spec((64, 128), ("batch", "ffn"), mesh=None)
    assert spec == P(("pod", "data"), "model")


def test_padding_policies():
    c = ARCHS["deepseek-coder-33b"]
    assert c.padded_heads(16) == 64      # 56 -> 64
    assert c.padded_kv_heads(16) == 16   # 8 -> repeat to 16
    g = ARCHS["granite-moe-3b-a800m"]
    assert g.padded_experts(16) == 48    # 40 -> 48
    assert g.padded_vocab() % 256 == 0


def test_cell_skip_rules():
    assert cell_status(ARCHS["hubert-xlarge"], SHAPES["decode_32k"]).startswith("skip")
    assert cell_status(ARCHS["deepseek-coder-33b"], SHAPES["long_500k"]).startswith("skip")
    assert cell_status(ARCHS["jamba-v0.1-52b"], SHAPES["long_500k"]) == "run"
    assert cell_status(ARCHS["mamba2-2.7b"], SHAPES["long_500k"]) == "run"
    assert len(runnable_cells()) == 31


HLO = """
HloModule test
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[16,2048]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[16,2048]{1,0} all-reduce(%ag), to_apply=sum
  %a2a = f32[16,2048]{1,0} all-to-all(%ar), dimensions={0}
  %cp = f32[16,2048]{1,0} collective-permute(%a2a), source_target_pairs={{0,1}}
  %rs.1 = f32[16,128]{1,0} reduce-scatter(%cp), dimensions={1}
  ROOT %out = f32[16,128]{1,0} add(%rs.1, %p0)
}
"""


def test_collective_bytes_parsing():
    st = collective_bytes(HLO)
    assert st.count_by_op == {"all-gather": 1, "all-reduce": 1,
                              "all-to-all": 1, "collective-permute": 1,
                              "reduce-scatter": 1}
    # all-gather operand = p0 = 16*128*4 bytes
    assert st.bytes_by_op["all-gather"] == 16 * 128 * 4
    assert st.bytes_by_op["all-reduce"] == 16 * 2048 * 4
    assert st.bytes_by_op["reduce-scatter"] == 16 * 2048 * 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, bytes_hbm=819e9 * 2, bytes_coll=0, chips=256,
                 model_flops=197e12 * 256 * 0.5)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.bottleneck == "memory"
    assert r.roofline_fraction == pytest.approx(0.25)
    assert r.useful_ratio == pytest.approx(0.5)


def test_model_flops_scales():
    dense = model_flops(ARCHS["deepseek-coder-33b"], SHAPES["train_4k"])
    # >= 6*N*D
    assert dense >= 6 * ARCHS["deepseek-coder-33b"].param_count() * 256 * 4096
    moe = model_flops(ARCHS["llama4-maverick-400b-a17b"], SHAPES["train_4k"])
    # active params only: far below 6*N_total*D
    assert moe < 6 * ARCHS["llama4-maverick-400b-a17b"].param_count() * 256 * 4096 / 5


def test_corrections_are_itemized_and_nonnegative():
    c = total_corrections(ARCHS["mamba2-2.7b"], SHAPES["prefill_32k"], 16,
                          2048, 512)
    assert c["flops"] >= 0 and c["bytes_hbm"] >= 0
    sites = {i["site"] for i in c["items"]}
    assert "ssd" in sites
    c2 = total_corrections(ARCHS["phi3-mini-3.8b"], SHAPES["train_4k"], 16,
                           2048, 512)
    assert {i["site"] for i in c2["items"]} == {"attention", "loss"}
