"""The paper's correctness contract: every dataflow engine (baseline /
O1 / V1 / V2) computes identical outputs for the same weights + stream."""
import jax
import numpy as np
import pytest

from repro.configs.dgnn import BC_ALPHA, DGNN_CONFIGS
from repro.core import build_model, run_batched, run_stream, stack_time
from repro.graph import (
    generate_temporal_graph,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)

MODES = {
    "evolvegcn": ["baseline", "o1", "v1", "v3"],   # v3 -> documented v1 fallback
    "gcrn-m2": ["baseline", "o1", "v2", "v3"],
    "stacked-gcn-gru": ["baseline", "o1", "v1", "v2", "v3"],
}


@pytest.fixture(scope="module")
def stream():
    tg, ft = generate_temporal_graph(BC_ALPHA)
    snaps = slice_snapshots(tg, 1.0)[:8]
    pads = [pad_snapshot(renumber_and_normalize(s), ft, 640, 4096, 64)
            for s in snaps]
    return tg, stack_time(pads)


@pytest.mark.parametrize("name", sorted(DGNN_CONFIGS))
def test_dataflow_modes_identical(stream, name):
    tg, sT = stream
    cfg = DGNN_CONFIGS[name]
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(0))
    outs = {}
    for mode in MODES[name]:
        st = model.init_state(params, mode=mode)
        _, o = run_stream(model, params, st, sT, mode=mode)
        outs[mode] = np.asarray(o)
    base = outs["baseline"]
    assert np.isfinite(base).all()
    assert np.abs(base).max() > 0  # non-degenerate
    for mode, o in outs.items():
        np.testing.assert_allclose(o, base, atol=2e-5,
                                   err_msg=f"{name} mode={mode}")


@pytest.mark.parametrize("name", sorted(DGNN_CONFIGS))
def test_recurrence_actually_carries_state(stream, name):
    """Shuffling the stream must change outputs (temporal dependence)."""
    tg, sT = stream
    cfg = DGNN_CONFIGS[name]
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(0))
    st = model.init_state(params, mode="baseline")
    _, o1 = run_stream(model, params, st, sT, mode="baseline")
    rev = jax.tree.map(lambda a: a[::-1], sT)
    st = model.init_state(params, mode="baseline")
    _, o2 = run_stream(model, params, st, rev, mode="baseline")
    # last outputs differ because recurrent state path differs
    assert not np.allclose(np.asarray(o1)[-1], np.asarray(o2)[0])


def test_batched_streams(stream):
    tg, sT = stream
    cfg = DGNN_CONFIGS["gcrn-m2"]
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(0))
    B = 3
    sTB = jax.tree.map(lambda a: np.stack([a] * B, axis=1), sT)
    states = jax.tree.map(
        lambda a: np.stack([np.asarray(a)] * B, axis=0),
        model.init_state(params, mode="baseline"))
    _, oB = run_batched(model, params, states, sTB, mode="baseline")
    st = model.init_state(params, mode="baseline")
    _, o1 = run_stream(model, params, st, sT, mode="baseline")
    # identical streams -> identical outputs per lane
    for b in range(B):
        np.testing.assert_allclose(np.asarray(oB)[:, b], np.asarray(o1),
                                   atol=1e-5)
