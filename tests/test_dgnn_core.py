"""The paper's correctness contract on the REAL synthetic datasets: every
dataflow engine computes identical outputs for the same weights + stream.

The mode lists and comparison loop live in tests/harness.py (shared with
the random-stream differential tests in test_differential.py)."""
import jax
import numpy as np
import pytest

import harness
from repro.configs.dgnn import BC_ALPHA, DGNN_CONFIGS
from repro.core import (
    build_model,
    init_states_batched,
    run_batched,
    run_stream,
    stack_time,
)
from repro.graph import (
    generate_temporal_graph,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)


@pytest.fixture(scope="module")
def stream():
    tg, ft = generate_temporal_graph(BC_ALPHA)
    snaps = slice_snapshots(tg, 1.0)[:8]
    pads = [pad_snapshot(renumber_and_normalize(s), ft, 640, 4096, 64)
            for s in snaps]
    return tg, stack_time(pads)


@pytest.mark.parametrize("name", sorted(DGNN_CONFIGS))
def test_dataflow_modes_identical(stream, name):
    tg, sT = stream
    cfg = DGNN_CONFIGS[name]
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(0))
    outs, _ = harness.run_all_modes(model, params, sT, harness.MODES[name])
    harness.assert_modes_match(outs, atol=2e-5, label=name)


@pytest.mark.parametrize("name", sorted(DGNN_CONFIGS))
def test_recurrence_actually_carries_state(stream, name):
    """Shuffling the stream must change outputs (temporal dependence) —
    except for the "static" temporal contract, whose whole point is the
    ABSENCE of recurrence: reversing the stream must permute outputs
    without changing any of them (order equivariance)."""
    from repro.kernels.ops import family_temporal

    tg, sT = stream
    cfg = DGNN_CONFIGS[name]
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(0))
    st = model.init_state(params, mode="baseline")
    _, o1 = run_stream(model, params, st, sT, mode="baseline")
    rev = jax.tree.map(lambda a: a[::-1], sT)
    st = model.init_state(params, mode="baseline")
    _, o2 = run_stream(model, params, st, rev, mode="baseline")
    if family_temporal(model.stream_family) == "static":
        np.testing.assert_allclose(np.asarray(o1)[-1], np.asarray(o2)[0],
                                   atol=1e-6)
        return
    # last outputs differ because recurrent state path differs
    assert not np.allclose(np.asarray(o1)[-1], np.asarray(o2)[0])


@pytest.mark.parametrize("mode", ["baseline", "v3"])
def test_batched_streams(stream, mode):
    """run_batched == per-stream run_stream on identical replicated rows;
    mode="v3" takes the single-launch batched stream kernel."""
    tg, sT = stream
    cfg = DGNN_CONFIGS["gcrn-m2"]
    model = build_model(cfg, n_global=tg.n_global_nodes)
    params = model.init(jax.random.PRNGKey(0))
    B = 3
    sTB = jax.tree.map(lambda a: np.stack([a] * B, axis=1), sT)
    states = init_states_batched(model, params, B, mode=mode)
    _, oB = run_batched(model, params, states, sTB, mode=mode)
    st = model.init_state(params, mode=mode)
    _, o1 = run_stream(model, params, st, sT, mode=mode)
    # identical streams -> identical outputs per lane
    for b in range(B):
        np.testing.assert_allclose(np.asarray(oB)[:, b], np.asarray(o1),
                                   atol=1e-5)
