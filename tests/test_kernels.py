"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes.

Kernels run in interpret mode on CPU (same tiling as the TPU build)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _ell(key, n, k, e):
    k1, k2, k3 = jax.random.split(key, 3)
    idx = jax.random.randint(k1, (n, k), 0, n)
    coef = jax.random.uniform(k2, (n, k))
    # kill ~half the lanes (padding semantics)
    coef = coef * (jax.random.uniform(k3, (n, k)) > 0.5)
    eidx = jax.random.randint(k1, (n, k), 0, e)
    return idx.astype(jnp.int32), coef.astype(jnp.float32), eidx.astype(jnp.int32)


@pytest.mark.parametrize("n,k,d", [(128, 8, 32), (256, 16, 64), (640, 32, 128)])
@pytest.mark.parametrize("edge", [False, True])
def test_ell_spmm(n, k, d, edge):
    e = 4 * n
    idx, coef, eidx = _ell(KEY, n, k, e)
    x = _rand(jax.random.PRNGKey(1), (n, d))
    em = _rand(jax.random.PRNGKey(2), (e, d)) if edge else None
    got = ops.ell_spmm(idx, coef, eidx, x, em, tn=128)
    want = ref.ell_spmm(idx, coef, eidx, x, em)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("b,din,h", [(128, 32, 64), (256, 64, 128), (384, 128, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_fused_gru(b, din, h, dtype):
    ks = jax.random.split(KEY, 5)
    x = _rand(ks[0], (b, din), dtype)
    hh = _rand(ks[1], (b, h), dtype)
    wx = _rand(ks[2], (din, 3 * h), dtype)
    wh = _rand(ks[3], (h, 3 * h), dtype)
    bb = _rand(ks[4], (3 * h,), dtype)
    got = ops.fused_gru(x, hh, wx, wh, bb, tb=128)
    want = ref.fused_gru(x, hh, wx, wh, bb)
    np.testing.assert_allclose(got, want, atol=2e-4)


@pytest.mark.parametrize("b,din,h", [(128, 32, 64), (256, 48, 128)])
def test_fused_lstm(b, din, h):
    ks = jax.random.split(KEY, 6)
    x = _rand(ks[0], (b, din))
    hh = _rand(ks[1], (b, h))
    cc = _rand(ks[2], (b, h))
    wx = _rand(ks[3], (din, 4 * h))
    wh = _rand(ks[4], (h, 4 * h))
    bb = _rand(ks[5], (4 * h,))
    gh, gc = ops.fused_lstm(x, hh, cc, wx, wh, bb, tb=128)
    wh_, wc_ = ref.fused_lstm(x, hh, cc, wx, wh, bb)
    np.testing.assert_allclose(gh, wh_, atol=2e-4)
    np.testing.assert_allclose(gc, wc_, atol=2e-4)


@pytest.mark.parametrize("n,k,din,h", [(128, 8, 32, 64), (256, 16, 64, 128)])
@pytest.mark.parametrize("edge", [False, True])
def test_dgnn_fused_gcrn(n, k, din, h, edge):
    e = 4 * n
    idx, coef, eidx = _ell(KEY, n, k, e)
    ks = jax.random.split(jax.random.PRNGKey(3), 7)
    x = _rand(ks[0], (n, din))
    hh = _rand(ks[1], (n, h))
    cc = _rand(ks[2], (n, h))
    wx = _rand(ks[3], (din, 4 * h))
    wh = _rand(ks[4], (h, 4 * h))
    bb = _rand(ks[5], (4 * h,))
    em = _rand(ks[6], (e, din)) if edge else None
    gh, gc = ops.dgnn_fused_step(idx, coef, eidx, x, hh, cc, wx, wh, bb, em, tn=128)
    wh_, wc_ = ref.dgnn_fused_step(idx, coef, eidx, x, hh, cc, wx, wh, bb, em)
    np.testing.assert_allclose(gh, wh_, atol=2e-4)
    np.testing.assert_allclose(gc, wc_, atol=2e-4)


@pytest.mark.parametrize("n,k,din,dmid,h", [(128, 8, 32, 48, 64), (256, 16, 64, 64, 128)])
def test_stacked_fused(n, k, din, dmid, h):
    e = 4 * n
    idx, coef, eidx = _ell(KEY, n, k, e)
    ks = jax.random.split(jax.random.PRNGKey(4), 7)
    x = _rand(ks[0], (n, din))
    hh = _rand(ks[1], (n, h))
    wg = _rand(ks[2], (din, dmid))
    bg = _rand(ks[3], (dmid,))
    wx = _rand(ks[4], (dmid, 3 * h))
    wh = _rand(ks[5], (h, 3 * h))
    bb = _rand(ks[6], (3 * h,))
    got = ops.stacked_fused_step(idx, coef, eidx, x, hh, wg, bg, wx, wh, bb, tn=128)
    want = ref.stacked_fused_step(idx, coef, eidx, x, hh, wg, bg, wx, wh, bb)
    np.testing.assert_allclose(got, want, atol=2e-4)


def _ragged_slice(n_r, idx, coef, eidx, *rest):
    """Trim ELL arrays to a ragged (non-tile-multiple) node count."""
    idx = jnp.clip(idx[:n_r], 0, n_r - 1)
    return (idx, coef[:n_r], eidx[:n_r]) + tuple(a[:n_r] for a in rest)


@pytest.mark.parametrize("n_r", [200, 130, 127])
def test_auto_padding_ragged_n(n_r):
    """Regression: node counts that are NOT a multiple of the node tile are
    auto-padded (sink-row coef-0 convention) instead of asserting."""
    n, k, din, h = 256, 8, 32, 64
    e = 4 * n
    idx0, coef0, eidx0 = _ell(KEY, n, k, e)
    ks = jax.random.split(jax.random.PRNGKey(8), 7)
    x0 = _rand(ks[0], (n, din))
    hh0 = _rand(ks[1], (n, h))
    cc0 = _rand(ks[2], (n, h))
    idx, coef, eidx, x, hh, cc = _ragged_slice(n_r, idx0, coef0, eidx0,
                                               x0, hh0, cc0)
    em = _rand(ks[6], (e, din))
    # ELL SpMM
    got = ops.ell_spmm(idx, coef, eidx, x, em, tn=128)
    want = ref.ell_spmm(idx, coef, eidx, x, em)
    assert got.shape == (n_r, din)
    np.testing.assert_allclose(got, want, atol=1e-5)
    # fused GCRN step
    wx = _rand(ks[3], (din, 4 * h))
    wh = _rand(ks[4], (h, 4 * h))
    bb = _rand(ks[5], (4 * h,))
    gh, gc = ops.dgnn_fused_step(idx, coef, eidx, x, hh, cc, wx, wh, bb,
                                 tn=128)
    wh_, wc_ = ref.dgnn_fused_step(idx, coef, eidx, x, hh, cc, wx, wh, bb)
    assert gh.shape == (n_r, h)
    np.testing.assert_allclose(gh, wh_, atol=2e-4)
    np.testing.assert_allclose(gc, wc_, atol=2e-4)
    # fused stacked step
    wg = _rand(ks[2], (din, 48))
    bg = _rand(ks[3], (48,))
    wx2 = _rand(ks[4], (48, 3 * h))
    wh2 = _rand(ks[5], (h, 3 * h))
    b2 = _rand(ks[6], (3 * h,))
    got2 = ops.stacked_fused_step(idx, coef, eidx, x, hh, wg, bg, wx2, wh2,
                                  b2, tn=128)
    want2 = ref.stacked_fused_step(idx, coef, eidx, x, hh, wg, bg, wx2, wh2, b2)
    assert got2.shape == (n_r, h)
    np.testing.assert_allclose(got2, want2, atol=2e-4)


from harness import random_ell_stream, random_ell_stream_batch


@pytest.mark.parametrize("T,n,k,din,h", [(4, 128, 8, 32, 64), (6, 256, 16, 64, 128)])
@pytest.mark.parametrize("edge", [False, True])
@pytest.mark.parametrize("td", [None, 32])
def test_gcrn_stream_kernel(T, n, k, din, h, edge, td):
    """Stream-engine V3 == per-step scan oracle (GCRN), fully resident
    (td=None) and D-blocked (d//td >= 2) alike."""
    e, G = 4 * n, 2 * n + 17
    idx, coef, eidx, x, ren, mask = random_ell_stream(11, T, n, k, e, din, G)
    ks = jax.random.split(jax.random.PRNGKey(12), 6)
    wx = _rand(ks[0], (din, 4 * h)) * 0.2
    wh = _rand(ks[1], (h, 4 * h)) * 0.2
    bb = _rand(ks[2], (4 * h,)) * 0.1
    h0 = _rand(ks[3], (G, h)) * 0.5
    c0 = _rand(ks[4], (G, h)) * 0.5
    em = _rand(ks[5], (T, e, din)) if edge else None
    got = ops.stream_steps("gcrn", idx, coef, eidx, x, ren, mask, h0, c0,
                           wx, wh, bb, em, tn=128, td=td)
    want = ref.gcrn_stream_ref(idx, coef, eidx, x, ren, mask, h0, c0,
                               wx, wh, bb, em)
    for g, w, nm in zip(got, want, ("outs", "h_final", "c_final")):
        np.testing.assert_allclose(g, w, atol=2e-4, err_msg=nm)


@pytest.mark.parametrize("T,n,k,din,dmid,h", [(5, 128, 8, 32, 48, 64)])
@pytest.mark.parametrize("edge", [False, True])
@pytest.mark.parametrize("td", [None, 16])
def test_stacked_stream_kernel(T, n, k, din, dmid, h, edge, td):
    """Stream-engine V3 == per-step scan oracle (stacked), resident and
    D-blocked."""
    e, G = 4 * n, 2 * n + 5
    idx, coef, eidx, x, ren, mask = random_ell_stream(13, T, n, k, e, din, G)
    ks = jax.random.split(jax.random.PRNGKey(14), 7)
    wg = _rand(ks[0], (din, dmid)) * 0.2
    bg = _rand(ks[1], (dmid,)) * 0.1
    wx = _rand(ks[2], (dmid, 3 * h)) * 0.2
    wh = _rand(ks[3], (h, 3 * h)) * 0.2
    bb = _rand(ks[4], (3 * h,)) * 0.1
    h0 = _rand(ks[5], (G, h)) * 0.5
    em = _rand(ks[6], (T, e, din)) if edge else None
    got = ops.stream_steps("stacked", idx, coef, eidx, x, ren, mask, h0,
                           wg, bg, wx, wh, bb, em, tn=128, td=td)
    want = ref.stacked_stream_ref(idx, coef, eidx, x, ren, mask, h0,
                                  wg, bg, wx, wh, bb, em)
    for g, w, nm in zip(got, want, ("outs", "h_final")):
        np.testing.assert_allclose(g, w, atol=2e-4, err_msg=nm)


def test_stream_kernel_ragged_n():
    """V3 auto-pads a node count that is not a tile multiple."""
    T, n, k, din, h = 4, 200, 8, 32, 64
    e, G = 4 * n, 600
    idx, coef, eidx, x, ren, mask = random_ell_stream(15, T, n, k, e, din, G)
    ks = jax.random.split(jax.random.PRNGKey(16), 5)
    wx = _rand(ks[0], (din, 4 * h)) * 0.2
    wh = _rand(ks[1], (h, 4 * h)) * 0.2
    bb = _rand(ks[2], (4 * h,)) * 0.1
    h0 = _rand(ks[3], (G, h)) * 0.5
    c0 = _rand(ks[4], (G, h)) * 0.5
    got = ops.stream_steps("gcrn", idx, coef, eidx, x, ren, mask, h0, c0,
                                wx, wh, bb, tn=128)
    want = ref.gcrn_stream_ref(idx, coef, eidx, x, ren, mask, h0, c0,
                               wx, wh, bb)
    assert got[0].shape == (T, n, h)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=2e-4)


@pytest.mark.parametrize("B,T,n,k,din,h", [(3, 4, 128, 8, 32, 64)])
@pytest.mark.parametrize("edge", [False, True])
def test_gcrn_stream_kernel_batched(B, T, n, k, din, h, edge):
    """Batched time-fused V3: B streams in one launch == vmapped oracle ==
    per-stream unbatched launches row-sliced (GCRN)."""
    e, G = 4 * n, 2 * n + 17
    S = random_ell_stream_batch(21, B, T, n, k, e, din, G)
    ks = jax.random.split(jax.random.PRNGKey(22), 6)
    wx = _rand(ks[0], (din, 4 * h)) * 0.2
    wh = _rand(ks[1], (h, 4 * h)) * 0.2
    bb = _rand(ks[2], (4 * h,)) * 0.1
    h0 = _rand(ks[3], (B, G, h)) * 0.5
    c0 = _rand(ks[4], (B, G, h)) * 0.5
    em = _rand(ks[5], (B, T, e, din)) if edge else None
    got = ops.stream_steps_batched("gcrn", *S, h0, c0, wx, wh, bb, em, tn=128)
    want = ref.gcrn_stream_batched_ref(*[jnp.asarray(s) for s in S], h0, c0,
                                       wx, wh, bb, em)
    for g, w, nm in zip(got, want, ("outs", "h_final", "c_final")):
        np.testing.assert_allclose(g, w, atol=2e-4, err_msg=nm)
    for b in range(B):
        solo = ops.stream_steps("gcrn", *[s[b] for s in S], h0[b], c0[b],
                                     wx, wh, bb,
                                     None if em is None else em[b], tn=128)
        for g, s_ in zip(got, solo):
            np.testing.assert_allclose(np.asarray(g)[b], s_, atol=2e-4)


def test_stacked_stream_kernel_batched():
    """Batched time-fused V3 == vmapped oracle (stacked GCN->GRU)."""
    B, T, n, k, din, dmid, h = 2, 5, 128, 8, 32, 48, 64
    e, G = 4 * n, 2 * n + 5
    S = random_ell_stream_batch(23, B, T, n, k, e, din, G)
    ks = jax.random.split(jax.random.PRNGKey(24), 7)
    wg = _rand(ks[0], (din, dmid)) * 0.2
    bg = _rand(ks[1], (dmid,)) * 0.1
    wx = _rand(ks[2], (dmid, 3 * h)) * 0.2
    wh = _rand(ks[3], (h, 3 * h)) * 0.2
    bb = _rand(ks[4], (3 * h,)) * 0.1
    h0 = _rand(ks[5], (B, G, h)) * 0.5
    got = ops.stream_steps_batched("stacked", *S, h0, wg, bg, wx, wh, bb, tn=128)
    want = ref.stacked_stream_batched_ref(*[jnp.asarray(s) for s in S], h0,
                                          wg, bg, wx, wh, bb)
    for g, w, nm in zip(got, want, ("outs", "h_final")):
        np.testing.assert_allclose(g, w, atol=2e-4, err_msg=nm)


def test_kernel_vs_segment_sum_production_path():
    """ELL kernel == the XLA segment-sum path on a real padded snapshot."""
    from repro.configs.dgnn import UCI
    from repro.core.gcn import propagate_segment
    from repro.graph import (
        generate_temporal_graph, pad_snapshot, renumber_and_normalize,
        slice_snapshots)

    tg, ft = generate_temporal_graph(UCI)
    snap = slice_snapshots(tg, 1.0)[0]
    ps = pad_snapshot(renumber_and_normalize(snap), ft, 640, 4096, 64)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(640, 64)), jnp.float32)
    a = propagate_segment(ps, x)
    b = ops.ell_spmm(ps.neigh_idx, ps.neigh_coef, ps.neigh_eidx, x, tn=128)
    np.testing.assert_allclose(a, b, atol=1e-4)


@pytest.mark.parametrize("s,bq,bk", [(128, 32, 32), (256, 64, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("group", [1, 4])
def test_flash_attention(s, bq, bk, causal, group):
    """Flash kernel (interpret) vs the grouped-einsum oracle, incl. GQA."""
    from repro.nn import attention as A

    b, hkv, hd = 2, 2, 32
    hq = hkv * group
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    want = A.full_attention(q, k, v, causal=causal)
    got = A.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_flash_flops_accounting_causal_saves_half():
    from repro.kernels.flash_attention import flops_bytes

    full = flops_bytes(1, 8, 8, 4096, 128, causal=False)
    caus = flops_bytes(1, 8, 8, 4096, 128, causal=True)
    assert caus["flops"] < 0.6 * full["flops"]
    assert caus["flops"] > 0.45 * full["flops"]


from harness import random_evolve_inputs as _evolve_inputs


@pytest.mark.parametrize("T,n,k", [(4, 128, 8), (5, 200, 12)])
@pytest.mark.parametrize("edge", [False, True])
@pytest.mark.parametrize("td", [None, 16])
def test_evolve_stream_kernel(T, n, k, edge, td):
    """Weights-resident V3 through the stream engine == per-step scan
    oracle (EvolveGCN): per-step outputs AND final evolved weights, incl.
    a ragged (non-tile-multiple) node count and a D-blocked evolving-W
    layout (dmax//td >= 2)."""
    dims = [(24, 40), (40, 16)]
    stream, ws, bg, gwx, gwh, gb, ea = _evolve_inputs(31, T, n, k, dims,
                                                      edge=edge)
    got = ops.stream_steps("evolve", *stream, ws, bg, gwx, gwh, gb, ea,
                           tn=128, td=td)
    want = ref.evolve_stream_ref(*stream, ws, bg, gwx, gwh, gb, ea)
    assert got[0].shape == (T, n, dims[-1][1])
    np.testing.assert_allclose(got[0], want[0], atol=2e-4, err_msg="outs")
    for i, (g, w) in enumerate(zip(got[1], want[1])):
        np.testing.assert_allclose(g, w, atol=2e-4, err_msg=f"weights[{i}]")


def test_evolve_stream_kernel_noop_steps_freeze_weights():
    """live=0 (all-padding) steps produce zero outputs and must NOT
    advance the in-kernel matrix-GRU — the serve-chunk tail-padding
    contract. Final weights equal those of the live prefix alone."""
    T, n, k = 6, 128, 8
    dims = [(24, 40), (40, 16)]
    stream, ws, bg, gwx, gwh, gb, _ = _evolve_inputs(
        37, T, n, k, dims, noop=(4, 5))  # live prefix of 4, no-op tail of 2
    outs, wT = ops.stream_steps("evolve", *stream, ws, bg, gwx, gwh, gb, tn=128)
    assert np.abs(np.asarray(outs)[4:]).max() == 0.0
    prefix = tuple(a[:4] for a in stream)
    _, wT_prefix = ops.stream_steps("evolve", *prefix, ws, bg, gwx, gwh, gb,
                                           tn=128)
    for i, (g, w) in enumerate(zip(wT, wT_prefix)):
        np.testing.assert_allclose(g, w, atol=1e-6,
                                   err_msg=f"weights[{i}] moved on no-op")


@pytest.mark.parametrize("edge", [False, True])
def test_evolve_stream_kernel_batched(edge):
    """Batched weights-resident V3: B streams (distinct weights, shared
    GRU params) in one launch == vmapped oracle == per-stream unbatched
    launches row-sliced."""
    B, T, n, k = 3, 4, 128, 8
    dims = [(24, 40), (40, 16)]
    per = [_evolve_inputs(41 + 7 * b, T, n, k, dims, edge=edge)
           for b in range(B)]
    S = tuple(np.stack([p[0][i] for p in per]) for i in range(5))
    _, ws0, bg, gwx, gwh, gb, ea0 = per[0]
    wsB = [jnp.stack([jnp.asarray(p[1][i]) * (1.0 + 0.05 * b)
                      for b, p in enumerate(per)])
           for i in range(len(dims))]
    eaB = None
    if edge:
        eaB = [jnp.stack([p[6][i] for p in per]) for i in range(len(dims))]
    got = ops.stream_steps_batched("evolve", *S, wsB, bg, gwx, gwh, gb, eaB,
                                          tn=128)
    want = ref.evolve_stream_batched_ref(*[jnp.asarray(s) for s in S], wsB,
                                         bg, gwx, gwh, gb, eaB)
    np.testing.assert_allclose(got[0], want[0], atol=2e-4, err_msg="outs")
    for i, (g, w) in enumerate(zip(got[1], want[1])):
        np.testing.assert_allclose(g, w, atol=2e-4, err_msg=f"weights[{i}]")
    for b in range(B):
        solo = ops.stream_steps("evolve", 
            *[s[b] for s in S], [w[b] for w in wsB], bg, gwx, gwh, gb,
            None if eaB is None else [e[b] for e in eaB], tn=128)
        np.testing.assert_allclose(np.asarray(got[0])[b], solo[0], atol=2e-4)
        for g, s_ in zip(got[1], solo[1]):
            np.testing.assert_allclose(np.asarray(g)[b], s_, atol=2e-4)
