"""Continuous-batching scheduler suite (docs/serve_scheduler.md).

What these tests pin:

  * the continuous scheduler's outputs AND final recurrent states are
    BIT-IDENTICAL to the round-based loop, per tenant, for all three DGNN
    families — under ragged arrivals, paged-pool eviction/recovery of an
    active tenant mid-stream, and chunked-prefill interleaving;
  * the paged tenant-state pool: LRU victim choice, block-table locations,
    bit-exact host round-trips, overflow rejection, end-of-run flush;
  * the fault contract holds unchanged under the scheduler: quarantine
    leaves survivors bit-identical, transient faults are retried from the
    rolled-back checkpoint, no producer threads leak;
  * the serve-path bugfix sweep: the measured promotion guard falls back
    to the static proxy PER MISS instead of raising a bare KeyError
    (recorded in ``ServeStats.calibration_fallback``), and measured-guard
    calibration never leaks into serve stats or fault occurrence windows
    (stats identical with ``promotion_guard`` "measured" vs "static").
"""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.dgnn import DGNNConfig
from repro.graph.coo import COOSnapshot
from repro.graph.padding import bucket_cost
from repro.serve import (
    FaultPlan,
    FaultSpec,
    InjectedFault,
    PoolOverflow,
    SnapshotServer,
    SupervisionPolicy,
    TenantStatePool,
    TenantSupervisor,
)

SEED = int(os.environ.get("CHAOS_SEED", "0"))
N_GLOBAL = 32
# all generated streams fit the small bucket (see tests/test_chaos.py)
BUCKETS = ((16, 32, 8), (32, 64, 8))
CHUNK = 2

FAMILIES = {
    "gcrn": DGNNConfig(name="sched-gcrn", dgnn_type="integrated", gnn="gcn",
                       rnn="lstm", dataflow="v3", in_dim=4, hidden=8,
                       out_dim=4, n_gnn_layers=1, edge_dim=2),
    "stacked": DGNNConfig(name="sched-stacked", dgnn_type="stacked",
                          gnn="gcn", rnn="gru", dataflow="v3", in_dim=4,
                          hidden=8, out_dim=4, n_gnn_layers=1, edge_dim=2),
    "evolve": DGNNConfig(name="sched-evolve", dgnn_type="weights_evolved",
                         gnn="gcn", rnn="gru", dataflow="v3", in_dim=4,
                         hidden=8, out_dim=4, n_gnn_layers=1, edge_dim=2),
}

_FEAT = np.asarray(
    np.random.default_rng(SEED).normal(size=(N_GLOBAL, 4)), np.float32)

# ragged arrivals: per-tenant stream lengths deliberately unequal
LENS = {"a": 7, "b": 3, "c": 5}


def _make_snaps(stream_ix, n_snap):
    r = np.random.default_rng(SEED * 7919 + stream_ix)
    out = []
    for t in range(n_snap):
        e = int(r.integers(3, 7))
        src = r.integers(0, N_GLOBAL, size=e)
        dst = r.choice(N_GLOBAL, size=e, replace=False)  # in-degree 1
        ef = np.asarray(r.normal(size=(e, 2)), np.float32)
        out.append(COOSnapshot(src=src, dst=dst, edge_feat=ef, t_index=t))
    return out


def _streams(lens=LENS):
    return {sid: _make_snaps(i, n)
            for i, (sid, n) in enumerate(sorted(lens.items()))}


def _server(family, **plan_kw):
    cfg = FAMILIES[family]
    plan = api.plan(cfg, level="v3", buckets=BUCKETS, stream_chunk=CHUNK,
                    **plan_kw)
    sess = api.BoosterSession(cfg, plan, n_global=N_GLOBAL, feat_table=_FEAT)
    return SnapshotServer(session=sess)


def _init(srv, sids):
    params, _ = srv.init(jax.random.PRNGKey(SEED))
    states = {sid: srv.model.init_state(params, mode=srv.mode)
              for sid in sids}
    return params, states


def _assert_tree_equal(a, b, msg=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


def _assert_same_serve(streams, st_a, outs_a, st_b, outs_b):
    for sid in streams:
        assert len(outs_a[sid]) == len(outs_b[sid]) == len(streams[sid])
        for t, (x, y) in enumerate(zip(outs_a[sid], outs_b[sid])):
            np.testing.assert_array_equal(x, y, err_msg=f"{sid} t={t}")
        _assert_tree_equal(st_a[sid], st_b[sid], msg=f"final state {sid}")


def _assert_no_serve_threads():
    leaked = [t.name for t in threading.enumerate()
              if t.name.startswith("dgnn-serve")]
    assert not leaked, f"leaked serve threads: {leaked}"


def _serve(family, streams, **plan_kw):
    srv = _server(family, **plan_kw)
    params, states = _init(srv, streams)
    return srv.run_multi(params, states, streams)


# ------------------------------------------------ differential equivalence ----


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_continuous_matches_rounds_bit_identical(family):
    """Tentpole invariant: continuous scheduling — arbitrary tick
    composition, pool pressure (2 pages for 3 tenants, so an ACTIVE tenant
    is evicted and recovered mid-stream), chunked prefill — serves every
    tenant bit-identically to the round-based barrier loop, outputs and
    final recurrent state both, under ragged arrivals."""
    streams = _streams()
    st_r, outs_r, stats_r = _serve(family, streams)
    st_c, outs_c, stats_c = _serve(family, streams,
                                   scheduler="continuous",
                                   state_pool_pages=2, prefill_chunk=1)
    _assert_same_serve(streams, st_r, outs_r, st_c, outs_c)
    assert stats_r.ticks == 0  # rounds loop reports no ticks
    assert stats_c.ticks > 0
    # 3 tenants through 2 pages: eviction/recovery genuinely exercised,
    # and every spill was eventually paged back in (flush included)
    assert stats_c.evictions > 0
    assert stats_c.recoveries == stats_c.evictions
    # every committed snapshot carries a commit timestamp
    assert {sid: len(v) for sid, v in stats_c.commit_ms.items()} == \
        {sid: len(s) for sid, s in streams.items()}
    _assert_no_serve_threads()


def test_chunked_prefill_interleaves_backlog():
    """A tenant with a deep snapshot backlog is served ``prefill_chunk``
    snapshots per tick, interleaved with the incremental tenants — the
    backlog never monopolizes launches — and the chunking is invisible in
    the outputs (bit-identical to the round-based run)."""
    streams = _streams({"deep": 16, "x": 3, "y": 3})
    st_r, outs_r, _ = _serve("gcrn", streams)
    # queue_depth > backlog so the producer hands the whole backlog to the
    # first admission pass and the prefill quota provably engages
    st_c, outs_c, stats = _serve("gcrn", streams, scheduler="continuous",
                                 prefill_chunk=1, queue_depth=32)
    _assert_same_serve(streams, st_r, outs_r, st_c, outs_c)
    assert stats.prefill_chunks > 0
    # the deep tenant needed many ticks; the incremental tenants' ticks
    # ran concurrently inside them, not after
    assert stats.ticks >= 16 // CHUNK


def test_forced_eviction_recovery_is_bit_exact_evolvegcn():
    """A 1-page pool over 3 tenants forces an eviction + recovery on
    nearly every tick; the family with the most failure-prone state (the
    evolving weight matrices) must serve bit-identically regardless."""
    streams = _streams()
    st_r, outs_r, _ = _serve("evolve", streams)
    st_c, outs_c, stats = _serve("evolve", streams, scheduler="continuous",
                                 state_pool_pages=1)
    _assert_same_serve(streams, st_r, outs_r, st_c, outs_c)
    assert stats.evictions >= 2
    assert stats.recoveries == stats.evictions
    # per-tenant counters surfaced through the supervision results
    assert sum(r.evictions for r in stats.tenants.values()) == stats.evictions


# ------------------------------------------------------- state pool unit ----


def test_tenant_state_pool_paging_unit():
    """Block-table locations, LRU victim order, bit-exact host round
    trips, overflow rejection, end-of-run flush."""
    sids = ["a", "b", "c"]
    sup = TenantSupervisor(sids, SupervisionPolicy(isolate=True))
    mk = lambda i: {"h": jnp.arange(4, dtype=jnp.float32) * (i + 1),
                    "c": jnp.ones((2, 2), jnp.float32) * (i + 1)}
    states = {sid: mk(i) for i, sid in enumerate(sids)}
    want = {sid: jax.tree.map(np.asarray, s) for sid, s in states.items()}
    pool = TenantStatePool(states, pages=2, supervisor=sup)
    # over-committed at construction: spilled down to capacity
    assert len(pool.resident) == 2 and len(pool.host_pages) == 1
    # acquiring the evicted tenant pages it back in, evicting the LRU
    # resident that is NOT in the working set
    (victim,) = set(sids) - pool.resident
    pool.acquire([victim])
    assert pool.location(victim) == "device"
    assert len(pool.resident) == 2
    # LRU: 'victim' is now MRU; acquiring the other evicted tenant must
    # not evict it
    (other,) = set(sids) - pool.resident
    pool.acquire([other])
    assert pool.location(victim) == "device"
    assert pool.location(other) == "device"
    with pytest.raises(PoolOverflow):
        pool.acquire(sids)
    with pytest.raises(KeyError):
        pool.location("nope")
    pool.flush()
    assert not pool.host_pages and pool.resident == set(sids)
    for sid in sids:  # f32 host round trip is bit-exact
        _assert_tree_equal(states[sid], want[sid], msg=sid)
    totals = sup.totals()
    assert totals["evictions"] == totals["recoveries"] > 0


def test_tenant_state_pool_hbm_paged_capacity_lift():
    """Under ``state_residency='hbm_paged'`` a tenant's device page is an
    HBM page, so the pool's EFFECTIVE capacity is
    ``pages * HBM_PAGE_FACTOR`` — the same nominal budget holds far more
    resident tenants, with zero evictions where the VMEM-resident pool
    would thrash."""
    from repro.serve.state_pool import HBM_PAGE_FACTOR

    sids = [f"t{i}" for i in range(5)]
    sup = TenantSupervisor(sids, SupervisionPolicy(isolate=True))
    mk = lambda: {"h": jnp.zeros(4, jnp.float32)}
    # VMEM-resident pool: 5 tenants over a 2-page budget spills 3
    vm = TenantStatePool({s: mk() for s in sids}, pages=2,
                         supervisor=sup, residency="vmem")
    assert vm.capacity == 2 and len(vm.host_pages) == 3
    # HBM-paged pool: same nominal budget, 2 * HBM_PAGE_FACTOR effective
    # pages — everyone stays resident, a full-set acquire is legal
    sup2 = TenantSupervisor(sids, SupervisionPolicy(isolate=True))
    hp = TenantStatePool({s: mk() for s in sids}, pages=2,
                         supervisor=sup2, residency="hbm_paged")
    assert hp.capacity == 2 * HBM_PAGE_FACTOR
    assert not hp.host_pages and hp.resident == set(sids)
    hp.acquire(sids)  # would raise PoolOverflow on the vmem pool
    with pytest.raises(PoolOverflow):
        vm.acquire(sids)
    # pages=None stays unbounded in both residencies
    assert TenantStatePool({s: mk() for s in sids}, pages=None,
                           supervisor=sup2,
                           residency="hbm_paged").capacity is None


# ------------------------------------------------------ chaos under ticks ----


def test_continuous_quarantine_leaves_survivors_bit_identical():
    """The docs/serve_robustness.md contract under the scheduler: a
    persistent launch fault pinned to tenant 'b' quarantines it while the
    survivors — co-batched across arbitrary tick compositions, through
    pool evictions — end bit-identical to a fault-free ROUND-BASED run."""
    streams = _streams()
    st_base, outs_base, _ = _serve("gcrn", streams)
    fp = FaultPlan(specs=(FaultSpec(site="launch", tenant="b", index=0,
                                    count=99),), seed=SEED)
    st, outs, stats = _serve("gcrn", streams, scheduler="continuous",
                             state_pool_pages=2, supervision="isolate",
                             fault_plan=fp)
    assert isinstance(stats.tenants["b"].error, InjectedFault)
    assert len(outs["b"]) < LENS["b"]
    for sid in ("a", "c"):
        assert stats.tenants[sid].ok
        for got, base in zip(outs[sid], outs_base[sid]):
            np.testing.assert_array_equal(got, base)
        _assert_tree_equal(st[sid], st_base[sid], msg=sid)
    _assert_no_serve_threads()


def test_continuous_transient_fault_retried_from_checkpoint():
    """A transient launch fault under the scheduler is replayed from the
    rolled-back checkpoint: nobody is quarantined and the evolving
    weights advance exactly once per served snapshot (final state equals
    the fault-free run EXACTLY)."""
    streams = _streams()
    st_base, outs_base, _ = _serve("evolve", streams)
    fp = FaultPlan(specs=(FaultSpec(site="launch", index=0, count=1),),
                   seed=SEED)
    st, outs, stats = _serve("evolve", streams, scheduler="continuous",
                             state_pool_pages=2, supervision="isolate",
                             max_retries=2, retry_backoff_ms=1.0,
                             fault_plan=fp)
    assert not stats.tenant_errors
    assert stats.retries >= 1 and stats.rollbacks >= 1
    _assert_same_serve(streams, st_base, outs_base, st, outs)


# ------------------------------------------- bugfix sweep regressions ----


def test_measured_cost_missing_bucket_falls_back_per_miss():
    """Satellite bugfix: a bucket absent from the measured calibration
    table must cost out via the static proxy for THAT bucket — never a
    bare KeyError mid-serve — and the miss is warned about and recorded
    in ``ServeStats.calibration_fallback``."""
    srv = _server("gcrn", promote_buckets=2.0, promotion_guard="measured")
    params, _ = _init(srv, ["a"])
    srv._bucket_ms = {BUCKETS[0]: 0.5}  # calibration "ran" but is partial
    cost = srv._promotion_cost(params)
    assert cost(BUCKETS[0]) == 0.5
    with pytest.warns(RuntimeWarning, match="missing from the measured"):
        got = cost(BUCKETS[1])
    assert got == bucket_cost(BUCKETS[1])
    assert "missing" in srv._calib_error
    # the recorded reason surfaces on the run's stats
    st, outs, stats = srv.run_multi(params, {"a": srv.model.init_state(
        params, mode=srv.mode)}, {"a": _make_snaps(0, 3)})
    assert "missing" in stats.calibration_fallback


def test_calibration_never_leaks_into_stats():
    """Satellite bugfix: measured-guard calibration launches are warm-up,
    not serving — every ServeStats counter and the output stream must be
    IDENTICAL between ``promotion_guard="measured"`` and ``"static"`` on
    a fault-free run."""
    streams = _streams()
    runs = {}
    for guard in ("static", "measured"):
        st, outs, stats = _serve("gcrn", streams, promote_buckets=100.0,
                                 promotion_guard=guard)
        runs[guard] = (st, outs, stats)
    st_s, outs_s, stats_s = runs["static"]
    st_m, outs_m, stats_m = runs["measured"]
    assert stats_m.calibration_fallback is None  # calibration succeeded
    _assert_same_serve(streams, st_s, outs_s, st_m, outs_m)
    for f in ("launches", "live_snapshots", "padded_snapshots",
              "promoted_chunks", "retries", "rollbacks",
              "degraded_launches", "timeouts", "ticks", "prefill_chunks"):
        assert getattr(stats_s, f) == getattr(stats_m, f), f
    assert len(stats_s.per_snapshot_ms) == len(stats_m.per_snapshot_ms)


def test_calibration_never_leaks_into_fault_windows():
    """An occurrence-indexed launch fault must fire on the same REAL
    launch whether or not calibration ran: calibration launches are
    exempt from launch-site occurrence counting, and (the concurrency
    half of the fix) host-site probes from producer threads are counted
    even while the calibration window's ``_fault_exempt`` flag is up."""
    streams = _streams()
    outcomes = {}
    for guard in ("static", "measured"):
        fp = FaultPlan(specs=(FaultSpec(site="launch", tenant="b", index=0,
                                        count=99),), seed=SEED)
        st, outs, stats = _serve("gcrn", streams, scheduler="continuous",
                                 promote_buckets=100.0,
                                 promotion_guard=guard,
                                 supervision="isolate", fault_plan=fp)
        assert isinstance(stats.tenants["b"].error, InjectedFault)
        outcomes[guard] = {sid: len(outs[sid]) for sid in streams}
    assert outcomes["static"] == outcomes["measured"]
    # concurrency half, pinned directly: _probe ignores _fault_exempt
    srv = _server("gcrn", supervision="isolate", fault_plan=FaultPlan(
        specs=(FaultSpec(site="preprocess", tenant="a", index=0),),
        seed=SEED))
    srv._fault_exempt = True  # a calibration window is open on another thread
    with pytest.raises(InjectedFault):
        srv._probe("preprocess", tenant="a")


# ------------------------------------------------------ plan validation ----


def test_plan_validates_scheduler_fields():
    cfg = FAMILIES["gcrn"]
    plan = api.plan(cfg, level="v3", scheduler="continuous",
                    state_pool_pages=4, prefill_chunk=2)
    assert plan.scheduler == "continuous"
    with pytest.raises(ValueError, match="scheduler"):
        api.plan(cfg, level="v3", scheduler="sometimes")
    with pytest.raises(ValueError, match="continuous"):
        api.plan(cfg, level="v2", scheduler="continuous")
    with pytest.raises(ValueError, match="state_pool_pages"):
        api.plan(cfg, level="v3", state_pool_pages=4)  # needs continuous
    with pytest.raises(ValueError, match="state_pool_pages"):
        api.plan(cfg, level="v3", scheduler="continuous", state_pool_pages=0)
    with pytest.raises(ValueError, match="prefill_chunk"):
        api.plan(cfg, level="v3", prefill_chunk=2)  # needs continuous
    with pytest.raises(ValueError, match="prefill_chunk"):
        api.plan(cfg, level="v3", scheduler="continuous", stream_chunk=4,
                 prefill_chunk=8)  # > stream_chunk
