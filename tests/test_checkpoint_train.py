"""Checkpoint manager + fault-tolerant train loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.optim import AdamWConfig
from repro.train import TrainLoopConfig, train


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,)), {"c": jnp.zeros((2, 2), jnp.int32)}]}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    t = _tree()
    mgr.save(5, t, blocking=True)
    back = mgr.restore(5, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_partial_checkpoints(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    # only fully published step dirs are listed
    os.makedirs(tmp_path / "tmp.99", exist_ok=True)  # simulated crash debris
    assert mgr.all_steps() == [1]


def test_keep_last_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_latest_and_missing(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() is None
    mgr.save(7, _tree(), blocking=True)
    assert mgr.latest_step() == 7


def _loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _batches(n, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(8, 1)).astype(np.float32)
    for _ in range(n):
        x = rng.normal(size=(32, 8)).astype(np.float32)
        yield {"x": jnp.asarray(x), "y": jnp.asarray(x @ w_true)}


def test_train_loop_learns_and_checkpoints(tmp_path):
    params = {"w": jnp.zeros((8, 1))}
    opt = AdamWConfig(lr=0.05, weight_decay=0.0)
    loop = TrainLoopConfig(total_steps=60, checkpoint_every=20,
                           checkpoint_dir=str(tmp_path), log_every=100)
    params, res = train(_loss_fn, params, _batches(60), opt, loop)
    assert res.final_step == 60
    assert res.losses[-1] < 0.1 * res.losses[0]
    mgr = CheckpointManager(str(tmp_path))
    assert 60 in mgr.all_steps()


def test_train_loop_resumes_from_checkpoint(tmp_path):
    params0 = {"w": jnp.zeros((8, 1))}
    opt = AdamWConfig(lr=0.05, weight_decay=0.0)
    loop1 = TrainLoopConfig(total_steps=30, checkpoint_every=10,
                            checkpoint_dir=str(tmp_path))
    _, r1 = train(_loss_fn, params0, _batches(30), opt, loop1)
    # "preemption": start fresh process-equivalent; must resume at step 30
    loop2 = TrainLoopConfig(total_steps=50, checkpoint_every=10,
                            checkpoint_dir=str(tmp_path))
    params2, r2 = train(_loss_fn, params0, _batches(50, seed=1), opt, loop2)
    assert r2.resumed_from == 30
    assert r2.final_step == 50
    assert r2.losses[0] < r1.losses[0]  # continued from trained weights


def test_straggler_counting(tmp_path):
    params = {"w": jnp.zeros((8, 1))}
    opt = AdamWConfig(lr=0.05)
    loop = TrainLoopConfig(total_steps=5, straggler_deadline_s=0.0)
    _, res = train(_loss_fn, params, _batches(5), opt, loop)
    assert res.straggler_steps == 5  # every step exceeds a 0-second deadline
