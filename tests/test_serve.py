"""Serving engine: host/device task split, double-buffered stream == offline."""
import jax
import numpy as np

from repro.configs.dgnn import DGNN_CONFIGS, GCRN_M2, UCI
from repro.core import build_model, run_stream, stack_time
from repro.graph import (
    generate_temporal_graph,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)
from repro.serve import SnapshotServer


def test_snapshot_server_matches_offline():
    tg, ft = generate_temporal_graph(UCI)
    snaps = slice_snapshots(tg, 1.0)[:6]
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes, mode="v2")
    params, state = srv.init(jax.random.PRNGKey(0))
    _, outs, stats = srv.run(params, state, snaps)
    assert len(outs) == 6
    assert stats.mean_latency_ms > 0
    assert len(stats.preprocess_ms) == 6
    # offline scan over the same padded stream gives identical outputs
    model = build_model(GCRN_M2, n_global=tg.n_global_nodes)
    pads = [pad_snapshot(renumber_and_normalize(s), ft, srv.n_pad, srv.e_pad,
                         srv.k_max) for s in snaps]
    st = model.init_state(params, mode="v2")
    _, offline = run_stream(model, params, st, stack_time(pads), mode="v2")
    for t in range(6):
        np.testing.assert_allclose(outs[t], np.asarray(offline)[t], atol=1e-5)


def test_snapshot_server_v3_stream_matches_offline():
    """The v3 fast path batches same-bucket snapshots into fixed-T chunks
    for the time-fused stream kernel (tail padded with no-op snapshots);
    outputs must equal the offline baseline scan."""
    tg, ft = generate_temporal_graph(UCI)
    snaps = slice_snapshots(tg, 1.0)[:6]
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes, mode="v3",
                         stream_chunk=4)  # 6 snaps -> 4 + padded tail of 2
    params, state = srv.init(jax.random.PRNGKey(0))
    final_state, outs, stats = srv.run(params, state, snaps)
    assert len(outs) == 6
    assert stats.mean_latency_ms > 0
    model = build_model(GCRN_M2, n_global=tg.n_global_nodes)
    pads = [pad_snapshot(renumber_and_normalize(s), ft, srv.n_pad, srv.e_pad,
                         srv.k_max) for s in snaps]
    st = model.init_state(params, mode="baseline")
    offline_state, offline = run_stream(model, params, st, stack_time(pads),
                                        mode="baseline")
    for t in range(6):
        np.testing.assert_allclose(outs[t], np.asarray(offline)[t], atol=1e-5)
    # the padded no-op tail must not disturb the recurrent state
    np.testing.assert_allclose(np.asarray(final_state["h"]),
                               np.asarray(offline_state["h"]), atol=1e-5)


def test_snapshot_server_spans_two_buckets():
    """Bucketed padding: a stream whose snapshots land in different buckets
    still produces offline-identical outputs (one compiled step per bucket,
    outputs shaped per bucket)."""
    from repro.graph import choose_bucket, max_in_degree

    tg, ft = generate_temporal_graph(UCI)
    snaps = slice_snapshots(tg, 1.0)[:8]
    buckets = ((256, 1024, 48), (640, 4096, 64))
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes,
                         mode="v2", buckets=buckets)
    params, state = srv.init(jax.random.PRNGKey(0))
    _, outs, _ = srv.run(params, state, snaps)
    assert len(outs) == 8
    # the stream must genuinely exercise both bucket sizes
    sizes = {o.shape[0] for o in outs}
    assert sizes == {256, 640}, sizes
    # offline replay with the same per-snapshot bucket choice
    model = build_model(GCRN_M2, n_global=tg.n_global_nodes)
    st = model.init_state(params, mode="v2")
    for t, s in enumerate(snaps):
        ls = renumber_and_normalize(s)
        b = choose_bucket(ls.n_nodes, ls.src.shape[0], max_in_degree(ls),
                          buckets)
        ps = pad_snapshot(ls, ft, *b)
        st, out = model.step(params, st, ps, mode="v2")
        np.testing.assert_allclose(outs[t], np.asarray(out), atol=1e-5,
                                   err_msg=f"t={t} bucket={b}")


def _forbid_per_step(srv):
    """Make the per-snapshot jitted step unusable: any fallback off the
    stream path fails loudly instead of silently degrading."""
    def boom(*a, **k):
        raise AssertionError("per-snapshot fallback taken — v3 must route "
                             "through the stream kernel")
    srv._step = boom


def test_snapshot_server_v3_evolvegcn_takes_stream_path():
    """EvolveGCN mode="v3" runs the weights-resident stream kernel in the
    server — NO per-snapshot fallback (regression: PR 2 fell back to v1
    stepping) — and the chunk's no-op tail snapshots must leave the
    evolving-weight state untouched (the final state equals the offline
    v1 scan over the LIVE snapshots only)."""
    cfg = DGNN_CONFIGS["evolvegcn"]
    tg, ft = generate_temporal_graph(UCI)
    # 7 snaps, stream_chunk=4 -> chunks of T=4 and T=3; the second pads to
    # the next power of two with ONE no-op tail snapshot (pow2(3) == 4),
    # so the single-tenant tail path is genuinely exercised.
    snaps = slice_snapshots(tg, 1.0)[:7]
    srv = SnapshotServer(cfg, ft, n_global=tg.n_global_nodes, mode="v3",
                         stream_chunk=4)
    _forbid_per_step(srv)
    params, state = srv.init(jax.random.PRNGKey(0))
    final_state, outs, _ = srv.run(params, state, snaps)
    assert len(outs) == 7
    model = build_model(cfg)
    pads = [pad_snapshot(renumber_and_normalize(s), ft, srv.n_pad, srv.e_pad,
                         srv.k_max) for s in snaps]
    st = model.init_state(params, mode="baseline")
    _, offline = run_stream(model, params, st, stack_time(pads),
                            mode="baseline")
    for t in range(7):
        np.testing.assert_allclose(outs[t], np.asarray(offline)[t], atol=1e-5)
    # evolving-weight state: equal to the v1 scan over the 7 live
    # snapshots — if the no-op tail step had evolved the weights, or the
    # kernel double-evolved at its first step, this diverges.
    st1 = model.init_state(params, mode="v1")
    off_state, _ = run_stream(model, params, st1, stack_time(pads),
                              mode="v1")
    for i, (got, want) in enumerate(zip(final_state["weights"],
                                        off_state["weights"])):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, err_msg=f"weights[{i}]")


def test_snapshot_server_no_fit_bucket_raises():
    """A snapshot that fits no bucket must raise in run(), not hang the
    consumer when the producer thread dies (regression)."""
    import pytest

    tg, ft = generate_temporal_graph(UCI)
    snaps = slice_snapshots(tg, 1.0)[:2]
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes, mode="v2",
                         buckets=((8, 8, 2),))
    params, state = srv.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="no bucket fits"):
        srv.run(params, state, snaps)


def _offline_outputs(cfg, tg, ft, params, snaps,
                     n_pad=640, e_pad=4096, k_max=64):
    """Ground truth: the baseline scan over one client's padded stream."""
    model = build_model(cfg, n_global=tg.n_global_nodes)
    pads = [pad_snapshot(renumber_and_normalize(s), ft, n_pad, e_pad, k_max)
            for s in snaps]
    st = model.init_state(params, mode="baseline")
    return run_stream(model, params, st, stack_time(pads), mode="baseline")


def test_run_multi_batched_v3_matches_per_stream_offline():
    """Multi-tenant batched V3: three clients with different streams and
    UNEVEN lengths (forcing no-op tail snapshots inside batched chunks).
    Every client's outputs must equal its own offline baseline, in its own
    snapshot order, and its final state must be undisturbed by the other
    tenants and by the no-op tails."""
    tg, ft = generate_temporal_graph(UCI)
    all_snaps = slice_snapshots(tg, 1.0)
    streams = {"a": all_snaps[:6], "b": all_snaps[4:9], "c": all_snaps[7:10]}
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes, mode="v3",
                         stream_chunk=4)  # 6 -> 4 + tail-padded chunk of 2
    params, _ = srv.init(jax.random.PRNGKey(0))
    states = {sid: srv.model.init_state(params, mode="v3") for sid in streams}
    states, outs, stats = srv.run_multi(params, states, streams)
    assert stats.mean_latency_ms > 0
    assert len(stats.preprocess_ms) == sum(len(s) for s in streams.values())
    for sid, snaps in streams.items():
        off_state, off = _offline_outputs(GCRN_M2, tg, ft, params, snaps,
                                          srv.n_pad, srv.e_pad, srv.k_max)
        assert len(outs[sid]) == len(snaps)
        for t in range(len(snaps)):
            np.testing.assert_allclose(outs[sid][t], np.asarray(off)[t],
                                       atol=1e-5, err_msg=f"{sid} t={t}")
        np.testing.assert_allclose(np.asarray(states[sid]["h"]),
                                   np.asarray(off_state["h"]), atol=1e-5,
                                   err_msg=f"{sid} final state")


def test_run_multi_bucketed_same_bucket_streams_share_launch():
    """With bucketed padding, same-bucket chunks from different clients
    batch into one V3 launch while off-bucket clients run separately —
    outputs stay offline-identical on the real-node rows either way."""
    tg, ft = generate_temporal_graph(UCI)
    all_snaps = slice_snapshots(tg, 1.0)
    streams = {"a": all_snaps[:4], "b": all_snaps[2:6], "c": all_snaps[5:9]}
    buckets = ((256, 1024, 48), (640, 4096, 64))
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes, mode="v3",
                         stream_chunk=4, buckets=buckets)
    params, _ = srv.init(jax.random.PRNGKey(0))
    states = {sid: srv.model.init_state(params, mode="v3") for sid in streams}
    states, outs, _ = srv.run_multi(params, states, streams)
    model = build_model(GCRN_M2, n_global=tg.n_global_nodes)
    for sid, snaps in streams.items():
        pads = [pad_snapshot(renumber_and_normalize(s), ft, 640, 4096, 64)
                for s in snaps]
        st = model.init_state(params, mode="baseline")
        _, off = run_stream(model, params, st, stack_time(pads),
                            mode="baseline")
        for t, s in enumerate(snaps):
            nr = renumber_and_normalize(s).n_nodes
            np.testing.assert_allclose(outs[sid][t][:nr],
                                       np.asarray(off)[t][:nr], atol=1e-5,
                                       err_msg=f"{sid} t={t}")


def _split_snaps_by_bucket(snaps, buckets):
    """Partition snapshots by the bucket choose_bucket assigns them."""
    from repro.graph import choose_bucket, max_in_degree

    by_bucket = {b: [] for b in buckets}
    for s in snaps:
        ls = renumber_and_normalize(s)
        b = choose_bucket(ls.n_nodes, ls.src.shape[0], max_in_degree(ls),
                          buckets)
        by_bucket[b].append(s)
    return by_bucket


def test_promote_bucket_groups_guard_and_chain():
    """Unit contract of the grouper helper: groups merge up the chain only
    while the padded-compute guard holds against each member's ORIGINAL
    bucket, and members are re-tagged to the target bucket."""
    from repro.graph import bucket_cost, promote_bucket_groups

    buckets = ((64, 256, 8), (128, 512, 16), (640, 4096, 64))
    small, mid, big = buckets
    groups = {small: [("a", ["s"], small)], mid: [("b", ["m"], mid)]}
    # generous guard: small promotes into mid (one launch)
    merged = promote_bucket_groups(groups, buckets,
                                   bucket_cost(mid) / bucket_cost(small))
    assert set(merged) == {mid}
    assert {sid for sid, _, _ in merged[mid]} == {"a", "b"}
    assert all(b == mid for _, _, b in merged[mid])
    # tight guard: no promotion
    assert set(promote_bucket_groups(groups, buckets, 1.0)) == {small, mid}
    # chain guard: with a guard that just covers mid -> big, a lone mid
    # group promotes into big ...
    groups3 = {mid: [("b", ["m"], mid)], big: [("c", ["g"], big)]}
    ratio_mid_big = bucket_cost(big) / bucket_cost(mid)
    merged3 = promote_bucket_groups(groups3, buckets, ratio_mid_big)
    assert set(merged3) == {big}
    # ... but after absorbing a small-bucket member, the second hop is
    # guarded against that member's ORIGINAL bucket and stays put
    groups4 = {small: [("a", ["s"], small)], mid: [("b", ["m"], mid)],
               big: [("c", ["g"], big)]}
    merged4 = promote_bucket_groups(groups4, buckets, ratio_mid_big)
    assert {s for s, _, _ in merged4[mid]} == {"a", "b"}
    assert {s for s, _, _ in merged4[big]} == {"c"}


def test_run_multi_bucket_promotion_joins_inflight_batch():
    """Cross-bucket batching: two clients whose chunks land in DIFFERENT
    buckets. Without promotion each round pays two batched launches; with
    ``promote_buckets`` the smaller chunk is promoted into the larger
    bucket's in-flight launch (one launch, promoted_chunks > 0, padding
    overhead visible in ServeStats) and every client's outputs stay
    offline-identical on its real-node rows. A tight guard (1.0) keeps
    promotion off."""
    tg, ft = generate_temporal_graph(UCI)
    buckets = ((256, 1024, 48), (640, 4096, 64))
    by_bucket = _split_snaps_by_bucket(slice_snapshots(tg, 1.0), buckets)
    small, big = (by_bucket[b] for b in buckets)
    assert len(small) >= 4 and len(big) >= 4, "dataset must span buckets"
    streams = {"s": small[:4], "b": big[:4]}

    def run(promote):
        srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes,
                             mode="v3", stream_chunk=4, buckets=buckets,
                             promote_buckets=promote)
        params, _ = srv.init(jax.random.PRNGKey(0))
        states = {sid: srv.model.init_state(params, mode="v3")
                  for sid in streams}
        _, outs, stats = srv.run_multi(params, states, streams)
        return outs, stats

    outs_off, stats_off = run(None)
    assert stats_off.launches == 2 and stats_off.promoted_chunks == 0
    outs_tight, stats_tight = run(1.0)       # guard blocks promotion
    assert stats_tight.launches == 2 and stats_tight.promoted_chunks == 0
    outs_on, stats_on = run(100.0)           # generous guard: one launch
    assert stats_on.launches == 1
    assert stats_on.promoted_chunks == 1
    # the promoted chunk's padding overhead is reported, not hidden
    assert stats_on.live_snapshots == 8
    assert stats_on.padded_snapshots >= stats_off.padded_snapshots
    # outputs stay offline-identical on real-node rows, promoted or not
    model = build_model(GCRN_M2, n_global=tg.n_global_nodes)
    srv0 = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes)
    params, _ = srv0.init(jax.random.PRNGKey(0))
    for outs in (outs_on, outs_tight, outs_off):
        for sid, snaps in streams.items():
            pads = [pad_snapshot(renumber_and_normalize(s), ft, 640, 4096,
                                 64) for s in snaps]
            st = model.init_state(params, mode="baseline")
            _, off = run_stream(model, params, st, stack_time(pads),
                                mode="baseline")
            for t, s in enumerate(snaps):
                nr = renumber_and_normalize(s).n_nodes
                np.testing.assert_allclose(outs[sid][t][:nr],
                                           np.asarray(off)[t][:nr],
                                           atol=1e-5, err_msg=f"{sid} t={t}")


def test_run_multi_adaptive_promotion_guard_measured():
    """promotion_guard="measured": the server calibrates per-bucket step
    times with a tiny warmup (one timed empty-chunk launch per bucket) and
    guards promotion with the MEASURED ratio instead of the static
    n_pad*(k_max+1) proxy. Outputs stay offline-identical and a generous
    ratio still merges the two buckets into one launch."""
    from repro import api
    from repro.graph import bucket_cost, promote_bucket_groups

    tg, ft = generate_temporal_graph(UCI)
    buckets = ((256, 1024, 48), (640, 4096, 64))
    by_bucket = _split_snaps_by_bucket(slice_snapshots(tg, 1.0), buckets)
    small, big = (by_bucket[b] for b in buckets)
    streams = {"s": small[:4], "b": big[:4]}
    plan = api.plan(DGNN_CONFIGS["gcrn-m2"], level="v3", stream_chunk=4,
                    buckets=buckets, promote_buckets=1e6,
                    promotion_guard="measured")
    srv = SnapshotServer(n_global=tg.n_global_nodes, feat_table=ft,
                         session=api.BoosterSession(
                             DGNN_CONFIGS["gcrn-m2"], plan,
                             n_global=tg.n_global_nodes, feat_table=ft))
    params, _ = srv.init(jax.random.PRNGKey(0))
    states = {sid: srv.model.init_state(params, mode="v3")
              for sid in streams}
    states, outs, stats = srv.run_multi(params, states, streams)
    # calibration happened: one measured positive step time per bucket
    assert srv._bucket_ms is not None
    assert set(srv._bucket_ms) == set(buckets)
    assert all(t > 0 for t in srv._bucket_ms.values())
    # generous measured guard merged the buckets into one launch
    assert stats.launches == 1 and stats.promoted_chunks == 1
    # outputs stay offline-identical on real-node rows
    model = build_model(DGNN_CONFIGS["gcrn-m2"], n_global=tg.n_global_nodes)
    for sid, snaps in streams.items():
        pads = [pad_snapshot(renumber_and_normalize(s), ft, 640, 4096, 64)
                for s in snaps]
        st = model.init_state(params, mode="baseline")
        _, off = run_stream(model, params, st, stack_time(pads),
                            mode="baseline")
        for t, s in enumerate(snaps):
            nr = renumber_and_normalize(s).n_nodes
            np.testing.assert_allclose(outs[sid][t][:nr],
                                       np.asarray(off)[t][:nr], atol=1e-5,
                                       err_msg=f"{sid} t={t}")
    # the measured costs actually drive the guard: a ratio below the
    # measured big/small quotient blocks promotion that the static proxy
    # (or a bigger ratio) would allow
    ms = srv._bucket_ms
    ratio = ms[buckets[1]] / ms[buckets[0]]
    groups = {buckets[0]: [("s", ["x"], buckets[0])],
              buckets[1]: [("b", ["y"], buckets[1])]}
    merged = promote_bucket_groups(groups, buckets, ratio * 0.5,
                                   cost=lambda b: ms[b])
    assert set(merged) == set(buckets)  # measured guard blocks
    merged = promote_bucket_groups(groups, buckets, ratio * 2.0,
                                   cost=lambda b: ms[b])
    assert set(merged) == {buckets[1]}  # measured guard allows
    # static proxy remains the default cost
    merged = promote_bucket_groups(groups, buckets,
                                   bucket_cost(buckets[1])
                                   / bucket_cost(buckets[0]))
    assert set(merged) == {buckets[1]}


def test_run_multi_producer_exception_propagates():
    """A no-fit snapshot in ONE tenant's stream must raise out of
    run_multi (not hang the round loop) and leave the producer threads
    joinable — the multi-tenant edition of the producer-crash regression."""
    import pytest

    tg, ft = generate_temporal_graph(UCI)
    all_snaps = slice_snapshots(tg, 1.0)
    streams = {"ok": all_snaps[:3], "bad": all_snaps[3:6]}
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes, mode="v3",
                         buckets=((8, 8, 2),))  # nothing fits
    params, _ = srv.init(jax.random.PRNGKey(0))
    states = {sid: srv.model.init_state(params, mode="v3") for sid in streams}
    with pytest.raises(ValueError, match="no bucket fits"):
        srv.run_multi(params, states, streams)


def test_run_multi_evolvegcn_takes_batched_stream_path():
    """EvolveGCN joins the multi-tenant batched V3 launch: run_multi must
    NOT take the per-snapshot round-robin path (regression: PR 2 fell
    back for the weights-evolved family). Uneven stream lengths force
    no-op tail snapshots AND a no-op padding stream in the batch — each
    client's outputs and final evolving weights must still equal its own
    offline run."""
    cfg = DGNN_CONFIGS["evolvegcn"]
    tg, ft = generate_temporal_graph(UCI)
    all_snaps = slice_snapshots(tg, 1.0)
    streams = {"x": all_snaps[:4], "y": all_snaps[1:6], "z": all_snaps[3:6]}
    srv = SnapshotServer(cfg, ft, n_global=tg.n_global_nodes, mode="v3",
                         stream_chunk=4)
    _forbid_per_step(srv)
    params, _ = srv.init(jax.random.PRNGKey(0))
    states = {sid: srv.model.init_state(params, mode="v3") for sid in streams}
    states, outs, _ = srv.run_multi(params, states, streams)
    model = build_model(cfg)
    for sid, snaps in streams.items():
        _, off = _offline_outputs(cfg, tg, ft, params, snaps)
        assert len(outs[sid]) == len(snaps)
        for t in range(len(snaps)):
            np.testing.assert_allclose(outs[sid][t], np.asarray(off)[t],
                                       atol=1e-5, err_msg=f"{sid} t={t}")
        pads = [pad_snapshot(renumber_and_normalize(s), ft, srv.n_pad,
                             srv.e_pad, srv.k_max) for s in snaps]
        st1 = model.init_state(params, mode="v1")
        off_state, _ = run_stream(model, params, st1, stack_time(pads),
                                  mode="v1")
        for i, (got, want) in enumerate(zip(states[sid]["weights"],
                                            off_state["weights"])):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), atol=1e-5,
                err_msg=f"{sid} weights[{i}] disturbed by co-tenants or "
                        "no-op padding")


def test_lm_generate_greedy_deterministic():
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduce_for_smoke
    from repro.models import RuntimeConfig, init_params
    from repro.serve import generate

    cfg = reduce_for_smoke(ARCHS["granite-moe-3b-a800m"])
    rt = RuntimeConfig(tp=1, moe_impl="dense", attn_chunk=64)
    params, _ = init_params(cfg, rt, jax.random.PRNGKey(0))
    prompt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out1 = generate(params, cfg, rt, prompt, steps=5, skv=32)
    out2 = generate(params, cfg, rt, prompt, steps=5, skv=32)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert (np.asarray(out1) < cfg.vocab_size).all()  # padding never sampled
