"""Serving engine: host/device task split, double-buffered stream == offline."""
import jax
import numpy as np

from repro.configs.dgnn import GCRN_M2, UCI
from repro.core import build_model, run_stream, stack_time
from repro.graph import (
    generate_temporal_graph,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)
from repro.serve import SnapshotServer


def test_snapshot_server_matches_offline():
    tg, ft = generate_temporal_graph(UCI)
    snaps = slice_snapshots(tg, 1.0)[:6]
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes, mode="v2")
    params, state = srv.init(jax.random.PRNGKey(0))
    _, outs, stats = srv.run(params, state, snaps)
    assert len(outs) == 6
    assert stats.mean_latency_ms > 0
    assert len(stats.preprocess_ms) == 6
    # offline scan over the same padded stream gives identical outputs
    model = build_model(GCRN_M2, n_global=tg.n_global_nodes)
    pads = [pad_snapshot(renumber_and_normalize(s), ft, srv.n_pad, srv.e_pad,
                         srv.k_max) for s in snaps]
    st = model.init_state(params, mode="v2")
    _, offline = run_stream(model, params, st, stack_time(pads), mode="v2")
    for t in range(6):
        np.testing.assert_allclose(outs[t], np.asarray(offline)[t], atol=1e-5)


def test_lm_generate_greedy_deterministic():
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduce_for_smoke
    from repro.models import RuntimeConfig, init_params
    from repro.serve import generate

    cfg = reduce_for_smoke(ARCHS["granite-moe-3b-a800m"])
    rt = RuntimeConfig(tp=1, moe_impl="dense", attn_chunk=64)
    params, _ = init_params(cfg, rt, jax.random.PRNGKey(0))
    prompt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out1 = generate(params, cfg, rt, prompt, steps=5, skv=32)
    out2 = generate(params, cfg, rt, prompt, steps=5, skv=32)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert (np.asarray(out1) < cfg.vocab_size).all()  # padding never sampled
