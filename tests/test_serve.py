"""Serving engine: host/device task split, double-buffered stream == offline."""
import jax
import numpy as np

from repro.configs.dgnn import DGNN_CONFIGS, GCRN_M2, UCI
from repro.core import build_model, run_stream, stack_time
from repro.graph import (
    generate_temporal_graph,
    pad_snapshot,
    renumber_and_normalize,
    slice_snapshots,
)
from repro.serve import SnapshotServer


def test_snapshot_server_matches_offline():
    tg, ft = generate_temporal_graph(UCI)
    snaps = slice_snapshots(tg, 1.0)[:6]
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes, mode="v2")
    params, state = srv.init(jax.random.PRNGKey(0))
    _, outs, stats = srv.run(params, state, snaps)
    assert len(outs) == 6
    assert stats.mean_latency_ms > 0
    assert len(stats.preprocess_ms) == 6
    # offline scan over the same padded stream gives identical outputs
    model = build_model(GCRN_M2, n_global=tg.n_global_nodes)
    pads = [pad_snapshot(renumber_and_normalize(s), ft, srv.n_pad, srv.e_pad,
                         srv.k_max) for s in snaps]
    st = model.init_state(params, mode="v2")
    _, offline = run_stream(model, params, st, stack_time(pads), mode="v2")
    for t in range(6):
        np.testing.assert_allclose(outs[t], np.asarray(offline)[t], atol=1e-5)


def test_snapshot_server_v3_stream_matches_offline():
    """The v3 fast path batches same-bucket snapshots into fixed-T chunks
    for the time-fused stream kernel (tail padded with no-op snapshots);
    outputs must equal the offline baseline scan."""
    tg, ft = generate_temporal_graph(UCI)
    snaps = slice_snapshots(tg, 1.0)[:6]
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes, mode="v3",
                         stream_chunk=4)  # 6 snaps -> 4 + padded tail of 2
    params, state = srv.init(jax.random.PRNGKey(0))
    final_state, outs, stats = srv.run(params, state, snaps)
    assert len(outs) == 6
    assert stats.mean_latency_ms > 0
    model = build_model(GCRN_M2, n_global=tg.n_global_nodes)
    pads = [pad_snapshot(renumber_and_normalize(s), ft, srv.n_pad, srv.e_pad,
                         srv.k_max) for s in snaps]
    st = model.init_state(params, mode="baseline")
    offline_state, offline = run_stream(model, params, st, stack_time(pads),
                                        mode="baseline")
    for t in range(6):
        np.testing.assert_allclose(outs[t], np.asarray(offline)[t], atol=1e-5)
    # the padded no-op tail must not disturb the recurrent state
    np.testing.assert_allclose(np.asarray(final_state["h"]),
                               np.asarray(offline_state["h"]), atol=1e-5)


def test_snapshot_server_spans_two_buckets():
    """Bucketed padding: a stream whose snapshots land in different buckets
    still produces offline-identical outputs (one compiled step per bucket,
    outputs shaped per bucket)."""
    from repro.graph import choose_bucket, max_in_degree

    tg, ft = generate_temporal_graph(UCI)
    snaps = slice_snapshots(tg, 1.0)[:8]
    buckets = ((256, 1024, 48), (640, 4096, 64))
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes,
                         mode="v2", buckets=buckets)
    params, state = srv.init(jax.random.PRNGKey(0))
    _, outs, _ = srv.run(params, state, snaps)
    assert len(outs) == 8
    # the stream must genuinely exercise both bucket sizes
    sizes = {o.shape[0] for o in outs}
    assert sizes == {256, 640}, sizes
    # offline replay with the same per-snapshot bucket choice
    model = build_model(GCRN_M2, n_global=tg.n_global_nodes)
    st = model.init_state(params, mode="v2")
    for t, s in enumerate(snaps):
        ls = renumber_and_normalize(s)
        b = choose_bucket(ls.n_nodes, ls.src.shape[0], max_in_degree(ls),
                          buckets)
        ps = pad_snapshot(ls, ft, *b)
        st, out = model.step(params, st, ps, mode="v2")
        np.testing.assert_allclose(outs[t], np.asarray(out), atol=1e-5,
                                   err_msg=f"t={t} bucket={b}")


def test_snapshot_server_v3_evolvegcn_fallback_matches_offline():
    """EvolveGCN has no step_stream, so the server's v3 engine takes the
    per-step path; its step() must treat v3 as the v1 schedule, NOT evolve
    the primed weights a second time (regression)."""
    cfg = DGNN_CONFIGS["evolvegcn"]
    tg, ft = generate_temporal_graph(UCI)
    snaps = slice_snapshots(tg, 1.0)[:5]
    srv = SnapshotServer(cfg, ft, n_global=tg.n_global_nodes, mode="v3")
    params, state = srv.init(jax.random.PRNGKey(0))
    _, outs, _ = srv.run(params, state, snaps)
    model = build_model(cfg)
    pads = [pad_snapshot(renumber_and_normalize(s), ft, srv.n_pad, srv.e_pad,
                         srv.k_max) for s in snaps]
    st = model.init_state(params, mode="baseline")
    _, offline = run_stream(model, params, st, stack_time(pads),
                            mode="baseline")
    for t in range(5):
        np.testing.assert_allclose(outs[t], np.asarray(offline)[t], atol=1e-5)


def test_snapshot_server_no_fit_bucket_raises():
    """A snapshot that fits no bucket must raise in run(), not hang the
    consumer when the producer thread dies (regression)."""
    import pytest

    tg, ft = generate_temporal_graph(UCI)
    snaps = slice_snapshots(tg, 1.0)[:2]
    srv = SnapshotServer(GCRN_M2, ft, n_global=tg.n_global_nodes, mode="v2",
                         buckets=((8, 8, 2),))
    params, state = srv.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="no bucket fits"):
        srv.run(params, state, snaps)


def test_lm_generate_greedy_deterministic():
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduce_for_smoke
    from repro.models import RuntimeConfig, init_params
    from repro.serve import generate

    cfg = reduce_for_smoke(ARCHS["granite-moe-3b-a800m"])
    rt = RuntimeConfig(tp=1, moe_impl="dense", attn_chunk=64)
    params, _ = init_params(cfg, rt, jax.random.PRNGKey(0))
    prompt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out1 = generate(params, cfg, rt, prompt, steps=5, skv=32)
    out2 = generate(params, cfg, rt, prompt, steps=5, skv=32)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert (np.asarray(out1) < cfg.vocab_size).all()  # padding never sampled
