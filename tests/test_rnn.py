"""RNN cells: fused (O1) == staged gates; matrix GRU evolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rnn as R


@pytest.mark.parametrize("din,h,b", [(16, 32, 5), (64, 64, 1), (128, 96, 7)])
def test_gru_fused_equals_staged(din, h, b):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    p = R.init_gru(k1, din, h)
    x = jax.random.normal(k2, (b, din))
    hh = jax.random.normal(k3, (b, h))
    np.testing.assert_allclose(
        R.gru_cell(p, x, hh, fused=True),
        R.gru_cell(p, x, hh, fused=False), atol=1e-6)


@pytest.mark.parametrize("din,h,b", [(16, 32, 5), (64, 64, 3)])
def test_lstm_fused_equals_staged(din, h, b):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(1), 4)
    p = R.init_lstm(k1, din, h)
    x = jax.random.normal(k2, (b, din))
    hh = jax.random.normal(k3, (b, h))
    cc = jax.random.normal(k4, (b, h))
    a = R.lstm_cell(p, x, hh, cc, fused=True)
    bb = R.lstm_cell(p, x, hh, cc, fused=False)
    np.testing.assert_allclose(a[0], bb[0], atol=1e-6)
    np.testing.assert_allclose(a[1], bb[1], atol=1e-6)


def test_lstm_forget_bias():
    p = R.init_lstm(jax.random.PRNGKey(0), 8, 16)
    f = p["b"][16:32]
    np.testing.assert_allclose(f, 1.0)


def test_matrix_gru_shape_and_evolution():
    din, dout = 24, 40
    p = R.init_gru(jax.random.PRNGKey(0), din, din)
    w = jax.random.normal(jax.random.PRNGKey(1), (din, dout))
    w1 = R.matrix_gru(p, w)
    assert w1.shape == w.shape
    w2 = R.matrix_gru(p, w1)
    # weights actually evolve and stay bounded (GRU output in tanh range mix)
    assert not np.allclose(w1, w)
    assert not np.allclose(w2, w1)
    assert np.isfinite(w2).all()


def test_matrix_gru_is_columnwise():
    """Each output column depends only on the same input column."""
    din, dout = 8, 6
    p = R.init_gru(jax.random.PRNGKey(0), din, din)
    w = jax.random.normal(jax.random.PRNGKey(1), (din, dout))
    w1 = R.matrix_gru(p, w)
    w_mod = w.at[:, 2].set(0.0)
    w1_mod = R.matrix_gru(p, w_mod)
    # column 2 changes, others identical
    np.testing.assert_allclose(np.delete(np.asarray(w1), 2, axis=1),
                               np.delete(np.asarray(w1_mod), 2, axis=1),
                               atol=1e-6)
    assert not np.allclose(w1[:, 2], w1_mod[:, 2])
